//! Integration: the cluster-scale shard/merge contract end-to-end.
//!
//! For shard counts N ∈ {1, 2, 3, 5}, running a grid as N independent
//! sharded checkpointed runs and merging the partials — in any (seeded,
//! shuffled) input order — must reproduce the unsharded run's
//! `summary.csv` byte-for-byte and agree on the manifest content hash.
//! N = 5 over a 4-cell grid covers the empty-shard case: a shard that
//! owns nothing still writes a valid, mergeable manifest.

use powertrace_sim::aggregate::Topology;
use powertrace_sim::api::{
    self, CheckpointedOutcome, RunKind, RunOptions, RunRequest, RunSpec,
};
use powertrace_sim::config::{ScenarioSpec, ServerAssignment, WorkloadSpec};
use powertrace_sim::coordinator::Generator;
use powertrace_sim::robust::merge::merge_manifests;
use powertrace_sim::robust::RunManifest;
use powertrace_sim::scenarios::{GridDefaults, SweepGrid, SWEEP_MANIFEST};
use powertrace_sim::shard::Shard;
use powertrace_sim::site::{SiteGrid, SiteSpec, SITE_SWEEP_MANIFEST};
use powertrace_sim::testutil::{check_seeded, synth_generator};
use std::path::PathBuf;

/// 2 workloads × 1 topology × 1 fleet × 2 seeds = 4 cells, 40 s horizon.
fn small_grid(id: &str) -> SweepGrid {
    SweepGrid {
        name: "shard-itest".into(),
        defaults: GridDefaults { horizon_s: 40.0, ..GridDefaults::default() },
        workloads: vec![
            WorkloadSpec::Poisson { rate: 0.5 },
            WorkloadSpec::Mmpp { mean_rate: 0.5, burstiness: 4.0 },
        ],
        topologies: vec![Topology { rows: 1, racks_per_row: 1, servers_per_rack: 2 }],
        fleets: vec![ServerAssignment::Uniform(id.to_string())],
        seeds: vec![3, 4],
    }
}

/// 1 phase spread × 2 seeds = 2 variants over a 2-facility, 40 s site.
fn site_grid(id: &str) -> SiteGrid {
    let mut scenario = ScenarioSpec::default_poisson(id, 0.5);
    scenario.topology = Topology { rows: 1, racks_per_row: 1, servers_per_rack: 2 };
    scenario.horizon_s = 40.0;
    let mut base = SiteSpec::staggered("shard", &scenario, 2, 0.0);
    base.utility_intervals_s = vec![15.0, 30.0];
    SiteGrid {
        name: "shard-site".into(),
        base,
        phase_spreads_h: vec![0.0],
        seeds: vec![0, 7],
        battery_kwh: Vec::new(),
        cap_w: Vec::new(),
        battery: None,
    }
}

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("powertrace_test_shard_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn run_checkpointed(gen: &mut Generator, req: &RunRequest, dir: &std::path::Path) {
    api::execute_checkpointed(gen, req, dir).unwrap();
}

/// Seeded Fisher–Yates over the input order — "merged in any order".
fn shuffled(dirs: &[PathBuf], rng: &mut impl FnMut() -> f64) -> Vec<PathBuf> {
    let mut order: Vec<PathBuf> = dirs.to_vec();
    for i in (1..order.len()).rev() {
        let j = (rng() * (i + 1) as f64) as usize;
        order.swap(i, j.min(i));
    }
    order
}

#[test]
fn sharded_sweeps_merge_to_unsharded_bytes_for_every_partition() {
    let (mut gen, ids) = synth_generator("shard_sweep", 8, 4, 1, 61).unwrap();
    let grid = small_grid(&ids[0]);
    let options = RunOptions::defaults_for(RunKind::Sweep);

    // The unsharded reference: summary bytes + manifest content hash.
    let ref_dir = temp_dir("sweep_ref");
    let req = RunRequest { spec: RunSpec::Sweep(grid.clone()), options: options.clone() };
    run_checkpointed(&mut gen, &req, &ref_dir);
    let reference = std::fs::read(ref_dir.join("summary.csv")).unwrap();
    let ref_hash = RunManifest::load(&ref_dir.join(SWEEP_MANIFEST)).unwrap().grid_hash;

    for count in [1usize, 2, 3, 5] {
        let dirs: Vec<PathBuf> = (0..count)
            .map(|i| {
                let dir = temp_dir(&format!("sweep_{i}_of_{count}"));
                let shard = Shard::new(i, count).unwrap();
                let req = RunRequest {
                    spec: RunSpec::Sweep(grid.clone()),
                    options: options.clone().with_shard(Some(shard)),
                };
                run_checkpointed(&mut gen, &req, &dir);
                // Every shard binds to the unsharded content hash.
                let m = RunManifest::load(&dir.join(SWEEP_MANIFEST)).unwrap();
                assert_eq!(m.grid_hash, ref_hash, "shard {i}/{count}");
                dir
            })
            .collect();
        if count == 5 {
            // Pigeonhole: 4 cells over 5 shards leaves an empty shard,
            // whose manifest must still be valid and mergeable.
            let empty = dirs
                .iter()
                .filter(|d| {
                    RunManifest::load(&d.join(SWEEP_MANIFEST)).unwrap().done_count() == 0
                })
                .count();
            assert!(empty >= 1, "5 shards of 4 cells must include an empty shard");
        }
        check_seeded(&format!("merge order, {count} shards"), 0xD1CE, 4, |rng| {
            let order = shuffled(&dirs, &mut || rng.f64());
            let out = temp_dir(&format!("sweep_merged_{count}"));
            let rep = merge_manifests(&order, &out, false).unwrap();
            assert_eq!((rep.cells, rep.done), (4, 4));
            assert_eq!(
                std::fs::read(&rep.summary_path).unwrap(),
                reference,
                "{count} shards merged != unsharded bytes"
            );
            let merged = RunManifest::load(&rep.manifest_path).unwrap();
            assert_eq!(merged.grid_hash, ref_hash);
            assert!(merged.options.get_opt("shard").is_none(), "merged manifest keeps no shard");
            let _ = std::fs::remove_dir_all(&out);
        });
        for d in &dirs {
            let _ = std::fs::remove_dir_all(d);
        }
    }
    let _ = std::fs::remove_dir_all(&ref_dir);
}

#[test]
fn sharded_site_sweep_merges_to_unsharded_bytes() {
    let (mut gen, ids) = synth_generator("shard_site", 8, 4, 1, 67).unwrap();
    let grid = site_grid(&ids[0]);
    let options = RunOptions::defaults_for(RunKind::SiteSweep)
        .with_dt(0.25)
        .with_window(7.0)
        .with_load_interval(1.0);

    let ref_dir = temp_dir("site_ref");
    let req = RunRequest { spec: RunSpec::SiteSweep(grid.clone()), options: options.clone() };
    let CheckpointedOutcome::SiteSweep(out) =
        api::execute_checkpointed(&mut gen, &req, &ref_dir).unwrap()
    else {
        unreachable!()
    };
    assert_eq!(out.executed.len(), 2);
    let reference = std::fs::read(ref_dir.join("site_sweep_summary.csv")).unwrap();
    let ref_hash = RunManifest::load(&ref_dir.join(SITE_SWEEP_MANIFEST)).unwrap().grid_hash;

    let dirs: Vec<PathBuf> = (0..2usize)
        .map(|i| {
            let dir = temp_dir(&format!("site_{i}_of_2"));
            let req = RunRequest {
                spec: RunSpec::SiteSweep(grid.clone()),
                options: options.clone().with_shard(Some(Shard::new(i, 2).unwrap())),
            };
            run_checkpointed(&mut gen, &req, &dir);
            dir
        })
        .collect();

    // Both input orders assemble the same bytes as the unsharded run.
    for order in [vec![dirs[0].clone(), dirs[1].clone()], vec![dirs[1].clone(), dirs[0].clone()]] {
        let out_dir = temp_dir("site_merged");
        let rep = merge_manifests(&order, &out_dir, false).unwrap();
        assert_eq!(rep.kind, "site_sweep");
        assert_eq!(std::fs::read(&rep.summary_path).unwrap(), reference);
        assert_eq!(RunManifest::load(&rep.manifest_path).unwrap().grid_hash, ref_hash);
        let _ = std::fs::remove_dir_all(&out_dir);
    }
    for d in dirs.iter().chain([&ref_dir]) {
        let _ = std::fs::remove_dir_all(d);
    }
}
