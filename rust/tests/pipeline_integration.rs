//! Integration: the full generation pipeline against held-out measured
//! traces (paper-level correctness), cross-engine testbed consistency, and
//! the facility coordinator.

use powertrace_sim::aggregate::Topology;
use powertrace_sim::config::{ScenarioSpec, ServerAssignment, WorkloadSpec};
use powertrace_sim::coordinator::Generator;
use powertrace_sim::experiments::common::ACF_MAX_LAG;
use powertrace_sim::metrics::{self, fidelity};
use powertrace_sim::testbed::{simulate, EngineOptions};
use powertrace_sim::testutil::synth_generator;
use powertrace_sim::util::json;
use powertrace_sim::util::rng::Rng;
use powertrace_sim::workload::{replay, Request};

fn generator() -> Option<Generator> {
    match Generator::native() {
        Ok(g) => Some(g),
        Err(e) => {
            eprintln!("skipping pipeline integration tests: {e:#}");
            None
        }
    }
}

#[test]
fn dense_energy_error_within_band_on_held_out_traces() {
    let Some(mut gen) = generator() else { return };
    // First dense config in the manifest.
    let ids = gen.store.manifest.configs.clone();
    let id = ids
        .iter()
        .find(|i| i.starts_with("llama8b") || i.starts_with("llama70b"))
        .expect("a dense config");
    let art = gen.config(id).unwrap();
    let cls = gen.classifier(&art).unwrap();
    let measured = gen.store.load_all_measured(id).unwrap();

    let mut des = Vec::new();
    for m in &measured {
        let mut seed_des = Vec::new();
        for seed in 0..3u64 {
            let mut rng = Rng::new(100 + seed);
            let intervals = powertrace_sim::surrogate::simulate_queue(
                &m.schedule,
                &art.surrogate,
                gen.cat.campaign.max_batch,
                &mut rng,
            );
            let feats = powertrace_sim::surrogate::features_from_intervals(
                &intervals,
                m.power_w.len(),
                m.dt_s,
            );
            let probs = powertrace_sim::classifier::StateClassifier::probs(
                &cls,
                &feats.interleaved(),
                m.power_w.len(),
            )
            .unwrap();
            let k = art.k;
            let kmax = powertrace_sim::classifier::StateClassifier::k_max(&cls);
            let mut live = vec![0.0f32; m.power_w.len() * k];
            for t in 0..m.power_w.len() {
                live[t * k..(t + 1) * k].copy_from_slice(&probs[t * kmax..t * kmax + k]);
            }
            let states = powertrace_sim::synth::sample_states(&live, k, &mut rng);
            let syn = powertrace_sim::synth::sample_power(&states, &art.dict, art.mode, &mut rng);
            seed_des.push(metrics::delta_energy(&m.power_w, &syn).abs() * 100.0);
        }
        des.push(metrics::median(&seed_des));
    }
    let med = metrics::median(&des);
    // Paper: median |ΔE| below 5% for most dense configs; allow slack for
    // the scaled-down single-core training budget.
    assert!(med < 10.0, "{id}: median |dE| {med:.1}% too high ({des:?})");
}

#[test]
fn synthesis_preserves_marginal_distribution() {
    let Some(mut gen) = generator() else { return };
    let id = gen.store.manifest.configs[0].clone();
    let art = gen.config(&id).unwrap();
    let cls = gen.classifier(&art).unwrap();
    let measured = gen.store.load_all_measured(&id).unwrap();
    let m = &measured[measured.len() - 1];
    let mut rng = Rng::new(3);
    let tr = gen
        .server_trace(&art, &cls, &m.schedule, m.power_w.len() as f64 * m.dt_s, m.dt_s, &mut rng)
        .unwrap();
    let f = fidelity(&m.power_w, &tr.power_w, ACF_MAX_LAG);
    assert!(f.ks < 0.5, "KS too high: {}", f.ks);
    assert!(f.nrmse < 1.0, "NRMSE too high: {}", f.nrmse);
    // Samples clipped to observed range.
    for &p in &tr.power_w {
        assert!((p as f64) >= art.dict.y_min - 1e-3 && (p as f64) <= art.dict.y_max + 1e-3);
    }
}

#[test]
fn rust_testbed_statistics_match_python_exported_traces() {
    // Cross-engine consistency: replay the exported schedule through the
    // Rust testbed and compare power statistics with the Python-generated
    // measured trace (same catalog truth, different RNG draws).
    let Some(gen) = generator() else { return };
    let id = gen.store.manifest.configs[0].clone();
    let measured = gen.store.load_all_measured(&id).unwrap();
    let cfg = gen.cat.config(&id).unwrap();
    // Use the highest-rate trace (most signal).
    let m = measured
        .iter()
        .max_by(|a, b| a.rate.partial_cmp(&b.rate).unwrap())
        .unwrap();
    let horizon = m.power_w.len() as f64 * m.dt_s;
    let opts = EngineOptions::from_catalog(&gen.cat, horizon);
    let mut rng = Rng::new(17);
    let tr = simulate(&gen.cat, cfg, &m.schedule, &opts, &mut rng);
    let mean_py: f64 = m.power_w.iter().map(|&x| x as f64).sum::<f64>() / m.power_w.len() as f64;
    let mean_rs: f64 = tr.power_w.iter().map(|&x| x as f64).sum::<f64>() / tr.power_w.len() as f64;
    let rel = (mean_rs - mean_py).abs() / mean_py;
    assert!(rel < 0.03, "engines diverge: python {mean_py:.1} W vs rust {mean_rs:.1} W ({rel:.3})");
    // Occupancy trajectories should correlate strongly (same scheduler).
    let n = m.a_measured.len().min(tr.a_measured.len());
    let corr = powertrace_sim::experiments::common::pearson(&m.a_measured[..n], &tr.a_measured[..n]);
    assert!(corr > 0.95, "occupancy corr {corr}");
}

#[test]
fn facility_coordinator_end_to_end() {
    let Some(mut gen) = generator() else { return };
    let id = gen.store.manifest.configs[0].clone();
    let mut spec = ScenarioSpec::default_poisson(&id, 0.5);
    spec.topology = Topology { rows: 1, racks_per_row: 2, servers_per_rack: 2 };
    spec.server_config = ServerAssignment::Uniform(id.clone());
    spec.workload = WorkloadSpec::Poisson { rate: 0.5 };
    spec.horizon_s = 300.0;
    spec.seed = 11;

    let run = gen.facility(&spec, 0.25, 2).unwrap();
    assert_eq!(run.acc.servers_added(), 4);
    let it = run.it_series();
    let site = run.facility_series();
    assert_eq!(it.len(), 1200);
    // PUE scaling exact.
    for (a, b) in it.iter().zip(&site) {
        assert!((b / a - spec.pue as f32).abs() < 1e-4);
    }
    // Non-GPU base power present: site ≥ servers × p_base × PUE.
    let floor = (4.0 * spec.p_base_w * spec.pue) as f32;
    assert!(site.iter().all(|&p| p >= floor));

    // Determinism: same seed → identical site series.
    let run2 = gen.facility(&spec, 0.25, 1).unwrap();
    assert_eq!(run.facility_series(), run2.facility_series());
}

/// Byte-level equality of two facility runs: the IT series, the PCC
/// series, and every per-rack buffer.
fn assert_runs_identical(
    a: &powertrace_sim::coordinator::FacilityResult,
    b: &powertrace_sim::coordinator::FacilityResult,
    ctx: &str,
) {
    let (ita, itb) = (a.it_series(), b.it_series());
    assert_eq!(ita.len(), itb.len(), "{ctx}: length mismatch");
    for (i, (x, y)) in ita.iter().zip(&itb).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: IT sample {i}: {x} vs {y}");
    }
    assert_eq!(a.facility_series(), b.facility_series(), "{ctx}: PCC series");
    for rack in 0..a.scenario.topology.n_racks() {
        assert_eq!(a.acc.rack_series(rack), b.acc.rack_series(rack), "{ctx}: rack {rack}");
    }
}

#[test]
fn batched_facility_is_bit_identical_to_sequential() {
    // The acceptance invariant of the batched engine: for a fixed
    // (spec, seed), facility output is byte-identical across the
    // sequential path (max_batch = 1, the pre-batching pipeline), the
    // default batched path, and a ragged sub-batch split — at any worker
    // count. Runs against a synthetic artifact store so it needs no
    // `make artifacts`.
    let (mut gen, ids) = synth_generator("batch_determinism", 16, 5, 1, 7).unwrap();
    let mut spec = ScenarioSpec::default_poisson(&ids[0], 1.0);
    // 5 servers/rack: batch width 5 (non-multiple of any SIMD lane width),
    // and max_batch = 3 splits it into ragged sub-batches of 3 + 2.
    spec.topology = Topology { rows: 1, racks_per_row: 2, servers_per_rack: 5 };
    spec.horizon_s = 120.0;
    spec.seed = 42;
    gen.prepare_for(&spec).unwrap();
    let sequential = gen.facility_shared_batched(&spec, 0.25, 2, 1).unwrap();
    let batched = gen.facility_shared_batched(&spec, 0.25, 3, 0).unwrap();
    let split = gen.facility_shared_batched(&spec, 0.25, 1, 3).unwrap();
    assert_eq!(sequential.acc.servers_added(), 10);
    assert_runs_identical(&sequential, &batched, "sequential vs default-batched");
    assert_runs_identical(&sequential, &split, "sequential vs max_batch=3");
}

#[test]
fn batched_facility_handles_long_horizons_with_tiling() {
    // 2400 steps (> any small tile, < BATCH_TILE) plus a worker count
    // exceeding racks: exercises the carry/checkpoint logic end to end.
    let (mut gen, ids) = synth_generator("batch_tiling", 8, 4, 1, 9).unwrap();
    let mut spec = ScenarioSpec::default_poisson(&ids[0], 0.8);
    spec.topology = Topology { rows: 1, racks_per_row: 1, servers_per_rack: 4 };
    spec.horizon_s = 600.0;
    spec.seed = 5;
    gen.prepare_for(&spec).unwrap();
    let sequential = gen.facility_shared_batched(&spec, 0.25, 1, 1).unwrap();
    let batched = gen.facility_shared_batched(&spec, 0.25, 4, 0).unwrap();
    assert_runs_identical(&sequential, &batched, "long-horizon batched");
}

#[test]
fn windowed_streaming_facility_is_bit_identical_to_buffered() {
    // The streaming-engine acceptance invariant: generating window-by-
    // window (ragged final window, ragged sub-batches, any window size)
    // reassembles the buffered facility run bit-for-bit — per-rack series,
    // site IT series, and the PCC f32 series the stats consume.
    let (mut gen, ids) = synth_generator("windowed_parity", 16, 5, 1, 23).unwrap();
    let mut spec = ScenarioSpec::default_poisson(&ids[0], 1.0);
    spec.topology = Topology { rows: 1, racks_per_row: 2, servers_per_rack: 5 };
    spec.horizon_s = 120.0; // 480 steps at dt=0.25
    spec.seed = 42;
    gen.prepare_for(&spec).unwrap();
    let buffered = gen.facility_shared_batched(&spec, 0.25, 2, 3).unwrap();
    let n_racks = spec.topology.n_racks();

    // 7.25 s windows → 29 steps; 480 = 16×29 + 16 → ragged final window.
    for window_s in [7.25, 120.0, 1000.0] {
        let mut racks: Vec<Vec<f32>> = vec![Vec::new(); n_racks];
        let mut site_f32: Vec<f32> = Vec::new();
        let mut rows_buf = Vec::new();
        let mut site_buf = Vec::new();
        gen.facility_shared_windowed(&spec, 0.25, window_s, 3, 3, |acc| {
            acc.fold_rows_site(&mut rows_buf, &mut site_buf);
            for (r, col) in racks.iter_mut().enumerate() {
                col.extend(acc.rack_window(r).iter().map(|&x| x as f32));
            }
            site_f32.extend(site_buf.iter().map(|&x| x as f32));
            Ok(())
        })
        .unwrap();
        for r in 0..n_racks {
            let reference = buffered.acc.rack_series(r);
            assert_eq!(racks[r].len(), reference.len(), "window {window_s}: rack {r} length");
            for (t, (a, b)) in racks[r].iter().zip(&reference).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "window {window_s}: rack {r} t {t}: {a} vs {b}"
                );
            }
        }
        let reference = buffered.it_series();
        for (t, (a, b)) in site_f32.iter().zip(&reference).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "window {window_s}: site t {t}");
        }
    }
}

#[test]
fn windowed_streaming_is_worker_and_batch_invariant() {
    // Same streamed output for any worker count and batching width
    // (max_batch = 1 drives the batched engine at B = 1).
    let (mut gen, ids) = synth_generator("windowed_invariance", 8, 4, 1, 29).unwrap();
    let mut spec = ScenarioSpec::default_poisson(&ids[0], 0.8);
    spec.topology = Topology { rows: 2, racks_per_row: 2, servers_per_rack: 3 };
    spec.horizon_s = 60.0;
    spec.seed = 9;
    gen.prepare_for(&spec).unwrap();
    let collect = |gen: &powertrace_sim::coordinator::Generator, workers, max_batch| {
        let mut site = Vec::new();
        let mut rows_buf = Vec::new();
        let mut site_buf = Vec::new();
        gen.facility_shared_windowed(&spec, 0.25, 11.0, workers, max_batch, |acc| {
            acc.fold_rows_site(&mut rows_buf, &mut site_buf);
            site.extend(site_buf.iter().map(|&x| x as f32));
            Ok(())
        })
        .unwrap();
        site
    };
    let a = collect(&gen, 1, 0);
    let b = collect(&gen, 4, 0);
    let c = collect(&gen, 2, 1);
    assert_eq!(a, b, "worker-count invariance");
    assert_eq!(a, c, "batch-width invariance");
}

#[test]
fn concurrent_replay_of_two_paths_parses_each_once() {
    // The per-path replay cache: many threads replaying two different
    // paths concurrently must all get correct schedules, and both paths
    // must be served from cache afterwards (files deleted). The old
    // implementation held one global lock across file I/O; this exercises
    // the per-path double-checked locking under real contention.
    let (mut gen, ids) = synth_generator("replay_two_paths", 8, 4, 1, 13).unwrap();
    let dir = std::env::temp_dir().join("powertrace_test_replay_two_paths");
    std::fs::create_dir_all(&dir).unwrap();
    let path_a = dir.join("sched_a.json");
    let path_b = dir.join("sched_b.json");
    let sched_a: Vec<Request> =
        (0..40).map(|i| Request { arrival_s: 1.2 * i as f64, n_in: 128, n_out: 64 }).collect();
    let sched_b: Vec<Request> =
        (0..25).map(|i| Request { arrival_s: 2.0 * i as f64, n_in: 64, n_out: 32 }).collect();
    json::write_file(&path_a, &replay::schedule_to_json(&sched_a)).unwrap();
    json::write_file(&path_b, &replay::schedule_to_json(&sched_b)).unwrap();

    let mk_spec = |path: &std::path::Path| {
        let mut spec = ScenarioSpec::default_poisson(&ids[0], 1.0);
        spec.workload =
            WorkloadSpec::Replay { path: path.to_str().unwrap().into(), offset_s: 0.0 };
        spec.horizon_s = 60.0;
        spec
    };
    let spec_a = mk_spec(&path_a);
    let spec_b = mk_spec(&path_b);
    gen.prepare_for(&spec_a).unwrap();
    let base = powertrace_sim::util::rng::Rng::new(5);
    let gen_ref = &gen;
    std::thread::scope(|scope| {
        for worker in 0..8usize {
            let (spec_a, spec_b, base) = (&spec_a, &spec_b, &base);
            scope.spawn(move || {
                for round in 0..16 {
                    let s = (worker + round) % 4;
                    let a = gen_ref.schedule_for(spec_a, s, base).unwrap();
                    let b = gen_ref.schedule_for(spec_b, s, base).unwrap();
                    // horizon 60 s clips nothing here; both full schedules.
                    assert_eq!(a.len(), 40);
                    assert_eq!(b.len(), 25);
                }
            });
        }
    });
    // Cached: files can vanish, both paths still served.
    std::fs::remove_file(&path_a).unwrap();
    std::fs::remove_file(&path_b).unwrap();
    assert_eq!(gen.schedule_for(&spec_a, 0, &base).unwrap().len(), 40);
    assert_eq!(gen.schedule_for(&spec_b, 0, &base).unwrap().len(), 25);
}

#[test]
fn replay_trace_loaded_exactly_once_per_path() {
    // schedule_for must serve every server from one parsed copy of the
    // replay file. Observable proof: after the first facility run the file
    // can disappear from disk and generation still succeeds; a fresh
    // generator (empty cache) fails on the same spec.
    let (mut gen, ids) = synth_generator("replay_cache", 8, 4, 1, 11).unwrap();
    let dir = std::env::temp_dir().join("powertrace_test_replay_cache");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("base_schedule.json");
    let sched: Vec<Request> = (0..40)
        .map(|i| Request { arrival_s: 1.5 * i as f64, n_in: 128, n_out: 64 })
        .collect();
    json::write_file(&path, &replay::schedule_to_json(&sched)).unwrap();

    let mut spec = ScenarioSpec::default_poisson(&ids[0], 1.0);
    spec.workload = WorkloadSpec::Replay { path: path.to_str().unwrap().into(), offset_s: 10.0 };
    spec.topology = Topology { rows: 1, racks_per_row: 2, servers_per_rack: 3 };
    spec.horizon_s = 60.0;
    spec.seed = 3;
    gen.prepare_for(&spec).unwrap();
    let first = gen.facility_shared(&spec, 0.25, 2).unwrap();
    std::fs::remove_file(&path).unwrap();
    let second = gen.facility_shared(&spec, 0.25, 2).unwrap();
    assert_eq!(first.facility_series(), second.facility_series());

    let (mut gen2, _) = synth_generator("replay_cache_fresh", 8, 4, 1, 11).unwrap();
    gen2.prepare_for(&spec).unwrap();
    assert!(
        gen2.facility_shared(&spec, 0.25, 1).is_err(),
        "fresh generator must fail once the replay file is gone"
    );
}

#[test]
fn heterogeneous_assignment_uses_both_configs() {
    let Some(mut gen) = generator() else { return };
    let ids = gen.store.manifest.configs.clone();
    if ids.len() < 2 {
        return;
    }
    let mut spec = ScenarioSpec::default_poisson(&ids[0], 0.5);
    spec.topology = Topology { rows: 1, racks_per_row: 2, servers_per_rack: 1 };
    spec.server_config = ServerAssignment::PerRack(vec![ids[0].clone(), ids[1].clone()]);
    spec.horizon_s = 120.0;
    let run = gen.facility(&spec, 0.25, 1).unwrap();
    assert_eq!(run.acc.servers_added(), 2);
    // Two different configs → the two rack series differ.
    assert_ne!(run.acc.rack_series(0), run.acc.rack_series(1));
}
