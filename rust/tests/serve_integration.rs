//! Integration: the live planning service end-to-end, in process — a
//! [`Server`] over a synthetic artifact store, driven by a raw
//! `std::net` HTTP client.
//!
//! The load-bearing contract is byte identity: replaying a run's
//! streamed NDJSON sink events reconstructs exactly the directory a
//! [`DirSink`] run of the same [`RunRequest`] writes. Everything else —
//! status/health/catalog endpoints, prepared-cache sharing across
//! concurrent requests, checkpointed `--runs-dir` execution, request
//! validation — is exercised around that pin.

use powertrace_sim::aggregate::Topology;
use powertrace_sim::api::{self, RunKind, RunOptions, RunRequest, RunSpec};
use powertrace_sim::artifacts::ArtifactStore;
use powertrace_sim::catalog::Catalog;
use powertrace_sim::config::{ScenarioSpec, ServerAssignment, WorkloadSpec};
use powertrace_sim::coordinator::Generator;
use powertrace_sim::export::DirSink;
use powertrace_sim::scenarios::{GridDefaults, SweepGrid};
use powertrace_sim::serve::sink::{reconstruct, SinkEvent};
use powertrace_sim::serve::{ServeConfig, Server};
use powertrace_sim::shard::Shard;
use powertrace_sim::site::{SiteGrid, SiteSpec};
use powertrace_sim::testutil::synth_artifact_store;
use powertrace_sim::util::json::{self, Json};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::{Path, PathBuf};

// ---------------------------------------------------------------------------
// Harness: store + generators, raw HTTP client, NDJSON decoding
// ---------------------------------------------------------------------------

/// Two generators over ONE synthetic store (bytes depend on the full
/// ordered config list, so reference and server must share a root), plus
/// the store root and the config ids it covers.
fn paired_generators(tag: &str, seed: u64) -> (Generator, Generator, PathBuf, Vec<String>) {
    let cat = Catalog::load_default().unwrap();
    let ids: Vec<String> = cat.config_ids().into_iter().take(1).collect();
    assert!(!ids.is_empty());
    let root = synth_artifact_store(tag, 8, 4, &ids, seed);
    let a = ArtifactStore::open(&root).unwrap();
    let b = ArtifactStore::open(&root).unwrap();
    (Generator::native_with(cat.clone(), a), Generator::native_with(cat, b), root, ids)
}

fn serve(gen: Generator, runs_dir: Option<PathBuf>) -> powertrace_sim::serve::ServerHandle {
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        max_concurrent_runs: 2,
        runs_dir,
        refresh_interval_s: 0.0,
    };
    Server::new(gen, &cfg).unwrap().spawn().unwrap()
}

/// One request over a fresh connection; returns (status, head, body) with
/// chunked transfer decoded. Reads to EOF — the server closes per request.
fn send_request(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: Option<&str>,
) -> (u16, String, Vec<u8>) {
    let mut stream = TcpStream::connect(addr).unwrap();
    let mut head = format!("{method} {path} HTTP/1.1\r\nHost: test\r\n");
    if let Some(b) = body {
        head.push_str(&format!("Content-Length: {}\r\n", b.len()));
    }
    head.push_str("Connection: close\r\n\r\n");
    stream.write_all(head.as_bytes()).unwrap();
    if let Some(b) = body {
        stream.write_all(b.as_bytes()).unwrap();
    }
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).unwrap();
    let split = raw.windows(4).position(|w| w == b"\r\n\r\n").expect("header terminator");
    let head = String::from_utf8_lossy(&raw[..split]).to_string();
    let status: u16 = head.split_whitespace().nth(1).unwrap().parse().unwrap();
    let mut payload = raw[split + 4..].to_vec();
    if head.to_ascii_lowercase().contains("transfer-encoding: chunked") {
        payload = decode_chunked(&payload);
    }
    (status, head, payload)
}

fn decode_chunked(mut b: &[u8]) -> Vec<u8> {
    let mut out = Vec::new();
    loop {
        let pos = b.windows(2).position(|w| w == b"\r\n").expect("chunk size line");
        let size =
            usize::from_str_radix(std::str::from_utf8(&b[..pos]).unwrap().trim(), 16).unwrap();
        b = &b[pos + 2..];
        if size == 0 {
            break;
        }
        out.extend_from_slice(&b[..size]);
        b = &b[size + 2..]; // payload + CRLF
    }
    out
}

fn body_json(payload: &[u8]) -> Json {
    json::parse(std::str::from_utf8(payload).unwrap()).unwrap()
}

/// Split a decoded NDJSON stream into control lines (accepted/done/error)
/// and replayable sink events — the documented client-side protocol.
fn split_events(ndjson: &[u8]) -> (Vec<Json>, Vec<SinkEvent>) {
    let text = std::str::from_utf8(ndjson).unwrap();
    let mut control = Vec::new();
    let mut events = Vec::new();
    for line in text.lines().filter(|l| !l.trim().is_empty()) {
        let v = json::parse(line).unwrap();
        let path = || v.str_field("path").unwrap();
        let data = || v.str_field("data").unwrap().into_bytes();
        match v.str_field("event").unwrap().as_str() {
            "open" => events.push(SinkEvent::Open { path: path() }),
            "append" => events.push(SinkEvent::Append { path: path(), data: data() }),
            "close" => events.push(SinkEvent::Close { path: path() }),
            "file" => events.push(SinkEvent::File { path: path(), data: data() }),
            _ => control.push(v),
        }
    }
    (control, events)
}

fn walk_dir(root: &Path) -> BTreeMap<String, Vec<u8>> {
    fn rec(base: &Path, dir: &Path, out: &mut BTreeMap<String, Vec<u8>>) {
        for entry in std::fs::read_dir(dir).unwrap() {
            let p = entry.unwrap().path();
            if p.is_dir() {
                rec(base, &p, out);
            } else {
                let rel = p.strip_prefix(base).unwrap().to_string_lossy().replace('\\', "/");
                out.insert(rel, std::fs::read(&p).unwrap());
            }
        }
    }
    let mut out = BTreeMap::new();
    rec(root, root, &mut out);
    out
}

fn assert_stream_matches_dir(payload: &[u8], dir: &Path, kind: &str) -> Vec<Json> {
    let (control, events) = split_events(payload);
    assert_eq!(control.first().unwrap().str_field("event").unwrap(), "accepted");
    assert_eq!(control.first().unwrap().str_field("kind").unwrap(), kind);
    assert_eq!(control.last().unwrap().str_field("event").unwrap(), "done", "{control:?}");
    let streamed = reconstruct(&events);
    let on_disk = walk_dir(dir);
    assert_eq!(
        streamed.keys().collect::<Vec<_>>(),
        on_disk.keys().collect::<Vec<_>>(),
        "file sets differ for kind {kind}"
    );
    for (path, bytes) in &on_disk {
        assert_eq!(&streamed[path], bytes, "bytes differ at {path} for kind {kind}");
    }
    control
}

// ---------------------------------------------------------------------------
// Fixtures
// ---------------------------------------------------------------------------

/// A 2-facility site over 1×2×2 halls and a 60 s horizon, matching the
/// site_integration fixtures.
fn small_site(id: &str) -> SiteSpec {
    let mut s = ScenarioSpec::default_poisson(id, 0.5);
    s.topology = Topology { rows: 1, racks_per_row: 2, servers_per_rack: 2 };
    s.horizon_s = 60.0;
    s.seed = 5;
    let mut spec = SiteSpec::staggered("served", &s, 2, 0.0);
    spec.utility_intervals_s = vec![15.0, 30.0];
    spec
}

fn site_request(id: &str) -> RunRequest {
    RunRequest {
        spec: RunSpec::Site(small_site(id)),
        options: RunOptions::defaults_for(RunKind::Site)
            .with_dt(0.25)
            .with_window(7.0)
            .with_load_interval(1.0),
    }
}

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(name);
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

// ---------------------------------------------------------------------------
// Tests
// ---------------------------------------------------------------------------

/// The tentpole pin: the streamed NDJSON of a site run reconstructs
/// byte-for-byte the DirSink directory of the same RunRequest, and the
/// windows arrive incrementally (many appends, not one blob). Rides
/// along: status / healthz / catalog smokes against the same server.
#[test]
fn streamed_site_run_byte_equals_dirsink_export() {
    let (mut gref, gsrv, _root, ids) = paired_generators("serve_site_bytes", 11);
    let req = site_request(&ids[0]);
    let dir = tmp_dir("powertrace_test_serve_site_ref");
    let sink = DirSink::new(&dir);
    api::execute(&mut gref, &req, Some(&sink)).unwrap();

    let handle = serve(gsrv, None);
    let body = json::to_string(&req.to_json());
    let (status, head, payload) = send_request(handle.addr(), "POST", "/v1/runs", Some(&body));
    assert_eq!(status, 200);
    assert!(head.to_ascii_lowercase().contains("application/x-ndjson"), "{head}");
    let control = assert_stream_matches_dir(&payload, &dir, "site");
    let run_id = control[0].str_field("run_id").unwrap();

    // Incremental streaming: site_load.csv rows arrived as multiple
    // appends across the 7 s windows, not one buffered write.
    let (_, events) = split_events(&payload);
    let load_appends = events
        .iter()
        .filter(|e| matches!(e, SinkEvent::Append { path, .. } if path == "site_load.csv"))
        .count();
    assert!(load_appends > 1, "expected windowed appends, got {load_appends}");

    // Status: the registry knows the finished run.
    let (status, _, payload) =
        send_request(handle.addr(), "GET", &format!("/v1/runs/{run_id}"), None);
    assert_eq!(status, 200);
    let v = body_json(&payload);
    assert_eq!(v.str_field("state").unwrap(), "done");
    assert_eq!(v.str_field("kind").unwrap(), "site");

    // Health: the shared generator kept the request's config warm.
    let (status, _, payload) = send_request(handle.addr(), "GET", "/healthz", None);
    assert_eq!(status, 200);
    let v = body_json(&payload);
    assert_eq!(v.str_field("status").unwrap(), "ok");
    let prepared = v.get("prepared_configs").unwrap().as_arr().unwrap();
    assert!(prepared.iter().any(|p| p.as_str().unwrap() == ids[0]), "{prepared:?}");

    // Catalog: serving configurations are listed.
    let (status, _, payload) = send_request(handle.addr(), "GET", "/v1/catalog", None);
    assert_eq!(status, 200);
    let v = body_json(&payload);
    assert!(!v.get("configs").unwrap().as_arr().unwrap().is_empty());

    handle.stop().unwrap();
}

/// The same pin for the buffered kinds: facility (a degenerate one-cell
/// sweep) and sweep stream their one-shot exports as `file` events that
/// replay to the DirSink bytes.
#[test]
fn streamed_facility_and_sweep_runs_byte_equal_dirsink_exports() {
    let (mut gref, gsrv, _root, ids) = paired_generators("serve_fac_sweep_bytes", 13);

    let mut scenario = ScenarioSpec::default_poisson(&ids[0], 0.5);
    scenario.topology = Topology { rows: 1, racks_per_row: 2, servers_per_rack: 2 };
    scenario.horizon_s = 60.0;
    scenario.seed = 5;
    let fac_req = RunRequest::new(RunSpec::Facility(scenario));

    let grid = SweepGrid {
        name: "served_grid".to_string(),
        defaults: GridDefaults { horizon_s: 60.0, ..GridDefaults::default() },
        workloads: vec![WorkloadSpec::Poisson { rate: 0.5 }],
        topologies: vec![Topology { rows: 1, racks_per_row: 2, servers_per_rack: 2 }],
        fleets: vec![ServerAssignment::Uniform(ids[0].clone())],
        seeds: vec![5, 9],
    };
    let sweep_req = RunRequest::new(RunSpec::Sweep(grid));

    let fac_dir = tmp_dir("powertrace_test_serve_fac_ref");
    let sweep_dir = tmp_dir("powertrace_test_serve_sweep_ref");
    api::execute(&mut gref, &fac_req, Some(&DirSink::new(&fac_dir))).unwrap();
    api::execute(&mut gref, &sweep_req, Some(&DirSink::new(&sweep_dir))).unwrap();

    let handle = serve(gsrv, None);
    for (req, dir, kind) in [(&fac_req, &fac_dir, "facility"), (&sweep_req, &sweep_dir, "sweep")] {
        let body = json::to_string(&req.to_json());
        let (status, _, payload) = send_request(handle.addr(), "POST", "/v1/runs", Some(&body));
        assert_eq!(status, 200, "kind {kind}");
        assert_stream_matches_dir(&payload, dir, kind);
    }
    handle.stop().unwrap();
}

/// Two concurrent site requests run against one warm generator; a third
/// request still succeeds after the artifact store is deleted from disk —
/// proof the requests share the prepared-config cache rather than
/// re-reading artifacts.
#[test]
fn concurrent_site_requests_share_the_prepared_cache() {
    let (_gref, gsrv, root, ids) = paired_generators("serve_cache", 17);
    let handle = serve(gsrv, None);
    let addr = handle.addr();
    let body = json::to_string(&site_request(&ids[0]).to_json());

    let payloads: Vec<Vec<u8>> = std::thread::scope(|scope| {
        let workers: Vec<_> = (0..2)
            .map(|_| {
                let body = body.clone();
                scope.spawn(move || {
                    let (status, _, payload) = send_request(addr, "POST", "/v1/runs", Some(&body));
                    assert_eq!(status, 200);
                    payload
                })
            })
            .collect();
        workers.into_iter().map(|w| w.join().unwrap()).collect()
    });
    let files_a = reconstruct(&split_events(&payloads[0]).1);
    let files_b = reconstruct(&split_events(&payloads[1]).1);
    assert_eq!(files_a, files_b, "concurrent identical requests must produce identical bytes");
    assert!(!files_a.is_empty());

    // The store is gone; only the in-memory prepared cache can serve this.
    std::fs::remove_dir_all(&root).unwrap();
    let (status, _, payload) = send_request(addr, "POST", "/v1/runs", Some(&body));
    assert_eq!(status, 200);
    let files_c = reconstruct(&split_events(&payload).1);
    assert_eq!(files_a, files_c, "cached-config run must reproduce the first run's bytes");

    handle.stop().unwrap();
}

/// Request validation happens before any stream starts: malformed bodies,
/// unknown kinds, and invalid specs are plain HTTP errors.
#[test]
fn malformed_requests_are_rejected_before_streaming() {
    let (_gref, gsrv, _root, _ids) = paired_generators("serve_400", 19);
    let handle = serve(gsrv, None);
    let addr = handle.addr();

    let (status, _, payload) = send_request(addr, "POST", "/v1/runs", Some("not json"));
    assert_eq!(status, 400);
    assert!(body_json(&payload).str_field("error").is_ok());

    let (status, _, _) =
        send_request(addr, "POST", "/v1/runs", Some(r#"{"kind": "mystery", "spec": {}}"#));
    assert_eq!(status, 400);

    let (status, _, payload) =
        send_request(addr, "POST", "/v1/runs", Some(r#"{"kind": "site", "spec": {"name": "x"}}"#));
    assert_eq!(status, 400);
    assert!(body_json(&payload).str_field("error").unwrap().contains("invalid RunRequest"));

    // A typo'd option must not silently run with defaults.
    let req = r#"{"kind": "site", "spec": {"name": "x"}, "options": {"dt": 1.0}}"#;
    let (status, _, _) = send_request(addr, "POST", "/v1/runs", Some(req));
    assert_eq!(status, 400);

    let (status, _, _) = send_request(addr, "GET", "/v1/runs/ghost", None);
    assert_eq!(status, 404);
    let (status, _, _) = send_request(addr, "GET", "/nope", None);
    assert_eq!(status, 404);
    let (status, _, _) = send_request(addr, "DELETE", "/healthz", None);
    assert_eq!(status, 405);

    handle.stop().unwrap();
}

/// With `--runs-dir`, sweep kinds execute checkpointed: the summary comes
/// back in one JSON body, the durable PR-7 manifest lands on disk under
/// `<runs_dir>/<run-id>/`, and the status endpoint folds its cell ledger.
#[test]
fn runs_dir_executes_sweep_kinds_checkpointed_with_manifest_status() {
    let (_gref, gsrv, _root, ids) = paired_generators("serve_runsdir", 23);
    let runs_dir = tmp_dir("powertrace_test_serve_runsdir");
    let handle = serve(gsrv, Some(runs_dir.clone()));

    let grid = SiteGrid {
        name: "served_site_sweep".to_string(),
        base: small_site(&ids[0]),
        phase_spreads_h: vec![0.0],
        seeds: vec![5],
        battery_kwh: Vec::new(),
        cap_w: Vec::new(),
        battery: None,
    };
    let req = RunRequest {
        spec: RunSpec::SiteSweep(grid),
        options: RunOptions::defaults_for(RunKind::SiteSweep)
            .with_dt(0.25)
            .with_window(7.0)
            .with_load_interval(1.0),
    };
    let body = json::to_string(&req.to_json());
    let (status, head, payload) = send_request(handle.addr(), "POST", "/v1/runs", Some(&body));
    assert_eq!(status, 200);
    assert!(!head.to_ascii_lowercase().contains("chunked"), "checkpointed runs do not stream");
    let v = body_json(&payload);
    let run_id = v.str_field("run_id").unwrap();
    assert_eq!(v.get("failed").unwrap().as_usize().unwrap(), 0);
    assert_eq!(v.get("interrupted").unwrap().as_usize().unwrap(), 0);
    assert!(v.str_field("summary_csv").unwrap().lines().count() >= 2);

    let run_dir = runs_dir.join(&run_id);
    assert!(run_dir.join("manifest.json").exists());
    assert!(run_dir.join("site_sweep_summary.csv").exists());

    let (status, _, payload) =
        send_request(handle.addr(), "GET", &format!("/v1/runs/{run_id}"), None);
    assert_eq!(status, 200);
    let v = body_json(&payload);
    assert_eq!(v.str_field("state").unwrap(), "done");
    let m = v.get("manifest").unwrap();
    assert_eq!(m.get("done").unwrap().as_usize().unwrap(), 1);
    assert_eq!(m.get("pending").unwrap().as_usize().unwrap(), 0);
    assert_eq!(m.get("failed").unwrap().as_usize().unwrap(), 0);

    handle.stop().unwrap();
}

/// The wire-version contract ([`RunRequest::WIRE_VERSION`]): `"v": 1` (or
/// an absent `v`) is accepted; any other declared version is a plain 400
/// before any stream starts. And a sharded sweep RunRequest is honored
/// over the wire — only the cells shard `0/2` owns appear in the streamed
/// partial summary.
#[test]
fn wire_version_gates_requests_and_sharded_sweeps_run_their_slice() {
    let (_gref, gsrv, _root, ids) = paired_generators("serve_version", 29);
    let handle = serve(gsrv, None);
    let addr = handle.addr();

    // Explicit v:1 — the version this build speaks — is accepted.
    let mut req_json = site_request(&ids[0]).to_json();
    if let Json::Obj(o) = &mut req_json {
        o.insert("v".to_string(), Json::Num(1.0));
    }
    let body = json::to_string(&req_json);
    let (status, _, _) = send_request(addr, "POST", "/v1/runs", Some(&body));
    assert_eq!(status, 200);

    // A future version is refused up front, naming the version.
    if let Json::Obj(o) = &mut req_json {
        o.insert("v".to_string(), Json::Num(2.0));
    }
    let body = json::to_string(&req_json);
    let (status, _, payload) = send_request(addr, "POST", "/v1/runs", Some(&body));
    assert_eq!(status, 400);
    let err = body_json(&payload).str_field("error").unwrap();
    assert!(err.contains("unsupported RunRequest version 2"), "{err}");

    // A sharded sweep over the wire: the partial summary.csv carries a
    // header plus exactly the owned cells' rows.
    let shard = Shard::parse("0/2").unwrap();
    let grid = SweepGrid {
        name: "served_shard".to_string(),
        defaults: GridDefaults { horizon_s: 60.0, ..GridDefaults::default() },
        workloads: vec![WorkloadSpec::Poisson { rate: 0.5 }],
        topologies: vec![Topology { rows: 1, racks_per_row: 2, servers_per_rack: 2 }],
        fleets: vec![ServerAssignment::Uniform(ids[0].clone())],
        seeds: vec![5, 9],
    };
    let owned: Vec<String> = grid
        .expand()
        .iter()
        .map(|c| c.id.clone())
        .filter(|id| shard.owns(id))
        .collect();
    let req = RunRequest {
        spec: RunSpec::Sweep(grid),
        options: RunOptions::defaults_for(RunKind::Sweep).with_shard(Some(shard)),
    };
    let body = json::to_string(&req.to_json());
    let (status, _, payload) = send_request(addr, "POST", "/v1/runs", Some(&body));
    assert_eq!(status, 200);
    let (_, events) = split_events(&payload);
    let summary = events
        .iter()
        .find_map(|e| match e {
            SinkEvent::File { path, data } if path == "summary.csv" => {
                Some(String::from_utf8(data.clone()).unwrap())
            }
            _ => None,
        })
        .expect("sharded sweep still streams its partial summary.csv");
    assert_eq!(summary.lines().count(), 1 + owned.len());
    for id in &owned {
        assert!(summary.contains(id), "owned cell {id} missing from partial summary");
    }

    handle.stop().unwrap();
}
