//! Compile coverage for the `#[deprecated]` `run_*` wrappers.
//!
//! Every example, bench, and integration test routes through
//! `crate::api` now; this binary keeps exactly ONE call site per wrapper
//! alive so a signature break is a compile error instead of silent rot.
//! Each test is also a minimal smoke run — the wrappers must still
//! execute, not just parse.
#![allow(deprecated)]

use powertrace_sim::aggregate::Topology;
use powertrace_sim::config::{ScenarioSpec, ServerAssignment, WorkloadSpec};
use powertrace_sim::export::MemSink;
use powertrace_sim::robust::RetryPolicy;
use powertrace_sim::scenarios::{
    run_sweep, run_sweep_checkpointed, run_sweep_sink, run_sweep_to, GridDefaults, SweepGrid,
    SweepOptions,
};
use powertrace_sim::site::{
    prepare_site, run_site, run_site_prepared, run_site_prepared_sink, run_site_sink,
    run_site_sweep, run_site_sweep_checkpointed, SiteGrid, SiteOptions, SiteSpec,
};
use powertrace_sim::testutil::synth_generator;
use std::path::PathBuf;

/// 1 workload × 1 topology × 1 fleet × 1 seed = a single 40 s cell.
fn one_cell_grid(id: &str) -> SweepGrid {
    SweepGrid {
        name: "deprecated-compat".into(),
        defaults: GridDefaults { horizon_s: 40.0, ..GridDefaults::default() },
        workloads: vec![WorkloadSpec::Poisson { rate: 0.5 }],
        topologies: vec![Topology { rows: 1, racks_per_row: 1, servers_per_rack: 2 }],
        fleets: vec![ServerAssignment::Uniform(id.to_string())],
        seeds: vec![3],
    }
}

fn small_site(id: &str) -> SiteSpec {
    let mut scenario = ScenarioSpec::default_poisson(id, 0.5);
    scenario.topology = Topology { rows: 1, racks_per_row: 1, servers_per_rack: 2 };
    scenario.horizon_s = 40.0;
    scenario.seed = 5;
    let mut spec = SiteSpec::staggered("deprecated-compat", &scenario, 2, 0.0);
    spec.utility_intervals_s = vec![15.0, 30.0];
    spec
}

fn site_grid(id: &str) -> SiteGrid {
    SiteGrid {
        name: "deprecated-compat-grid".into(),
        base: small_site(id),
        phase_spreads_h: vec![0.0],
        seeds: vec![0],
        battery_kwh: Vec::new(),
        cap_w: Vec::new(),
        battery: None,
    }
}

fn site_opts() -> SiteOptions {
    SiteOptions { dt_s: 1.0, window_s: 7.0, load_interval_s: 1.0, ..SiteOptions::default() }
}

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("powertrace_test_deprecated_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn run_sweep_still_compiles_and_runs() {
    let (mut gen, ids) = synth_generator("dep_sweep", 8, 4, 1, 11).unwrap();
    let report = run_sweep(&mut gen, &one_cell_grid(&ids[0]), &SweepOptions::default()).unwrap();
    assert_eq!(report.cells.len(), 1);
}

#[test]
fn run_sweep_to_still_compiles_and_runs() {
    let (mut gen, ids) = synth_generator("dep_sweep_to", 8, 4, 1, 13).unwrap();
    let grid = one_cell_grid(&ids[0]);
    let opts = SweepOptions { window_s: 7.0, ..SweepOptions::default() };
    let dir = temp_dir("sweep_to");
    let report = run_sweep_to(&mut gen, &grid, &opts, Some(&dir)).unwrap();
    assert_eq!(report.cells.len(), 1);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn run_sweep_sink_still_compiles_and_runs() {
    let (mut gen, ids) = synth_generator("dep_sweep_sink", 8, 4, 1, 17).unwrap();
    let grid = one_cell_grid(&ids[0]);
    let opts = SweepOptions { window_s: 7.0, ..SweepOptions::default() };
    let mem = MemSink::new();
    let report = run_sweep_sink(&mut gen, &grid, &opts, Some(&mem)).unwrap();
    assert_eq!(report.cells.len(), 1);
    assert!(!mem.files().is_empty(), "streamed series went through the sink");
}

#[test]
fn run_sweep_checkpointed_still_compiles_and_runs() {
    let (mut gen, ids) = synth_generator("dep_sweep_ckpt", 8, 4, 1, 19).unwrap();
    let grid = one_cell_grid(&ids[0]);
    let dir = temp_dir("sweep_ckpt");
    let out = run_sweep_checkpointed(
        &mut gen,
        &grid,
        &SweepOptions::default(),
        &dir,
        &RetryPolicy::default(),
    )
    .unwrap();
    assert!(out.failed.is_empty());
    assert_eq!(out.report.cells.len(), 1);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn run_site_still_compiles_and_runs() {
    let (mut gen, ids) = synth_generator("dep_site", 8, 4, 1, 23).unwrap();
    let report = run_site(&mut gen, &small_site(&ids[0]), &site_opts(), None).unwrap();
    assert_eq!(report.facilities.len(), 2);
}

#[test]
fn run_site_prepared_still_compiles_and_runs() {
    let (mut gen, ids) = synth_generator("dep_site_prep", 8, 4, 1, 29).unwrap();
    let spec = small_site(&ids[0]);
    prepare_site(&mut gen, &spec).unwrap();
    let report = run_site_prepared(&gen, &spec, &site_opts(), None).unwrap();
    assert_eq!(report.facilities.len(), 2);
}

#[test]
fn run_site_sink_still_compiles_and_runs() {
    let (mut gen, ids) = synth_generator("dep_site_sink", 8, 4, 1, 31).unwrap();
    let mem = MemSink::new();
    let report = run_site_sink(&mut gen, &small_site(&ids[0]), &site_opts(), Some(&mem)).unwrap();
    assert_eq!(report.facilities.len(), 2);
    assert!(!mem.files().is_empty(), "site exports went through the sink");
}

#[test]
fn run_site_prepared_sink_still_compiles_and_runs() {
    let (mut gen, ids) = synth_generator("dep_site_prep_sink", 8, 4, 1, 37).unwrap();
    let spec = small_site(&ids[0]);
    prepare_site(&mut gen, &spec).unwrap();
    let report = run_site_prepared_sink(&gen, &spec, &site_opts(), None).unwrap();
    assert_eq!(report.facilities.len(), 2);
}

#[test]
fn run_site_sweep_still_compiles_and_runs() {
    let (mut gen, ids) = synth_generator("dep_site_sweep", 8, 4, 1, 41).unwrap();
    let results = run_site_sweep(&mut gen, &site_grid(&ids[0]), &site_opts(), None).unwrap();
    assert_eq!(results.len(), 1);
}

#[test]
fn run_site_sweep_checkpointed_still_compiles_and_runs() {
    let (mut gen, ids) = synth_generator("dep_site_sweep_ckpt", 8, 4, 1, 43).unwrap();
    let grid = site_grid(&ids[0]);
    let dir = temp_dir("site_sweep_ckpt");
    let out =
        run_site_sweep_checkpointed(&mut gen, &grid, &site_opts(), &dir, &RetryPolicy::default())
            .unwrap();
    assert!(out.failed.is_empty());
    assert_eq!(out.executed.len(), 1);
    std::fs::remove_dir_all(&dir).ok();
}
