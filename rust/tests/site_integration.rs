//! Integration: the site composition engine end-to-end — lockstep
//! multi-facility composition over the windowed pipeline, the composition
//! invariants (site peak vs Σ facility peaks, coincidence factor range,
//! single-facility identity), and byte-stable exports across worker
//! counts and window sizes.

use powertrace_sim::aggregate::Topology;
use powertrace_sim::config::{ScenarioSpec, WorkloadSpec};
use powertrace_sim::scenarios::diff_summary_files;
use powertrace_sim::site::{
    run_site, run_site_sweep, FacilitySpec, SiteGrid, SiteOptions, SiteSpec,
};
use powertrace_sim::testutil::synth_generator;
use powertrace_sim::workload::TrafficMode;

/// A small facility scenario every test composes from: 1×2×2 = 4 servers,
/// 60 s horizon.
fn base_scenario(id: &str) -> ScenarioSpec {
    let mut s = ScenarioSpec::default_poisson(id, 0.5);
    s.topology = Topology { rows: 1, racks_per_row: 2, servers_per_rack: 2 };
    s.horizon_s = 60.0;
    s.seed = 5;
    s
}

/// Site options sized for the 60 s test horizon: ragged 7 s windows,
/// utility intervals that actually complete, 1 s load export.
fn test_opts() -> SiteOptions {
    SiteOptions {
        dt_s: 0.25,
        window_s: 7.0,
        load_interval_s: 1.0,
        collect_series: true,
        ..SiteOptions::default()
    }
}

fn small_site(id: &str, n_facilities: usize) -> SiteSpec {
    let mut spec = SiteSpec::staggered("itest", &base_scenario(id), n_facilities, 0.0);
    spec.utility_intervals_s = vec![15.0, 30.0];
    spec
}

#[test]
fn single_facility_site_reproduces_the_plain_facility_path() {
    let (mut gen, ids) = synth_generator("site_single", 8, 4, 1, 23).unwrap();
    let spec = small_site(&ids[0], 1);
    let opts = test_opts();
    let report = run_site(&mut gen, &spec, &opts, None).unwrap();
    let site_series = report.site_series.as_ref().expect("collect_series requested");

    // The buffered facility path on the identical scenario (phase 0 +
    // Poisson ⇒ effective scenario == declared scenario).
    let run = gen.facility(&spec.facilities[0].scenario, opts.dt_s, 0).unwrap();
    let reference = run.facility_series();
    assert_eq!(site_series.len(), reference.len());
    for (t, (a, b)) in site_series.iter().zip(&reference).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "site vs facility PCC at step {t}");
    }
    // And the summary stats agree with the buffered computation.
    use powertrace_sim::metrics::PlanningStats;
    let ramp_s =
        powertrace_sim::metrics::planning::clamp_ramp_interval(900.0, spec.horizon_s(), opts.dt_s);
    let want = PlanningStats::compute(&reference, opts.dt_s, ramp_s).unwrap();
    assert_eq!(report.site.stats, want);
    assert!(report.site.exact_quantiles);
    // One facility: the composition metrics degenerate exactly.
    assert_eq!(report.coincidence_factor, 1.0);
    assert_eq!(report.sum_facility_peaks_w.to_bits(), report.site.stats.peak_w.to_bits());
}

#[test]
fn site_peak_bounded_by_sum_of_facility_peaks() {
    let (mut gen, ids) = synth_generator("site_bound", 8, 4, 1, 29).unwrap();
    // Three facilities, distinct seeds (the staggered builder's seed
    // ladder), zero phase offsets.
    let spec = small_site(&ids[0], 3);
    let report = run_site(&mut gen, &spec, &test_opts(), None).unwrap();
    assert_eq!(report.facilities.len(), 3);
    let sum: f64 = report.facilities.iter().map(|f| f.summary.stats.peak_w).sum();
    assert_eq!(sum.to_bits(), report.sum_facility_peaks_w.to_bits());
    // The composed series is f32: allow its half-ulp (~6e-8 relative).
    assert!(
        report.site.stats.peak_w <= sum * (1.0 + 1e-6),
        "site peak {} vs Σ facility peaks {sum}",
        report.site.stats.peak_w
    );
    assert!(report.coincidence_factor > 0.0 && report.coincidence_factor <= 1.0);
    assert!(report.diversity_factor >= 1.0);
    // Default nameplate is Σ facility peaks; headroom is measured from it.
    assert_eq!(report.nameplate_w.to_bits(), sum.to_bits());
    assert!((report.headroom_w - (report.nameplate_w - report.site.stats.peak_w)).abs() < 1e-9);
    // Site energy is the sum of facility energies (linearity of Σ P·dt).
    let fac_energy: f64 = report.facilities.iter().map(|f| f.summary.stats.energy_kwh).sum();
    assert!(
        (report.site.stats.energy_kwh - fac_energy).abs() < 1e-6 * fac_energy.max(1.0),
        "site {} vs Σ facilities {fac_energy}",
        report.site.stats.energy_kwh
    );
}

#[test]
fn cloned_facilities_with_zero_offsets_are_fully_coincident() {
    let (mut gen, ids) = synth_generator("site_clones", 8, 4, 1, 37).unwrap();
    let base = base_scenario(&ids[0]);
    let fac = |name: &str| FacilitySpec {
        name: name.into(),
        phase_offset_s: 0.0,
        scenario: base.clone(),
    };
    let spec = SiteSpec {
        name: "clones".into(),
        nameplate_w: None,
        utility_intervals_s: vec![15.0, 30.0],
        facilities: vec![fac("a"), fac("b"), fac("c")],
    };
    let report = run_site(&mut gen, &spec, &test_opts(), None).unwrap();
    // Identical facilities peak together: coincidence 1 up to the f32
    // rounding of the composed series (half an ulp, ~6e-8 relative).
    assert!(
        (report.coincidence_factor - 1.0).abs() < 1e-6,
        "coincidence {} for cloned facilities",
        report.coincidence_factor
    );
    assert!(report.coincidence_factor <= 1.0);
    // All three facility summaries are identical.
    let p0 = report.facilities[0].summary.stats;
    for f in &report.facilities[1..] {
        assert_eq!(f.summary.stats, p0);
    }
}

#[test]
fn site_exports_byte_identical_across_workers_and_windows() {
    let (mut gen, ids) = synth_generator("site_bytes", 8, 4, 1, 41).unwrap();
    let spec = small_site(&ids[0], 3);
    let layouts = [
        (1usize, 7.0f64),  // serial facilities, ragged windows
        (4, 13.0),         // parallel, different ragged windows
        (2, 60.0),         // whole horizon in one window
    ];
    let mut dirs = Vec::new();
    for (i, &(workers, window_s)) in layouts.iter().enumerate() {
        let dir = std::env::temp_dir().join(format!("powertrace_test_site_bytes_{i}"));
        let _ = std::fs::remove_dir_all(&dir);
        let opts = SiteOptions {
            workers,
            window_s,
            collect_series: false,
            ..test_opts()
        };
        run_site(&mut gen, &spec, &opts, Some(&dir)).unwrap();
        dirs.push(dir);
    }
    for name in ["site_load.csv", "site_summary.csv", "site_spec.json"] {
        let a = std::fs::read(dirs[0].join(name)).unwrap();
        assert!(!a.is_empty());
        for d in &dirs[1..] {
            let b = std::fs::read(d.join(name)).unwrap();
            assert_eq!(a, b, "{name} differs between {:?} and {:?}", dirs[0], d);
        }
    }
    // site_load.csv shape: header + one row per completed 1 s interval,
    // with site + 3 facility columns.
    let load = std::fs::read_to_string(dirs[0].join("site_load.csv")).unwrap();
    let lines: Vec<&str> = load.lines().collect();
    assert_eq!(lines[0], "t_s,site_w,fac0_w,fac1_w,fac2_w");
    assert_eq!(lines.len(), 1 + 60);
    // Each row's site column is the sum of its facility columns.
    for line in &lines[1..] {
        let f: Vec<f64> = line.split(',').map(|x| x.parse().unwrap()).collect();
        assert!((f[1] - (f[2] + f[3] + f[4])).abs() < 1e-3 * f[1].abs().max(1.0), "{line}");
    }
}

#[test]
fn site_summary_feeds_the_diff_gate() {
    let (mut gen, ids) = synth_generator("site_diff", 8, 4, 1, 43).unwrap();
    let spec = small_site(&ids[0], 2);
    let dir = std::env::temp_dir().join("powertrace_test_site_diff");
    let _ = std::fs::remove_dir_all(&dir);
    run_site(&mut gen, &spec, &test_opts(), Some(&dir)).unwrap();
    let summary = dir.join("site_summary.csv");
    // Self-diff matches exactly.
    let r = diff_summary_files(&summary, &summary, 0.0).unwrap();
    assert!(r.is_match(), "{}", r.render());
    assert_eq!(r.rows_compared, 3); // 2 facilities + the site row
    // An injected metric change is detected.
    let text = std::fs::read_to_string(&summary).unwrap();
    let mut rows: Vec<String> = text.lines().map(String::from).collect();
    let site_row = rows.last().unwrap().clone();
    let peak_field = site_row.split(',').nth(5).unwrap().to_string();
    let perturbed: f64 = peak_field.parse::<f64>().unwrap() * 1.001;
    *rows.last_mut().unwrap() = site_row.replacen(&peak_field, &format!("{perturbed}"), 1);
    let mutated = dir.join("site_summary_mutated.csv");
    std::fs::write(&mutated, rows.join("\n") + "\n").unwrap();
    let r = diff_summary_files(&summary, &mutated, 1e-9).unwrap();
    assert!(!r.is_match());
    // ...and tolerated above the injected magnitude.
    let r = diff_summary_files(&summary, &mutated, 0.01).unwrap();
    assert!(r.is_match(), "{}", r.render());
}

#[test]
fn phase_offsets_change_diurnal_composition_deterministically() {
    let (mut gen, ids) = synth_generator("site_sweep", 8, 4, 1, 47).unwrap();
    let mut base = base_scenario(&ids[0]);
    base.workload = WorkloadSpec::Diurnal {
        base_rate: 0.5,
        swing: 0.65,
        peak_hour: 15.0,
        burst_sigma: 0.3,
        mode: TrafficMode::SharedIntensity,
    };
    let mut site = SiteSpec::staggered("diurnal", &base, 2, 0.0);
    site.utility_intervals_s = vec![15.0, 30.0];
    let grid = SiteGrid {
        name: "spread".into(),
        base: site,
        phase_spreads_h: vec![0.0, 6.0],
        seeds: vec![5],
    };
    let dir = std::env::temp_dir().join("powertrace_test_site_sweep");
    let _ = std::fs::remove_dir_all(&dir);
    let opts = SiteOptions { collect_series: false, ..test_opts() };
    let results = run_site_sweep(&mut gen, &grid, &opts, Some(&dir)).unwrap();
    assert_eq!(results.len(), 2);
    assert!(dir.join("site_sweep_summary.csv").exists());
    assert!(dir.join("p0-s5").join("site_load.csv").exists());
    assert!(dir.join("p1-s5").join("site_summary.csv").exists());
    for (_, r) in &results {
        assert!(r.coincidence_factor > 0.0 && r.coincidence_factor <= 1.0);
    }
    // Re-running the sweep reproduces the summary byte-for-byte.
    let dir2 = std::env::temp_dir().join("powertrace_test_site_sweep_rerun");
    let _ = std::fs::remove_dir_all(&dir2);
    run_site_sweep(&mut gen, &grid, &opts, Some(&dir2)).unwrap();
    assert_eq!(
        std::fs::read(dir.join("site_sweep_summary.csv")).unwrap(),
        std::fs::read(dir2.join("site_sweep_summary.csv")).unwrap()
    );
}
