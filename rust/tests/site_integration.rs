//! Integration: the site composition engine end-to-end — lockstep
//! multi-facility composition over the windowed pipeline, the composition
//! invariants (site peak vs Σ facility peaks, coincidence factor range,
//! single-facility identity), and byte-stable exports across worker
//! counts and window sizes.

use powertrace_sim::aggregate::Topology;
use powertrace_sim::api::{self, RunKind, RunOptions, RunOutcome, RunRequest, RunSpec};
use powertrace_sim::config::{ScenarioSpec, WorkloadSpec};
use powertrace_sim::coordinator::Generator;
use powertrace_sim::export::{DirSink, TraceSink};
use powertrace_sim::scenarios::diff_summary_files;
use powertrace_sim::site::{
    FacilitySpec, OverlaySpec, SiteGrid, SiteReport, SiteSpec, SiteVariant, TrainingSpec,
};
use powertrace_sim::testutil::synth_generator;
use powertrace_sim::workload::TrafficMode;
use std::path::Path;

/// `api::execute` a [`RunSpec::Site`], optionally against a directory sink.
fn run_site(
    gen: &mut Generator,
    spec: &SiteSpec,
    options: RunOptions,
    out_dir: Option<&Path>,
) -> SiteReport {
    let req = RunRequest { spec: RunSpec::Site(spec.clone()), options };
    let sink = out_dir.map(DirSink::new);
    let sink_ref = sink.as_ref().map(|s| s as &dyn TraceSink);
    match api::execute(gen, &req, sink_ref).unwrap() {
        RunOutcome::Site(r) => r,
        _ => unreachable!(),
    }
}

/// `api::execute` a [`RunSpec::SiteSweep`], optionally against a directory
/// sink.
fn run_site_sweep(
    gen: &mut Generator,
    grid: &SiteGrid,
    options: RunOptions,
    out_dir: Option<&Path>,
) -> Vec<(SiteVariant, SiteReport)> {
    let req = RunRequest { spec: RunSpec::SiteSweep(grid.clone()), options };
    let sink = out_dir.map(DirSink::new);
    let sink_ref = sink.as_ref().map(|s| s as &dyn TraceSink);
    match api::execute(gen, &req, sink_ref).unwrap() {
        RunOutcome::SiteSweep(r) => r,
        _ => unreachable!(),
    }
}

/// A small facility scenario every test composes from: 1×2×2 = 4 servers,
/// 60 s horizon.
fn base_scenario(id: &str) -> ScenarioSpec {
    let mut s = ScenarioSpec::default_poisson(id, 0.5);
    s.topology = Topology { rows: 1, racks_per_row: 2, servers_per_rack: 2 };
    s.horizon_s = 60.0;
    s.seed = 5;
    s
}

/// Site options sized for the 60 s test horizon: ragged 7 s windows,
/// utility intervals that actually complete, 1 s load export.
fn test_opts() -> RunOptions {
    RunOptions::defaults_for(RunKind::Site)
        .with_dt(0.25)
        .with_window(7.0)
        .with_load_interval(1.0)
        .with_collect_series(true)
}

/// The training archetype every mixed-class test composes: 60 s horizon
/// matching `base_scenario`, 20 s compute/checkpoint period, 50 % duty.
fn training_spec() -> TrainingSpec {
    TrainingSpec {
        horizon_s: 60.0,
        base_w: 1.0e4,
        amplitude_w: 5.0e4,
        period_s: 20.0,
        duty: 0.5,
    }
}

fn small_site(id: &str, n_facilities: usize) -> SiteSpec {
    let mut spec = SiteSpec::staggered("itest", &base_scenario(id), n_facilities, 0.0);
    spec.utility_intervals_s = vec![15.0, 30.0];
    spec
}

#[test]
fn single_facility_site_reproduces_the_plain_facility_path() {
    let (mut gen, ids) = synth_generator("site_single", 8, 4, 1, 23).unwrap();
    let spec = small_site(&ids[0], 1);
    let opts = test_opts();
    let report = run_site(&mut gen, &spec, opts.clone(), None);
    let site_series = report.site_series.as_ref().expect("collect_series requested");

    // The buffered facility path on the identical scenario (phase 0 +
    // Poisson ⇒ effective scenario == declared scenario).
    let run = gen.facility(spec.facilities[0].scenario().unwrap(), opts.dt_s, 0).unwrap();
    let reference = run.facility_series();
    assert_eq!(site_series.len(), reference.len());
    for (t, (a, b)) in site_series.iter().zip(&reference).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "site vs facility PCC at step {t}");
    }
    // And the summary stats agree with the buffered computation.
    use powertrace_sim::metrics::PlanningStats;
    let ramp_s =
        powertrace_sim::metrics::planning::clamp_ramp_interval(900.0, spec.horizon_s(), opts.dt_s);
    let want = PlanningStats::compute(&reference, opts.dt_s, ramp_s).unwrap();
    assert_eq!(report.site.stats, want);
    assert!(report.site.exact_quantiles);
    // One facility: the composition metrics degenerate exactly.
    assert_eq!(report.coincidence_factor, 1.0);
    assert_eq!(report.sum_facility_peaks_w.to_bits(), report.site.stats.peak_w.to_bits());
}

#[test]
fn site_peak_bounded_by_sum_of_facility_peaks() {
    let (mut gen, ids) = synth_generator("site_bound", 8, 4, 1, 29).unwrap();
    // Three facilities, distinct seeds (the staggered builder's seed
    // ladder), zero phase offsets.
    let spec = small_site(&ids[0], 3);
    let report = run_site(&mut gen, &spec, test_opts(), None);
    assert_eq!(report.facilities.len(), 3);
    let sum: f64 = report.facilities.iter().map(|f| f.summary.stats.peak_w).sum();
    assert_eq!(sum.to_bits(), report.sum_facility_peaks_w.to_bits());
    // The composed series is f32: allow its half-ulp (~6e-8 relative).
    assert!(
        report.site.stats.peak_w <= sum * (1.0 + 1e-6),
        "site peak {} vs Σ facility peaks {sum}",
        report.site.stats.peak_w
    );
    assert!(report.coincidence_factor > 0.0 && report.coincidence_factor <= 1.0);
    assert!(report.diversity_factor >= 1.0);
    // Default nameplate is Σ facility peaks; headroom is measured from it.
    assert_eq!(report.nameplate_w.to_bits(), sum.to_bits());
    assert!((report.headroom_w - (report.nameplate_w - report.site.stats.peak_w)).abs() < 1e-9);
    // Site energy is the sum of facility energies (linearity of Σ P·dt).
    let fac_energy: f64 = report.facilities.iter().map(|f| f.summary.stats.energy_kwh).sum();
    assert!(
        (report.site.stats.energy_kwh - fac_energy).abs() < 1e-6 * fac_energy.max(1.0),
        "site {} vs Σ facilities {fac_energy}",
        report.site.stats.energy_kwh
    );
}

#[test]
fn cloned_facilities_with_zero_offsets_are_fully_coincident() {
    let (mut gen, ids) = synth_generator("site_clones", 8, 4, 1, 37).unwrap();
    let base = base_scenario(&ids[0]);
    let fac = |name: &str| FacilitySpec::inference(name, 0.0, base.clone());
    let spec = SiteSpec {
        name: "clones".into(),
        nameplate_w: None,
        utility_intervals_s: vec![15.0, 30.0],
        facilities: vec![fac("a"), fac("b"), fac("c")],
        overlays: Vec::new(),
    };
    let report = run_site(&mut gen, &spec, test_opts(), None);
    // Identical facilities peak together: coincidence 1 up to the f32
    // rounding of the composed series (half an ulp, ~6e-8 relative).
    assert!(
        (report.coincidence_factor - 1.0).abs() < 1e-6,
        "coincidence {} for cloned facilities",
        report.coincidence_factor
    );
    assert!(report.coincidence_factor <= 1.0);
    // All three facility summaries are identical.
    let p0 = report.facilities[0].summary.stats;
    for f in &report.facilities[1..] {
        assert_eq!(f.summary.stats, p0);
    }
}

#[test]
fn site_exports_byte_identical_across_workers_and_windows() {
    let (mut gen, ids) = synth_generator("site_bytes", 8, 4, 1, 41).unwrap();
    let spec = small_site(&ids[0], 3);
    let layouts = [
        (1usize, 7.0f64),  // serial facilities, ragged windows
        (4, 13.0),         // parallel, different ragged windows
        (2, 60.0),         // whole horizon in one window
    ];
    let mut dirs = Vec::new();
    for (i, &(workers, window_s)) in layouts.iter().enumerate() {
        let dir = std::env::temp_dir().join(format!("powertrace_test_site_bytes_{i}"));
        let _ = std::fs::remove_dir_all(&dir);
        let opts =
            test_opts().with_workers(workers).with_window(window_s).with_collect_series(false);
        run_site(&mut gen, &spec, opts, Some(&dir));
        dirs.push(dir);
    }
    for name in ["site_load.csv", "site_summary.csv", "site_spec.json"] {
        let a = std::fs::read(dirs[0].join(name)).unwrap();
        assert!(!a.is_empty());
        for d in &dirs[1..] {
            let b = std::fs::read(d.join(name)).unwrap();
            assert_eq!(a, b, "{name} differs between {:?} and {:?}", dirs[0], d);
        }
    }
    // site_load.csv shape: header + one row per completed 1 s interval,
    // with site + 3 facility columns.
    let load = std::fs::read_to_string(dirs[0].join("site_load.csv")).unwrap();
    let lines: Vec<&str> = load.lines().collect();
    assert_eq!(lines[0], "t_s,site_w,fac0_w,fac1_w,fac2_w");
    assert_eq!(lines.len(), 1 + 60);
    // Each row's site column is the sum of its facility columns.
    for line in &lines[1..] {
        let f: Vec<f64> = line.split(',').map(|x| x.parse().unwrap()).collect();
        assert!((f[1] - (f[2] + f[3] + f[4])).abs() < 1e-3 * f[1].abs().max(1.0), "{line}");
    }
}

#[test]
fn site_summary_feeds_the_diff_gate() {
    let (mut gen, ids) = synth_generator("site_diff", 8, 4, 1, 43).unwrap();
    let spec = small_site(&ids[0], 2);
    let dir = std::env::temp_dir().join("powertrace_test_site_diff");
    let _ = std::fs::remove_dir_all(&dir);
    run_site(&mut gen, &spec, test_opts(), Some(&dir));
    let summary = dir.join("site_summary.csv");
    // Self-diff matches exactly.
    let r = diff_summary_files(&summary, &summary, 0.0).unwrap();
    assert!(r.is_match(), "{}", r.render());
    assert_eq!(r.rows_compared, 3); // 2 facilities + the site row
    // An injected metric change is detected.
    let text = std::fs::read_to_string(&summary).unwrap();
    let mut rows: Vec<String> = text.lines().map(String::from).collect();
    let site_row = rows.last().unwrap().clone();
    let peak_field = site_row.split(',').nth(5).unwrap().to_string();
    let perturbed: f64 = peak_field.parse::<f64>().unwrap() * 1.001;
    *rows.last_mut().unwrap() = site_row.replacen(&peak_field, &format!("{perturbed}"), 1);
    let mutated = dir.join("site_summary_mutated.csv");
    std::fs::write(&mutated, rows.join("\n") + "\n").unwrap();
    let r = diff_summary_files(&summary, &mutated, 1e-9).unwrap();
    assert!(!r.is_match());
    // ...and tolerated above the injected magnitude.
    let r = diff_summary_files(&summary, &mutated, 0.01).unwrap();
    assert!(r.is_match(), "{}", r.render());
}

#[test]
fn phase_offsets_change_diurnal_composition_deterministically() {
    let (mut gen, ids) = synth_generator("site_sweep", 8, 4, 1, 47).unwrap();
    let mut base = base_scenario(&ids[0]);
    base.workload = WorkloadSpec::Diurnal {
        base_rate: 0.5,
        swing: 0.65,
        peak_hour: 15.0,
        burst_sigma: 0.3,
        mode: TrafficMode::SharedIntensity,
    };
    let mut site = SiteSpec::staggered("diurnal", &base, 2, 0.0);
    site.utility_intervals_s = vec![15.0, 30.0];
    let grid = SiteGrid {
        name: "spread".into(),
        base: site,
        phase_spreads_h: vec![0.0, 6.0],
        seeds: vec![5],
        battery_kwh: Vec::new(),
        cap_w: Vec::new(),
        battery: None,
    };
    let dir = std::env::temp_dir().join("powertrace_test_site_sweep");
    let _ = std::fs::remove_dir_all(&dir);
    let opts = test_opts().with_collect_series(false);
    let results = run_site_sweep(&mut gen, &grid, opts.clone(), Some(&dir));
    assert_eq!(results.len(), 2);
    assert!(dir.join("site_sweep_summary.csv").exists());
    assert!(dir.join("p0-s5").join("site_load.csv").exists());
    assert!(dir.join("p1-s5").join("site_summary.csv").exists());
    for (_, r) in &results {
        assert!(r.coincidence_factor > 0.0 && r.coincidence_factor <= 1.0);
    }
    // Re-running the sweep reproduces the summary byte-for-byte.
    let dir2 = std::env::temp_dir().join("powertrace_test_site_sweep_rerun");
    let _ = std::fs::remove_dir_all(&dir2);
    run_site_sweep(&mut gen, &grid, opts, Some(&dir2));
    assert_eq!(
        std::fs::read(dir.join("site_sweep_summary.csv")).unwrap(),
        std::fs::read(dir2.join("site_sweep_summary.csv")).unwrap()
    );
}

/// The exact pre-overlay header of `site_summary.csv` for the test sites'
/// utility intervals (15/30 s) — the byte-identity surface an empty
/// overlay list must preserve.
const OVERLAY_FREE_HEADER: &str = "name,role,servers,seed,phase_offset_s,peak_w,avg_w,p99_w,\
     energy_kwh,cv,load_factor,max_ramp_w,ld_p50_w,ld_p90_w,ld_p95_w,ld_p99_w,\
     ramp_max_15s_w,ramp_p99_15s_w,ramp_max_30s_w,ramp_p99_30s_w,\
     coincidence_factor,diversity_factor,sum_facility_peaks_w,nameplate_w,headroom_w,headroom_frac";

#[test]
fn empty_overlay_list_is_the_identity_surface() {
    let (mut gen, ids) = synth_generator("site_identity_ov", 8, 4, 1, 53).unwrap();
    let spec = small_site(&ids[0], 2);
    // `"overlays": []` in the JSON parses to the same spec as no field at
    // all — and the field stays out of the serialized spec.
    use powertrace_sim::util::json::Json;
    let mut with_field = spec.to_json();
    if let Json::Obj(ref mut o) = with_field {
        o.insert("overlays".into(), Json::Arr(Vec::new()));
        let facs = match o.get_mut("facilities").unwrap() {
            Json::Arr(a) => a,
            other => panic!("facilities not an array: {other:?}"),
        };
        for f in facs {
            if let Json::Obj(fo) = f {
                fo.insert("overlays".into(), Json::Arr(Vec::new()));
            }
        }
    }
    let parsed = SiteSpec::from_json(&with_field).unwrap();
    assert_eq!(parsed, spec);
    assert!(parsed.to_json().get_opt("overlays").is_none());

    // And the run takes the exact overlay-free path: pre-overlay summary
    // header, no overlay columns, byte-identical exports from both specs.
    let dir_a = std::env::temp_dir().join("powertrace_test_site_identity_a");
    let dir_b = std::env::temp_dir().join("powertrace_test_site_identity_b");
    let _ = std::fs::remove_dir_all(&dir_a);
    let _ = std::fs::remove_dir_all(&dir_b);
    let opts = test_opts().with_collect_series(false);
    run_site(&mut gen, &spec, opts.clone(), Some(&dir_a));
    run_site(&mut gen, &parsed, opts, Some(&dir_b));
    for name in ["site_load.csv", "site_summary.csv", "site_spec.json"] {
        assert_eq!(
            std::fs::read(dir_a.join(name)).unwrap(),
            std::fs::read(dir_b.join(name)).unwrap(),
            "{name}"
        );
    }
    let summary = std::fs::read_to_string(dir_a.join("site_summary.csv")).unwrap();
    assert_eq!(summary.lines().next().unwrap(), OVERLAY_FREE_HEADER);
}

#[test]
fn cap_overlay_bounds_the_site_and_gains_delta_columns() {
    let (mut gen, ids) = synth_generator("site_cap_ov", 8, 4, 1, 59).unwrap();
    let mut spec = small_site(&ids[0], 3);
    // Baseline raw peak, to place the cap where it actually clips.
    let baseline = run_site(&mut gen, &spec, test_opts(), None);
    let raw_peak = baseline.site.stats.peak_w;
    let cap_w = 0.9 * raw_peak;
    spec.overlays = vec![OverlaySpec::Cap { cap_w }];

    let dir = std::env::temp_dir().join("powertrace_test_site_cap_ov");
    let _ = std::fs::remove_dir_all(&dir);
    let report = run_site(&mut gen, &spec, test_opts(), Some(&dir));
    let overlay = report.site.overlay.expect("site chain ran");
    // The tentpole properties: exact cap bound on the f64-tracked net
    // peak, raw peak unchanged, clip integral = shaved energy.
    assert!(overlay.net_peak_w <= cap_w);
    assert_eq!(overlay.raw_peak_w.to_bits(), raw_peak.to_bits());
    assert_eq!(overlay.shaved_kwh.to_bits(), overlay.cap_clipped_kwh.to_bits());
    assert!(overlay.cap_violation_s > 0.0);
    // The characterized series is the net load (within f32 rounding of
    // the cap), and the facility summaries are untouched.
    assert!(report.site.stats.peak_w <= cap_w * (1.0 + 1e-6));
    for (f, b) in report.facilities.iter().zip(&baseline.facilities) {
        assert_eq!(f.summary.stats, b.summary.stats);
        assert!(f.summary.overlay.is_none());
    }
    // Export: overlay columns present, empty on facility rows, filled on
    // the site row; the summary still self-diffs cleanly.
    let summary = std::fs::read_to_string(dir.join("site_summary.csv")).unwrap();
    let header = summary.lines().next().unwrap();
    assert!(header.contains(",net_peak_w,"));
    assert!(header.contains(",shaved_kwh,"));
    assert!(header.contains(",cap_violation_s,"));
    let cols = |line: &str| line.matches(',').count();
    for line in summary.lines().skip(1) {
        assert_eq!(cols(line), cols(header), "ragged row: {line}");
    }
    let r = diff_summary_files(&dir.join("site_summary.csv"), &dir.join("site_summary.csv"), 0.0)
        .unwrap();
    assert!(r.is_match());
    // The spec round-trips with its overlays through the exported JSON.
    let back = SiteSpec::load(&dir.join("site_spec.json")).unwrap();
    assert_eq!(back, spec);
}

#[test]
fn overlaid_exports_are_byte_identical_across_workers_and_windows() {
    // The ISSUE invariant: overlay results are independent of worker count
    // and window size — battery SoC carries across every window layout.
    let (mut gen, ids) = synth_generator("site_ov_bytes", 8, 4, 1, 61).unwrap();
    let mut spec = small_site(&ids[0], 3);
    spec.facilities[0].overlays = vec![OverlaySpec::Cap { cap_w: 2.0e4 }];
    spec.overlays = vec![
        OverlaySpec::Battery {
            capacity_kwh: 0.05,
            power_w: 5e3,
            efficiency: 0.9,
            threshold_w: 4.5e4,
            initial_soc_frac: 0.5,
        },
        OverlaySpec::Pv { peak_w: 1e4, peak_hour: 0.005, daylight_h: 12.0 },
    ];
    let layouts = [(1usize, 7.0f64), (4, 13.0), (2, 60.0)];
    let mut dirs = Vec::new();
    for (i, &(workers, window_s)) in layouts.iter().enumerate() {
        let dir = std::env::temp_dir().join(format!("powertrace_test_site_ov_bytes_{i}"));
        let _ = std::fs::remove_dir_all(&dir);
        let opts =
            test_opts().with_workers(workers).with_window(window_s).with_collect_series(false);
        run_site(&mut gen, &spec, opts, Some(&dir));
        dirs.push(dir);
    }
    for name in ["site_load.csv", "site_summary.csv", "site_spec.json"] {
        let a = std::fs::read(dirs[0].join(name)).unwrap();
        assert!(!a.is_empty());
        for d in &dirs[1..] {
            assert_eq!(a, std::fs::read(d.join(name)).unwrap(), "{name} differs vs {d:?}");
        }
    }
}

#[test]
fn facility_overlays_modulate_the_stream_the_site_composes() {
    let (mut gen, ids) = synth_generator("site_fac_ov", 8, 4, 1, 67).unwrap();
    let mut spec = small_site(&ids[0], 2);
    // Cap below the facility's raw peak, so the stage actually clips.
    let baseline = run_site(&mut gen, &spec, test_opts(), None);
    let cap_w = 0.85 * baseline.facilities[0].summary.stats.peak_w;
    spec.facilities[0].overlays = vec![OverlaySpec::Cap { cap_w }];
    let dir = std::env::temp_dir().join("powertrace_test_site_fac_ov");
    let _ = std::fs::remove_dir_all(&dir);
    let report = run_site(&mut gen, &spec, test_opts(), Some(&dir));
    // The capped facility carries its own delta summary; the site row has
    // none (no site-level chain) but the export still gains the columns.
    let o = report.facilities[0].summary.overlay.expect("facility chain ran");
    assert!(o.net_peak_w <= cap_w);
    assert!(o.cap_violation_s > 0.0, "cap at 85 % of peak never clipped");
    assert!(report.facilities[1].summary.overlay.is_none());
    assert!(report.site.overlay.is_none());
    assert!(report.has_overlays());
    // site_load.csv: the site column is the sum of the *net* facility
    // columns (the site composes post-overlay streams), and the capped
    // facility's exported load respects its cap.
    let load = std::fs::read_to_string(dir.join("site_load.csv")).unwrap();
    for line in load.lines().skip(1) {
        let f: Vec<f64> = line.split(',').map(|x| x.parse().unwrap()).collect();
        assert!((f[1] - (f[2] + f[3])).abs() < 1e-3 * f[1].abs().max(1.0), "{line}");
        assert!(f[2] <= cap_w * (1.0 + 1e-6), "capped facility exceeds cap: {line}");
    }
}

#[test]
fn training_only_site_is_the_exact_phase_shifted_step_function() {
    let (mut gen, _ids) = synth_generator("site_train_only", 8, 4, 1, 73).unwrap();
    let tspec = training_spec();
    let spec = SiteSpec {
        name: "train_site".into(),
        nameplate_w: None,
        utility_intervals_s: vec![15.0, 30.0],
        facilities: vec![FacilitySpec::training("train0", 5.0, tspec.clone())],
        overlays: Vec::new(),
    };
    let opts = test_opts();
    let dir = std::env::temp_dir().join("powertrace_test_site_train_only");
    let _ = std::fs::remove_dir_all(&dir);
    let report = run_site(&mut gen, &spec, opts.clone(), Some(&dir));
    // The composed series IS the step function, shifted 5 s later,
    // bit-for-bit (the step levels are exactly representable in f32).
    let series = report.site_series.as_ref().expect("collect_series requested");
    assert_eq!(series.len(), 240);
    for (i, &w) in series.iter().enumerate() {
        let want = tspec.power_at(i as f64 * opts.dt_s - 5.0) as f32;
        assert_eq!(w.to_bits(), want.to_bits(), "step {i}");
    }
    // Training rows are serverless and seedless, with their own role.
    let f = &report.facilities[0];
    assert_eq!(f.role, "training");
    assert_eq!(f.servers, 0);
    assert_eq!(f.seed, None);
    assert_eq!(report.site.stats.peak_w, 6.0e4);
    assert_eq!(report.coincidence_factor, 1.0);
    let summary = std::fs::read_to_string(dir.join("site_summary.csv")).unwrap();
    let row = summary.lines().nth(1).unwrap();
    assert!(row.starts_with("train0,training,0,,5,"), "{row}");
    // The spec round-trips through the exported JSON.
    assert_eq!(SiteSpec::load(&dir.join("site_spec.json")).unwrap(), spec);

    // And the synthesizer honours the lockstep byte-identity contract:
    // exports are identical across worker counts and window sizes.
    let mut dirs = Vec::new();
    for (i, &(workers, window_s)) in [(1usize, 7.0f64), (4, 13.0), (2, 60.0)].iter().enumerate() {
        let d = std::env::temp_dir().join(format!("powertrace_test_site_train_only_{i}"));
        let _ = std::fs::remove_dir_all(&d);
        let opts =
            test_opts().with_workers(workers).with_window(window_s).with_collect_series(false);
        run_site(&mut gen, &spec, opts, Some(&d));
        dirs.push(d);
    }
    for name in ["site_load.csv", "site_summary.csv"] {
        let a = std::fs::read(dirs[0].join(name)).unwrap();
        assert!(!a.is_empty());
        for d in &dirs[1..] {
            assert_eq!(a, std::fs::read(d.join(name)).unwrap(), "{name}");
        }
    }
}

#[test]
fn mixed_site_strictly_smooths_relative_training_ramps() {
    let (mut gen, ids) = synth_generator("site_mixed", 8, 4, 1, 79).unwrap();
    let train_only = SiteSpec {
        name: "train_only".into(),
        nameplate_w: None,
        utility_intervals_s: vec![15.0, 30.0],
        facilities: vec![FacilitySpec::training("train0", 0.0, training_spec())],
        overlays: Vec::new(),
    };
    let mut mixed = train_only.clone();
    mixed.name = "mixed".into();
    mixed.facilities.push(FacilitySpec::inference("inf0", 0.0, base_scenario(&ids[0])));
    let a = run_site(&mut gen, &train_only, test_opts(), None);
    let b = run_site(&mut gen, &mixed, test_opts(), None);
    assert_eq!(b.facilities.len(), 2);
    assert_eq!(b.facilities[1].role, "facility");
    // The inference class adds load between the training steps, so every
    // utility interval's ramp *relative to the average load* strictly
    // shrinks — the mixed-class smoothing the archetype exists to study.
    assert!(b.site.stats.avg_w > a.site.stats.avg_w);
    assert_eq!(a.site.ramps.len(), b.site.ramps.len());
    for (ra, rb) in a.site.ramps.iter().zip(&b.site.ramps) {
        assert_eq!(ra.interval_s, rb.interval_s);
        assert!(ra.max_w > 0.0, "training step never crossed an interval boundary");
        let rel_a = ra.max_w / a.site.stats.avg_w;
        let rel_b = rb.max_w / b.site.stats.avg_w;
        assert!(
            rel_b < rel_a,
            "interval {}s: mixed relative ramp {rel_b} !< training-only {rel_a}",
            ra.interval_s
        );
    }
    // Site energy stays the sum of the class energies.
    let fac_energy: f64 = b.facilities.iter().map(|f| f.summary.stats.energy_kwh).sum();
    assert!((b.site.stats.energy_kwh - fac_energy).abs() < 1e-6 * fac_energy.max(1.0));
}

#[test]
fn site_sweep_training_rows_ignore_the_seed_axis() {
    let (mut gen, ids) = synth_generator("site_train_sweep", 8, 4, 1, 83).unwrap();
    let mut site = small_site(&ids[0], 1);
    site.facilities.push(FacilitySpec::training("train0", 10.0, training_spec()));
    let grid = SiteGrid {
        name: "mix".into(),
        base: site,
        phase_spreads_h: vec![0.0],
        seeds: vec![5, 9],
        battery_kwh: Vec::new(),
        cap_w: Vec::new(),
        battery: None,
    };
    let dir = std::env::temp_dir().join("powertrace_test_site_train_sweep");
    let _ = std::fs::remove_dir_all(&dir);
    let opts = test_opts().with_collect_series(false);
    let results = run_site_sweep(&mut gen, &grid, opts.clone(), Some(&dir));
    assert_eq!(results.len(), 2);
    let fac = |r: &SiteReport, role: &str| {
        r.facilities.iter().find(|f| f.role == role).map(|f| f.summary.stats).unwrap()
    };
    // The seed axis re-seeds the generated stream but leaves the
    // deterministic training profile untouched.
    assert_eq!(fac(&results[0].1, "training"), fac(&results[1].1, "training"));
    assert_ne!(fac(&results[0].1, "facility"), fac(&results[1].1, "facility"));
    // The whole mixed sweep reruns byte-identically.
    let dir2 = std::env::temp_dir().join("powertrace_test_site_train_sweep_rerun");
    let _ = std::fs::remove_dir_all(&dir2);
    run_site_sweep(&mut gen, &grid, opts, Some(&dir2));
    assert_eq!(
        std::fs::read(dir.join("site_sweep_summary.csv")).unwrap(),
        std::fs::read(dir2.join("site_sweep_summary.csv")).unwrap()
    );
}

#[test]
fn battery_cap_sweep_axis_runs_and_orders_peaks() {
    let (mut gen, ids) = synth_generator("site_ov_sweep", 8, 4, 1, 71).unwrap();
    let mut site = small_site(&ids[0], 2);
    site.name = "ovsweep".into();
    // Size the axes off the measured raw peak so the stages engage: the
    // battery shaves toward 80 %, the cap clips at 90 %.
    let baseline = run_site(&mut gen, &site, test_opts(), None);
    let raw_peak = baseline.site.stats.peak_w;
    let cap_w = 0.9 * raw_peak;
    let grid = SiteGrid {
        name: "sizing".into(),
        base: site,
        phase_spreads_h: vec![0.0],
        seeds: vec![5],
        battery_kwh: vec![0.0, 0.05],
        cap_w: vec![0.0, cap_w],
        battery: Some(OverlaySpec::Battery {
            capacity_kwh: 1.0,
            power_w: 5e3,
            efficiency: 0.9,
            threshold_w: 0.8 * raw_peak,
            initial_soc_frac: 0.5,
        }),
    };
    let dir = std::env::temp_dir().join("powertrace_test_site_ov_sweep");
    let _ = std::fs::remove_dir_all(&dir);
    let opts = test_opts().with_collect_series(false);
    let results = run_site_sweep(&mut gen, &grid, opts.clone(), Some(&dir));
    assert_eq!(results.len(), 4);
    // b0-c0 is the untouched baseline; every overlaid variant's peak is
    // bounded by it, and the capped variants respect their cap.
    let peak = |id: &str| {
        results
            .iter()
            .find(|(v, _)| v.id == format!("p0-s5-{id}"))
            .map(|(_, r)| r.site.stats.peak_w)
            .unwrap()
    };
    assert!(results[0].1.site.overlay.is_none());
    // The baseline variant reproduces the pre-sweep baseline exactly.
    assert_eq!(peak("b0-c0").to_bits(), raw_peak.to_bits());
    // With its threshold below the raw peak, a shaving battery never
    // raises the peak (charging is bounded by the gap to the threshold,
    // so net load ≤ max(raw, threshold) = raw peak); the capped variants
    // respect the cap.
    assert!(peak("b1-c0") <= peak("b0-c0"));
    assert!(peak("b0-c1") <= cap_w * (1.0 + 1e-6));
    assert!(peak("b1-c1") <= cap_w * (1.0 + 1e-6));
    // The sweep summary carries the overlay columns (some variant has a
    // chain) with aligned rows, and reruns byte-identically.
    let text = std::fs::read_to_string(dir.join("site_sweep_summary.csv")).unwrap();
    let header = text.lines().next().unwrap();
    assert!(header.contains(",net_peak_w,"));
    for line in text.lines().skip(1) {
        assert_eq!(line.matches(',').count(), header.matches(',').count(), "{line}");
    }
    let dir2 = std::env::temp_dir().join("powertrace_test_site_ov_sweep_rerun");
    let _ = std::fs::remove_dir_all(&dir2);
    run_site_sweep(&mut gen, &grid, opts, Some(&dir2));
    assert_eq!(
        std::fs::read(dir.join("site_sweep_summary.csv")).unwrap(),
        std::fs::read(dir2.join("site_sweep_summary.csv")).unwrap()
    );
}
