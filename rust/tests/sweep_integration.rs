//! Integration: the sweep engine end-to-end — grid expansion feeding the
//! facility pipeline, shared prepared configs, multi-scale export shapes,
//! and bit-exact reproducibility of the summary across runs and worker
//! counts.

use powertrace_sim::aggregate::Topology;
use powertrace_sim::api::{self, RunKind, RunOptions, RunOutcome, RunRequest, RunSpec};
use powertrace_sim::config::{ServerAssignment, WorkloadSpec};
use powertrace_sim::coordinator::Generator;
use powertrace_sim::export::DirSink;
use powertrace_sim::scenarios::{GridDefaults, SweepGrid, SweepReport};
use powertrace_sim::testutil::synth_generator;

fn generator() -> Option<Generator> {
    match Generator::native() {
        Ok(g) => Some(g),
        Err(e) => {
            eprintln!("skipping sweep integration tests: {e:#}");
            None
        }
    }
}

fn sweep_defaults() -> RunOptions {
    RunOptions::defaults_for(RunKind::Sweep)
}

fn run(gen: &mut Generator, grid: &SweepGrid, options: RunOptions) -> SweepReport {
    let req = RunRequest { spec: RunSpec::Sweep(grid.clone()), options };
    match api::execute(gen, &req, None).unwrap() {
        RunOutcome::Sweep(r) => r,
        _ => unreachable!(),
    }
}

fn small_grid(ids: &[String]) -> SweepGrid {
    SweepGrid {
        name: "itest".into(),
        defaults: GridDefaults { horizon_s: 60.0, ..GridDefaults::default() },
        workloads: vec![
            WorkloadSpec::Poisson { rate: 0.5 },
            WorkloadSpec::Mmpp { mean_rate: 0.5, burstiness: 4.0 },
        ],
        topologies: vec![Topology { rows: 1, racks_per_row: 2, servers_per_rack: 1 }],
        fleets: vec![ServerAssignment::Uniform(ids[0].clone())],
        seeds: vec![3, 4],
    }
}

#[test]
fn sweep_runs_and_exports_every_scale() {
    let Some(mut gen) = generator() else { return };
    let ids = gen.store.manifest.configs.clone();
    let grid = small_grid(&ids);
    let report = run(&mut gen, &grid, sweep_defaults().with_dt(0.25));
    assert_eq!(report.cells.len(), 4);
    for c in &report.cells {
        // 60 s horizon: 2 racks @1s → 60 pts, 1 row @15s → 4 pts,
        // facility @300s/@900s → single partial-window points.
        let scales = c.scales.as_ref().expect("buffered cells carry scales");
        assert_eq!(scales.racks_w.len(), 2);
        assert_eq!(scales.racks_w[0].len(), 60);
        assert_eq!(scales.rows_w.len(), 1);
        assert_eq!(scales.rows_w[0].len(), 4);
        assert_eq!(scales.facility_w.len(), 2);
        assert_eq!(scales.facility_w[0].len(), 1);
        assert!(c.stats.peak_w >= c.stats.p99_w);
        assert!(c.stats.p99_w >= c.stats.avg_w);
        // Facility floor: 2 servers × 1 kW base × PUE.
        assert!(c.stats.avg_w > 2.0 * 1000.0 * 1.3);
    }
}

#[test]
fn sweep_summary_is_reproducible_across_runs_and_worker_counts() {
    let Some(mut gen) = generator() else { return };
    let ids = gen.store.manifest.configs.clone();
    let grid = small_grid(&ids);
    let a = run(&mut gen, &grid, sweep_defaults());
    // Different parallelism layout, fresh generator: same bytes.
    let mut gen2 = generator().unwrap();
    let b = run(&mut gen2, &grid, sweep_defaults().with_workers(1).with_server_workers(2));
    assert_eq!(a.summary_csv(), b.summary_csv());
    for (x, y) in a.cells.iter().zip(&b.cells) {
        let (xs, ys) = (x.scales.as_ref().unwrap(), y.scales.as_ref().unwrap());
        assert_eq!(xs.racks_w, ys.racks_w);
        assert_eq!(xs.rows_w, ys.rows_w);
        assert_eq!(xs.facility_w, ys.facility_w);
    }
}

#[test]
fn sweep_batched_output_matches_sequential_bytes() {
    // The sweep engine inherits rack batching through
    // facility_shared_batched; per-cell exports must be byte-identical to
    // the sequential (max_batch = 1) pipeline. Runs on a synthetic store.
    let (mut gen, ids) = synth_generator("sweep_batch", 8, 4, 1, 17).unwrap();
    let grid = SweepGrid {
        name: "batch-parity".into(),
        defaults: GridDefaults { horizon_s: 60.0, ..GridDefaults::default() },
        workloads: vec![
            WorkloadSpec::Poisson { rate: 0.5 },
            WorkloadSpec::Mmpp { mean_rate: 0.5, burstiness: 4.0 },
        ],
        topologies: vec![Topology { rows: 1, racks_per_row: 2, servers_per_rack: 3 }],
        fleets: vec![ServerAssignment::Uniform(ids[0].clone())],
        seeds: vec![3, 4],
    };
    let a = run(&mut gen, &grid, sweep_defaults().with_max_batch(1));
    let b = run(&mut gen, &grid, sweep_defaults());
    assert_eq!(a.summary_csv(), b.summary_csv());
    for (x, y) in a.cells.iter().zip(&b.cells) {
        let (xs, ys) = (x.scales.as_ref().unwrap(), y.scales.as_ref().unwrap());
        assert_eq!(xs.racks_w, ys.racks_w);
        assert_eq!(xs.rows_w, ys.rows_w);
        assert_eq!(xs.facility_w, ys.facility_w);
    }
}

#[test]
fn streamed_sweep_export_is_byte_identical_to_buffered() {
    // The streaming-export acceptance invariant: for a horizon both paths
    // can hold, a windowed `api::execute` against a directory sink must
    // leave byte-identical files on disk — summary.csv (exact-quantile
    // fallback ⇒ identical stats), grid.json, every scenario.json, and
    // every incremental rack/row/facility series CSV.
    let (mut gen, ids) = synth_generator("sweep_stream_parity", 8, 4, 1, 31).unwrap();
    let grid = SweepGrid {
        name: "stream-parity".into(),
        defaults: GridDefaults { horizon_s: 60.0, ..GridDefaults::default() },
        workloads: vec![
            WorkloadSpec::Poisson { rate: 0.5 },
            WorkloadSpec::Mmpp { mean_rate: 0.5, burstiness: 4.0 },
        ],
        topologies: vec![Topology { rows: 1, racks_per_row: 2, servers_per_rack: 3 }],
        fleets: vec![ServerAssignment::Uniform(ids[0].clone())],
        seeds: vec![3],
    };
    let dir_buf = std::env::temp_dir().join("powertrace_test_stream_parity_buffered");
    let dir_str = std::env::temp_dir().join("powertrace_test_stream_parity_streamed");
    let _ = std::fs::remove_dir_all(&dir_buf);
    let _ = std::fs::remove_dir_all(&dir_str);

    let buffered = run(&mut gen, &grid, sweep_defaults());
    buffered.write(&dir_buf).unwrap();

    // 7 s windows: 60 s / 0.25 s = 240 steps = 8×28 + 16 → ragged tail.
    // `api::execute` with a sink streams the incremental series through it
    // and then writes the one-shot artifacts (grid.json, summary.csv,
    // per-cell scenario.json) to the same sink — no separate write() call.
    std::fs::create_dir_all(&dir_str).unwrap();
    let req = RunRequest {
        spec: RunSpec::Sweep(grid.clone()),
        options: sweep_defaults().with_window(7.0),
    };
    let sink = DirSink::new(&dir_str);
    let RunOutcome::Sweep(streamed) = api::execute(&mut gen, &req, Some(&sink)).unwrap() else {
        unreachable!()
    };

    for (b, s) in buffered.cells.iter().zip(&streamed.cells) {
        assert!(s.scales.is_none(), "streamed cells must not buffer series");
        assert!(s.exact_quantiles, "60 s horizon fits the exact-quantile cap");
        assert_eq!(b.stats, s.stats, "cell {}", b.cell.id);
    }
    assert_eq!(buffered.summary_csv(), streamed.summary_csv());

    let mut compared = 0;
    for c in &buffered.cells {
        for name in ["scenario.json", "racks_1s.csv", "rows_15s.csv", "facility_300s.csv", "facility_900s.csv"] {
            let a = std::fs::read(dir_buf.join(&c.cell.id).join(name)).unwrap();
            let b = std::fs::read(dir_str.join(&c.cell.id).join(name))
                .unwrap_or_else(|e| panic!("{}/{name}: {e}", c.cell.id));
            assert_eq!(a, b, "cell {} file {name} differs", c.cell.id);
            compared += 1;
        }
    }
    assert_eq!(compared, 10);
    for name in ["summary.csv", "grid.json"] {
        let a = std::fs::read(dir_buf.join(name)).unwrap();
        let b = std::fs::read(dir_str.join(name)).unwrap();
        assert_eq!(a, b, "{name} differs");
    }
}

#[test]
fn sweep_shares_prepared_configs_across_cells() {
    let Some(mut gen) = generator() else { return };
    let ids = gen.store.manifest.configs.clone();
    let grid = small_grid(&ids);
    run(&mut gen, &grid, sweep_defaults());
    // The one config the grid references is prepared, and re-preparing
    // returns the same shared instance (pointer equality on the Arc).
    let p1 = gen.get_prepared(&ids[0]).expect("prepared by the sweep");
    let p2 = gen.prepare(&ids[0]).unwrap();
    assert!(std::sync::Arc::ptr_eq(&p1, &p2));
}

#[test]
fn sweep_report_write_creates_full_tree() {
    let Some(mut gen) = generator() else { return };
    let ids = gen.store.manifest.configs.clone();
    let grid = small_grid(&ids);
    let report = run(&mut gen, &grid, sweep_defaults());
    let dir = std::env::temp_dir().join("powertrace_test_sweep_report");
    let _ = std::fs::remove_dir_all(&dir);
    report.write(&dir).unwrap();
    assert!(dir.join("grid.json").exists());
    assert!(dir.join("summary.csv").exists());
    let cell = &report.cells[0].cell.id;
    assert!(dir.join(cell).join("scenario.json").exists());
    assert!(dir.join(cell).join("racks_1s.csv").exists());
    assert!(dir.join(cell).join("rows_15s.csv").exists());
    assert!(dir.join(cell).join("facility_300s.csv").exists());
    assert!(dir.join(cell).join("facility_900s.csv").exists());
    // The summary on disk matches the in-memory one (no timing columns).
    let on_disk = std::fs::read_to_string(dir.join("summary.csv")).unwrap();
    assert_eq!(on_disk, report.summary_csv());
}
