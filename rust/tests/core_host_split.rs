//! Integration: the core/host split — exports routed through the
//! in-memory [`MemSink`] are byte-identical to the file-backed
//! [`DirSink`](powertrace_sim::export::DirSink) path for a single
//! facility cell, a full sweep, and a composed site (across worker
//! counts and window sizes), and the sequential [`Executor`] reproduces
//! the threaded one bit-for-bit on seeded runs.
//!
//! These are the contract tests for embedding: a host that buffers
//! windows in memory (wasm, a service, a notebook) must see exactly the
//! bytes the CLI writes to disk.

use powertrace_sim::aggregate::Topology;
use powertrace_sim::api::{self, RunKind, RunOptions, RunOutcome, RunRequest, RunSpec};
use powertrace_sim::config::{ScenarioSpec, ServerAssignment, WorkloadSpec};
use powertrace_sim::coordinator::Generator;
use powertrace_sim::export::{DirSink, MemSink, TraceSink};
use powertrace_sim::scenarios::{GridDefaults, SweepGrid, SweepReport};
use powertrace_sim::site::{SiteReport, SiteSpec};
use powertrace_sim::testutil::synth_generator;
use powertrace_sim::util::threadpool::Executor;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// `api::execute` a sweep against a sink. For sweep kinds the API writes
/// the one-shot artifacts (grid.json, summary.csv, per-cell scenario.json)
/// through the sink after streaming, so no separate write() call follows.
fn run_sweep_sink(
    gen: &mut Generator,
    grid: &SweepGrid,
    options: RunOptions,
    sink: &dyn TraceSink,
) -> SweepReport {
    let req = RunRequest { spec: RunSpec::Sweep(grid.clone()), options };
    match api::execute(gen, &req, Some(sink)).unwrap() {
        RunOutcome::Sweep(r) => r,
        _ => unreachable!(),
    }
}

/// `api::execute` a site against a sink.
fn run_site_sink(
    gen: &mut Generator,
    spec: &SiteSpec,
    options: RunOptions,
    sink: &dyn TraceSink,
) -> SiteReport {
    let req = RunRequest { spec: RunSpec::Site(spec.clone()), options };
    match api::execute(gen, &req, Some(sink)).unwrap() {
        RunOutcome::Site(r) => r,
        _ => unreachable!(),
    }
}

// ---------------------------------------------------------------------------
// Fixtures (mirroring the sweep/site integration suites)
// ---------------------------------------------------------------------------

fn small_grid(ids: &[String]) -> SweepGrid {
    SweepGrid {
        name: "core-host".into(),
        defaults: GridDefaults { horizon_s: 60.0, ..GridDefaults::default() },
        workloads: vec![
            WorkloadSpec::Poisson { rate: 0.5 },
            WorkloadSpec::Mmpp { mean_rate: 0.5, burstiness: 4.0 },
        ],
        topologies: vec![Topology { rows: 1, racks_per_row: 2, servers_per_rack: 1 }],
        fleets: vec![ServerAssignment::Uniform(ids[0].clone())],
        seeds: vec![3, 4],
    }
}

/// A 1-cell grid: the "single facility run" case.
fn one_cell_grid(ids: &[String]) -> SweepGrid {
    SweepGrid {
        name: "core-host-one".into(),
        defaults: GridDefaults { horizon_s: 60.0, ..GridDefaults::default() },
        workloads: vec![WorkloadSpec::Poisson { rate: 0.5 }],
        topologies: vec![Topology { rows: 1, racks_per_row: 2, servers_per_rack: 1 }],
        fleets: vec![ServerAssignment::Uniform(ids[0].clone())],
        seeds: vec![7],
    }
}

fn small_site(id: &str, n_facilities: usize) -> SiteSpec {
    let mut s = ScenarioSpec::default_poisson(id, 0.5);
    s.topology = Topology { rows: 1, racks_per_row: 2, servers_per_rack: 2 };
    s.horizon_s = 60.0;
    s.seed = 5;
    let mut spec = SiteSpec::staggered("core-host", &s, n_facilities, 0.0);
    spec.utility_intervals_s = vec![15.0, 30.0];
    spec
}

fn temp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("powertrace_core_host_{tag}"));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Every file under `root`, keyed by `/`-separated root-relative path —
/// the same logical-path scheme `TraceSink` uses.
fn read_tree(root: &Path) -> BTreeMap<String, Vec<u8>> {
    fn walk(root: &Path, dir: &Path, out: &mut BTreeMap<String, Vec<u8>>) {
        for entry in std::fs::read_dir(dir).unwrap() {
            let p = entry.unwrap().path();
            if p.is_dir() {
                walk(root, &p, out);
            } else {
                let rel: Vec<String> = p
                    .strip_prefix(root)
                    .unwrap()
                    .components()
                    .map(|c| c.as_os_str().to_string_lossy().into_owned())
                    .collect();
                out.insert(rel.join("/"), std::fs::read(&p).unwrap());
            }
        }
    }
    let mut out = BTreeMap::new();
    walk(root, root, &mut out);
    out
}

type Tree = BTreeMap<String, Vec<u8>>;

fn assert_trees_equal(disk: &Tree, mem: &Tree, ctx: &str) {
    let dk: Vec<&String> = disk.keys().collect();
    let mk: Vec<&String> = mem.keys().collect();
    assert_eq!(dk, mk, "{ctx}: logical paths differ");
    for (path, bytes) in disk {
        assert_eq!(bytes, &mem[path], "{ctx}: bytes differ at {path}");
    }
}

// ---------------------------------------------------------------------------
// MemSink vs DirSink byte identity
// ---------------------------------------------------------------------------

#[test]
fn facility_cell_memsink_matches_dirsink_bytes() {
    let (mut gen, ids) = synth_generator("chs_cell", 8, 4, 1, 41).unwrap();
    let grid = one_cell_grid(&ids);
    let opts = RunOptions::defaults_for(RunKind::Sweep).with_window(7.0);

    let dir = temp_dir("cell");
    let disk = DirSink::new(&dir);
    let a = run_sweep_sink(&mut gen, &grid, opts.clone(), &disk);

    let mem = MemSink::new();
    let b = run_sweep_sink(&mut gen, &grid, opts, &mem);

    assert_eq!(a.summary_csv(), b.summary_csv());
    assert_trees_equal(&read_tree(&dir), &mem.files(), "facility cell");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn sweep_memsink_matches_dirsink_bytes_across_workers_and_windows() {
    let (mut gen, ids) = synth_generator("chs_sweep", 8, 4, 1, 43).unwrap();
    let grid = small_grid(&ids);
    for workers in [1usize, 4] {
        for window_s in [7.0f64, 60.0] {
            let ctx = format!("sweep workers={workers} window={window_s}");
            let opts = RunOptions::defaults_for(RunKind::Sweep)
                .with_window(window_s)
                .with_workers(workers)
                .with_server_workers(workers);

            let dir = temp_dir(&format!("sweep_w{workers}_s{window_s}"));
            let disk = DirSink::new(&dir);
            let a = run_sweep_sink(&mut gen, &grid, opts.clone(), &disk);

            let mem = MemSink::new();
            let b = run_sweep_sink(&mut gen, &grid, opts, &mem);

            assert_eq!(a.summary_csv(), b.summary_csv(), "{ctx}: summary");
            assert_trees_equal(&read_tree(&dir), &mem.files(), &ctx);
            std::fs::remove_dir_all(&dir).ok();
        }
    }
}

#[test]
fn site_memsink_matches_dirsink_bytes_across_workers_and_windows() {
    let (mut gen, ids) = synth_generator("chs_site", 8, 4, 1, 47).unwrap();
    let spec = small_site(&ids[0], 2);
    for workers in [1usize, 4] {
        for window_s in [7.0f64, 60.0] {
            let ctx = format!("site workers={workers} window={window_s}");
            let opts = RunOptions::defaults_for(RunKind::Site)
                .with_dt(0.25)
                .with_window(window_s)
                .with_workers(workers)
                .with_load_interval(1.0);

            let dir = temp_dir(&format!("site_w{workers}_s{window_s}"));
            let disk = DirSink::new(&dir);
            let a = run_site_sink(&mut gen, &spec, opts.clone(), &disk);

            let mem = MemSink::new();
            let b = run_site_sink(&mut gen, &spec, opts, &mem);

            assert_eq!(a.site.stats, b.site.stats, "{ctx}: site stats");
            assert_trees_equal(&read_tree(&dir), &mem.files(), &ctx);
            std::fs::remove_dir_all(&dir).ok();
        }
    }
}

// ---------------------------------------------------------------------------
// Sequential Executor vs threaded: bit identity
// ---------------------------------------------------------------------------

#[test]
fn sequential_executor_matches_threaded_sweep_bytes() {
    let (mut gen, ids) = synth_generator("chs_exec", 8, 4, 1, 53).unwrap();
    let grid = small_grid(&ids);
    let threaded = RunOptions::defaults_for(RunKind::Sweep)
        .with_window(7.0)
        .with_workers(4)
        .with_server_workers(2);

    let mem_t = MemSink::new();
    let a = run_sweep_sink(&mut gen, &grid, threaded.clone(), &mem_t);

    let sequential = threaded.with_executor(Executor::Sequential);
    let mem_s = MemSink::new();
    let b = run_sweep_sink(&mut gen, &grid, sequential, &mem_s);

    assert_eq!(a.summary_csv(), b.summary_csv());
    assert_trees_equal(&mem_t.files(), &mem_s.files(), "sequential vs threaded sweep");
}

#[test]
fn sequential_executor_matches_threaded_site_bytes() {
    let (mut gen, ids) = synth_generator("chs_exec_site", 8, 4, 1, 59).unwrap();
    let spec = small_site(&ids[0], 2);
    let threaded = RunOptions::defaults_for(RunKind::Site)
        .with_dt(0.25)
        .with_window(7.0)
        .with_workers(4)
        .with_load_interval(1.0);

    let mem_t = MemSink::new();
    let a = run_site_sink(&mut gen, &spec, threaded.clone(), &mem_t);

    let sequential = threaded.with_executor(Executor::Sequential);
    let mem_s = MemSink::new();
    let b = run_site_sink(&mut gen, &spec, sequential, &mem_s);

    assert_eq!(a.site.stats, b.site.stats);
    assert_trees_equal(&mem_t.files(), &mem_s.files(), "sequential vs threaded site");
}
