//! Integration: the artifacts produced by `make artifacts` satisfy every
//! structural invariant the runtime relies on.

use powertrace_sim::artifacts::ArtifactStore;
use powertrace_sim::catalog::Catalog;
use powertrace_sim::classifier::{flat_param_count, K_MAX};
use powertrace_sim::workload::validate;

fn store() -> Option<ArtifactStore> {
    match ArtifactStore::open_default() {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("skipping artifact integration tests: {e:#}");
            None
        }
    }
}

#[test]
fn manifest_is_consistent() {
    let Some(store) = store() else { return };
    assert_eq!(store.manifest.k_max, K_MAX);
    assert_eq!(store.manifest.hidden, 64);
    assert!(store.manifest.chunk.t >= 4 * store.manifest.chunk.halo);
    assert!(!store.manifest.configs.is_empty());
    assert!(store.hlo_path().exists(), "HLO artifact missing");
}

#[test]
fn every_config_artifact_is_valid() {
    let Some(store) = store() else { return };
    let cat = Catalog::load_default().unwrap();
    for id in &store.manifest.configs {
        let art = store.load_config(id).expect(id);
        assert_eq!(art.config_id, *id);
        assert!((1..=K_MAX).contains(&art.k), "{id}: k={}", art.k);
        art.dict.validate().expect(id);
        assert_eq!(art.weights.len(), flat_param_count(64, K_MAX), "{id}");
        assert!(art.weights.iter().all(|w| w.is_finite()), "{id}: non-finite weights");
        assert!(art.train_mean_w.is_finite() && art.train_mean_w > 0.0, "{id}");
        // Clip range within the physical envelope of the server.
        let cfg = cat.config(id).unwrap();
        let gpu = cat.gpu_of(cfg);
        let ceiling = cfg.n_gpus_server as f64 * gpu.tdp_w;
        assert!(art.dict.y_max <= ceiling + 1.0, "{id}: y_max beyond TDP ceiling");
        assert!(art.dict.y_min >= 0.0, "{id}");
        // Surrogate calibration is physically sane.
        assert!(art.surrogate.alpha1 > 0.0, "{id}: TTFT must grow with prompt length");
        assert!(art.surrogate.median_tbt() > 1e-4 && art.surrogate.median_tbt() < 1.0, "{id}");
        // MoE configs use AR(1), dense i.i.d.
        let is_moe = matches!(cat.model_of(cfg).kind, powertrace_sim::catalog::ModelKind::Moe);
        match art.mode {
            powertrace_sim::synth::SynthMode::Ar1 => assert!(is_moe, "{id}"),
            powertrace_sim::synth::SynthMode::Iid => assert!(!is_moe, "{id}"),
        }
        if is_moe {
            assert!(art.dict.phi.iter().any(|&p| p > 0.1), "{id}: MoE should have AR structure");
        }
    }
}

#[test]
fn measured_traces_parse_and_are_physical() {
    let Some(store) = store() else { return };
    let cat = Catalog::load_default().unwrap();
    for id in &store.manifest.configs {
        let traces = store.load_all_measured(id).expect(id);
        assert!(!traces.is_empty(), "{id}: no held-out traces");
        let cfg = cat.config(id).unwrap();
        let gpu = cat.gpu_of(cfg);
        for m in &traces {
            assert_eq!(m.dt_s, 0.25, "{id}");
            assert!(!m.power_w.is_empty());
            validate(&m.schedule, m.power_w.len() as f64 * m.dt_s + 1.0).expect(id);
            let ceiling = (cfg.n_gpus_server as f64 * gpu.tdp_w) as f32;
            for &p in &m.power_w {
                assert!(p > 0.0 && p <= ceiling + 1.0, "{id}: power {p}");
            }
            for &a in &m.a_measured {
                assert!((0.0..=64.0).contains(&a), "{id}: A {a}");
            }
            assert!(m.durations.len() <= m.schedule.len(), "{id}");
            assert!(m.durations.len() > 0, "{id}: no completed requests");
        }
        // Held-out traces span multiple arrival rates (rep-level split).
        let mut rates: Vec<f64> = traces.iter().map(|m| m.rate).collect();
        rates.sort_by(|a, b| a.partial_cmp(b).unwrap());
        rates.dedup();
        assert!(rates.len() >= 3, "{id}: test traces should span rates, got {rates:?}");
    }
}
