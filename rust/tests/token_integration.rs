//! Integration: the token-level workload axis end-to-end — the
//! differential layer the tentpole is locked down by.
//!
//! * Degenerate equivalence: a token workload with constant lengths and
//!   batch 1 produces `OccupancyEvents` bit-identical to the poisson path
//!   at the same rate, reconstructed over every streaming window
//!   partition.
//! * Conservation: total served tokens equal the sum of sampled lengths
//!   no matter how the batching policy (slot cap × token budget) reshapes
//!   the schedule into batches.
//! * Layout invariance: facility bytes and streamed sweep exports are
//!   identical across window sizes {7, 13, 60} s and worker counts
//!   {1, 2, 4}.
//! * Replay cache: empirical length distributions and replay workloads
//!   sharing one trace path parse it exactly once, even under concurrent
//!   facility runs.

use powertrace_sim::aggregate::Topology;
use powertrace_sim::api::{self, RunKind, RunOptions, RunOutcome, RunRequest, RunSpec};
use powertrace_sim::config::{ScenarioSpec, ServerAssignment, WorkloadSpec};
use powertrace_sim::coordinator::Generator;
use powertrace_sim::export::DirSink;
use powertrace_sim::scenarios::{GridDefaults, SweepGrid, SweepReport};
use powertrace_sim::surrogate::features::{features_interleaved_into, OccupancyEvents};
use powertrace_sim::surrogate::queue::max_concurrency;
use powertrace_sim::surrogate::{
    simulate_queue, simulate_queue_policy, QueuePolicy, SurrogateParams,
};
use powertrace_sim::testutil::{check, synth_generator};
use powertrace_sim::util::rng::Rng;
use powertrace_sim::workload::{
    poisson_arrivals, token_arrivals, total_tokens, LengthSampler, TokenLengths,
};

fn sweep_defaults() -> RunOptions {
    RunOptions::defaults_for(RunKind::Sweep)
}

fn run(gen: &mut Generator, grid: &SweepGrid, options: RunOptions) -> SweepReport {
    let req = RunRequest { spec: RunSpec::Sweep(grid.clone()), options };
    match api::execute(gen, &req, None).unwrap() {
        RunOutcome::Sweep(r) => r,
        _ => unreachable!(),
    }
}

/// Deterministic surrogate (σ = 0 everywhere): TTFT depends only on
/// `n_in`, and decode time is exactly `n_out × 0.01 s` — so intervals
/// encode the sampled token counts, which the conservation test exploits.
fn det_params() -> SurrogateParams {
    SurrogateParams {
        alpha0: -2.0,
        alpha1: 0.7,
        sigma_ttft: 0.0,
        mu_log_tbt: (0.01f64).ln(),
        sigma_log_tbt: 0.0,
    }
}

/// Reconstruct interleaved `(A_t, ΔA_t)` rows window-by-window.
fn fill_windowed(ev: &OccupancyEvents, n_steps: usize, window: usize) -> Vec<f32> {
    let mut got = vec![0.0f32; 2 * n_steps];
    let mut t0 = 0;
    while t0 < n_steps {
        let n = window.min(n_steps - t0);
        ev.fill_interleaved(t0, n, &mut got[2 * t0..2 * (t0 + n)]);
        t0 += n;
    }
    got
}

#[test]
fn degenerate_token_occupancy_is_bitwise_the_poisson_path() {
    // The tentpole's differential anchor, one level above the schedule
    // unit test: constant-length token traffic at batch 1 must flow
    // through queue → OccupancyEvents → windowed feature rows with the
    // exact bits of the poisson path at the same rate — including the RNG
    // states both paths leave behind.
    let (horizon, dt) = (600.0, 0.25);
    let n_steps = (horizon / dt) as usize;
    let sampler = TokenLengths::Fixed { n_in: 256, n_out: 64 }.sampler_local().unwrap();
    let reference = LengthSampler::fixed(256, 64);
    for seed in [1u64, 9, 33] {
        let mut ra = Rng::new(seed).fork(0xA21);
        let mut rb = Rng::new(seed).fork(0xA21);
        let tok = token_arrivals(2.0, horizon, &sampler, &mut ra);
        let poi = poisson_arrivals(2.0, horizon, &reference, &mut rb);
        assert_eq!(tok.len(), poi.len(), "seed {seed}");
        assert!(!tok.is_empty(), "600 s at λ=2 cannot be empty");
        assert_eq!(ra.next_u64(), rb.next_u64(), "schedule RNG state diverged");

        let mut qa = Rng::new(seed).fork(0x5E21);
        let mut qb = Rng::new(seed).fork(0x5E21);
        let ivs_tok = simulate_queue_policy(&tok, &det_params(), QueuePolicy::slots(1), &mut qa);
        let ivs_poi = simulate_queue(&poi, &det_params(), 1, &mut qb);
        assert_eq!(qa.next_u64(), qb.next_u64(), "queue RNG state diverged");
        assert_eq!(max_concurrency(&ivs_tok), 1, "batch 1 fully serializes");

        let ev_tok = OccupancyEvents::from_intervals(&ivs_tok, n_steps, dt);
        let ev_poi = OccupancyEvents::from_intervals(&ivs_poi, n_steps, dt);
        assert_eq!(ev_tok.n_events(), ev_poi.n_events(), "seed {seed}");
        let mut diff = Vec::new();
        let mut rows_poi = Vec::new();
        features_interleaved_into(&ivs_poi, n_steps, dt, &mut diff, &mut rows_poi);
        // The streaming windows the engine actually uses (7/13/60 s).
        for window_s in [7.0f64, 13.0, 60.0] {
            let window = (window_s / dt) as usize;
            let rows_tok = fill_windowed(&ev_tok, n_steps, window);
            for (i, (a, b)) in rows_tok.iter().zip(&rows_poi).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "window {window_s}s element {i}");
            }
        }
    }
}

#[test]
fn prop_batching_policy_conserves_total_tokens() {
    // Batching parameters reshape *when* tokens are served, never *how
    // many*: with the σ=0 surrogate, each interval's decode time encodes
    // its n_out exactly, so the served totals can be reconstructed from
    // the queue output and compared against the sampled schedule.
    check("token totals conserved", |rng| {
        let rate = rng.range(0.5, 6.0);
        let spec = match rng.below(3) {
            0 => TokenLengths::Fixed {
                n_in: 1 + rng.below(1024) as u32,
                n_out: 1 + rng.below(256) as u32,
            },
            1 => TokenLengths::Lognormal {
                in_median: rng.range(16.0, 1024.0),
                in_sigma: rng.range(0.0, 1.2),
                out_median: rng.range(8.0, 256.0),
                out_sigma: rng.range(0.0, 1.2),
            },
            _ => TokenLengths::Pareto {
                in_min: rng.range(8.0, 256.0),
                in_alpha: rng.range(0.8, 3.0),
                out_min: rng.range(4.0, 64.0),
                out_alpha: rng.range(0.8, 3.0),
            },
        };
        let sampler = spec.sampler_local().unwrap();
        let mut local = rng.clone();
        let sched = token_arrivals(rate, 120.0, &sampler, &mut local);
        if sched.is_empty() {
            return;
        }
        let expected = total_tokens(&sched);
        let budget = 256 + rng.below(8192) as u64;
        let policies = [
            QueuePolicy::slots(1),
            QueuePolicy::slots(1 + rng.below(64)),
            QueuePolicy { max_batch: 1 + rng.below(16), token_budget: Some(budget) },
            QueuePolicy { max_batch: 64, token_budget: Some(u64::MAX) },
        ];
        for pol in policies {
            let mut qrng = local.clone();
            let ivs = simulate_queue_policy(&sched, &det_params(), pol, &mut qrng);
            assert_eq!(ivs.len(), sched.len(), "every request is served exactly once");
            assert!(max_concurrency(&ivs) <= pol.max_batch);
            let served: u64 = sched
                .iter()
                .zip(&ivs)
                .map(|(r, iv)| {
                    let n_out = (iv.decode_s / 0.01).round() as u64;
                    assert_eq!(n_out, r.n_out as u64, "decode must encode n_out");
                    r.n_in as u64 + n_out
                })
                .sum();
            assert_eq!(served, expected, "policy {pol:?}");
        }
    });
}

#[test]
fn prop_token_occupancy_reconstructs_over_any_window_partition() {
    // The streaming-resume contract on the token path: OccupancyEvents
    // built from a budget-packed token schedule reproduce the full-horizon
    // feature rows bit-for-bit over an arbitrary window partition.
    check("token occupancy windows", |rng| {
        let spec = TokenLengths::Lognormal {
            in_median: rng.range(32.0, 512.0),
            in_sigma: rng.range(0.0, 1.0),
            out_median: rng.range(16.0, 128.0),
            out_sigma: rng.range(0.0, 1.0),
        };
        let sampler = spec.sampler_local().unwrap();
        let mut local = rng.clone();
        let sched = token_arrivals(rng.range(0.5, 4.0), 60.0, &sampler, &mut local);
        if sched.is_empty() {
            return;
        }
        let pol = QueuePolicy { max_batch: 1 + rng.below(8), token_budget: Some(1024) };
        let ivs = simulate_queue_policy(&sched, &det_params(), pol, &mut local);
        let n_steps = 240; // 60 s at dt 0.25
        let ev = OccupancyEvents::from_intervals(&ivs, n_steps, 0.25);
        let mut diff = Vec::new();
        let mut reference = Vec::new();
        features_interleaved_into(&ivs, n_steps, 0.25, &mut diff, &mut reference);
        let window = 1 + rng.below(n_steps);
        let got = fill_windowed(&ev, n_steps, window);
        for (i, (a, b)) in got.iter().zip(&reference).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "window {window} element {i}");
        }
    });
}

fn token_scenario(id: &str) -> ScenarioSpec {
    let mut s = ScenarioSpec::default_poisson(id, 0.5);
    s.topology = Topology { rows: 1, racks_per_row: 2, servers_per_rack: 2 };
    s.workload = WorkloadSpec::Token {
        rate: 0.8,
        lengths: TokenLengths::Lognormal {
            in_median: 256.0,
            in_sigma: 0.8,
            out_median: 64.0,
            out_sigma: 0.6,
        },
        max_batch: 6,
        token_budget: 2048,
    };
    s.horizon_s = 60.0;
    s.seed = 11;
    s
}

#[test]
fn token_facility_bytes_are_invariant_across_worker_and_batch_layouts() {
    // The token axis inherits the facility engine's determinism contract:
    // worker count and classifier batching width never change the bytes.
    let (mut gen, ids) = synth_generator("token_fac", 8, 4, 1, 41).unwrap();
    let spec = token_scenario(&ids[0]);
    let base = gen.facility(&spec, 0.25, 1).unwrap().facility_series();
    assert_eq!(base.len(), 240);
    for workers in [2usize, 4] {
        for max_batch in [1usize, 3, 0] {
            let run = gen.facility_shared_batched(&spec, 0.25, workers, max_batch).unwrap();
            let series = run.facility_series();
            assert_eq!(series.len(), base.len());
            for (i, (a, b)) in series.iter().zip(&base).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "workers {workers} max_batch {max_batch} step {i}"
                );
            }
        }
    }
}

fn token_grid(id: &str) -> SweepGrid {
    SweepGrid {
        name: "token-axis".into(),
        defaults: GridDefaults { horizon_s: 60.0, ..GridDefaults::default() },
        workloads: vec![
            WorkloadSpec::Token {
                rate: 0.8,
                lengths: TokenLengths::Fixed { n_in: 200, n_out: 40 },
                max_batch: 4,
                token_budget: 1024,
            },
            WorkloadSpec::Token {
                rate: 0.8,
                lengths: TokenLengths::Pareto {
                    in_min: 64.0,
                    in_alpha: 1.4,
                    out_min: 16.0,
                    out_alpha: 1.8,
                },
                max_batch: 4,
                token_budget: 0,
            },
        ],
        topologies: vec![Topology { rows: 1, racks_per_row: 2, servers_per_rack: 2 }],
        fleets: vec![ServerAssignment::Uniform(id.to_string())],
        seeds: vec![3],
    }
}

#[test]
fn token_sweep_exports_are_byte_identical_across_windows_and_workers() {
    // Satellite contract: the token axis sweeps end-to-end, and the
    // streamed exports match the buffered ones byte-for-byte for every
    // window size {7, 13, 60} s × worker count {1, 2, 4}.
    let (mut gen, ids) = synth_generator("token_sweep", 8, 4, 1, 47).unwrap();
    let grid = token_grid(&ids[0]);
    let dir_buf = std::env::temp_dir().join("powertrace_test_token_sweep_buffered");
    let _ = std::fs::remove_dir_all(&dir_buf);
    let buffered = run(&mut gen, &grid, sweep_defaults());
    buffered.write(&dir_buf).unwrap();
    let cell_files =
        ["scenario.json", "racks_1s.csv", "rows_15s.csv", "facility_300s.csv", "facility_900s.csv"];

    for (li, (window_s, workers)) in
        [(7.0f64, 1usize), (7.0, 4), (13.0, 2), (60.0, 1), (60.0, 4), (13.0, 1)]
            .into_iter()
            .enumerate()
    {
        let dir = std::env::temp_dir().join(format!("powertrace_test_token_sweep_{li}"));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let req = RunRequest {
            spec: RunSpec::Sweep(grid.clone()),
            options: sweep_defaults()
                .with_window(window_s)
                .with_workers(1)
                .with_server_workers(workers),
        };
        let sink = DirSink::new(&dir);
        let RunOutcome::Sweep(streamed) = api::execute(&mut gen, &req, Some(&sink)).unwrap()
        else {
            unreachable!()
        };
        assert_eq!(
            buffered.summary_csv(),
            streamed.summary_csv(),
            "window {window_s}s workers {workers}"
        );
        for c in &buffered.cells {
            for name in cell_files {
                let a = std::fs::read(dir_buf.join(&c.cell.id).join(name)).unwrap();
                let b = std::fs::read(dir.join(&c.cell.id).join(name))
                    .unwrap_or_else(|e| panic!("{}/{name}: {e}", c.cell.id));
                assert_eq!(a, b, "window {window_s}s workers {workers} cell {} {name}", c.cell.id);
            }
        }
    }
}

#[test]
fn token_grid_json_roundtrip_preserves_the_token_axis() {
    // The sweep-grid file format carries the token axis losslessly, so a
    // written grid is a complete reproduction recipe for a token sweep.
    let grid = token_grid("some_config");
    let dir = std::env::temp_dir().join("powertrace_test_token_grid");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("grid.json");
    grid.save(&path).unwrap();
    let back = SweepGrid::load(&path).unwrap();
    assert_eq!(back, grid);
    assert_eq!(back.workloads[0].kind(), "token");
}

#[test]
fn empirical_trace_parses_once_under_concurrent_access() {
    // The checked-in request trace drives both workload kinds that read
    // traces — replay and token-empirical — concurrently over one
    // generator; the per-path cache must hold exactly one parsed trace.
    let path = "data/traces/sample_requests.csv";
    assert!(std::path::Path::new(path).exists(), "fixture must be checked in");
    let (mut gen, ids) = synth_generator("token_replay", 8, 4, 1, 53).unwrap();
    let mut tok = ScenarioSpec::default_poisson(&ids[0], 0.5);
    tok.topology = Topology { rows: 1, racks_per_row: 1, servers_per_rack: 2 };
    tok.workload = WorkloadSpec::Token {
        rate: 1.0,
        lengths: TokenLengths::Empirical { path: path.to_string() },
        max_batch: 4,
        token_budget: 0,
    };
    tok.horizon_s = 60.0;
    tok.seed = 7;
    let mut rep = tok.clone();
    rep.workload = WorkloadSpec::Replay { path: path.to_string(), offset_s: 0.0 };

    gen.prepare_for(&tok).unwrap();
    assert_eq!(gen.cached_replay_paths(), 0, "prepare must not touch traces");

    // Empirical lengths resample only pairs present in the fixture
    // (columns generated as 16 + s%1500 and 8 + s%400).
    let sched = gen.schedule_for(&tok, 0, &Rng::new(tok.seed)).unwrap();
    assert!(!sched.is_empty());
    for r in &sched {
        assert!((16..=1515).contains(&r.n_in), "n_in {} outside fixture range", r.n_in);
        assert!((8..=407).contains(&r.n_out), "n_out {} outside fixture range", r.n_out);
    }
    assert_eq!(gen.cached_replay_paths(), 1);

    let gen = gen; // freeze: concurrent runs borrow the generator shared
    let series: Vec<Vec<f32>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let spec = if i % 2 == 0 { tok.clone() } else { rep.clone() };
                let gref = &gen;
                s.spawn(move || gref.facility_shared(&spec, 0.25, 1).unwrap().facility_series())
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    // Same spec ⇒ same bytes, no matter which thread parsed the trace.
    assert_eq!(series[0], series[2], "token-empirical runs diverged");
    assert_eq!(series[1], series[3], "replay runs diverged");
    assert_ne!(series[0], series[1], "distinct workload kinds must differ");
    assert_eq!(gen.cached_replay_paths(), 1, "one path ⇒ one parsed trace");
}

#[test]
fn replay_sweep_over_the_fixture_is_deterministic() {
    // The replay axis sweeps end-to-end off the checked-in CSV, shares
    // the parsed trace across every cell, and reproduces its summary
    // byte-for-byte on a rerun.
    let path = "data/traces/sample_requests.csv";
    let (mut gen, ids) = synth_generator("replay_sweep_t", 8, 4, 1, 59).unwrap();
    let grid = SweepGrid {
        name: "replay-axis".into(),
        defaults: GridDefaults { horizon_s: 60.0, ..GridDefaults::default() },
        workloads: vec![
            WorkloadSpec::Replay { path: path.to_string(), offset_s: 0.0 },
            WorkloadSpec::Replay { path: path.to_string(), offset_s: 30.0 },
            WorkloadSpec::Token {
                rate: 1.0,
                lengths: TokenLengths::Empirical { path: path.to_string() },
                max_batch: 8,
                token_budget: 4096,
            },
        ],
        topologies: vec![Topology { rows: 1, racks_per_row: 1, servers_per_rack: 2 }],
        fleets: vec![ServerAssignment::Uniform(ids[0].clone())],
        seeds: vec![0, 1],
    };
    let a = run(&mut gen, &grid, sweep_defaults().with_workers(2));
    assert_eq!(a.cells.len(), 6);
    assert_eq!(gen.cached_replay_paths(), 1, "all six cells share one parsed trace");
    let b = run(&mut gen, &grid, sweep_defaults());
    assert_eq!(a.summary_csv(), b.summary_csv(), "replay sweep must be reproducible");
}
