//! Integration: the AOT-compiled XLA artifacts executed through PJRT agree
//! with the pure-Rust reference implementations — the cross-layer
//! correctness contract of the three-layer architecture.

use powertrace_sim::artifacts::ArtifactStore;
use powertrace_sim::classifier::chunk::FixedLenClassifier;
use powertrace_sim::classifier::native::BiGruWeights;
use powertrace_sim::classifier::pjrt::PjrtBiGru;
use powertrace_sim::classifier::{NativeBiGru, StateClassifier};
use powertrace_sim::runtime::Runtime;
use powertrace_sim::states::Gmm1d;
use powertrace_sim::testutil::assert_allclose;
use powertrace_sim::util::rng::Rng;
use std::sync::Arc;

fn store() -> Option<ArtifactStore> {
    match ArtifactStore::open_default() {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("skipping pjrt integration tests: {e:#}");
            None
        }
    }
}

fn realistic_features(t: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let mut a = 0i32;
    let mut out = Vec::with_capacity(2 * t);
    for _ in 0..t {
        let da = rng.below(5) as i32 - 2;
        let na = (a + da).clamp(0, 64);
        out.push(na as f32);
        out.push((na - a) as f32);
        a = na;
    }
    out
}

#[test]
fn pjrt_bigru_matches_native_on_trained_weights() {
    let Some(store) = store() else { return };
    let rt = Runtime::cpu().expect("pjrt cpu");
    let exe = Arc::new(rt.load_hlo_text(&store.hlo_path()).expect("compile bigru"));
    let spec = store.manifest.chunk;

    for id in store.manifest.configs.iter().take(3) {
        let art = store.load_config(id).unwrap();
        let native = NativeBiGru::new(
            BiGruWeights::new(store.manifest.hidden, store.manifest.k_max, art.weights.clone())
                .unwrap(),
        );
        let pjrt =
            PjrtBiGru::new(exe.clone(), art.weights.clone(), spec, store.manifest.k_max).unwrap();

        let x = realistic_features(spec.t, 42);
        let p_native = native.probs(&x, spec.t).unwrap();
        let p_pjrt = pjrt.probs_fixed(&x).unwrap();
        assert_allclose(&p_pjrt, &p_native, 1e-4, 1e-3, &format!("{id}: pjrt vs native"));
    }
}

#[test]
fn chunked_pjrt_matches_native_on_long_sequence() {
    let Some(store) = store() else { return };
    let rt = Runtime::cpu().unwrap();
    let exe = Arc::new(rt.load_hlo_text(&store.hlo_path()).unwrap());
    let id = &store.manifest.configs[0];
    let art = store.load_config(id).unwrap();

    let native = NativeBiGru::new(
        BiGruWeights::new(store.manifest.hidden, store.manifest.k_max, art.weights.clone())
            .unwrap(),
    );
    let chunked = PjrtBiGru::new(exe, art.weights.clone(), store.manifest.chunk, store.manifest.k_max)
        .unwrap()
        .chunked();

    // 1900 steps ≈ a full held-out trace: several chunks + a shifted tail.
    let t = 1900;
    let x = realistic_features(t, 7);
    let p_native = native.probs(&x, t).unwrap();
    let p_chunked = chunked.probs(&x, t).unwrap();
    // The trained BiGRU integrates occupancy over long windows, so halo
    // truncation perturbs some posteriors (measured: ≤0.25 at halo=64).
    // What the pipeline consumes is the *power expectation*; assert the
    // bounded prob perturbation AND the immaterial energy impact
    // (EXPERIMENTS.md §Perf documents the halo/cost tradeoff).
    let mut max_diff = 0.0f32;
    for (a, b) in p_native.iter().zip(&p_chunked) {
        max_diff = max_diff.max((a - b).abs());
    }
    assert!(max_diff < 0.35, "chunked vs full max prob diff {max_diff}");
    let k = art.k;
    let expected_power = |probs: &[f32]| -> f64 {
        let mut total = 0.0f64;
        for i in 0..t {
            let (mut p, mut z) = (0.0f64, 0.0f64);
            for j in 0..k {
                p += probs[i * 12 + j] as f64 * art.dict.mu[j];
                z += probs[i * 12 + j] as f64;
            }
            total += p / z.max(1e-9);
        }
        total
    };
    let e_full = expected_power(&p_native);
    let e_chunk = expected_power(&p_chunked);
    let rel = ((e_chunk - e_full) / e_full).abs();
    assert!(rel < 0.005, "chunking changes expected energy by {:.3}%", rel * 100.0);
}

#[test]
fn gmm_label_artifact_matches_rust_posterior() {
    let Some(store) = store() else { return };
    let path = store.root.join("gmm_label.hlo.txt");
    if !path.exists() {
        eprintln!("gmm_label.hlo.txt not built; skipping");
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load_hlo_text(&path).unwrap();
    let id = &store.manifest.configs[0];
    let art = store.load_config(id).unwrap();
    let k = art.k;

    // Pad mixture params to K_MAX as the artifact expects.
    let kmax = store.manifest.k_max;
    let mut pi = vec![1e-12f32; kmax];
    let mut mu = vec![0.0f32; kmax];
    let mut sigma = vec![1.0f32; kmax];
    for j in 0..k {
        pi[j] = art.dict.pi[j] as f32;
        mu[j] = art.dict.mu[j] as f32;
        sigma[j] = art.dict.sigma[j] as f32;
    }
    // Park unused components far away so they get ~zero posterior.
    for j in k..kmax {
        mu[j] = -1e6;
    }

    let t = store.manifest.chunk.t;
    let mut rng = Rng::new(9);
    let y: Vec<f32> = (0..t)
        .map(|_| {
            let j = rng.below(k);
            rng.normal_ms(art.dict.mu[j], art.dict.sigma[j]) as f32
        })
        .collect();
    let out = exe
        .run_f32_first(&[
            (&pi, &[kmax as i64]),
            (&mu, &[kmax as i64]),
            (&sigma, &[kmax as i64]),
            (&y, &[t as i64]),
        ])
        .unwrap();
    assert_eq!(out.len(), t * kmax);

    let gmm = Gmm1d::new(art.dict.pi.clone(), art.dict.mu.clone(), art.dict.sigma.clone());
    for (i, &yi) in y.iter().enumerate() {
        let post = gmm.posterior(yi as f64);
        let row = &out[i * kmax..i * kmax + k];
        let rust_row: Vec<f32> = post.iter().map(|&p| p as f32).collect();
        assert_allclose(row, &rust_row, 2e-4, 2e-3, &format!("sample {i}"));
    }
}
