//! Integration: the crash-safe sweep layer end-to-end — checkpointed
//! execution against the plain runner's bytes, manifest-driven resume
//! from arbitrary completed prefixes, per-cell quarantine of persistent
//! failures, and (behind `--features failpoints`) deterministic fault
//! injection at the named sites.
//!
//! Armed failpoints are process-global, so every test in this binary
//! serializes on one lock — a failpoint armed for one test must never
//! leak into a concurrently running sweep.

use powertrace_sim::aggregate::Topology;
use powertrace_sim::api::{
    self, CheckpointedOutcome, RunKind, RunOptions, RunOutcome, RunRequest, RunSpec,
};
use powertrace_sim::config::{ScenarioSpec, ServerAssignment, WorkloadSpec};
use powertrace_sim::coordinator::Generator;
use powertrace_sim::export::DirSink;
use powertrace_sim::robust::{CellStatus, RunManifest};
use powertrace_sim::scenarios::{GridDefaults, SweepGrid, SweepOutcome, SWEEP_MANIFEST};
use powertrace_sim::site::{
    sweep_summary_csv, SiteGrid, SiteReport, SiteSpec, SiteSweepOutcome, SiteVariant,
    SITE_SWEEP_MANIFEST,
};
use powertrace_sim::testutil::{check_seeded, synth_generator};
use std::path::{Path, PathBuf};
use std::sync::{Mutex, MutexGuard};

/// Checkpointed sweep through the unified API: the retry policy rides on
/// [`RunOptions`] (`max_retries`, `cell_timeout_s`).
fn run_sweep_checkpointed(
    gen: &mut Generator,
    grid: &SweepGrid,
    options: RunOptions,
    dir: &Path,
) -> SweepOutcome {
    let req = RunRequest { spec: RunSpec::Sweep(grid.clone()), options };
    match api::execute_checkpointed(gen, &req, dir).unwrap() {
        CheckpointedOutcome::Sweep(o) => o,
        _ => unreachable!(),
    }
}

/// Checkpointed site sweep through the unified API.
fn run_site_sweep_checkpointed(
    gen: &mut Generator,
    grid: &SiteGrid,
    options: RunOptions,
    dir: &Path,
) -> SiteSweepOutcome {
    let req = RunRequest { spec: RunSpec::SiteSweep(grid.clone()), options };
    match api::execute_checkpointed(gen, &req, dir).unwrap() {
        CheckpointedOutcome::SiteSweep(o) => o,
        _ => unreachable!(),
    }
}

/// Plain (non-checkpointed) site sweep against a directory sink.
fn run_site_sweep(
    gen: &mut Generator,
    grid: &SiteGrid,
    options: RunOptions,
    out_dir: &Path,
) -> Vec<(SiteVariant, SiteReport)> {
    let req = RunRequest { spec: RunSpec::SiteSweep(grid.clone()), options };
    let sink = DirSink::new(out_dir);
    match api::execute(gen, &req, Some(&sink)).unwrap() {
        RunOutcome::SiteSweep(r) => r,
        _ => unreachable!(),
    }
}

static SERIAL: Mutex<()> = Mutex::new(());

/// Serialize the whole binary (see the module docs). Poisoning is
/// harmless here — a failed test already reported its panic.
fn serial() -> MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

/// A fresh per-test output directory under the system temp root.
fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("powertrace_test_robust_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// 2 workloads × 1 topology × 1 fleet × 2 seeds = 4 cells
/// (`w{0,1}-t0-f0-s{3,4}`), 40 s horizon — small enough that every test
/// runs the grid several times.
fn small_grid(id: &str) -> SweepGrid {
    SweepGrid {
        name: "robust-itest".into(),
        defaults: GridDefaults { horizon_s: 40.0, ..GridDefaults::default() },
        workloads: vec![
            WorkloadSpec::Poisson { rate: 0.5 },
            WorkloadSpec::Mmpp { mean_rate: 0.5, burstiness: 4.0 },
        ],
        topologies: vec![Topology { rows: 1, racks_per_row: 1, servers_per_rack: 2 }],
        fleets: vec![ServerAssignment::Uniform(id.to_string())],
        seeds: vec![3, 4],
    }
}

/// 1 phase spread × 2 seeds = 2 variants (`p0-s0`, `p0-s7`) over a
/// 2-facility, 40 s site.
fn site_grid(id: &str) -> SiteGrid {
    let mut scenario = ScenarioSpec::default_poisson(id, 0.5);
    scenario.topology = Topology { rows: 1, racks_per_row: 1, servers_per_rack: 2 };
    scenario.horizon_s = 40.0;
    let mut base = SiteSpec::staggered("robust", &scenario, 2, 0.0);
    base.utility_intervals_s = vec![15.0, 30.0];
    SiteGrid {
        name: "robust-site".into(),
        base,
        phase_spreads_h: vec![0.0],
        seeds: vec![0, 7],
        battery_kwh: Vec::new(),
        cap_w: Vec::new(),
        battery: None,
    }
}

fn sweep_opts() -> RunOptions {
    RunOptions::defaults_for(RunKind::Sweep)
}

fn site_opts() -> RunOptions {
    RunOptions::defaults_for(RunKind::Site).with_dt(0.25).with_window(7.0).with_load_interval(1.0)
}

fn load_manifest(dir: &Path) -> RunManifest {
    RunManifest::load(&dir.join(SWEEP_MANIFEST)).unwrap()
}

/// Rewind one cell to `pending` the way a pre-completion crash would have
/// left it (attempts survive, the row and exports do not).
fn demote(m: &mut RunManifest, id: &str) {
    let c = m.cells.get_mut(id).unwrap();
    c.status = CellStatus::Pending;
    c.row = None;
    c.reason = None;
    c.exports.clear();
}

/// No `.tmp` staging file may survive a successful run, anywhere in the
/// output tree — atomic exports either rename into place or vanish.
fn assert_no_tmp(dir: &Path) {
    for entry in std::fs::read_dir(dir).unwrap() {
        let p = entry.unwrap().path();
        if p.is_dir() {
            assert_no_tmp(&p);
        } else {
            let stale = p.extension().map(|e| e == "tmp").unwrap_or(false);
            assert!(!stale, "stale staging file {}", p.display());
        }
    }
}

#[test]
fn checkpointed_run_matches_plain_run_and_completes_manifest() {
    let _guard = serial();
    let (mut gen, ids) = synth_generator("robust_ckpt_full", 8, 4, 1, 11).unwrap();
    let grid = small_grid(&ids[0]);
    let req = RunRequest { spec: RunSpec::Sweep(grid.clone()), options: sweep_opts() };
    let reference = api::execute(&mut gen, &req, None).unwrap().summary_csv();

    let dir = temp_dir("ckpt_full");
    let out = run_sweep_checkpointed(&mut gen, &grid, sweep_opts(), &dir);
    assert_eq!(out.summary_csv, reference, "checkpointed bytes == plain runner bytes");
    assert_eq!(out.restored, 0);
    assert!(out.failed.is_empty());
    assert_eq!(out.report.cells.len(), 4);
    assert_eq!(std::fs::read_to_string(dir.join("summary.csv")).unwrap(), reference);
    assert!(out.manifest_path.exists(), "{} must exist", out.manifest_path.display());

    let m = load_manifest(&dir);
    assert_eq!(m.kind, "sweep");
    assert_eq!(m.done_count(), 4);
    for (id, c) in &m.cells {
        assert_eq!(c.attempts, 1, "cell {id}");
        assert!(!c.exports.is_empty(), "cell {id} must record its exports");
        for e in &c.exports {
            let meta = std::fs::metadata(dir.join(&e.path))
                .unwrap_or_else(|err| panic!("export {}: {err}", e.path));
            assert_eq!(meta.len(), e.bytes, "recorded size of {}", e.path);
        }
    }
    assert_no_tmp(&dir);
}

#[test]
fn resume_reruns_demoted_cells_to_identical_bytes() {
    let _guard = serial();
    let (mut gen, ids) = synth_generator("robust_resume", 8, 4, 1, 19).unwrap();
    let grid = small_grid(&ids[0]);
    let dir = temp_dir("resume");
    let reference =
        run_sweep_checkpointed(&mut gen, &grid, sweep_opts().with_window(7.0), &dir).summary_csv;

    // Simulate a crash: one cell rewound in the manifest, one with its
    // export directory deleted (reconcile_exports must demote it), and
    // the assembled summary removed.
    let mut m = load_manifest(&dir);
    demote(&mut m, "w0-t0-f0-s3");
    m.save(&dir.join(SWEEP_MANIFEST)).unwrap();
    std::fs::remove_dir_all(dir.join("w0-t0-f0-s3")).unwrap();
    std::fs::remove_dir_all(dir.join("w1-t0-f0-s4")).unwrap();
    std::fs::remove_file(dir.join("summary.csv")).unwrap();

    // Resume under a different byte-invariant layout: window size and
    // worker counts may change freely between runs of one manifest.
    let opts2 = sweep_opts().with_window(16.0).with_workers(1).with_server_workers(2);
    let out = run_sweep_checkpointed(&mut gen, &grid, opts2, &dir);
    assert_eq!(out.restored, 2);
    assert_eq!(out.report.cells.len(), 2, "only the demoted cells re-run");
    assert!(out.failed.is_empty());
    assert_eq!(out.summary_csv, reference);
    assert_eq!(std::fs::read_to_string(dir.join("summary.csv")).unwrap(), reference);
    let m = load_manifest(&dir);
    assert_eq!(m.attempts("w0-t0-f0-s3"), 2, "demoted cells accumulate attempts");
    assert_eq!(m.attempts("w1-t0-f0-s4"), 2);
    assert_eq!(m.attempts("w0-t0-f0-s4"), 1);
    assert_eq!(m.attempts("w1-t0-f0-s3"), 1);
    assert_no_tmp(&dir);
}

#[test]
fn failing_cell_is_quarantined_then_resumes_clean() {
    let _guard = serial();
    let (mut gen, ids) = synth_generator("robust_quarantine", 8, 4, 1, 13).unwrap();
    // A replay workload whose trace file does not exist (yet): the load
    // happens lazily inside the cell run, so the failure is isolated to
    // that cell and the grid as a whole keeps going.
    let replay_path = std::env::temp_dir().join("powertrace_test_robust_replay.csv");
    let _ = std::fs::remove_file(&replay_path);
    let mut grid = small_grid(&ids[0]);
    grid.workloads = vec![
        WorkloadSpec::Poisson { rate: 0.5 },
        WorkloadSpec::Replay { path: replay_path.to_string_lossy().into_owned(), offset_s: 0.0 },
    ];
    grid.seeds = vec![3];
    let opts = sweep_opts().with_max_retries(2);

    let dir = temp_dir("quarantine");
    let out = run_sweep_checkpointed(&mut gen, &grid, opts.clone(), &dir);
    assert_eq!(out.report.cells.len(), 1, "the healthy cell still completes");
    assert_eq!(out.failed.len(), 1);
    assert_eq!(out.failed[0].id, "w1-t0-f0-s3");
    assert_eq!(out.failed[0].attempts, 3, "1 initial + 2 retries");
    assert!(!out.failed[0].reason.is_empty());
    assert_eq!(out.summary_csv.lines().count(), 2, "header + the one done row");

    // Provide the missing trace and resume: only the quarantined cell
    // re-runs, and the summary completes.
    std::fs::copy("data/traces/sample_requests.csv", &replay_path).unwrap();
    let out = run_sweep_checkpointed(&mut gen, &grid, opts.clone(), &dir);
    assert_eq!(out.restored, 1);
    assert!(out.failed.is_empty());
    let m = load_manifest(&dir);
    assert_eq!(m.done_count(), 2);
    assert_eq!(m.attempts("w1-t0-f0-s3"), 4, "3 failed attempts + the successful one");

    // A from-scratch run with the trace present produces the same bytes.
    let clean = temp_dir("quarantine_clean");
    let fresh = run_sweep_checkpointed(&mut gen, &grid, opts, &clean);
    assert_eq!(fresh.summary_csv, out.summary_csv);
}

#[test]
fn prop_resume_from_any_prefix_reproduces_summary_bytes() {
    let _guard = serial();
    let (mut gen, ids) = synth_generator("robust_prefix", 8, 4, 1, 41).unwrap();
    let grid = small_grid(&ids[0]);
    let req = RunRequest { spec: RunSpec::Sweep(grid.clone()), options: sweep_opts() };
    let reference = api::execute(&mut gen, &req, None).unwrap().summary_csv();
    let cell_ids: Vec<String> = grid.expand().iter().map(|c| c.id.clone()).collect();

    let gen = std::cell::RefCell::new(gen);
    let case_no = std::cell::Cell::new(0u32);
    check_seeded("resume from any completed prefix", 0xBEEF, 5, |rng| {
        let case = case_no.get();
        case_no.set(case + 1);
        let dir = temp_dir(&format!("prefix_{case}"));
        let opts1 = sweep_opts()
            .with_window(if rng.f64() < 0.5 { 7.0 } else { 0.0 })
            .with_workers(1 + (rng.f64() * 2.0) as usize);
        let mut g = gen.borrow_mut();
        let out = run_sweep_checkpointed(&mut g, &grid, opts1, &dir);
        assert_eq!(out.summary_csv, reference, "clean checkpointed run, case {case}");

        // Rewind a random subset to pending — a crash after an arbitrary
        // completed-cell prefix — then resume under an independently
        // random byte-invariant layout.
        let mut m = load_manifest(&dir);
        let mut demoted = 0;
        for id in &cell_ids {
            if rng.f64() < 0.5 {
                demote(&mut m, id);
                let _ = std::fs::remove_dir_all(dir.join(id));
                demoted += 1;
            }
        }
        m.save(&dir.join(SWEEP_MANIFEST)).unwrap();
        let _ = std::fs::remove_file(dir.join("summary.csv"));

        let opts2 = sweep_opts()
            .with_window(if rng.f64() < 0.5 { 16.0 } else { 0.0 })
            .with_workers(1 + (rng.f64() * 2.0) as usize)
            .with_server_workers(1 + (rng.f64() * 2.0) as usize);
        let out = run_sweep_checkpointed(&mut g, &grid, opts2, &dir);
        assert_eq!(out.restored, cell_ids.len() - demoted, "case {case}");
        assert_eq!(out.summary_csv, reference, "resumed run, case {case}");
        let _ = std::fs::remove_dir_all(&dir);
    });
}

#[test]
fn site_sweep_checkpoint_and_resume_are_byte_identical() {
    let _guard = serial();
    let (mut gen, ids) = synth_generator("robust_site", 8, 4, 1, 23).unwrap();
    let grid = site_grid(&ids[0]);
    let opts = site_opts();

    let dir = temp_dir("site_ckpt");
    let out = run_site_sweep_checkpointed(&mut gen, &grid, opts.clone(), &dir);
    assert_eq!(out.executed.len(), 2);
    assert_eq!(out.restored, 0);
    assert!(out.failed.is_empty());

    // The plain (non-checkpointed) sweep writes the same bytes — summary
    // and every per-variant export.
    let plain_dir = temp_dir("site_plain");
    let results = run_site_sweep(&mut gen, &grid, opts.clone(), &plain_dir);
    let plain = std::fs::read_to_string(plain_dir.join("site_sweep_summary.csv")).unwrap();
    assert_eq!(plain, sweep_summary_csv(&results));
    assert_eq!(out.summary_csv, plain);
    for (v, _) in &results {
        for name in ["site_load.csv", "site_summary.csv", "site_spec.json"] {
            let a = std::fs::read(dir.join(&v.id).join(name)).unwrap();
            let b = std::fs::read(plain_dir.join(&v.id).join(name)).unwrap();
            assert_eq!(a, b, "variant {} file {name}", v.id);
        }
    }

    // Delete one variant's load export: reconcile demotes it, resume
    // re-runs exactly that variant, and the summary bytes are unchanged.
    std::fs::remove_file(dir.join("p0-s7").join("site_load.csv")).unwrap();
    std::fs::remove_file(dir.join("site_sweep_summary.csv")).unwrap();
    let out = run_site_sweep_checkpointed(&mut gen, &grid, opts, &dir);
    assert_eq!(out.restored, 1);
    assert_eq!(out.executed.len(), 1);
    assert_eq!(out.executed[0].0.id, "p0-s7");
    assert!(out.failed.is_empty());
    assert_eq!(out.summary_csv, plain);
    assert_eq!(std::fs::read_to_string(dir.join("site_sweep_summary.csv")).unwrap(), plain);
    let m = RunManifest::load(&dir.join(SITE_SWEEP_MANIFEST)).unwrap();
    assert_eq!(m.kind, "site_sweep");
    assert_eq!(m.done_count(), 2);
    assert_no_tmp(&dir);
}

/// Deterministic fault injection at the named sites — compiled only with
/// `--features failpoints` (CI runs this suite in a dedicated job).
#[cfg(feature = "failpoints")]
mod failpoints {
    use super::*;
    use powertrace_sim::robust::failpoint::{arm, clear_all, FailAction, FailSpec};

    fn always(site: &str, tag: &str, action: FailAction) -> FailSpec {
        FailSpec { site: site.into(), tag: tag.into(), action, remaining: None }
    }

    fn once(site: &str, tag: &str, action: FailAction) -> FailSpec {
        FailSpec { site: site.into(), tag: tag.into(), action, remaining: Some(1) }
    }

    #[test]
    fn injected_panic_quarantines_cell_and_resume_recovers() {
        let _guard = serial();
        clear_all();
        let (mut gen, ids) = synth_generator("robust_fp_panic", 8, 4, 1, 29).unwrap();
        let grid = small_grid(&ids[0]);
        let opts = sweep_opts().with_max_retries(1);

        let dir = temp_dir("fp_panic");
        arm(always("sweep.cell", "w1-t0-f0-s3", FailAction::Panic));
        let out = run_sweep_checkpointed(&mut gen, &grid, opts.clone(), &dir);
        clear_all();
        assert_eq!(out.report.cells.len(), 3, "healthy cells complete despite the panic");
        assert_eq!(out.failed.len(), 1);
        assert_eq!(out.failed[0].id, "w1-t0-f0-s3");
        assert_eq!(out.failed[0].attempts, 2, "1 initial + 1 retry");
        assert!(out.failed[0].reason.contains("injected panic"), "{}", out.failed[0].reason);

        // Disarmed, the resume completes and matches a clean run.
        let out = run_sweep_checkpointed(&mut gen, &grid, opts.clone(), &dir);
        assert_eq!(out.restored, 3);
        assert!(out.failed.is_empty());
        let clean = temp_dir("fp_panic_clean");
        let fresh = run_sweep_checkpointed(&mut gen, &grid, opts, &clean);
        assert_eq!(fresh.summary_csv, out.summary_csv);
    }

    #[test]
    fn transient_panic_is_retried_to_success() {
        let _guard = serial();
        clear_all();
        let (mut gen, ids) = synth_generator("robust_fp_retry", 8, 4, 1, 31).unwrap();
        let grid = small_grid(&ids[0]);
        let dir = temp_dir("fp_retry");
        arm(once("sweep.cell", "w0-t0-f0-s4", FailAction::Panic));
        let out = run_sweep_checkpointed(&mut gen, &grid, sweep_opts(), &dir);
        clear_all();
        assert!(out.failed.is_empty(), "one panic fits the default retry budget");
        assert_eq!(out.report.cells.len(), 4);
        let m = load_manifest(&dir);
        assert_eq!(m.attempts("w0-t0-f0-s4"), 2);
        assert_eq!(m.attempts("w0-t0-f0-s3"), 1);
    }

    #[test]
    fn transient_export_error_retries_without_stale_tmp_files() {
        let _guard = serial();
        clear_all();
        let (mut gen, ids) = synth_generator("robust_fp_export", 8, 4, 1, 37).unwrap();
        let grid = small_grid(&ids[0]);
        let opts = sweep_opts().with_window(7.0);

        let clean = temp_dir("fp_export_clean");
        let reference = run_sweep_checkpointed(&mut gen, &grid, opts.clone(), &clean);

        // One injected write failure on the first rack-series export the
        // pool reaches: that cell fails mid-stream and is retried.
        let dir = temp_dir("fp_export");
        arm(once("export.write", "racks", FailAction::Error));
        let out = run_sweep_checkpointed(&mut gen, &grid, opts, &dir);
        clear_all();
        assert!(out.failed.is_empty());
        assert_eq!(out.report.cells.len(), 4);
        assert_eq!(out.summary_csv, reference.summary_csv);
        let m = load_manifest(&dir);
        let attempts: Vec<u32> = m.cells.values().map(|c| c.attempts).collect();
        assert_eq!(attempts.iter().sum::<u32>(), 5, "exactly one cell retried: {attempts:?}");
        assert_no_tmp(&dir);
        // The retried cell's exports match the clean run byte-for-byte.
        for (id, c) in &m.cells {
            for e in &c.exports {
                let a = std::fs::read(dir.join(&e.path)).unwrap();
                let b = std::fs::read(clean.join(&e.path)).unwrap();
                assert_eq!(a, b, "cell {id} export {}", e.path);
            }
        }
    }

    #[test]
    fn stalled_cell_exceeds_deadline_and_is_quarantined() {
        let _guard = serial();
        clear_all();
        let (mut gen, ids) = synth_generator("robust_fp_stall", 8, 4, 1, 43).unwrap();
        let grid = small_grid(&ids[0]);
        let opts = sweep_opts().with_window(7.0);

        // The stalled cell sleeps 1.5 s at its first window boundary and
        // the 1 s soft budget trips at the next deadline check; healthy
        // cells never sleep and finish far inside the budget.
        let dir = temp_dir("fp_stall");
        arm(always("sweep.cell.window", "w1-t0-f0-s4", FailAction::SleepMs(1500)));
        let strict = opts.clone().with_max_retries(0).with_cell_timeout(1.0);
        let out = run_sweep_checkpointed(&mut gen, &grid, strict, &dir);
        clear_all();
        assert_eq!(out.report.cells.len(), 3);
        assert_eq!(out.failed.len(), 1);
        assert_eq!(out.failed[0].id, "w1-t0-f0-s4");
        assert_eq!(out.failed[0].attempts, 1, "max_retries = 0: a single attempt");
        assert!(out.failed[0].reason.contains("budget"), "{}", out.failed[0].reason);

        // Disarmed, resume completes to the clean run's bytes (default
        // retry budget, no cell deadline).
        let out = run_sweep_checkpointed(&mut gen, &grid, opts.clone(), &dir);
        assert_eq!(out.restored, 3);
        assert!(out.failed.is_empty());
        let clean = temp_dir("fp_stall_clean");
        let fresh = run_sweep_checkpointed(&mut gen, &grid, opts, &clean);
        assert_eq!(fresh.summary_csv, out.summary_csv);
    }

    /// The graceful-shutdown contract: a SIGINT stand-in fired mid-sweep
    /// leaves interrupted cells *pending* (not quarantined, no attempt
    /// charged) behind a consistent manifest, and `--resume` converges to
    /// the uninterrupted run's bytes.
    #[test]
    fn interrupt_mid_sweep_leaves_pending_cells_and_resume_converges() {
        use powertrace_sim::robust::shutdown;
        let _guard = serial();
        clear_all();
        shutdown::reset();
        let (mut gen, ids) = synth_generator("robust_fp_interrupt", 8, 4, 1, 53).unwrap();
        let grid = small_grid(&ids[0]);
        // Sequential cells make the interrupt point deterministic: the
        // grid-order prefix before the armed cell completes, the rest
        // never starts.
        let options = RunOptions::defaults_for(RunKind::Sweep).with_window(7.0).with_workers(1);
        let req = RunRequest { spec: RunSpec::Sweep(grid.clone()), options };

        let clean = temp_dir("fp_interrupt_clean");
        let CheckpointedOutcome::Sweep(reference) =
            api::execute_checkpointed(&mut gen, &req, &clean).unwrap()
        else {
            unreachable!("sweep request yields a sweep outcome")
        };
        assert_eq!(reference.interrupted, 0);

        // "^C" at the third cell's first window boundary.
        let dir = temp_dir("fp_interrupt");
        arm(once("sweep.cell.window", "w1-t0-f0-s3", FailAction::Interrupt));
        let CheckpointedOutcome::Sweep(out) =
            api::execute_checkpointed(&mut gen, &req, &dir).unwrap()
        else {
            unreachable!("sweep request yields a sweep outcome")
        };
        clear_all();
        assert!(shutdown::requested(), "the failpoint stood in for the signal");
        shutdown::reset();
        assert_eq!(out.report.cells.len(), 2, "the pre-interrupt prefix completed");
        assert_eq!(out.interrupted, 2, "the interrupted cell and the never-started one");
        assert!(out.failed.is_empty(), "an interrupt is not a failure");
        let m = load_manifest(&dir);
        assert_eq!(m.done_count(), 2);
        assert_eq!(m.attempts("w1-t0-f0-s3"), 0, "no attempt charged for the interrupt");
        assert_eq!(m.attempts("w1-t0-f0-s4"), 0, "never started");
        assert_eq!(m.attempts("w0-t0-f0-s3"), 1);

        // The flushed manifest is a valid resume point: exactly the two
        // pending cells run, and the bytes converge.
        let CheckpointedOutcome::Sweep(resumed) =
            api::execute_checkpointed(&mut gen, &req, &dir).unwrap()
        else {
            unreachable!("sweep request yields a sweep outcome")
        };
        assert_eq!(resumed.restored, 2);
        assert_eq!(resumed.report.cells.len(), 2);
        assert_eq!(resumed.interrupted, 0);
        assert!(resumed.failed.is_empty());
        assert_eq!(resumed.summary_csv, reference.summary_csv);
        let m = load_manifest(&dir);
        assert_eq!(m.done_count(), 4);
        assert_eq!(m.attempts("w1-t0-f0-s3"), 1, "the resume attempt is the first charged");
        assert_no_tmp(&dir);
    }

    #[test]
    fn injected_site_variant_panic_quarantines_and_resumes() {
        let _guard = serial();
        clear_all();
        let (mut gen, ids) = synth_generator("robust_fp_site", 8, 4, 1, 47).unwrap();
        let grid = site_grid(&ids[0]);
        let opts = site_opts().with_max_retries(0);

        let dir = temp_dir("fp_site");
        arm(always("site.variant", "p0-s7", FailAction::Panic));
        let out = run_site_sweep_checkpointed(&mut gen, &grid, opts.clone(), &dir);
        clear_all();
        assert_eq!(out.executed.len(), 1, "the healthy variant completes");
        assert_eq!(out.failed.len(), 1);
        assert_eq!(out.failed[0].id, "p0-s7");
        assert!(out.failed[0].reason.contains("injected panic"), "{}", out.failed[0].reason);

        let out = run_site_sweep_checkpointed(&mut gen, &grid, opts.clone(), &dir);
        assert_eq!(out.restored, 1);
        assert!(out.failed.is_empty());
        let clean = temp_dir("fp_site_clean");
        let fresh = run_site_sweep_checkpointed(&mut gen, &grid, opts, &clean);
        assert_eq!(fresh.summary_csv, out.summary_csv);
    }
}
