//! Expectation–maximization fitting of 1-D GMMs with BIC model selection
//! (paper §3.2 / Fig. 4). Used by the Rust `fit` path and the Fig-4 bench;
//! the Python build path has an equivalent implementation whose outputs are
//! cross-checked in integration tests.

use super::gmm::{log_sum_exp, Gmm1d};
use crate::util::rng::Rng;
use anyhow::{ensure, Result};

#[derive(Debug, Clone, Copy)]
pub struct EmOptions {
    pub max_iters: usize,
    /// Convergence threshold on mean log-likelihood improvement.
    pub tol: f64,
    /// Random restarts; the best log-likelihood wins.
    pub n_init: usize,
    /// Variance floor as a fraction of data variance (avoids collapse).
    pub var_floor_frac: f64,
}

impl Default for EmOptions {
    fn default() -> Self {
        EmOptions { max_iters: 200, tol: 1e-6, n_init: 3, var_floor_frac: 1e-4 }
    }
}

/// k-means++-style seeding: spread initial means over the data.
fn init_means(ys: &[f32], k: usize, rng: &mut Rng) -> Vec<f64> {
    let mut means = Vec::with_capacity(k);
    means.push(ys[rng.below(ys.len())] as f64);
    while means.len() < k {
        // Sample proportional to squared distance to the nearest mean
        // (subsample for speed on long traces).
        let stride = (ys.len() / 2048).max(1);
        let mut weights: Vec<f32> = Vec::with_capacity(ys.len() / stride + 1);
        let mut idxs: Vec<usize> = Vec::with_capacity(ys.len() / stride + 1);
        for (i, &y) in ys.iter().enumerate().step_by(stride) {
            let d = means
                .iter()
                .map(|&m| (y as f64 - m).abs())
                .fold(f64::INFINITY, f64::min);
            weights.push((d * d) as f32);
            idxs.push(i);
        }
        let pick = rng.categorical(&weights);
        means.push(ys[idxs[pick]] as f64);
    }
    means
}

/// Fit a K-component GMM to `ys` by EM.
pub fn fit_gmm(ys: &[f32], k: usize, opts: &EmOptions, rng: &mut Rng) -> Result<Gmm1d> {
    ensure!(k >= 1, "k must be >= 1");
    ensure!(ys.len() >= 10 * k, "need >= {} samples for k={k}, got {}", 10 * k, ys.len());

    let n = ys.len();
    let mean = ys.iter().map(|&y| y as f64).sum::<f64>() / n as f64;
    let var = ys.iter().map(|&y| (y as f64 - mean).powi(2)).sum::<f64>() / n as f64;
    let var_floor = (var * opts.var_floor_frac).max(1e-9);

    let mut best: Option<(f64, Gmm1d)> = None;
    for _init in 0..opts.n_init {
        let mut mu = init_means(ys, k, rng);
        mu.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut pi = vec![1.0 / k as f64; k];
        let mut sigma = vec![(var / k as f64).sqrt().max(var_floor.sqrt()); k];

        let mut prev_ll = f64::NEG_INFINITY;
        let mut resp = vec![0.0f64; k]; // scratch
        let mut nk = vec![0.0f64; k];
        let mut sum_y = vec![0.0f64; k];
        let mut sum_y2 = vec![0.0f64; k];
        for _iter in 0..opts.max_iters {
            // E+M fused single pass: accumulate responsibilities.
            nk.iter_mut().for_each(|x| *x = 0.0);
            sum_y.iter_mut().for_each(|x| *x = 0.0);
            sum_y2.iter_mut().for_each(|x| *x = 0.0);
            let mut ll = 0.0f64;
            let log_pi: Vec<f64> = pi.iter().map(|&p| p.max(1e-300).ln()).collect();
            let inv_two_var: Vec<f64> = sigma.iter().map(|&s| 0.5 / (s * s)).collect();
            let log_sigma: Vec<f64> = sigma.iter().map(|&s| s.ln()).collect();
            for &yf in ys {
                let y = yf as f64;
                for j in 0..k {
                    let d = y - mu[j];
                    resp[j] = log_pi[j] - d * d * inv_two_var[j] - log_sigma[j];
                }
                let lse = log_sum_exp(&resp);
                ll += lse;
                for j in 0..k {
                    let r = (resp[j] - lse).exp();
                    nk[j] += r;
                    sum_y[j] += r * y;
                    sum_y2[j] += r * y * y;
                }
            }
            // M step.
            for j in 0..k {
                let w = nk[j].max(1e-12);
                pi[j] = w / n as f64;
                mu[j] = sum_y[j] / w;
                let v = (sum_y2[j] / w - mu[j] * mu[j]).max(var_floor);
                sigma[j] = v.sqrt();
            }
            // Renormalize weights (guards accumulation error).
            let total: f64 = pi.iter().sum();
            pi.iter_mut().for_each(|p| *p /= total);

            let mean_ll = ll / n as f64;
            if (mean_ll - prev_ll).abs() < opts.tol {
                prev_ll = mean_ll;
                break;
            }
            prev_ll = mean_ll;
        }
        let candidate = Gmm1d::new(pi.clone(), mu.clone(), sigma.clone());
        let ll = prev_ll;
        if best.as_ref().map(|(b, _)| ll > *b).unwrap_or(true) {
            best = Some((ll, candidate));
        }
    }
    Ok(best.expect("at least one init").1.sorted_by_mean().0)
}

/// BIC values across a range of K (paper Fig. 4).
#[derive(Debug, Clone)]
pub struct BicCurve {
    pub ks: Vec<usize>,
    pub bic: Vec<f64>,
    pub best_k: usize,
}

impl BicCurve {
    /// BIC normalized to [0,1] over the curve (as plotted in Fig. 4).
    pub fn normalized(&self) -> Vec<f64> {
        let lo = self.bic.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = self.bic.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let span = (hi - lo).max(1e-12);
        self.bic.iter().map(|b| (b - lo) / span).collect()
    }
}

/// Fit GMMs for each K in `k_range` and select the BIC minimizer, with the
/// paper's "plateau" rule: prefer the smallest K within `plateau_frac` of
/// the minimum BIC span (avoids buying components for negligible gain).
pub fn select_k(
    ys: &[f32],
    k_range: std::ops::RangeInclusive<usize>,
    opts: &EmOptions,
    rng: &mut Rng,
) -> Result<(Gmm1d, BicCurve)> {
    let mut ks = Vec::new();
    let mut bics = Vec::new();
    let mut fits = Vec::new();
    for k in k_range {
        let g = fit_gmm(ys, k, opts, rng)?;
        bics.push(g.bic(ys));
        fits.push(g);
        ks.push(k);
    }
    let lo = bics.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = bics.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let thresh = lo + 0.02 * (hi - lo).max(1e-12);
    let best_idx = bics.iter().position(|&b| b <= thresh).expect("nonempty");
    let curve = BicCurve { ks: ks.clone(), bic: bics, best_k: ks[best_idx] };
    Ok((fits.swap_remove(best_idx), curve))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_mixture(g: &Gmm1d, n: usize, rng: &mut Rng) -> Vec<f32> {
        (0..n)
            .map(|_| {
                let w: Vec<f32> = g.pi.iter().map(|&p| p as f32).collect();
                let k = rng.categorical(&w);
                rng.normal_ms(g.mu[k], g.sigma[k]) as f32
            })
            .collect()
    }

    #[test]
    fn recovers_well_separated_mixture() {
        let truth = Gmm1d::new(vec![0.3, 0.5, 0.2], vec![60.0, 200.0, 350.0], vec![5.0, 8.0, 6.0]);
        let mut rng = Rng::new(60);
        let ys = sample_mixture(&truth, 8000, &mut rng);
        let fit = fit_gmm(&ys, 3, &EmOptions::default(), &mut rng).unwrap();
        for j in 0..3 {
            assert!((fit.mu[j] - truth.mu[j]).abs() < 3.0, "mu[{j}] {}", fit.mu[j]);
            assert!((fit.pi[j] - truth.pi[j]).abs() < 0.03, "pi[{j}] {}", fit.pi[j]);
            assert!((fit.sigma[j] - truth.sigma[j]).abs() < 1.5, "sigma[{j}] {}", fit.sigma[j]);
        }
    }

    #[test]
    fn single_component_matches_moments() {
        let mut rng = Rng::new(61);
        let ys: Vec<f32> = (0..5000).map(|_| rng.normal_ms(100.0, 10.0) as f32).collect();
        let fit = fit_gmm(&ys, 1, &EmOptions::default(), &mut rng).unwrap();
        assert!((fit.mu[0] - 100.0).abs() < 0.5);
        assert!((fit.sigma[0] - 10.0).abs() < 0.3);
        assert_eq!(fit.pi[0], 1.0);
    }

    #[test]
    fn select_k_finds_true_order() {
        let truth = Gmm1d::new(
            vec![0.25, 0.25, 0.25, 0.25],
            vec![50.0, 150.0, 250.0, 350.0],
            vec![8.0, 8.0, 8.0, 8.0],
        );
        let mut rng = Rng::new(62);
        let ys = sample_mixture(&truth, 12_000, &mut rng);
        let (fit, curve) = select_k(&ys, 1..=7, &EmOptions::default(), &mut rng).unwrap();
        assert_eq!(curve.best_k, 4, "bic: {:?}", curve.bic);
        assert_eq!(fit.k(), 4);
        // Curve should drop then plateau: BIC(4) well below BIC(1).
        assert!(curve.bic[3] < curve.bic[0]);
    }

    #[test]
    fn bic_curve_normalization() {
        let c = BicCurve { ks: vec![1, 2, 3], bic: vec![100.0, 50.0, 60.0], best_k: 2 };
        let n = c.normalized();
        assert_eq!(n[0], 1.0);
        assert_eq!(n[1], 0.0);
        assert!((n[2] - 0.2).abs() < 1e-12);
    }

    #[test]
    fn variance_floor_prevents_collapse() {
        // Many repeated identical values tempt sigma → 0.
        let mut ys = vec![100.0f32; 500];
        ys.extend(vec![200.0f32; 500]);
        let mut rng = Rng::new(63);
        let fit = fit_gmm(&ys, 2, &EmOptions::default(), &mut rng).unwrap();
        assert!(fit.sigma.iter().all(|&s| s > 0.0 && s.is_finite()));
    }

    #[test]
    fn rejects_too_few_samples() {
        let mut rng = Rng::new(64);
        assert!(fit_gmm(&[1.0f32; 5], 2, &EmOptions::default(), &mut rng).is_err());
    }

    #[test]
    fn sorted_output_is_ascending() {
        let truth = Gmm1d::new(vec![0.5, 0.5], vec![300.0, 60.0], vec![10.0, 10.0]);
        let mut rng = Rng::new(65);
        let ys = sample_mixture(&truth, 4000, &mut rng);
        let fit = fit_gmm(&ys, 2, &EmOptions::default(), &mut rng).unwrap();
        assert!(fit.mu.windows(2).all(|w| w[0] <= w[1]));
    }
}
