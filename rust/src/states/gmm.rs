//! One-dimensional Gaussian mixture model (paper Eq. 1–2):
//! `p(y) = Σ_k π_k N(y | μ_k, σ_k²)`, with hard state labels from posterior
//! maximization `z_t = argmax_k π_k N(y_t | μ_k, σ_k²)`.

const LOG_2PI: f64 = 1.8378770664093453;

/// A 1-D GMM with K components.
#[derive(Debug, Clone, PartialEq)]
pub struct Gmm1d {
    pub pi: Vec<f64>,
    pub mu: Vec<f64>,
    pub sigma: Vec<f64>,
}

impl Gmm1d {
    pub fn new(pi: Vec<f64>, mu: Vec<f64>, sigma: Vec<f64>) -> Gmm1d {
        assert_eq!(pi.len(), mu.len());
        assert_eq!(pi.len(), sigma.len());
        assert!(!pi.is_empty());
        assert!(sigma.iter().all(|&s| s > 0.0), "sigmas must be positive");
        let total: f64 = pi.iter().sum();
        assert!((total - 1.0).abs() < 1e-6, "weights must sum to 1, got {total}");
        Gmm1d { pi, mu, sigma }
    }

    pub fn k(&self) -> usize {
        self.pi.len()
    }

    /// log N(y | μ_k, σ_k²)
    #[inline]
    pub fn log_normal(&self, y: f64, k: usize) -> f64 {
        let z = (y - self.mu[k]) / self.sigma[k];
        -0.5 * (z * z + LOG_2PI) - self.sigma[k].ln()
    }

    /// log p(y) via log-sum-exp over components.
    pub fn log_likelihood(&self, y: f64) -> f64 {
        let mut terms: Vec<f64> = Vec::with_capacity(self.k());
        for k in 0..self.k() {
            terms.push(self.pi[k].max(1e-300).ln() + self.log_normal(y, k));
        }
        log_sum_exp(&terms)
    }

    /// Total log-likelihood of a sample.
    pub fn total_log_likelihood(&self, ys: &[f32]) -> f64 {
        ys.iter().map(|&y| self.log_likelihood(y as f64)).sum()
    }

    /// Posterior responsibilities γ_k(y) (normalized).
    pub fn posterior(&self, y: f64) -> Vec<f64> {
        let logs: Vec<f64> =
            (0..self.k()).map(|k| self.pi[k].max(1e-300).ln() + self.log_normal(y, k)).collect();
        let lse = log_sum_exp(&logs);
        logs.iter().map(|l| (l - lse).exp()).collect()
    }

    /// Hard label by posterior maximization (paper Eq. 2).
    pub fn label(&self, y: f64) -> usize {
        let mut best = 0;
        let mut best_v = f64::NEG_INFINITY;
        for k in 0..self.k() {
            let v = self.pi[k].max(1e-300).ln() + self.log_normal(y, k);
            if v > best_v {
                best_v = v;
                best = k;
            }
        }
        best
    }

    /// Label a whole trace.
    pub fn label_trace(&self, ys: &[f32]) -> Vec<usize> {
        ys.iter().map(|&y| self.label(y as f64)).collect()
    }

    /// Return a copy with components sorted by ascending mean (the paper
    /// orders states from idle to full load), along with the permutation.
    pub fn sorted_by_mean(&self) -> (Gmm1d, Vec<usize>) {
        let mut idx: Vec<usize> = (0..self.k()).collect();
        idx.sort_by(|&a, &b| self.mu[a].partial_cmp(&self.mu[b]).unwrap());
        let g = Gmm1d {
            pi: idx.iter().map(|&i| self.pi[i]).collect(),
            mu: idx.iter().map(|&i| self.mu[i]).collect(),
            sigma: idx.iter().map(|&i| self.sigma[i]).collect(),
        };
        (g, idx)
    }

    /// Number of free parameters (for BIC): K-1 weights + K means + K vars.
    pub fn n_params(&self) -> usize {
        3 * self.k() - 1
    }

    /// BIC = k·ln(n) − 2·logL (lower is better).
    pub fn bic(&self, ys: &[f32]) -> f64 {
        let ll = self.total_log_likelihood(ys);
        self.n_params() as f64 * (ys.len() as f64).ln() - 2.0 * ll
    }
}

pub fn log_sum_exp(xs: &[f64]) -> f64 {
    let m = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if m.is_infinite() {
        return m;
    }
    m + xs.iter().map(|x| (x - m).exp()).sum::<f64>().ln()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn two_state() -> Gmm1d {
        Gmm1d::new(vec![0.5, 0.5], vec![0.0, 10.0], vec![1.0, 1.0])
    }

    #[test]
    fn density_integrates_to_one() {
        let g = two_state();
        // Riemann sum over a wide grid.
        let mut total = 0.0;
        let dx = 0.01;
        let mut x = -10.0;
        while x < 20.0 {
            total += g.log_likelihood(x).exp() * dx;
            x += dx;
        }
        assert!((total - 1.0).abs() < 1e-3, "integral {total}");
    }

    #[test]
    fn labels_assign_to_nearest_component() {
        let g = two_state();
        assert_eq!(g.label(-1.0), 0);
        assert_eq!(g.label(11.0), 1);
        assert_eq!(g.label(4.99), 0);
        assert_eq!(g.label(5.01), 1);
    }

    #[test]
    fn posterior_normalizes_and_is_confident_far_from_boundary() {
        let g = two_state();
        let p = g.posterior(0.0);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(p[0] > 0.99);
        let p = g.posterior(5.0);
        assert!((p[0] - 0.5).abs() < 1e-9, "{p:?}");
    }

    #[test]
    fn unequal_weights_shift_boundary() {
        let g = Gmm1d::new(vec![0.9, 0.1], vec![0.0, 10.0], vec![1.0, 1.0]);
        // At the midpoint the prior favors component 0.
        assert_eq!(g.label(5.0), 0);
    }

    #[test]
    fn sorted_by_mean_orders_states() {
        let g = Gmm1d::new(vec![0.2, 0.5, 0.3], vec![5.0, 1.0, 3.0], vec![1.0, 1.0, 1.0]);
        let (s, perm) = g.sorted_by_mean();
        assert_eq!(s.mu, vec![1.0, 3.0, 5.0]);
        assert_eq!(perm, vec![1, 2, 0]);
        assert_eq!(s.pi, vec![0.5, 0.3, 0.2]);
    }

    #[test]
    fn bic_prefers_true_model_order() {
        let mut rng = Rng::new(50);
        let truth = two_state();
        let ys: Vec<f32> = (0..4000)
            .map(|_| {
                let k = if rng.f64() < 0.5 { 0 } else { 1 };
                rng.normal_ms(truth.mu[k], truth.sigma[k]) as f32
            })
            .collect();
        let one = Gmm1d::new(vec![1.0], vec![5.0], vec![5.1]);
        assert!(truth.bic(&ys) < one.bic(&ys));
    }

    #[test]
    fn log_sum_exp_stable() {
        assert!((log_sum_exp(&[0.0, 0.0]) - 2f64.ln()).abs() < 1e-12);
        assert!((log_sum_exp(&[-1000.0, -1000.0]) - (-1000.0 + 2f64.ln())).abs() < 1e-9);
        assert_eq!(log_sum_exp(&[f64::NEG_INFINITY, -2.0]), -2.0);
    }

    #[test]
    #[should_panic]
    fn rejects_bad_weights() {
        Gmm1d::new(vec![0.5, 0.6], vec![0.0, 1.0], vec![1.0, 1.0]);
    }
}
