//! The state dictionary (paper §3.2–3.3): ordered operating states
//! `{(μ_k, σ_k)}` for one configuration, with per-state AR(1) coefficients
//! (MoE) and the observed clip range. This is the `states` block of the
//! per-configuration artifact JSON.

use super::gmm::Gmm1d;
use crate::util::json::{self, Json};
use anyhow::{ensure, Result};

/// Ordered power-state dictionary for one (H, M, TP) configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct StateDictionary {
    /// Mixture weights (sorted by ascending mean power).
    pub pi: Vec<f64>,
    /// State mean power (W), ascending (idle → full load).
    pub mu: Vec<f64>,
    /// State power std (W).
    pub sigma: Vec<f64>,
    /// Per-state AR(1) coefficient (≈0 for dense, >0 for MoE; paper Eq. 9).
    pub phi: Vec<f64>,
    /// Observed power range from training data; samples are clipped here.
    pub y_min: f64,
    pub y_max: f64,
}

impl StateDictionary {
    pub fn k(&self) -> usize {
        self.mu.len()
    }

    /// Build from a fitted (sorted) GMM with uniform AR coefficient.
    pub fn from_gmm(gmm: &Gmm1d, phi: f64, y_min: f64, y_max: f64) -> StateDictionary {
        StateDictionary {
            pi: gmm.pi.clone(),
            mu: gmm.mu.clone(),
            sigma: gmm.sigma.clone(),
            phi: vec![phi; gmm.k()],
            y_min,
            y_max,
        }
    }

    pub fn to_gmm(&self) -> Gmm1d {
        Gmm1d::new(self.pi.clone(), self.mu.clone(), self.sigma.clone())
    }

    pub fn validate(&self) -> Result<()> {
        let k = self.k();
        ensure!(k >= 1, "empty state dictionary");
        ensure!(self.pi.len() == k && self.sigma.len() == k && self.phi.len() == k, "ragged fields");
        ensure!(self.mu.windows(2).all(|w| w[0] <= w[1]), "states must be sorted by mean");
        ensure!(self.sigma.iter().all(|&s| s > 0.0), "sigmas must be positive");
        ensure!(self.phi.iter().all(|&p| (0.0..1.0).contains(&p)), "phi must be in [0,1)");
        ensure!(self.y_min < self.y_max, "invalid clip range");
        let total: f64 = self.pi.iter().sum();
        ensure!((total - 1.0).abs() < 1e-4, "weights must sum to 1 (got {total})");
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        json::obj([
            ("pi", Json::from_f64s(&self.pi)),
            ("mu", Json::from_f64s(&self.mu)),
            ("sigma", Json::from_f64s(&self.sigma)),
            ("phi", Json::from_f64s(&self.phi)),
            ("y_min", self.y_min.into()),
            ("y_max", self.y_max.into()),
        ])
    }

    pub fn from_json(v: &Json) -> Result<StateDictionary> {
        let d = StateDictionary {
            pi: v.get("pi")?.f64_array()?,
            mu: v.get("mu")?.f64_array()?,
            sigma: v.get("sigma")?.f64_array()?,
            phi: v.get("phi")?.f64_array()?,
            y_min: v.f64_field("y_min")?,
            y_max: v.f64_field("y_max")?,
        };
        d.validate()?;
        Ok(d)
    }

    /// Clip a power sample to the observed range (paper §3.2: "generated
    /// samples are clipped to the observed power range").
    #[inline]
    pub fn clip(&self, y: f64) -> f64 {
        y.clamp(self.y_min, self.y_max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dict() -> StateDictionary {
        StateDictionary {
            pi: vec![0.6, 0.4],
            mu: vec![100.0, 300.0],
            sigma: vec![5.0, 10.0],
            phi: vec![0.0, 0.8],
            y_min: 80.0,
            y_max: 340.0,
        }
    }

    #[test]
    fn json_roundtrip() {
        let d = dict();
        let j = d.to_json();
        let back = StateDictionary::from_json(&j).unwrap();
        assert_eq!(back, d);
    }

    #[test]
    fn validation_catches_issues() {
        let mut bad = dict();
        bad.mu = vec![300.0, 100.0];
        assert!(bad.validate().is_err());

        let mut bad = dict();
        bad.sigma[0] = 0.0;
        assert!(bad.validate().is_err());

        let mut bad = dict();
        bad.phi[1] = 1.5;
        assert!(bad.validate().is_err());

        let mut bad = dict();
        bad.pi = vec![0.5, 0.4];
        assert!(bad.validate().is_err());

        let mut bad = dict();
        bad.y_min = 400.0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn clip_bounds_samples() {
        let d = dict();
        assert_eq!(d.clip(50.0), 80.0);
        assert_eq!(d.clip(500.0), 340.0);
        assert_eq!(d.clip(200.0), 200.0);
    }

    #[test]
    fn from_gmm_copies_parameters() {
        let g = Gmm1d::new(vec![0.3, 0.7], vec![50.0, 250.0], vec![4.0, 9.0]);
        let d = StateDictionary::from_gmm(&g, 0.85, 40.0, 300.0);
        assert_eq!(d.mu, g.mu);
        assert_eq!(d.phi, vec![0.85, 0.85]);
        d.validate().unwrap();
        let g2 = d.to_gmm();
        assert_eq!(g2.mu, g.mu);
    }
}
