//! Power-state modeling (paper §3.2): per-configuration Gaussian mixture
//! models over measured 250 ms power samples, EM fitting with BIC model
//! selection (K ∈ 8..12 typically), and the ordered state dictionary used
//! both to label training data and to sample power at generation time.

pub mod dictionary;
pub mod em;
pub mod gmm;

pub use dictionary::StateDictionary;
pub use em::{fit_gmm, select_k, BicCurve, EmOptions};
pub use gmm::Gmm1d;
