//! PJRT runtime: loads AOT-compiled HLO **text** artifacts produced by the
//! Python build path and executes them on the XLA CPU client.
//!
//! The `xla` binding only exists in the offline registry cache of the
//! artifact-build image, so the whole execution path is gated behind the
//! `pjrt` cargo feature. Without it this module exposes the same
//! [`Runtime`] / [`Executable`] API whose constructors return a clear
//! error, and the coordinator falls back to the pure-Rust `native`
//! classifier backend (identical numerics, see `classifier::native`).
//!
//! Interchange is HLO text, not serialized `HloModuleProto` — jax ≥ 0.5
//! emits protos with 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see /opt/xla-example/README.md
//! and DESIGN.md §6).
//!
//! # Thread-safety (pjrt feature)
//!
//! The `xla` crate's client handle is an `Rc` and its executables are raw
//! pointers — neither is `Send`. PJRT's CPU plugin itself is thread-safe,
//! but the binding's `Rc` reference counting is not, so this module routes
//! *every* PJRT interaction (client creation, compilation, execution,
//! buffer→literal transfer, and drops) through one global mutex. With that
//! invariant, sharing [`Executable`] across the coordinator's worker
//! threads is sound, which the `unsafe impl Send/Sync` encode.
//! Multi-worker throughput is preserved by keeping per-call critical
//! sections short (one chunk execution) and by the fact that most of a
//! server's generation time is outside the classifier call.

#[cfg(feature = "pjrt")]
mod imp {
    use anyhow::{anyhow, ensure, Context, Result};
    use std::mem::ManuallyDrop;
    use std::path::Path;
    use std::sync::{Mutex, MutexGuard};

    /// The single global PJRT lock. All binding calls happen while holding it.
    static PJRT_LOCK: Mutex<()> = Mutex::new(());

    fn pjrt_lock() -> MutexGuard<'static, ()> {
        PJRT_LOCK.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Wrapper over the PJRT CPU client.
    pub struct Runtime {
        client: ManuallyDrop<xla::PjRtClient>,
    }

    // SAFETY: every use of `client` (and its Rc refcount) happens under
    // PJRT_LOCK, including Drop.
    unsafe impl Send for Runtime {}
    unsafe impl Sync for Runtime {}

    impl Drop for Runtime {
        fn drop(&mut self) {
            let _g = pjrt_lock();
            unsafe { ManuallyDrop::drop(&mut self.client) };
        }
    }

    impl Runtime {
        /// Create a CPU PJRT client.
        pub fn cpu() -> Result<Runtime> {
            let _g = pjrt_lock();
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
            Ok(Runtime { client: ManuallyDrop::new(client) })
        }

        pub fn platform(&self) -> String {
            let _g = pjrt_lock();
            self.client.platform_name()
        }

        /// Load an HLO-text artifact and compile it for this client.
        pub fn load_hlo_text(&self, path: &Path) -> Result<Executable> {
            ensure!(path.exists(), "artifact not found: {} (run `make artifacts`)", path.display());
            let _g = pjrt_lock();
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .map_err(|e| anyhow!("parse HLO text {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))?;
            Ok(Executable { exe: ManuallyDrop::new(exe), name: path.display().to_string() })
        }
    }

    /// A compiled executable. Inputs/outputs are f32 tensors; the lowered jax
    /// functions return a tuple (we lower with `return_tuple=True`).
    pub struct Executable {
        exe: ManuallyDrop<xla::PjRtLoadedExecutable>,
        name: String,
    }

    // SAFETY: see module docs — all PJRT calls (execute, transfers, drops) are
    // serialized by PJRT_LOCK.
    unsafe impl Send for Executable {}
    unsafe impl Sync for Executable {}

    impl Drop for Executable {
        fn drop(&mut self) {
            let _g = pjrt_lock();
            unsafe { ManuallyDrop::drop(&mut self.exe) };
        }
    }

    impl Executable {
        /// Execute with f32 inputs of the given shapes; returns every tuple
        /// element flattened to `Vec<f32>`.
        pub fn run_f32(&self, inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
            // Literals are standalone host buffers (no client handle): build
            // them outside the lock.
            let mut literals = Vec::with_capacity(inputs.len());
            for (data, shape) in inputs {
                let n: i64 = shape.iter().product();
                ensure!(
                    n as usize == data.len(),
                    "{}: input length {} != shape {:?}",
                    self.name,
                    data.len(),
                    shape
                );
                let lit = xla::Literal::vec1(data)
                    .reshape(shape)
                    .map_err(|e| anyhow!("{}: reshape: {e:?}", self.name))?;
                literals.push(lit);
            }
            // Execute + fetch + drop device buffers under the PJRT lock.
            let out = {
                let _g = pjrt_lock();
                let result = self
                    .exe
                    .execute::<xla::Literal>(&literals)
                    .map_err(|e| anyhow!("{}: execute: {e:?}", self.name))?;
                let lit = result[0][0]
                    .to_literal_sync()
                    .map_err(|e| anyhow!("{}: fetch: {e:?}", self.name))?;
                drop(result); // device buffers (hold client refs) die here
                lit
            };
            let tuple = out.to_tuple().map_err(|e| anyhow!("{}: tuple: {e:?}", self.name))?;
            tuple
                .into_iter()
                .map(|t| t.to_vec::<f32>().map_err(|e| anyhow!("{}: to_vec: {e:?}", self.name)))
                .collect()
        }

        /// Execute and return only the first tuple element.
        pub fn run_f32_first(&self, inputs: &[(&[f32], &[i64])]) -> Result<Vec<f32>> {
            let mut outs = self.run_f32(inputs)?;
            ensure!(!outs.is_empty(), "{}: empty output tuple", self.name);
            Ok(outs.swap_remove(0))
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use anyhow::{bail, Result};
    use std::path::Path;

    const UNAVAILABLE: &str =
        "PJRT backend unavailable: built without the `pjrt` cargo feature \
         (rebuild with `cargo build --features pjrt` in the artifact image, \
         or use the `native` classifier backend)";

    /// Stub PJRT client: constructors fail so callers fall back to the
    /// native backend. API mirrors the `pjrt`-feature implementation.
    pub struct Runtime {
        _private: (),
    }

    impl Runtime {
        /// Always fails in a `pjrt`-less build.
        pub fn cpu() -> Result<Runtime> {
            bail!(UNAVAILABLE)
        }

        pub fn platform(&self) -> String {
            String::from("unavailable")
        }

        /// Always fails in a `pjrt`-less build.
        pub fn load_hlo_text(&self, _path: &Path) -> Result<Executable> {
            bail!(UNAVAILABLE)
        }
    }

    /// Stub executable; never constructible (see [`Runtime::load_hlo_text`]).
    pub struct Executable {
        _private: (),
    }

    impl Executable {
        pub fn run_f32(&self, _inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
            bail!(UNAVAILABLE)
        }

        pub fn run_f32_first(&self, _inputs: &[(&[f32], &[i64])]) -> Result<Vec<f32>> {
            bail!(UNAVAILABLE)
        }
    }
}

pub use imp::{Executable, Runtime};

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;
    use std::path::Path;

    // A tiny checked-in HLO fixture: fn(x, y) = (matmul(x, y) + 2,) over
    // f32[2,2], generated by /opt/xla-example/gen_hlo.py. Lets runtime tests
    // run without `make artifacts`.
    fn fixture() -> std::path::PathBuf {
        crate::catalog::Catalog::repo_root().join("rust/tests/data/matmul_add.hlo.txt")
    }

    #[test]
    fn load_and_execute_fixture() {
        let rt = Runtime::cpu().expect("cpu client");
        assert!(!rt.platform().is_empty());
        let exe = rt.load_hlo_text(&fixture()).expect("compile fixture");
        let x = [1.0f32, 2.0, 3.0, 4.0];
        let y = [1.0f32, 1.0, 1.0, 1.0];
        let out = exe.run_f32_first(&[(&x, &[2, 2]), (&y, &[2, 2])]).expect("run");
        assert_eq!(out, vec![5.0, 5.0, 9.0, 9.0]);
    }

    #[test]
    fn reexecution_is_stable() {
        let rt = Runtime::cpu().unwrap();
        let exe = rt.load_hlo_text(&fixture()).unwrap();
        let x = [2.0f32, 0.0, 0.0, 2.0];
        let y = [1.0f32, 2.0, 3.0, 4.0];
        let a = exe.run_f32_first(&[(&x, &[2, 2]), (&y, &[2, 2])]).unwrap();
        let b = exe.run_f32_first(&[(&x, &[2, 2]), (&y, &[2, 2])]).unwrap();
        assert_eq!(a, b);
        assert_eq!(a, vec![4.0, 6.0, 8.0, 10.0]);
    }

    #[test]
    fn concurrent_execution_is_serialized_and_correct() {
        let rt = Runtime::cpu().unwrap();
        let exe = std::sync::Arc::new(rt.load_hlo_text(&fixture()).unwrap());
        std::thread::scope(|s| {
            for i in 0..4 {
                let exe = exe.clone();
                s.spawn(move || {
                    let v = i as f32;
                    let x = [v, 0.0, 0.0, v];
                    let y = [1.0f32, 0.0, 0.0, 1.0];
                    for _ in 0..5 {
                        let out =
                            exe.run_f32_first(&[(&x, &[2, 2]), (&y, &[2, 2])]).unwrap();
                        assert_eq!(out, vec![v + 2.0, 2.0, 2.0, v + 2.0]);
                    }
                });
            }
        });
    }

    #[test]
    fn missing_artifact_is_a_clear_error() {
        let rt = Runtime::cpu().unwrap();
        let err = match rt.load_hlo_text(Path::new("/nonexistent/x.hlo.txt")) {
            Err(e) => e,
            Ok(_) => panic!("expected error"),
        };
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn shape_mismatch_rejected() {
        let rt = Runtime::cpu().unwrap();
        let exe = rt.load_hlo_text(&fixture()).unwrap();
        let x = [1.0f32; 3];
        assert!(exe.run_f32_first(&[(&x, &[2, 2]), (&x, &[2, 2])]).is_err());
    }
}
