//! Synthetic measurement testbed — the stand-in for the paper's Azure DGX
//! A100/H100 + vLLM 0.10 + `nvidia-smi` campaign (DESIGN.md §3).
//!
//! The testbed is a time-stepped continuous-batching engine plus a
//! physically-motivated GPU power law. It produces the "measured" traces the
//! pipeline learns from and is deliberately *richer* than the surrogate the
//! paper fits: TTFT follows a power law with batch interference, TBT slows
//! with occupancy, and MoE configurations carry hidden AR(1) expert-routing
//! power noise that is invisible to workload features — reproducing the
//! dense/MoE fidelity split in the paper's Table 1.
//!
//! The Python build path (`python/compile/testbed.py`) implements the exact
//! same math from the same `data/catalog.json`; cross-consistency is
//! enforced by integration tests comparing summary statistics on a fixed
//! schedule.

pub mod engine;

pub use engine::{simulate, EngineOptions, TestbedTrace};

use crate::catalog::{Gpu, ServerConfig, TruthParams};

/// Ground-truth instantaneous GPU utilization (fraction of the idle→TDP
/// span) given batch occupancy `a` and whether prefill work is present.
/// Shared by Rust and Python testbeds — keep in sync with
/// `python/compile/testbed.py::utilization`.
#[inline]
pub fn utilization(truth: &TruthParams, a: usize, prefill_present: bool) -> f64 {
    if a == 0 {
        return 0.0;
    }
    if prefill_present {
        let mix = ((a as f64 - 1.0) / 16.0).min(1.0);
        (truth.pre_frac + truth.mixed_bonus_frac * mix).min(1.0)
    } else {
        let sat = 1.0 - (-((a as f64 - 1.0) / truth.a0)).exp();
        truth.dec_min_frac + (truth.dec_max_frac - truth.dec_min_frac) * sat
    }
}

/// Deterministic per-GPU power (W) before noise at utilization `u`.
#[inline]
pub fn gpu_power_w(gpu: &Gpu, u: f64) -> f64 {
    gpu.idle_w + (gpu.tdp_w - gpu.idle_w) * u
}

/// Deterministic server power (W, GPUs only) for a config at utilization
/// `u` on the active tensor-parallel group; the remaining GPUs idle.
#[inline]
pub fn server_gpu_power_w(cfg: &ServerConfig, gpu: &Gpu, u: f64) -> f64 {
    cfg.tp as f64 * gpu_power_w(gpu, u) + (cfg.n_gpus_server - cfg.tp) as f64 * gpu.idle_w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catalog::Catalog;

    #[cfg(feature = "host")]
    #[test]
    fn utilization_monotone_in_occupancy_without_prefill() {
        let c = Catalog::load_default().unwrap();
        let t = &c.config("llama70b_a100_tp8").unwrap().truth;
        let mut prev = 0.0;
        for a in 0..64 {
            let u = utilization(t, a, false);
            assert!(u >= prev - 1e-12, "a={a}");
            prev = u;
        }
        assert_eq!(utilization(t, 0, false), 0.0);
        // saturates below prefill level
        assert!(utilization(t, 64, false) < t.pre_frac);
    }

    #[cfg(feature = "host")]
    #[test]
    fn prefill_dominates_decode() {
        let c = Catalog::load_default().unwrap();
        let t = &c.config("llama8b_a100_tp2").unwrap().truth;
        for a in 1..32 {
            assert!(utilization(t, a, true) > utilization(t, a, false), "a={a}");
        }
        assert!(utilization(t, 64, true) <= 1.0);
    }

    #[cfg(feature = "host")]
    #[test]
    fn server_power_bounds() {
        let c = Catalog::load_default().unwrap();
        let cfg = c.config("llama70b_h100_tp4").unwrap();
        let gpu = c.gpu_of(cfg);
        let idle = server_gpu_power_w(cfg, gpu, 0.0);
        let full = server_gpu_power_w(cfg, gpu, 1.0);
        // idle: all 8 GPUs at idle
        assert!((idle - 8.0 * gpu.idle_w).abs() < 1e-9);
        // full: 4 at TDP + 4 idle
        assert!((full - (4.0 * gpu.tdp_w + 4.0 * gpu.idle_w)).abs() < 1e-9);
        assert!(idle < full);
    }
}
