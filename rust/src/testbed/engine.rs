//! Time-stepped continuous-batching engine + 250 ms power sampler.
//!
//! Semantics (mirrored exactly by `python/compile/testbed.py`):
//! * substeps of `dt_sim` (default 50 ms); requests admitted FIFO at substep
//!   boundaries while occupancy < `max_batch`;
//! * prefill progresses at rate `1 / (ttft_base · (1 + κ_pre·(b−1)/B))`
//!   where `ttft_base = c_pre·(n_in/512)^γ` and `b` is current occupancy;
//! * decode generates tokens at rate `1 / (tbt0 · (1 + κ_dec·(b−1)/B))`;
//! * per 250 ms window, deterministic utilization is averaged over substeps
//!   and noise (white GPU noise, hidden MoE AR(1), measurement noise) is
//!   added at window granularity so results are substep-invariant.

use super::{server_gpu_power_w, utilization};
use crate::catalog::{Catalog, ServerConfig};
use crate::surrogate::DurationSamples;
use crate::util::rng::Rng;
use crate::workload::Schedule;
use std::collections::VecDeque;

#[derive(Debug, Clone, Copy)]
pub struct EngineOptions {
    /// Simulation substep (s).
    pub dt_sim: f64,
    /// Power sampling interval (s) — the paper measures at 250 ms.
    pub dt_sample: f64,
    /// Batch capacity (the paper uses vLLM's default, modeled as 64).
    pub max_batch: usize,
    /// Trace horizon (s).
    pub horizon_s: f64,
}

impl EngineOptions {
    pub fn from_catalog(cat: &Catalog, horizon_s: f64) -> EngineOptions {
        EngineOptions {
            dt_sim: 0.05,
            dt_sample: cat.campaign.dt_s,
            max_batch: cat.campaign.max_batch,
            horizon_s,
        }
    }
}

/// The "measured" output of one testbed run.
#[derive(Debug, Clone)]
pub struct TestbedTrace {
    pub dt_s: f64,
    /// Server power (W) per sampling window — what `nvidia-smi` would log.
    pub power_w: Vec<f32>,
    /// Mean batch occupancy per window (ground-truth A_t for Fig 3/13).
    pub a_measured: Vec<f32>,
    /// Fraction of substeps with prefill present per window.
    pub prefill_frac: Vec<f32>,
    /// Realized per-request durations (for calibration and Fig 5).
    pub durations: DurationSamples,
    /// Per-request execution start times (s).
    pub starts: Vec<f64>,
}

struct Running {
    idx: usize,
    n_in: u32,
    n_out: u32,
    /// Prefill work remaining in [0,1].
    prefill_left: f64,
    /// Output tokens remaining (fractional).
    tokens_left: f64,
    started_at: f64,
    prefill_done_at: Option<f64>,
}

/// Run the testbed for one server configuration over a request schedule.
pub fn simulate(
    cat: &Catalog,
    cfg: &ServerConfig,
    schedule: &Schedule,
    opts: &EngineOptions,
    rng: &mut Rng,
) -> TestbedTrace {
    let truth = &cfg.truth;
    let gpu = cat.gpu_of(cfg);
    let b_cap = opts.max_batch as f64;
    let n_windows = (opts.horizon_s / opts.dt_sample).round() as usize;
    let steps_per_window = (opts.dt_sample / opts.dt_sim).round().max(1.0) as usize;

    let mut pending: VecDeque<usize> = VecDeque::new();
    let mut next_arrival = 0usize;
    let mut running: Vec<Running> = Vec::with_capacity(opts.max_batch);

    let mut starts = vec![f64::NAN; schedule.len()];
    let mut durations = DurationSamples::default();
    let mut power_w = Vec::with_capacity(n_windows);
    let mut a_measured = Vec::with_capacity(n_windows);
    let mut prefill_frac = Vec::with_capacity(n_windows);

    // Hidden MoE expert-routing noise (AR(1) at window granularity).
    let mut ar_state = 0.0f64;
    let ar_innov = truth.ar_sigma_w * (1.0 - truth.ar_phi * truth.ar_phi).max(0.0).sqrt();

    let mut t = 0.0f64;
    for _w in 0..n_windows {
        let mut u_sum = 0.0f64;
        let mut a_sum = 0.0f64;
        let mut pre_steps = 0usize;
        for _s in 0..steps_per_window {
            // 1. Arrivals into the FIFO queue.
            while next_arrival < schedule.len() && schedule[next_arrival].arrival_s <= t {
                pending.push_back(next_arrival);
                next_arrival += 1;
            }
            // 2. Admission while capacity remains.
            while running.len() < opts.max_batch {
                match pending.pop_front() {
                    Some(idx) => {
                        let req = &schedule[idx];
                        starts[idx] = t;
                        running.push(Running {
                            idx,
                            n_in: req.n_in,
                            n_out: req.n_out,
                            prefill_left: 1.0,
                            tokens_left: req.n_out as f64,
                            started_at: t,
                            prefill_done_at: None,
                        });
                    }
                    None => break,
                }
            }
            // 3. Progress work at occupancy-dependent rates.
            let b = running.len();
            if b > 0 {
                let interference = (b as f64 - 1.0) / b_cap;
                let pre_slow = 1.0 + truth.kappa_pre * interference;
                let dec_rate =
                    1.0 / (truth.tbt0_s * (1.0 + truth.kappa_dec * interference));
                let mut prefill_present = false;
                for r in running.iter_mut() {
                    if r.prefill_left > 0.0 {
                        prefill_present = true;
                        let ttft_base =
                            truth.c_pre_s * ((r.n_in as f64) / 512.0).powf(truth.gamma_pre);
                        r.prefill_left -= opts.dt_sim / (ttft_base.max(1e-6) * pre_slow);
                        if r.prefill_left <= 0.0 {
                            r.prefill_done_at = Some(t + opts.dt_sim);
                        }
                    } else {
                        r.tokens_left -= dec_rate * opts.dt_sim;
                    }
                }
                u_sum += utilization(truth, b, prefill_present);
                a_sum += b as f64;
                if prefill_present {
                    pre_steps += 1;
                }
                // 4. Completions.
                let end_t = t + opts.dt_sim;
                running.retain(|r| {
                    if r.prefill_left <= 0.0 && r.tokens_left <= 0.0 {
                        let pre_end = r.prefill_done_at.unwrap_or(end_t);
                        durations.push(
                            r.n_in,
                            (pre_end - r.started_at).max(opts.dt_sim),
                            r.n_out,
                            (end_t - pre_end).max(opts.dt_sim),
                        );
                        false
                    } else {
                        true
                    }
                });
            }
            t += opts.dt_sim;
        }
        // 5. Sample the window.
        let u_avg = u_sum / steps_per_window as f64;
        let mut p = server_gpu_power_w(cfg, gpu, u_avg);
        // White GPU noise (per active GPU, summed over the TP group).
        p += (cfg.tp as f64).sqrt() * truth.noise_w * rng.normal();
        // Hidden MoE routing noise, only while work is present.
        if truth.ar_sigma_w > 0.0 {
            ar_state = truth.ar_phi * ar_state + ar_innov * rng.normal();
            if a_sum > 0.0 {
                p += ar_state * cfg.tp as f64;
            }
        }
        // Measurement noise.
        p += truth.meas_noise_w * rng.normal();
        // Physical floor/ceiling: an 8-GPU server cannot go below all-idle
        // or above all-TDP.
        let floor = cfg.n_gpus_server as f64 * gpu.idle_w * 0.95;
        let ceil = cfg.n_gpus_server as f64 * gpu.tdp_w;
        power_w.push(p.clamp(floor, ceil) as f32);
        a_measured.push((a_sum / steps_per_window as f64) as f32);
        prefill_frac.push(pre_steps as f32 / steps_per_window as f32);
    }

    TestbedTrace { dt_s: opts.dt_sample, power_w, a_measured, prefill_frac, durations, starts }
}

#[cfg(all(test, feature = "host"))]
mod tests {
    use super::*;
    use crate::testutil::check;
    use crate::workload::{poisson_arrivals, LengthSampler, Request};

    fn setup() -> (Catalog, EngineOptions) {
        let cat = Catalog::load_default().unwrap();
        let opts = EngineOptions::from_catalog(&cat, 120.0);
        (cat, opts)
    }

    #[test]
    fn idle_server_draws_idle_power() {
        let (cat, opts) = setup();
        let cfg = cat.config("llama8b_a100_tp2").unwrap();
        let gpu = cat.gpu_of(cfg);
        let mut rng = Rng::new(70);
        let tr = simulate(&cat, cfg, &vec![], &opts, &mut rng);
        assert_eq!(tr.power_w.len(), 480);
        let mean: f64 = tr.power_w.iter().map(|&x| x as f64).sum::<f64>() / 480.0;
        let idle = 8.0 * gpu.idle_w;
        assert!((mean - idle).abs() < 10.0, "mean {mean} vs idle {idle}");
        assert!(tr.a_measured.iter().all(|&a| a == 0.0));
    }

    #[test]
    fn single_request_produces_prefill_spike_then_decode() {
        let (cat, opts) = setup();
        let cfg = cat.config("llama70b_a100_tp8").unwrap();
        let sched = vec![Request { arrival_s: 10.0, n_in: 4096, n_out: 2000 }];
        let mut rng = Rng::new(71);
        let tr = simulate(&cat, cfg, &sched, &opts, &mut rng);
        // Some window shows prefill.
        assert!(tr.prefill_frac.iter().any(|&f| f > 0.0));
        // Power during decode is between idle and prefill levels.
        let peak = tr.power_w.iter().cloned().fold(f32::MIN, f32::max) as f64;
        let gpu = cat.gpu_of(cfg);
        assert!(peak > 8.0 * gpu.idle_w + 0.5 * 8.0 * (gpu.tdp_w - gpu.idle_w));
        // Request completes and is logged.
        assert_eq!(tr.durations.len(), 1);
        assert!(tr.durations.prefill_s[0] > 0.0);
        assert!(tr.durations.decode_s[0] > tr.durations.prefill_s[0]);
    }

    #[test]
    fn ttft_superlinear_in_prompt_length() {
        let (cat, opts) = setup();
        let cfg = cat.config("llama8b_h100_tp1").unwrap();
        let rng = Rng::new(72);
        let run = |n_in: u32| {
            let sched = vec![Request { arrival_s: 0.0, n_in, n_out: 10 }];
            let tr = simulate(&cat, cfg, &sched, &opts, &mut rng.fork(n_in as u64));
            tr.durations.prefill_s[0]
        };
        let short = run(512);
        let long = run(4096);
        // power law with gamma 1.15: ratio should exceed 8 (linear) clearly
        assert!(long / short > 8.0, "ratio {}", long / short);
    }

    #[test]
    fn decode_slows_with_occupancy() {
        let (cat, mut opts) = setup();
        opts.horizon_s = 300.0;
        let cfg = cat.config("llama8b_a100_tp2").unwrap();
        let mut rng = Rng::new(73);
        // One lone request...
        let lone = simulate(
            &cat,
            cfg,
            &vec![Request { arrival_s: 0.0, n_in: 64, n_out: 200 }],
            &opts,
            &mut rng,
        );
        // ...vs the same request among 32 concurrent ones.
        let mut busy_sched: Schedule = (0..32)
            .map(|_| Request { arrival_s: 0.0, n_in: 64, n_out: 200 })
            .collect();
        busy_sched[0] = Request { arrival_s: 0.0, n_in: 64, n_out: 200 };
        let busy = simulate(&cat, cfg, &busy_sched, &opts, &mut rng);
        let lone_tbt = lone.durations.decode_s[0] / 200.0;
        let busy_tbt = busy.durations.decode_s[0] / 200.0;
        // κ_dec = 0.5 → ~1.24× slowdown at b=32 (catalog truth).
        assert!(busy_tbt > lone_tbt * 1.15, "lone {lone_tbt} busy {busy_tbt}");
    }

    #[test]
    fn moe_traces_have_stronger_autocorrelation() {
        let (cat, mut opts) = setup();
        opts.horizon_s = 480.0;
        let lengths = LengthSampler::fixed(256, 128);
        let run = |id: &str, seed: u64| {
            let cfg = cat.config(id).unwrap();
            let mut rng = Rng::new(seed);
            let sched = poisson_arrivals(1.0, opts.horizon_s, &lengths, &mut rng);
            let tr = simulate(&cat, cfg, &sched, &opts, &mut rng);
            // Residual ACF at lag 1 after removing a long-window moving mean
            // isolates within-state noise correlation.
            crate::metrics::acf(&tr.power_w, 1)[1]
        };
        let dense = run("llama8b_a100_tp2", 74);
        let moe = run("gptoss120b_a100_tp4", 74);
        assert!(moe > dense - 0.05, "dense {dense} moe {moe}");
    }

    #[test]
    fn prop_power_within_physical_bounds_and_batch_capped() {
        check("testbed physical bounds", |rng| {
            let (cat, mut opts) = setup();
            opts.horizon_s = 60.0;
            let cfgs = cat.config_ids();
            let cfg = cat.config(&cfgs[rng.below(cfgs.len())]).unwrap();
            let gpu = cat.gpu_of(cfg);
            let rate = rng.range(0.2, 6.0);
            let lengths = LengthSampler::fixed(128, 64);
            let mut local = rng.clone();
            let sched = poisson_arrivals(rate, opts.horizon_s, &lengths, &mut local);
            let tr = simulate(&cat, cfg, &sched, &opts, &mut local);
            let hi = cfg.n_gpus_server as f64 * gpu.tdp_w;
            let lo = cfg.n_gpus_server as f64 * gpu.idle_w * 0.95;
            for &p in &tr.power_w {
                assert!((p as f64) >= lo - 1e-6 && (p as f64) <= hi + 1e-6, "p={p}");
            }
            for &a in &tr.a_measured {
                assert!(a >= 0.0 && a <= opts.max_batch as f32);
            }
        });
    }

    #[test]
    fn all_requests_eventually_complete_with_long_horizon() {
        let (cat, mut opts) = setup();
        opts.horizon_s = 600.0;
        let cfg = cat.config("llama8b_a100_tp2").unwrap();
        let lengths = LengthSampler::fixed(128, 32);
        let mut rng = Rng::new(76);
        let sched = poisson_arrivals(0.5, 300.0, &lengths, &mut rng);
        let tr = simulate(&cat, cfg, &sched, &opts, &mut rng);
        assert_eq!(tr.durations.len(), sched.len(), "all requests complete");
        // Starts are recorded for every admitted request.
        assert!(tr.starts.iter().all(|s| s.is_finite()));
    }
}
