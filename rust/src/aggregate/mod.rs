//! Datacenter-scale aggregation (paper §3.4): data hall → rows → racks →
//! servers, constant non-GPU IT power per server, and a constant-PUE map
//! from IT power to facility power at the point of common coupling
//! (Eq. 10–11).
//!
//! Two consumers sit on top of the streaming [`FacilityAccumulator`]:
//!
//! * single-series accessors ([`FacilityAccumulator::rack_series`],
//!   [`FacilityAccumulator::row_series`],
//!   [`FacilityAccumulator::facility_series`]) for one level at a time;
//! * the multi-resolution reduction ([`FacilityAccumulator::multi_scale`])
//!   that derives every planner-facing scale — per-rack, per-row, and
//!   facility series, each resampled to its own interval — in **one
//!   streaming pass** over the per-rack buffers. This is what the sweep
//!   engine ([`crate::scenarios`]) exports per grid cell: racks at 1 s
//!   match in-rack PDU telemetry, rows at 15 s match busway metering, and
//!   the facility at 5/15 min matches utility interconnection data.
//!
//! Above the facility sits the **site** layer: [`SiteAccumulator`] composes
//! several facilities' PCC windows into one utility-facing site window with
//! bounded memory — the fold the [`crate::site`] engine drives.

use crate::metrics::planning::resample_mean;
use anyhow::{ensure, Result};

/// Facility topology: `rows × racks_per_row × servers_per_rack` servers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Topology {
    pub rows: usize,
    pub racks_per_row: usize,
    pub servers_per_rack: usize,
}

impl Topology {
    pub fn n_servers(&self) -> usize {
        self.rows * self.racks_per_row * self.servers_per_rack
    }

    pub fn n_racks(&self) -> usize {
        self.rows * self.racks_per_row
    }

    /// Map a flat server index to (row, rack-in-row, server-in-rack).
    pub fn addr(&self, server_idx: usize) -> (usize, usize, usize) {
        assert!(server_idx < self.n_servers());
        let per_row = self.racks_per_row * self.servers_per_rack;
        let row = server_idx / per_row;
        let rem = server_idx % per_row;
        (row, rem / self.servers_per_rack, rem % self.servers_per_rack)
    }

    /// Flat rack index for a server.
    pub fn rack_of(&self, server_idx: usize) -> usize {
        let (row, rack, _) = self.addr(server_idx);
        row * self.racks_per_row + rack
    }

    /// Row index for a server.
    pub fn row_of(&self, server_idx: usize) -> usize {
        self.addr(server_idx).0
    }

    /// Row index of a flat rack index.
    pub fn row_of_rack(&self, rack_idx: usize) -> usize {
        assert!(rack_idx < self.n_racks());
        rack_idx / self.racks_per_row
    }
}

/// Streaming bottom-up aggregator: accumulates per-rack IT power so the
/// full per-server matrix never needs to be materialized (240 servers ×
/// 24 h × 250 ms ≈ 83 M samples stays bounded at racks × T).
#[derive(Debug, Clone)]
pub struct FacilityAccumulator {
    topo: Topology,
    n_steps: usize,
    /// Per-server non-GPU IT power (paper: constant 1 kW).
    p_base_w: f64,
    /// Per-rack summed IT power (includes p_base for added servers).
    rack_w: Vec<Vec<f64>>,
    added: usize,
}

impl FacilityAccumulator {
    pub fn new(topo: Topology, n_steps: usize, p_base_w: f64) -> FacilityAccumulator {
        FacilityAccumulator {
            topo,
            n_steps,
            p_base_w,
            rack_w: vec![vec![0.0; n_steps]; topo.n_racks()],
            added: 0,
        }
    }

    pub fn topology(&self) -> Topology {
        self.topo
    }

    pub fn n_steps(&self) -> usize {
        self.n_steps
    }

    pub fn servers_added(&self) -> usize {
        self.added
    }

    /// Add one server's GPU power trace (IT power = GPU + p_base).
    pub fn add_server(&mut self, server_idx: usize, gpu_power_w: &[f32]) -> Result<()> {
        ensure!(
            gpu_power_w.len() == self.n_steps,
            "trace length {} != facility steps {}",
            gpu_power_w.len(),
            self.n_steps
        );
        let rack = self.topo.rack_of(server_idx);
        let dst = &mut self.rack_w[rack];
        for (d, &p) in dst.iter_mut().zip(gpu_power_w) {
            *d += p as f64 + self.p_base_w;
        }
        self.added += 1;
        Ok(())
    }

    /// Merge another accumulator (same topology) — used by parallel folds.
    pub fn merge(&mut self, other: &FacilityAccumulator) {
        assert_eq!(self.topo, other.topo);
        assert_eq!(self.n_steps, other.n_steps);
        for (a, b) in self.rack_w.iter_mut().zip(&other.rack_w) {
            for (x, y) in a.iter_mut().zip(b) {
                *x += y;
            }
        }
        self.added += other.added;
    }

    /// IT power of one rack.
    pub fn rack_series(&self, rack_idx: usize) -> Vec<f32> {
        self.rack_w[rack_idx].iter().map(|&x| x as f32).collect()
    }

    /// IT power of one row (sum of its racks).
    pub fn row_series(&self, row_idx: usize) -> Vec<f32> {
        assert!(row_idx < self.topo.rows);
        let mut out = vec![0.0f64; self.n_steps];
        for r in 0..self.topo.racks_per_row {
            let rack = row_idx * self.topo.racks_per_row + r;
            for (o, &x) in out.iter_mut().zip(&self.rack_w[rack]) {
                *o += x;
            }
        }
        out.into_iter().map(|x| x as f32).collect()
    }

    /// Total facility IT power (paper Eq. 10).
    pub fn site_it_series(&self) -> Vec<f32> {
        let mut out = vec![0.0f64; self.n_steps];
        for rack in &self.rack_w {
            for (o, &x) in out.iter_mut().zip(rack) {
                *o += x;
            }
        }
        out.into_iter().map(|x| x as f32).collect()
    }

    /// Facility power at the PCC: `PUE × P_IT(t)` (paper Eq. 11).
    pub fn facility_series(&self, pue: f64) -> Vec<f32> {
        self.site_it_series().into_iter().map(|x| (x as f64 * pue) as f32).collect()
    }

    /// Derive every planner-facing scale in one streaming pass over the
    /// per-rack buffers: each rack is visited exactly once, feeding its row
    /// accumulator and the site accumulator while its own resampled series
    /// is emitted. Rack/row series are IT power; facility series are at the
    /// PCC (`pue` applied, Eq. 11). Errors on non-positive `dt_s` or
    /// export intervals (reachable from sweep JSON).
    pub fn multi_scale(&self, dt_s: f64, pue: f64, scales: &ScaleConfig) -> Result<MultiScale> {
        let mut rows = vec![vec![0.0f64; self.n_steps]; self.topo.rows];
        let mut site = vec![0.0f64; self.n_steps];
        let mut racks_w = Vec::with_capacity(self.topo.n_racks());
        for (rack_idx, rack) in self.rack_w.iter().enumerate() {
            let row = &mut rows[self.topo.row_of_rack(rack_idx)];
            for (t, &x) in rack.iter().enumerate() {
                row[t] += x;
                site[t] += x;
            }
            racks_w.push(resample_mean_f64(rack, dt_s, scales.rack_interval_s, 1.0)?);
        }
        let rows_w = rows
            .iter()
            .map(|r| resample_mean_f64(r, dt_s, scales.row_interval_s, 1.0))
            .collect::<Result<Vec<_>>>()?;
        let facility_w = scales
            .facility_intervals_s
            .iter()
            .map(|&interval| resample_mean_f64(&site, dt_s, interval, pue))
            .collect::<Result<Vec<_>>>()?;
        Ok(MultiScale { dt_s, pue, scales: scales.clone(), racks_w, rows_w, facility_w })
    }
}

/// Bounded window accumulator for the streaming (>24 h) facility path: the
/// same bottom-up rack fold as [`FacilityAccumulator`], but holding only
/// the **current time window** — O(racks × window) instead of racks × T.
///
/// Concurrency: rack buffers sit behind per-rack mutexes so the windowed
/// pipeline's workers (one rack per task, racks disjoint) can fold in
/// parallel; the locks are uncontended by construction. Between windows
/// the single-threaded sink reads via `&mut self` accessors (no locking).
///
/// Equivalence with the buffered path: per element, servers add in index
/// order with the identical `gpu_w as f64 + p_base_w` expression, and
/// [`StreamingFacilityAccumulator::fold_rows_site`] sums racks in rack
/// order exactly as [`FacilityAccumulator::multi_scale`] does — so every
/// derived f64 (and its f32 cast) is bit-identical to the buffered run's.
#[derive(Debug)]
pub struct StreamingFacilityAccumulator {
    topo: Topology,
    p_base_w: f64,
    /// Capacity in timesteps of one window.
    window: usize,
    /// Start step and length of the current window.
    t0: usize,
    len: usize,
    rack_w: Vec<std::sync::Mutex<Vec<f64>>>,
    added: std::sync::atomic::AtomicUsize,
}

impl StreamingFacilityAccumulator {
    pub fn new(topo: Topology, window: usize, p_base_w: f64) -> StreamingFacilityAccumulator {
        assert!(window > 0, "streaming accumulator: zero-length window");
        StreamingFacilityAccumulator {
            topo,
            p_base_w,
            window,
            t0: 0,
            len: 0,
            rack_w: (0..topo.n_racks())
                .map(|_| std::sync::Mutex::new(vec![0.0; window]))
                .collect(),
            added: std::sync::atomic::AtomicUsize::new(0),
        }
    }

    pub fn topology(&self) -> Topology {
        self.topo
    }

    /// Start step of the current window.
    pub fn window_t0(&self) -> usize {
        self.t0
    }

    /// Filled length of the current window (≤ capacity for the final,
    /// partial window of a horizon).
    pub fn window_len(&self) -> usize {
        self.len
    }

    /// Distinct server-window contributions folded so far (diagnostics).
    pub fn servers_added(&self) -> usize {
        self.added.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Reset for the window starting at `t0` covering `len` steps.
    pub fn begin_window(&mut self, t0: usize, len: usize) {
        assert!(len <= self.window, "window {len} exceeds capacity {}", self.window);
        self.t0 = t0;
        self.len = len;
        for m in &mut self.rack_w {
            let buf = m.get_mut().unwrap();
            buf[..len].fill(0.0);
        }
    }

    /// Fold one server's GPU power for window steps `offset .. offset +
    /// gpu_power_w.len()` (offsets are window-relative). Callable from the
    /// rack's worker while other racks fold concurrently.
    pub fn add_server_tile(
        &self,
        server_idx: usize,
        offset: usize,
        gpu_power_w: &[f32],
    ) -> Result<()> {
        ensure!(
            offset + gpu_power_w.len() <= self.len,
            "tile {offset}+{} beyond window length {}",
            gpu_power_w.len(),
            self.len
        );
        let rack = self.topo.rack_of(server_idx);
        let mut buf = self.rack_w[rack].lock().unwrap();
        for (d, &p) in buf[offset..offset + gpu_power_w.len()].iter_mut().zip(gpu_power_w) {
            *d += p as f64 + self.p_base_w;
        }
        if offset + gpu_power_w.len() == self.len {
            self.added.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        }
        Ok(())
    }

    /// The current window of one rack's IT power (single-threaded phase).
    pub fn rack_window(&mut self, rack_idx: usize) -> &[f64] {
        let len = self.len;
        &self.rack_w[rack_idx].get_mut().unwrap()[..len]
    }

    /// Sum the rack windows into per-row and site windows, visiting racks
    /// in rack order — the exact element-wise f64 addition sequence of
    /// [`FacilityAccumulator::multi_scale`]. Buffers are resized to the
    /// window length.
    pub fn fold_rows_site(&mut self, rows: &mut Vec<Vec<f64>>, site: &mut Vec<f64>) {
        let len = self.len;
        rows.resize(self.topo.rows, Vec::new());
        for r in rows.iter_mut() {
            r.clear();
            r.resize(len, 0.0);
        }
        site.clear();
        site.resize(len, 0.0);
        for rack_idx in 0..self.topo.n_racks() {
            let row = self.topo.row_of_rack(rack_idx);
            let buf = self.rack_w[rack_idx].get_mut().unwrap();
            for (t, &x) in buf[..len].iter().enumerate() {
                rows[row][t] += x;
                site[t] += x;
            }
        }
    }
}

/// Build the facility PCC f32 series from an f64 site-IT window: per
/// sample, `((x as f32) as f64 * pue) as f32` — f64 sum → f32
/// ([`FacilityAccumulator::site_it_series`]), then ×PUE in f64 → f32
/// ([`FacilityAccumulator::facility_series`]). The double rounding is
/// deliberate: it is the exact expression of the buffered path, and every
/// streaming consumer (the sweep runner's cells, the facility CLI, the
/// site composition engine) must build PCC through this one helper so the
/// bit-identity invariant cannot drift between call sites.
pub fn pcc_window_into(site_it_w: &[f64], pue: f64, out: &mut Vec<f32>) {
    out.clear();
    out.extend(site_it_w.iter().map(|&x| ((x as f32) as f64 * pue) as f32));
}

/// Bounded window accumulator for **multi-facility site composition** (the
/// paper's utility-facing layer above [`FacilityAccumulator`]): holds one
/// generation window of every facility's PCC power plus their sum —
/// O(facilities × window) samples, never the horizon.
///
/// The composition contract mirrors the facility fold's determinism: the
/// site window is the f64 sum of the facilities' f32 PCC windows taken in
/// **facility order** ([`SiteAccumulator::fold_site`]), so the composed
/// series is a pure function of the facility windows — independent of how
/// many workers produced them or how the horizon was windowed. A
/// single-facility site therefore reproduces the plain facility PCC series
/// bit-for-bit (`f32 → f64 → f32` round-trips exactly).
#[derive(Debug)]
pub struct SiteAccumulator {
    /// Capacity in timesteps of one window.
    window: usize,
    t0: usize,
    len: usize,
    /// Per-facility PCC window (facility power at each facility's PCC —
    /// PUE already applied upstream).
    fac_w: Vec<Vec<f32>>,
    filled: Vec<bool>,
    /// Site window: Σ facilities, f64, valid after `fold_site`.
    site_w: Vec<f64>,
}

impl SiteAccumulator {
    pub fn new(n_facilities: usize, window: usize) -> SiteAccumulator {
        assert!(n_facilities > 0, "site accumulator: zero facilities");
        assert!(window > 0, "site accumulator: zero-length window");
        SiteAccumulator {
            window,
            t0: 0,
            len: 0,
            fac_w: (0..n_facilities).map(|_| vec![0.0; window]).collect(),
            filled: vec![false; n_facilities],
            site_w: vec![0.0; window],
        }
    }

    pub fn n_facilities(&self) -> usize {
        self.fac_w.len()
    }

    /// Start step of the current window.
    pub fn window_t0(&self) -> usize {
        self.t0
    }

    /// Filled length of the current window.
    pub fn window_len(&self) -> usize {
        self.len
    }

    /// Reset for the window starting at `t0` covering `len` steps.
    pub fn begin_window(&mut self, t0: usize, len: usize) {
        assert!(len <= self.window, "window {len} exceeds capacity {}", self.window);
        self.t0 = t0;
        self.len = len;
        self.filled.fill(false);
    }

    /// Deposit one facility's PCC window (must match the window length).
    pub fn set_facility(&mut self, facility: usize, pcc_w: &[f32]) -> Result<()> {
        ensure!(
            pcc_w.len() == self.len,
            "facility {facility}: window length {} != site window {}",
            pcc_w.len(),
            self.len
        );
        ensure!(!self.filled[facility], "facility {facility}: window delivered twice");
        self.fac_w[facility][..self.len].copy_from_slice(pcc_w);
        self.filled[facility] = true;
        Ok(())
    }

    /// One facility's current window (after [`SiteAccumulator::set_facility`]).
    pub fn facility_window(&self, facility: usize) -> &[f32] {
        &self.fac_w[facility][..self.len]
    }

    /// Sum the facility windows into the site window, visiting facilities
    /// in index order (the deterministic composition fold). Errors if any
    /// facility has not delivered this window.
    pub fn fold_site(&mut self) -> Result<&[f64]> {
        for (f, &ok) in self.filled.iter().enumerate() {
            ensure!(ok, "facility {f}: window {} not delivered", self.t0);
        }
        self.site_w[..self.len].fill(0.0);
        for fac in &self.fac_w {
            for (s, &x) in self.site_w[..self.len].iter_mut().zip(&fac[..self.len]) {
                *s += x as f64;
            }
        }
        Ok(&self.site_w[..self.len])
    }

    /// The folded site window (valid after [`SiteAccumulator::fold_site`]).
    pub fn site_window(&self) -> &[f64] {
        &self.site_w[..self.len]
    }
}

/// Which interval each aggregation level is exported at.
#[derive(Debug, Clone, PartialEq)]
pub struct ScaleConfig {
    /// Rack-level export interval (default 1 s — PDU telemetry cadence).
    pub rack_interval_s: f64,
    /// Row-level export interval (default 15 s — busway metering cadence).
    pub row_interval_s: f64,
    /// Facility-level export intervals (default 5 min and 15 min — utility
    /// settlement cadences).
    pub facility_intervals_s: Vec<f64>,
}

impl Default for ScaleConfig {
    fn default() -> Self {
        ScaleConfig {
            rack_interval_s: 1.0,
            row_interval_s: 15.0,
            facility_intervals_s: vec![300.0, 900.0],
        }
    }
}

/// Multi-resolution view of one facility run (see
/// [`FacilityAccumulator::multi_scale`]).
#[derive(Debug, Clone)]
pub struct MultiScale {
    /// Source sample interval the accumulator was filled at.
    pub dt_s: f64,
    /// PUE applied to the facility-level series.
    pub pue: f64,
    pub scales: ScaleConfig,
    /// Per-rack IT power at `scales.rack_interval_s`.
    pub racks_w: Vec<Vec<f32>>,
    /// Per-row IT power at `scales.row_interval_s`.
    pub rows_w: Vec<Vec<f32>>,
    /// Facility PCC power, one series per `scales.facility_intervals_s`.
    pub facility_w: Vec<Vec<f32>>,
}

/// `resample_mean` over an `f64` accumulator buffer with a final scale
/// factor (used to apply PUE without an intermediate allocation). Window
/// geometry is shared with the f32 path via
/// [`resample_stride`](crate::metrics::planning::resample_stride), and the
/// emitted value expression `((sum / count) * scale) as f32` is shared
/// with [`crate::metrics::planning::StreamingResampler`] — the streaming
/// CSV writers are byte-identical to this path because of it.
fn resample_mean_f64(series: &[f64], dt_s: f64, interval_s: f64, scale: f64) -> Result<Vec<f32>> {
    Ok(series
        .chunks(crate::metrics::planning::resample_stride(dt_s, interval_s)?)
        .map(|c| ((c.iter().sum::<f64>() / c.len() as f64) * scale) as f32)
        .collect())
}

/// Resample any aggregated series to a coarser interval (mean-preserving).
pub fn resample(series: &[f32], dt_s: f64, interval_s: f64) -> Result<Vec<f32>> {
    resample_mean(series, dt_s, interval_s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::check;
    use crate::util::rng::Rng;

    fn topo() -> Topology {
        Topology { rows: 2, racks_per_row: 3, servers_per_rack: 4 }
    }

    #[test]
    fn addressing_roundtrip() {
        let t = topo();
        assert_eq!(t.n_servers(), 24);
        assert_eq!(t.n_racks(), 6);
        assert_eq!(t.addr(0), (0, 0, 0));
        assert_eq!(t.addr(3), (0, 0, 3));
        assert_eq!(t.addr(4), (0, 1, 0));
        assert_eq!(t.addr(12), (1, 0, 0));
        assert_eq!(t.rack_of(12), 3);
        assert_eq!(t.addr(23), (1, 2, 3));
    }

    #[test]
    fn aggregation_includes_p_base() {
        let t = topo();
        let mut acc = FacilityAccumulator::new(t, 4, 1000.0);
        acc.add_server(0, &[100.0f32; 4]).unwrap();
        acc.add_server(1, &[200.0f32; 4]).unwrap();
        // Both in rack 0: 100+1000 + 200+1000 = 2300
        assert_eq!(acc.rack_series(0), vec![2300.0f32; 4]);
        assert_eq!(acc.rack_series(1), vec![0.0f32; 4]);
        assert_eq!(acc.row_series(0), vec![2300.0f32; 4]);
        assert_eq!(acc.site_it_series(), vec![2300.0f32; 4]);
    }

    #[test]
    fn facility_applies_pue() {
        let t = topo();
        let mut acc = FacilityAccumulator::new(t, 2, 0.0);
        acc.add_server(0, &[1000.0f32; 2]).unwrap();
        assert_eq!(acc.facility_series(1.3), vec![1300.0f32; 2]);
        // PUE=1 is identity.
        assert_eq!(acc.facility_series(1.0), acc.site_it_series());
    }

    #[test]
    fn rejects_wrong_length() {
        let mut acc = FacilityAccumulator::new(topo(), 4, 0.0);
        assert!(acc.add_server(0, &[1.0f32; 3]).is_err());
    }

    #[test]
    fn merge_equals_sequential() {
        let t = topo();
        let mut a = FacilityAccumulator::new(t, 3, 500.0);
        let mut b = FacilityAccumulator::new(t, 3, 500.0);
        a.add_server(0, &[10.0f32; 3]).unwrap();
        b.add_server(13, &[20.0f32; 3]).unwrap();
        a.merge(&b);
        let mut seq = FacilityAccumulator::new(t, 3, 500.0);
        seq.add_server(0, &[10.0f32; 3]).unwrap();
        seq.add_server(13, &[20.0f32; 3]).unwrap();
        assert_eq!(a.site_it_series(), seq.site_it_series());
        assert_eq!(a.servers_added(), 2);
    }

    #[test]
    fn prop_site_equals_sum_of_rows_and_racks() {
        check("aggregation linearity", |rng| {
            let t = Topology {
                rows: 1 + rng.below(3),
                racks_per_row: 1 + rng.below(4),
                servers_per_rack: 1 + rng.below(4),
            };
            let n_steps = 5 + rng.below(20);
            let mut acc = FacilityAccumulator::new(t, n_steps, 1000.0);
            let mut local = Rng::new(rng.next_u64());
            for s in 0..t.n_servers() {
                let trace: Vec<f32> =
                    (0..n_steps).map(|_| local.range(50.0, 3000.0) as f32).collect();
                acc.add_server(s, &trace).unwrap();
            }
            let site = acc.site_it_series();
            // Sum of rows == site
            let mut row_sum = vec![0.0f64; n_steps];
            for r in 0..t.rows {
                for (o, &x) in row_sum.iter_mut().zip(&acc.row_series(r)) {
                    *o += x as f64;
                }
            }
            for (a, b) in site.iter().zip(&row_sum) {
                assert!((*a as f64 - b).abs() < 1.0, "site vs rows");
            }
            // Sum of racks == site
            let mut rack_sum = vec![0.0f64; n_steps];
            for r in 0..t.n_racks() {
                for (o, &x) in rack_sum.iter_mut().zip(&acc.rack_series(r)) {
                    *o += x as f64;
                }
            }
            for (a, b) in site.iter().zip(&rack_sum) {
                assert!((*a as f64 - b).abs() < 1.0, "site vs racks");
            }
        });
    }

    #[test]
    fn row_addressing_matches_addr() {
        let t = topo();
        for s in 0..t.n_servers() {
            assert_eq!(t.row_of(s), t.addr(s).0);
            assert_eq!(t.row_of_rack(t.rack_of(s)), t.row_of(s));
        }
    }

    #[test]
    fn multi_scale_matches_single_series_accessors() {
        let t = topo();
        let n_steps = 60; // 15 s at dt=0.25
        let dt = 0.25;
        let mut acc = FacilityAccumulator::new(t, n_steps, 1000.0);
        let mut rng = Rng::new(7);
        for s in 0..t.n_servers() {
            let trace: Vec<f32> = (0..n_steps).map(|_| rng.range(50.0, 3000.0) as f32).collect();
            acc.add_server(s, &trace).unwrap();
        }
        let scales = ScaleConfig {
            rack_interval_s: 1.0,
            row_interval_s: 5.0,
            facility_intervals_s: vec![5.0, 15.0],
        };
        let ms = acc.multi_scale(dt, 1.3, &scales).unwrap();
        assert_eq!(ms.racks_w.len(), t.n_racks());
        assert_eq!(ms.rows_w.len(), t.rows);
        assert_eq!(ms.facility_w.len(), 2);
        // One pass equals resampling the per-level accessors.
        for r in 0..t.n_racks() {
            let expect = resample(&acc.rack_series(r), dt, 1.0).unwrap();
            crate::testutil::assert_allclose(&ms.racks_w[r], &expect, 1e-2, 1e-5, "rack");
        }
        for r in 0..t.rows {
            let expect = resample(&acc.row_series(r), dt, 5.0).unwrap();
            crate::testutil::assert_allclose(&ms.rows_w[r], &expect, 1e-2, 1e-5, "row");
        }
        let expect = resample(&acc.facility_series(1.3), dt, 15.0).unwrap();
        crate::testutil::assert_allclose(&ms.facility_w[1], &expect, 1e-1, 1e-5, "facility");
        // Expected lengths: 15 s of data → 15 rack points, 3 row points,
        // 3- and 1-point facility series.
        assert_eq!(ms.racks_w[0].len(), 15);
        assert_eq!(ms.rows_w[0].len(), 3);
        assert_eq!(ms.facility_w[0].len(), 3);
        assert_eq!(ms.facility_w[1].len(), 1);
    }

    #[test]
    fn multi_scale_applies_pue_only_to_facility() {
        let t = Topology { rows: 1, racks_per_row: 1, servers_per_rack: 1 };
        let mut acc = FacilityAccumulator::new(t, 4, 0.0);
        acc.add_server(0, &[1000.0f32; 4]).unwrap();
        let ms = acc.multi_scale(1.0, 1.5, &ScaleConfig::default()).unwrap();
        assert_eq!(ms.racks_w[0], vec![1000.0f32; 4]);
        assert_eq!(ms.rows_w[0], vec![1000.0f32]); // 4 s < 15 s window
        assert_eq!(ms.facility_w[0], vec![1500.0f32]);
        assert_eq!(ms.facility_w[1], vec![1500.0f32]);
    }

    #[test]
    fn streaming_windows_reassemble_buffered_accumulator_bitwise() {
        // Folding the same servers window-by-window (ragged final window,
        // sub-tile pushes inside windows) must reproduce the buffered
        // accumulator's f64 rack/row/site buffers exactly.
        let t = topo();
        let n_steps = 50;
        let window = 16; // 50 = 3×16 + 2 → ragged final window
        let mut rng = Rng::new(21);
        let traces: Vec<Vec<f32>> = (0..t.n_servers())
            .map(|_| (0..n_steps).map(|_| rng.range(50.0, 3000.0) as f32).collect())
            .collect();
        let mut buffered = FacilityAccumulator::new(t, n_steps, 1000.0);
        for (s, tr) in traces.iter().enumerate() {
            buffered.add_server(s, tr).unwrap();
        }
        let reference = buffered.multi_scale(0.25, 1.3, &ScaleConfig::default()).unwrap();

        let mut acc = StreamingFacilityAccumulator::new(t, window, 1000.0);
        let mut rows = Vec::new();
        let mut site = Vec::new();
        let mut got_site_f32: Vec<f32> = Vec::new();
        let mut t0 = 0;
        while t0 < n_steps {
            let n = window.min(n_steps - t0);
            acc.begin_window(t0, n);
            for (s, tr) in traces.iter().enumerate() {
                // Two ragged sub-tiles per window, like the scan emits.
                let cut = (n / 3).max(1).min(n);
                acc.add_server_tile(s, 0, &tr[t0..t0 + cut]).unwrap();
                if cut < n {
                    acc.add_server_tile(s, cut, &tr[t0 + cut..t0 + n]).unwrap();
                }
            }
            for r in 0..t.n_racks() {
                let win = acc.rack_window(r).to_vec();
                let buf_rack = buffered.rack_series(r);
                for (i, &x) in win.iter().enumerate() {
                    assert_eq!(
                        (x as f32).to_bits(),
                        buf_rack[t0 + i].to_bits(),
                        "rack {r} t {}",
                        t0 + i
                    );
                }
            }
            acc.fold_rows_site(&mut rows, &mut site);
            got_site_f32.extend(site.iter().map(|&x| x as f32));
            t0 += n;
        }
        assert_eq!(got_site_f32, buffered.site_it_series());
        let _ = reference; // multi_scale path exercised above
    }

    #[test]
    fn streaming_accumulator_rejects_out_of_window_tiles() {
        let mut acc = StreamingFacilityAccumulator::new(topo(), 8, 0.0);
        acc.begin_window(0, 4);
        assert!(acc.add_server_tile(0, 2, &[1.0f32; 3]).is_err());
        assert!(acc.add_server_tile(0, 0, &[1.0f32; 4]).is_ok());
    }

    #[test]
    fn site_accumulator_sums_facilities_in_order() {
        let mut acc = SiteAccumulator::new(3, 8);
        acc.begin_window(0, 4);
        // Missing facilities are an error, not a silent zero.
        assert!(acc.fold_site().is_err());
        acc.set_facility(0, &[1.0f32; 4]).unwrap();
        acc.set_facility(1, &[2.0f32; 4]).unwrap();
        // Double delivery and wrong lengths are rejected.
        assert!(acc.set_facility(1, &[2.0f32; 4]).is_err());
        assert!(acc.set_facility(2, &[3.0f32; 3]).is_err());
        acc.set_facility(2, &[3.0f32; 4]).unwrap();
        assert_eq!(acc.fold_site().unwrap(), &[6.0f64; 4]);
        assert_eq!(acc.facility_window(1), &[2.0f32; 4]);
        // Next window resets the delivery markers and length.
        acc.begin_window(4, 2);
        assert!(acc.fold_site().is_err());
        for f in 0..3 {
            acc.set_facility(f, &[10.0f32; 2]).unwrap();
        }
        assert_eq!(acc.fold_site().unwrap(), &[30.0f64; 2]);
        assert_eq!(acc.window_t0(), 4);
        assert_eq!(acc.window_len(), 2);
    }

    #[test]
    fn site_single_facility_roundtrips_f32_exactly() {
        // f32 → f64 → f32 is exact: a 1-facility site reproduces the
        // facility PCC series bit-for-bit.
        let mut rng = Rng::new(11);
        let win: Vec<f32> = (0..64).map(|_| rng.range(1e3, 5e6) as f32).collect();
        let mut acc = SiteAccumulator::new(1, 64);
        acc.begin_window(0, 64);
        acc.set_facility(0, &win).unwrap();
        let site: Vec<f32> = acc.fold_site().unwrap().iter().map(|&x| x as f32).collect();
        for (a, b) in site.iter().zip(&win) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn aggregation_reduces_cov() {
        // The §4.5 smoothing property: CoV falls as independent servers sum.
        use crate::metrics::coefficient_of_variation;
        let t = Topology { rows: 1, racks_per_row: 1, servers_per_rack: 16 };
        let mut acc = FacilityAccumulator::new(t, 2000, 0.0);
        let mut rng = Rng::new(90);
        let mut server_cov = 0.0;
        for s in 0..16 {
            let trace: Vec<f32> =
                (0..2000).map(|_| rng.normal_ms(1000.0, 300.0).max(0.0) as f32).collect();
            if s == 0 {
                server_cov = coefficient_of_variation(&trace).unwrap();
            }
            acc.add_server(s, &trace).unwrap();
        }
        let site_cov = coefficient_of_variation(&acc.site_it_series()).unwrap();
        assert!(
            site_cov < server_cov / 2.5,
            "site {site_cov} vs server {server_cov} (expect ~1/4)"
        );
    }
}
