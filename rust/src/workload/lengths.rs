//! Prompt/output token-length distributions.
//!
//! The paper draws request streams from four prompt datasets (ShareGPT,
//! InstructCoder, AIMO-AIME, Edit-10K-Char); we model each as a lognormal
//! length profile from `data/catalog.json` (DESIGN.md §3). Reasoning models
//! (DeepSeek-R1-Distill, gpt-oss) multiply output lengths.

use crate::catalog::DatasetProfile;
use crate::util::rng::Rng;

/// Samples `(n_in, n_out)` token counts for a request.
#[derive(Debug, Clone)]
pub struct LengthSampler {
    /// mu of ln(n_in); lognormal median = exp(mu).
    mu_in: f64,
    sigma_in: f64,
    mu_out: f64,
    sigma_out: f64,
    /// Output-length multiplier (reasoning models).
    out_mult: f64,
    /// Hard caps to keep the queue simulator bounded.
    max_in: u32,
    max_out: u32,
}

impl LengthSampler {
    /// Build from a catalog dataset profile.
    pub fn from_profile(p: &DatasetProfile, out_mult: f64) -> LengthSampler {
        LengthSampler {
            mu_in: p.in_median.ln(),
            sigma_in: p.in_sigma,
            mu_out: p.out_median.ln(),
            sigma_out: p.out_sigma,
            out_mult,
            max_in: 32_768,
            max_out: 16_384,
        }
    }

    /// Explicit lognormal profile from medians + log-space sigmas — the
    /// token-level workload axis ([`crate::workload::token`]) configures
    /// lengths directly instead of via a catalog dataset. Same caps and
    /// draw order as [`LengthSampler::from_profile`].
    pub fn lognormal(
        in_median: f64,
        in_sigma: f64,
        out_median: f64,
        out_sigma: f64,
    ) -> LengthSampler {
        LengthSampler {
            mu_in: in_median.ln(),
            sigma_in: in_sigma,
            mu_out: out_median.ln(),
            sigma_out: out_sigma,
            out_mult: 1.0,
            max_in: 32_768,
            max_out: 16_384,
        }
    }

    /// Degenerate sampler emitting constant lengths (tests, calibration).
    pub fn fixed(n_in: u32, n_out: u32) -> LengthSampler {
        LengthSampler {
            mu_in: (n_in as f64).ln(),
            sigma_in: 0.0,
            mu_out: (n_out as f64).ln(),
            sigma_out: 0.0,
            out_mult: 1.0,
            max_in: u32::MAX,
            max_out: u32::MAX,
        }
    }

    /// Draw one request's lengths (≥1 token each).
    pub fn sample(&self, rng: &mut Rng) -> (u32, u32) {
        let n_in = rng.lognormal(self.mu_in, self.sigma_in).round();
        let n_out = (rng.lognormal(self.mu_out, self.sigma_out) * self.out_mult).round();
        (
            (n_in.max(1.0) as u32).min(self.max_in),
            (n_out.max(1.0) as u32).min(self.max_out),
        )
    }

    /// Median lengths (used by calibration sweeps / reporting).
    pub fn medians(&self) -> (f64, f64) {
        (self.mu_in.exp(), self.mu_out.exp() * self.out_mult)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catalog::Catalog;

    #[test]
    fn fixed_sampler_is_constant() {
        let s = LengthSampler::fixed(100, 50);
        let mut rng = Rng::new(1);
        for _ in 0..10 {
            assert_eq!(s.sample(&mut rng), (100, 50));
        }
    }

    #[cfg(feature = "host")]
    #[test]
    fn lognormal_median_matches_profile() {
        let c = Catalog::load_default().unwrap();
        let p = &c.datasets["sharegpt"];
        let s = LengthSampler::from_profile(p, 1.0);
        let mut rng = Rng::new(2);
        let mut ins: Vec<u32> = (0..20_001).map(|_| s.sample(&mut rng).0).collect();
        ins.sort_unstable();
        let med = ins[ins.len() / 2] as f64;
        assert!((med - p.in_median).abs() / p.in_median < 0.05, "median {med} vs {}", p.in_median);
    }

    #[cfg(feature = "host")]
    #[test]
    fn reasoning_multiplier_scales_outputs() {
        let c = Catalog::load_default().unwrap();
        let p = &c.datasets["aime"];
        let base = LengthSampler::from_profile(p, 1.0);
        let reasoning = LengthSampler::from_profile(p, 2.0);
        assert!((reasoning.medians().1 - 2.0 * base.medians().1).abs() < 1e-9);
    }

    #[cfg(feature = "host")]
    #[test]
    fn lengths_always_positive_and_capped() {
        let c = Catalog::load_default().unwrap();
        let p = &c.datasets["edit10k"];
        let s = LengthSampler::from_profile(p, 2.0);
        let mut rng = Rng::new(3);
        for _ in 0..5000 {
            let (a, b) = s.sample(&mut rng);
            assert!(a >= 1 && a <= 32_768);
            assert!(b >= 1 && b <= 16_384);
        }
    }
}
