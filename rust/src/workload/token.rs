//! Token-level request workloads (TokenPowerBench-style, arxiv 2512.03024):
//! arrivals still follow a Poisson clock, but every request carries token
//! lengths drawn from an explicitly configured distribution — lognormal,
//! Pareto (heavy-tailed prompts), a degenerate constant, or the empirical
//! pairs of a recorded trace — instead of a dataset profile. Combined with
//! the surrogate queue's token-budget packing
//! ([`crate::surrogate::queue::QueuePolicy`]), traffic maps to
//! prefill/decode/idle state transitions *mechanistically*: per-request
//! service time is `TTFT(n_in) + n_out × TBT`, so occupancy is derived from
//! token counts rather than from a scalar rate alone.
//!
//! Determinism contract: [`token_arrivals`] consumes its RNG in exactly the
//! same order as [`super::poisson::poisson_arrivals`] (one exponential gap,
//! then one length draw per request), and the `Lognormal`/`Fixed`
//! distributions delegate to [`LengthSampler`] — so a degenerate token
//! workload (constant lengths) reproduces the poisson path's schedule
//! bit-for-bit from the same RNG state. The differential tests in
//! `rust/tests/token_integration.rs` pin this equivalence.

use super::lengths::LengthSampler;
use super::{Request, Schedule};
use crate::util::rng::Rng;
use std::sync::Arc;

/// Hard caps mirroring [`LengthSampler::from_profile`], so a heavy-tailed
/// draw cannot stall the queue simulator.
const MAX_IN: u32 = 32_768;
const MAX_OUT: u32 = 16_384;

/// A configurable token-length distribution (the sweepable spec; the
/// resolved sampler is [`TokenLengthSampler`]).
#[derive(Debug, Clone, PartialEq)]
pub enum TokenLengths {
    /// Independent lognormal prompt/output lengths, parameterized by their
    /// medians (`exp(mu)`) and log-space sigmas.
    Lognormal { in_median: f64, in_sigma: f64, out_median: f64, out_sigma: f64 },
    /// Independent Pareto (heavy-tailed) lengths: minimum token count and
    /// tail index per side. Smaller `alpha` ⇒ heavier tail.
    Pareto { in_min: f64, in_alpha: f64, out_min: f64, out_alpha: f64 },
    /// Degenerate constant lengths (the differential-test anchor).
    Fixed { n_in: u32, n_out: u32 },
    /// Empirical `(n_in, n_out)` pairs resampled uniformly from a recorded
    /// request trace (JSON schedule or `t_s,n_in,n_out` CSV — see
    /// [`super::replay`]). Resolved by the pipeline, which caches the
    /// parsed trace per path.
    Empirical { path: String },
}

/// `v` is a finite number ≥ `lo` (NaN and ±inf fail).
fn at_least(v: f64, lo: f64) -> bool {
    v.is_finite() && v >= lo
}

impl TokenLengths {
    /// Validate the distribution parameters.
    pub fn validate(&self) -> Result<(), String> {
        match self {
            TokenLengths::Lognormal { in_median, in_sigma, out_median, out_sigma } => {
                if !at_least(*in_median, 1.0) || !at_least(*out_median, 1.0) {
                    return Err(format!(
                        "token lengths: medians must be >= 1, got {in_median}/{out_median}"
                    ));
                }
                if !at_least(*in_sigma, 0.0) || !at_least(*out_sigma, 0.0) {
                    return Err("token lengths: sigmas must be >= 0".into());
                }
            }
            TokenLengths::Pareto { in_min, in_alpha, out_min, out_alpha } => {
                if !at_least(*in_min, 1.0) || !at_least(*out_min, 1.0) {
                    return Err(format!(
                        "token lengths: minima must be >= 1, got {in_min}/{out_min}"
                    ));
                }
                if !(in_alpha.is_finite() && *in_alpha > 0.0)
                    || !(out_alpha.is_finite() && *out_alpha > 0.0)
                {
                    return Err("token lengths: Pareto alpha must be > 0".into());
                }
            }
            TokenLengths::Fixed { n_in, n_out } => {
                if *n_in == 0 || *n_out == 0 {
                    return Err("token lengths: fixed lengths must be >= 1".into());
                }
            }
            TokenLengths::Empirical { path } => {
                if path.is_empty() {
                    return Err("token lengths: empirical path is empty".into());
                }
            }
        }
        Ok(())
    }

    /// Short human label (sweep summaries, reports). Comma-free so summary
    /// CSV cells need no quoting.
    pub fn label(&self) -> String {
        match self {
            TokenLengths::Lognormal { in_median, in_sigma, out_median, out_sigma } => {
                format!("ln({in_median}±{in_sigma}/{out_median}±{out_sigma})")
            }
            TokenLengths::Pareto { in_min, in_alpha, out_min, out_alpha } => {
                format!("pareto({in_min}^{in_alpha}/{out_min}^{out_alpha})")
            }
            TokenLengths::Fixed { n_in, n_out } => format!("fixed({n_in}/{n_out})"),
            TokenLengths::Empirical { path } => format!("empirical({path})"),
        }
    }

    /// Resolve to a sampler without touching the filesystem. `None` for
    /// `Empirical`, whose trace the caller loads (and caches) itself —
    /// pair it with [`TokenLengthSampler::empirical`].
    pub fn sampler_local(&self) -> Option<TokenLengthSampler> {
        match self {
            TokenLengths::Lognormal { in_median, in_sigma, out_median, out_sigma } => {
                let ls = LengthSampler::lognormal(*in_median, *in_sigma, *out_median, *out_sigma);
                Some(TokenLengthSampler::Delegate(ls))
            }
            TokenLengths::Pareto { in_min, in_alpha, out_min, out_alpha } => {
                Some(TokenLengthSampler::Pareto {
                    in_min: *in_min,
                    in_alpha: *in_alpha,
                    out_min: *out_min,
                    out_alpha: *out_alpha,
                })
            }
            TokenLengths::Fixed { n_in, n_out } => {
                Some(TokenLengthSampler::Delegate(LengthSampler::fixed(*n_in, *n_out)))
            }
            TokenLengths::Empirical { .. } => None,
        }
    }
}

/// A resolved token-length sampler.
///
/// `Lognormal`/`Fixed` delegate to [`LengthSampler`] so their RNG draw
/// count and order match the rate-driven workloads exactly (the degenerate
/// bit-identity contract); `Pareto` and `Empirical` consume their own draw
/// patterns (two uniforms, resp. one index draw) — fine, because only the
/// degenerate case claims cross-path equivalence.
#[derive(Debug, Clone)]
pub enum TokenLengthSampler {
    /// Lognormal or fixed lengths via the shared [`LengthSampler`].
    Delegate(LengthSampler),
    /// Heavy-tailed lengths via inverse-CDF Pareto draws.
    Pareto { in_min: f64, in_alpha: f64, out_min: f64, out_alpha: f64 },
    /// Uniform resampling of a recorded trace's `(n_in, n_out)` pairs.
    Empirical(Arc<Schedule>),
}

impl TokenLengthSampler {
    /// Wrap a loaded empirical trace; errors on an empty one.
    pub fn empirical(trace: Arc<Schedule>) -> Result<TokenLengthSampler, String> {
        if trace.is_empty() {
            return Err("token lengths: empirical trace has no requests".into());
        }
        Ok(TokenLengthSampler::Empirical(trace))
    }

    /// Draw one request's `(n_in, n_out)` (≥ 1 token each, capped).
    pub fn sample(&self, rng: &mut Rng) -> (u32, u32) {
        match self {
            TokenLengthSampler::Delegate(ls) => ls.sample(rng),
            TokenLengthSampler::Pareto { in_min, in_alpha, out_min, out_alpha } => {
                let n_in = pareto_draw(rng, *in_min, *in_alpha, MAX_IN);
                let n_out = pareto_draw(rng, *out_min, *out_alpha, MAX_OUT);
                (n_in, n_out)
            }
            TokenLengthSampler::Empirical(trace) => {
                let r = trace[rng.below(trace.len())];
                (r.n_in.clamp(1, MAX_IN), r.n_out.clamp(1, MAX_OUT))
            }
        }
    }
}

/// Inverse-CDF Pareto draw: `x_min · u^(-1/alpha)` with `u ∈ (0, 1]`.
fn pareto_draw(rng: &mut Rng, x_min: f64, alpha: f64, cap: u32) -> u32 {
    let u = 1.0 - rng.f64(); // (0, 1]: keeps the power finite
    let x = (x_min * u.powf(-1.0 / alpha)).round();
    (x.max(1.0) as u32).min(cap)
}

/// Generate Poisson(λ) arrivals whose lengths come from a token-level
/// distribution. The generation loop mirrors
/// [`super::poisson::poisson_arrivals`] exactly (same RNG consumption per
/// request), which is what makes the degenerate token workload bit-identical
/// to the poisson path.
pub fn token_arrivals(
    rate: f64,
    horizon_s: f64,
    lengths: &TokenLengthSampler,
    rng: &mut Rng,
) -> Schedule {
    assert!(rate > 0.0, "token_arrivals: rate must be positive");
    assert!(horizon_s > 0.0, "token_arrivals: horizon must be positive");
    let mut out = Schedule::new();
    let mut t = 0.0f64;
    loop {
        t += rng.exponential(rate);
        if t >= horizon_s {
            break;
        }
        let (n_in, n_out) = lengths.sample(rng);
        out.push(Request { arrival_s: t, n_in, n_out });
    }
    out
}

/// Σ (n_in + n_out) over a schedule — the conserved quantity the token
/// property tests pin: batching policy and window partition may reshape
/// *when* tokens are served, never *how many*.
pub fn total_tokens(schedule: &Schedule) -> u64 {
    schedule.iter().map(|r| r.n_in as u64 + r.n_out as u64).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::check;
    use crate::workload::{poisson_arrivals, validate};

    #[test]
    fn degenerate_token_schedule_matches_poisson_bitwise() {
        // The tentpole anchor at the schedule level: constant lengths via
        // the token path == the poisson path from the same RNG state.
        let spec = TokenLengths::Fixed { n_in: 1, n_out: 1 };
        let sampler = spec.sampler_local().unwrap();
        let reference = LengthSampler::fixed(1, 1);
        for seed in [0u64, 7, 42] {
            let mut ra = Rng::new(seed).fork(0xA21);
            let mut rb = Rng::new(seed).fork(0xA21);
            let a = token_arrivals(1.5, 500.0, &sampler, &mut ra);
            let b = poisson_arrivals(1.5, 500.0, &reference, &mut rb);
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.arrival_s.to_bits(), y.arrival_s.to_bits());
                assert_eq!((x.n_in, x.n_out), (y.n_in, y.n_out));
            }
            // ...and the generators left their RNGs in the same state.
            assert_eq!(ra.next_u64(), rb.next_u64());
        }
    }

    #[test]
    fn lognormal_spec_matches_length_sampler_medians() {
        let spec = TokenLengths::Lognormal {
            in_median: 128.0,
            in_sigma: 0.6,
            out_median: 256.0,
            out_sigma: 0.4,
        };
        let TokenLengthSampler::Delegate(ls) = spec.sampler_local().unwrap() else {
            panic!("lognormal resolves to a delegate sampler");
        };
        let (mi, mo) = ls.medians();
        assert!((mi - 128.0).abs() < 1e-9 && (mo - 256.0).abs() < 1e-9);
    }

    #[test]
    fn pareto_draws_are_bounded_below_and_capped() {
        let spec = TokenLengths::Pareto {
            in_min: 64.0,
            in_alpha: 1.2,
            out_min: 16.0,
            out_alpha: 0.3, // violently heavy tail: exercises the cap
        };
        let sampler = spec.sampler_local().unwrap();
        let mut rng = Rng::new(9);
        let mut capped = 0;
        for _ in 0..5000 {
            let (a, b) = sampler.sample(&mut rng);
            assert!(a >= 64 && a <= MAX_IN);
            assert!(b >= 16 && b <= MAX_OUT);
            if b == MAX_OUT {
                capped += 1;
            }
        }
        assert!(capped > 0, "alpha 0.3 must hit the output cap");
    }

    #[test]
    fn empirical_resamples_only_trace_pairs() {
        let trace = Arc::new(vec![
            Request { arrival_s: 0.0, n_in: 10, n_out: 3 },
            Request { arrival_s: 1.0, n_in: 70, n_out: 9 },
        ]);
        let sampler = TokenLengthSampler::empirical(trace).unwrap();
        let mut rng = Rng::new(4);
        let mut seen = [false; 2];
        for _ in 0..200 {
            match sampler.sample(&mut rng) {
                (10, 3) => seen[0] = true,
                (70, 9) => seen[1] = true,
                other => panic!("drew a pair not in the trace: {other:?}"),
            }
        }
        assert!(seen[0] && seen[1]);
        assert!(TokenLengthSampler::empirical(Arc::new(Vec::new())).is_err());
    }

    #[test]
    fn validation_rejects_bad_parameters() {
        assert!(TokenLengths::Fixed { n_in: 0, n_out: 1 }.validate().is_err());
        let bad_median = TokenLengths::Lognormal {
            in_median: 0.5,
            in_sigma: 0.1,
            out_median: 10.0,
            out_sigma: 0.1,
        };
        assert!(bad_median.validate().is_err());
        let bad_alpha =
            TokenLengths::Pareto { in_min: 8.0, in_alpha: 0.0, out_min: 8.0, out_alpha: 1.0 };
        assert!(bad_alpha.validate().is_err());
        assert!(TokenLengths::Empirical { path: String::new() }.validate().is_err());
        assert!(TokenLengths::Fixed { n_in: 1, n_out: 1 }.validate().is_ok());
    }

    #[test]
    fn prop_token_schedules_valid_and_conserve_totals() {
        check("token schedules valid", |rng| {
            let rate = rng.range(0.1, 6.0);
            let horizon = rng.range(10.0, 400.0);
            let spec = match rng.below(3) {
                0 => TokenLengths::Fixed {
                    n_in: 1 + rng.below(512) as u32,
                    n_out: 1 + rng.below(512) as u32,
                },
                1 => TokenLengths::Lognormal {
                    in_median: rng.range(4.0, 2048.0),
                    in_sigma: rng.range(0.0, 1.5),
                    out_median: rng.range(4.0, 1024.0),
                    out_sigma: rng.range(0.0, 1.5),
                },
                _ => TokenLengths::Pareto {
                    in_min: rng.range(1.0, 256.0),
                    in_alpha: rng.range(0.5, 3.0),
                    out_min: rng.range(1.0, 128.0),
                    out_alpha: rng.range(0.5, 3.0),
                },
            };
            spec.validate().expect("generated specs are valid");
            let sampler = spec.sampler_local().unwrap();
            let mut local = rng.clone();
            let s = token_arrivals(rate, horizon, &sampler, &mut local);
            validate(&s, horizon).expect("valid schedule");
            let direct: u64 = s.iter().map(|r| r.n_in as u64 + r.n_out as u64).sum();
            assert_eq!(total_tokens(&s), direct);
        });
    }
}
