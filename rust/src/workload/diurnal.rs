//! Diurnal + bursty arrival-rate profile standing in for the production
//! Azure coding-activity trace the paper drives its facility study with
//! (§4.4; the real single-day trace is not public — DESIGN.md §3).
//!
//! The profile is a two-harmonic diurnal envelope with an afternoon peak,
//! multiplied by slowly-varying lognormal bursts. Per-server streams are
//! produced either independently (each server gets a random temporal offset,
//! as the paper does to decorrelate arrivals across the facility) or by
//! thinning the shared intensity (correlated traffic).

use super::{lengths::LengthSampler, thinned_arrivals, Schedule, TrafficMode};
use crate::util::rng::Rng;

/// A 24-hour arrival-rate profile λ(t) in requests/second/server.
#[derive(Debug, Clone)]
pub struct DiurnalProfile {
    /// Mean per-server rate (req/s).
    pub base_rate: f64,
    /// Diurnal swing as a fraction of base (0..1).
    pub swing: f64,
    /// Hour of peak demand (local), e.g. 15.0 for an afternoon surge.
    pub peak_hour: f64,
    /// Burst amplitude (lognormal sigma of the multiplicative burst factor).
    pub burst_sigma: f64,
    /// Burst correlation time in seconds.
    pub burst_tau_s: f64,
    /// Traffic distribution mode across servers.
    pub mode: TrafficMode,
}

impl Default for DiurnalProfile {
    fn default() -> Self {
        // Matches the qualitative shape of the paper's Fig. 9 input: clear
        // diurnal envelope, afternoon peak, bursty small-timescale structure.
        DiurnalProfile {
            base_rate: 0.5,
            swing: 0.65,
            peak_hour: 15.0,
            burst_sigma: 0.35,
            burst_tau_s: 300.0,
            mode: TrafficMode::Independent,
        }
    }
}

impl DiurnalProfile {
    /// Deterministic diurnal envelope at time `t` seconds from midnight.
    pub fn envelope(&self, t: f64) -> f64 {
        let hours = t / 3600.0;
        let phase = (hours - self.peak_hour) / 24.0 * std::f64::consts::TAU;
        // Primary harmonic peaked at `peak_hour` + a weaker second harmonic
        // that deepens the overnight trough.
        let shape = phase.cos() + 0.25 * (2.0 * phase).cos();
        (self.base_rate * (1.0 + self.swing * shape / 1.25)).max(0.0)
    }

    /// Sample a piecewise-constant burst factor series over the horizon:
    /// lognormal AR(1) with correlation time `burst_tau_s`.
    fn burst_series(&self, horizon_s: f64, rng: &mut Rng) -> Vec<f64> {
        let n = (horizon_s / self.burst_tau_s).ceil() as usize + 1;
        let phi: f64 = 0.7;
        let mut x = 0.0f64;
        (0..n)
            .map(|_| {
                x = phi * x + (1.0 - phi * phi).sqrt() * rng.normal();
                (self.burst_sigma * x - 0.5 * self.burst_sigma * self.burst_sigma).exp()
            })
            .collect()
    }

    /// Build the schedule for one server.
    ///
    /// * `Independent`: the server's own burst series and a random offset of
    ///   up to ±30 min applied to the envelope (the paper's "random temporal
    ///   offset so that arrivals are decorrelated across the facility").
    /// * `SharedIntensity`: all servers share the burst series derived from
    ///   `shared_rng_label`; only the thinning randomness differs.
    pub fn schedule(
        &self,
        server_idx: usize,
        horizon_s: f64,
        lengths: &LengthSampler,
        base_rng: &Rng,
    ) -> Schedule {
        let mut rng = match self.mode {
            TrafficMode::Independent => base_rng.fork(0x0D1E ^ server_idx as u64),
            TrafficMode::SharedIntensity => base_rng.fork(0x0D1E_0000),
        };
        let bursts = self.burst_series(horizon_s, &mut rng);
        let offset = match self.mode {
            TrafficMode::Independent => rng.range(-1800.0, 1800.0),
            TrafficMode::SharedIntensity => 0.0,
        };
        // Upper bound for thinning: envelope max × generous burst headroom.
        let burst_max = bursts.iter().cloned().fold(0.0f64, f64::max);
        let env_max = self.base_rate * (1.0 + self.swing);
        let rate_max = (env_max * burst_max).max(1e-9);
        let rate = |t: f64| {
            let b = bursts[((t / self.burst_tau_s) as usize).min(bursts.len() - 1)];
            self.envelope(t + offset) * b
        };
        // Thinning randomness must differ per server even in shared mode.
        let mut thin_rng = base_rng.fork(0x7417 ^ server_idx as u64);
        // Note: `rate` uses the shared/offset series; only acceptance differs.
        let _ = &mut rng;
        thinned_arrivals(rate, rate_max, horizon_s, lengths, &mut thin_rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::validate;

    #[test]
    fn envelope_peaks_at_peak_hour() {
        let p = DiurnalProfile::default();
        let at_peak = p.envelope(p.peak_hour * 3600.0);
        for h in [0.0, 4.0, 9.0, 20.0] {
            assert!(p.envelope(h * 3600.0) <= at_peak + 1e-9, "hour {h}");
        }
        // Overnight trough well below peak.
        assert!(p.envelope(3.0 * 3600.0) < 0.6 * at_peak);
    }

    #[test]
    fn envelope_nonnegative_over_day() {
        let p = DiurnalProfile { swing: 1.0, ..Default::default() };
        for i in 0..288 {
            assert!(p.envelope(i as f64 * 300.0) >= 0.0);
        }
    }

    #[test]
    fn schedules_valid_and_rate_plausible() {
        let p = DiurnalProfile::default();
        let lengths = LengthSampler::fixed(128, 128);
        let rng = Rng::new(31);
        let horizon = 86_400.0;
        let s = p.schedule(0, horizon, &lengths, &rng);
        validate(&s, horizon).unwrap();
        let mean = s.len() as f64 / horizon;
        // Long-run mean should be near base_rate (burst factor mean ≈ 1).
        assert!((mean - p.base_rate).abs() < 0.3 * p.base_rate, "mean {mean}");
    }

    #[test]
    fn independent_servers_are_decorrelated() {
        let p = DiurnalProfile::default();
        let lengths = LengthSampler::fixed(64, 64);
        let rng = Rng::new(32);
        let a = p.schedule(0, 7200.0, &lengths, &rng);
        let b = p.schedule(1, 7200.0, &lengths, &rng);
        assert_ne!(
            a.first().map(|r| r.arrival_s.to_bits()),
            b.first().map(|r| r.arrival_s.to_bits())
        );
    }

    #[test]
    fn shared_intensity_correlates_binned_counts() {
        // Shared mode: same rate function → binned counts correlate more
        // than independent mode with offsets.
        let lengths = LengthSampler::fixed(64, 64);
        let rng = Rng::new(33);
        let correlation = |mode: TrafficMode| {
            let p = DiurnalProfile {
                base_rate: 2.0,
                burst_sigma: 0.8,
                burst_tau_s: 120.0,
                mode,
                ..Default::default()
            };
            let horizon = 14_400.0;
            let a = p.schedule(0, horizon, &lengths, &rng);
            let b = p.schedule(1, horizon, &lengths, &rng);
            let nbins = 120;
            let bin = |s: &Schedule| {
                let mut v = vec![0f64; nbins];
                for r in s {
                    v[(r.arrival_s / horizon * nbins as f64) as usize] += 1.0;
                }
                v
            };
            let (xa, xb) = (bin(&a), bin(&b));
            let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
            let (ma, mb) = (mean(&xa), mean(&xb));
            let cov: f64 = xa.iter().zip(&xb).map(|(x, y)| (x - ma) * (y - mb)).sum();
            let va: f64 = xa.iter().map(|x| (x - ma) * (x - ma)).sum();
            let vb: f64 = xb.iter().map(|x| (x - mb) * (x - mb)).sum();
            cov / (va.sqrt() * vb.sqrt())
        };
        let shared = correlation(TrafficMode::SharedIntensity);
        let indep = correlation(TrafficMode::Independent);
        assert!(
            shared > indep + 0.1,
            "shared {shared} should exceed independent {indep}"
        );
    }
}
