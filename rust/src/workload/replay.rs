//! Replay arrival schedules from JSON or CSV files.
//!
//! This is the interface through which a *real* production trace (e.g. the
//! Azure token-traffic trace of the paper's §4.4) would be fed to the
//! pipeline if available: a JSON array of `{"t": s, "n_in": .., "n_out": ..}`
//! records, or a `t_s,n_in,n_out` CSV (the format of the checked-in
//! `data/traces/sample_requests.csv` fixture). The held-out measured-trace
//! artifacts exported by the Python build path use the JSON representation.

use super::{Request, Schedule};
use crate::util::json::{self, Json};
use anyhow::{Context, Result};

/// Parse a schedule from a JSON value (array of request objects).
pub fn schedule_from_json(v: &Json) -> Result<Schedule> {
    let mut out = Schedule::new();
    for (i, r) in v.as_arr().map_err(anyhow::Error::from)?.iter().enumerate() {
        let req = Request {
            arrival_s: r.f64_field("t").with_context(|| format!("request {i}"))?,
            n_in: r.f64_field("n_in").with_context(|| format!("request {i}"))? as u32,
            n_out: r.f64_field("n_out").with_context(|| format!("request {i}"))? as u32,
        };
        out.push(req);
    }
    // Replayed traces may be unsorted on disk; normalize.
    out.sort_by(|a, b| a.arrival_s.partial_cmp(&b.arrival_s).unwrap());
    Ok(out)
}

/// Serialize a schedule to JSON (inverse of [`schedule_from_json`]).
pub fn schedule_to_json(s: &Schedule) -> Json {
    Json::Arr(
        s.iter()
            .map(|r| {
                json::obj([
                    ("t", r.arrival_s.into()),
                    ("n_in", (r.n_in as f64).into()),
                    ("n_out", (r.n_out as f64).into()),
                ])
            })
            .collect(),
    )
}

/// Parse a schedule from `t_s,n_in,n_out` CSV text (header row optional;
/// any line whose first field does not parse as a number is skipped as a
/// header). Rows may be unsorted on disk; the result is time-sorted.
pub fn schedule_from_csv(text: &str) -> Result<Schedule> {
    let mut out = Schedule::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let fields: Vec<&str> = line.split(',').map(str::trim).collect();
        anyhow::ensure!(
            fields.len() == 3,
            "trace CSV line {}: expected 3 fields (t_s,n_in,n_out), got {}",
            lineno + 1,
            fields.len()
        );
        let Ok(t) = fields[0].parse::<f64>() else {
            // Header row (e.g. "t_s,n_in,n_out").
            anyhow::ensure!(lineno == 0, "trace CSV line {}: unparsable timestamp", lineno + 1);
            continue;
        };
        let parse_len = |s: &str, what: &str| -> Result<u32> {
            s.parse::<u32>()
                .map_err(|e| anyhow::anyhow!("trace CSV line {}: bad {what}: {e}", lineno + 1))
        };
        out.push(Request {
            arrival_s: t,
            n_in: parse_len(fields[1], "n_in")?,
            n_out: parse_len(fields[2], "n_out")?,
        });
    }
    out.sort_by(|a, b| a.arrival_s.partial_cmp(&b.arrival_s).unwrap());
    Ok(out)
}

/// Parse a schedule from raw trace bytes, dispatching on the logical
/// name's `.csv` extension (anything else parses as JSON). This is the
/// core entry point behind [`crate::source::ArtifactSource`]-routed
/// replay loading; [`load`] is its file-backed wrapper.
pub fn from_named_bytes(name: &str, bytes: &[u8]) -> Result<Schedule> {
    let text = std::str::from_utf8(bytes).with_context(|| format!("trace {name}: not UTF-8"))?;
    let is_csv = name.rsplit('.').next().is_some_and(|e| e.eq_ignore_ascii_case("csv"))
        && name.contains('.');
    if is_csv {
        return schedule_from_csv(text).with_context(|| format!("parsing schedule {name}"));
    }
    let v = json::parse(text).map_err(anyhow::Error::from)?;
    schedule_from_json(&v).with_context(|| format!("parsing schedule {name}"))
}

/// Load a schedule from a JSON or (by `.csv` extension) CSV file.
#[cfg(feature = "host")]
pub fn load(path: &std::path::Path) -> Result<Schedule> {
    let bytes =
        std::fs::read(path).with_context(|| format!("reading trace {}", path.display()))?;
    from_named_bytes(&path.to_string_lossy(), &bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let s = vec![
            Request { arrival_s: 0.5, n_in: 100, n_out: 20 },
            Request { arrival_s: 2.25, n_in: 64, n_out: 8 },
        ];
        let j = schedule_to_json(&s);
        let back = schedule_from_json(&j).unwrap();
        assert_eq!(back, s);
    }

    #[test]
    fn sorts_unsorted_input() {
        let j = json::parse(r#"[{"t": 5, "n_in": 1, "n_out": 1}, {"t": 1, "n_in": 2, "n_out": 2}]"#)
            .unwrap();
        let s = schedule_from_json(&j).unwrap();
        assert!(s[0].arrival_s < s[1].arrival_s);
    }

    #[test]
    fn rejects_malformed() {
        let j = json::parse(r#"[{"t": 1}]"#).unwrap();
        assert!(schedule_from_json(&j).is_err());
        let j = json::parse(r#"{"not": "an array"}"#).unwrap();
        assert!(schedule_from_json(&j).is_err());
    }

    #[test]
    fn csv_parses_with_and_without_header() {
        let with_header = "t_s,n_in,n_out\n0.5,100,20\n2.25,64,8\n";
        let bare = "0.5,100,20\n2.25,64,8\n";
        let want = vec![
            Request { arrival_s: 0.5, n_in: 100, n_out: 20 },
            Request { arrival_s: 2.25, n_in: 64, n_out: 8 },
        ];
        assert_eq!(schedule_from_csv(with_header).unwrap(), want);
        assert_eq!(schedule_from_csv(bare).unwrap(), want);
        // Unsorted rows normalize, like the JSON path.
        let unsorted = "2.25,64,8\n0.5,100,20\n";
        assert_eq!(schedule_from_csv(unsorted).unwrap(), want);
    }

    #[test]
    fn csv_rejects_malformed_rows() {
        assert!(schedule_from_csv("0.5,100\n").is_err());
        assert!(schedule_from_csv("t_s,n_in,n_out\nnope,1,1\n").is_err());
        assert!(schedule_from_csv("0.5,1.5,2\n").is_err());
        assert!(schedule_from_csv("").unwrap().is_empty());
    }

    #[test]
    fn named_bytes_dispatch_on_extension() {
        let want = vec![Request { arrival_s: 1.0, n_in: 10, n_out: 5 }];
        let csv = b"t_s,n_in,n_out\n1.0,10,5\n";
        assert_eq!(from_named_bytes("sched.csv", csv).unwrap(), want);
        assert_eq!(from_named_bytes("SCHED.CSV", csv).unwrap(), want);
        let js = br#"[{"t": 1, "n_in": 10, "n_out": 5}]"#;
        assert_eq!(from_named_bytes("sched.json", js).unwrap(), want);
        // No extension → JSON, matching the file path's dispatch rule.
        assert_eq!(from_named_bytes("sched", js).unwrap(), want);
        assert!(from_named_bytes("sched.json", &[0xff, 0xfe]).is_err());
    }

    #[cfg(feature = "host")]
    #[test]
    fn csv_file_loads_by_extension() {
        let dir = std::env::temp_dir().join("powertrace_test_replay_csv");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("sched.csv");
        std::fs::write(&path, "t_s,n_in,n_out\n1.0,10,5\n").unwrap();
        assert_eq!(load(&path).unwrap(), vec![Request { arrival_s: 1.0, n_in: 10, n_out: 5 }]);
    }

    #[cfg(feature = "host")]
    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("powertrace_test_replay");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("sched.json");
        let s = vec![Request { arrival_s: 1.0, n_in: 10, n_out: 5 }];
        json::write_file(&path, &schedule_to_json(&s)).unwrap();
        assert_eq!(load(&path).unwrap(), s);
    }
}
