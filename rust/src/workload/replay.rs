//! Replay arrival schedules from JSON files.
//!
//! This is the interface through which a *real* production trace (e.g. the
//! Azure token-traffic trace of the paper's §4.4) would be fed to the
//! pipeline if available: a JSON array of `{"t": s, "n_in": .., "n_out": ..}`
//! records. The held-out measured-trace artifacts exported by the Python
//! build path use the same representation.

use super::{Request, Schedule};
use crate::util::json::{self, Json};
use anyhow::{Context, Result};
use std::path::Path;

/// Parse a schedule from a JSON value (array of request objects).
pub fn schedule_from_json(v: &Json) -> Result<Schedule> {
    let mut out = Schedule::new();
    for (i, r) in v.as_arr().map_err(anyhow::Error::from)?.iter().enumerate() {
        let req = Request {
            arrival_s: r.f64_field("t").with_context(|| format!("request {i}"))?,
            n_in: r.f64_field("n_in").with_context(|| format!("request {i}"))? as u32,
            n_out: r.f64_field("n_out").with_context(|| format!("request {i}"))? as u32,
        };
        out.push(req);
    }
    // Replayed traces may be unsorted on disk; normalize.
    out.sort_by(|a, b| a.arrival_s.partial_cmp(&b.arrival_s).unwrap());
    Ok(out)
}

/// Serialize a schedule to JSON (inverse of [`schedule_from_json`]).
pub fn schedule_to_json(s: &Schedule) -> Json {
    Json::Arr(
        s.iter()
            .map(|r| {
                json::obj([
                    ("t", r.arrival_s.into()),
                    ("n_in", (r.n_in as f64).into()),
                    ("n_out", (r.n_out as f64).into()),
                ])
            })
            .collect(),
    )
}

/// Load a schedule from a JSON file.
pub fn load(path: &Path) -> Result<Schedule> {
    let v = json::parse_file(path).map_err(anyhow::Error::from)?;
    schedule_from_json(&v).with_context(|| format!("parsing schedule {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let s = vec![
            Request { arrival_s: 0.5, n_in: 100, n_out: 20 },
            Request { arrival_s: 2.25, n_in: 64, n_out: 8 },
        ];
        let j = schedule_to_json(&s);
        let back = schedule_from_json(&j).unwrap();
        assert_eq!(back, s);
    }

    #[test]
    fn sorts_unsorted_input() {
        let j = json::parse(r#"[{"t": 5, "n_in": 1, "n_out": 1}, {"t": 1, "n_in": 2, "n_out": 2}]"#)
            .unwrap();
        let s = schedule_from_json(&j).unwrap();
        assert!(s[0].arrival_s < s[1].arrival_s);
    }

    #[test]
    fn rejects_malformed() {
        let j = json::parse(r#"[{"t": 1}]"#).unwrap();
        assert!(schedule_from_json(&j).is_err());
        let j = json::parse(r#"{"not": "an array"}"#).unwrap();
        assert!(schedule_from_json(&j).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("powertrace_test_replay");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("sched.json");
        let s = vec![Request { arrival_s: 1.0, n_in: 10, n_out: 5 }];
        json::write_file(&path, &schedule_to_json(&s)).unwrap();
        assert_eq!(load(&path).unwrap(), s);
    }
}
