//! Homogeneous Poisson arrivals — the paper's server-level measurement
//! campaign uses Poisson(λ) for λ ∈ [0.125, 4] req/s (§4.1).

use super::{lengths::LengthSampler, Request, Schedule};
use crate::util::rng::Rng;

/// Generate Poisson(λ) arrivals over `[0, horizon_s)`.
pub fn poisson_arrivals(rate: f64, horizon_s: f64, lengths: &LengthSampler, rng: &mut Rng) -> Schedule {
    assert!(rate > 0.0, "poisson_arrivals: rate must be positive");
    assert!(horizon_s > 0.0, "poisson_arrivals: horizon must be positive");
    let mut out = Schedule::new();
    let mut t = 0.0f64;
    loop {
        t += rng.exponential(rate);
        if t >= horizon_s {
            break;
        }
        let (n_in, n_out) = lengths.sample(rng);
        out.push(Request { arrival_s: t, n_in, n_out });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::check;
    use crate::workload::validate;

    #[test]
    fn mean_rate_matches() {
        let lengths = LengthSampler::fixed(64, 64);
        let mut rng = Rng::new(10);
        let s = poisson_arrivals(0.5, 40_000.0, &lengths, &mut rng);
        let rate = s.len() as f64 / 40_000.0;
        assert!((rate - 0.5).abs() < 0.02, "rate {rate}");
    }

    #[test]
    fn interarrivals_are_exponential() {
        let lengths = LengthSampler::fixed(64, 64);
        let mut rng = Rng::new(11);
        let s = poisson_arrivals(2.0, 20_000.0, &lengths, &mut rng);
        let gaps: Vec<f64> = s.windows(2).map(|w| w[1].arrival_s - w[0].arrival_s).collect();
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        // CV of exponential is 1.
        let var = gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>() / gaps.len() as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
        assert!((var.sqrt() / mean - 1.0).abs() < 0.05, "cv {}", var.sqrt() / mean);
    }

    #[test]
    fn prop_schedules_valid() {
        check("poisson schedules valid", |rng| {
            let rate = rng.range(0.05, 8.0);
            let horizon = rng.range(10.0, 1000.0);
            let lengths = LengthSampler::fixed(32, 32);
            let mut local = rng.clone();
            let s = poisson_arrivals(rate, horizon, &lengths, &mut local);
            validate(&s, horizon).expect("valid");
        });
    }
}
