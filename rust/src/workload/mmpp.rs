//! Markov-modulated Poisson process — a bursty arrival process used in the
//! scope/extension studies (paper §5.3 notes fidelity is validated under
//! Poisson; MMPP lets planners stress-test burstier-than-Poisson traffic,
//! as production traces like BurstGPT motivate).

use super::{lengths::LengthSampler, Request, Schedule};
use crate::util::rng::Rng;

/// Two-state MMPP: arrivals are Poisson with rate `rates[state]`, and the
/// hidden state switches with exponential holding times `1/switch[state]`.
#[derive(Debug, Clone, Copy)]
pub struct Mmpp {
    /// Arrival rate in each hidden state (req/s).
    pub rates: [f64; 2],
    /// State-leave rates (1/s): expected dwell time in state i is 1/switch[i].
    pub switch: [f64; 2],
}

impl Mmpp {
    /// A bursty profile around a target mean rate: a quiet state at
    /// 0.3×mean and a burst state at `burstiness`×mean, dwell times chosen
    /// so the long-run mean is `mean_rate`.
    pub fn bursty(mean_rate: f64, burstiness: f64) -> Mmpp {
        assert!(burstiness > 1.0);
        let lo = 0.3 * mean_rate;
        let hi = burstiness * mean_rate;
        // stationary weight on hi: w solves w*hi + (1-w)*lo = mean
        let w = (mean_rate - lo) / (hi - lo);
        // dwell: quiet 60 s, burst scaled by w/(1-w)
        let quiet_dwell = 60.0;
        let burst_dwell = quiet_dwell * w / (1.0 - w);
        Mmpp { rates: [lo, hi], switch: [1.0 / quiet_dwell, 1.0 / burst_dwell] }
    }

    /// Long-run mean arrival rate.
    pub fn mean_rate(&self) -> f64 {
        // stationary distribution ∝ 1/switch
        let d0 = 1.0 / self.switch[0];
        let d1 = 1.0 / self.switch[1];
        (self.rates[0] * d0 + self.rates[1] * d1) / (d0 + d1)
    }

    /// Generate arrivals over `[0, horizon_s)`.
    pub fn arrivals(&self, horizon_s: f64, lengths: &LengthSampler, rng: &mut Rng) -> Schedule {
        let mut out = Schedule::new();
        let mut t = 0.0f64;
        let mut state = if rng.f64() < 0.5 { 0 } else { 1 };
        let mut state_end = rng.exponential(self.switch[state]);
        loop {
            let rate = self.rates[state];
            let dt = if rate > 0.0 { rng.exponential(rate) } else { f64::INFINITY };
            if t + dt < state_end.min(horizon_s) {
                t += dt;
                let (n_in, n_out) = lengths.sample(rng);
                out.push(Request { arrival_s: t, n_in, n_out });
            } else {
                t = state_end;
                if t >= horizon_s {
                    break;
                }
                state = 1 - state;
                state_end = t + rng.exponential(self.switch[state]);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::validate;

    #[test]
    fn mean_rate_formula() {
        let m = Mmpp { rates: [1.0, 5.0], switch: [0.1, 0.1] };
        assert!((m.mean_rate() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn bursty_profile_hits_target_mean() {
        let m = Mmpp::bursty(1.0, 4.0);
        assert!((m.mean_rate() - 1.0).abs() < 1e-9, "mean {}", m.mean_rate());
        let lengths = LengthSampler::fixed(64, 64);
        let mut rng = Rng::new(21);
        let s = m.arrivals(100_000.0, &lengths, &mut rng);
        let rate = s.len() as f64 / 100_000.0;
        assert!((rate - 1.0).abs() < 0.1, "rate {rate}");
        validate(&s, 100_000.0).unwrap();
    }

    #[test]
    fn burstier_than_poisson() {
        // Count arrivals in 10 s bins; MMPP variance-to-mean should exceed 1.
        let m = Mmpp::bursty(2.0, 5.0);
        let lengths = LengthSampler::fixed(64, 64);
        let mut rng = Rng::new(22);
        let s = m.arrivals(50_000.0, &lengths, &mut rng);
        let mut bins = vec![0f64; 5000];
        for r in &s {
            bins[(r.arrival_s / 10.0) as usize] += 1.0;
        }
        let mean = bins.iter().sum::<f64>() / bins.len() as f64;
        let var = bins.iter().map(|b| (b - mean) * (b - mean)).sum::<f64>() / bins.len() as f64;
        assert!(var / mean > 1.5, "index of dispersion {}", var / mean);
    }
}
