//! Workload scenarios: request arrival processes and token-length
//! distributions (paper §3.1 "Workload scenario" and §3.4 "Cross-server
//! arrival structure").
//!
//! A scenario produces per-server [`Schedule`]s — lists of
//! `(arrival time, n_in, n_out)` requests — either independently per server
//! or by thinning a shared intensity so request streams are correlated
//! across the facility.
//!
//! Five arrival-process families are available, selected by the
//! `workload.kind` field of a scenario (or one axis entry of a sweep grid,
//! see [`crate::scenarios`]):
//!
//! | kind      | model                                    | module      |
//! |-----------|------------------------------------------|-------------|
//! | `poisson` | homogeneous Poisson at a fixed rate      | [`poisson`] |
//! | `mmpp`    | 2-state Markov-modulated Poisson bursts  | [`mmpp`]    |
//! | `diurnal` | Azure-like day/night intensity + bursts  | [`diurnal`] |
//! | `replay`  | replay a recorded schedule (JSON or CSV) | [`replay`]  |
//! | `token`   | token-level lengths + batching policy    | [`token`]   |
//!
//! All draws flow through the deterministic forked [`crate::util::rng::Rng`]
//! streams, so any schedule is reproducible from `(scenario seed, server
//! index)` alone.

pub mod diurnal;
pub mod lengths;
pub mod mmpp;
pub mod poisson;
pub mod replay;
pub mod token;

pub use diurnal::DiurnalProfile;
pub use lengths::LengthSampler;
pub use mmpp::Mmpp;
pub use poisson::poisson_arrivals;
pub use token::{token_arrivals, total_tokens, TokenLengthSampler, TokenLengths};

use crate::util::rng::Rng;

/// One inference request in an arrival schedule (paper §3.3:
/// `{(t_i, n_in_i, n_out_i)}`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Request {
    /// Arrival time in seconds from trace start.
    pub arrival_s: f64,
    /// Prompt length in tokens.
    pub n_in: u32,
    /// Output length in tokens.
    pub n_out: u32,
}

/// A time-sorted request schedule.
pub type Schedule = Vec<Request>;

/// Check a schedule is sorted, non-negative, and within the horizon.
pub fn validate(schedule: &Schedule, horizon_s: f64) -> Result<(), String> {
    let mut prev = 0.0f64;
    for (i, r) in schedule.iter().enumerate() {
        if r.arrival_s < prev {
            return Err(format!("request {i}: arrivals not sorted"));
        }
        if r.arrival_s >= horizon_s {
            return Err(format!("request {i}: arrival {} beyond horizon {horizon_s}", r.arrival_s));
        }
        if r.n_in == 0 || r.n_out == 0 {
            return Err(format!("request {i}: zero-length prompt or output"));
        }
        prev = r.arrival_s;
    }
    Ok(())
}

/// How request streams are distributed across servers (paper §3.4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrafficMode {
    /// Every server draws from its own independent arrival process.
    Independent,
    /// Servers share a common arrival-rate function; each receives an
    /// independently thinned stream (correlated load).
    SharedIntensity,
}

/// An arrival process that can emit per-server schedules.
pub trait ArrivalProcess {
    /// Generate the schedule for server `server_idx` over `[0, horizon_s)`.
    /// Implementations must honor [`TrafficMode`] semantics themselves.
    fn schedule(&self, server_idx: usize, horizon_s: f64, lengths: &LengthSampler, rng: &Rng) -> Schedule;
}

/// Inhomogeneous Poisson arrivals for an arbitrary rate function via
/// thinning (Lewis & Shedler). `rate_max` must bound `rate(t)`.
pub fn thinned_arrivals(
    rate: impl Fn(f64) -> f64,
    rate_max: f64,
    horizon_s: f64,
    lengths: &LengthSampler,
    rng: &mut Rng,
) -> Schedule {
    assert!(rate_max > 0.0, "thinned_arrivals: rate_max must be positive");
    let mut out = Schedule::new();
    let mut t = 0.0f64;
    loop {
        t += rng.exponential(rate_max);
        if t >= horizon_s {
            break;
        }
        let r = rate(t);
        debug_assert!(r <= rate_max * (1.0 + 1e-9), "rate exceeds bound at t={t}: {r} > {rate_max}");
        if rng.f64() * rate_max < r {
            let (n_in, n_out) = lengths.sample(rng);
            out.push(Request { arrival_s: t, n_in, n_out });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::check;

    fn test_lengths() -> LengthSampler {
        LengthSampler::fixed(128, 128)
    }

    #[test]
    fn validate_catches_issues() {
        let ok = vec![
            Request { arrival_s: 0.5, n_in: 10, n_out: 5 },
            Request { arrival_s: 1.0, n_in: 10, n_out: 5 },
        ];
        assert!(validate(&ok, 10.0).is_ok());
        let unsorted = vec![
            Request { arrival_s: 1.0, n_in: 10, n_out: 5 },
            Request { arrival_s: 0.5, n_in: 10, n_out: 5 },
        ];
        assert!(validate(&unsorted, 10.0).is_err());
        let beyond = vec![Request { arrival_s: 11.0, n_in: 10, n_out: 5 }];
        assert!(validate(&beyond, 10.0).is_err());
        let zero = vec![Request { arrival_s: 0.0, n_in: 0, n_out: 5 }];
        assert!(validate(&zero, 10.0).is_err());
    }

    #[test]
    fn thinning_matches_constant_rate() {
        let mut rng = Rng::new(1);
        let lengths = test_lengths();
        let sched = thinned_arrivals(|_| 2.0, 2.0, 10_000.0, &lengths, &mut rng);
        let rate = sched.len() as f64 / 10_000.0;
        assert!((rate - 2.0).abs() < 0.1, "rate {rate}");
        assert!(validate(&sched, 10_000.0).is_ok());
    }

    #[test]
    fn thinning_tracks_varying_rate() {
        let mut rng = Rng::new(2);
        let lengths = test_lengths();
        // rate 4 in first half, 1 in second half
        let sched =
            thinned_arrivals(|t| if t < 5000.0 { 4.0 } else { 1.0 }, 4.0, 10_000.0, &lengths, &mut rng);
        let first = sched.iter().filter(|r| r.arrival_s < 5000.0).count() as f64 / 5000.0;
        let second = sched.iter().filter(|r| r.arrival_s >= 5000.0).count() as f64 / 5000.0;
        assert!((first - 4.0).abs() < 0.2, "first {first}");
        assert!((second - 1.0).abs() < 0.1, "second {second}");
    }

    #[test]
    fn prop_thinned_schedules_always_valid() {
        check("thinned schedules valid", |rng| {
            let horizon = rng.range(10.0, 500.0);
            let peak = rng.range(0.1, 8.0);
            let lengths = LengthSampler::fixed(64, 64);
            let mut local = rng.clone();
            let sched = thinned_arrivals(
                |t| peak * (0.5 + 0.5 * (t * 0.01).sin().abs()),
                peak,
                horizon,
                &lengths,
                &mut local,
            );
            validate(&sched, horizon).expect("valid schedule");
        });
    }
}
