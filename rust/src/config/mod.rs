//! Planner-facing configuration (paper §3.1): facility topology, server
//! configuration, workload scenario, and site-level assumptions, with JSON
//! round-trip so scenarios are files a planner can version and share.

use crate::aggregate::Topology;
use crate::util::json::{self, Json};
use crate::workload::{TokenLengths, TrafficMode};
use anyhow::{bail, Context, Result};
#[cfg(feature = "host")]
use std::path::Path;

/// Workload scenario: the request arrival process driving every server.
#[derive(Debug, Clone, PartialEq)]
pub enum WorkloadSpec {
    /// Poisson arrivals at a fixed per-server rate (req/s).
    Poisson { rate: f64 },
    /// Bursty MMPP around a mean per-server rate.
    Mmpp { mean_rate: f64, burstiness: f64 },
    /// Diurnal Azure-like profile (paper §4.4).
    Diurnal {
        base_rate: f64,
        swing: f64,
        peak_hour: f64,
        burst_sigma: f64,
        mode: TrafficMode,
    },
    /// Replay a schedule from a JSON/CSV file (every server gets the same
    /// schedule shifted by a per-server random offset).
    Replay { path: String, offset_s: f64 },
    /// Token-level workload: Poisson arrivals with explicitly configured
    /// prompt/output length distributions and a batching policy
    /// (`max_batch` 0 ⇒ the campaign default; `token_budget` 0 ⇒ no
    /// budget). See [`crate::workload::token`].
    Token { rate: f64, lengths: TokenLengths, max_batch: usize, token_budget: u64 },
}

/// Dataset length profile selection.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioSpec {
    /// Which serving configuration every server runs (homogeneous), or
    /// per-rack assignments (heterogeneous fleets).
    pub server_config: ServerAssignment,
    pub topology: Topology,
    pub workload: WorkloadSpec,
    /// Length-profile dataset key from the catalog (e.g. "sharegpt").
    pub dataset: String,
    /// Trace horizon in seconds.
    pub horizon_s: f64,
    /// Per-server non-GPU IT power (W); paper default 1000.
    pub p_base_w: f64,
    /// Site PUE; paper default 1.3.
    pub pue: f64,
    /// RNG seed for the whole scenario.
    pub seed: u64,
}

/// Server-to-configuration mapping.
#[derive(Debug, Clone, PartialEq)]
pub enum ServerAssignment {
    /// All servers run the same configuration.
    Uniform(String),
    /// Per-rack configuration ids, cycled over racks (heterogeneous halls,
    /// paper §5.2 "mixed deployments").
    PerRack(Vec<String>),
}

impl ServerAssignment {
    /// Configuration id for a flat server index.
    pub fn config_for(&self, topo: &Topology, server_idx: usize) -> &str {
        match self {
            ServerAssignment::Uniform(id) => id,
            ServerAssignment::PerRack(ids) => {
                let rack = topo.rack_of(server_idx);
                &ids[rack % ids.len()]
            }
        }
    }

    /// Unique configuration ids referenced by this assignment, in first-use
    /// order (every id named, whether or not a given topology reaches it).
    pub fn config_ids(&self) -> Vec<String> {
        match self {
            ServerAssignment::Uniform(id) => vec![id.clone()],
            ServerAssignment::PerRack(ids) => {
                let mut out: Vec<String> = Vec::new();
                for id in ids {
                    if !out.contains(id) {
                        out.push(id.clone());
                    }
                }
                out
            }
        }
    }

    /// Unique configuration ids actually used on `topo`, in first-use
    /// order — a `PerRack` list longer than the rack count never reaches
    /// its tail, so only the reachable artifact set needs loading.
    pub fn config_ids_used(&self, topo: &Topology) -> Vec<String> {
        match self {
            ServerAssignment::Uniform(id) => vec![id.clone()],
            ServerAssignment::PerRack(ids) => {
                let mut out: Vec<String> = Vec::new();
                for rack in 0..topo.n_racks() {
                    let id = &ids[rack % ids.len()];
                    if !out.contains(id) {
                        out.push(id.clone());
                    }
                }
                out
            }
        }
    }

    /// JSON form: a string (uniform) or an array of strings (per-rack).
    pub fn to_json(&self) -> Json {
        match self {
            ServerAssignment::Uniform(id) => Json::Str(id.clone()),
            ServerAssignment::PerRack(ids) => {
                Json::Arr(ids.iter().map(|s| Json::Str(s.clone())).collect())
            }
        }
    }

    pub fn from_json(v: &Json) -> Result<ServerAssignment> {
        Ok(match v {
            Json::Str(s) => ServerAssignment::Uniform(s.clone()),
            Json::Arr(a) => {
                if a.is_empty() {
                    bail!("per-rack assignment must name at least one config");
                }
                ServerAssignment::PerRack(
                    a.iter().map(|x| x.as_str().map(String::from)).collect::<Result<_, _>>()?,
                )
            }
            _ => bail!("server_config must be a string or array of strings"),
        })
    }
}

/// Parse a `{"rows": .., "racks_per_row": .., "servers_per_rack": ..}`
/// object into a [`Topology`].
pub fn topology_from_json(v: &Json) -> Result<Topology> {
    let topo = Topology {
        rows: v.usize_field("rows")?,
        racks_per_row: v.usize_field("racks_per_row")?,
        servers_per_rack: v.usize_field("servers_per_rack")?,
    };
    if topo.n_servers() == 0 {
        bail!("topology has zero servers");
    }
    Ok(topo)
}

/// Serialize a [`Topology`] (inverse of [`topology_from_json`]).
pub fn topology_to_json(t: &Topology) -> Json {
    json::obj([
        ("rows", t.rows.into()),
        ("racks_per_row", t.racks_per_row.into()),
        ("servers_per_rack", t.servers_per_rack.into()),
    ])
}

impl WorkloadSpec {
    /// Short kind tag ("poisson" | "mmpp" | "diurnal" | "replay" | "token").
    pub fn kind(&self) -> &'static str {
        match self {
            WorkloadSpec::Poisson { .. } => "poisson",
            WorkloadSpec::Mmpp { .. } => "mmpp",
            WorkloadSpec::Diurnal { .. } => "diurnal",
            WorkloadSpec::Replay { .. } => "replay",
            WorkloadSpec::Token { .. } => "token",
        }
    }

    /// One-line human label for tables ("poisson λ=0.5" etc.).
    pub fn label(&self) -> String {
        match self {
            WorkloadSpec::Poisson { rate } => format!("poisson λ={rate}"),
            WorkloadSpec::Mmpp { mean_rate, burstiness } => {
                format!("mmpp λ̄={mean_rate} B={burstiness}")
            }
            WorkloadSpec::Diurnal { base_rate, swing, .. } => {
                format!("diurnal λ₀={base_rate} swing={swing}")
            }
            WorkloadSpec::Replay { path, .. } => format!("replay {path}"),
            WorkloadSpec::Token { rate, lengths, max_batch, token_budget } => {
                let mut s = format!("token λ={rate} {}", lengths.label());
                if *max_batch > 0 {
                    s.push_str(&format!(" b={max_batch}"));
                }
                if *token_budget > 0 {
                    s.push_str(&format!(" tb={token_budget}"));
                }
                s
            }
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            WorkloadSpec::Poisson { rate } => {
                json::obj([("kind", "poisson".into()), ("rate", (*rate).into())])
            }
            WorkloadSpec::Mmpp { mean_rate, burstiness } => json::obj([
                ("kind", "mmpp".into()),
                ("mean_rate", (*mean_rate).into()),
                ("burstiness", (*burstiness).into()),
            ]),
            WorkloadSpec::Diurnal { base_rate, swing, peak_hour, burst_sigma, mode } => json::obj([
                ("kind", "diurnal".into()),
                ("base_rate", (*base_rate).into()),
                ("swing", (*swing).into()),
                ("peak_hour", (*peak_hour).into()),
                ("burst_sigma", (*burst_sigma).into()),
                (
                    "mode",
                    match mode {
                        TrafficMode::Independent => "independent".into(),
                        TrafficMode::SharedIntensity => "shared".into(),
                    },
                ),
            ]),
            WorkloadSpec::Replay { path, offset_s } => json::obj([
                ("kind", "replay".into()),
                ("path", path.as_str().into()),
                ("offset_s", (*offset_s).into()),
            ]),
            WorkloadSpec::Token { rate, lengths, max_batch, token_budget } => json::obj([
                ("kind", "token".into()),
                ("rate", (*rate).into()),
                ("lengths", token_lengths_to_json(lengths)),
                ("max_batch", (*max_batch as f64).into()),
                ("token_budget", (*token_budget as f64).into()),
            ]),
        }
    }

    pub fn from_json(w: &Json) -> Result<WorkloadSpec> {
        Ok(match w.str_field("kind")?.as_str() {
            "poisson" => WorkloadSpec::Poisson { rate: w.f64_field("rate")? },
            "mmpp" => WorkloadSpec::Mmpp {
                mean_rate: w.f64_field("mean_rate")?,
                burstiness: w.f64_field("burstiness")?,
            },
            "diurnal" => WorkloadSpec::Diurnal {
                base_rate: w.f64_field("base_rate")?,
                swing: w.f64_field("swing")?,
                peak_hour: w.f64_field("peak_hour")?,
                burst_sigma: w.f64_field("burst_sigma")?,
                mode: match w.str_field("mode")?.as_str() {
                    "independent" => TrafficMode::Independent,
                    "shared" => TrafficMode::SharedIntensity,
                    other => bail!("unknown traffic mode '{other}'"),
                },
            },
            "replay" => WorkloadSpec::Replay {
                path: w.str_field("path")?,
                offset_s: w.f64_field("offset_s").unwrap_or(0.0),
            },
            "token" => {
                let lengths = token_lengths_from_json(w.get("lengths")?)?;
                lengths.validate().map_err(|e| anyhow::anyhow!(e))?;
                WorkloadSpec::Token {
                    rate: w.f64_field("rate")?,
                    lengths,
                    max_batch: w.f64_field("max_batch").unwrap_or(0.0) as usize,
                    token_budget: w.f64_field("token_budget").unwrap_or(0.0) as u64,
                }
            }
            other => bail!("unknown workload kind '{other}'"),
        })
    }
}

/// JSON for a token-length distribution, tagged by `dist`.
fn token_lengths_to_json(l: &TokenLengths) -> Json {
    match l {
        TokenLengths::Lognormal { in_median, in_sigma, out_median, out_sigma } => json::obj([
            ("dist", "lognormal".into()),
            ("in_median", (*in_median).into()),
            ("in_sigma", (*in_sigma).into()),
            ("out_median", (*out_median).into()),
            ("out_sigma", (*out_sigma).into()),
        ]),
        TokenLengths::Pareto { in_min, in_alpha, out_min, out_alpha } => json::obj([
            ("dist", "pareto".into()),
            ("in_min", (*in_min).into()),
            ("in_alpha", (*in_alpha).into()),
            ("out_min", (*out_min).into()),
            ("out_alpha", (*out_alpha).into()),
        ]),
        TokenLengths::Fixed { n_in, n_out } => json::obj([
            ("dist", "fixed".into()),
            ("n_in", (*n_in as f64).into()),
            ("n_out", (*n_out as f64).into()),
        ]),
        TokenLengths::Empirical { path } => {
            json::obj([("dist", "empirical".into()), ("path", path.as_str().into())])
        }
    }
}

fn token_lengths_from_json(v: &Json) -> Result<TokenLengths> {
    Ok(match v.str_field("dist")?.as_str() {
        "lognormal" => TokenLengths::Lognormal {
            in_median: v.f64_field("in_median")?,
            in_sigma: v.f64_field("in_sigma")?,
            out_median: v.f64_field("out_median")?,
            out_sigma: v.f64_field("out_sigma")?,
        },
        "pareto" => TokenLengths::Pareto {
            in_min: v.f64_field("in_min")?,
            in_alpha: v.f64_field("in_alpha")?,
            out_min: v.f64_field("out_min")?,
            out_alpha: v.f64_field("out_alpha")?,
        },
        "fixed" => TokenLengths::Fixed {
            n_in: v.f64_field("n_in")? as u32,
            n_out: v.f64_field("n_out")? as u32,
        },
        "empirical" => TokenLengths::Empirical { path: v.str_field("path")? },
        other => bail!("unknown token length distribution '{other}'"),
    })
}

impl ScenarioSpec {
    /// A small default scenario (quickstart).
    pub fn default_poisson(config_id: &str, rate: f64) -> ScenarioSpec {
        ScenarioSpec {
            server_config: ServerAssignment::Uniform(config_id.to_string()),
            topology: Topology { rows: 1, racks_per_row: 1, servers_per_rack: 1 },
            workload: WorkloadSpec::Poisson { rate },
            dataset: "sharegpt".to_string(),
            horizon_s: 600.0,
            p_base_w: 1000.0,
            pue: 1.3,
            seed: 0,
        }
    }

    pub fn to_json(&self) -> Json {
        json::obj([
            ("server_config", self.server_config.to_json()),
            ("topology", topology_to_json(&self.topology)),
            ("workload", self.workload.to_json()),
            ("dataset", self.dataset.as_str().into()),
            ("horizon_s", self.horizon_s.into()),
            ("p_base_w", self.p_base_w.into()),
            ("pue", self.pue.into()),
            ("seed", self.seed.into()),
        ])
    }

    pub fn from_json(v: &Json) -> Result<ScenarioSpec> {
        let spec = ScenarioSpec {
            server_config: ServerAssignment::from_json(v.get("server_config")?)?,
            topology: topology_from_json(v.get("topology")?)?,
            workload: WorkloadSpec::from_json(v.get("workload")?)?,
            dataset: v.str_field("dataset")?,
            horizon_s: v.f64_field("horizon_s")?,
            p_base_w: v.f64_field("p_base_w")?,
            pue: v.f64_field("pue")?,
            seed: v.f64_field("seed")? as u64,
        };
        if spec.horizon_s <= 0.0 {
            bail!("horizon_s must be positive");
        }
        if spec.pue < 1.0 {
            bail!("pue must be >= 1.0 (got {})", spec.pue);
        }
        Ok(spec)
    }

    #[cfg(feature = "host")]
    pub fn load(path: &Path) -> Result<ScenarioSpec> {
        let v = json::parse_file(path).map_err(anyhow::Error::from)?;
        Self::from_json(&v).with_context(|| format!("parsing scenario {}", path.display()))
    }

    #[cfg(feature = "host")]
    pub fn save(&self, path: &Path) -> Result<()> {
        json::write_file(path, &self.to_json()).map_err(anyhow::Error::from)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_workload_kinds() {
        let mut spec = ScenarioSpec::default_poisson("llama70b_a100_tp8", 0.5);
        for wl in [
            WorkloadSpec::Poisson { rate: 1.5 },
            WorkloadSpec::Mmpp { mean_rate: 0.7, burstiness: 4.0 },
            WorkloadSpec::Diurnal {
                base_rate: 0.5,
                swing: 0.6,
                peak_hour: 15.0,
                burst_sigma: 0.3,
                mode: TrafficMode::SharedIntensity,
            },
            WorkloadSpec::Replay { path: "trace.json".into(), offset_s: 30.0 },
            WorkloadSpec::Token {
                rate: 0.8,
                lengths: TokenLengths::Lognormal {
                    in_median: 512.0,
                    in_sigma: 0.9,
                    out_median: 128.0,
                    out_sigma: 0.7,
                },
                max_batch: 16,
                token_budget: 8192,
            },
            WorkloadSpec::Token {
                rate: 1.2,
                lengths: TokenLengths::Pareto {
                    in_min: 32.0,
                    in_alpha: 1.8,
                    out_min: 16.0,
                    out_alpha: 2.2,
                },
                max_batch: 0,
                token_budget: 0,
            },
            WorkloadSpec::Token {
                rate: 2.0,
                lengths: TokenLengths::Fixed { n_in: 256, n_out: 64 },
                max_batch: 8,
                token_budget: 0,
            },
            WorkloadSpec::Token {
                rate: 0.25,
                lengths: TokenLengths::Empirical { path: "data/traces/sample_requests.csv".into() },
                max_batch: 0,
                token_budget: 4096,
            },
        ] {
            spec.workload = wl.clone();
            let back = ScenarioSpec::from_json(&spec.to_json()).unwrap();
            assert_eq!(back, spec);
        }
    }

    #[test]
    fn roundtrip_per_rack_assignment() {
        let mut spec = ScenarioSpec::default_poisson("x", 1.0);
        spec.server_config =
            ServerAssignment::PerRack(vec!["a".into(), "b".into(), "c".into()]);
        spec.topology = Topology { rows: 2, racks_per_row: 3, servers_per_rack: 2 };
        let back = ScenarioSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(back, spec);
    }

    #[test]
    fn per_rack_assignment_cycles() {
        let topo = Topology { rows: 1, racks_per_row: 4, servers_per_rack: 2 };
        let a = ServerAssignment::PerRack(vec!["x".into(), "y".into()]);
        assert_eq!(a.config_for(&topo, 0), "x"); // rack 0
        assert_eq!(a.config_for(&topo, 2), "y"); // rack 1
        assert_eq!(a.config_for(&topo, 4), "x"); // rack 2 cycles
        let u = ServerAssignment::Uniform("z".into());
        assert_eq!(u.config_for(&topo, 5), "z");
    }

    #[test]
    fn config_ids_used_truncates_to_reachable_racks() {
        let topo = Topology { rows: 1, racks_per_row: 2, servers_per_rack: 2 };
        let a = ServerAssignment::PerRack(vec!["x".into(), "y".into(), "z".into()]);
        // Only racks 0 and 1 exist: "z" is never reachable.
        assert_eq!(a.config_ids_used(&topo), vec!["x".to_string(), "y".to_string()]);
        // The full referenced set still lists it.
        assert_eq!(a.config_ids(), vec!["x".to_string(), "y".to_string(), "z".to_string()]);
        // A short list cycles without duplicates.
        let b = ServerAssignment::PerRack(vec!["x".into()]);
        assert_eq!(b.config_ids_used(&topo), vec!["x".to_string()]);
        let u = ServerAssignment::Uniform("u".into());
        assert_eq!(u.config_ids_used(&topo), vec!["u".to_string()]);
    }

    #[test]
    fn validation_rejects_bad_specs() {
        let spec = ScenarioSpec::default_poisson("c", 1.0);
        let mut j = spec.to_json();
        if let Json::Obj(o) = &mut j {
            o.insert("pue".into(), Json::Num(0.5));
        }
        assert!(ScenarioSpec::from_json(&j).is_err());

        let mut j = spec.to_json();
        if let Json::Obj(o) = &mut j {
            o.insert("horizon_s".into(), Json::Num(-1.0));
        }
        assert!(ScenarioSpec::from_json(&j).is_err());
    }

    #[cfg(feature = "host")]
    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("powertrace_test_config");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("scenario.json");
        let spec = ScenarioSpec::default_poisson("llama8b_a100_tp2", 0.25);
        spec.save(&p).unwrap();
        assert_eq!(ScenarioSpec::load(&p).unwrap(), spec);
    }
}
