//! Deterministic shard partitioning for cluster-scale sweeps.
//!
//! A [`Shard`] names one slice of a sweep's cell list: shard `i/N` owns
//! exactly the cells whose stable id hashes to `i` modulo `N` under
//! [`fnv1a64`]. Ownership depends only on the cell id — never on grid
//! order, worker counts, or which process asks — so N processes (or
//! machines) given shards `0/N .. N-1/N` partition any grid exactly, with
//! no coordination and no overlap, and `powertrace merge` can reassemble
//! their partial summaries into the bytes an unsharded run would have
//! written.
//!
//! Sharding is an *execution-layout* knob, like worker counts: it is
//! recorded in run manifests (`--resume` re-runs the same slice by
//! default) but excluded from the manifest identity hash, so every shard
//! of a grid — and the merged result — shares one content hash.

use anyhow::{bail, Result};
use std::fmt;

/// FNV-1a 64-bit over raw bytes. This is the crate's stable id hash: cell
/// ownership ([`Shard::owns`]) and the manifest content hash
/// (`robust::manifest::content_hash`) both ride on it, so its constants
/// are part of the on-disk and cross-process contract.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut hash: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        hash ^= b as u64;
        hash = hash.wrapping_mul(0x0000_0100_0000_01b3);
    }
    hash
}

/// One slice of a deterministic cell partition: shard `index` of `count`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Shard {
    /// Which slice this process runs (`0 ..= count - 1`).
    pub index: usize,
    /// Total number of slices the grid is split into (≥ 1).
    pub count: usize,
}

impl Shard {
    pub fn new(index: usize, count: usize) -> Result<Shard> {
        if count == 0 {
            bail!("shard: count must be >= 1 (got {index}/{count})");
        }
        if index >= count {
            bail!("shard: index must be < count (got {index}/{count})");
        }
        Ok(Shard { index, count })
    }

    /// Parse the CLI / wire form `"i/N"` (e.g. `"0/3"`).
    pub fn parse(s: &str) -> Result<Shard> {
        let Some((i, n)) = s.split_once('/') else {
            bail!("shard: expected 'i/N' (e.g. '0/3'), got '{s}'");
        };
        let index: usize =
            i.trim().parse().map_err(|_| anyhow::anyhow!("shard: bad index in '{s}'"))?;
        let count: usize =
            n.trim().parse().map_err(|_| anyhow::anyhow!("shard: bad count in '{s}'"))?;
        Shard::new(index, count)
    }

    /// Does this shard own the cell with stable id `id`? Every id is owned
    /// by exactly one shard of any `count`-way partition, and `0/1` owns
    /// everything.
    pub fn owns(&self, id: &str) -> bool {
        fnv1a64(id.as_bytes()) % self.count as u64 == self.index as u64
    }

    /// `true` for the trivial whole-grid shard `0/1`.
    pub fn is_whole(&self) -> bool {
        self.count == 1
    }
}

impl fmt::Display for Shard {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}/{}", self.index, self.count)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a64_is_pinned() {
        // The FNV-1a reference vectors: these constants are a cross-process
        // contract (shard ownership + manifest content hashes).
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"w0-t0-f0-s3"), fnv1a64(b"w0-t0-f0-s3"));
        assert_ne!(fnv1a64(b"w0-t0-f0-s3"), fnv1a64(b"w0-t0-f0-s4"));
    }

    #[test]
    fn parse_and_display_roundtrip() {
        for s in ["0/1", "0/3", "2/3", "11/12"] {
            let sh = Shard::parse(s).unwrap();
            assert_eq!(sh.to_string(), s);
        }
        assert_eq!(Shard::parse(" 1 / 4 ").unwrap(), Shard { index: 1, count: 4 });
        assert!(Shard::parse("3").is_err());
        assert!(Shard::parse("a/3").is_err());
        assert!(Shard::parse("1/x").is_err());
        assert!(Shard::parse("3/3").is_err(), "index must be < count");
        assert!(Shard::parse("0/0").is_err(), "count must be >= 1");
        assert!(Shard::new(2, 2).is_err());
    }

    #[test]
    fn every_id_is_owned_by_exactly_one_shard() {
        let ids: Vec<String> = (0..64)
            .flat_map(|w| (0..3).map(move |s| format!("w{w}-t0-f1-s{s}")))
            .collect();
        for count in [1usize, 2, 3, 5, 8] {
            let shards: Vec<Shard> = (0..count).map(|i| Shard::new(i, count).unwrap()).collect();
            for id in &ids {
                let owners = shards.iter().filter(|s| s.owns(id)).count();
                assert_eq!(owners, 1, "id {id} owned by {owners} shards of {count}");
            }
        }
        // 0/1 owns everything.
        let whole = Shard::new(0, 1).unwrap();
        assert!(whole.is_whole());
        assert!(ids.iter().all(|id| whole.owns(id)));
    }

    #[test]
    fn ownership_is_id_stable_not_order_dependent() {
        let shard = Shard::parse("1/3").unwrap();
        let a = shard.owns("p0-s7");
        // Same id, asked again or in any order: same answer.
        assert_eq!(shard.owns("p0-s7"), a);
    }
}
