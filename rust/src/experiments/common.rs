//! Shared experiment machinery: the evaluation context (generator +
//! classifier cache), synthesis against held-out measured traces, the
//! baseline traces, and CSV output.

use crate::artifacts::{ConfigArtifact, MeasuredTrace};
use crate::baselines::{lut::LutBaseline, mean_trace, tdp_gpu_trace};
use crate::classifier::pjrt::AnyClassifier;
use crate::coordinator::Generator;
use crate::surrogate::{features_from_intervals, simulate_queue, ActiveInterval};
use crate::synth::{sample_power, sample_states};
use crate::util::cli::Args;
use crate::util::rng::Rng;
use anyhow::Result;
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;

/// Evaluation context for experiments.
pub struct EvalCtx {
    pub gen: Generator,
    classifiers: BTreeMap<String, Arc<AnyClassifier>>,
    /// Seeds per synthetic replication (paper: 5; `--fast` uses 2).
    pub n_seeds: usize,
    pub out_dir: PathBuf,
}

impl EvalCtx {
    pub fn new(args: &Args) -> Result<EvalCtx> {
        let backend = args.str_or("backend", "pjrt");
        let gen = match Generator::with_backend(&backend) {
            Ok(g) => g,
            Err(e) if backend == "pjrt" => {
                eprintln!("note: pjrt backend unavailable ({e:#}); falling back to native");
                Generator::native()?
            }
            Err(e) => return Err(e),
        };
        let n_seeds = if args.has("fast") { 2 } else { 5 };
        let out_dir = crate::catalog::Catalog::repo_root().join("out");
        Ok(EvalCtx { gen, classifiers: BTreeMap::new(), n_seeds, out_dir })
    }

    pub fn config(&mut self, id: &str) -> Result<Arc<ConfigArtifact>> {
        self.gen.config(id)
    }

    pub fn classifier(&mut self, id: &str) -> Result<Arc<AnyClassifier>> {
        if let Some(c) = self.classifiers.get(id) {
            return Ok(c.clone());
        }
        let art = self.gen.config(id)?;
        let c = Arc::new(self.gen.classifier(&art)?);
        self.classifiers.insert(id.to_string(), c.clone());
        Ok(c)
    }

    /// Artifact config ids, optionally filtered by model key prefix.
    pub fn config_ids(&self) -> Vec<String> {
        self.gen.store.manifest.configs.clone()
    }

    /// Surrogate intervals for a measured trace's schedule.
    pub fn intervals_for(
        &self,
        art: &ConfigArtifact,
        m: &MeasuredTrace,
        rng: &mut Rng,
    ) -> Vec<ActiveInterval> {
        simulate_queue(&m.schedule, &art.surrogate, self.gen.cat.campaign.max_batch, rng)
    }

    /// Full pipeline synthesis matched to a measured trace (same schedule,
    /// same horizon) — the paper's held-out evaluation setup.
    pub fn synth_like(
        &self,
        art: &ConfigArtifact,
        cls: &AnyClassifier,
        m: &MeasuredTrace,
        seed: u64,
    ) -> Result<Vec<f32>> {
        let n_steps = m.power_w.len();
        let mut rng = Rng::new(seed).fork(0x51D);
        let intervals = self.intervals_for(art, m, &mut rng);
        let feats = features_from_intervals(&intervals, n_steps, m.dt_s);
        let probs = crate::classifier::StateClassifier::probs(cls, &feats.interleaved(), n_steps)?;
        let k_max = crate::classifier::StateClassifier::k_max(cls);
        let k = art.k;
        let mut live = vec![0.0f32; n_steps * k];
        for t in 0..n_steps {
            live[t * k..(t + 1) * k].copy_from_slice(&probs[t * k_max..t * k_max + k]);
        }
        let states = sample_states(&live, k, &mut rng);
        Ok(sample_power(&states, &art.dict, art.mode, &mut rng))
    }

    /// LUT baseline trace matched to a measured trace.
    pub fn lut_like(&self, art: &ConfigArtifact, m: &MeasuredTrace, seed: u64) -> Result<Vec<f32>> {
        let cfg = self.gen.cat.config(&art.config_id)?;
        let mut rng = Rng::new(seed).fork(0x107);
        let intervals = self.intervals_for(art, m, &mut rng);
        Ok(LutBaseline::default().trace(&self.gen.cat, cfg, &intervals, m.power_w.len(), m.dt_s))
    }

    /// TDP baseline (GPU-only, matching measured server GPU power).
    pub fn tdp_like(&self, art: &ConfigArtifact, m: &MeasuredTrace) -> Result<Vec<f32>> {
        let cfg = self.gen.cat.config(&art.config_id)?;
        Ok(tdp_gpu_trace(&self.gen.cat, cfg, m.power_w.len()))
    }

    /// Mean-power baseline (training-set mean).
    pub fn mean_like(&self, art: &ConfigArtifact, m: &MeasuredTrace) -> Vec<f32> {
        mean_trace(art.train_mean_w, m.power_w.len())
    }

    /// Write columns as CSV under `out/<exp>/<name>.csv`.
    pub fn write_csv(&self, exp: &str, name: &str, headers: &[&str], cols: &[&[f32]]) -> Result<()> {
        assert_eq!(headers.len(), cols.len());
        let dir = self.out_dir.join(exp);
        let n = cols.iter().map(|c| c.len()).max().unwrap_or(0);
        let mut s = String::new();
        s.push_str(&headers.join(","));
        s.push('\n');
        for i in 0..n {
            for (j, c) in cols.iter().enumerate() {
                if j > 0 {
                    s.push(',');
                }
                if i < c.len() {
                    s.push_str(&format!("{}", c[i]));
                }
            }
            s.push('\n');
        }
        let path = dir.join(format!("{name}.csv"));
        // Atomic like every other durable export: stage + rename, parents
        // created by the helper.
        crate::robust::fsx::atomic_write(&path, s.as_bytes())?;
        println!("  wrote {}", path.display());
        Ok(())
    }
}

/// ACF comparison lag bound: 60 s of 250 ms samples (paper preserves
/// sub-minute temporal structure).
pub const ACF_MAX_LAG: usize = 240;

/// Pearson correlation between two equal-length series.
pub fn pearson(a: &[f32], b: &[f32]) -> f64 {
    let n = a.len().min(b.len()) as f64;
    let ma = a.iter().map(|&x| x as f64).sum::<f64>() / n;
    let mb = b.iter().map(|&x| x as f64).sum::<f64>() / n;
    let (mut cov, mut va, mut vb) = (0.0, 0.0, 0.0);
    for (&x, &y) in a.iter().zip(b.iter()) {
        cov += (x as f64 - ma) * (y as f64 - mb);
        va += (x as f64 - ma).powi(2);
        vb += (y as f64 - mb).powi(2);
    }
    cov / (va.sqrt() * vb.sqrt()).max(1e-12)
}

/// Format "a ± b" with given precision.
pub fn pm(mean: f64, std: f64, prec: usize) -> String {
    format!("{mean:.prec$} ± {std:.prec$}")
}
