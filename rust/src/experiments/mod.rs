//! Experiment harness: one module per paper table/figure (DESIGN.md §5).
//!
//! Every experiment prints the paper's rows/series to stdout and writes
//! machine-readable CSV/JSON under `out/<experiment>/`. Run via
//! `powertrace repro <id>` or the corresponding bench target.

pub mod common;
pub mod facility;
pub mod figs;
pub mod oversub;
pub mod table1;
pub mod table2;

use crate::util::cli::Args;
use anyhow::{bail, Result};

/// All experiment ids, in paper order.
pub const ALL: &[&str] = &[
    "fig1", "fig3", "fig4", "fig5", "table1", "table2", "fig6", "fig7", "fig8",
    "fig9", "table3", "fig10", "fig11", "fig12", "fig13",
];

/// Run one experiment (or "all").
pub fn run(id: &str, args: &Args) -> Result<()> {
    match id {
        "table1" => table1::run(args),
        "table2" => table2::run(args),
        "fig1" => figs::fig1(args),
        "fig3" => figs::fig3(args),
        "fig4" => figs::fig4(args),
        "fig5" => figs::fig5(args),
        "fig6" => figs::fig6(args),
        "fig7" => figs::fig7(args),
        "fig8" => figs::fig8(args),
        "fig13" => figs::fig13(args),
        // The 24-hour facility study powers Fig 9, Table 3, Fig 10 and
        // Fig 12 from a single generation run.
        "fig9" | "table3" | "fig10" | "fig12" | "facility" => facility::run(args),
        "fig11" | "oversub" => oversub::run(args),
        "all" => {
            let mut done = std::collections::BTreeSet::new();
            for id in ALL {
                // facility ids share one run; only execute once
                let canonical = match *id {
                    "fig9" | "table3" | "fig10" | "fig12" => "facility",
                    other => other,
                };
                if done.insert(canonical) {
                    println!("\n################ {id} ################");
                    run(canonical, args)?;
                }
            }
            Ok(())
        }
        other => bail!("unknown experiment '{other}' (try: {}, all)", ALL.join(", ")),
    }
}
