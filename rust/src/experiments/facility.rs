//! The production-workload facility study (paper §4.4–4.5): a data hall
//! driven by the diurnal Azure-like trace. One generation run produces:
//!
//! * **Fig 9** — 24-hour facility profile at 15-min resolution + arrival rate;
//! * **Table 3** — interconnection sizing (peak / avg / PAR / ramp / load
//!   factor) for TDP, Mean, LUT-based, and Ours;
//! * **Fig 10** — per-rack power over the 4-hour peak window;
//! * **Fig 12** — server/rack/row/site series and the CoV cascade.
//!
//! Defaults are scaled to the single-core testbed (60 servers, dt = 1 s);
//! `--servers 240 --dt 0.25` reproduces the paper's full scale.

use super::common::EvalCtx;
use crate::aggregate::{resample, FacilityAccumulator, Topology};
use crate::baselines::lut::LutBaseline;
use crate::config::{ScenarioSpec, ServerAssignment, WorkloadSpec};
use crate::metrics::{coefficient_of_variation, PlanningStats};
use crate::surrogate::simulate_queue;
use crate::util::cli::Args;
use crate::util::rng::Rng;
use crate::workload::{DiurnalProfile, TrafficMode};
use anyhow::Result;

pub struct Study {
    pub dt_s: f64,
    pub pue: f64,
    pub ours: FacilityAccumulator,
    pub lut: FacilityAccumulator,
    pub server0: Vec<f32>,
    pub tdp_w_site: f64,
    pub mean_w_site: f64,
    pub arrival_rate: Vec<f32>,
    pub topo: Topology,
}

pub fn generate(ctx: &mut EvalCtx, args: &Args) -> Result<Study> {
    let ids = ctx.config_ids();
    let id = if ids.iter().any(|i| i == "llama70b_a100_tp8") {
        "llama70b_a100_tp8".to_string()
    } else {
        ids[0].clone()
    };
    let art = ctx.config(&id)?;
    let cls = ctx.classifier(&id)?;
    let cfg = ctx.gen.cat.config(&id)?.clone();

    let n_servers = args.usize_or("servers", if args.has("fast") { 24 } else { 60 })?;
    let horizon_h = args.f64_or("horizon-h", if args.has("fast") { 6.0 } else { 24.0 })?;
    let dt = args.f64_or("dt", 1.0)?;
    let horizon = horizon_h * 3600.0;
    let servers_per_rack = 4;
    let racks_per_row = 6;
    let rows = (n_servers + servers_per_rack * racks_per_row - 1) / (servers_per_rack * racks_per_row);
    let topo = Topology { rows: rows.max(1), racks_per_row, servers_per_rack };
    let n_servers = topo.n_servers();

    let profile = DiurnalProfile::default();
    let mut spec = ScenarioSpec::default_poisson(&id, profile.base_rate);
    spec.topology = topo;
    spec.horizon_s = horizon;
    spec.server_config = ServerAssignment::Uniform(id.clone());
    spec.workload = WorkloadSpec::Diurnal {
        base_rate: profile.base_rate,
        swing: profile.swing,
        peak_hour: profile.peak_hour,
        burst_sigma: profile.burst_sigma,
        mode: TrafficMode::Independent,
    };
    let n_steps = (horizon / dt).round() as usize;
    let base_rng = Rng::new(args.u64_or("seed", 9)?);

    println!(
        "generating facility run: {n_servers} servers ({id}), {horizon_h} h at dt={dt}s \
         (use --servers 240 --dt 0.25 for the paper's full scale)"
    );
    let mut ours = FacilityAccumulator::new(topo, n_steps, spec.p_base_w);
    let mut lut = FacilityAccumulator::new(topo, n_steps, spec.p_base_w);
    let mut server0 = Vec::new();
    let mut arrivals_per_bin = vec![0f32; (horizon / 300.0).ceil() as usize];
    let t0 = std::time::Instant::now();
    for s in 0..n_servers {
        let sched = ctx.gen.schedule_for(&spec, s, &base_rng)?;
        for r in &sched {
            let b = (r.arrival_s / 300.0) as usize;
            if b < arrivals_per_bin.len() {
                arrivals_per_bin[b] += 1.0;
            }
        }
        let mut rng = base_rng.fork(0xFAC ^ s as u64);
        let tr = ctx.gen.server_trace(&art, &cls, &sched, horizon, dt, &mut rng)?;
        if s == 0 {
            server0 = tr.power_w.clone();
        }
        ours.add_server(s, &tr.power_w)?;
        let intervals =
            simulate_queue(&sched, &art.surrogate, ctx.gen.cat.campaign.max_batch, &mut rng);
        let l = LutBaseline::default().trace(&ctx.gen.cat, &cfg, &intervals, n_steps, dt);
        lut.add_server(s, &l)?;
        if (s + 1) % 20 == 0 {
            println!("  {}/{} servers ({:.1}s)", s + 1, n_servers, t0.elapsed().as_secs_f32());
        }
    }
    // arrivals per 5-min bin → req/s across the facility
    for a in arrivals_per_bin.iter_mut() {
        *a /= 300.0;
    }
    let pue = spec.pue;
    Ok(Study {
        dt_s: dt,
        pue,
        tdp_w_site: ctx.gen.cat.server_nameplate_w(&cfg) * n_servers as f64 * pue,
        mean_w_site: (art.train_mean_w + spec.p_base_w) * n_servers as f64 * pue,
        ours,
        lut,
        server0,
        arrival_rate: arrivals_per_bin,
        topo,
    })
}

pub fn run(args: &Args) -> Result<()> {
    let mut ctx = EvalCtx::new(args)?;
    let study = generate(&mut ctx, args)?;
    let dt = study.dt_s;
    let pue = study.pue;

    // ---- Fig 9: 15-min site profile + 5-min arrival rate ----
    let site = study.ours.facility_series(pue);
    let site_15m = resample(&site, dt, 900.0)?;
    println!("\nFig 9 — 24 h facility profile ({} servers, PUE {pue})", study.topo.n_servers());
    let st = PlanningStats::compute(&site, dt, 900.0)?;
    println!("  site peak {:.2} MW, avg {:.2} MW (15-min series has {} points)",
        st.peak_w / 1e6, st.avg_w / 1e6, site_15m.len());
    ctx.write_csv("fig9", "site_15min", &["site_mw"], &[&site_15m.iter().map(|&x| x / 1e6).collect::<Vec<f32>>()])?;
    ctx.write_csv("fig9", "arrival_rate_5min", &["req_per_s"], &[&study.arrival_rate])?;

    // ---- Table 3: interconnection sizing ----
    let lut_site = study.lut.facility_series(pue);
    let methods: Vec<(&str, Vec<f32>)> = vec![
        ("TDP", vec![study.tdp_w_site as f32; site.len()]),
        ("Mean", vec![study.mean_w_site as f32; site.len()]),
        ("LUT-Based", lut_site.clone()),
        ("Ours", site.clone()),
    ];
    println!("\nTable 3 — infrastructure sizing from the facility simulation");
    println!(
        "{:<26} {:>8} {:>8} {:>10} {:>8}",
        "Metric", "TDP", "Mean", "LUT-Based", "Ours"
    );
    let stats: Vec<PlanningStats> =
        methods.iter().map(|(_, s)| PlanningStats::compute(s, dt, 900.0)).collect::<Result<Vec<_>>>()?;
    let row = |name: &str, f: &dyn Fn(&PlanningStats) -> f64, prec: usize| {
        println!(
            "{:<26} {:>8.prec$} {:>8.prec$} {:>10.prec$} {:>8.prec$}",
            name,
            f(&stats[0]),
            f(&stats[1]),
            f(&stats[2]),
            f(&stats[3]),
        );
    };
    row("Peak facility power (MW)", &|s| s.peak_w / 1e6, 2);
    row("Average facility power (MW)", &|s| s.avg_w / 1e6, 2);
    row("Peak-to-average ratio", &|s| s.peak_to_average, 2);
    row("Max ramp (MW/15-min)", &|s| s.max_ramp_w / 1e6, 3);
    row("Load factor", &|s| s.load_factor, 2);
    println!(
        "\nshape check: TDP > LUT/Mean > Ours peak; only trace methods show ramps \
         (paper: 1.19 / 0.82 / 0.75 MW peaks; ramp 0 / 0.07 / 0.11 MW)"
    );

    // ---- Fig 10: per-rack heatmap over the 4-hour peak window ----
    let peak_idx = site
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0);
    let window = (4.0 * 3600.0 / dt) as usize;
    let start = peak_idx.saturating_sub(window / 2).min(site.len().saturating_sub(window));
    let mut rack_cols: Vec<Vec<f32>> = Vec::new();
    for r in 0..study.topo.n_racks() {
        let series = study.ours.rack_series(r);
        let slice = &series[start..(start + window).min(series.len())];
        rack_cols.push(resample(slice, dt, 300.0)?.iter().map(|&x| x / 1e3).collect());
    }
    let refs: Vec<&[f32]> = rack_cols.iter().map(|c| c.as_slice()).collect();
    let headers: Vec<String> = (0..rack_cols.len()).map(|r| format!("rack{r}_kw")).collect();
    let headers_ref: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    ctx.write_csv("fig10", "rack_heatmap_5min", &headers_ref, &refs)?;
    // Decorrelation check: mean pairwise correlation of rack series.
    let mut corrs = Vec::new();
    for i in 0..rack_cols.len() {
        for j in (i + 1)..rack_cols.len() {
            corrs.push(super::common::pearson(&rack_cols[i], &rack_cols[j]));
        }
    }
    let mean_corr = corrs.iter().sum::<f64>() / corrs.len().max(1) as f64;
    println!("\nFig 10 — per-rack peak-window heatmap: mean pairwise rack correlation {mean_corr:.2}");

    // ---- Fig 12: hierarchy smoothing ----
    let server = &study.server0;
    let rack0 = study.ours.rack_series(0);
    let row0 = study.ours.row_series(0);
    let cov_server = coefficient_of_variation(server)?;
    let cov_rack = coefficient_of_variation(&rack0)?;
    let cov_row = coefficient_of_variation(&row0)?;
    let cov_site = coefficient_of_variation(&site)?;
    println!("\nFig 12 — aggregation across the hierarchy (CoV cascade)");
    println!(
        "  CoV: server {cov_server:.3} → rack {cov_rack:.3} → row {cov_row:.3} → site {cov_site:.3} \
         (paper: 0.583 → … → 0.127)"
    );
    anyhow::ensure!(cov_site < cov_server, "aggregation must smooth variability");
    ctx.write_csv(
        "fig12",
        "hierarchy_15min",
        &["server_kw", "rack_kw", "row_kw", "site_kw"],
        &[
            &resample(server, dt, 900.0)?.iter().map(|&x| x / 1e3).collect::<Vec<f32>>(),
            &resample(&rack0, dt, 900.0)?.iter().map(|&x| x / 1e3).collect::<Vec<f32>>(),
            &resample(&row0, dt, 900.0)?.iter().map(|&x| x / 1e3).collect::<Vec<f32>>(),
            &resample(&site, dt, 900.0)?.iter().map(|&x| x / 1e3).collect::<Vec<f32>>(),
        ],
    )?;
    Ok(())
}
