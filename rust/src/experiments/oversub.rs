//! Fig 11 / §4.4 oversubscription analysis: how many racks fit under a
//! 600 kW row distribution limit when provisioning by generated traces
//! instead of nameplate TDP.
//!
//! Method (paper): provision racks until the P95 of row power exceeds the
//! limit, across seeds. We generate a pool of rack traces under the
//! production-like diurnal workload, then sweep the rack count for each
//! method (TDP / Mean / LUT / Ours).

use super::common::EvalCtx;
use crate::baselines::lut::LutBaseline;
use crate::config::{ScenarioSpec, ServerAssignment, WorkloadSpec};
use crate::metrics::percentile;
use crate::surrogate::simulate_queue;
use crate::util::cli::Args;
use crate::util::rng::Rng;
use crate::workload::{DiurnalProfile, TrafficMode};
use anyhow::Result;

pub fn run(args: &Args) -> Result<()> {
    let mut ctx = EvalCtx::new(args)?;
    let ids = ctx.config_ids();
    let id = if ids.iter().any(|i| i == "llama70b_a100_tp8") {
        "llama70b_a100_tp8".to_string()
    } else {
        ids[0].clone()
    };
    let art = ctx.config(&id)?;
    let cls = ctx.classifier(&id)?;
    let cfg = ctx.gen.cat.config(&id)?.clone();

    let limit_kw = args.f64_or("limit-kw", 600.0)?;
    let servers_per_rack = 4;
    let horizon_h = args.f64_or("horizon-h", if args.has("fast") { 1.0 } else { 4.0 })?;
    let dt = args.f64_or("dt", 1.0)?;
    let horizon = horizon_h * 3600.0;
    let n_steps = (horizon / dt).round() as usize;
    let max_racks = args.usize_or("max-racks", 80)?;

    // Nameplate math (paper: ⌊600 kW / rack TDP⌋).
    let rack_tdp_kw = ctx.gen.cat.server_nameplate_w(&cfg) * servers_per_rack as f64 / 1e3;
    let nameplate_racks = (limit_kw / rack_tdp_kw).floor() as usize;
    let rack_mean_kw = (art.train_mean_w + 1000.0) * servers_per_rack as f64 / 1e3;

    let profile = DiurnalProfile::default();
    let mut spec = ScenarioSpec::default_poisson(&id, profile.base_rate);
    spec.horizon_s = horizon;
    spec.server_config = ServerAssignment::Uniform(id.clone());
    spec.topology = crate::aggregate::Topology {
        rows: 1,
        racks_per_row: max_racks,
        servers_per_rack,
    };
    spec.workload = WorkloadSpec::Diurnal {
        base_rate: profile.base_rate,
        swing: profile.swing,
        peak_hour: 2.0, // align the window with peak demand hours
        burst_sigma: profile.burst_sigma,
        mode: TrafficMode::Independent,
    };

    println!(
        "Fig 11 — oversubscription under a {limit_kw:.0} kW row limit \
         ({id}, {servers_per_rack} servers/rack, {horizon_h} h window)"
    );
    println!("  rack nameplate: {rack_tdp_kw:.1} kW → {nameplate_racks} racks by TDP provisioning");

    // Generate the rack-trace pool (ours + LUT share schedules).
    let base_rng = Rng::new(args.u64_or("seed", 11)?);
    let mut rack_ours: Vec<Vec<f64>> = Vec::with_capacity(max_racks);
    let mut rack_lut: Vec<Vec<f64>> = Vec::with_capacity(max_racks);
    let t0 = std::time::Instant::now();
    for r in 0..max_racks {
        let mut ours = vec![0.0f64; n_steps];
        let mut lutv = vec![0.0f64; n_steps];
        for srv in 0..servers_per_rack {
            let s = r * servers_per_rack + srv;
            let sched = ctx.gen.schedule_for(&spec, s, &base_rng)?;
            let mut rng = base_rng.fork(0x0B5 ^ s as u64);
            let tr = ctx.gen.server_trace(&art, &cls, &sched, horizon, dt, &mut rng)?;
            for (o, &p) in ours.iter_mut().zip(&tr.power_w) {
                *o += p as f64 + 1000.0;
            }
            let intervals =
                simulate_queue(&sched, &art.surrogate, ctx.gen.cat.campaign.max_batch, &mut rng);
            let l = LutBaseline::default().trace(&ctx.gen.cat, &cfg, &intervals, n_steps, dt);
            for (o, &p) in lutv.iter_mut().zip(&l) {
                *o += p as f64 + 1000.0;
            }
        }
        rack_ours.push(ours);
        rack_lut.push(lutv);
        if (r + 1) % 20 == 0 {
            println!("  rack pool {}/{} ({:.1}s)", r + 1, max_racks, t0.elapsed().as_secs_f32());
        }
    }

    // Sweep rack count: P95 of row power vs the limit.
    let sweep = |pool: &[Vec<f64>]| -> (usize, Vec<f32>, f64) {
        let mut row = vec![0.0f64; n_steps];
        let mut curve = Vec::new();
        let mut max_ok = 0usize;
        let mut peak_at_max = 0.0f64;
        for (r, rack) in pool.iter().enumerate() {
            for (o, &p) in row.iter_mut().zip(rack) {
                *o += p;
            }
            let series: Vec<f32> = row.iter().map(|&x| (x / 1e3) as f32).collect();
            let p95 = percentile(&series, 95.0).expect("non-empty row series");
            curve.push(p95 as f32);
            if p95 <= limit_kw {
                max_ok = r + 1;
                peak_at_max = series.iter().cloned().fold(f32::MIN, f32::max) as f64;
            }
        }
        (max_ok, curve, peak_at_max)
    };
    let (ours_racks, ours_curve, ours_peak) = sweep(&rack_ours);
    let (lut_racks, lut_curve, _) = sweep(&rack_lut);
    let mean_racks = (limit_kw / rack_mean_kw).floor() as usize;

    // Row power when provisioning only the nameplate rack count.
    let nameplate_row_peak: f64 = {
        let mut row = vec![0.0f64; n_steps];
        for rack in rack_ours.iter().take(nameplate_racks.min(max_racks)) {
            for (o, &p) in row.iter_mut().zip(rack) {
                *o += p;
            }
        }
        row.iter().cloned().fold(f64::MIN, f64::max) / 1e3
    };

    println!("  {nameplate_racks} nameplate racks actually draw ≤ {nameplate_row_peak:.0} kW at peak (headroom unused)");
    println!("  max racks under P95 ≤ {limit_kw:.0} kW:");
    println!("    ours: {ours_racks} racks (peak {ours_peak:.0} kW)");
    println!("    LUT : {lut_racks} racks");
    println!("    Mean: {mean_racks} racks (flat model)");
    println!("    TDP : {nameplate_racks} racks");
    println!(
        "\nshape check: ours ≥ LUT ≥ Mean > TDP rack counts \
         (paper: 57 / 52 / 42 / 23 racks)"
    );
    anyhow::ensure!(ours_racks > nameplate_racks, "trace-based provisioning must beat nameplate");

    let idx: Vec<f32> = (1..=max_racks).map(|r| r as f32).collect();
    ctx.write_csv("fig11", "row_p95_vs_racks", &["racks", "ours_p95_kw", "lut_p95_kw"], &[&idx, &ours_curve, &lut_curve])
}
