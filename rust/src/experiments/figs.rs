//! Server-level figure reproductions (Figs 1, 3, 4, 5, 6, 7, 8, 13).
//! Each prints the paper's headline quantities and writes the plotted
//! series as CSV under `out/<fig>/`.

use super::common::{EvalCtx, ACF_MAX_LAG};
use crate::metrics::{self, ks::ecdf, ks_statistic};
use crate::states::{select_k, EmOptions};
use crate::surrogate::features_from_intervals;
use crate::util::cli::Args;
use crate::util::rng::Rng;
use anyhow::{Context, Result};

/// Pick the measured trace closest to an arrival rate.
fn trace_at_rate<'a>(
    traces: &'a [crate::artifacts::MeasuredTrace],
    rate: f64,
) -> &'a crate::artifacts::MeasuredTrace {
    traces
        .iter()
        .min_by(|a, b| {
            (a.rate - rate).abs().partial_cmp(&(b.rate - rate).abs()).unwrap()
        })
        .expect("nonempty traces")
}

fn first_available(ctx: &EvalCtx, prefs: &[&str]) -> Result<String> {
    let ids = ctx.config_ids();
    prefs
        .iter()
        .find(|p| ids.iter().any(|i| i == *p))
        .map(|s| s.to_string())
        .or_else(|| ids.first().cloned())
        .context("no artifacts built")
}

use super::common::pearson;

/// Fig 1: measured vs LUT vs ours for Llama-3.1 70B TP=8 on A100.
pub fn fig1(args: &Args) -> Result<()> {
    let mut ctx = EvalCtx::new(args)?;
    let id = first_available(&ctx, &["llama70b_a100_tp8"])?;
    let art = ctx.config(&id)?;
    let cls = ctx.classifier(&id)?;
    let traces = ctx.gen.store.load_all_measured(&id)?;
    let m = trace_at_rate(&traces, 0.5);
    let ours = ctx.synth_like(&art, &cls, m, 42)?;
    let lut = ctx.lut_like(&art, m, 42)?;
    println!("Fig 1 — server trace comparison ({id}, λ={})", m.rate);
    let f_ours = metrics::fidelity(&m.power_w, &ours, ACF_MAX_LAG);
    let f_lut = metrics::fidelity(&m.power_w, &lut, ACF_MAX_LAG);
    println!("  ours: KS={:.2} NRMSE={:.2} |ΔE|={:.1}%", f_ours.ks, f_ours.nrmse, f_ours.delta_energy.abs() * 100.0);
    println!("  LUT : KS={:.2} NRMSE={:.2} |ΔE|={:.1}%", f_lut.ks, f_lut.nrmse, f_lut.delta_energy.abs() * 100.0);
    // Count distinct LUT levels — the structural failure the figure shows.
    let mut levels: Vec<i64> = lut.iter().map(|&p| p.round() as i64).collect();
    levels.sort_unstable();
    levels.dedup();
    println!("  LUT produces {} distinct power levels; measured spans {:.0}–{:.0} W continuously",
        levels.len(),
        m.power_w.iter().cloned().fold(f32::MAX, f32::min),
        m.power_w.iter().cloned().fold(f32::MIN, f32::max));
    ctx.write_csv("fig1", &format!("{id}_r{}", m.rate), &["measured_w", "ours_w", "lut_w"], &[&m.power_w, &ours, &lut])
}

/// Fig 3: measured GPU power and active request count co-movement
/// (Llama-3.1 8B on H100, λ = 0.25 req/s).
pub fn fig3(args: &Args) -> Result<()> {
    let mut ctx = EvalCtx::new(args)?;
    let id = first_available(&ctx, &["llama8b_h100_tp1", "llama8b_a100_tp2"])?;
    let traces = ctx.gen.store.load_all_measured(&id)?;
    let m = trace_at_rate(&traces, 0.25);
    let r = pearson(&m.power_w, &m.a_measured);
    println!("Fig 3 — power / A_t co-movement ({id}, λ={})", m.rate);
    println!("  Pearson corr(power, A_t) = {r:.3} (paper: 'the two signals move together')");
    anyhow::ensure!(r > 0.6, "power and A_t should co-move (got {r})");
    ctx.write_csv("fig3", &format!("{id}_r{}", m.rate), &["power_w", "a_t"], &[&m.power_w, &m.a_measured])
}

/// Fig 4: normalized BIC vs K for four representative configurations
/// (Rust EM substrate on held-out measured power).
pub fn fig4(args: &Args) -> Result<()> {
    let mut ctx = EvalCtx::new(args)?;
    let ids = ctx.config_ids();
    let pick: Vec<String> = ["llama8b_a100_tp2", "llama70b_a100_tp8", "r1d70b_h100_tp4", "gptoss120b_a100_tp4"]
        .iter()
        .filter(|p| ids.iter().any(|i| i == *p))
        .map(|s| s.to_string())
        .collect();
    let pick = if pick.is_empty() { ids[..ids.len().min(4)].to_vec() } else { pick };
    println!("Fig 4 — normalized BIC vs number of mixture components K");
    let k_max = if args.has("fast") { 8 } else { 12 };
    for id in &pick {
        let measured = ctx.gen.store.load_all_measured(id)?;
        let pooled: Vec<f32> = measured.iter().flat_map(|m| m.power_w.iter().copied()).collect();
        let mut rng = Rng::new(4);
        let opts = EmOptions { n_init: 1, max_iters: 60, ..Default::default() };
        let (_, curve) = select_k(&pooled, 1..=k_max, &opts, &mut rng)?;
        let norm = curve.normalized();
        println!("  {id}: best K = {} ; normalized BIC = {:?}", curve.best_k,
            norm.iter().map(|b| (b * 100.0).round() / 100.0).collect::<Vec<_>>());
        let ks: Vec<f32> = curve.ks.iter().map(|&k| k as f32).collect();
        let bic: Vec<f32> = norm.iter().map(|&b| b as f32).collect();
        ctx.write_csv("fig4", id, &["k", "normalized_bic"], &[&ks, &bic])?;
    }
    Ok(())
}

/// Fig 5: CDFs of modeled vs measured prefill/decode durations
/// (DeepSeek-R1-Distill 8B on H100 TP=8).
pub fn fig5(args: &Args) -> Result<()> {
    let mut ctx = EvalCtx::new(args)?;
    // The paper plots R1-Distill 8B on H100 TP=8; on our testbed that
    // config's TTFT (≈5–20 ms) sits entirely below the 50 ms engine
    // substep, leaving only quantization in the measured durations, so we
    // default to a configuration whose durations the substrate resolves
    // (r1d8b on A100 TP=2) and keep the H100 one reachable via artifacts.
    let id = first_available(&ctx, &["r1d8b_a100_tp2", "llama70b_a100_tp8", "r1d8b_h100_tp8"])?;
    let art = ctx.config(&id)?;
    let traces = ctx.gen.store.load_all_measured(&id)?;
    let mut meas_pre: Vec<f32> = vec![];
    let mut meas_dec: Vec<f32> = vec![];
    let mut model_pre: Vec<f32> = vec![];
    let mut model_dec: Vec<f32> = vec![];
    let mut rng = Rng::new(5);
    // The testbed logs durations on its 50 ms engine substep (cf. the
    // paper's nvidia-smi-derived measurements); apply the same
    // quantization to the surrogate draws so the CDFs are comparable.
    let q = |x: f64| ((x / 0.05).ceil() * 0.05) as f32;
    for m in &traces {
        for i in 0..m.durations.len() {
            meas_pre.push(m.durations.prefill_s[i] as f32);
            meas_dec.push(m.durations.decode_s[i] as f32);
            // Surrogate draws for the same request sizes.
            model_pre.push(q(art.surrogate.sample_ttft(m.durations.n_in[i], &mut rng)));
            model_dec.push(q(m.durations.n_out[i] as f64 * art.surrogate.sample_tbt(&mut rng)));
        }
    }
    let ks_pre = ks_statistic(&meas_pre, &model_pre);
    let ks_dec = ks_statistic(&meas_dec, &model_dec);
    println!("Fig 5 — prefill/decode duration CDFs ({id})");
    println!("  prefill: KS(measured, modeled) = {ks_pre:.3}  (n={})", meas_pre.len());
    println!("  decode : KS(measured, modeled) = {ks_dec:.3}");
    anyhow::ensure!(ks_pre < 0.35 && ks_dec < 0.35, "surrogate should match duration CDFs");
    ctx.write_csv("fig5", &format!("{id}_durations"),
        &["measured_prefill_s", "model_prefill_s", "measured_decode_s", "model_decode_s"],
        &[&meas_pre, &model_pre, &meas_dec, &model_dec])
}

/// Fig 6: dense traces at three arrival rates + one MoE trace.
pub fn fig6(args: &Args) -> Result<()> {
    let mut ctx = EvalCtx::new(args)?;
    let dense = first_available(&ctx, &["llama8b_a100_tp2"])?;
    let moe = first_available(&ctx, &["gptoss120b_a100_tp4", "gptoss120b_h100_tp4"])?;
    println!("Fig 6 — measured vs simulated server traces");
    for (id, rates) in [(&dense, vec![0.125, 0.5, 4.0]), (&moe, vec![1.0])] {
        let art = ctx.config(id)?;
        let cls = ctx.classifier(id)?;
        let traces = ctx.gen.store.load_all_measured(id)?;
        for rate in rates {
            let m = trace_at_rate(&traces, rate);
            let syn = ctx.synth_like(&art, &cls, m, 6)?;
            let f = metrics::fidelity(&m.power_w, &syn, ACF_MAX_LAG);
            println!(
                "  {id} λ={}: KS={:.2} ACF R²={} NRMSE={:.2} |ΔE|={:.1}%",
                m.rate, f.ks,
                f.acf_r2.map(|v| format!("{v:.2}")).unwrap_or("–".into()),
                f.nrmse, f.delta_energy.abs() * 100.0
            );
            ctx.write_csv("fig6", &format!("{id}_r{}", m.rate), &["measured_w", "synthetic_w"], &[&m.power_w, &syn])?;
        }
    }
    Ok(())
}

/// Fig 7: CDFs of synthetic vs measured power for representative configs.
pub fn fig7(args: &Args) -> Result<()> {
    let mut ctx = EvalCtx::new(args)?;
    let picks = [
        first_available(&ctx, &["r1d70b_h100_tp4", "r1d70b_a100_tp8"])?,
        first_available(&ctx, &["llama8b_a100_tp2"])?,
        first_available(&ctx, &["gptoss120b_a100_tp4"])?,
    ];
    println!("Fig 7 — synthetic vs measured power CDFs");
    for id in &picks {
        let art = ctx.config(id)?;
        let cls = ctx.classifier(id)?;
        let traces = ctx.gen.store.load_all_measured(id)?;
        let mut meas: Vec<f32> = vec![];
        let mut syn: Vec<f32> = vec![];
        for m in &traces {
            meas.extend_from_slice(&m.power_w);
            syn.extend(ctx.synth_like(&art, &cls, m, 7)?);
        }
        let ks = ks_statistic(&meas, &syn);
        println!("  {id}: KS = {ks:.3} over {} pooled samples", meas.len());
        // Evaluate both ECDFs on a common grid for the CSV.
        let lo = meas.iter().cloned().fold(f32::MAX, f32::min);
        let hi = meas.iter().cloned().fold(f32::MIN, f32::max);
        let grid: Vec<f32> = (0..200).map(|i| lo + (hi - lo) * i as f32 / 199.0).collect();
        let c_m: Vec<f32> = ecdf(&meas, &grid).iter().map(|&x| x as f32).collect();
        let c_s: Vec<f32> = ecdf(&syn, &grid).iter().map(|&x| x as f32).collect();
        ctx.write_csv("fig7", id, &["power_w", "cdf_measured", "cdf_synthetic"], &[&grid, &c_m, &c_s])?;
    }
    Ok(())
}

/// Fig 8: 15 minutes of facility power (60 servers) by method.
pub fn fig8(args: &Args) -> Result<()> {
    use crate::aggregate::{FacilityAccumulator, Topology};
    use crate::baselines::lut::LutBaseline;
    use crate::config::{ScenarioSpec, ServerAssignment, WorkloadSpec};
    use crate::surrogate::simulate_queue;

    let mut ctx = EvalCtx::new(args)?;
    let id = first_available(&ctx, &["llama70b_h100_tp8", "llama70b_h100_tp4"])?;
    let art = ctx.config(&id)?;
    let cls = ctx.classifier(&id)?;
    let n_servers = args.usize_or("servers", 60)?;
    let horizon = args.f64_or("horizon", 900.0)?;
    let dt = 0.25;
    let topo = Topology { rows: 1, racks_per_row: n_servers / 4, servers_per_rack: 4 };
    let mut spec = ScenarioSpec::default_poisson(&id, 0.5);
    spec.topology = topo;
    spec.horizon_s = horizon;
    spec.server_config = ServerAssignment::Uniform(id.clone());
    spec.workload = WorkloadSpec::Poisson { rate: 0.5 };
    let n_steps = (horizon / dt).round() as usize;
    let base_rng = Rng::new(8);

    let mut acc_ours = FacilityAccumulator::new(topo, n_steps, spec.p_base_w);
    let mut acc_lut = FacilityAccumulator::new(topo, n_steps, spec.p_base_w);
    let cfg = ctx.gen.cat.config(&id)?.clone();
    for s in 0..topo.n_servers() {
        let sched = ctx.gen.schedule_for(&spec, s, &base_rng)?;
        let mut rng = base_rng.fork(0xF18 ^ s as u64);
        let tr = ctx.gen.server_trace(&art, &cls, &sched, horizon, dt, &mut rng)?;
        acc_ours.add_server(s, &tr.power_w)?;
        let intervals = simulate_queue(&sched, &art.surrogate, ctx.gen.cat.campaign.max_batch, &mut rng);
        let lut = LutBaseline::default().trace(&ctx.gen.cat, &cfg, &intervals, n_steps, dt);
        acc_lut.add_server(s, &lut)?;
    }
    let pue = spec.pue;
    let ours = acc_ours.facility_series(pue);
    let lut = acc_lut.facility_series(pue);
    let tdp_w = ctx.gen.cat.server_nameplate_w(&cfg) * topo.n_servers() as f64 * pue;
    let mean_w = (art.train_mean_w + spec.p_base_w) * topo.n_servers() as f64 * pue;
    let stats = |s: &[f32]| -> anyhow::Result<(f64, f64)> {
        let st = metrics::PlanningStats::compute(s, dt, 60.0)?;
        Ok((st.peak_w / 1e3, st.avg_w / 1e3))
    };
    println!("Fig 8 — 15-min facility power, {n_servers} servers ({id}), kW:");
    let (pk, av) = stats(&ours)?;
    println!("  ours: peak {pk:.0} kW avg {av:.0} kW");
    let (pk, av) = stats(&lut)?;
    println!("  LUT : peak {pk:.0} kW avg {av:.0} kW");
    println!("  Mean: flat {:.0} kW   TDP: flat {:.0} kW", mean_w / 1e3, tdp_w / 1e3);
    let tdp_series = vec![(tdp_w / 1.0) as f32; n_steps.min(8)];
    let _ = tdp_series;
    ctx.write_csv("fig8", &format!("{id}_{n_servers}servers"), &["ours_w", "lut_w"], &[&ours, &lut])
}

/// Fig 13: surrogate vs measured A_t trajectories (R1-Distill 70B,
/// two GPU generations / TP settings, three rates).
pub fn fig13(args: &Args) -> Result<()> {
    let mut ctx = EvalCtx::new(args)?;
    let ids = ctx.config_ids();
    let picks: Vec<String> = ["r1d70b_a100_tp8", "r1d70b_h100_tp4"]
        .iter()
        .filter(|p| ids.iter().any(|i| i == *p))
        .map(|s| s.to_string())
        .collect();
    anyhow::ensure!(!picks.is_empty(), "no r1d70b artifacts");
    println!("Fig 13 — surrogate vs measured A_t (workload-feature adherence)");
    for id in &picks {
        let art = ctx.config(id)?;
        let traces = ctx.gen.store.load_all_measured(id)?;
        for rate in [0.25, 0.5, 4.0] {
            let m = trace_at_rate(&traces, rate);
            let mut rng = Rng::new(13);
            let intervals = ctx.intervals_for(&art, m, &mut rng);
            let feats = features_from_intervals(&intervals, m.power_w.len(), m.dt_s);
            let corr = pearson(&feats.a, &m.a_measured);
            let mean_meas: f64 =
                m.a_measured.iter().map(|&x| x as f64).sum::<f64>() / m.a_measured.len() as f64;
            let mean_sur: f64 = feats.a.iter().map(|&x| x as f64).sum::<f64>() / feats.a.len() as f64;
            println!(
                "  {id} λ={}: corr={corr:.2} mean A meas={mean_meas:.2} vs surrogate={mean_sur:.2}",
                m.rate
            );
            ctx.write_csv("fig13", &format!("{id}_r{}", m.rate), &["a_measured", "a_surrogate"], &[&m.a_measured, &feats.a])?;
        }
    }
    Ok(())
}
