//! Table 1: synthetic-trace fidelity on held-out test data, averaged
//! across hardware and TP configurations per model. Dense models use
//! i.i.d. generation (Eq. 8), MoE use AR(1) (Eq. 9). Metrics: KS ↓,
//! ACF R² ↑, NRMSE ↓, median |ΔE| % ↓ (median over seeds per trace).

use super::common::{pm, EvalCtx, ACF_MAX_LAG};
use crate::metrics::{self, fidelity};
use crate::util::cli::Args;
use anyhow::Result;

pub struct Row {
    pub model: String,
    pub ks: (f64, f64),
    pub acf_r2: (f64, f64),
    pub nrmse: (f64, f64),
    pub de_pct: (f64, f64),
    pub n_configs: usize,
}

pub fn compute(ctx: &mut EvalCtx) -> Result<Vec<Row>> {
    let model_order = ["llama8b", "llama70b", "llama405b", "r1d8b", "r1d70b", "gptoss20b", "gptoss120b"];
    let mut rows = Vec::new();
    for model in model_order {
        let ids: Vec<String> = ctx
            .config_ids()
            .into_iter()
            .filter(|id| id.starts_with(&format!("{model}_")))
            .collect();
        if ids.is_empty() {
            continue;
        }
        // Per (config, trace): median metric over seeds.
        let (mut kss, mut acfs, mut nrmses, mut des) = (vec![], vec![], vec![], vec![]);
        for id in &ids {
            let art = ctx.config(id)?;
            let cls = ctx.classifier(id)?;
            let measured = ctx.gen.store.load_all_measured(id)?;
            for m in &measured {
                let (mut k_s, mut a_s, mut n_s, mut d_s) = (vec![], vec![], vec![], vec![]);
                for seed in 0..ctx.n_seeds as u64 {
                    let syn = ctx.synth_like(&art, &cls, m, 1000 + seed)?;
                    let f = fidelity(&m.power_w, &syn, ACF_MAX_LAG);
                    k_s.push(f.ks);
                    if let Some(r2) = f.acf_r2 {
                        a_s.push(r2);
                    }
                    n_s.push(f.nrmse);
                    d_s.push(f.delta_energy.abs() * 100.0);
                }
                kss.push(metrics::median(&k_s));
                if !a_s.is_empty() {
                    acfs.push(metrics::median(&a_s));
                }
                nrmses.push(metrics::median(&n_s));
                des.push(metrics::median(&d_s));
            }
        }
        rows.push(Row {
            model: model.to_string(),
            ks: metrics::mean_std(&kss),
            acf_r2: metrics::mean_std(&acfs),
            nrmse: metrics::mean_std(&nrmses),
            de_pct: metrics::mean_std(&des),
            n_configs: ids.len(),
        });
    }
    Ok(rows)
}

pub fn run(args: &Args) -> Result<()> {
    let mut ctx = EvalCtx::new(args)?;
    let rows = compute(&mut ctx)?;
    println!("Table 1 — synthetic trace fidelity on held-out test data");
    println!("(averaged across hardware/TP configs per model; median over {} seeds per trace)\n", ctx.n_seeds);
    println!(
        "{:<28} {:>4} {:>14} {:>14} {:>14} {:>16}",
        "Model", "cfgs", "KS ↓", "ACF R² ↑", "NRMSE ↓", "median |ΔE|% ↓"
    );
    for r in &rows {
        println!(
            "{:<28} {:>4} {:>14} {:>14} {:>14} {:>16}",
            ctx.gen.cat.models.get(&r.model).map(|m| m.name.clone()).unwrap_or(r.model.clone()),
            r.n_configs,
            pm(r.ks.0, r.ks.1, 2),
            pm(r.acf_r2.0, r.acf_r2.1, 2),
            pm(r.nrmse.0, r.nrmse.1, 2),
            pm(r.de_pct.0, r.de_pct.1, 1),
        );
    }
    // Paper shape check summary.
    let dense: Vec<&Row> = rows.iter().filter(|r| !r.model.starts_with("gptoss")).collect();
    let moe: Vec<&Row> = rows.iter().filter(|r| r.model.starts_with("gptoss")).collect();
    if !dense.is_empty() && !moe.is_empty() {
        let d_acf = dense.iter().map(|r| r.acf_r2.0).sum::<f64>() / dense.len() as f64;
        let m_acf = moe.iter().map(|r| r.acf_r2.0).sum::<f64>() / moe.len() as f64;
        let d_de = dense.iter().map(|r| r.de_pct.0).sum::<f64>() / dense.len() as f64;
        let m_de = moe.iter().map(|r| r.de_pct.0).sum::<f64>() / moe.len() as f64;
        println!(
            "\nshape check: dense ACF R² {d_acf:.2} vs MoE {m_acf:.2}; dense |ΔE| {d_de:.1}% vs MoE {m_de:.1}% \
             (paper: dense ≥0.96 / <5%; MoE lower fidelity)"
        );
    }
    Ok(())
}
