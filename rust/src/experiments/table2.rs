//! Table 2: server-level baseline comparison (TDP / Mean / Splitwise-style
//! LUT / Ours) on Llama-3.1 (70B) A100 TP=4 and TP=8 held-out data.

use super::common::{EvalCtx, ACF_MAX_LAG};
use crate::metrics::{self, fidelity, Fidelity};
use crate::util::cli::Args;
use anyhow::Result;

#[derive(Debug, Clone, Copy)]
pub struct Row {
    pub ks: f64,
    pub acf_r2: Option<f64>,
    pub nrmse: f64,
    pub de_pct: f64,
}

fn aggregate(per_trace: &[Fidelity]) -> Row {
    let med = |xs: Vec<f64>| metrics::median(&xs);
    let acfs: Vec<f64> = per_trace.iter().filter_map(|f| f.acf_r2).collect();
    Row {
        ks: med(per_trace.iter().map(|f| f.ks).collect()),
        acf_r2: if acfs.is_empty() { None } else { Some(metrics::median(&acfs)) },
        nrmse: med(per_trace.iter().map(|f| f.nrmse).collect()),
        de_pct: med(per_trace.iter().map(|f| f.delta_energy.abs() * 100.0).collect()),
    }
}

pub fn compute(ctx: &mut EvalCtx, ids: &[&str]) -> Result<Vec<(String, Row)>> {
    let (mut tdp, mut mean, mut lut, mut ours) = (vec![], vec![], vec![], vec![]);
    for id in ids {
        let art = ctx.config(id)?;
        let cls = ctx.classifier(id)?;
        for m in &ctx.gen.store.load_all_measured(id)? {
            tdp.push(fidelity(&m.power_w, &ctx.tdp_like(&art, m)?, ACF_MAX_LAG));
            mean.push(fidelity(&m.power_w, &ctx.mean_like(&art, m), ACF_MAX_LAG));
            let mut lut_seeds = vec![];
            let mut ours_seeds = vec![];
            for seed in 0..ctx.n_seeds as u64 {
                lut_seeds.push(fidelity(&m.power_w, &ctx.lut_like(&art, m, 300 + seed)?, ACF_MAX_LAG));
                ours_seeds.push(fidelity(
                    &m.power_w,
                    &ctx.synth_like(&art, &cls, m, 300 + seed)?,
                    ACF_MAX_LAG,
                ));
            }
            lut.push(aggregate_fid(&lut_seeds));
            ours.push(aggregate_fid(&ours_seeds));
        }
    }
    Ok(vec![
        ("TDP".into(), aggregate(&tdp)),
        ("Mean".into(), aggregate(&mean)),
        ("LUT-based".into(), aggregate(&lut)),
        ("Ours".into(), aggregate(&ours)),
    ])
}

/// Median-of-seeds reduction back into one Fidelity per trace.
fn aggregate_fid(fs: &[Fidelity]) -> Fidelity {
    let acfs: Vec<f64> = fs.iter().filter_map(|f| f.acf_r2).collect();
    Fidelity {
        ks: metrics::median(&fs.iter().map(|f| f.ks).collect::<Vec<_>>()),
        acf_r2: if acfs.is_empty() { None } else { Some(metrics::median(&acfs)) },
        nrmse: metrics::median(&fs.iter().map(|f| f.nrmse).collect::<Vec<_>>()),
        delta_energy: metrics::median(
            &fs.iter().map(|f| f.delta_energy.abs()).collect::<Vec<_>>(),
        ),
    }
}

pub fn run(args: &Args) -> Result<()> {
    let mut ctx = EvalCtx::new(args)?;
    let available = ctx.config_ids();
    let want = ["llama70b_a100_tp4", "llama70b_a100_tp8"];
    let ids: Vec<&str> = want.iter().copied().filter(|id| available.iter().any(|a| a == id)).collect();
    anyhow::ensure!(!ids.is_empty(), "no llama70b A100 artifacts built");
    let rows = compute(&mut ctx, &ids)?;
    println!("Table 2 — baseline comparison at server level ({})\n", ids.join(" + "));
    println!("{:<12} {:>8} {:>10} {:>9} {:>9}", "Method", "KS ↓", "ACF R² ↑", "NRMSE ↓", "|ΔE|% ↓");
    for (name, r) in &rows {
        println!(
            "{:<12} {:>8.2} {:>10} {:>9.2} {:>9.2}",
            name,
            r.ks,
            r.acf_r2.map(|v| format!("{v:.2}")).unwrap_or_else(|| "–".into()),
            r.nrmse,
            r.de_pct
        );
    }
    let ours = &rows[3].1;
    let tdp = &rows[0].1;
    let lut = &rows[2].1;
    println!(
        "\nshape check: ours beats LUT beats constants (paper: TDP ΔE≈244%, LUT 13.7%, ours 6.1%): \
         tdp {:.0}% > lut {:.1}% > ours {:.1}%",
        tdp.de_pct, lut.de_pct, ours.de_pct
    );
    Ok(())
}
