//! `powertrace` — the planner-facing CLI (paper §3.1).
//!
//! Subcommands:
//!   generate   one server power trace from a workload scenario
//!   facility   facility-scale run from a scenario JSON
//!   site       compose N facilities into a utility-facing site profile
//!   sweep      expand a scenario grid and run every cell (multi-scale export)
//!   merge      assemble sharded partial sweeps into one summary + manifest
//!   serve      live planning service: RunRequests over HTTP, NDJSON streams
//!   diff       compare two summary CSVs cell-by-cell (regression gate)
//!   repro      regenerate a paper table/figure (or `all`)
//!   fit        Rust-side GMM+BIC refit on held-out measured traces
//!   testbed    run the synthetic measurement testbed (ground truth)
//!   info       catalog + artifact inventory

// Same clippy policy as the library crate root (see rust/src/lib.rs):
// clippy is a CI gate; these style lints conflict with the CLI's
// deliberate long-literal help tables and format-heavy reporting.
#![allow(
    clippy::too_many_arguments,
    clippy::uninlined_format_args,
    clippy::useless_format,
    clippy::format_push_string
)]

use anyhow::Result;
use powertrace_sim::api::{self, RunKind, RunOptions, RunOutcome, RunRequest, RunSpec};
use powertrace_sim::catalog::Catalog;
use powertrace_sim::config::ScenarioSpec;
use powertrace_sim::coordinator::Generator;
use powertrace_sim::experiments;
use powertrace_sim::metrics::PlanningStats;
use powertrace_sim::scenarios::SweepGrid;
use powertrace_sim::states::{select_k, EmOptions};
use powertrace_sim::testbed;
use powertrace_sim::util::cli::{usage, Args, Opt};
use powertrace_sim::util::rng::Rng;
use powertrace_sim::workload::{poisson_arrivals, LengthSampler};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print_help();
        std::process::exit(2);
    }
    let cmd = argv[0].clone();
    let args = Args::parse(argv[1..].iter().cloned());
    let result = match cmd.as_str() {
        "generate" => cmd_generate(&args),
        "facility" => cmd_facility(&args),
        "site" => cmd_site(&args),
        "sweep" => cmd_sweep(&args),
        "merge" => cmd_merge(&args),
        "serve" => cmd_serve(&args),
        "diff" => cmd_diff(&args),
        "repro" => cmd_repro(&args),
        "fit" => cmd_fit(&args),
        "testbed" => cmd_testbed(&args),
        "info" => cmd_info(&args),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'");
            print_help();
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "powertrace — compositional LLM-inference power trace generation\n\
         \n\
         usage: powertrace <command> [options]\n\
         \n\
         commands:\n\
           generate   generate one server power trace (Poisson workload)\n\
           facility   run a facility scenario (JSON spec) → site load shape\n\
           site       compose N phase-offset facilities (site spec JSON) →\n\
                      utility-facing load profile + interconnect summary\n\
           sweep      expand a scenario grid (JSON), run every cell in\n\
                      parallel, export multi-scale series + summary\n\
           merge      assemble sharded sweep runs (--shard i/N) into the\n\
                      summary an unsharded run would have written\n\
           serve      live planning service: POST RunRequest envelopes to\n\
                      /v1/runs, stream windows back as NDJSON (feature `serve`)\n\
           diff       compare two summary CSVs cell-by-cell; non-zero exit\n\
                      above --tolerance (metric regression gate)\n\
           repro      reproduce a paper table/figure: {} | all\n\
           fit        fit GMM power states on held-out measured traces\n\
           testbed    run the ground-truth measurement testbed\n\
           info       show catalog and artifact inventory\n\
         \n\
         common options: --backend native|pjrt  --seed N  --fast",
        experiments::ALL.join(" | ")
    );
}

fn cmd_generate(args: &Args) -> Result<()> {
    if args.has("help") {
        println!("{}", usage("generate", "generate one server power trace", &[
            Opt { name: "config", help: "serving configuration id", default: Some("llama70b_a100_tp8") },
            Opt { name: "rate", help: "Poisson arrival rate (req/s)", default: Some("0.5") },
            Opt { name: "horizon", help: "trace length (s)", default: Some("600") },
            Opt { name: "dataset", help: "length profile", default: Some("sharegpt") },
            Opt { name: "seed", help: "RNG seed", default: Some("0") },
            Opt { name: "backend", help: "classifier backend (native|pjrt)", default: Some("pjrt") },
            Opt { name: "out", help: "CSV output path", default: None },
        ]));
        return Ok(());
    }
    let mut gen = Generator::with_backend(&args.str_or("backend", "pjrt"))?;
    let id = args.str_or("config", "llama70b_a100_tp8");
    let rate = args.f64_or("rate", 0.5)?;
    let horizon = args.f64_or("horizon", 600.0)?;
    let seed = args.u64_or("seed", 0)?;
    let art = gen.config(&id)?;
    let cls = gen.classifier(&art)?;
    let profile = gen
        .cat
        .datasets
        .get(&args.str_or("dataset", "sharegpt"))
        .ok_or_else(|| anyhow::anyhow!("unknown dataset"))?
        .clone();
    let lengths = LengthSampler::from_profile(&profile, 1.0);
    let mut rng = Rng::new(seed);
    let sched = poisson_arrivals(rate, horizon, &lengths, &mut rng);
    let tr = gen.server_trace(&art, &cls, &sched, horizon, 0.25, &mut rng)?;
    let stats = PlanningStats::compute(&tr.power_w, 0.25, 60.0)?;
    println!(
        "generated {} samples @250ms for {id} (λ={rate}): peak {:.0} W, avg {:.0} W, PAR {:.2}",
        tr.power_w.len(),
        stats.peak_w,
        stats.avg_w,
        stats.peak_to_average
    );
    if let Some(out) = args.str_opt("out") {
        let mut s = String::from("t_s,power_w,a\n");
        for (i, (&p, &a)) in tr.power_w.iter().zip(&tr.a).enumerate() {
            s.push_str(&format!("{},{p},{a}\n", i as f64 * 0.25));
        }
        std::fs::write(out, s)?;
        println!("wrote {out}");
    }
    Ok(())
}

fn cmd_facility(args: &Args) -> Result<()> {
    if args.has("help") {
        println!("{}", usage("facility", "run a facility scenario → site load shape", &[
            Opt { name: "scenario", help: "scenario JSON (default: built-in demo)", default: None },
            Opt { name: "dt", help: "generation sample interval (s)", default: Some("1") },
            Opt { name: "workers", help: "worker threads (0 = auto)", default: Some("0") },
            Opt { name: "window", help: "streaming window (s; 0 = buffered). Memory stays O(racks × window) — use for >24 h horizons", default: Some("0") },
            Opt { name: "resample", help: "--out export interval (s)", default: Some("900") },
            Opt { name: "out", help: "CSV output path for the facility series", default: None },
            Opt { name: "backend", help: "classifier backend (native|pjrt; streaming requires native)", default: Some("pjrt") },
        ]));
        return Ok(());
    }
    let mut gen = Generator::with_backend(&args.str_or("backend", "pjrt"))?;
    let spec = match args.str_opt("scenario") {
        Some(path) => ScenarioSpec::load(std::path::Path::new(path))?,
        None => {
            let mut s = ScenarioSpec::default_poisson("llama70b_a100_tp8", 0.5);
            s.topology = powertrace_sim::aggregate::Topology {
                rows: 2,
                racks_per_row: 3,
                servers_per_rack: 4,
            };
            s
        }
    };
    let dt = args.f64_or("dt", 1.0)?;
    let workers = args.usize_or("workers", 0)?;
    let window_s = args.f64_or("window", 0.0)?;
    let t0 = std::time::Instant::now();
    if window_s > 0.0 {
        return cmd_facility_streamed(&mut gen, &spec, dt, window_s, workers, args, t0);
    }
    // The buffered path is a facility RunRequest: a degenerate one-cell
    // sweep through the same engine the server executes, with the --out
    // export taken from the cell's multi-scale facility series.
    let resample_s = args.f64_or("resample", 900.0)?;
    let options = RunOptions::defaults_for(RunKind::Facility)
        .with_dt(dt)
        .with_server_workers(workers)
        .with_scales(powertrace_sim::aggregate::ScaleConfig {
            facility_intervals_s: vec![resample_s],
            ..Default::default()
        });
    let req = RunRequest { spec: RunSpec::Facility(spec.clone()), options };
    let RunOutcome::Facility(report) = api::execute(&mut gen, &req, None)? else {
        unreachable!("facility request yields a facility outcome")
    };
    let cell = report
        .cells
        .first()
        .ok_or_else(|| anyhow::anyhow!("facility run produced no cell"))?;
    print_facility_summary(
        &spec,
        dt,
        &cell.stats,
        cell.exact_quantiles,
        cell.p99_bound_w,
        t0.elapsed().as_secs_f64(),
    );
    if let Some(out) = args.str_opt("out") {
        let scales =
            cell.scales.as_ref().ok_or_else(|| anyhow::anyhow!("facility cell lost its series"))?;
        let mut s = String::from("t_s,facility_w\n");
        for (i, &p) in scales.facility_w[0].iter().enumerate() {
            s.push_str(&format!("{},{p}\n", i as f64 * resample_s));
        }
        std::fs::write(out, s)?;
        println!("wrote {out}");
    }
    Ok(())
}

/// `powertrace facility --window N`: windowed streaming generation — the
/// horizon never lives in memory; stats fold per window and the optional
/// `--out` CSV is appended incrementally.
fn cmd_facility_streamed(
    gen: &mut Generator,
    spec: &ScenarioSpec,
    dt: f64,
    window_s: f64,
    workers: usize,
    args: &Args,
    t0: std::time::Instant,
) -> Result<()> {
    use powertrace_sim::metrics::planning::{
        clamp_ramp_interval, StreamingPlanningStats, StreamingResampler,
    };
    use std::io::Write as _;
    let mut stats =
        StreamingPlanningStats::new(dt, clamp_ramp_interval(900.0, spec.horizon_s, dt))?;
    let resample_s = args.f64_or("resample", 900.0)?;
    let mut writer = match args.str_opt("out") {
        Some(out) => {
            let mut f = std::io::BufWriter::new(std::fs::File::create(out)?);
            f.write_all(b"t_s,facility_w\n")?;
            Some((f, StreamingResampler::new(dt, resample_s, 1.0)?, 0usize, out.to_string()))
        }
        None => None,
    };
    let mut rows = Vec::new();
    let mut site = Vec::new();
    let mut pcc = Vec::new();
    gen.facility_windowed(spec, dt, window_s, workers, 0, |acc| {
        acc.fold_rows_site(&mut rows, &mut site);
        powertrace_sim::aggregate::pcc_window_into(&site, spec.pue, &mut pcc);
        stats.push_slice(&pcc);
        if let Some((f, r, n, _)) = writer.as_mut() {
            for &p in &pcc {
                if let Some(v) = r.push(p as f64) {
                    writeln!(f, "{},{v}", *n as f64 * resample_s)?;
                    *n += 1;
                }
            }
        }
        Ok(())
    })?;
    if let Some((mut f, mut r, mut n, path)) = writer {
        if let Some((v, _)) = r.flush() {
            writeln!(f, "{},{v}", n as f64 * resample_s)?;
            n += 1;
        }
        f.flush()?;
        println!("wrote {path} ({n} rows @{resample_s}s, appended per {window_s}s window)");
    }
    let out = stats.finalize()?;
    print_facility_summary(
        spec,
        dt,
        &out.stats,
        out.exact_quantiles,
        out.p99_error_bound_w,
        t0.elapsed().as_secs_f64(),
    );
    Ok(())
}

fn print_facility_summary(
    spec: &ScenarioSpec,
    dt: f64,
    stats: &PlanningStats,
    exact: bool,
    p99_bound_w: f64,
    wall_s: f64,
) {
    println!(
        "facility: {} servers, {:.1} h, dt={dt}s → peak {:.3} MW avg {:.3} MW p99 {:.3} MW{} \
         energy {:.2} MWh PAR {:.2} ({:.1}s wall)",
        spec.topology.n_servers(),
        spec.horizon_s / 3600.0,
        stats.peak_w / 1e6,
        stats.avg_w / 1e6,
        stats.p99_w / 1e6,
        if exact { String::new() } else { format!(" (±{:.4} MW hist)", p99_bound_w / 1e6) },
        stats.energy_kwh / 1e3,
        stats.peak_to_average,
        wall_s
    );
}

fn cmd_site(args: &Args) -> Result<()> {
    use anyhow::Context as _;
    use powertrace_sim::robust::RunManifest;
    use powertrace_sim::site::{SiteGrid, SiteSpec, SITE_SWEEP_MANIFEST};
    if args.has("help") {
        println!("{}", usage("site", "compose N facilities into a utility-facing site profile", &[
            Opt { name: "site", help: "site spec JSON (facilities + phase offsets + nameplate)", default: None },
            Opt { name: "grid", help: "site sweep JSON (phase spreads × seeds over a base site); overrides --site", default: None },
            Opt { name: "resume", help: "resume a checkpointed site sweep from its manifest.json (or the directory holding it); done variants are restored, pending/failed ones re-run", default: None },
            Opt { name: "shard", help: "with --grid: run only shard i of N (format i/N, 0-based); variants partition deterministically by id hash, partials assemble with 'powertrace merge'", default: None },
            Opt { name: "max-retries", help: "retries per failing variant before quarantine (checkpointed sweeps)", default: Some("1") },
            Opt { name: "cell-timeout", help: "soft wall-clock budget per variant attempt (s; 0 = unlimited, checked at window boundaries)", default: Some("0") },
            Opt { name: "overlay", help: "net-load overlay JSON: an ordered array of stages ({kind: cap|battery|pv, ...}) appended to the (base) site's site-level overlays", default: None },
            Opt { name: "dt", help: "generation sample interval (s)", default: Some("1") },
            Opt { name: "window", help: "lockstep generation window (s); memory is O(facilities × window)", default: Some("3600") },
            Opt { name: "workers", help: "total worker budget across facilities (0 = auto)", default: Some("0") },
            Opt { name: "max-batch", help: "servers per batched classifier call (0 = auto)", default: Some("0") },
            Opt { name: "ramp", help: "headline ramp interval (s; clamped to horizon/2)", default: Some("900") },
            Opt { name: "load-interval", help: "site_load.csv export interval (s)", default: Some("60") },
            Opt { name: "out", help: "output directory (site_load.csv + site_summary.csv; with --grid, runs checkpointed with a manifest.json for --resume)", default: None },
            Opt { name: "backend", help: "classifier backend (windowed composition requires native)", default: Some("native") },
            Opt { name: "synth", help: "run on a synthetic random-weight artifact store (CI smokes / demos; no `make artifacts` needed)", default: None },
            Opt { name: "synth-seed", help: "seed of the synthetic artifact store (with --synth)", default: Some("7") },
        ]));
        return Ok(());
    }
    // `--overlay <list.json>`: ad-hoc site-level modulation — the stages
    // append to whatever the (base) spec already declares, so a committed
    // spec stays untouched while CI smokes and what-ifs bolt a battery or
    // cap on from the command line.
    let extra_overlays = match args.str_opt("overlay") {
        Some(opath) => {
            let v = powertrace_sim::util::json::parse_file(std::path::Path::new(opath))
                .map_err(anyhow::Error::from)?;
            powertrace_sim::site::OverlaySpec::list_from_json(&v)
                .with_context(|| format!("parsing overlay list {opath}"))?
        }
        None => Vec::new(),
    };
    let t0 = std::time::Instant::now();
    // --shard i/N partitions a --grid sweep's variants by id hash; see
    // `powertrace sweep --shard` and `powertrace merge`.
    let shard = match args.str_opt("shard") {
        Some(s) => Some(powertrace_sim::shard::Shard::parse(s)?),
        None => None,
    };
    if let Some(rpath) = args.str_opt("resume") {
        anyhow::ensure!(
            args.str_opt("grid").is_none() && args.str_opt("site").is_none(),
            "--resume and --grid/--site are mutually exclusive (the manifest records its grid)"
        );
        anyhow::ensure!(
            extra_overlays.is_empty(),
            "--resume: --overlay would alter the recorded grid; the manifest already carries \
             the overlays the sweep was launched with"
        );
        let mut mp = std::path::PathBuf::from(rpath);
        if mp.is_dir() {
            mp = mp.join(SITE_SWEEP_MANIFEST);
        }
        let m = RunManifest::load(&mp)?;
        anyhow::ensure!(
            m.kind == "site_sweep",
            "--resume: {} is a '{}' manifest, not a site-sweep manifest \
             (scenario sweeps resume via 'powertrace sweep --resume')",
            mp.display(),
            m.kind
        );
        let grid = SiteGrid::from_json(&m.grid).context("--resume: manifest grid")?;
        let dir = mp.parent().unwrap_or(std::path::Path::new(".")).to_path_buf();
        let options = RunOptions::defaults_for(RunKind::SiteSweep)
            .with_dt(args.f64_or("dt", m.options.f64_field("dt_s").unwrap_or(1.0))?)
            .with_window(args.f64_or("window", m.options.f64_field("window_s").unwrap_or(3600.0))?)
            .with_workers(args.usize_or("workers", 0)?)
            .with_max_batch(args.usize_or("max-batch", 0)?)
            .with_ramp_interval(
                args.f64_or("ramp", m.options.f64_field("ramp_interval_s").unwrap_or(900.0))?,
            )
            .with_load_interval({
                let mdefault = m.options.f64_field("load_interval_s").unwrap_or(60.0);
                args.f64_or("load-interval", mdefault)?
            })
            .with_max_retries(args.usize_or("max-retries", 1)? as u32)
            .with_cell_timeout(args.f64_or("cell-timeout", 0.0)?)
            // The manifest remembers the shard the run was launched with;
            // an explicit --shard overrides (e.g. '0/1' finishes unsharded).
            .with_shard(match shard {
                Some(sh) => Some(sh),
                None => m
                    .options
                    .str_field("shard")
                    .ok()
                    .map(|s| powertrace_sim::shard::Shard::parse(&s))
                    .transpose()
                    .context("--resume: manifest shard")?,
            });
        let mut gen = site_generator(args, &grid.base.config_ids())?;
        return run_site_sweep_ckpt(&mut gen, &grid, &options, &dir, t0);
    }
    let options = RunOptions::defaults_for(RunKind::Site)
        .with_dt(args.f64_or("dt", 1.0)?)
        .with_window(args.f64_or("window", 3600.0)?)
        .with_workers(args.usize_or("workers", 0)?)
        .with_max_batch(args.usize_or("max-batch", 0)?)
        .with_ramp_interval(args.f64_or("ramp", 900.0)?)
        .with_load_interval(args.f64_or("load-interval", 60.0)?)
        .with_max_retries(args.usize_or("max-retries", 1)? as u32)
        .with_cell_timeout(args.f64_or("cell-timeout", 0.0)?);
    let out = args.str_opt("out").map(std::path::PathBuf::from);
    if let Some(gpath) = args.str_opt("grid") {
        let options = options.with_shard(shard);
        let mut grid = SiteGrid::load(std::path::Path::new(gpath))?;
        grid.base.overlays.extend(extra_overlays);
        grid.validate()?;
        let mut gen = site_generator(args, &grid.base.config_ids())?;
        // With an output directory the sweep runs checkpointed (per-variant
        // fault isolation + manifest for --resume); summary bytes match the
        // plain path either way.
        if let Some(dir) = &out {
            return run_site_sweep_ckpt(&mut gen, &grid, &options, dir, t0);
        }
        let req = RunRequest { spec: RunSpec::SiteSweep(grid.clone()), options };
        let RunOutcome::SiteSweep(results) = api::execute(&mut gen, &req, None)? else {
            unreachable!("site_sweep request yields a site_sweep outcome")
        };
        println!(
            "site sweep '{}': {} variants × {} facilities ({:.1}s wall)\n",
            grid.name,
            results.len(),
            grid.base.facilities.len(),
            t0.elapsed().as_secs_f64()
        );
        for (v, r) in &results {
            println!("-- {} ({}) --", v.id, v.label);
            print!("{}", r.summary_table());
        }
        return Ok(());
    }
    anyhow::ensure!(
        shard.is_none(),
        "--shard partitions a sweep's variants; a single --site run has no grid to shard \
         (use --grid <sweep.json>)"
    );
    let spath = args.str_opt("site").ok_or_else(|| {
        anyhow::anyhow!("--site <spec.json> (or --grid <sweep.json>) is required; see 'powertrace site --help'")
    })?;
    let mut spec = SiteSpec::load(std::path::Path::new(spath))?;
    spec.overlays.extend(extra_overlays);
    spec.validate()?;
    let mut gen = site_generator(args, &spec.config_ids())?;
    let sink = out.as_ref().map(powertrace_sim::export::DirSink::new);
    let req = RunRequest { spec: RunSpec::Site(spec.clone()), options };
    let RunOutcome::Site(report) = api::execute(
        &mut gen,
        &req,
        sink.as_ref().map(|s| s as &dyn powertrace_sim::export::TraceSink),
    )?
    else {
        unreachable!("site request yields a site outcome")
    };
    println!(
        "site '{}': {} facilities, {} servers, {:.1} h horizon, dt={}s, {}s windows ({:.1}s wall)",
        spec.name,
        spec.facilities.len(),
        spec.n_servers(),
        spec.horizon_s() / 3600.0,
        req.options.dt_s,
        req.options.window_s,
        t0.elapsed().as_secs_f64()
    );
    print!("{}", report.summary_table());
    if let Some(dir) = &out {
        println!("wrote site_load.csv + site_summary.csv under {}", dir.display());
    }
    Ok(())
}

/// Checkpointed site-sweep execution shared by `--grid --out` and
/// `--resume`: run (or finish) the sweep, print per-variant tables for the
/// variants executed this run, and fail with a resume hint if any variant
/// was quarantined.
fn run_site_sweep_ckpt(
    gen: &mut Generator,
    grid: &powertrace_sim::site::SiteGrid,
    options: &RunOptions,
    dir: &std::path::Path,
    t0: std::time::Instant,
) -> Result<()> {
    // SIGINT/SIGTERM drain cooperatively from here on: the manifest
    // flushes and --resume re-runs exactly the still-pending variants.
    powertrace_sim::robust::shutdown::install_handlers();
    let req = RunRequest { spec: RunSpec::SiteSweep(grid.clone()), options: options.clone() };
    let api::CheckpointedOutcome::SiteSweep(outcome) = api::execute_checkpointed(gen, &req, dir)?
    else {
        unreachable!("site_sweep request yields a site_sweep outcome")
    };
    println!(
        "site sweep '{}': {} variants ({} run, {} restored, {} quarantined) × {} facilities ({:.1}s wall)\n",
        grid.name,
        grid.n_variants(),
        outcome.executed.len(),
        outcome.restored,
        outcome.failed.len(),
        grid.base.facilities.len(),
        t0.elapsed().as_secs_f64()
    );
    for (v, r) in &outcome.executed {
        println!("-- {} ({}) --", v.id, v.label);
        print!("{}", r.summary_table());
    }
    println!("\nwrote site_sweep_summary.csv + manifest.json under {}", dir.display());
    if let Some(sh) = options.shard {
        println!(
            "shard {sh}: site_sweep_summary.csv covers only this shard's variants; \
             assemble all shards with 'powertrace merge <dir>... --out <merged>'"
        );
    }
    if outcome.interrupted > 0 {
        anyhow::bail!(
            "interrupted: {} variant(s) still pending (manifest is consistent); \
             finish with --resume {}",
            outcome.interrupted,
            outcome.manifest_path.display()
        );
    }
    if !outcome.failed.is_empty() {
        for q in &outcome.failed {
            eprintln!("quarantined {} after {} attempt(s): {}", q.id, q.attempts, q.reason);
        }
        anyhow::bail!(
            "{} variant(s) quarantined; fix the cause and re-run with --resume {}",
            outcome.failed.len(),
            outcome.manifest_path.display()
        );
    }
    Ok(())
}

/// Generator for `powertrace site`: the named backend, or — with
/// `--synth` — the native backend over a synthetic random-weight artifact
/// store covering exactly the configurations the spec references (CI
/// smokes and demos run without `make artifacts`; traces are
/// deterministic per seed but statistically meaningless).
fn site_generator(args: &Args, config_ids: &[String]) -> Result<Generator> {
    if args.has("synth") {
        let cat = Catalog::load_default()?;
        let root = powertrace_sim::testutil::synth_artifact_store(
            "site_cli",
            16,
            6,
            config_ids,
            args.u64_or("synth-seed", 7)?,
        );
        let store = powertrace_sim::artifacts::ArtifactStore::open(&root)?;
        Ok(Generator::native_with(cat, store))
    } else {
        Generator::with_backend(&args.str_or("backend", "native"))
    }
}

fn cmd_diff(args: &Args) -> Result<()> {
    use powertrace_sim::scenarios::diff_summary_files;
    if args.has("help") {
        println!("{}", usage("diff <a.csv> <b.csv>", "compare two summary CSVs cell-by-cell", &[
            Opt { name: "tolerance", help: "max relative difference per numeric cell", default: Some("0") },
        ]));
        return Ok(());
    }
    let (a, b) = match (args.positional.first(), args.positional.get(1)) {
        (Some(a), Some(b)) => (a, b),
        _ => anyhow::bail!("usage: powertrace diff <a.csv> <b.csv> [--tolerance 1e-9]"),
    };
    let tolerance = args.f64_or("tolerance", 0.0)?;
    let report = diff_summary_files(
        std::path::Path::new(a),
        std::path::Path::new(b),
        tolerance,
    )?;
    if report.is_match() {
        println!(
            "summaries match: {} row(s), {} cell(s) within tolerance {tolerance}",
            report.rows_compared, report.cells_compared
        );
        Ok(())
    } else {
        print!("{}", report.render());
        std::process::exit(1);
    }
}

fn cmd_sweep(args: &Args) -> Result<()> {
    use anyhow::Context as _;
    use powertrace_sim::robust::RunManifest;
    use powertrace_sim::scenarios::SWEEP_MANIFEST;
    if args.has("help") {
        println!("{}", usage("sweep", "expand a scenario grid and run every cell", &[
            Opt { name: "grid", help: "sweep grid JSON (see scenarios module docs)", default: None },
            Opt { name: "dt", help: "generation sample interval (s)", default: Some("0.25") },
            Opt { name: "ramp", help: "ramp interval (s; clamped to horizon/2)", default: Some("900") },
            Opt { name: "out", help: "output directory for CSV/JSON export (runs checkpointed: a manifest.json records per-cell progress for --resume)", default: None },
            Opt { name: "resume", help: "resume a checkpointed sweep from its manifest.json (or the directory holding it); done cells are restored, pending/failed cells re-run", default: None },
            Opt { name: "shard", help: "run only shard i of N (format i/N, 0-based): cells partition deterministically by id hash; partial outputs assemble with 'powertrace merge'", default: None },
            Opt { name: "max-retries", help: "retries per failing cell before quarantine (checkpointed runs)", default: Some("1") },
            Opt { name: "cell-timeout", help: "soft wall-clock budget per cell attempt (s; 0 = unlimited, checked at window boundaries)", default: Some("0") },
            Opt { name: "workers", help: "concurrent scenarios (0 = auto)", default: Some("0") },
            Opt { name: "server-workers", help: "threads per scenario (0 = auto)", default: Some("0") },
            Opt { name: "max-batch", help: "servers per batched classifier call (0 = auto, 1 = sequential)", default: Some("0") },
            Opt { name: "window", help: "streaming window (s; 0 = buffered). Cells generate window-by-window with O(racks × window) memory and CSVs stream into --out", default: Some("0") },
            Opt { name: "horizon", help: "horizon for the built-in demo grid (s)", default: Some("600") },
            Opt { name: "backend", help: "classifier backend (native|pjrt; streaming requires native)", default: Some("pjrt") },
            Opt { name: "synth", help: "run on a synthetic random-weight artifact store (CI smokes / demos; no `make artifacts` needed; requires --grid or --resume)", default: None },
            Opt { name: "synth-seed", help: "seed of the synthetic artifact store (with --synth)", default: Some("7") },
        ]));
        return Ok(());
    }
    // Resolve the grid (and option defaults) before building a generator:
    // a --resume run re-reads both from the manifest, so the resumed run
    // is byte-compatible with the interrupted one by construction.
    let resume = match args.str_opt("resume") {
        Some(p) => {
            anyhow::ensure!(
                args.str_opt("grid").is_none(),
                "--resume and --grid are mutually exclusive (the manifest records its grid)"
            );
            let mut mp = std::path::PathBuf::from(p);
            if mp.is_dir() {
                mp = mp.join(SWEEP_MANIFEST);
            }
            let m = RunManifest::load(&mp)?;
            anyhow::ensure!(
                m.kind == "sweep",
                "--resume: {} is a '{}' manifest, not a scenario-sweep manifest \
                 (site sweeps resume via 'powertrace site --resume')",
                mp.display(),
                m.kind
            );
            Some((m, mp))
        }
        None => None,
    };
    let loaded = match (&resume, args.str_opt("grid")) {
        (Some((m, _)), _) => {
            Some(SweepGrid::from_json(&m.grid).context("--resume: manifest grid")?)
        }
        (None, Some(path)) => Some(SweepGrid::load(std::path::Path::new(path))?),
        (None, None) => None,
    };
    let mut gen = if args.has("synth") {
        // Mirror `powertrace site --synth`: a deterministic random-weight
        // store over exactly the configs the grid references.
        let Some(grid) = loaded.as_ref() else {
            anyhow::bail!(
                "--synth requires --grid or --resume (the store is built from the grid's config ids)"
            );
        };
        let cat = Catalog::load_default()?;
        let root = powertrace_sim::testutil::synth_artifact_store(
            "sweep_cli",
            16,
            6,
            &grid.config_ids(),
            args.u64_or("synth-seed", 7)?,
        );
        let store = powertrace_sim::artifacts::ArtifactStore::open(&root)?;
        Generator::native_with(cat, store)
    } else {
        let backend = args.str_or("backend", "pjrt");
        match Generator::with_backend(&backend) {
            Ok(g) => g,
            Err(e) if backend == "pjrt" => {
                eprintln!("note: pjrt backend unavailable ({e:#}); falling back to native");
                Generator::native()?
            }
            Err(e) => return Err(e),
        }
    };
    let grid = match loaded {
        Some(grid) => grid,
        None => {
            let horizon = args.f64_or("horizon", 600.0)?;
            let ids = gen.store.manifest.configs.clone();
            if ids.is_empty() {
                anyhow::bail!("artifact manifest lists no configs; cannot build the demo grid");
            }
            eprintln!("note: no --grid given; running the built-in demo grid");
            SweepGrid::example("demo", &ids, horizon)
        }
    };
    // Explicit flags still win on resume, but the manifest supplies the
    // defaults the run was launched with (a mismatched dt/ramp is then
    // caught by the manifest's content-hash check).
    let (mdt, mramp, mwindow) = match &resume {
        Some((m, _)) => (
            m.options.f64_field("dt_s").unwrap_or(0.25),
            m.options.f64_field("ramp_interval_s").unwrap_or(900.0),
            m.options.f64_field("window_s").unwrap_or(0.0),
        ),
        None => (0.25, 900.0, 0.0),
    };
    // --shard i/N runs only the cells this process owns (stable id hash);
    // on --resume the manifest supplies the shard the run was launched
    // with, and an explicit flag overrides it (e.g. to finish unsharded).
    let shard = match args.str_opt("shard") {
        Some(s) => Some(powertrace_sim::shard::Shard::parse(s)?),
        None => match &resume {
            Some((m, _)) => m
                .options
                .str_field("shard")
                .ok()
                .map(|s| powertrace_sim::shard::Shard::parse(&s))
                .transpose()
                .context("--resume: manifest shard")?,
            None => None,
        },
    };
    let options = RunOptions::defaults_for(RunKind::Sweep)
        .with_dt(args.f64_or("dt", mdt)?)
        .with_ramp_interval(args.f64_or("ramp", mramp)?)
        .with_workers(args.usize_or("workers", 0)?)
        .with_server_workers(args.usize_or("server-workers", 0)?)
        .with_max_batch(args.usize_or("max-batch", 0)?)
        .with_window(args.f64_or("window", mwindow)?)
        .with_max_retries(args.usize_or("max-retries", 1)? as u32)
        .with_cell_timeout(args.f64_or("cell-timeout", 0.0)?)
        .with_shard(shard);
    let t0 = std::time::Instant::now();
    let out_dir = match &resume {
        Some((_, mp)) => Some(mp.parent().unwrap_or(std::path::Path::new(".")).to_path_buf()),
        None => args.str_opt("out").map(std::path::PathBuf::from),
    };
    // With an output directory the sweep runs checkpointed: per-cell fault
    // isolation + a manifest for --resume. Summary bytes are identical to
    // the plain path (same header, same rows, grid order).
    if let Some(dir) = &out_dir {
        // SIGINT/SIGTERM drain cooperatively: the manifest flushes and
        // --resume re-runs exactly the still-pending cells.
        powertrace_sim::robust::shutdown::install_handlers();
        let req = RunRequest { spec: RunSpec::Sweep(grid.clone()), options };
        let api::CheckpointedOutcome::Sweep(outcome) =
            api::execute_checkpointed(&mut gen, &req, dir)?
        else {
            unreachable!("sweep request yields a sweep outcome")
        };
        println!(
            "sweep '{}': {} cells ({} run, {} restored, {} quarantined), dt={}s ({:.1}s wall)\n",
            grid.name,
            grid.n_cells(),
            outcome.report.cells.len(),
            outcome.restored,
            outcome.failed.len(),
            req.options.dt_s,
            t0.elapsed().as_secs_f64()
        );
        print!("{}", outcome.report.summary_table());
        println!("\nwrote summary.csv + manifest.json under {}", dir.display());
        if let Some(sh) = req.options.shard {
            println!(
                "shard {sh}: summary.csv covers only this shard's cells; \
                 assemble all shards with 'powertrace merge <dir>... --out <merged>'"
            );
        }
        if outcome.interrupted > 0 {
            anyhow::bail!(
                "interrupted: {} cell(s) still pending (manifest is consistent); \
                 finish with --resume {}",
                outcome.interrupted,
                outcome.manifest_path.display()
            );
        }
        if !outcome.failed.is_empty() {
            for q in &outcome.failed {
                eprintln!("quarantined {} after {} attempt(s): {}", q.id, q.attempts, q.reason);
            }
            anyhow::bail!(
                "{} cell(s) quarantined; fix the cause and re-run with --resume {}",
                outcome.failed.len(),
                outcome.manifest_path.display()
            );
        }
        return Ok(());
    }
    let req = RunRequest { spec: RunSpec::Sweep(grid.clone()), options };
    let RunOutcome::Sweep(report) = api::execute(&mut gen, &req, None)? else {
        unreachable!("sweep request yields a sweep outcome")
    };
    println!(
        "sweep '{}': {} cells × {} servers/cell-max, dt={}s ({:.1}s wall)\n",
        grid.name,
        report.cells.len(),
        grid.topologies.iter().map(|t| t.n_servers()).max().unwrap_or(0),
        req.options.dt_s,
        t0.elapsed().as_secs_f64()
    );
    print!("{}", report.summary_table());
    Ok(())
}

/// `powertrace merge <dir|manifest>... --out <dir>` — assemble the partial
/// outputs of sharded sweep runs (`--shard i/N`) into the summary an
/// unsharded run would have written, byte for byte. See
/// `robust::merge::merge_manifests` for the union rules.
fn cmd_merge(args: &Args) -> Result<()> {
    use powertrace_sim::robust::merge::merge_manifests;
    if args.has("help") {
        println!("{}", usage(
            "merge <run-dir|manifest.json>...",
            "assemble sharded sweep runs into one summary + resumable manifest",
            &[
                Opt { name: "out", help: "output directory (merged manifest.json + summary CSV + grid snapshot)", default: None },
                Opt { name: "allow-partial", help: "write the merged summary even if some cells are failed or were never run", default: None },
            ],
        ));
        return Ok(());
    }
    let inputs: Vec<std::path::PathBuf> =
        args.positional.iter().map(std::path::PathBuf::from).collect();
    anyhow::ensure!(
        !inputs.is_empty(),
        "usage: powertrace merge <run-dir|manifest.json>... --out <dir> [--allow-partial]"
    );
    let out = args
        .str_opt("out")
        .ok_or_else(|| anyhow::anyhow!("--out <dir> is required (the merged run directory)"))?;
    let rep = merge_manifests(&inputs, std::path::Path::new(out), args.has("allow-partial"))?;
    println!(
        "merged {} input(s): {} run '{}' — {}/{} cells done",
        rep.inputs,
        rep.kind,
        out,
        rep.done,
        rep.cells
    );
    println!("wrote {} + {}", rep.summary_path.display(), rep.manifest_path.display());
    for id in &rep.failed {
        eprintln!("quarantined in inputs: {id}");
    }
    if !rep.failed.is_empty() || !rep.pending.is_empty() {
        println!(
            "{} cell(s) outstanding ({} failed, {} pending); finish with \
             'powertrace {} --resume {}'",
            rep.failed.len() + rep.pending.len(),
            rep.failed.len(),
            rep.pending.len(),
            if rep.kind == "sweep" { "sweep" } else { "site" },
            rep.manifest_path.display()
        );
    }
    Ok(())
}

fn cmd_repro(args: &Args) -> Result<()> {
    let id = args.positional.first().map(|s| s.as_str()).unwrap_or("all");
    experiments::run(id, args)
}

fn cmd_fit(args: &Args) -> Result<()> {
    let store = powertrace_sim::artifacts::ArtifactStore::open_default()?;
    let default_id = store.manifest.configs[0].clone();
    let id = args.str_or("config", &default_id);
    let traces = store.load_all_measured(&id)?;
    let pooled: Vec<f32> = traces.iter().flat_map(|m| m.power_w.iter().copied()).collect();
    let mut rng = Rng::new(args.u64_or("seed", 0)?);
    let kmax = args.usize_or("kmax", 12)?;
    let (gmm, curve) = select_k(&pooled, 1..=kmax, &EmOptions::default(), &mut rng)?;
    println!("GMM fit for {id} over {} samples:", pooled.len());
    println!("  BIC-selected K = {}", curve.best_k);
    for j in 0..gmm.k() {
        println!("  state {j}: π={:.3} μ={:.1} W σ={:.1} W", gmm.pi[j], gmm.mu[j], gmm.sigma[j]);
    }
    Ok(())
}

fn cmd_testbed(args: &Args) -> Result<()> {
    let cat = Catalog::load_default()?;
    let id = args.str_or("config", "llama70b_a100_tp8");
    let cfg = cat.config(&id)?;
    let rate = args.f64_or("rate", 0.5)?;
    let horizon = args.f64_or("horizon", 600.0)?;
    let profile = cat.datasets.get("sharegpt").unwrap();
    let lengths = LengthSampler::from_profile(profile, 1.0);
    let mut rng = Rng::new(args.u64_or("seed", 0)?);
    let sched = poisson_arrivals(rate, horizon, &lengths, &mut rng);
    let opts = testbed::EngineOptions::from_catalog(&cat, horizon);
    let tr = testbed::simulate(&cat, cfg, &sched, &opts, &mut rng);
    let stats = PlanningStats::compute(&tr.power_w, opts.dt_sample, 60.0)?;
    println!(
        "testbed {id} λ={rate}: {} samples, peak {:.0} W avg {:.0} W, {} requests completed",
        tr.power_w.len(),
        stats.peak_w,
        stats.avg_w,
        tr.durations.len()
    );
    Ok(())
}

fn cmd_info(_args: &Args) -> Result<()> {
    let cat = Catalog::load_default()?;
    println!(
        "catalog: {} GPUs, {} models, {} datasets, {} configs",
        cat.gpus.len(),
        cat.models.len(),
        cat.datasets.len(),
        cat.configs.len()
    );
    for c in &cat.configs {
        let m = cat.model_of(c);
        println!("  {:<24} {} TP={} ({:?})", c.id, cat.gpu_of(c).name, c.tp, m.kind);
    }
    match powertrace_sim::artifacts::ArtifactStore::open_default() {
        Ok(store) => {
            println!(
                "artifacts: {} configs trained, chunk T={} halo={}, hlo={}",
                store.manifest.configs.len(),
                store.manifest.chunk.t,
                store.manifest.chunk.halo,
                store.manifest.hlo
            );
        }
        Err(e) => println!("artifacts: not built ({e})"),
    }
    Ok(())
}

/// `powertrace serve` — the live planning service (feature `serve`).
///
/// One warm generator, HTTP in front, NDJSON out: see
/// `rust/src/serve/mod.rs` and README §"Planning service".
#[cfg(feature = "serve")]
fn cmd_serve(args: &Args) -> Result<()> {
    use powertrace_sim::serve::{ServeConfig, Server};
    use std::sync::atomic::AtomicBool;
    use std::sync::Arc;
    if args.has("help") {
        println!("{}", usage("serve", "serve RunRequests over HTTP, streaming NDJSON windows", &[
            Opt { name: "addr", help: "bind address (port 0 picks a free port)", default: Some("127.0.0.1:8791") },
            Opt { name: "max-runs", help: "concurrent-run cap; excess requests queue", default: Some("2") },
            Opt { name: "runs-dir", help: "run sweep kinds checkpointed under <dir>/<run-id>/", default: None },
            Opt { name: "refresh-interval", help: "artifact-store re-check cadence in seconds (0 = off)", default: Some("0") },
            Opt { name: "backend", help: "native | pjrt", default: Some("native") },
            Opt { name: "synth", help: "serve from a synthetic random-weight artifact store", default: None },
            Opt { name: "synth-configs", help: "comma-separated config ids for --synth (required with it)", default: None },
            Opt { name: "synth-seed", help: "seed for the synthetic store", default: Some("7") },
        ]));
        return Ok(());
    }
    let cfg = ServeConfig {
        addr: args.str_or("addr", "127.0.0.1:8791"),
        max_concurrent_runs: args.usize_or("max-runs", 2)?,
        runs_dir: args.str_opt("runs-dir").map(std::path::PathBuf::from),
        refresh_interval_s: args.f64_or("refresh-interval", 0.0)?,
    };
    let mut gen = if args.has("synth") {
        // Synthetic-store bytes depend on the full *ordered* config-id
        // list (one sequential RNG spans all configs), so the serving set
        // must be stated up front to match any batch run's bytes.
        let ids: Vec<String> = args
            .str_opt("synth-configs")
            .map(|s| s.split(',').map(|c| c.trim().to_string()).filter(|c| !c.is_empty()).collect())
            .unwrap_or_default();
        if ids.is_empty() {
            anyhow::bail!(
                "--synth needs --synth-configs <id,id,...>: synthetic store bytes \
                 depend on the full ordered config list, so it cannot be grown per request"
            );
        }
        let cat = Catalog::load_default()?;
        let root = powertrace_sim::testutil::synth_artifact_store(
            "serve_cli",
            16,
            6,
            &ids,
            args.u64_or("synth-seed", 7)?,
        );
        let store = powertrace_sim::artifacts::ArtifactStore::open(&root)?;
        let mut g = Generator::native_with(cat, store);
        for id in &ids {
            g.prepare(id)?;
        }
        g
    } else {
        Generator::with_backend(&args.str_or("backend", "native"))?
    };
    // Pre-warm everything the store already has; requests for configs
    // outside this set still prepare on demand.
    if !args.has("synth") {
        let ids = gen.store.manifest.configs.clone();
        for id in &ids {
            gen.prepare(id)?;
        }
    }
    powertrace_sim::robust::shutdown::install_handlers();
    let server = Server::new(gen, &cfg)?;
    let addr = server.local_addr()?;
    println!("powertrace serve listening on http://{addr}");
    println!("  POST /v1/runs       RunRequest {{kind, spec, options}} → NDJSON stream");
    println!("  GET  /v1/runs/:id   run status (+ manifest counts with --runs-dir)");
    println!("  GET  /healthz       liveness + prepared configs + active runs");
    println!("  GET  /v1/catalog    serving configurations");
    server.run(Arc::new(AtomicBool::new(false)))
}

#[cfg(not(feature = "serve"))]
fn cmd_serve(_args: &Args) -> Result<()> {
    anyhow::bail!(
        "this binary was built without the `serve` feature; \
         rebuild with `cargo build --release --features serve`"
    )
}
