//! `powertrace` — the planner-facing CLI (paper §3.1).
//!
//! Subcommands:
//!   generate   one server power trace from a workload scenario
//!   facility   facility-scale run from a scenario JSON
//!   sweep      expand a scenario grid and run every cell (multi-scale export)
//!   repro      regenerate a paper table/figure (or `all`)
//!   fit        Rust-side GMM+BIC refit on held-out measured traces
//!   testbed    run the synthetic measurement testbed (ground truth)
//!   info       catalog + artifact inventory

use anyhow::Result;
use powertrace_sim::catalog::Catalog;
use powertrace_sim::config::ScenarioSpec;
use powertrace_sim::coordinator::Generator;
use powertrace_sim::experiments;
use powertrace_sim::metrics::PlanningStats;
use powertrace_sim::scenarios::{run_sweep, SweepGrid, SweepOptions};
use powertrace_sim::states::{select_k, EmOptions};
use powertrace_sim::testbed;
use powertrace_sim::util::cli::{usage, Args, Opt};
use powertrace_sim::util::rng::Rng;
use powertrace_sim::workload::{poisson_arrivals, LengthSampler};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print_help();
        std::process::exit(2);
    }
    let cmd = argv[0].clone();
    let args = Args::parse(argv[1..].iter().cloned());
    let result = match cmd.as_str() {
        "generate" => cmd_generate(&args),
        "facility" => cmd_facility(&args),
        "sweep" => cmd_sweep(&args),
        "repro" => cmd_repro(&args),
        "fit" => cmd_fit(&args),
        "testbed" => cmd_testbed(&args),
        "info" => cmd_info(&args),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'");
            print_help();
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "powertrace — compositional LLM-inference power trace generation\n\
         \n\
         usage: powertrace <command> [options]\n\
         \n\
         commands:\n\
           generate   generate one server power trace (Poisson workload)\n\
           facility   run a facility scenario (JSON spec) → site load shape\n\
           sweep      expand a scenario grid (JSON), run every cell in\n\
                      parallel, export multi-scale series + summary\n\
           repro      reproduce a paper table/figure: {} | all\n\
           fit        fit GMM power states on held-out measured traces\n\
           testbed    run the ground-truth measurement testbed\n\
           info       show catalog and artifact inventory\n\
         \n\
         common options: --backend native|pjrt  --seed N  --fast",
        experiments::ALL.join(" | ")
    );
}

fn cmd_generate(args: &Args) -> Result<()> {
    if args.has("help") {
        println!("{}", usage("generate", "generate one server power trace", &[
            Opt { name: "config", help: "serving configuration id", default: Some("llama70b_a100_tp8") },
            Opt { name: "rate", help: "Poisson arrival rate (req/s)", default: Some("0.5") },
            Opt { name: "horizon", help: "trace length (s)", default: Some("600") },
            Opt { name: "dataset", help: "length profile", default: Some("sharegpt") },
            Opt { name: "seed", help: "RNG seed", default: Some("0") },
            Opt { name: "backend", help: "classifier backend (native|pjrt)", default: Some("pjrt") },
            Opt { name: "out", help: "CSV output path", default: None },
        ]));
        return Ok(());
    }
    let mut gen = Generator::with_backend(&args.str_or("backend", "pjrt"))?;
    let id = args.str_or("config", "llama70b_a100_tp8");
    let rate = args.f64_or("rate", 0.5)?;
    let horizon = args.f64_or("horizon", 600.0)?;
    let seed = args.u64_or("seed", 0)?;
    let art = gen.config(&id)?;
    let cls = gen.classifier(&art)?;
    let profile = gen
        .cat
        .datasets
        .get(&args.str_or("dataset", "sharegpt"))
        .ok_or_else(|| anyhow::anyhow!("unknown dataset"))?
        .clone();
    let lengths = LengthSampler::from_profile(&profile, 1.0);
    let mut rng = Rng::new(seed);
    let sched = poisson_arrivals(rate, horizon, &lengths, &mut rng);
    let tr = gen.server_trace(&art, &cls, &sched, horizon, 0.25, &mut rng)?;
    let stats = PlanningStats::compute(&tr.power_w, 0.25, 60.0);
    println!(
        "generated {} samples @250ms for {id} (λ={rate}): peak {:.0} W, avg {:.0} W, PAR {:.2}",
        tr.power_w.len(),
        stats.peak_w,
        stats.avg_w,
        stats.peak_to_average
    );
    if let Some(out) = args.str_opt("out") {
        let mut s = String::from("t_s,power_w,a\n");
        for (i, (&p, &a)) in tr.power_w.iter().zip(&tr.a).enumerate() {
            s.push_str(&format!("{},{p},{a}\n", i as f64 * 0.25));
        }
        std::fs::write(out, s)?;
        println!("wrote {out}");
    }
    Ok(())
}

fn cmd_facility(args: &Args) -> Result<()> {
    let mut gen = Generator::with_backend(&args.str_or("backend", "pjrt"))?;
    let spec = match args.str_opt("scenario") {
        Some(path) => ScenarioSpec::load(std::path::Path::new(path))?,
        None => {
            let mut s = ScenarioSpec::default_poisson("llama70b_a100_tp8", 0.5);
            s.topology = powertrace_sim::aggregate::Topology {
                rows: 2,
                racks_per_row: 3,
                servers_per_rack: 4,
            };
            s
        }
    };
    let dt = args.f64_or("dt", 1.0)?;
    let workers = args.usize_or("workers", 0)?;
    let t0 = std::time::Instant::now();
    let result = gen.facility(&spec, dt, workers)?;
    let site = result.facility_series();
    let stats = PlanningStats::compute(&site, dt, 900.0);
    println!(
        "facility: {} servers, {:.1} h, dt={dt}s → peak {:.3} MW avg {:.3} MW PAR {:.2} ({:.1}s wall)",
        spec.topology.n_servers(),
        spec.horizon_s / 3600.0,
        stats.peak_w / 1e6,
        stats.avg_w / 1e6,
        stats.peak_to_average,
        t0.elapsed().as_secs_f64()
    );
    if let Some(out) = args.str_opt("out") {
        let resample_s = args.f64_or("resample", 900.0)?;
        let series = powertrace_sim::aggregate::resample(&site, dt, resample_s);
        let mut s = String::from("t_s,facility_w\n");
        for (i, &p) in series.iter().enumerate() {
            s.push_str(&format!("{},{p}\n", i as f64 * resample_s));
        }
        std::fs::write(out, s)?;
        println!("wrote {out}");
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    if args.has("help") {
        println!("{}", usage("sweep", "expand a scenario grid and run every cell", &[
            Opt { name: "grid", help: "sweep grid JSON (see scenarios module docs)", default: None },
            Opt { name: "dt", help: "generation sample interval (s)", default: Some("0.25") },
            Opt { name: "ramp", help: "ramp interval (s; clamped to horizon/2)", default: Some("900") },
            Opt { name: "out", help: "output directory for CSV/JSON export", default: None },
            Opt { name: "workers", help: "concurrent scenarios (0 = auto)", default: Some("0") },
            Opt { name: "server-workers", help: "threads per scenario (0 = auto)", default: Some("0") },
            Opt { name: "max-batch", help: "servers per batched classifier call (0 = auto, 1 = sequential)", default: Some("0") },
            Opt { name: "horizon", help: "horizon for the built-in demo grid (s)", default: Some("600") },
            Opt { name: "backend", help: "classifier backend (native|pjrt)", default: Some("pjrt") },
        ]));
        return Ok(());
    }
    let backend = args.str_or("backend", "pjrt");
    let mut gen = match Generator::with_backend(&backend) {
        Ok(g) => g,
        Err(e) if backend == "pjrt" => {
            eprintln!("note: pjrt backend unavailable ({e:#}); falling back to native");
            Generator::native()?
        }
        Err(e) => return Err(e),
    };
    let grid = match args.str_opt("grid") {
        Some(path) => SweepGrid::load(std::path::Path::new(path))?,
        None => {
            let horizon = args.f64_or("horizon", 600.0)?;
            let ids = gen.store.manifest.configs.clone();
            if ids.is_empty() {
                anyhow::bail!("artifact manifest lists no configs; cannot build the demo grid");
            }
            eprintln!("note: no --grid given; running the built-in demo grid");
            SweepGrid::example("demo", &ids, horizon)
        }
    };
    let opts = SweepOptions {
        dt_s: args.f64_or("dt", 0.25)?,
        ramp_interval_s: args.f64_or("ramp", 900.0)?,
        scenario_workers: args.usize_or("workers", 0)?,
        server_workers: args.usize_or("server-workers", 0)?,
        max_batch: args.usize_or("max-batch", 0)?,
        ..SweepOptions::default()
    };
    let t0 = std::time::Instant::now();
    let report = run_sweep(&mut gen, &grid, &opts)?;
    println!(
        "sweep '{}': {} cells × {} servers/cell-max, dt={}s ({:.1}s wall)\n",
        grid.name,
        report.cells.len(),
        grid.topologies.iter().map(|t| t.n_servers()).max().unwrap_or(0),
        opts.dt_s,
        t0.elapsed().as_secs_f64()
    );
    print!("{}", report.summary_table());
    if let Some(out) = args.str_opt("out") {
        let dir = std::path::Path::new(out);
        report.write(dir)?;
        println!("\nwrote {} cells + summary.csv under {}", report.cells.len(), dir.display());
    }
    Ok(())
}

fn cmd_repro(args: &Args) -> Result<()> {
    let id = args.positional.first().map(|s| s.as_str()).unwrap_or("all");
    experiments::run(id, args)
}

fn cmd_fit(args: &Args) -> Result<()> {
    let store = powertrace_sim::artifacts::ArtifactStore::open_default()?;
    let default_id = store.manifest.configs[0].clone();
    let id = args.str_or("config", &default_id);
    let traces = store.load_all_measured(&id)?;
    let pooled: Vec<f32> = traces.iter().flat_map(|m| m.power_w.iter().copied()).collect();
    let mut rng = Rng::new(args.u64_or("seed", 0)?);
    let kmax = args.usize_or("kmax", 12)?;
    let (gmm, curve) = select_k(&pooled, 1..=kmax, &EmOptions::default(), &mut rng)?;
    println!("GMM fit for {id} over {} samples:", pooled.len());
    println!("  BIC-selected K = {}", curve.best_k);
    for j in 0..gmm.k() {
        println!("  state {j}: π={:.3} μ={:.1} W σ={:.1} W", gmm.pi[j], gmm.mu[j], gmm.sigma[j]);
    }
    Ok(())
}

fn cmd_testbed(args: &Args) -> Result<()> {
    let cat = Catalog::load_default()?;
    let id = args.str_or("config", "llama70b_a100_tp8");
    let cfg = cat.config(&id)?;
    let rate = args.f64_or("rate", 0.5)?;
    let horizon = args.f64_or("horizon", 600.0)?;
    let profile = cat.datasets.get("sharegpt").unwrap();
    let lengths = LengthSampler::from_profile(profile, 1.0);
    let mut rng = Rng::new(args.u64_or("seed", 0)?);
    let sched = poisson_arrivals(rate, horizon, &lengths, &mut rng);
    let opts = testbed::EngineOptions::from_catalog(&cat, horizon);
    let tr = testbed::simulate(&cat, cfg, &sched, &opts, &mut rng);
    let stats = PlanningStats::compute(&tr.power_w, opts.dt_sample, 60.0);
    println!(
        "testbed {id} λ={rate}: {} samples, peak {:.0} W avg {:.0} W, {} requests completed",
        tr.power_w.len(),
        stats.peak_w,
        stats.avg_w,
        tr.durations.len()
    );
    Ok(())
}

fn cmd_info(_args: &Args) -> Result<()> {
    let cat = Catalog::load_default()?;
    println!(
        "catalog: {} GPUs, {} models, {} datasets, {} configs",
        cat.gpus.len(),
        cat.models.len(),
        cat.datasets.len(),
        cat.configs.len()
    );
    for c in &cat.configs {
        let m = cat.model_of(c);
        println!("  {:<24} {} TP={} ({:?})", c.id, cat.gpu_of(c).name, c.tp, m.kind);
    }
    match powertrace_sim::artifacts::ArtifactStore::open_default() {
        Ok(store) => {
            println!(
                "artifacts: {} configs trained, chunk T={} halo={}, hlo={}",
                store.manifest.configs.len(),
                store.manifest.chunk.t,
                store.manifest.chunk.halo,
                store.manifest.hlo
            );
        }
        Err(e) => println!("artifacts: not built ({e})"),
    }
    Ok(())
}
