//! `TraceSink` — the windows-out seam of the core/host split.
//!
//! Everything the engine *emits* (streamed series CSVs, summaries, spec
//! snapshots) leaves through this trait, so the pure core never touches
//! `std::fs`. The host shell provides [`DirSink`] (a directory on disk,
//! with the crate's stage-to-`.tmp`-then-rename crash-safety discipline);
//! embedders provide [`MemSink`] or their own impl.
//!
//! Paths are logical and `/`-separated, relative to the sink root (e.g.
//! `w0-t0-f0-s0/racks_1s.csv`). Both built-in sinks share the same
//! publish-on-close contract: bytes written through a [`TraceOut`] become
//! visible at the logical path only when [`TraceOut::close`] succeeds, so
//! an abandoned writer never leaves a plausible-looking partial export.
//!
//! [`StreamingCsv`] — the incremental columnar series writer every
//! streamed export goes through — lives here too, generic over the sink,
//! so the file-backed and in-memory paths share one formatting/resampling
//! implementation and can never drift byte-wise.

use crate::metrics::planning::StreamingResampler;
use crate::robust::failpoint;
use anyhow::Result;
use std::collections::{BTreeMap, VecDeque};
use std::sync::{Arc, Mutex};

/// An open, append-only export stream (one logical file).
pub trait TraceOut: Send {
    fn append(&mut self, bytes: &[u8]) -> Result<()>;
    /// Flush and publish. Until this succeeds the logical path must not
    /// appear in the sink.
    fn close(self: Box<Self>) -> Result<()>;
}

/// Byte consumer for everything the engine emits.
pub trait TraceSink: Sync {
    /// Open a logical path for streamed writing.
    fn open(&self, path: &str) -> Result<Box<dyn TraceOut>>;
    /// Write a complete logical file in one shot (atomically where the
    /// backend supports it).
    fn put(&self, path: &str, bytes: &[u8]) -> Result<()>;
}

/// In-memory [`TraceSink`]: logical path → bytes, published on close.
/// The wasm/embedding exit point ("windows out"), and the test double
/// used to prove sink-routed exports byte-equal the file-backed ones.
#[derive(Debug, Default, Clone)]
pub struct MemSink {
    files: Arc<Mutex<BTreeMap<String, Vec<u8>>>>,
}

impl MemSink {
    pub fn new() -> MemSink {
        MemSink::default()
    }

    /// The published bytes of one logical path.
    pub fn get(&self, path: &str) -> Option<Vec<u8>> {
        self.files.lock().unwrap().get(path).cloned()
    }

    /// All published files, by logical path.
    pub fn files(&self) -> BTreeMap<String, Vec<u8>> {
        self.files.lock().unwrap().clone()
    }

    /// Published logical paths, sorted.
    pub fn paths(&self) -> Vec<String> {
        self.files.lock().unwrap().keys().cloned().collect()
    }
}

struct MemOut {
    files: Arc<Mutex<BTreeMap<String, Vec<u8>>>>,
    path: String,
    buf: Vec<u8>,
}

impl TraceOut for MemOut {
    fn append(&mut self, bytes: &[u8]) -> Result<()> {
        self.buf.extend_from_slice(bytes);
        Ok(())
    }

    fn close(self: Box<Self>) -> Result<()> {
        self.files.lock().unwrap().insert(self.path, self.buf);
        Ok(())
    }
}

impl TraceSink for MemSink {
    fn open(&self, path: &str) -> Result<Box<dyn TraceOut>> {
        Ok(Box::new(MemOut {
            files: Arc::clone(&self.files),
            path: path.to_string(),
            buf: Vec::new(),
        }))
    }

    fn put(&self, path: &str, bytes: &[u8]) -> Result<()> {
        self.files.lock().unwrap().insert(path.to_string(), bytes.to_vec());
        Ok(())
    }
}

/// [`TraceSink`] adapter that prefixes every logical path with a
/// directory-like scope (`<scope>/<path>`) — how a sweep routes each
/// variant's exports into its own subtree of one shared sink without the
/// sink knowing about variants.
pub struct ScopedSink<'a> {
    inner: &'a dyn TraceSink,
    prefix: String,
}

impl<'a> ScopedSink<'a> {
    pub fn new(inner: &'a dyn TraceSink, scope: &str) -> ScopedSink<'a> {
        ScopedSink { inner, prefix: format!("{scope}/") }
    }
}

impl TraceSink for ScopedSink<'_> {
    fn open(&self, path: &str) -> Result<Box<dyn TraceOut>> {
        self.inner.open(&format!("{}{path}", self.prefix))
    }

    fn put(&self, path: &str, bytes: &[u8]) -> Result<()> {
        self.inner.put(&format!("{}{path}", self.prefix), bytes)
    }
}

/// Directory-backed [`TraceSink`]: logical paths resolve under `root`,
/// streamed writes stage to `<name>.tmp` and rename on close (the same
/// durability discipline [`crate::robust::fsx`] gives one-shot writes),
/// parent directories are created on demand.
#[cfg(feature = "host")]
#[derive(Debug, Clone)]
pub struct DirSink {
    root: std::path::PathBuf,
}

#[cfg(feature = "host")]
impl DirSink {
    pub fn new(root: impl Into<std::path::PathBuf>) -> DirSink {
        DirSink { root: root.into() }
    }
}

#[cfg(feature = "host")]
struct DirOut {
    out: std::io::BufWriter<std::fs::File>,
    tmp: std::path::PathBuf,
    path: std::path::PathBuf,
}

#[cfg(feature = "host")]
impl TraceOut for DirOut {
    fn append(&mut self, bytes: &[u8]) -> Result<()> {
        use std::io::Write;
        self.out.write_all(bytes)?;
        Ok(())
    }

    fn close(self: Box<Self>) -> Result<()> {
        let file = self
            .out
            .into_inner()
            .map_err(|e| anyhow::anyhow!("flushing {}: {e}", self.tmp.display()))?;
        // Make the rename durable, not just atomic: the bytes reach disk
        // before the final name does.
        let _ = file.sync_all();
        drop(file);
        crate::robust::fsx::persist(&self.tmp, &self.path)
    }
}

#[cfg(feature = "host")]
impl TraceSink for DirSink {
    fn open(&self, path: &str) -> Result<Box<dyn TraceOut>> {
        use anyhow::Context;
        let full = self.root.join(path);
        if let Some(parent) = full.parent() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating {}", parent.display()))?;
        }
        let tmp = crate::robust::fsx::tmp_path(&full);
        let file = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        Ok(Box::new(DirOut { out: std::io::BufWriter::new(file), tmp, path: full }))
    }

    fn put(&self, path: &str, bytes: &[u8]) -> Result<()> {
        crate::robust::fsx::atomic_write(&self.root.join(path), bytes)
    }
}

/// The file-name component of a logical path (failpoint tags, messages).
pub(crate) fn path_file_name(path: &str) -> &str {
    path.rsplit('/').next().unwrap_or(path)
}

/// Incremental columnar series CSV (`t_s,<stem>_0,...`): each column owns a
/// [`StreamingResampler`], rows are appended as soon as every column has
/// emitted a value. Byte-identical to [`write_series_csv`] on the buffered
/// [`crate::aggregate::MultiScale`] series because the resampler reproduces
/// `resample_mean_f64` exactly and both share [`fmt_secs`] + Rust's
/// shortest round-trip f32 formatting. The sweep runner
/// ([`crate::scenarios::runner`]) and the site composition engine
/// ([`crate::site`]) stream every series export through this one writer so
/// facility and site exports can never drift in format.
///
/// Rows stream through the sink's [`TraceOut`]; only
/// [`StreamingCsv::finish`] publishes the logical path (for [`DirSink`]
/// that is the historical `.tmp`-then-rename), so a crash mid-cell never
/// leaves a plausible-looking partial series at the real path.
pub struct StreamingCsv {
    out: Box<dyn TraceOut>,
    /// The logical path [`StreamingCsv::finish`] publishes.
    path: String,
    /// File name — the `export.write` failpoint tag.
    tag: String,
    interval_s: f64,
    next_row: usize,
    cols: Vec<StreamingResampler>,
    pending: Vec<VecDeque<f32>>,
    line: String,
}

impl StreamingCsv {
    pub fn create(
        sink: &dyn TraceSink,
        path: &str,
        stem: &str,
        n_cols: usize,
        dt_s: f64,
        interval_s: f64,
        scale: f64,
    ) -> Result<StreamingCsv> {
        let names: Vec<String> = (0..n_cols).map(|i| format!("{stem}_{i}")).collect();
        Self::create_named(sink, path, &names, dt_s, interval_s, scale)
    }

    /// [`StreamingCsv::create`] with explicit column names (the site
    /// export's `site_w,<facility>_w` header).
    pub fn create_named(
        sink: &dyn TraceSink,
        path: &str,
        col_names: &[String],
        dt_s: f64,
        interval_s: f64,
        scale: f64,
    ) -> Result<StreamingCsv> {
        let mut out = sink.open(path)?;
        let mut header = String::from("t_s");
        for name in col_names {
            header.push(',');
            header.push_str(&csv_field(name));
        }
        header.push('\n');
        out.append(header.as_bytes())?;
        let cols = col_names
            .iter()
            .map(|_| StreamingResampler::new(dt_s, interval_s, scale))
            .collect::<Result<Vec<_>>>()?;
        Ok(StreamingCsv {
            out,
            path: path.to_string(),
            tag: path_file_name(path).to_string(),
            interval_s,
            next_row: 0,
            cols,
            pending: (0..col_names.len()).map(|_| VecDeque::new()).collect(),
            line: String::new(),
        })
    }

    pub fn push_col(&mut self, col: usize, xs: &[f64]) {
        let (r, q) = (&mut self.cols[col], &mut self.pending[col]);
        for &x in xs {
            if let Some(v) = r.push(x) {
                q.push_back(v);
            }
        }
    }

    /// [`StreamingCsv::push_col`] over an f32 window (each sample widened
    /// to f64 before the resampler fold — the same expression the f64 path
    /// would see for values that started life as f32).
    pub fn push_col_f32(&mut self, col: usize, xs: &[f32]) {
        let (r, q) = (&mut self.cols[col], &mut self.pending[col]);
        for &x in xs {
            if let Some(v) = r.push(x as f64) {
                q.push_back(v);
            }
        }
    }

    pub fn write_ready_rows(&mut self) -> Result<()> {
        failpoint::hit("export.write", &self.tag)?;
        let ready = self.pending.iter().map(|q| q.len()).min().unwrap_or(0);
        for _ in 0..ready {
            self.line.clear();
            self.line.push_str(&fmt_secs(self.next_row as f64 * self.interval_s));
            for q in self.pending.iter_mut() {
                let v = q.pop_front().expect("ready rows counted");
                self.line.push(',');
                self.line.push_str(&format!("{v}"));
            }
            self.line.push('\n');
            self.out.append(self.line.as_bytes())?;
            self.next_row += 1;
        }
        Ok(())
    }

    /// Flush the trailing partial resample window of every column (the
    /// buffered `resample_mean` emits it averaged over its actual length),
    /// write the final row(s), and publish the logical path through the
    /// sink. Returns the finished path.
    pub fn finish(mut self) -> Result<String> {
        for (r, q) in self.cols.iter_mut().zip(self.pending.iter_mut()) {
            if let Some((v, _count)) = r.flush() {
                q.push_back(v);
            }
        }
        self.write_ready_rows()?;
        debug_assert!(self.pending.iter().all(|q| q.is_empty()), "ragged columns");
        self.out.close()?;
        Ok(self.path)
    }
}

/// RFC-4180 quoting for free-text CSV fields (a replay workload's path
/// may contain commas or quotes).
pub fn csv_field(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

/// `300` for whole seconds, `0.25` otherwise (file-name friendly).
pub fn fmt_secs(x: f64) -> String {
    if x.fract() == 0.0 {
        format!("{}", x as i64)
    } else {
        format!("{x}")
    }
}

/// `t_s,<stem>_0,<stem>_1,...` — shared by the buffered and streaming
/// writers so their headers can never drift apart.
pub(crate) fn series_csv_header(stem: &str, n_cols: usize) -> String {
    let mut out = String::from("t_s");
    for i in 0..n_cols {
        out.push_str(&format!(",{stem}_{i}"));
    }
    out.push('\n');
    out
}

/// Columnar CSV: `t_s,<stem>_0,<stem>_1,...` with one row per interval,
/// published through the sink in one shot.
pub(crate) fn write_series_csv(
    sink: &dyn TraceSink,
    path: &str,
    stem: &str,
    interval_s: f64,
    series: &[Vec<f32>],
) -> Result<()> {
    let n = series.iter().map(|s| s.len()).max().unwrap_or(0);
    let mut out = series_csv_header(stem, series.len());
    for t in 0..n {
        out.push_str(&fmt_secs(t as f64 * interval_s));
        for s in series {
            out.push(',');
            if t < s.len() {
                out.push_str(&format!("{}", s[t]));
            }
        }
        out.push('\n');
    }
    sink.put(path, out.as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_field_quotes_only_when_needed() {
        assert_eq!(csv_field("poisson λ=0.5"), "poisson λ=0.5");
        assert_eq!(csv_field("replay a,b.json"), "\"replay a,b.json\"");
        assert_eq!(csv_field("say \"hi\""), "\"say \"\"hi\"\"\"");
    }

    #[test]
    fn fmt_secs_is_filename_friendly() {
        assert_eq!(fmt_secs(300.0), "300");
        assert_eq!(fmt_secs(1.0), "1");
        assert_eq!(fmt_secs(0.25), "0.25");
    }

    #[test]
    fn series_csv_shape() {
        let sink = MemSink::new();
        write_series_csv(&sink, "racks.csv", "rack", 15.0, &[vec![1.0, 2.0], vec![3.0, 4.0]])
            .unwrap();
        let s = String::from_utf8(sink.get("racks.csv").unwrap()).unwrap();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[0], "t_s,rack_0,rack_1");
        assert_eq!(lines[1], "0,1,2");
        assert_eq!(lines[2], "15,3,4");
        assert_eq!(lines.len(), 3);
    }

    #[test]
    fn streaming_csv_matches_buffered_writer_bytes() {
        // Two columns of f64 data pushed in ragged windows must produce the
        // byte-identical file to resampling whole series and using
        // write_series_csv — including the partial trailing window.
        let sink = MemSink::new();
        let (dt, interval) = (0.25, 1.5); // stride 6
        let n = 100; // 100 = 16×6 + 4 → partial tail
        let cols: Vec<Vec<f64>> = (0..2)
            .map(|c| (0..n).map(|i| 1000.0 + (c * 37 + i) as f64 * 0.83).collect())
            .collect();
        // Buffered reference.
        let buffered: Vec<Vec<f32>> = cols
            .iter()
            .map(|col| {
                col.chunks(6)
                    .map(|ch| (ch.iter().sum::<f64>() / ch.len() as f64) as f32)
                    .collect()
            })
            .collect();
        write_series_csv(&sink, "buffered.csv", "rack", interval, &buffered).unwrap();
        // Streaming writer fed in windows of 7.
        let mut w = StreamingCsv::create(&sink, "streamed.csv", "rack", 2, dt, interval, 1.0)
            .unwrap();
        let mut t0 = 0;
        while t0 < n {
            let wlen = 7.min(n - t0);
            for (c, col) in cols.iter().enumerate() {
                w.push_col(c, &col[t0..t0 + wlen]);
            }
            w.write_ready_rows().unwrap();
            t0 += wlen;
        }
        let finished = w.finish().unwrap();
        assert_eq!(finished, "streamed.csv");
        let a = sink.get("buffered.csv").unwrap();
        let b = sink.get("streamed.csv").unwrap();
        assert_eq!(a, b, "streamed CSV bytes differ from buffered");
    }

    #[test]
    fn scoped_sink_prefixes_both_write_paths() {
        let sink = MemSink::new();
        let scoped = ScopedSink::new(&sink, "p0-s5");
        scoped.put("site_summary.csv", b"a\n").unwrap();
        let mut out = scoped.open("site_load.csv").unwrap();
        out.append(b"b\n").unwrap();
        out.close().unwrap();
        assert_eq!(sink.paths(), vec!["p0-s5/site_load.csv", "p0-s5/site_summary.csv"]);
    }

    #[test]
    fn mem_sink_publishes_only_on_close() {
        let sink = MemSink::new();
        let mut w = StreamingCsv::create(&sink, "atomic.csv", "rack", 1, 0.25, 0.5, 1.0).unwrap();
        w.push_col(0, &[1.0, 2.0, 3.0, 4.0]);
        w.write_ready_rows().unwrap();
        assert!(sink.get("atomic.csv").is_none(), "path must not appear before finish");
        w.finish().unwrap();
        let s = String::from_utf8(sink.get("atomic.csv").unwrap()).unwrap();
        assert_eq!(s, "t_s,rack_0\n0,1.5\n0.5,3.5\n");
    }

    #[cfg(feature = "host")]
    #[test]
    fn dir_sink_is_atomic_until_finish() {
        let dir = std::env::temp_dir().join("powertrace_test_streaming_atomic");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("atomic.csv");
        let _ = std::fs::remove_file(&p);
        let sink = DirSink::new(&dir);
        let mut w = StreamingCsv::create(&sink, "atomic.csv", "rack", 1, 0.25, 0.5, 1.0).unwrap();
        w.push_col(0, &[1.0, 2.0, 3.0, 4.0]);
        w.write_ready_rows().unwrap();
        // Rows exist only in the staging file until finish renames it.
        assert!(!p.exists(), "final path must not appear before finish");
        assert!(crate::robust::fsx::tmp_path(&p).exists());
        w.finish().unwrap();
        assert!(p.exists());
        assert!(!crate::robust::fsx::tmp_path(&p).exists());
        let s = std::fs::read_to_string(&p).unwrap();
        assert_eq!(s, "t_s,rack_0\n0,1.5\n0.5,3.5\n");
    }

    #[cfg(feature = "host")]
    #[test]
    fn dir_sink_creates_nested_parents() {
        let dir = std::env::temp_dir().join("powertrace_test_dir_sink_nested");
        let _ = std::fs::remove_dir_all(&dir);
        let sink = DirSink::new(&dir);
        sink.put("cell-a/summary.csv", b"x\n").unwrap();
        let mut out = sink.open("cell-b/racks.csv").unwrap();
        out.append(b"y\n").unwrap();
        out.close().unwrap();
        assert_eq!(std::fs::read(dir.join("cell-a/summary.csv")).unwrap(), b"x\n");
        assert_eq!(std::fs::read(dir.join("cell-b/racks.csv")).unwrap(), b"y\n");
    }
}
