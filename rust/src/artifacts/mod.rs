//! The per-configuration artifact store — the contract between the Python
//! build path (`python/compile/aot.py`, run once via `make artifacts`) and
//! the Rust runtime (DESIGN.md §6).
//!
//! On-disk layout under `<repo>/artifacts/`:
//!
//! ```text
//! artifacts/
//!   manifest.json            chunk geometry, k_max, hidden, hlo, config ids
//!   bigru_fwd.hlo.txt        AOT-lowered BiGRU forward pass (PJRT input)
//!   configs/<id>.json        state dictionary + surrogate + BiGRU weights
//!   measured/<id>/r*.json    held-out measured test traces + schedules
//! ```
//!
//! Everything is JSON so artifacts stay diffable and the two sides can
//! never disagree silently: [`ArtifactStore::load_config`] re-validates the
//! state dictionary, the weight count, and the synthesis mode on every
//! load.

use crate::classifier::{flat_param_count, ChunkSpec};
use crate::source::{self, ArtifactSource};
use crate::states::StateDictionary;
use crate::surrogate::{DurationSamples, SurrogateParams};
use crate::synth::SynthMode;
use crate::util::json::{self, Json};
use crate::workload::{replay, Schedule};
use anyhow::{bail, ensure, Context, Result};
use std::path::PathBuf;
use std::sync::Arc;

/// The artifact manifest (`artifacts/manifest.json`).
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    /// Configuration ids with trained artifacts, in build order.
    pub configs: Vec<String>,
    /// Chunking geometry of the AOT-compiled classifier.
    pub chunk: ChunkSpec,
    /// Maximum state count the classifier head was trained with.
    pub k_max: usize,
    /// BiGRU hidden size.
    pub hidden: usize,
    /// File name of the HLO-text artifact, relative to the store root.
    pub hlo: String,
}

impl Manifest {
    pub fn from_json(v: &Json) -> Result<Manifest> {
        let chunk_v = v.get("chunk")?;
        let chunk = ChunkSpec { t: chunk_v.usize_field("t")?, halo: chunk_v.usize_field("halo")? };
        ensure!(chunk.t > 2 * chunk.halo, "chunk t={} too small for halo={}", chunk.t, chunk.halo);
        let configs = v
            .get("configs")?
            .as_arr()
            .map_err(anyhow::Error::from)?
            .iter()
            .map(|x| x.as_str().map(String::from))
            .collect::<Result<Vec<_>, _>>()?;
        Ok(Manifest {
            configs,
            chunk,
            k_max: v.usize_field("k_max")?,
            hidden: v.usize_field("hidden")?,
            hlo: v.str_field("hlo")?,
        })
    }

    pub fn to_json(&self) -> Json {
        json::obj([
            (
                "chunk",
                json::obj([("t", self.chunk.t.into()), ("halo", self.chunk.halo.into())]),
            ),
            ("k_max", self.k_max.into()),
            ("hidden", self.hidden.into()),
            ("hlo", self.hlo.as_str().into()),
            (
                "configs",
                Json::Arr(self.configs.iter().map(|s| Json::Str(s.clone())).collect()),
            ),
        ])
    }
}

/// One trained per-configuration artifact (`artifacts/configs/<id>.json`):
/// the ordered state dictionary, the calibrated throughput surrogate, the
/// synthesis mode, and the flat BiGRU parameter vector.
#[derive(Debug, Clone)]
pub struct ConfigArtifact {
    pub config_id: String,
    /// Number of live states (BIC-selected); logits `k..k_max` are masked.
    pub k: usize,
    /// Mean training-set power (W) — the "mean" baseline level.
    pub train_mean_w: f64,
    pub dict: StateDictionary,
    pub mode: SynthMode,
    pub surrogate: SurrogateParams,
    /// Flat BiGRU parameters, `flat_param_count(hidden, k_max)` long.
    pub weights: Vec<f32>,
}

impl ConfigArtifact {
    /// Parse and validate against the store's manifest geometry.
    pub fn from_json(v: &Json, manifest: &Manifest) -> Result<ConfigArtifact> {
        let dict = StateDictionary::from_json(v.get("states")?)?;
        let k = v.usize_field("k")?;
        ensure!(k == dict.k(), "k={} disagrees with {} dictionary states", k, dict.k());
        ensure!((1..=manifest.k_max).contains(&k), "k={} outside 1..={}", k, manifest.k_max);
        let mode = match v.str_field("mode")?.as_str() {
            "iid" => SynthMode::Iid,
            "ar1" => SynthMode::Ar1,
            other => bail!("unknown synthesis mode '{other}'"),
        };
        let s = v.get("surrogate")?;
        let surrogate = SurrogateParams {
            alpha0: s.f64_field("alpha0")?,
            alpha1: s.f64_field("alpha1")?,
            sigma_ttft: s.f64_field("sigma_ttft")?,
            mu_log_tbt: s.f64_field("mu_log_tbt")?,
            sigma_log_tbt: s.f64_field("sigma_log_tbt")?,
        };
        let weights = v.get("weights")?.f32_array().map_err(anyhow::Error::from)?;
        let expect = flat_param_count(manifest.hidden, manifest.k_max);
        ensure!(weights.len() == expect, "{} weights, expected {expect}", weights.len());
        ensure!(weights.iter().all(|w| w.is_finite()), "non-finite weight");
        let train_mean_w = v.f64_field("train_power_mean_w")?;
        ensure!(train_mean_w.is_finite() && train_mean_w > 0.0, "bad train mean {train_mean_w}");
        Ok(ConfigArtifact {
            config_id: v.str_field("config_id")?,
            k,
            train_mean_w,
            dict,
            mode,
            surrogate,
            weights,
        })
    }
}

/// One held-out measured trace (`artifacts/measured/<id>/r<rate>_rep<n>.json`):
/// the testbed's ground truth for evaluation — power samples, measured
/// batch occupancy, the driving schedule, and completed-request durations.
#[derive(Debug, Clone)]
pub struct MeasuredTrace {
    /// Poisson arrival rate (req/s) this trace was measured under.
    pub rate: f64,
    /// Campaign repetition index.
    pub rep: usize,
    /// Sample interval (paper: 250 ms).
    pub dt_s: f64,
    /// Measured server GPU power (W) per sample.
    pub power_w: Vec<f32>,
    /// Measured batch occupancy `A_t` per sample.
    pub a_measured: Vec<f32>,
    /// The arrival schedule that drove the measurement.
    pub schedule: Schedule,
    /// Per-completed-request prefill/decode durations.
    pub durations: DurationSamples,
}

impl MeasuredTrace {
    pub fn from_json(v: &Json) -> Result<MeasuredTrace> {
        let d = v.get("durations")?;
        let u32s = |key: &str| -> Result<Vec<u32>> {
            Ok(d.get(key)?
                .f64_array()
                .map_err(anyhow::Error::from)?
                .into_iter()
                .map(|x| x as u32)
                .collect())
        };
        let durations = DurationSamples {
            n_in: u32s("n_in")?,
            prefill_s: d.get("prefill_s")?.f64_array().map_err(anyhow::Error::from)?,
            n_out: u32s("n_out")?,
            decode_s: d.get("decode_s")?.f64_array().map_err(anyhow::Error::from)?,
        };
        ensure!(
            durations.n_in.len() == durations.prefill_s.len()
                && durations.n_in.len() == durations.n_out.len()
                && durations.n_in.len() == durations.decode_s.len(),
            "ragged duration arrays"
        );
        let dt_s = v.f64_field("dt_s")?;
        ensure!(dt_s > 0.0, "dt_s must be positive");
        Ok(MeasuredTrace {
            rate: v.f64_field("rate")?,
            rep: v.usize_field("rep")?,
            dt_s,
            power_w: v.get("power_w")?.f32_array().map_err(anyhow::Error::from)?,
            a_measured: v.get("a")?.f32_array().map_err(anyhow::Error::from)?,
            schedule: replay::schedule_from_json(v.get("schedule")?)?,
            durations,
        })
    }
}

/// Handle to an artifact store — any [`ArtifactSource`] holding the
/// `manifest.json` / `configs/` / `measured/` layout. The file-backed
/// constructors ([`ArtifactStore::open`], [`ArtifactStore::open_default`])
/// are host-only; [`ArtifactStore::from_source`] works anywhere, including
/// wasm, over in-memory bytes.
pub struct ArtifactStore {
    /// Store root directory — meaningful for file-backed stores (HLO
    /// artifact path, messages); empty for in-memory sources.
    pub root: PathBuf,
    pub manifest: Manifest,
    source: Arc<dyn ArtifactSource>,
}

impl ArtifactStore {
    /// Open `<repo_root>/artifacts` (see `Catalog::repo_root`).
    #[cfg(feature = "host")]
    pub fn open_default() -> Result<ArtifactStore> {
        Self::open(&crate::catalog::Catalog::repo_root().join("artifacts"))
    }

    /// Open a store rooted at `root` (must contain `manifest.json`).
    #[cfg(feature = "host")]
    pub fn open(root: &std::path::Path) -> Result<ArtifactStore> {
        let mpath = root.join("manifest.json");
        if !mpath.exists() {
            bail!("artifact store not found at {} (run `make artifacts`)", root.display());
        }
        let mut store = Self::from_source(Arc::new(source::FsSource::new(root)))
            .with_context(|| format!("opening artifact store {}", root.display()))?;
        store.root = root.to_path_buf();
        Ok(store)
    }

    /// Open a store over any byte provider (the wasm/embedding entry
    /// point): reads and validates `manifest.json` from the source root.
    pub fn from_source(src: Arc<dyn ArtifactSource>) -> Result<ArtifactStore> {
        let text = source::read_to_string(src.as_ref(), "manifest.json")?;
        let v = json::parse(&text).map_err(anyhow::Error::from)?;
        let manifest = Manifest::from_json(&v).context("parsing manifest.json")?;
        Ok(ArtifactStore { root: PathBuf::new(), manifest, source: src })
    }

    /// Path of the AOT-compiled classifier artifact (file-backed stores).
    pub fn hlo_path(&self) -> PathBuf {
        self.root.join(&self.manifest.hlo)
    }

    /// Path of one configuration's artifact JSON (file-backed stores).
    pub fn config_path(&self, config_id: &str) -> PathBuf {
        self.root.join("configs").join(format!("{config_id}.json"))
    }

    /// Load and validate one configuration artifact.
    pub fn load_config(&self, config_id: &str) -> Result<ConfigArtifact> {
        let path = format!("configs/{config_id}.json");
        let text = source::read_to_string(self.source.as_ref(), &path)?;
        let v = json::parse(&text).map_err(anyhow::Error::from)?;
        let art =
            ConfigArtifact::from_json(&v, &self.manifest).with_context(|| format!("parsing {path}"))?;
        ensure!(
            art.config_id == config_id,
            "artifact {path} claims config '{}'",
            art.config_id
        );
        Ok(art)
    }

    /// Load every held-out measured trace for a configuration, in a stable
    /// (file-name sorted) order.
    pub fn load_all_measured(&self, config_id: &str) -> Result<Vec<MeasuredTrace>> {
        let dir = format!("measured/{config_id}");
        let mut names: Vec<String> = self
            .source
            .list(&dir)
            .with_context(|| format!("no measured traces at {dir}"))?
            .into_iter()
            .filter(|n| n.ends_with(".json"))
            .collect();
        names.sort();
        let mut out = Vec::with_capacity(names.len());
        for name in names {
            let path = format!("{dir}/{name}");
            let text = source::read_to_string(self.source.as_ref(), &path)?;
            let v = json::parse(&text).map_err(anyhow::Error::from)?;
            out.push(
                MeasuredTrace::from_json(&v).with_context(|| format!("parsing {path}"))?,
            );
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::classifier::flat_param_count;
    use crate::source::MemSource;

    /// Build a minimal synthetic in-memory store (small hidden/k_max so
    /// the weight vector stays tiny).
    fn synth_store() -> Arc<MemSource> {
        let src = Arc::new(MemSource::new());

        let manifest = Manifest {
            configs: vec!["cfg_a".into()],
            chunk: ChunkSpec { t: 32, halo: 4 },
            k_max: 3,
            hidden: 2,
            hlo: "bigru_fwd.hlo.txt".into(),
        };
        src.insert("manifest.json", json::to_string(&manifest.to_json()).into_bytes());

        let n_params = flat_param_count(2, 3);
        let art = json::obj([
            ("config_id", "cfg_a".into()),
            ("k", 2usize.into()),
            ("train_power_mean_w", 850.0.into()),
            (
                "states",
                json::obj([
                    ("pi", Json::from_f64s(&[0.6, 0.4])),
                    ("mu", Json::from_f64s(&[400.0, 1800.0])),
                    ("sigma", Json::from_f64s(&[30.0, 80.0])),
                    ("phi", Json::from_f64s(&[0.0, 0.0])),
                    ("y_min", 350.0.into()),
                    ("y_max", 2000.0.into()),
                ]),
            ),
            ("mode", "iid".into()),
            (
                "surrogate",
                json::obj([
                    ("alpha0", (-2.0).into()),
                    ("alpha1", 0.8.into()),
                    ("sigma_ttft", 0.2.into()),
                    ("mu_log_tbt", (-4.0).into()),
                    ("sigma_log_tbt", 0.2.into()),
                ]),
            ),
            ("weights", Json::from_f32s(&vec![0.01f32; n_params])),
        ]);
        src.insert("configs/cfg_a.json", json::to_string(&art).into_bytes());

        let m = json::obj([
            ("rate", 0.5.into()),
            ("rep", 3usize.into()),
            ("dt_s", 0.25.into()),
            ("power_w", Json::from_f64s(&[400.0, 410.0, 1800.0, 395.0])),
            ("a", Json::from_f64s(&[0.0, 1.0, 2.0, 0.0])),
            (
                "schedule",
                json::parse(r#"[{"t": 0.1, "n_in": 128, "n_out": 64}]"#).unwrap(),
            ),
            (
                "durations",
                json::obj([
                    ("n_in", Json::from_f64s(&[128.0])),
                    ("prefill_s", Json::from_f64s(&[0.21])),
                    ("n_out", Json::from_f64s(&[64.0])),
                    ("decode_s", Json::from_f64s(&[1.1])),
                ]),
            ),
        ]);
        src.insert("measured/cfg_a/r0.5_rep3.json", json::to_string(&m).into_bytes());
        src
    }

    #[cfg(feature = "host")]
    #[test]
    fn open_missing_store_is_clear_error() {
        let err =
            ArtifactStore::open(std::path::Path::new("/nonexistent/artifacts")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[cfg(feature = "host")]
    #[test]
    fn open_reads_a_directory_store() {
        // `open` is a thin FsSource wrapper over `from_source`; one smoke
        // proves the directory path still round-trips end to end.
        let src = synth_store();
        let root = std::env::temp_dir().join("powertrace_test_artifacts_open");
        let _ = std::fs::remove_dir_all(&root);
        std::fs::create_dir_all(root.join("configs")).unwrap();
        for path in ["manifest.json", "configs/cfg_a.json"] {
            std::fs::write(root.join(path), src.read(path).unwrap()).unwrap();
        }
        let store = ArtifactStore::open(&root).unwrap();
        assert_eq!(store.root, root);
        assert_eq!(store.manifest.configs, vec!["cfg_a".to_string()]);
        assert!(store.hlo_path().ends_with("bigru_fwd.hlo.txt"));
        store.load_config("cfg_a").unwrap();
    }

    #[test]
    fn manifest_roundtrip() {
        let m = Manifest {
            configs: vec!["a".into(), "b".into()],
            chunk: ChunkSpec { t: 512, halo: 64 },
            k_max: 12,
            hidden: 64,
            hlo: "bigru_fwd.hlo.txt".into(),
        };
        assert_eq!(Manifest::from_json(&m.to_json()).unwrap(), m);
    }

    #[test]
    fn loads_synthetic_store() {
        let store = ArtifactStore::from_source(synth_store()).unwrap();
        assert_eq!(store.manifest.configs, vec!["cfg_a".to_string()]);
        assert_eq!(store.manifest.chunk, ChunkSpec { t: 32, halo: 4 });
        assert!(store.hlo_path().ends_with("bigru_fwd.hlo.txt"));

        let art = store.load_config("cfg_a").unwrap();
        assert_eq!(art.config_id, "cfg_a");
        assert_eq!(art.k, 2);
        assert_eq!(art.mode, SynthMode::Iid);
        assert_eq!(art.dict.k(), 2);
        assert_eq!(art.weights.len(), flat_param_count(2, 3));
        assert!((art.surrogate.alpha1 - 0.8).abs() < 1e-12);
        assert!((art.train_mean_w - 850.0).abs() < 1e-12);

        let measured = store.load_all_measured("cfg_a").unwrap();
        assert_eq!(measured.len(), 1);
        let m = &measured[0];
        assert_eq!(m.rate, 0.5);
        assert_eq!(m.rep, 3);
        assert_eq!(m.dt_s, 0.25);
        assert_eq!(m.power_w.len(), 4);
        assert_eq!(m.a_measured.len(), 4);
        assert_eq!(m.schedule.len(), 1);
        assert_eq!(m.durations.len(), 1);
        assert_eq!(m.durations.n_in[0], 128);
    }

    /// Re-insert `configs/cfg_a.json` with one field mutated.
    fn mutate_config(src: &MemSource, field: &str, value: Json) {
        let text = String::from_utf8(src.read("configs/cfg_a.json").unwrap()).unwrap();
        let mut v = json::parse(&text).unwrap();
        if let Json::Obj(o) = &mut v {
            o.insert(field.into(), value);
        }
        src.insert("configs/cfg_a.json", json::to_string(&v).into_bytes());
    }

    #[test]
    fn rejects_weight_count_mismatch() {
        let src = synth_store();
        // Truncate the weight vector and re-insert.
        mutate_config(&src, "weights", Json::from_f64s(&[1.0, 2.0]));
        let store = ArtifactStore::from_source(src).unwrap();
        assert!(store.load_config("cfg_a").is_err());
    }

    #[test]
    fn rejects_k_dictionary_mismatch() {
        let src = synth_store();
        mutate_config(&src, "k", Json::Num(3.0));
        let store = ArtifactStore::from_source(src).unwrap();
        assert!(store.load_config("cfg_a").is_err());
    }

    #[test]
    fn missing_measured_dir_is_error() {
        let store = ArtifactStore::from_source(synth_store()).unwrap();
        assert!(store.load_all_measured("cfg_missing").is_err());
    }
}
