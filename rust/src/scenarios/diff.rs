//! Summary-CSV regression diffing: compare two `summary.csv` /
//! `site_summary.csv` / `site_sweep_summary.csv` revisions cell-by-cell
//! and report per-metric deltas — the ROADMAP's "cross-cell diff tooling".
//!
//! Sweep and site summaries are deterministic per `(grid, seeds)` (no
//! wall-clock columns, shortest round-trip float formatting), so two runs
//! of the same scenario set on the same code revision must match exactly;
//! a metric that moved is a behavioral change. `powertrace diff` turns
//! that property into a CI gate: exit 0 when every cell agrees within
//! `--tolerance` (relative), non-zero otherwise.
//!
//! Comparison model: rows are keyed by their first column (the cell /
//! facility / variant id) so row reordering is not a difference, columns
//! are matched by header name, and each cell is compared numerically when
//! both sides parse as finite floats (relative error against the larger
//! magnitude) and textually otherwise. Missing rows or columns are
//! structural differences regardless of tolerance.

use anyhow::{ensure, Context, Result};
use std::collections::BTreeMap;
#[cfg(feature = "host")]
use std::path::Path;

/// One differing cell.
#[derive(Debug, Clone)]
pub struct CellDelta {
    /// Row key (first-column value).
    pub row: String,
    /// Column (header) name.
    pub column: String,
    pub a: String,
    pub b: String,
    /// Relative difference (`f64::INFINITY` for non-numeric mismatches).
    pub rel: f64,
}

/// Outcome of diffing two summaries.
#[derive(Debug, Clone, Default)]
pub struct DiffReport {
    /// Columns present in exactly one input (header name, which side).
    pub missing_columns: Vec<(String, char)>,
    /// Row keys present in exactly one input (key, which side).
    pub missing_rows: Vec<(String, char)>,
    /// Cells whose relative difference exceeds the tolerance.
    pub deltas: Vec<CellDelta>,
    /// Rows compared (present in both).
    pub rows_compared: usize,
    /// Cells compared (shared rows × shared columns).
    pub cells_compared: usize,
}

impl DiffReport {
    /// `true` when the summaries agree within tolerance.
    pub fn is_match(&self) -> bool {
        self.missing_columns.is_empty() && self.missing_rows.is_empty() && self.deltas.is_empty()
    }

    /// Human-readable report: structural differences, per-metric worst
    /// deltas, then every differing cell.
    pub fn render(&self) -> String {
        let mut s = String::new();
        for (col, side) in &self.missing_columns {
            s.push_str(&format!("column '{col}' only in {side}\n"));
        }
        for (row, side) in &self.missing_rows {
            s.push_str(&format!("row '{row}' only in {side}\n"));
        }
        // Per-metric worst relative delta — the headline a planner reads.
        let mut worst: BTreeMap<&str, f64> = BTreeMap::new();
        for d in &self.deltas {
            let w = worst.entry(d.column.as_str()).or_insert(0.0);
            *w = w.max(d.rel);
        }
        for (col, rel) in &worst {
            s.push_str(&format!("metric '{col}': worst relative delta {rel:.3e}\n"));
        }
        for d in &self.deltas {
            s.push_str(&format!(
                "  {} / {}: {} -> {} (rel {:.3e})\n",
                d.row, d.column, d.a, d.b, d.rel
            ));
        }
        s.push_str(&format!(
            "{} differing cell(s) over {} row(s) x shared columns ({} cells compared)\n",
            self.deltas.len(),
            self.rows_compared,
            self.cells_compared
        ));
        s
    }
}

/// Diff two summary-CSV texts. `tolerance` is the maximum allowed
/// relative difference per numeric cell (0 = exact).
pub fn diff_summaries(a: &str, b: &str, tolerance: f64) -> Result<DiffReport> {
    ensure!(
        tolerance.is_finite() && tolerance >= 0.0,
        "diff: tolerance must be a non-negative number (got {tolerance})"
    );
    let ta = parse_table(a).context("first input")?;
    let tb = parse_table(b).context("second input")?;
    let mut report = DiffReport::default();
    for col in &ta.header {
        if !tb.header.contains(col) {
            report.missing_columns.push((col.clone(), 'a'));
        }
    }
    for col in &tb.header {
        if !ta.header.contains(col) {
            report.missing_columns.push((col.clone(), 'b'));
        }
    }
    // Shared columns, in a's order, with each side's column index.
    let shared: Vec<(String, usize, usize)> = ta
        .header
        .iter()
        .enumerate()
        .filter_map(|(ia, col)| {
            tb.header.iter().position(|c| c == col).map(|ib| (col.clone(), ia, ib))
        })
        .collect();
    for key in ta.rows.keys() {
        if !tb.rows.contains_key(key) {
            report.missing_rows.push((key.clone(), 'a'));
        }
    }
    for key in tb.rows.keys() {
        if !ta.rows.contains_key(key) {
            report.missing_rows.push((key.clone(), 'b'));
        }
    }
    for (key, row_a) in &ta.rows {
        let Some(row_b) = tb.rows.get(key) else { continue };
        report.rows_compared += 1;
        for (col, ia, ib) in &shared {
            let va = row_a.get(*ia).map(|s| s.as_str()).unwrap_or("");
            let vb = row_b.get(*ib).map(|s| s.as_str()).unwrap_or("");
            report.cells_compared += 1;
            let rel = cell_delta(va, vb);
            if rel > tolerance {
                report.deltas.push(CellDelta {
                    row: key.clone(),
                    column: col.clone(),
                    a: va.to_string(),
                    b: vb.to_string(),
                    rel,
                });
            }
        }
    }
    Ok(report)
}

/// [`diff_summaries`] over two files.
#[cfg(feature = "host")]
pub fn diff_summary_files(a: &Path, b: &Path, tolerance: f64) -> Result<DiffReport> {
    let ta = std::fs::read_to_string(a).with_context(|| format!("reading {}", a.display()))?;
    let tb = std::fs::read_to_string(b).with_context(|| format!("reading {}", b.display()))?;
    diff_summaries(&ta, &tb, tolerance)
}

/// Relative difference of one cell: 0 for identical text, numeric
/// relative error when both sides parse as finite floats, ∞ otherwise.
fn cell_delta(a: &str, b: &str) -> f64 {
    if a == b {
        return 0.0;
    }
    match (a.parse::<f64>(), b.parse::<f64>()) {
        (Ok(x), Ok(y)) if x.is_finite() && y.is_finite() => {
            let scale = x.abs().max(y.abs());
            if scale == 0.0 {
                0.0
            } else {
                (x - y).abs() / scale
            }
        }
        _ => f64::INFINITY,
    }
}

struct Table {
    header: Vec<String>,
    /// Row key (first column; duplicate keys get a `#<n>` suffix so every
    /// row participates) → remaining + first fields, in file order.
    rows: BTreeMap<String, Vec<String>>,
}

fn parse_table(text: &str) -> Result<Table> {
    let mut lines = text.lines().filter(|l| !l.is_empty());
    let header = parse_csv_line(lines.next().context("empty CSV (no header)")?);
    ensure!(!header.is_empty(), "empty CSV header");
    let mut rows = BTreeMap::new();
    for (i, line) in lines.enumerate() {
        let fields = parse_csv_line(line);
        ensure!(
            fields.len() == header.len(),
            "row {} has {} fields, header has {}",
            i + 2,
            fields.len(),
            header.len()
        );
        let mut key = fields[0].clone();
        let mut n = 1;
        while rows.contains_key(&key) {
            n += 1;
            key = format!("{}#{n}", fields[0]);
        }
        rows.insert(key, fields);
    }
    Ok(Table { header, rows })
}

/// Split one CSV line, honoring RFC-4180 quoting (`""` escapes a quote
/// inside a quoted field). Fields never span lines in our summaries.
fn parse_csv_line(line: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut field = String::new();
    let mut quoted = false;
    let mut chars = line.chars().peekable();
    while let Some(c) = chars.next() {
        if quoted {
            if c == '"' {
                if chars.peek() == Some(&'"') {
                    chars.next();
                    field.push('"');
                } else {
                    quoted = false;
                }
            } else {
                field.push(c);
            }
        } else {
            match c {
                '"' => quoted = true,
                ',' => out.push(std::mem::take(&mut field)),
                _ => field.push(c),
            }
        }
    }
    out.push(field);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const BASE: &str = "cell,peak_w,avg_w,label\n\
                        w0,1000.5,800.25,poisson\n\
                        w1,2000,1600,\"mmpp, bursty\"\n";

    #[test]
    fn identical_summaries_match() {
        let r = diff_summaries(BASE, BASE, 0.0).unwrap();
        assert!(r.is_match(), "{}", r.render());
        assert_eq!(r.rows_compared, 2);
        assert_eq!(r.cells_compared, 8);
    }

    #[test]
    fn detects_an_injected_metric_change() {
        let b = BASE.replace("800.25", "801.25");
        let r = diff_summaries(BASE, &b, 0.0).unwrap();
        assert!(!r.is_match());
        assert_eq!(r.deltas.len(), 1);
        assert_eq!(r.deltas[0].row, "w0");
        assert_eq!(r.deltas[0].column, "avg_w");
        assert!((r.deltas[0].rel - 1.0 / 801.25).abs() < 1e-6);
        // ...and the same change passes under a loose tolerance.
        assert!(diff_summaries(BASE, &b, 0.01).unwrap().is_match());
    }

    #[test]
    fn row_reordering_is_not_a_difference() {
        let b = "cell,peak_w,avg_w,label\n\
                 w1,2000,1600,\"mmpp, bursty\"\n\
                 w0,1000.5,800.25,poisson\n";
        assert!(diff_summaries(BASE, b, 0.0).unwrap().is_match());
    }

    #[test]
    fn numeric_formatting_differences_compare_numerically() {
        let b = BASE.replace("2000", "2000.0").replace("1600", "1.6e3");
        assert!(diff_summaries(BASE, &b, 0.0).unwrap().is_match());
    }

    #[test]
    fn structural_differences_are_reported() {
        // Missing row.
        let b = "cell,peak_w,avg_w,label\nw0,1000.5,800.25,poisson\n";
        let r = diff_summaries(BASE, b, 1.0).unwrap();
        assert!(!r.is_match());
        assert_eq!(r.missing_rows, vec![("w1".to_string(), 'a')]);
        // Missing column.
        let b = BASE.replace(",label", "").replace(",poisson", "").replace(",\"mmpp, bursty\"", "");
        let r = diff_summaries(BASE, &b, 1.0).unwrap();
        assert_eq!(r.missing_columns, vec![("label".to_string(), 'a')]);
        // Textual change is infinite however large the tolerance.
        let b = BASE.replace("poisson", "diurnal");
        let r = diff_summaries(BASE, &b, 1e9).unwrap();
        assert_eq!(r.deltas.len(), 1);
        assert!(r.deltas[0].rel.is_infinite());
    }

    #[test]
    fn quoted_fields_and_empty_cells_roundtrip() {
        assert_eq!(
            parse_csv_line("a,\"b,c\",\"say \"\"hi\"\"\",,d"),
            vec!["a", "b,c", "say \"hi\"", "", "d"]
        );
        // Empty-vs-empty cells (site summary facility rows) are equal.
        let s = "name,cf\nfac0,\nsite,0.9\n";
        assert!(diff_summaries(s, s, 0.0).unwrap().is_match());
    }

    #[test]
    fn duplicate_keys_all_participate() {
        let a = "cell,x\nw0,1\nw0,2\n";
        let b = "cell,x\nw0,1\nw0,3\n";
        let r = diff_summaries(a, b, 0.0).unwrap();
        assert_eq!(r.rows_compared, 2);
        assert_eq!(r.deltas.len(), 1);
    }

    #[test]
    fn invalid_inputs_error() {
        assert!(diff_summaries("", "", 0.0).is_err());
        assert!(diff_summaries("a,b\n1\n", "a,b\n1,2\n", 0.0).is_err()); // ragged row
        assert!(diff_summaries(BASE, BASE, f64::NAN).is_err());
        assert!(diff_summaries(BASE, BASE, -1.0).is_err());
    }
}
