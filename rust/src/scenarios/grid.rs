//! Sweep-grid definition, JSON round-trip, and cross-product expansion.
//!
//! A [`SweepGrid`] is four axes (workloads, topologies, fleets, seeds) plus
//! shared per-cell defaults; [`SweepGrid::expand`] materializes the full
//! cross-product as [`SweepCell`]s with stable ids and labels. Expansion is
//! pure and deterministic — the same grid always yields the same cells in
//! the same order — so grid cells are comparable across runs and code
//! revisions.
//!
//! The workload axis accepts every [`WorkloadSpec`] kind, including
//! token-level workloads — so length-distribution parameters (e.g. two
//! `token` entries differing only in `lengths.in_median`) and batching
//! parameters (`max_batch`, `token_budget`) are sweepable axes like any
//! other workload knob.

use crate::aggregate::Topology;
use crate::config::{
    topology_from_json, topology_to_json, ScenarioSpec, ServerAssignment, WorkloadSpec,
};
use crate::util::json::{self, Json};
use anyhow::{bail, Context, Result};
#[cfg(feature = "host")]
use std::path::Path;

/// Scenario fields shared by every cell of a grid.
#[derive(Debug, Clone, PartialEq)]
pub struct GridDefaults {
    /// Length-profile dataset key (catalog).
    pub dataset: String,
    /// Trace horizon per cell (s).
    pub horizon_s: f64,
    /// Per-server non-GPU IT power (W).
    pub p_base_w: f64,
    /// Site PUE.
    pub pue: f64,
}

impl Default for GridDefaults {
    fn default() -> Self {
        GridDefaults { dataset: "sharegpt".to_string(), horizon_s: 600.0, p_base_w: 1000.0, pue: 1.3 }
    }
}

/// A declarative sweep: the cross-product of four axes.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepGrid {
    pub name: String,
    pub defaults: GridDefaults,
    pub workloads: Vec<WorkloadSpec>,
    pub topologies: Vec<Topology>,
    pub fleets: Vec<ServerAssignment>,
    pub seeds: Vec<u64>,
}

/// One expanded grid cell: a concrete scenario plus its stable identity.
#[derive(Debug, Clone)]
pub struct SweepCell {
    /// Stable id `w<i>-t<j>-f<k>-s<seed>` (axis indices, not values).
    pub id: String,
    /// Human-readable one-liner for tables.
    pub label: String,
    pub spec: ScenarioSpec,
}

impl SweepGrid {
    /// Number of cells the grid expands to.
    pub fn n_cells(&self) -> usize {
        self.workloads.len() * self.topologies.len() * self.fleets.len() * self.seeds.len()
    }

    /// Reject empty axes and unusable defaults before any work starts.
    pub fn validate(&self) -> Result<()> {
        if self.workloads.is_empty() {
            bail!("grid '{}' has no workloads", self.name);
        }
        if self.topologies.is_empty() {
            bail!("grid '{}' has no topologies", self.name);
        }
        if self.fleets.is_empty() {
            bail!("grid '{}' has no fleets", self.name);
        }
        if self.seeds.is_empty() {
            bail!("grid '{}' has no seeds", self.name);
        }
        if self.config_ids().iter().any(|id| id.is_empty()) {
            bail!("grid '{}' references an empty config id", self.name);
        }
        // Seeds round-trip through JSON numbers (f64): beyond 2^53 they
        // would silently change value on save/load, breaking the
        // grid-file-as-reproduction-recipe guarantee.
        if self.seeds.iter().any(|&s| s > (1u64 << 53)) {
            bail!("grid '{}': seeds must be < 2^53 to round-trip through JSON", self.name);
        }
        if self.defaults.horizon_s <= 0.0 {
            bail!("grid '{}': horizon_s must be positive", self.name);
        }
        if self.defaults.pue < 1.0 {
            bail!("grid '{}': pue must be >= 1.0", self.name);
        }
        Ok(())
    }

    /// Unique configuration ids across every fleet, in first-use order —
    /// the artifact set shared by all cells (each id is prepared once no
    /// matter how many cells reference it).
    pub fn config_ids(&self) -> Vec<String> {
        let mut out: Vec<String> = Vec::new();
        for fleet in &self.fleets {
            for id in fleet.config_ids() {
                if !out.contains(&id) {
                    out.push(id);
                }
            }
        }
        out
    }

    /// Expand the cross-product. Nesting order (workload-major, seed-minor)
    /// and cell ids are stable across runs.
    pub fn expand(&self) -> Vec<SweepCell> {
        let mut out = Vec::with_capacity(self.n_cells());
        for (wi, workload) in self.workloads.iter().enumerate() {
            for (ti, topology) in self.topologies.iter().enumerate() {
                for (fi, fleet) in self.fleets.iter().enumerate() {
                    for &seed in &self.seeds {
                        let spec = ScenarioSpec {
                            server_config: fleet.clone(),
                            topology: *topology,
                            workload: workload.clone(),
                            dataset: self.defaults.dataset.clone(),
                            horizon_s: self.defaults.horizon_s,
                            p_base_w: self.defaults.p_base_w,
                            pue: self.defaults.pue,
                            seed,
                        };
                        let fleet_label = match fleet {
                            ServerAssignment::Uniform(id) => id.clone(),
                            ServerAssignment::PerRack(ids) => ids.join("+"),
                        };
                        out.push(SweepCell {
                            id: format!("w{wi}-t{ti}-f{fi}-s{seed}"),
                            label: format!(
                                "{} | {}x{}x{} | {} | seed {}",
                                workload.label(),
                                topology.rows,
                                topology.racks_per_row,
                                topology.servers_per_rack,
                                fleet_label,
                                seed
                            ),
                            spec,
                        });
                    }
                }
            }
        }
        out
    }

    pub fn to_json(&self) -> Json {
        json::obj([
            ("name", self.name.as_str().into()),
            (
                "defaults",
                json::obj([
                    ("dataset", self.defaults.dataset.as_str().into()),
                    ("horizon_s", self.defaults.horizon_s.into()),
                    ("p_base_w", self.defaults.p_base_w.into()),
                    ("pue", self.defaults.pue.into()),
                ]),
            ),
            ("workloads", Json::Arr(self.workloads.iter().map(|w| w.to_json()).collect())),
            ("topologies", Json::Arr(self.topologies.iter().map(topology_to_json).collect())),
            ("fleets", Json::Arr(self.fleets.iter().map(|f| f.to_json()).collect())),
            ("seeds", Json::Arr(self.seeds.iter().map(|&s| Json::Num(s as f64)).collect())),
        ])
    }

    pub fn from_json(v: &Json) -> Result<SweepGrid> {
        let mut defaults = GridDefaults::default();
        if let Some(d) = v.get_opt("defaults") {
            if let Some(x) = d.get_opt("dataset") {
                defaults.dataset = x.as_str()?.to_string();
            }
            if let Some(x) = d.get_opt("horizon_s") {
                defaults.horizon_s = x.as_f64()?;
            }
            if let Some(x) = d.get_opt("p_base_w") {
                defaults.p_base_w = x.as_f64()?;
            }
            if let Some(x) = d.get_opt("pue") {
                defaults.pue = x.as_f64()?;
            }
        }
        let workloads = v
            .get("workloads")?
            .as_arr()
            .map_err(anyhow::Error::from)?
            .iter()
            .enumerate()
            .map(|(i, w)| WorkloadSpec::from_json(w).with_context(|| format!("workloads[{i}]")))
            .collect::<Result<Vec<_>>>()?;
        let topologies = v
            .get("topologies")?
            .as_arr()
            .map_err(anyhow::Error::from)?
            .iter()
            .enumerate()
            .map(|(i, t)| topology_from_json(t).with_context(|| format!("topologies[{i}]")))
            .collect::<Result<Vec<_>>>()?;
        let fleets = v
            .get("fleets")?
            .as_arr()
            .map_err(anyhow::Error::from)?
            .iter()
            .enumerate()
            .map(|(i, f)| ServerAssignment::from_json(f).with_context(|| format!("fleets[{i}]")))
            .collect::<Result<Vec<_>>>()?;
        let seeds = v
            .get("seeds")?
            .f64_array()
            .map_err(anyhow::Error::from)?
            .into_iter()
            .map(|s| {
                if s < 0.0 || s.fract() != 0.0 || s > (1u64 << 53) as f64 {
                    bail!("seeds must be integers in [0, 2^53] (got {s})");
                }
                Ok(s as u64)
            })
            .collect::<Result<Vec<_>>>()?;
        let name = match v.get_opt("name") {
            Some(x) => x.as_str()?.to_string(),
            None => "sweep".to_string(),
        };
        let grid = SweepGrid {
            name,
            defaults,
            workloads,
            topologies,
            fleets,
            seeds,
        };
        grid.validate()?;
        Ok(grid)
    }

    #[cfg(feature = "host")]
    pub fn load(path: &Path) -> Result<SweepGrid> {
        let v = json::parse_file(path).map_err(anyhow::Error::from)?;
        Self::from_json(&v).with_context(|| format!("parsing sweep grid {}", path.display()))
    }

    #[cfg(feature = "host")]
    pub fn save(&self, path: &Path) -> Result<()> {
        json::write_file(path, &self.to_json()).map_err(anyhow::Error::from)
    }

    /// A small built-in demonstration grid over `config_ids`: steady vs
    /// bursty traffic × homogeneous vs mixed fleet × two seeds = 8 cells.
    /// Used by `powertrace sweep` when no `--grid` file is given and by
    /// `examples/sweep_grid.rs`.
    pub fn example(name: &str, config_ids: &[String], horizon_s: f64) -> SweepGrid {
        let primary = config_ids.first().cloned().unwrap_or_default();
        let mixed: Vec<String> = config_ids.iter().take(2).cloned().collect();
        let fleets = if mixed.len() > 1 {
            vec![ServerAssignment::Uniform(primary), ServerAssignment::PerRack(mixed)]
        } else {
            vec![
                ServerAssignment::Uniform(primary.clone()),
                ServerAssignment::Uniform(primary),
            ]
        };
        SweepGrid {
            name: name.to_string(),
            defaults: GridDefaults { horizon_s, ..GridDefaults::default() },
            workloads: vec![
                WorkloadSpec::Poisson { rate: 0.5 },
                WorkloadSpec::Mmpp { mean_rate: 0.5, burstiness: 4.0 },
            ],
            topologies: vec![Topology { rows: 2, racks_per_row: 2, servers_per_rack: 2 }],
            fleets,
            seeds: vec![0, 1],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid() -> SweepGrid {
        SweepGrid {
            name: "t".into(),
            defaults: GridDefaults::default(),
            workloads: vec![
                WorkloadSpec::Poisson { rate: 0.25 },
                WorkloadSpec::Poisson { rate: 1.0 },
                WorkloadSpec::Mmpp { mean_rate: 0.5, burstiness: 4.0 },
            ],
            topologies: vec![
                Topology { rows: 1, racks_per_row: 2, servers_per_rack: 2 },
                Topology { rows: 2, racks_per_row: 3, servers_per_rack: 4 },
            ],
            fleets: vec![
                ServerAssignment::Uniform("a".into()),
                ServerAssignment::PerRack(vec!["a".into(), "b".into()]),
            ],
            seeds: vec![0, 7],
        }
    }

    #[test]
    fn expansion_is_full_cross_product() {
        let g = grid();
        assert_eq!(g.n_cells(), 3 * 2 * 2 * 2);
        let cells = g.expand();
        assert_eq!(cells.len(), g.n_cells());
        // Ids are unique.
        let mut ids: Vec<&str> = cells.iter().map(|c| c.id.as_str()).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), cells.len());
    }

    #[test]
    fn expansion_is_deterministic() {
        let g = grid();
        let a = g.expand();
        let b = g.expand();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.label, y.label);
            assert_eq!(x.spec, y.spec);
        }
    }

    #[test]
    fn seeds_propagate_to_specs() {
        let g = grid();
        for cell in g.expand() {
            let seed_tag = format!("-s{}", cell.spec.seed);
            assert!(cell.id.ends_with(&seed_tag), "{} vs seed {}", cell.id, cell.spec.seed);
            assert!(g.seeds.contains(&cell.spec.seed));
        }
    }

    #[test]
    fn config_ids_deduplicate_across_fleets() {
        let g = grid();
        // "a" appears in both fleets; "b" once.
        assert_eq!(g.config_ids(), vec!["a".to_string(), "b".to_string()]);
    }

    #[test]
    fn json_roundtrip() {
        let g = grid();
        let back = SweepGrid::from_json(&g.to_json()).unwrap();
        assert_eq!(back, g);
    }

    #[test]
    fn defaults_are_optional_in_json() {
        let v = json::parse(
            r#"{
              "name": "mini",
              "workloads": [{"kind": "poisson", "rate": 1.0}],
              "topologies": [{"rows": 1, "racks_per_row": 1, "servers_per_rack": 1}],
              "fleets": ["cfg"],
              "seeds": [0]
            }"#,
        )
        .unwrap();
        let g = SweepGrid::from_json(&v).unwrap();
        assert_eq!(g.defaults, GridDefaults::default());
        assert_eq!(g.n_cells(), 1);
    }

    #[test]
    fn validation_rejects_empty_axes_and_bad_defaults() {
        let mut g = grid();
        g.seeds.clear();
        assert!(g.validate().is_err());

        let mut g = grid();
        g.workloads.clear();
        assert!(g.validate().is_err());

        let mut g = grid();
        g.defaults.pue = 0.9;
        assert!(g.validate().is_err());

        let mut g = grid();
        g.defaults.horizon_s = 0.0;
        assert!(g.validate().is_err());

        let mut g = grid();
        g.fleets = vec![ServerAssignment::Uniform(String::new())];
        assert!(g.validate().is_err());
    }

    #[test]
    fn wrong_typed_name_is_an_error_not_a_default() {
        let mut g = grid().to_json();
        if let Json::Obj(o) = &mut g {
            o.insert("name".into(), Json::Num(42.0));
        }
        assert!(SweepGrid::from_json(&g).is_err());
        // Absent name still defaults.
        if let Json::Obj(o) = &mut g {
            o.remove("name");
        }
        assert_eq!(SweepGrid::from_json(&g).unwrap().name, "sweep");
    }

    #[cfg(feature = "host")]
    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("powertrace_test_grid");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("grid.json");
        let g = grid();
        g.save(&p).unwrap();
        assert_eq!(SweepGrid::load(&p).unwrap(), g);
    }

    #[test]
    fn example_grid_has_at_least_eight_cells() {
        let ids = vec!["a".to_string(), "b".to_string()];
        let g = SweepGrid::example("demo", &ids, 120.0);
        g.validate().unwrap();
        assert!(g.n_cells() >= 8, "{}", g.n_cells());
        assert_eq!(g.config_ids(), ids);
    }
}
