//! Sweep execution: run every grid cell through the facility pipeline in
//! parallel over one shared [`Generator`], then summarize and export.
//!
//! Artifact sharing: [`run_sweep`] first [`Generator::prepare`]s each
//! configuration some cell actually uses (artifact JSON parse + classifier
//! construction + packed-weight build happen exactly once per config, not
//! per cell), then fans cells across a thread pool with
//! [`Generator::facility_shared_batched`] — which itself parallelizes
//! across racks inside a cell and scans each rack's same-config servers
//! through the classifier as one batched call (§Perf). Outer/inner worker
//! counts are balanced automatically unless pinned in [`SweepOptions`].
//!
//! Streaming (>24 h) mode: with [`SweepOptions::window_s`] set, each cell
//! runs through [`Generator::facility_shared_windowed`] instead — horizon
//! length no longer bounds memory. Per window, incremental RFC-4180 CSV
//! writers ([`StreamingCsv`]) append the rack/row/facility rows that the
//! buffered [`SweepReport::write`] would have produced (byte-identical
//! where both paths can run: the writers share the exact resample-chunk
//! geometry and float formatting), and a
//! [`StreamingPlanningStats`] folds the summary — exact
//! peak/mean/energy/ramp, p99 exact up to
//! [`crate::metrics::planning::EXACT_QUANTILE_CAP`] samples and
//! histogram-bounded beyond it.
//!
//! Determinism: every cell's output is a pure function of its
//! `(ScenarioSpec, seed)` (see [`Generator::facility_shared`]), and the
//! summary CSV deliberately contains no wall-clock fields, so re-running a
//! grid with the same seeds reproduces byte-identical summaries.

use super::grid::{SweepCell, SweepGrid};
use crate::aggregate::{MultiScale, ScaleConfig, StreamingFacilityAccumulator};
use crate::coordinator::Generator;
use crate::metrics::planning::{PlanningStats, StreamingPlanningStats, StreamingResampler};
use crate::util::threadpool::{default_workers, parallel_map};
use anyhow::{ensure, Context, Result};
use std::io::Write;
use std::path::Path;
use std::time::Instant;

/// Execution knobs for one sweep run.
#[derive(Debug, Clone)]
pub struct SweepOptions {
    /// Server-sample interval the pipeline generates at (paper: 250 ms).
    pub dt_s: f64,
    /// Ramp-measurement interval for the summary stats (paper: 15 min).
    /// Per cell this is clamped to half the scenario horizon (and no less
    /// than `dt_s`) so short grids still measure a ramp instead of
    /// reporting an identically-zero one from a single window.
    pub ramp_interval_s: f64,
    /// Concurrent scenarios; 0 = auto (bounded by cell count and cores).
    pub scenario_workers: usize,
    /// Worker threads inside each scenario; 0 = auto (cores left over
    /// after scenario-level parallelism).
    pub server_workers: usize,
    /// Servers per batched classifier call inside each rack
    /// (0 = [`crate::coordinator::DEFAULT_MAX_BATCH`], 1 = sequential).
    /// Every width produces byte-identical cell output — see
    /// [`Generator::facility_shared_batched`] — so this is purely a
    /// throughput/memory knob.
    pub max_batch: usize,
    /// Generation window in seconds for the streaming path
    /// (0 = buffered one-shot). With a window set, per-cell memory is
    /// O(racks × window) and exports stream to disk as windows complete —
    /// pass the output directory to [`run_sweep_to`] so the writers have
    /// somewhere to stream.
    pub window_s: f64,
    /// Export intervals per aggregation level.
    pub scales: ScaleConfig,
}

impl Default for SweepOptions {
    fn default() -> Self {
        SweepOptions {
            dt_s: 0.25,
            ramp_interval_s: 900.0,
            scenario_workers: 0,
            server_workers: 0,
            max_batch: 0,
            window_s: 0.0,
            scales: ScaleConfig::default(),
        }
    }
}

/// One executed grid cell.
pub struct CellResult {
    pub cell: SweepCell,
    /// Planning summary of the facility PCC series at the generation dt.
    pub stats: PlanningStats,
    /// Multi-resolution export (racks / rows / facility). `None` for
    /// streamed cells — their series went straight to disk, window by
    /// window, and were never materialized.
    pub scales: Option<MultiScale>,
    /// `false` when `stats.p99_w` / `stats.cv` came from the streaming
    /// histogram (horizon exceeded the exact-sample cap); the error bound
    /// is in [`CellResult::p99_bound_w`].
    pub exact_quantiles: bool,
    /// Absolute error bound on `stats.p99_w` (0 when exact).
    pub p99_bound_w: f64,
    /// Wall-clock seconds this cell took (reporting only; never exported).
    pub wall_s: f64,
}

/// A completed sweep: the grid plus every cell result, in grid order.
pub struct SweepReport {
    pub grid: SweepGrid,
    pub dt_s: f64,
    pub cells: Vec<CellResult>,
}

/// Expand and execute a grid (buffered, or streaming when
/// `opts.window_s > 0` — see [`run_sweep_to`] to stream CSV exports).
pub fn run_sweep(gen: &mut Generator, grid: &SweepGrid, opts: &SweepOptions) -> Result<SweepReport> {
    run_sweep_to(gen, grid, opts, None)
}

/// [`run_sweep`] with a streaming export directory: when
/// `opts.window_s > 0` and `stream_dir` is given, every cell's
/// rack/row/facility CSVs are appended window-by-window under
/// `<stream_dir>/<cell>/` while the cell generates (byte-identical to what
/// the buffered [`SweepReport::write`] would produce). Call
/// [`SweepReport::write`] on the same directory afterwards to add
/// `grid.json`, `summary.csv`, and the per-cell `scenario.json`s.
pub fn run_sweep_to(
    gen: &mut Generator,
    grid: &SweepGrid,
    opts: &SweepOptions,
    stream_dir: Option<&Path>,
) -> Result<SweepReport> {
    grid.validate()?;
    ensure!(
        opts.dt_s.is_finite() && opts.dt_s > 0.0,
        "sweep: dt must be positive seconds (got {})",
        opts.dt_s
    );
    let cells = grid.expand();
    // Shared-artifact hoist: each config some cell actually uses is
    // prepared exactly once, no matter how many cells (or racks) use it.
    let mut needed: Vec<String> = Vec::new();
    for cell in &cells {
        for id in cell.spec.server_config.config_ids_used(&cell.spec.topology) {
            if !needed.contains(&id) {
                needed.push(id);
            }
        }
    }
    for id in needed {
        gen.prepare(&id).with_context(|| format!("preparing config '{id}'"))?;
    }
    let n = cells.len();
    let outer = match opts.scenario_workers {
        0 => default_workers().min(n).max(1),
        w => w.min(n).max(1),
    };
    let inner = match opts.server_workers {
        0 => (default_workers() / outer).max(1),
        w => w,
    };
    if let Some(dir) = stream_dir {
        std::fs::create_dir_all(dir)?;
    }
    let gen_ro: &Generator = gen;
    let results: Vec<Result<CellResult>> = parallel_map(n, outer, |i| {
        let cell = &cells[i];
        let t0 = Instant::now();
        let (stats, scales, exact, bound) = (|| -> Result<_> {
            if opts.window_s > 0.0 {
                let cdir = stream_dir.map(|d| d.join(&cell.id));
                let (stats, exact, bound) =
                    run_cell_streaming(gen_ro, cell, opts, inner, cdir.as_deref())?;
                Ok((stats, None, exact, bound))
            } else {
                let run =
                    gen_ro.facility_shared_batched(&cell.spec, opts.dt_s, inner, opts.max_batch)?;
                let site = run.facility_series();
                let ramp_s = cell_ramp_interval(opts, cell.spec.horizon_s);
                let stats = PlanningStats::compute(&site, opts.dt_s, ramp_s)?;
                let scales = run.acc.multi_scale(opts.dt_s, cell.spec.pue, &opts.scales)?;
                Ok((stats, Some(scales), true, 0.0))
            }
        })()
        .with_context(|| format!("cell {}", cell.id))?;
        Ok(CellResult {
            cell: cell.clone(),
            stats,
            scales,
            exact_quantiles: exact,
            p99_bound_w: bound,
            wall_s: t0.elapsed().as_secs_f64(),
        })
    });
    let mut out = Vec::with_capacity(n);
    for r in results {
        out.push(r?);
    }
    Ok(SweepReport { grid: grid.clone(), dt_s: opts.dt_s, cells: out })
}

/// See [`SweepOptions::ramp_interval_s`]: keep ≥ 2 windows in range (the
/// shared [`clamp_ramp_interval`](crate::metrics::planning::clamp_ramp_interval) policy).
fn cell_ramp_interval(opts: &SweepOptions, horizon_s: f64) -> f64 {
    crate::metrics::planning::clamp_ramp_interval(opts.ramp_interval_s, horizon_s, opts.dt_s)
}

/// Run one cell through the windowed streaming pipeline: fold summary
/// stats per window and (optionally) append the multi-scale CSVs under
/// `cdir`. Returns `(stats, exact_quantiles, p99_bound)`.
fn run_cell_streaming(
    gen: &Generator,
    cell: &SweepCell,
    opts: &SweepOptions,
    inner_workers: usize,
    cdir: Option<&Path>,
) -> Result<(PlanningStats, bool, f64)> {
    let spec = &cell.spec;
    let ramp_s = cell_ramp_interval(opts, spec.horizon_s);
    let mut stats = StreamingPlanningStats::new(opts.dt_s, ramp_s)?;
    let mut writers = match cdir {
        Some(d) => Some(CellWriters::create(
            d,
            spec.topology.n_racks(),
            spec.topology.rows,
            spec.pue,
            opts,
        )?),
        None => None,
    };
    let mut rows_buf: Vec<Vec<f64>> = Vec::new();
    let mut site_buf: Vec<f64> = Vec::new();
    let mut site_pcc: Vec<f32> = Vec::new();
    let pue = spec.pue;
    gen.facility_shared_windowed(
        spec,
        opts.dt_s,
        opts.window_s,
        inner_workers,
        opts.max_batch,
        |acc| {
            acc.fold_rows_site(&mut rows_buf, &mut site_buf);
            // The PCC f32 series exactly as the buffered stats path builds
            // it — the shared helper owns the deliberate double rounding.
            crate::aggregate::pcc_window_into(&site_buf, pue, &mut site_pcc);
            stats.push_slice(&site_pcc);
            if let Some(w) = writers.as_mut() {
                w.push_window(acc, &rows_buf, &site_buf)?;
            }
            Ok(())
        },
    )?;
    if let Some(w) = writers {
        w.finish()?;
    }
    let out = stats.finalize()?;
    Ok((out.stats, out.exact_quantiles, out.p99_error_bound_w))
}

impl SweepReport {
    /// The planning summary as CSV. Deterministic per (grid, seeds): values
    /// are emitted with Rust's shortest round-trip float formatting and no
    /// timing columns.
    pub fn summary_csv(&self) -> String {
        let mut s = String::from(
            "cell,workload,topology,fleet,servers,seed,\
             peak_w,avg_w,p99_w,energy_kwh,max_ramp_w,cv,peak_to_average,load_factor\n",
        );
        for c in &self.cells {
            let t = c.cell.spec.topology;
            let fleet = c.cell.spec.server_config.config_ids().join("+");
            s.push_str(&format!(
                "{},{},{}x{}x{},{},{},{},{},{},{},{},{},{},{},{}\n",
                c.cell.id,
                csv_field(&c.cell.spec.workload.label()),
                t.rows,
                t.racks_per_row,
                t.servers_per_rack,
                csv_field(&fleet),
                t.n_servers(),
                c.cell.spec.seed,
                c.stats.peak_w,
                c.stats.avg_w,
                c.stats.p99_w,
                c.stats.energy_kwh,
                c.stats.max_ramp_w,
                c.stats.cv,
                c.stats.peak_to_average,
                c.stats.load_factor,
            ));
        }
        s
    }

    /// Human-readable summary table (kW units, wall-clock included).
    pub fn summary_table(&self) -> String {
        let mut s = format!(
            "{:<14} {:<44} {:>6} {:>9} {:>9} {:>9} {:>9} {:>9} {:>7} {:>6} {:>7}\n",
            "cell", "scenario", "srv", "peak kW", "avg kW", "p99 kW", "MWh", "ramp kW", "CV", "PAR",
            "wall s"
        );
        for c in &self.cells {
            s.push_str(&format!(
                "{:<14} {:<44} {:>6} {:>9.1} {:>9.1} {:>8.1}{} {:>9.2} {:>9.1} {:>7.3} {:>6.2} {:>7.1}\n",
                c.cell.id,
                truncate(&c.cell.label, 44),
                c.cell.spec.topology.n_servers(),
                c.stats.peak_w / 1e3,
                c.stats.avg_w / 1e3,
                c.stats.p99_w / 1e3,
                if c.exact_quantiles { " " } else { "~" },
                c.stats.energy_kwh / 1e3,
                c.stats.max_ramp_w / 1e3,
                c.stats.cv,
                c.stats.peak_to_average,
                c.wall_s,
            ));
        }
        s
    }

    /// Write the full report under `dir`:
    ///
    /// ```text
    /// <dir>/grid.json                      the grid (reproduction recipe)
    /// <dir>/summary.csv                    one PlanningStats row per cell
    /// <dir>/<cell>/scenario.json           the expanded ScenarioSpec
    /// <dir>/<cell>/racks_<interval>s.csv   per-rack IT power
    /// <dir>/<cell>/rows_<interval>s.csv    per-row IT power
    /// <dir>/<cell>/facility_<interval>s.csv  PCC power per facility scale
    /// ```
    ///
    /// Cells executed in streaming mode carry no in-memory series
    /// (`scales: None`); their series CSVs were already appended
    /// incrementally by [`run_sweep_to`] into the same layout, so this
    /// writes only the metadata files for them.
    pub fn write(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        self.grid.save(&dir.join("grid.json"))?;
        std::fs::write(dir.join("summary.csv"), self.summary_csv())?;
        for c in &self.cells {
            let cdir = dir.join(&c.cell.id);
            std::fs::create_dir_all(&cdir)?;
            c.cell.spec.save(&cdir.join("scenario.json"))?;
            let Some(scales) = &c.scales else { continue };
            let sc = &scales.scales;
            write_series_csv(
                &cdir.join(format!("racks_{}s.csv", fmt_secs(sc.rack_interval_s))),
                "rack",
                sc.rack_interval_s,
                &scales.racks_w,
            )?;
            write_series_csv(
                &cdir.join(format!("rows_{}s.csv", fmt_secs(sc.row_interval_s))),
                "row",
                sc.row_interval_s,
                &scales.rows_w,
            )?;
            for (k, &interval) in sc.facility_intervals_s.iter().enumerate() {
                write_series_csv(
                    &cdir.join(format!("facility_{}s.csv", fmt_secs(interval))),
                    "facility",
                    interval,
                    std::slice::from_ref(&scales.facility_w[k]),
                )?;
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Incremental CSV writers (streaming mode)
// ---------------------------------------------------------------------------

/// One cell's set of incremental multi-scale CSV writers.
struct CellWriters {
    racks: StreamingCsv,
    rows: StreamingCsv,
    facility: Vec<StreamingCsv>,
}

impl CellWriters {
    fn create(
        cdir: &Path,
        n_racks: usize,
        n_rows: usize,
        pue: f64,
        opts: &SweepOptions,
    ) -> Result<CellWriters> {
        std::fs::create_dir_all(cdir)?;
        let sc = &opts.scales;
        let racks = StreamingCsv::create(
            &cdir.join(format!("racks_{}s.csv", fmt_secs(sc.rack_interval_s))),
            "rack",
            n_racks,
            opts.dt_s,
            sc.rack_interval_s,
            1.0,
        )?;
        let rows = StreamingCsv::create(
            &cdir.join(format!("rows_{}s.csv", fmt_secs(sc.row_interval_s))),
            "row",
            n_rows,
            opts.dt_s,
            sc.row_interval_s,
            1.0,
        )?;
        let facility = sc
            .facility_intervals_s
            .iter()
            .map(|&interval| {
                // PUE rides on the resampler's scale factor, exactly as the
                // buffered `resample_mean_f64(&site, dt, interval, pue)`.
                StreamingCsv::create(
                    &cdir.join(format!("facility_{}s.csv", fmt_secs(interval))),
                    "facility",
                    1,
                    opts.dt_s,
                    interval,
                    pue,
                )
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(CellWriters { racks, rows, facility })
    }

    /// Append one generation window across every writer. `rows_w`/`site_w`
    /// are the per-row and site IT windows from
    /// [`StreamingFacilityAccumulator::fold_rows_site`].
    fn push_window(
        &mut self,
        acc: &mut StreamingFacilityAccumulator,
        rows_w: &[Vec<f64>],
        site_w: &[f64],
    ) -> Result<()> {
        for r in 0..acc.topology().n_racks() {
            self.racks.push_col(r, acc.rack_window(r));
        }
        self.racks.write_ready_rows()?;
        for (r, row) in rows_w.iter().enumerate() {
            self.rows.push_col(r, row);
        }
        self.rows.write_ready_rows()?;
        for f in self.facility.iter_mut() {
            f.push_col(0, site_w);
            f.write_ready_rows()?;
        }
        Ok(())
    }

    fn finish(self) -> Result<()> {
        self.racks.finish()?;
        self.rows.finish()?;
        for f in self.facility {
            f.finish()?;
        }
        Ok(())
    }
}

/// Incremental columnar series CSV (`t_s,<stem>_0,...`): each column owns a
/// [`StreamingResampler`], rows are appended as soon as every column has
/// emitted a value. Byte-identical to [`write_series_csv`] on the buffered
/// [`MultiScale`] series because the resampler reproduces
/// `resample_mean_f64` exactly and both share [`fmt_secs`] + Rust's
/// shortest round-trip f32 formatting. Crate-visible: the site composition
/// engine ([`crate::site`]) streams `site_load.csv` through the same
/// writer so facility and site exports can never drift in format.
pub(crate) struct StreamingCsv {
    out: std::io::BufWriter<std::fs::File>,
    interval_s: f64,
    next_row: usize,
    cols: Vec<StreamingResampler>,
    pending: Vec<std::collections::VecDeque<f32>>,
    line: String,
}

impl StreamingCsv {
    pub(crate) fn create(
        path: &Path,
        stem: &str,
        n_cols: usize,
        dt_s: f64,
        interval_s: f64,
        scale: f64,
    ) -> Result<StreamingCsv> {
        let names: Vec<String> = (0..n_cols).map(|i| format!("{stem}_{i}")).collect();
        Self::create_named(path, &names, dt_s, interval_s, scale)
    }

    /// [`StreamingCsv::create`] with explicit column names (the site
    /// export's `site_w,<facility>_w` header).
    pub(crate) fn create_named(
        path: &Path,
        col_names: &[String],
        dt_s: f64,
        interval_s: f64,
        scale: f64,
    ) -> Result<StreamingCsv> {
        let file = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        let mut out = std::io::BufWriter::new(file);
        let mut header = String::from("t_s");
        for name in col_names {
            header.push(',');
            header.push_str(&csv_field(name));
        }
        header.push('\n');
        out.write_all(header.as_bytes())?;
        let cols = col_names
            .iter()
            .map(|_| StreamingResampler::new(dt_s, interval_s, scale))
            .collect::<Result<Vec<_>>>()?;
        Ok(StreamingCsv {
            out,
            interval_s,
            next_row: 0,
            cols,
            pending: (0..col_names.len()).map(|_| std::collections::VecDeque::new()).collect(),
            line: String::new(),
        })
    }

    pub(crate) fn push_col(&mut self, col: usize, xs: &[f64]) {
        let (r, q) = (&mut self.cols[col], &mut self.pending[col]);
        for &x in xs {
            if let Some(v) = r.push(x) {
                q.push_back(v);
            }
        }
    }

    /// [`StreamingCsv::push_col`] over an f32 window (each sample widened
    /// to f64 before the resampler fold — the same expression the f64 path
    /// would see for values that started life as f32).
    pub(crate) fn push_col_f32(&mut self, col: usize, xs: &[f32]) {
        let (r, q) = (&mut self.cols[col], &mut self.pending[col]);
        for &x in xs {
            if let Some(v) = r.push(x as f64) {
                q.push_back(v);
            }
        }
    }

    pub(crate) fn write_ready_rows(&mut self) -> Result<()> {
        let ready = self.pending.iter().map(|q| q.len()).min().unwrap_or(0);
        for _ in 0..ready {
            self.line.clear();
            self.line.push_str(&fmt_secs(self.next_row as f64 * self.interval_s));
            for q in self.pending.iter_mut() {
                let v = q.pop_front().expect("ready rows counted");
                self.line.push(',');
                self.line.push_str(&format!("{v}"));
            }
            self.line.push('\n');
            self.out.write_all(self.line.as_bytes())?;
            self.next_row += 1;
        }
        Ok(())
    }

    /// Flush the trailing partial resample window of every column (the
    /// buffered `resample_mean` emits it averaged over its actual length)
    /// and write the final row(s).
    pub(crate) fn finish(mut self) -> Result<()> {
        for (r, q) in self.cols.iter_mut().zip(self.pending.iter_mut()) {
            if let Some((v, _count)) = r.flush() {
                q.push_back(v);
            }
        }
        self.write_ready_rows()?;
        debug_assert!(self.pending.iter().all(|q| q.is_empty()), "ragged columns");
        self.out.flush()?;
        Ok(())
    }
}

/// RFC-4180 quoting for free-text CSV fields (a replay workload's path
/// may contain commas or quotes).
pub(crate) fn csv_field(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

/// `300` for whole seconds, `0.25` otherwise (file-name friendly).
pub(crate) fn fmt_secs(x: f64) -> String {
    if x.fract() == 0.0 {
        format!("{}", x as i64)
    } else {
        format!("{x}")
    }
}

fn truncate(s: &str, max: usize) -> String {
    if s.chars().count() <= max {
        s.to_string()
    } else {
        let cut: String = s.chars().take(max.saturating_sub(1)).collect();
        format!("{cut}…")
    }
}

/// `t_s,<stem>_0,<stem>_1,...` — shared by the buffered and streaming
/// writers so their headers can never drift apart.
fn series_csv_header(stem: &str, n_cols: usize) -> String {
    let mut out = String::from("t_s");
    for i in 0..n_cols {
        out.push_str(&format!(",{stem}_{i}"));
    }
    out.push('\n');
    out
}

/// Columnar CSV: `t_s,<stem>_0,<stem>_1,...` with one row per interval.
fn write_series_csv(path: &Path, stem: &str, interval_s: f64, series: &[Vec<f32>]) -> Result<()> {
    let n = series.iter().map(|s| s.len()).max().unwrap_or(0);
    let mut out = series_csv_header(stem, series.len());
    for t in 0..n {
        out.push_str(&fmt_secs(t as f64 * interval_s));
        for s in series {
            out.push(',');
            if t < s.len() {
                out.push_str(&format!("{}", s[t]));
            }
        }
        out.push('\n');
    }
    std::fs::write(path, out).with_context(|| format!("writing {}", path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_field_quotes_only_when_needed() {
        assert_eq!(csv_field("poisson λ=0.5"), "poisson λ=0.5");
        assert_eq!(csv_field("replay a,b.json"), "\"replay a,b.json\"");
        assert_eq!(csv_field("say \"hi\""), "\"say \"\"hi\"\"\"");
    }

    #[test]
    fn fmt_secs_is_filename_friendly() {
        assert_eq!(fmt_secs(300.0), "300");
        assert_eq!(fmt_secs(1.0), "1");
        assert_eq!(fmt_secs(0.25), "0.25");
    }

    #[test]
    fn truncate_respects_char_boundaries() {
        assert_eq!(truncate("short", 10), "short");
        let t = truncate("λ̄-burstiness-very-long-label", 10);
        assert!(t.chars().count() <= 10);
    }

    #[test]
    fn series_csv_shape() {
        let dir = std::env::temp_dir().join("powertrace_test_runner");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("racks.csv");
        write_series_csv(&p, "rack", 15.0, &[vec![1.0, 2.0], vec![3.0, 4.0]]).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[0], "t_s,rack_0,rack_1");
        assert_eq!(lines[1], "0,1,2");
        assert_eq!(lines[2], "15,3,4");
        assert_eq!(lines.len(), 3);
    }

    #[test]
    fn streaming_csv_matches_buffered_writer_bytes() {
        // Two columns of f64 data pushed in ragged windows must produce the
        // byte-identical file to resampling whole series and using
        // write_series_csv — including the partial trailing window.
        let dir = std::env::temp_dir().join("powertrace_test_streaming_csv");
        std::fs::create_dir_all(&dir).unwrap();
        let (dt, interval) = (0.25, 1.5); // stride 6
        let n = 100; // 100 = 16×6 + 4 → partial tail
        let cols: Vec<Vec<f64>> = (0..2)
            .map(|c| (0..n).map(|i| 1000.0 + (c * 37 + i) as f64 * 0.83).collect())
            .collect();
        // Buffered reference.
        let buffered: Vec<Vec<f32>> = cols
            .iter()
            .map(|col| {
                col.chunks(6)
                    .map(|ch| (ch.iter().sum::<f64>() / ch.len() as f64) as f32)
                    .collect()
            })
            .collect();
        let pb = dir.join("buffered.csv");
        write_series_csv(&pb, "rack", interval, &buffered).unwrap();
        // Streaming writer fed in windows of 7.
        let ps = dir.join("streamed.csv");
        let mut w = StreamingCsv::create(&ps, "rack", 2, dt, interval, 1.0).unwrap();
        let mut t0 = 0;
        while t0 < n {
            let wlen = 7.min(n - t0);
            for (c, col) in cols.iter().enumerate() {
                w.push_col(c, &col[t0..t0 + wlen]);
            }
            w.write_ready_rows().unwrap();
            t0 += wlen;
        }
        w.finish().unwrap();
        let a = std::fs::read(&pb).unwrap();
        let b = std::fs::read(&ps).unwrap();
        assert_eq!(a, b, "streamed CSV bytes differ from buffered");
    }
}
