//! Sweep execution: run every grid cell through the facility pipeline in
//! parallel over one shared [`Generator`], then summarize and export.
//!
//! Artifact sharing: [`run_sweep`] first [`Generator::prepare`]s each
//! configuration some cell actually uses (artifact JSON parse + classifier
//! construction + packed-weight build happen exactly once per config, not
//! per cell), then fans cells across a thread pool with
//! [`Generator::facility_shared_batched`] — which itself parallelizes
//! across racks inside a cell and scans each rack's same-config servers
//! through the classifier as one batched call (§Perf). Outer/inner worker
//! counts are balanced automatically unless pinned in [`SweepOptions`].
//!
//! Determinism: every cell's output is a pure function of its
//! `(ScenarioSpec, seed)` (see [`Generator::facility_shared`]), and the
//! summary CSV deliberately contains no wall-clock fields, so re-running a
//! grid with the same seeds reproduces byte-identical summaries.

use super::grid::{SweepCell, SweepGrid};
use crate::aggregate::{MultiScale, ScaleConfig};
use crate::coordinator::Generator;
use crate::metrics::PlanningStats;
use crate::util::threadpool::{default_workers, parallel_map};
use anyhow::{Context, Result};
use std::path::Path;
use std::time::Instant;

/// Execution knobs for one sweep run.
#[derive(Debug, Clone)]
pub struct SweepOptions {
    /// Server-sample interval the pipeline generates at (paper: 250 ms).
    pub dt_s: f64,
    /// Ramp-measurement interval for the summary stats (paper: 15 min).
    /// Per cell this is clamped to half the scenario horizon (and no less
    /// than `dt_s`) so short grids still measure a ramp instead of
    /// reporting an identically-zero one from a single window.
    pub ramp_interval_s: f64,
    /// Concurrent scenarios; 0 = auto (bounded by cell count and cores).
    pub scenario_workers: usize,
    /// Worker threads inside each scenario; 0 = auto (cores left over
    /// after scenario-level parallelism).
    pub server_workers: usize,
    /// Servers per batched classifier call inside each rack
    /// (0 = [`crate::coordinator::DEFAULT_MAX_BATCH`], 1 = sequential).
    /// Every width produces byte-identical cell output — see
    /// [`Generator::facility_shared_batched`] — so this is purely a
    /// throughput/memory knob.
    pub max_batch: usize,
    /// Export intervals per aggregation level.
    pub scales: ScaleConfig,
}

impl Default for SweepOptions {
    fn default() -> Self {
        SweepOptions {
            dt_s: 0.25,
            ramp_interval_s: 900.0,
            scenario_workers: 0,
            server_workers: 0,
            max_batch: 0,
            scales: ScaleConfig::default(),
        }
    }
}

/// One executed grid cell.
pub struct CellResult {
    pub cell: SweepCell,
    /// Planning summary of the facility PCC series at the generation dt.
    pub stats: PlanningStats,
    /// Multi-resolution export (racks / rows / facility).
    pub scales: MultiScale,
    /// Wall-clock seconds this cell took (reporting only; never exported).
    pub wall_s: f64,
}

/// A completed sweep: the grid plus every cell result, in grid order.
pub struct SweepReport {
    pub grid: SweepGrid,
    pub dt_s: f64,
    pub cells: Vec<CellResult>,
}

/// Expand and execute a grid. Cell results come back in expansion order.
pub fn run_sweep(gen: &mut Generator, grid: &SweepGrid, opts: &SweepOptions) -> Result<SweepReport> {
    grid.validate()?;
    let cells = grid.expand();
    // Shared-artifact hoist: each config some cell actually uses is
    // prepared exactly once, no matter how many cells (or racks) use it.
    let mut needed: Vec<String> = Vec::new();
    for cell in &cells {
        for id in cell.spec.server_config.config_ids_used(&cell.spec.topology) {
            if !needed.contains(&id) {
                needed.push(id);
            }
        }
    }
    for id in needed {
        gen.prepare(&id).with_context(|| format!("preparing config '{id}'"))?;
    }
    let n = cells.len();
    let outer = match opts.scenario_workers {
        0 => default_workers().min(n).max(1),
        w => w.min(n).max(1),
    };
    let inner = match opts.server_workers {
        0 => (default_workers() / outer).max(1),
        w => w,
    };
    let gen_ro: &Generator = gen;
    let results: Vec<Result<CellResult>> = parallel_map(n, outer, |i| {
        let cell = &cells[i];
        let t0 = Instant::now();
        let run = gen_ro
            .facility_shared_batched(&cell.spec, opts.dt_s, inner, opts.max_batch)
            .with_context(|| format!("cell {}", cell.id))?;
        let site = run.facility_series();
        // See SweepOptions::ramp_interval_s: keep ≥ 2 windows in range.
        let ramp_s = opts.ramp_interval_s.min(cell.spec.horizon_s / 2.0).max(opts.dt_s);
        let stats = PlanningStats::compute(&site, opts.dt_s, ramp_s);
        let scales = run.acc.multi_scale(opts.dt_s, cell.spec.pue, &opts.scales);
        Ok(CellResult { cell: cell.clone(), stats, scales, wall_s: t0.elapsed().as_secs_f64() })
    });
    let mut out = Vec::with_capacity(n);
    for r in results {
        out.push(r?);
    }
    Ok(SweepReport { grid: grid.clone(), dt_s: opts.dt_s, cells: out })
}

impl SweepReport {
    /// The planning summary as CSV. Deterministic per (grid, seeds): values
    /// are emitted with Rust's shortest round-trip float formatting and no
    /// timing columns.
    pub fn summary_csv(&self) -> String {
        let mut s = String::from(
            "cell,workload,topology,fleet,servers,seed,\
             peak_w,avg_w,p99_w,max_ramp_w,cv,peak_to_average,load_factor\n",
        );
        for c in &self.cells {
            let t = c.cell.spec.topology;
            let fleet = c.cell.spec.server_config.config_ids().join("+");
            s.push_str(&format!(
                "{},{},{}x{}x{},{},{},{},{},{},{},{},{},{},{}\n",
                c.cell.id,
                csv_field(&c.cell.spec.workload.label()),
                t.rows,
                t.racks_per_row,
                t.servers_per_rack,
                csv_field(&fleet),
                t.n_servers(),
                c.cell.spec.seed,
                c.stats.peak_w,
                c.stats.avg_w,
                c.stats.p99_w,
                c.stats.max_ramp_w,
                c.stats.cv,
                c.stats.peak_to_average,
                c.stats.load_factor,
            ));
        }
        s
    }

    /// Human-readable summary table (kW units, wall-clock included).
    pub fn summary_table(&self) -> String {
        let mut s = format!(
            "{:<14} {:<44} {:>6} {:>9} {:>9} {:>9} {:>9} {:>7} {:>6} {:>7}\n",
            "cell", "scenario", "srv", "peak kW", "avg kW", "p99 kW", "ramp kW", "CV", "PAR", "wall s"
        );
        for c in &self.cells {
            s.push_str(&format!(
                "{:<14} {:<44} {:>6} {:>9.1} {:>9.1} {:>9.1} {:>9.1} {:>7.3} {:>6.2} {:>7.1}\n",
                c.cell.id,
                truncate(&c.cell.label, 44),
                c.cell.spec.topology.n_servers(),
                c.stats.peak_w / 1e3,
                c.stats.avg_w / 1e3,
                c.stats.p99_w / 1e3,
                c.stats.max_ramp_w / 1e3,
                c.stats.cv,
                c.stats.peak_to_average,
                c.wall_s,
            ));
        }
        s
    }

    /// Write the full report under `dir`:
    ///
    /// ```text
    /// <dir>/grid.json                      the grid (reproduction recipe)
    /// <dir>/summary.csv                    one PlanningStats row per cell
    /// <dir>/<cell>/scenario.json           the expanded ScenarioSpec
    /// <dir>/<cell>/racks_<interval>s.csv   per-rack IT power
    /// <dir>/<cell>/rows_<interval>s.csv    per-row IT power
    /// <dir>/<cell>/facility_<interval>s.csv  PCC power per facility scale
    /// ```
    pub fn write(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        self.grid.save(&dir.join("grid.json"))?;
        std::fs::write(dir.join("summary.csv"), self.summary_csv())?;
        for c in &self.cells {
            let cdir = dir.join(&c.cell.id);
            std::fs::create_dir_all(&cdir)?;
            c.cell.spec.save(&cdir.join("scenario.json"))?;
            let sc = &c.scales.scales;
            write_series_csv(
                &cdir.join(format!("racks_{}s.csv", fmt_secs(sc.rack_interval_s))),
                "rack",
                sc.rack_interval_s,
                &c.scales.racks_w,
            )?;
            write_series_csv(
                &cdir.join(format!("rows_{}s.csv", fmt_secs(sc.row_interval_s))),
                "row",
                sc.row_interval_s,
                &c.scales.rows_w,
            )?;
            for (k, &interval) in sc.facility_intervals_s.iter().enumerate() {
                write_series_csv(
                    &cdir.join(format!("facility_{}s.csv", fmt_secs(interval))),
                    "facility",
                    interval,
                    std::slice::from_ref(&c.scales.facility_w[k]),
                )?;
            }
        }
        Ok(())
    }
}

/// RFC-4180 quoting for free-text CSV fields (a replay workload's path
/// may contain commas or quotes).
fn csv_field(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

/// `300` for whole seconds, `0.25` otherwise (file-name friendly).
fn fmt_secs(x: f64) -> String {
    if x.fract() == 0.0 {
        format!("{}", x as i64)
    } else {
        format!("{x}")
    }
}

fn truncate(s: &str, max: usize) -> String {
    if s.chars().count() <= max {
        s.to_string()
    } else {
        let cut: String = s.chars().take(max.saturating_sub(1)).collect();
        format!("{cut}…")
    }
}

/// Columnar CSV: `t_s,<stem>_0,<stem>_1,...` with one row per interval.
fn write_series_csv(path: &Path, stem: &str, interval_s: f64, series: &[Vec<f32>]) -> Result<()> {
    let n = series.iter().map(|s| s.len()).max().unwrap_or(0);
    let mut out = String::from("t_s");
    for i in 0..series.len() {
        out.push_str(&format!(",{stem}_{i}"));
    }
    out.push('\n');
    for t in 0..n {
        out.push_str(&fmt_secs(t as f64 * interval_s));
        for s in series {
            out.push(',');
            if t < s.len() {
                out.push_str(&format!("{}", s[t]));
            }
        }
        out.push('\n');
    }
    std::fs::write(path, out).with_context(|| format!("writing {}", path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_field_quotes_only_when_needed() {
        assert_eq!(csv_field("poisson λ=0.5"), "poisson λ=0.5");
        assert_eq!(csv_field("replay a,b.json"), "\"replay a,b.json\"");
        assert_eq!(csv_field("say \"hi\""), "\"say \"\"hi\"\"\"");
    }

    #[test]
    fn fmt_secs_is_filename_friendly() {
        assert_eq!(fmt_secs(300.0), "300");
        assert_eq!(fmt_secs(1.0), "1");
        assert_eq!(fmt_secs(0.25), "0.25");
    }

    #[test]
    fn truncate_respects_char_boundaries() {
        assert_eq!(truncate("short", 10), "short");
        let t = truncate("λ̄-burstiness-very-long-label", 10);
        assert!(t.chars().count() <= 10);
    }

    #[test]
    fn series_csv_shape() {
        let dir = std::env::temp_dir().join("powertrace_test_runner");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("racks.csv");
        write_series_csv(&p, "rack", 15.0, &[vec![1.0, 2.0], vec![3.0, 4.0]]).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[0], "t_s,rack_0,rack_1");
        assert_eq!(lines[1], "0,1,2");
        assert_eq!(lines[2], "15,3,4");
        assert_eq!(lines.len(), 3);
    }
}
