//! Sweep execution: run every grid cell through the facility pipeline in
//! parallel over one shared [`Generator`], then summarize and export.
//!
//! Artifact sharing: [`run_sweep`] first [`Generator::prepare`]s each
//! configuration some cell actually uses (artifact JSON parse + classifier
//! construction + packed-weight build happen exactly once per config, not
//! per cell), then fans cells across a thread pool with
//! [`Generator::facility_shared_batched`] — which itself parallelizes
//! across racks inside a cell and scans each rack's same-config servers
//! through the classifier as one batched call (§Perf). Outer/inner worker
//! counts are balanced automatically unless pinned in [`SweepOptions`].
//!
//! Streaming (>24 h) mode: with [`SweepOptions::window_s`] set, each cell
//! runs through [`Generator::facility_shared_windowed`] instead — horizon
//! length no longer bounds memory. Per window, incremental RFC-4180 CSV
//! writers ([`StreamingCsv`]) append the rack/row/facility rows that the
//! buffered [`SweepReport::write`] would have produced (byte-identical
//! where both paths can run: the writers share the exact resample-chunk
//! geometry and float formatting), and a
//! [`StreamingPlanningStats`] folds the summary — exact
//! peak/mean/energy/ramp, p99 exact up to
//! [`crate::metrics::planning::EXACT_QUANTILE_CAP`] samples and
//! histogram-bounded beyond it.
//!
//! Determinism: every cell's output is a pure function of its
//! `(ScenarioSpec, seed)` (see [`Generator::facility_shared`]), and the
//! summary CSV deliberately contains no wall-clock fields, so re-running a
//! grid with the same seeds reproduces byte-identical summaries.
//!
//! Crash safety: [`run_sweep_checkpointed`] wraps the same execution in the
//! [`crate::robust`] layer — a durable [`RunManifest`] under the output
//! directory, per-cell `catch_unwind` + retry isolation
//! ([`RetryPolicy`]), and atomic exports. A run killed at any point (or
//! with cells quarantined) resumes from its manifest: `done` rows replay
//! verbatim, everything else re-runs, and cell purity makes the final
//! `summary.csv` byte-identical to an uninterrupted run.

use super::grid::{SweepCell, SweepGrid};
use crate::aggregate::{MultiScale, ScaleConfig, StreamingFacilityAccumulator};
use crate::coordinator::Generator;
use crate::metrics::planning::{PlanningStats, StreamingPlanningStats, StreamingResampler};
use crate::robust::manifest::content_hash;
use crate::robust::{
    failpoint, fsx, run_isolated, CellStatus, Deadline, ExportRecord, Isolated, ManifestKeeper,
    RetryPolicy, RunManifest,
};
use crate::util::json::{self, Json};
use crate::util::threadpool::{default_workers, parallel_map_results};
use anyhow::{ensure, Context, Result};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::time::Instant;

/// Execution knobs for one sweep run.
#[derive(Debug, Clone)]
pub struct SweepOptions {
    /// Server-sample interval the pipeline generates at (paper: 250 ms).
    pub dt_s: f64,
    /// Ramp-measurement interval for the summary stats (paper: 15 min).
    /// Per cell this is clamped to half the scenario horizon (and no less
    /// than `dt_s`) so short grids still measure a ramp instead of
    /// reporting an identically-zero one from a single window.
    pub ramp_interval_s: f64,
    /// Concurrent scenarios; 0 = auto (bounded by cell count and cores).
    pub scenario_workers: usize,
    /// Worker threads inside each scenario; 0 = auto (cores left over
    /// after scenario-level parallelism).
    pub server_workers: usize,
    /// Servers per batched classifier call inside each rack
    /// (0 = [`crate::coordinator::DEFAULT_MAX_BATCH`], 1 = sequential).
    /// Every width produces byte-identical cell output — see
    /// [`Generator::facility_shared_batched`] — so this is purely a
    /// throughput/memory knob.
    pub max_batch: usize,
    /// Generation window in seconds for the streaming path
    /// (0 = buffered one-shot). With a window set, per-cell memory is
    /// O(racks × window) and exports stream to disk as windows complete —
    /// pass the output directory to [`run_sweep_to`] so the writers have
    /// somewhere to stream.
    pub window_s: f64,
    /// Export intervals per aggregation level.
    pub scales: ScaleConfig,
}

impl Default for SweepOptions {
    fn default() -> Self {
        SweepOptions {
            dt_s: 0.25,
            ramp_interval_s: 900.0,
            scenario_workers: 0,
            server_workers: 0,
            max_batch: 0,
            window_s: 0.0,
            scales: ScaleConfig::default(),
        }
    }
}

impl SweepOptions {
    /// The options that determine output *bytes* — the run manifest's hash
    /// binds to exactly these. Worker counts, batch width, and the
    /// streaming window are byte-invariant by contract (see the module
    /// docs) and deliberately excluded, so a resumed run may pick a
    /// different parallel layout or switch streaming on or off.
    pub(crate) fn identity_json(&self) -> Json {
        let scales = json::obj([
            ("rack_interval_s", Json::Num(self.scales.rack_interval_s)),
            ("row_interval_s", Json::Num(self.scales.row_interval_s)),
            ("facility_intervals_s", Json::from_f64s(&self.scales.facility_intervals_s)),
        ]);
        json::obj([
            ("dt_s", Json::Num(self.dt_s)),
            ("ramp_interval_s", Json::Num(self.ramp_interval_s)),
            ("scales", scales),
        ])
    }

    /// What the manifest records as launch options: the identity fields
    /// plus the window size — `--resume` reads its defaults from here.
    pub(crate) fn record_json(&self) -> Json {
        let Json::Obj(mut o) = self.identity_json() else { unreachable!("identity is an object") };
        o.insert("window_s".to_string(), Json::Num(self.window_s));
        Json::Obj(o)
    }
}

/// One executed grid cell.
pub struct CellResult {
    pub cell: SweepCell,
    /// Planning summary of the facility PCC series at the generation dt.
    pub stats: PlanningStats,
    /// Multi-resolution export (racks / rows / facility). `None` for
    /// streamed cells — their series went straight to disk, window by
    /// window, and were never materialized.
    pub scales: Option<MultiScale>,
    /// `false` when `stats.p99_w` / `stats.cv` came from the streaming
    /// histogram (horizon exceeded the exact-sample cap); the error bound
    /// is in [`CellResult::p99_bound_w`].
    pub exact_quantiles: bool,
    /// Absolute error bound on `stats.p99_w` (0 when exact).
    pub p99_bound_w: f64,
    /// Wall-clock seconds this cell took (reporting only; never exported).
    pub wall_s: f64,
}

/// A completed sweep: the grid plus every cell result, in grid order.
pub struct SweepReport {
    pub grid: SweepGrid,
    pub dt_s: f64,
    pub cells: Vec<CellResult>,
}

/// Expand and execute a grid (buffered, or streaming when
/// `opts.window_s > 0` — see [`run_sweep_to`] to stream CSV exports).
pub fn run_sweep(gen: &mut Generator, grid: &SweepGrid, opts: &SweepOptions) -> Result<SweepReport> {
    run_sweep_to(gen, grid, opts, None)
}

/// [`run_sweep`] with a streaming export directory: when
/// `opts.window_s > 0` and `stream_dir` is given, every cell's
/// rack/row/facility CSVs are appended window-by-window under
/// `<stream_dir>/<cell>/` while the cell generates (byte-identical to what
/// the buffered [`SweepReport::write`] would produce). Call
/// [`SweepReport::write`] on the same directory afterwards to add
/// `grid.json`, `summary.csv`, and the per-cell `scenario.json`s.
pub fn run_sweep_to(
    gen: &mut Generator,
    grid: &SweepGrid,
    opts: &SweepOptions,
    stream_dir: Option<&Path>,
) -> Result<SweepReport> {
    grid.validate()?;
    ensure!(
        opts.dt_s.is_finite() && opts.dt_s > 0.0,
        "sweep: dt must be positive seconds (got {})",
        opts.dt_s
    );
    let cells = grid.expand();
    // Shared-artifact hoist: each config some cell actually uses is
    // prepared exactly once, no matter how many cells (or racks) use it.
    let mut needed: Vec<String> = Vec::new();
    for cell in &cells {
        for id in cell.spec.server_config.config_ids_used(&cell.spec.topology) {
            if !needed.contains(&id) {
                needed.push(id);
            }
        }
    }
    for id in needed {
        gen.prepare(&id).with_context(|| format!("preparing config '{id}'"))?;
    }
    let n = cells.len();
    let outer = match opts.scenario_workers {
        0 => default_workers().min(n).max(1),
        w => w.min(n).max(1),
    };
    let inner = match opts.server_workers {
        0 => (default_workers() / outer).max(1),
        w => w,
    };
    if let Some(dir) = stream_dir {
        std::fs::create_dir_all(dir)?;
    }
    let gen_ro: &Generator = gen;
    let results: Vec<Result<CellResult>> = parallel_map_results(n, outer, |i| {
        let cell = &cells[i];
        let t0 = Instant::now();
        let (stats, scales, exact, bound) = if opts.window_s > 0.0 {
            let cdir = stream_dir.map(|d| d.join(&cell.id));
            let (stats, exact, bound, _paths) =
                run_cell_streaming(gen_ro, cell, opts, inner, cdir.as_deref(), None)?;
            (stats, None, exact, bound)
        } else {
            let run =
                gen_ro.facility_shared_batched(&cell.spec, opts.dt_s, inner, opts.max_batch)?;
            let site = run.facility_series();
            let ramp_s = cell_ramp_interval(opts, cell.spec.horizon_s);
            let stats = PlanningStats::compute(&site, opts.dt_s, ramp_s)?;
            let scales = run.acc.multi_scale(opts.dt_s, cell.spec.pue, &opts.scales)?;
            (stats, Some(scales), true, 0.0)
        };
        Ok(CellResult {
            cell: cell.clone(),
            stats,
            scales,
            exact_quantiles: exact,
            p99_bound_w: bound,
            wall_s: t0.elapsed().as_secs_f64(),
        })
    });
    let mut out = Vec::with_capacity(n);
    for (i, r) in results.into_iter().enumerate() {
        out.push(r.with_context(|| format!("cell {}", cells[i].id))?);
    }
    Ok(SweepReport { grid: grid.clone(), dt_s: opts.dt_s, cells: out })
}

/// See [`SweepOptions::ramp_interval_s`]: keep ≥ 2 windows in range (the
/// shared [`clamp_ramp_interval`](crate::metrics::planning::clamp_ramp_interval) policy).
fn cell_ramp_interval(opts: &SweepOptions, horizon_s: f64) -> f64 {
    crate::metrics::planning::clamp_ramp_interval(opts.ramp_interval_s, horizon_s, opts.dt_s)
}

/// Run one cell through the windowed streaming pipeline: fold summary
/// stats per window and (optionally) append the multi-scale CSVs under
/// `cdir`. With a [`Deadline`], the soft wall-clock budget is checked at
/// every window boundary (the streaming path's cooperative yield points).
/// Returns `(stats, exact_quantiles, p99_bound, finished export paths)`.
fn run_cell_streaming(
    gen: &Generator,
    cell: &SweepCell,
    opts: &SweepOptions,
    inner_workers: usize,
    cdir: Option<&Path>,
    deadline: Option<&Deadline>,
) -> Result<(PlanningStats, bool, f64, Vec<PathBuf>)> {
    let spec = &cell.spec;
    let ramp_s = cell_ramp_interval(opts, spec.horizon_s);
    let mut stats = StreamingPlanningStats::new(opts.dt_s, ramp_s)?;
    let mut writers = match cdir {
        Some(d) => Some(CellWriters::create(
            d,
            spec.topology.n_racks(),
            spec.topology.rows,
            spec.pue,
            opts,
        )?),
        None => None,
    };
    let mut rows_buf: Vec<Vec<f64>> = Vec::new();
    let mut site_buf: Vec<f64> = Vec::new();
    let mut site_pcc: Vec<f32> = Vec::new();
    let pue = spec.pue;
    gen.facility_shared_windowed(
        spec,
        opts.dt_s,
        opts.window_s,
        inner_workers,
        opts.max_batch,
        |acc| {
            failpoint::hit("sweep.cell.window", &cell.id)?;
            if let Some(d) = deadline {
                d.check()?;
            }
            acc.fold_rows_site(&mut rows_buf, &mut site_buf);
            // The PCC f32 series exactly as the buffered stats path builds
            // it — the shared helper owns the deliberate double rounding.
            crate::aggregate::pcc_window_into(&site_buf, pue, &mut site_pcc);
            stats.push_slice(&site_pcc);
            if let Some(w) = writers.as_mut() {
                w.push_window(acc, &rows_buf, &site_buf)?;
            }
            Ok(())
        },
    )?;
    let paths = match writers {
        Some(w) => w.finish()?,
        None => Vec::new(),
    };
    let out = stats.finalize()?;
    Ok((out.stats, out.exact_quantiles, out.p99_error_bound_w, paths))
}

// ---------------------------------------------------------------------------
// Checkpointed execution (crash-safe sweeps)
// ---------------------------------------------------------------------------

/// File name of the run manifest inside a checkpointed output directory.
pub const SWEEP_MANIFEST: &str = "manifest.json";

/// A cell that failed every attempt and was quarantined in the manifest
/// (the rest of the sweep still completed).
#[derive(Debug, Clone)]
pub struct QuarantinedCell {
    pub id: String,
    /// Cumulative attempts across every run of the manifest.
    pub attempts: u32,
    /// The last failure: an error chain, a panic payload, or a deadline.
    pub reason: String,
}

/// Result of a checkpointed (possibly resumed) sweep run.
pub struct SweepOutcome {
    /// Cells executed by *this* process, in grid order. Restored cells are
    /// not re-materialized — their rows replay from the manifest into
    /// [`SweepOutcome::summary_csv`].
    pub report: SweepReport,
    /// Cells restored from the manifest without re-running.
    pub restored: usize,
    /// Cells quarantined after exhausting the retry budget, grid order.
    pub failed: Vec<QuarantinedCell>,
    /// The assembled summary (all `done` cells, grid order) — exactly the
    /// bytes written to `<dir>/summary.csv`, and byte-identical to an
    /// uninterrupted [`run_sweep_to`] + [`SweepReport::write`] once every
    /// cell is done.
    pub summary_csv: String,
    /// `<dir>/manifest.json` — pass to `--resume`.
    pub manifest_path: PathBuf,
}

/// Crash-safe variant of [`run_sweep_to`]: execute `grid` under `dir` with
/// a durable [`RunManifest`], per-cell fault isolation, and atomic exports.
///
/// * A fresh directory starts an all-`pending` manifest; a directory that
///   already holds one **resumes** it — `done` cells are skipped and their
///   summary rows replayed verbatim, `pending`/`failed` cells re-run. The
///   manifest's content hash must match this grid + byte-relevant options.
/// * Each cell runs under [`run_isolated`]: panics are caught, failures
///   retried up to `policy.max_retries` times, and a cell that fails every
///   attempt is quarantined (recorded `failed`) without aborting the rest.
/// * All exports land atomically, and the manifest is atomically rewritten
///   after every cell, so a kill at any instant leaves a resumable state.
///
/// Because cells are pure functions of `(spec, seed)`, the final
/// `summary.csv` after any crash/resume sequence is byte-identical to the
/// uninterrupted run's.
pub fn run_sweep_checkpointed(
    gen: &mut Generator,
    grid: &SweepGrid,
    opts: &SweepOptions,
    dir: &Path,
    policy: &RetryPolicy,
) -> Result<SweepOutcome> {
    grid.validate()?;
    ensure!(
        opts.dt_s.is_finite() && opts.dt_s > 0.0,
        "sweep: dt must be positive seconds (got {})",
        opts.dt_s
    );
    let cells = grid.expand();
    let ids: Vec<String> = cells.iter().map(|c| c.id.clone()).collect();
    let hash = content_hash("sweep", &grid.to_json(), &opts.identity_json());
    std::fs::create_dir_all(dir)?;
    let mpath = dir.join(SWEEP_MANIFEST);
    let mut manifest = if mpath.exists() {
        let m = RunManifest::load(&mpath)?;
        m.ensure_matches("sweep", &hash, &ids)?;
        m
    } else {
        RunManifest::new("sweep", &grid.name, hash, grid.to_json(), opts.record_json(), &ids)
    };
    manifest.reconcile_exports(dir);
    manifest.header = Some(summary_header().to_string());
    let restored = manifest.done_count();
    let todo: Vec<usize> = (0..cells.len()).filter(|&i| !manifest.is_done(&cells[i].id)).collect();
    // Shared-artifact hoist, restricted to configs a re-run cell needs.
    let mut needed: Vec<String> = Vec::new();
    for &i in &todo {
        for id in cells[i].spec.server_config.config_ids_used(&cells[i].spec.topology) {
            if !needed.contains(&id) {
                needed.push(id);
            }
        }
    }
    for id in needed {
        gen.prepare(&id).with_context(|| format!("preparing config '{id}'"))?;
    }
    let keeper = ManifestKeeper::new(manifest, mpath.clone())?;
    let n = todo.len();
    let outer = match opts.scenario_workers {
        0 => default_workers().min(n).max(1),
        w => w.min(n).max(1),
    };
    let inner = match opts.server_workers {
        0 => (default_workers() / outer).max(1),
        w => w,
    };
    let gen_ro: &Generator = gen;
    let results = parallel_map_results(n, outer, |k| -> Result<Option<CellResult>> {
        let cell = &cells[todo[k]];
        let prior = keeper.with(|m| m.attempts(&cell.id));
        match run_isolated(policy, prior, |deadline| {
            failpoint::hit("sweep.cell", &cell.id)?;
            run_cell_checkpointed(gen_ro, cell, opts, inner, dir, deadline)
        }) {
            Isolated::Done { value: (result, exports), attempts } => {
                let row = summary_row(&result);
                keeper.update(|m| m.mark_done(&cell.id, attempts, row, exports))?;
                Ok(Some(result))
            }
            Isolated::Failed { attempts, reason } => {
                keeper.update(|m| m.mark_failed(&cell.id, attempts, reason))?;
                Ok(None)
            }
        }
    });
    // Only manifest-save failures (or pool bugs) surface here — cell
    // failures were quarantined above.
    let mut executed = Vec::new();
    for (k, r) in results.into_iter().enumerate() {
        if let Some(res) = r.with_context(|| format!("cell {}", cells[todo[k]].id))? {
            executed.push(res);
        }
    }
    let manifest = keeper.into_inner();
    let mut summary = String::from(summary_header());
    for c in &cells {
        if let Some(row) = manifest.row(&c.id) {
            summary.push_str(row);
        }
    }
    grid.save(&dir.join("grid.json"))?;
    fsx::atomic_write(&dir.join("summary.csv"), summary.as_bytes())?;
    let failed: Vec<QuarantinedCell> = cells
        .iter()
        .filter_map(|c| {
            let st = manifest.cells.get(&c.id)?;
            (st.status == CellStatus::Failed).then(|| QuarantinedCell {
                id: c.id.clone(),
                attempts: st.attempts,
                reason: st.reason.clone().unwrap_or_default(),
            })
        })
        .collect();
    Ok(SweepOutcome {
        report: SweepReport { grid: grid.clone(), dt_s: opts.dt_s, cells: executed },
        restored,
        failed,
        summary_csv: summary,
        manifest_path: mpath,
    })
}

/// One cell of a checkpointed run: generate (streaming or buffered), write
/// every export atomically under `<root>/<cell>/`, and return the result
/// plus the [`ExportRecord`]s the manifest needs for resume validation.
fn run_cell_checkpointed(
    gen: &Generator,
    cell: &SweepCell,
    opts: &SweepOptions,
    inner_workers: usize,
    root: &Path,
    deadline: &Deadline,
) -> Result<(CellResult, Vec<ExportRecord>)> {
    let t0 = Instant::now();
    let cdir = root.join(&cell.id);
    let (stats, scales, exact, bound, mut paths) = if opts.window_s > 0.0 {
        let (stats, exact, bound, paths) =
            run_cell_streaming(gen, cell, opts, inner_workers, Some(&cdir), Some(deadline))?;
        (stats, None, exact, bound, paths)
    } else {
        let run =
            gen.facility_shared_batched(&cell.spec, opts.dt_s, inner_workers, opts.max_batch)?;
        let site = run.facility_series();
        let ramp_s = cell_ramp_interval(opts, cell.spec.horizon_s);
        let stats = PlanningStats::compute(&site, opts.dt_s, ramp_s)?;
        let scales = run.acc.multi_scale(opts.dt_s, cell.spec.pue, &opts.scales)?;
        (stats, Some(scales), true, 0.0, Vec::new())
    };
    let result = CellResult {
        cell: cell.clone(),
        stats,
        scales,
        exact_quantiles: exact,
        p99_bound_w: bound,
        wall_s: t0.elapsed().as_secs_f64(),
    };
    paths.extend(write_cell_exports(&cdir, &result)?);
    let mut exports = Vec::with_capacity(paths.len());
    for p in paths {
        let bytes = std::fs::metadata(&p)
            .with_context(|| format!("stat export {}", p.display()))?
            .len();
        let rel = p.strip_prefix(root).unwrap_or(&p).to_string_lossy().replace('\\', "/");
        exports.push(ExportRecord { path: rel, bytes });
    }
    Ok((result, exports))
}

/// The static sweep summary header line — shared by [`SweepReport`] and
/// the checkpointed runner (which replays manifest rows under it).
pub(crate) fn summary_header() -> &'static str {
    "cell,workload,topology,fleet,servers,seed,\
     peak_w,avg_w,p99_w,energy_kwh,max_ramp_w,cv,peak_to_average,load_factor\n"
}

/// One cell's summary row (with trailing newline) — the exact bytes
/// [`SweepReport::summary_csv`] emits, also recorded verbatim into the run
/// manifest so a resumed run replays rather than recomputes them.
pub(crate) fn summary_row(c: &CellResult) -> String {
    let t = c.cell.spec.topology;
    let fleet = c.cell.spec.server_config.config_ids().join("+");
    format!(
        "{},{},{}x{}x{},{},{},{},{},{},{},{},{},{},{},{}\n",
        c.cell.id,
        csv_field(&c.cell.spec.workload.label()),
        t.rows,
        t.racks_per_row,
        t.servers_per_rack,
        csv_field(&fleet),
        t.n_servers(),
        c.cell.spec.seed,
        c.stats.peak_w,
        c.stats.avg_w,
        c.stats.p99_w,
        c.stats.energy_kwh,
        c.stats.max_ramp_w,
        c.stats.cv,
        c.stats.peak_to_average,
        c.stats.load_factor,
    )
}

impl SweepReport {
    /// The planning summary as CSV. Deterministic per (grid, seeds): values
    /// are emitted with Rust's shortest round-trip float formatting and no
    /// timing columns.
    pub fn summary_csv(&self) -> String {
        let mut s = String::from(summary_header());
        for c in &self.cells {
            s.push_str(&summary_row(c));
        }
        s
    }

    /// Human-readable summary table (kW units, wall-clock included).
    pub fn summary_table(&self) -> String {
        let mut s = format!(
            "{:<14} {:<44} {:>6} {:>9} {:>9} {:>9} {:>9} {:>9} {:>7} {:>6} {:>7}\n",
            "cell", "scenario", "srv", "peak kW", "avg kW", "p99 kW", "MWh", "ramp kW", "CV", "PAR",
            "wall s"
        );
        for c in &self.cells {
            s.push_str(&format!(
                "{:<14} {:<44} {:>6} {:>9.1} {:>9.1} {:>8.1}{} {:>9.2} {:>9.1} {:>7.3} {:>6.2} {:>7.1}\n",
                c.cell.id,
                truncate(&c.cell.label, 44),
                c.cell.spec.topology.n_servers(),
                c.stats.peak_w / 1e3,
                c.stats.avg_w / 1e3,
                c.stats.p99_w / 1e3,
                if c.exact_quantiles { " " } else { "~" },
                c.stats.energy_kwh / 1e3,
                c.stats.max_ramp_w / 1e3,
                c.stats.cv,
                c.stats.peak_to_average,
                c.wall_s,
            ));
        }
        s
    }

    /// Write the full report under `dir`:
    ///
    /// ```text
    /// <dir>/grid.json                      the grid (reproduction recipe)
    /// <dir>/summary.csv                    one PlanningStats row per cell
    /// <dir>/<cell>/scenario.json           the expanded ScenarioSpec
    /// <dir>/<cell>/racks_<interval>s.csv   per-rack IT power
    /// <dir>/<cell>/rows_<interval>s.csv    per-row IT power
    /// <dir>/<cell>/facility_<interval>s.csv  PCC power per facility scale
    /// ```
    ///
    /// Cells executed in streaming mode carry no in-memory series
    /// (`scales: None`); their series CSVs were already appended
    /// incrementally by [`run_sweep_to`] into the same layout, so this
    /// writes only the metadata files for them.
    pub fn write(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        self.grid.save(&dir.join("grid.json"))?;
        fsx::atomic_write(&dir.join("summary.csv"), self.summary_csv().as_bytes())?;
        for c in &self.cells {
            write_cell_exports(&dir.join(&c.cell.id), c)?;
        }
        Ok(())
    }
}

/// Write one cell's metadata + buffered series exports under `cdir` and
/// return every path written (streamed series CSVs are not re-written —
/// they were already finalized by [`CellWriters::finish`]). Every file
/// lands atomically.
fn write_cell_exports(cdir: &Path, c: &CellResult) -> Result<Vec<PathBuf>> {
    std::fs::create_dir_all(cdir)?;
    let mut paths = Vec::new();
    let spec_path = cdir.join("scenario.json");
    c.cell.spec.save(&spec_path)?;
    paths.push(spec_path);
    let Some(scales) = &c.scales else { return Ok(paths) };
    let sc = &scales.scales;
    let p = cdir.join(format!("racks_{}s.csv", fmt_secs(sc.rack_interval_s)));
    write_series_csv(&p, "rack", sc.rack_interval_s, &scales.racks_w)?;
    paths.push(p);
    let p = cdir.join(format!("rows_{}s.csv", fmt_secs(sc.row_interval_s)));
    write_series_csv(&p, "row", sc.row_interval_s, &scales.rows_w)?;
    paths.push(p);
    for (k, &interval) in sc.facility_intervals_s.iter().enumerate() {
        let p = cdir.join(format!("facility_{}s.csv", fmt_secs(interval)));
        write_series_csv(&p, "facility", interval, std::slice::from_ref(&scales.facility_w[k]))?;
        paths.push(p);
    }
    Ok(paths)
}

// ---------------------------------------------------------------------------
// Incremental CSV writers (streaming mode)
// ---------------------------------------------------------------------------

/// One cell's set of incremental multi-scale CSV writers.
struct CellWriters {
    racks: StreamingCsv,
    rows: StreamingCsv,
    facility: Vec<StreamingCsv>,
}

impl CellWriters {
    fn create(
        cdir: &Path,
        n_racks: usize,
        n_rows: usize,
        pue: f64,
        opts: &SweepOptions,
    ) -> Result<CellWriters> {
        std::fs::create_dir_all(cdir)?;
        let sc = &opts.scales;
        let racks = StreamingCsv::create(
            &cdir.join(format!("racks_{}s.csv", fmt_secs(sc.rack_interval_s))),
            "rack",
            n_racks,
            opts.dt_s,
            sc.rack_interval_s,
            1.0,
        )?;
        let rows = StreamingCsv::create(
            &cdir.join(format!("rows_{}s.csv", fmt_secs(sc.row_interval_s))),
            "row",
            n_rows,
            opts.dt_s,
            sc.row_interval_s,
            1.0,
        )?;
        let facility = sc
            .facility_intervals_s
            .iter()
            .map(|&interval| {
                // PUE rides on the resampler's scale factor, exactly as the
                // buffered `resample_mean_f64(&site, dt, interval, pue)`.
                StreamingCsv::create(
                    &cdir.join(format!("facility_{}s.csv", fmt_secs(interval))),
                    "facility",
                    1,
                    opts.dt_s,
                    interval,
                    pue,
                )
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(CellWriters { racks, rows, facility })
    }

    /// Append one generation window across every writer. `rows_w`/`site_w`
    /// are the per-row and site IT windows from
    /// [`StreamingFacilityAccumulator::fold_rows_site`].
    fn push_window(
        &mut self,
        acc: &mut StreamingFacilityAccumulator,
        rows_w: &[Vec<f64>],
        site_w: &[f64],
    ) -> Result<()> {
        for r in 0..acc.topology().n_racks() {
            self.racks.push_col(r, acc.rack_window(r));
        }
        self.racks.write_ready_rows()?;
        for (r, row) in rows_w.iter().enumerate() {
            self.rows.push_col(r, row);
        }
        self.rows.write_ready_rows()?;
        for f in self.facility.iter_mut() {
            f.push_col(0, site_w);
            f.write_ready_rows()?;
        }
        Ok(())
    }

    /// Finalize every writer (flush + atomic rename) and return the
    /// finished file paths.
    fn finish(self) -> Result<Vec<PathBuf>> {
        let mut paths = Vec::with_capacity(2 + self.facility.len());
        paths.push(self.racks.finish()?);
        paths.push(self.rows.finish()?);
        for f in self.facility {
            paths.push(f.finish()?);
        }
        Ok(paths)
    }
}

/// Incremental columnar series CSV (`t_s,<stem>_0,...`): each column owns a
/// [`StreamingResampler`], rows are appended as soon as every column has
/// emitted a value. Byte-identical to [`write_series_csv`] on the buffered
/// [`MultiScale`] series because the resampler reproduces
/// `resample_mean_f64` exactly and both share [`fmt_secs`] + Rust's
/// shortest round-trip f32 formatting. Crate-visible: the site composition
/// engine ([`crate::site`]) streams `site_load.csv` through the same
/// writer so facility and site exports can never drift in format.
///
/// Rows stream to `<name>.tmp`; only [`StreamingCsv::finish`] renames the
/// file into its final place, so a crash mid-cell never leaves a
/// plausible-looking partial series at the real path.
pub(crate) struct StreamingCsv {
    out: std::io::BufWriter<std::fs::File>,
    /// The staging path rows stream to.
    tmp: PathBuf,
    /// The final path [`StreamingCsv::finish`] renames to.
    path: PathBuf,
    /// File name — the `export.write` failpoint tag.
    tag: String,
    interval_s: f64,
    next_row: usize,
    cols: Vec<StreamingResampler>,
    pending: Vec<std::collections::VecDeque<f32>>,
    line: String,
}

impl StreamingCsv {
    pub(crate) fn create(
        path: &Path,
        stem: &str,
        n_cols: usize,
        dt_s: f64,
        interval_s: f64,
        scale: f64,
    ) -> Result<StreamingCsv> {
        let names: Vec<String> = (0..n_cols).map(|i| format!("{stem}_{i}")).collect();
        Self::create_named(path, &names, dt_s, interval_s, scale)
    }

    /// [`StreamingCsv::create`] with explicit column names (the site
    /// export's `site_w,<facility>_w` header).
    pub(crate) fn create_named(
        path: &Path,
        col_names: &[String],
        dt_s: f64,
        interval_s: f64,
        scale: f64,
    ) -> Result<StreamingCsv> {
        let tmp = fsx::tmp_path(path);
        let file =
            std::fs::File::create(&tmp).with_context(|| format!("creating {}", tmp.display()))?;
        let mut out = std::io::BufWriter::new(file);
        let mut header = String::from("t_s");
        for name in col_names {
            header.push(',');
            header.push_str(&csv_field(name));
        }
        header.push('\n');
        out.write_all(header.as_bytes())?;
        let cols = col_names
            .iter()
            .map(|_| StreamingResampler::new(dt_s, interval_s, scale))
            .collect::<Result<Vec<_>>>()?;
        let tag = path.file_name().map(|n| n.to_string_lossy().into_owned()).unwrap_or_default();
        Ok(StreamingCsv {
            out,
            tmp,
            path: path.to_path_buf(),
            tag,
            interval_s,
            next_row: 0,
            cols,
            pending: (0..col_names.len()).map(|_| std::collections::VecDeque::new()).collect(),
            line: String::new(),
        })
    }

    pub(crate) fn push_col(&mut self, col: usize, xs: &[f64]) {
        let (r, q) = (&mut self.cols[col], &mut self.pending[col]);
        for &x in xs {
            if let Some(v) = r.push(x) {
                q.push_back(v);
            }
        }
    }

    /// [`StreamingCsv::push_col`] over an f32 window (each sample widened
    /// to f64 before the resampler fold — the same expression the f64 path
    /// would see for values that started life as f32).
    pub(crate) fn push_col_f32(&mut self, col: usize, xs: &[f32]) {
        let (r, q) = (&mut self.cols[col], &mut self.pending[col]);
        for &x in xs {
            if let Some(v) = r.push(x as f64) {
                q.push_back(v);
            }
        }
    }

    pub(crate) fn write_ready_rows(&mut self) -> Result<()> {
        failpoint::hit("export.write", &self.tag)?;
        let ready = self.pending.iter().map(|q| q.len()).min().unwrap_or(0);
        for _ in 0..ready {
            self.line.clear();
            self.line.push_str(&fmt_secs(self.next_row as f64 * self.interval_s));
            for q in self.pending.iter_mut() {
                let v = q.pop_front().expect("ready rows counted");
                self.line.push(',');
                self.line.push_str(&format!("{v}"));
            }
            self.line.push('\n');
            self.out.write_all(self.line.as_bytes())?;
            self.next_row += 1;
        }
        Ok(())
    }

    /// Flush the trailing partial resample window of every column (the
    /// buffered `resample_mean` emits it averaged over its actual length),
    /// write the final row(s), and atomically rename the staged file into
    /// its final place. Returns the finished path.
    pub(crate) fn finish(mut self) -> Result<PathBuf> {
        for (r, q) in self.cols.iter_mut().zip(self.pending.iter_mut()) {
            if let Some((v, _count)) = r.flush() {
                q.push_back(v);
            }
        }
        self.write_ready_rows()?;
        debug_assert!(self.pending.iter().all(|q| q.is_empty()), "ragged columns");
        let file = self
            .out
            .into_inner()
            .map_err(|e| anyhow::anyhow!("flushing {}: {e}", self.tmp.display()))?;
        // Make the rename durable, not just atomic: the bytes reach disk
        // before the final name does.
        let _ = file.sync_all();
        drop(file);
        fsx::persist(&self.tmp, &self.path)?;
        Ok(self.path)
    }
}

/// RFC-4180 quoting for free-text CSV fields (a replay workload's path
/// may contain commas or quotes).
pub(crate) fn csv_field(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

/// `300` for whole seconds, `0.25` otherwise (file-name friendly).
pub(crate) fn fmt_secs(x: f64) -> String {
    if x.fract() == 0.0 {
        format!("{}", x as i64)
    } else {
        format!("{x}")
    }
}

fn truncate(s: &str, max: usize) -> String {
    if s.chars().count() <= max {
        s.to_string()
    } else {
        let cut: String = s.chars().take(max.saturating_sub(1)).collect();
        format!("{cut}…")
    }
}

/// `t_s,<stem>_0,<stem>_1,...` — shared by the buffered and streaming
/// writers so their headers can never drift apart.
fn series_csv_header(stem: &str, n_cols: usize) -> String {
    let mut out = String::from("t_s");
    for i in 0..n_cols {
        out.push_str(&format!(",{stem}_{i}"));
    }
    out.push('\n');
    out
}

/// Columnar CSV: `t_s,<stem>_0,<stem>_1,...` with one row per interval,
/// written atomically (staged + renamed).
fn write_series_csv(path: &Path, stem: &str, interval_s: f64, series: &[Vec<f32>]) -> Result<()> {
    let n = series.iter().map(|s| s.len()).max().unwrap_or(0);
    let mut out = series_csv_header(stem, series.len());
    for t in 0..n {
        out.push_str(&fmt_secs(t as f64 * interval_s));
        for s in series {
            out.push(',');
            if t < s.len() {
                out.push_str(&format!("{}", s[t]));
            }
        }
        out.push('\n');
    }
    fsx::atomic_write(path, out.as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_field_quotes_only_when_needed() {
        assert_eq!(csv_field("poisson λ=0.5"), "poisson λ=0.5");
        assert_eq!(csv_field("replay a,b.json"), "\"replay a,b.json\"");
        assert_eq!(csv_field("say \"hi\""), "\"say \"\"hi\"\"\"");
    }

    #[test]
    fn fmt_secs_is_filename_friendly() {
        assert_eq!(fmt_secs(300.0), "300");
        assert_eq!(fmt_secs(1.0), "1");
        assert_eq!(fmt_secs(0.25), "0.25");
    }

    #[test]
    fn truncate_respects_char_boundaries() {
        assert_eq!(truncate("short", 10), "short");
        let t = truncate("λ̄-burstiness-very-long-label", 10);
        assert!(t.chars().count() <= 10);
    }

    #[test]
    fn series_csv_shape() {
        let dir = std::env::temp_dir().join("powertrace_test_runner");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("racks.csv");
        write_series_csv(&p, "rack", 15.0, &[vec![1.0, 2.0], vec![3.0, 4.0]]).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[0], "t_s,rack_0,rack_1");
        assert_eq!(lines[1], "0,1,2");
        assert_eq!(lines[2], "15,3,4");
        assert_eq!(lines.len(), 3);
    }

    #[test]
    fn streaming_csv_matches_buffered_writer_bytes() {
        // Two columns of f64 data pushed in ragged windows must produce the
        // byte-identical file to resampling whole series and using
        // write_series_csv — including the partial trailing window.
        let dir = std::env::temp_dir().join("powertrace_test_streaming_csv");
        std::fs::create_dir_all(&dir).unwrap();
        let (dt, interval) = (0.25, 1.5); // stride 6
        let n = 100; // 100 = 16×6 + 4 → partial tail
        let cols: Vec<Vec<f64>> = (0..2)
            .map(|c| (0..n).map(|i| 1000.0 + (c * 37 + i) as f64 * 0.83).collect())
            .collect();
        // Buffered reference.
        let buffered: Vec<Vec<f32>> = cols
            .iter()
            .map(|col| {
                col.chunks(6)
                    .map(|ch| (ch.iter().sum::<f64>() / ch.len() as f64) as f32)
                    .collect()
            })
            .collect();
        let pb = dir.join("buffered.csv");
        write_series_csv(&pb, "rack", interval, &buffered).unwrap();
        // Streaming writer fed in windows of 7.
        let ps = dir.join("streamed.csv");
        let mut w = StreamingCsv::create(&ps, "rack", 2, dt, interval, 1.0).unwrap();
        let mut t0 = 0;
        while t0 < n {
            let wlen = 7.min(n - t0);
            for (c, col) in cols.iter().enumerate() {
                w.push_col(c, &col[t0..t0 + wlen]);
            }
            w.write_ready_rows().unwrap();
            t0 += wlen;
        }
        let finished = w.finish().unwrap();
        assert_eq!(finished, ps);
        let a = std::fs::read(&pb).unwrap();
        let b = std::fs::read(&ps).unwrap();
        assert_eq!(a, b, "streamed CSV bytes differ from buffered");
    }

    #[test]
    fn streaming_csv_is_atomic_until_finish() {
        let dir = std::env::temp_dir().join("powertrace_test_streaming_atomic");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("atomic.csv");
        let _ = std::fs::remove_file(&p);
        let mut w = StreamingCsv::create(&p, "rack", 1, 0.25, 0.5, 1.0).unwrap();
        w.push_col(0, &[1.0, 2.0, 3.0, 4.0]);
        w.write_ready_rows().unwrap();
        // Rows exist only in the staging file until finish renames it.
        assert!(!p.exists(), "final path must not appear before finish");
        assert!(crate::robust::fsx::tmp_path(&p).exists());
        w.finish().unwrap();
        assert!(p.exists());
        assert!(!crate::robust::fsx::tmp_path(&p).exists());
        let s = std::fs::read_to_string(&p).unwrap();
        assert_eq!(s, "t_s,rack_0\n0,1.5\n0.5,3.5\n");
    }
}
