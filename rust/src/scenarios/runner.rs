//! Sweep execution: run every grid cell through the facility pipeline in
//! parallel over one shared [`Generator`], then summarize and export.
//!
//! Artifact sharing: [`run_sweep`] first [`Generator::prepare`]s each
//! configuration some cell actually uses (artifact JSON parse + classifier
//! construction + packed-weight build happen exactly once per config, not
//! per cell), then fans cells across the [`Executor`]
//! with [`Generator::facility_shared_batched`] — which itself parallelizes
//! across racks inside a cell and scans each rack's same-config servers
//! through the classifier as one batched call (§Perf). Outer/inner worker
//! counts are balanced automatically unless pinned in [`SweepOptions`];
//! a sequential executor (the core-build default) runs every cell on the
//! caller thread with byte-identical output.
//!
//! Streaming (>24 h) mode: with [`SweepOptions::window_s`] set, each cell
//! runs through [`Generator::facility_shared_windowed`] instead — horizon
//! length no longer bounds memory. Per window, incremental RFC-4180 CSV
//! writers ([`StreamingCsv`]) append the rack/row/facility rows that the
//! buffered [`SweepReport::write`] would have produced (byte-identical
//! where both paths can run: the writers share the exact resample-chunk
//! geometry and float formatting), and a
//! [`StreamingPlanningStats`] folds the summary — exact
//! peak/mean/energy/ramp, p99 exact up to
//! [`crate::metrics::planning::EXACT_QUANTILE_CAP`] samples and
//! histogram-bounded beyond it.
//!
//! Exports route through the [`TraceSink`] seam of the core/host split:
//! [`run_sweep_sink`] / [`SweepReport::write_sink`] work against any sink
//! (the in-memory [`crate::export::MemSink`] in embeddings and tests);
//! the path-taking wrappers ([`run_sweep_to`], [`SweepReport::write`])
//! bind them to a [`DirSink`] and are host-only.
//!
//! Determinism: every cell's output is a pure function of its
//! `(ScenarioSpec, seed)` (see [`Generator::facility_shared`]), and the
//! summary CSV deliberately contains no wall-clock fields, so re-running a
//! grid with the same seeds reproduces byte-identical summaries.
//!
//! Entry points: the unified [`crate::api`] layer (`RunRequest` →
//! [`crate::api::execute`]) is the public surface — the CLI, the serve
//! layer, and embedders all route through it. The historical `run_sweep*`
//! functions remain as thin deprecated wrappers over the same
//! `pub(crate)` internals ([`prepare_sweep`] + [`sweep_prepared_sink`] /
//! [`sweep_checkpointed_prepared`]), which take a shared `&Generator` so
//! one warm prepared-config cache can serve concurrent runs.
//!
//! Crash safety: [`run_sweep_checkpointed`] wraps the same execution in the
//! [`crate::robust`] layer — a durable [`RunManifest`] under the output
//! directory, per-cell `catch_unwind` + retry isolation
//! ([`RetryPolicy`]), and atomic exports. A run killed at any point (or
//! with cells quarantined) resumes from its manifest: `done` rows replay
//! verbatim, everything else re-runs, and cell purity makes the final
//! `summary.csv` byte-identical to an uninterrupted run.

use super::grid::{SweepCell, SweepGrid};
use crate::aggregate::{MultiScale, ScaleConfig, StreamingFacilityAccumulator};
use crate::coordinator::Generator;
#[cfg(feature = "host")]
use crate::export::DirSink;
use crate::export::{csv_field, fmt_secs, write_series_csv, StreamingCsv, TraceSink};
use crate::metrics::planning::{PlanningStats, StreamingPlanningStats};
#[cfg(feature = "host")]
use crate::robust::manifest::content_hash;
use crate::robust::{failpoint, Deadline};
#[cfg(feature = "host")]
use crate::robust::{
    fsx, run_isolated, CellStatus, ExportRecord, Isolated, ManifestKeeper, RetryPolicy,
    RunManifest,
};
use crate::util::json::{self, Json};
use crate::util::threadpool::{default_workers, Executor};
use anyhow::{ensure, Context, Result};
#[cfg(feature = "host")]
use std::path::{Path, PathBuf};

/// Execution knobs for one sweep run.
#[derive(Debug, Clone)]
pub struct SweepOptions {
    /// Server-sample interval the pipeline generates at (paper: 250 ms).
    pub dt_s: f64,
    /// Ramp-measurement interval for the summary stats (paper: 15 min).
    /// Per cell this is clamped to half the scenario horizon (and no less
    /// than `dt_s`) so short grids still measure a ramp instead of
    /// reporting an identically-zero one from a single window.
    pub ramp_interval_s: f64,
    /// Concurrent scenarios; 0 = auto (bounded by cell count and cores).
    pub scenario_workers: usize,
    /// Worker threads inside each scenario; 0 = auto (cores left over
    /// after scenario-level parallelism).
    pub server_workers: usize,
    /// Servers per batched classifier call inside each rack
    /// (0 = [`crate::coordinator::DEFAULT_MAX_BATCH`], 1 = sequential).
    /// Every width produces byte-identical cell output — see
    /// [`Generator::facility_shared_batched`] — so this is purely a
    /// throughput/memory knob.
    pub max_batch: usize,
    /// Generation window in seconds for the streaming path
    /// (0 = buffered one-shot). With a window set, per-cell memory is
    /// O(racks × window) and exports stream through the sink as windows
    /// complete — pass the output directory to [`run_sweep_to`] (or a
    /// sink to [`run_sweep_sink`]) so the writers have somewhere to go.
    pub window_s: f64,
    /// Export intervals per aggregation level.
    pub scales: ScaleConfig,
    /// How cell fan-out (and each cell's inner fan-out) runs: threaded
    /// (host default) or sequential on the caller thread (the core-build
    /// default). Byte-invariant like the worker counts.
    pub executor: Executor,
    /// Run only the cells this shard owns (`None` = the whole grid). A
    /// deterministic partition by stable cell id — see [`crate::shard`] —
    /// so N processes given shards `0/N .. N-1/N` cover a grid exactly
    /// once, and `powertrace merge` reassembles their partial summaries.
    /// Like the worker knobs this is an execution-layout choice: recorded
    /// in the manifest (so `--resume` re-runs the same slice) but excluded
    /// from the identity hash (so every shard shares one content hash).
    pub shard: Option<crate::shard::Shard>,
}

impl Default for SweepOptions {
    fn default() -> Self {
        SweepOptions {
            dt_s: 0.25,
            ramp_interval_s: 900.0,
            scenario_workers: 0,
            server_workers: 0,
            max_batch: 0,
            window_s: 0.0,
            scales: ScaleConfig::default(),
            executor: Executor::default(),
            shard: None,
        }
    }
}

impl SweepOptions {
    /// The options that determine output *bytes* — the run manifest's hash
    /// binds to exactly these. Worker counts, batch width, the executor,
    /// and the streaming window are byte-invariant by contract (see the
    /// module docs) and deliberately excluded, so a resumed run may pick a
    /// different parallel layout or switch streaming on or off.
    pub(crate) fn identity_json(&self) -> Json {
        let scales = json::obj([
            ("rack_interval_s", Json::Num(self.scales.rack_interval_s)),
            ("row_interval_s", Json::Num(self.scales.row_interval_s)),
            ("facility_intervals_s", Json::from_f64s(&self.scales.facility_intervals_s)),
        ]);
        json::obj([
            ("dt_s", Json::Num(self.dt_s)),
            ("ramp_interval_s", Json::Num(self.ramp_interval_s)),
            ("scales", scales),
        ])
    }

    /// What the manifest records as launch options: the identity fields
    /// plus the window size and shard — `--resume` reads its defaults from
    /// here (an explicit `--shard` flag overrides the recorded one).
    pub(crate) fn record_json(&self) -> Json {
        let Json::Obj(mut o) = self.identity_json() else { unreachable!("identity is an object") };
        o.insert("window_s".to_string(), Json::Num(self.window_s));
        if let Some(sh) = self.shard {
            o.insert("shard".to_string(), Json::Str(sh.to_string()));
        }
        Json::Obj(o)
    }

    /// Does this run own `id`? `None` (no shard) owns everything.
    pub(crate) fn owns_cell(&self, id: &str) -> bool {
        self.shard.map_or(true, |s| s.owns(id))
    }
}

/// Wall-clock timer for the reporting-only `wall_s` column. Core builds
/// have no monotonic clock (`Instant::now` aborts on wasm), so they
/// report 0 — `wall_s` is never exported, so nothing byte-visible moves.
struct WallTimer {
    #[cfg(feature = "host")]
    t0: std::time::Instant,
}

impl WallTimer {
    fn start() -> WallTimer {
        WallTimer {
            #[cfg(feature = "host")]
            t0: std::time::Instant::now(),
        }
    }

    fn elapsed_s(&self) -> f64 {
        #[cfg(feature = "host")]
        {
            self.t0.elapsed().as_secs_f64()
        }
        #[cfg(not(feature = "host"))]
        {
            0.0
        }
    }
}

/// One executed grid cell.
pub struct CellResult {
    pub cell: SweepCell,
    /// Planning summary of the facility PCC series at the generation dt.
    pub stats: PlanningStats,
    /// Multi-resolution export (racks / rows / facility). `None` for
    /// streamed cells — their series went straight through the sink,
    /// window by window, and were never materialized.
    pub scales: Option<MultiScale>,
    /// `false` when `stats.p99_w` / `stats.cv` came from the streaming
    /// histogram (horizon exceeded the exact-sample cap); the error bound
    /// is in [`CellResult::p99_bound_w`].
    pub exact_quantiles: bool,
    /// Absolute error bound on `stats.p99_w` (0 when exact).
    pub p99_bound_w: f64,
    /// Wall-clock seconds this cell took (reporting only; never exported;
    /// 0 in core builds — see [`WallTimer`]).
    pub wall_s: f64,
}

/// A completed sweep: the grid plus every cell result, in grid order.
pub struct SweepReport {
    pub grid: SweepGrid,
    pub dt_s: f64,
    pub cells: Vec<CellResult>,
}

/// Expand and execute a grid (buffered, or streaming when
/// `opts.window_s > 0` — see [`run_sweep_to`] to stream CSV exports).
#[deprecated(
    since = "0.2.0",
    note = "route through crate::api::execute with RunSpec::Sweep (one RunRequest envelope \
            for every run kind)"
)]
pub fn run_sweep(gen: &mut Generator, grid: &SweepGrid, opts: &SweepOptions) -> Result<SweepReport> {
    prepare_sweep(gen, grid)?;
    sweep_prepared_sink(gen, grid, opts, None)
}

/// [`run_sweep`] with a streaming export directory: when
/// `opts.window_s > 0` and `stream_dir` is given, every cell's
/// rack/row/facility CSVs are appended window-by-window under
/// `<stream_dir>/<cell>/` while the cell generates (byte-identical to what
/// the buffered [`SweepReport::write`] would produce). Call
/// [`SweepReport::write`] on the same directory afterwards to add
/// `grid.json`, `summary.csv`, and the per-cell `scenario.json`s.
#[cfg(feature = "host")]
#[deprecated(
    since = "0.2.0",
    note = "route through crate::api::execute with RunSpec::Sweep and a DirSink"
)]
pub fn run_sweep_to(
    gen: &mut Generator,
    grid: &SweepGrid,
    opts: &SweepOptions,
    stream_dir: Option<&Path>,
) -> Result<SweepReport> {
    if let Some(dir) = stream_dir {
        std::fs::create_dir_all(dir)?;
    }
    let sink = stream_dir.map(DirSink::new);
    prepare_sweep(gen, grid)?;
    sweep_prepared_sink(gen, grid, opts, sink.as_ref().map(|s| s as &dyn TraceSink))
}

/// [`run_sweep_to`] with streamed exports routed through an arbitrary
/// [`TraceSink`] (each cell under `<cell>/` at the sink root) — the
/// embedding entry point, available without the `host` feature.
#[deprecated(
    since = "0.2.0",
    note = "route through crate::api::execute with RunSpec::Sweep and a TraceSink"
)]
pub fn run_sweep_sink(
    gen: &mut Generator,
    grid: &SweepGrid,
    opts: &SweepOptions,
    stream_sink: Option<&dyn TraceSink>,
) -> Result<SweepReport> {
    prepare_sweep(gen, grid)?;
    sweep_prepared_sink(gen, grid, opts, stream_sink)
}

/// The configuration ids a grid's expanded cells actually use, in
/// first-use order (a `PerRack` fleet longer than its rack count never
/// reaches its tail).
pub(crate) fn grid_config_ids_used(grid: &SweepGrid) -> Vec<String> {
    let mut needed: Vec<String> = Vec::new();
    for cell in grid.expand() {
        for id in cell.spec.server_config.config_ids_used(&cell.spec.topology) {
            if !needed.contains(&id) {
                needed.push(id);
            }
        }
    }
    needed
}

/// Validate `grid` and prepare every configuration some cell actually
/// uses — the shared-artifact hoist: artifact JSON parse + classifier
/// construction + packed-weight build happen exactly once per config, no
/// matter how many cells (or racks) use it.
pub(crate) fn prepare_sweep(gen: &mut Generator, grid: &SweepGrid) -> Result<()> {
    grid.validate()?;
    for id in grid_config_ids_used(grid) {
        gen.prepare(&id).with_context(|| format!("preparing config '{id}'"))?;
    }
    Ok(())
}

/// The sweep engine proper, over an already-prepared shared generator
/// (see [`prepare_sweep`]): validation + cell fan-out, no `&mut` access —
/// the form [`crate::api::execute_prepared`] and the serve layer call so
/// one warm prepared-config cache serves concurrent runs. Fails inside
/// generation if a cell references a configuration that was never
/// prepared.
pub(crate) fn sweep_prepared_sink(
    gen: &Generator,
    grid: &SweepGrid,
    opts: &SweepOptions,
    stream_sink: Option<&dyn TraceSink>,
) -> Result<SweepReport> {
    grid.validate()?;
    ensure!(
        opts.dt_s.is_finite() && opts.dt_s > 0.0,
        "sweep: dt must be positive seconds (got {})",
        opts.dt_s
    );
    let mut cells = grid.expand();
    if opts.shard.is_some() {
        cells.retain(|c| opts.owns_cell(&c.id));
    }
    let n = cells.len();
    let outer = match opts.scenario_workers {
        0 => default_workers().min(n).max(1),
        w => w.min(n).max(1),
    };
    let inner = opts.executor.workers(match opts.server_workers {
        0 => (default_workers() / outer).max(1),
        w => w,
    });
    let gen_ro: &Generator = gen;
    let results: Vec<Result<CellResult>> = opts.executor.map_results(n, outer, |i| {
        let cell = &cells[i];
        let timer = WallTimer::start();
        let (stats, scales, exact, bound) = if opts.window_s > 0.0 {
            let csink = stream_sink.map(|s| (s, cell.id.as_str()));
            let (stats, exact, bound, _paths) =
                run_cell_streaming(gen_ro, cell, opts, inner, csink, None)?;
            (stats, None, exact, bound)
        } else {
            let run =
                gen_ro.facility_shared_batched(&cell.spec, opts.dt_s, inner, opts.max_batch)?;
            let site = run.facility_series();
            let ramp_s = cell_ramp_interval(opts, cell.spec.horizon_s);
            let stats = PlanningStats::compute(&site, opts.dt_s, ramp_s)?;
            let scales = run.acc.multi_scale(opts.dt_s, cell.spec.pue, &opts.scales)?;
            (stats, Some(scales), true, 0.0)
        };
        Ok(CellResult {
            cell: cell.clone(),
            stats,
            scales,
            exact_quantiles: exact,
            p99_bound_w: bound,
            wall_s: timer.elapsed_s(),
        })
    });
    let mut out = Vec::with_capacity(n);
    for (i, r) in results.into_iter().enumerate() {
        out.push(r.with_context(|| format!("cell {}", cells[i].id))?);
    }
    Ok(SweepReport { grid: grid.clone(), dt_s: opts.dt_s, cells: out })
}

/// See [`SweepOptions::ramp_interval_s`]: keep ≥ 2 windows in range (the
/// shared [`clamp_ramp_interval`](crate::metrics::planning::clamp_ramp_interval) policy).
fn cell_ramp_interval(opts: &SweepOptions, horizon_s: f64) -> f64 {
    crate::metrics::planning::clamp_ramp_interval(opts.ramp_interval_s, horizon_s, opts.dt_s)
}

/// Run one cell through the windowed streaming pipeline: fold summary
/// stats per window and (optionally) append the multi-scale CSVs under
/// the logical cell directory of `sink`. With a [`Deadline`], the soft
/// wall-clock budget is checked at every window boundary (the streaming
/// path's cooperative yield points).
/// Returns `(stats, exact_quantiles, p99_bound, finished logical paths)`.
fn run_cell_streaming(
    gen: &Generator,
    cell: &SweepCell,
    opts: &SweepOptions,
    inner_workers: usize,
    sink: Option<(&dyn TraceSink, &str)>,
    deadline: Option<&Deadline>,
) -> Result<(PlanningStats, bool, f64, Vec<String>)> {
    let spec = &cell.spec;
    let ramp_s = cell_ramp_interval(opts, spec.horizon_s);
    let mut stats = StreamingPlanningStats::new(opts.dt_s, ramp_s)?;
    let mut writers = match sink {
        Some((s, cdir)) => Some(CellWriters::create(
            s,
            cdir,
            spec.topology.n_racks(),
            spec.topology.rows,
            spec.pue,
            opts,
        )?),
        None => None,
    };
    let mut rows_buf: Vec<Vec<f64>> = Vec::new();
    let mut site_buf: Vec<f64> = Vec::new();
    let mut site_pcc: Vec<f32> = Vec::new();
    let pue = spec.pue;
    gen.facility_shared_windowed(
        spec,
        opts.dt_s,
        opts.window_s,
        inner_workers,
        opts.max_batch,
        |acc| {
            failpoint::hit("sweep.cell.window", &cell.id)?;
            if let Some(d) = deadline {
                d.check()?;
            }
            acc.fold_rows_site(&mut rows_buf, &mut site_buf);
            // The PCC f32 series exactly as the buffered stats path builds
            // it — the shared helper owns the deliberate double rounding.
            crate::aggregate::pcc_window_into(&site_buf, pue, &mut site_pcc);
            stats.push_slice(&site_pcc);
            if let Some(w) = writers.as_mut() {
                w.push_window(acc, &rows_buf, &site_buf)?;
            }
            Ok(())
        },
    )?;
    let paths = match writers {
        Some(w) => w.finish()?,
        None => Vec::new(),
    };
    let out = stats.finalize()?;
    Ok((out.stats, out.exact_quantiles, out.p99_error_bound_w, paths))
}

// ---------------------------------------------------------------------------
// Checkpointed execution (crash-safe sweeps) — host-only: the durable
// manifest, retry deadlines, and resume validation are filesystem-bound.
// ---------------------------------------------------------------------------

/// File name of the run manifest inside a checkpointed output directory.
#[cfg(feature = "host")]
pub const SWEEP_MANIFEST: &str = "manifest.json";

/// A cell that failed every attempt and was quarantined in the manifest
/// (the rest of the sweep still completed).
#[cfg(feature = "host")]
#[derive(Debug, Clone)]
pub struct QuarantinedCell {
    pub id: String,
    /// Cumulative attempts across every run of the manifest.
    pub attempts: u32,
    /// The last failure: an error chain, a panic payload, or a deadline.
    pub reason: String,
}

/// Result of a checkpointed (possibly resumed) sweep run.
#[cfg(feature = "host")]
pub struct SweepOutcome {
    /// Cells executed by *this* process, in grid order. Restored cells are
    /// not re-materialized — their rows replay from the manifest into
    /// [`SweepOutcome::summary_csv`].
    pub report: SweepReport,
    /// Cells restored from the manifest without re-running.
    pub restored: usize,
    /// Cells quarantined after exhausting the retry budget, grid order.
    pub failed: Vec<QuarantinedCell>,
    /// Cells still `pending` when the run stopped — nonzero only when a
    /// cooperative shutdown ([`crate::robust::shutdown`]) interrupted the
    /// run. Interrupted cells are never quarantined and carry no attempt
    /// charge; `--resume` re-runs exactly these.
    pub interrupted: usize,
    /// The assembled summary (all `done` cells, grid order) — exactly the
    /// bytes written to `<dir>/summary.csv`, and byte-identical to an
    /// uninterrupted [`run_sweep_to`] + [`SweepReport::write`] once every
    /// cell is done.
    pub summary_csv: String,
    /// `<dir>/manifest.json` — pass to `--resume`.
    pub manifest_path: PathBuf,
}

/// Crash-safe variant of [`run_sweep_to`]: execute `grid` under `dir` with
/// a durable [`RunManifest`], per-cell fault isolation, and atomic exports.
///
/// * A fresh directory starts an all-`pending` manifest; a directory that
///   already holds one **resumes** it — `done` cells are skipped and their
///   summary rows replayed verbatim, `pending`/`failed` cells re-run. The
///   manifest's content hash must match this grid + byte-relevant options.
/// * Each cell runs under [`run_isolated`]: panics are caught, failures
///   retried up to `policy.max_retries` times, and a cell that fails every
///   attempt is quarantined (recorded `failed`) without aborting the rest.
/// * All exports land atomically, and the manifest is atomically rewritten
///   after every cell, so a kill at any instant leaves a resumable state.
///
/// Because cells are pure functions of `(spec, seed)`, the final
/// `summary.csv` after any crash/resume sequence is byte-identical to the
/// uninterrupted run's.
#[cfg(feature = "host")]
#[deprecated(
    since = "0.2.0",
    note = "route through crate::api::execute_checkpointed with RunSpec::Sweep"
)]
pub fn run_sweep_checkpointed(
    gen: &mut Generator,
    grid: &SweepGrid,
    opts: &SweepOptions,
    dir: &Path,
    policy: &RetryPolicy,
) -> Result<SweepOutcome> {
    prepare_sweep(gen, grid)?;
    sweep_checkpointed_prepared(gen, grid, opts, dir, policy)
}

/// [`run_sweep_checkpointed`] over an already-prepared shared generator
/// (see [`prepare_sweep`]) — the `pub(crate)` engine behind
/// [`crate::api::execute_checkpointed`] and the serve layer's persisted
/// runs. Preparing the full used-config set (rather than only the configs
/// the pending cells need) is deliberate: the superset is cheap, cached,
/// and lets a read-only generator be shared across resumes.
#[cfg(feature = "host")]
pub(crate) fn sweep_checkpointed_prepared(
    gen: &Generator,
    grid: &SweepGrid,
    opts: &SweepOptions,
    dir: &Path,
    policy: &RetryPolicy,
) -> Result<SweepOutcome> {
    use crate::robust::shutdown;
    grid.validate()?;
    ensure!(
        opts.dt_s.is_finite() && opts.dt_s > 0.0,
        "sweep: dt must be positive seconds (got {})",
        opts.dt_s
    );
    let cells = grid.expand();
    let ids: Vec<String> = cells.iter().map(|c| c.id.clone()).collect();
    let hash = content_hash("sweep", &grid.to_json(), &opts.identity_json());
    std::fs::create_dir_all(dir)?;
    let mpath = dir.join(SWEEP_MANIFEST);
    let mut manifest = if mpath.exists() {
        let m = RunManifest::load(&mpath)?;
        m.ensure_matches("sweep", &hash, &ids)?;
        m
    } else {
        RunManifest::new("sweep", &grid.name, hash, grid.to_json(), opts.record_json(), &ids)
    };
    manifest.reconcile_exports(dir);
    manifest.header = Some(summary_header().to_string());
    let restored = manifest.done_count();
    // The manifest always covers the FULL cell set (so every shard of a
    // grid shares one manifest shape and `merge` is a plain done-cell
    // union); sharding only narrows which pending cells *this* process
    // runs. Cells another shard owns stay `pending` here — that is their
    // normal state, not an interruption.
    let todo: Vec<usize> = (0..cells.len())
        .filter(|&i| !manifest.is_done(&cells[i].id) && opts.owns_cell(&cells[i].id))
        .collect();
    let keeper = ManifestKeeper::new(manifest, mpath.clone())?;
    let n = todo.len();
    let outer = match opts.scenario_workers {
        0 => default_workers().min(n).max(1),
        w => w.min(n).max(1),
    };
    let inner = opts.executor.workers(match opts.server_workers {
        0 => (default_workers() / outer).max(1),
        w => w,
    });
    let sink = DirSink::new(dir);
    let gen_ro: &Generator = gen;
    let results = opts.executor.map_results(n, outer, |k| -> Result<Option<CellResult>> {
        let cell = &cells[todo[k]];
        // A cell not yet started when shutdown arrives never starts: it
        // stays `pending` in the (already durable) manifest and carries
        // no attempt charge — `--resume` picks it up.
        if shutdown::requested() {
            return Ok(None);
        }
        let prior = keeper.with(|m| m.attempts(&cell.id));
        match run_isolated(policy, prior, |deadline| {
            failpoint::hit("sweep.cell", &cell.id)?;
            run_cell_checkpointed(gen_ro, cell, opts, inner, dir, &sink, deadline)
        }) {
            Isolated::Done { value: (result, exports), attempts } => {
                let row = summary_row(&result);
                keeper.update(|m| m.mark_done(&cell.id, attempts, row, exports))?;
                Ok(Some(result))
            }
            // Interrupted mid-cell (the deadline check at a window
            // boundary surfaced the shutdown request): not a failure —
            // the cell stays pending, uncharged, for --resume.
            Isolated::Failed { reason, .. } if shutdown::is_interrupt(&reason) => Ok(None),
            Isolated::Failed { attempts, reason } => {
                keeper.update(|m| m.mark_failed(&cell.id, attempts, reason))?;
                Ok(None)
            }
        }
    });
    // Only manifest-save failures (or pool bugs) surface here — cell
    // failures were quarantined above.
    let mut executed = Vec::new();
    for (k, r) in results.into_iter().enumerate() {
        if let Some(res) = r.with_context(|| format!("cell {}", cells[todo[k]].id))? {
            executed.push(res);
        }
    }
    let manifest = keeper.into_inner();
    let mut summary = String::from(summary_header());
    for c in &cells {
        if let Some(row) = manifest.row(&c.id) {
            summary.push_str(row);
        }
    }
    grid.save(&dir.join("grid.json"))?;
    fsx::atomic_write(&dir.join("summary.csv"), summary.as_bytes())?;
    let failed: Vec<QuarantinedCell> = cells
        .iter()
        .filter_map(|c| {
            let st = manifest.cells.get(&c.id)?;
            (st.status == CellStatus::Failed).then(|| QuarantinedCell {
                id: c.id.clone(),
                attempts: st.attempts,
                reason: st.reason.clone().unwrap_or_default(),
            })
        })
        .collect();
    let interrupted = cells
        .iter()
        .filter(|c| {
            opts.owns_cell(&c.id)
                && manifest.cells.get(&c.id).is_some_and(|st| st.status == CellStatus::Pending)
        })
        .count();
    Ok(SweepOutcome {
        report: SweepReport { grid: grid.clone(), dt_s: opts.dt_s, cells: executed },
        restored,
        failed,
        interrupted,
        summary_csv: summary,
        manifest_path: mpath,
    })
}

/// One cell of a checkpointed run: generate (streaming or buffered), write
/// every export atomically under `<root>/<cell>/`, and return the result
/// plus the [`ExportRecord`]s the manifest needs for resume validation.
#[cfg(feature = "host")]
fn run_cell_checkpointed(
    gen: &Generator,
    cell: &SweepCell,
    opts: &SweepOptions,
    inner_workers: usize,
    root: &Path,
    sink: &DirSink,
    deadline: &Deadline,
) -> Result<(CellResult, Vec<ExportRecord>)> {
    let timer = WallTimer::start();
    let (stats, scales, exact, bound, mut paths) = if opts.window_s > 0.0 {
        let (stats, exact, bound, paths) = run_cell_streaming(
            gen,
            cell,
            opts,
            inner_workers,
            Some((sink as &dyn TraceSink, cell.id.as_str())),
            Some(deadline),
        )?;
        (stats, None, exact, bound, paths)
    } else {
        let run =
            gen.facility_shared_batched(&cell.spec, opts.dt_s, inner_workers, opts.max_batch)?;
        let site = run.facility_series();
        let ramp_s = cell_ramp_interval(opts, cell.spec.horizon_s);
        let stats = PlanningStats::compute(&site, opts.dt_s, ramp_s)?;
        let scales = run.acc.multi_scale(opts.dt_s, cell.spec.pue, &opts.scales)?;
        (stats, Some(scales), true, 0.0, Vec::new())
    };
    let result = CellResult {
        cell: cell.clone(),
        stats,
        scales,
        exact_quantiles: exact,
        p99_bound_w: bound,
        wall_s: timer.elapsed_s(),
    };
    paths.extend(write_cell_exports(sink, &cell.id, &result)?);
    let mut exports = Vec::with_capacity(paths.len());
    for p in paths {
        // Logical sink paths are already `/`-separated and root-relative —
        // exactly the manifest's export-record format.
        let full = root.join(&p);
        let bytes = std::fs::metadata(&full)
            .with_context(|| format!("stat export {}", full.display()))?
            .len();
        exports.push(ExportRecord { path: p, bytes });
    }
    Ok((result, exports))
}

/// The static sweep summary header line — shared by [`SweepReport`] and
/// the checkpointed runner (which replays manifest rows under it).
pub(crate) fn summary_header() -> &'static str {
    "cell,workload,topology,fleet,servers,seed,\
     peak_w,avg_w,p99_w,energy_kwh,max_ramp_w,cv,peak_to_average,load_factor\n"
}

/// One cell's summary row (with trailing newline) — the exact bytes
/// [`SweepReport::summary_csv`] emits, also recorded verbatim into the run
/// manifest so a resumed run replays rather than recomputes them.
pub(crate) fn summary_row(c: &CellResult) -> String {
    let t = c.cell.spec.topology;
    let fleet = c.cell.spec.server_config.config_ids().join("+");
    format!(
        "{},{},{}x{}x{},{},{},{},{},{},{},{},{},{},{},{}\n",
        c.cell.id,
        csv_field(&c.cell.spec.workload.label()),
        t.rows,
        t.racks_per_row,
        t.servers_per_rack,
        csv_field(&fleet),
        t.n_servers(),
        c.cell.spec.seed,
        c.stats.peak_w,
        c.stats.avg_w,
        c.stats.p99_w,
        c.stats.energy_kwh,
        c.stats.max_ramp_w,
        c.stats.cv,
        c.stats.peak_to_average,
        c.stats.load_factor,
    )
}

impl SweepReport {
    /// The planning summary as CSV. Deterministic per (grid, seeds): values
    /// are emitted with Rust's shortest round-trip float formatting and no
    /// timing columns.
    pub fn summary_csv(&self) -> String {
        let mut s = String::from(summary_header());
        for c in &self.cells {
            s.push_str(&summary_row(c));
        }
        s
    }

    /// Human-readable summary table (kW units, wall-clock included).
    pub fn summary_table(&self) -> String {
        let mut s = format!(
            "{:<14} {:<44} {:>6} {:>9} {:>9} {:>9} {:>9} {:>9} {:>7} {:>6} {:>7}\n",
            "cell", "scenario", "srv", "peak kW", "avg kW", "p99 kW", "MWh", "ramp kW", "CV", "PAR",
            "wall s"
        );
        for c in &self.cells {
            s.push_str(&format!(
                "{:<14} {:<44} {:>6} {:>9.1} {:>9.1} {:>8.1}{} {:>9.2} {:>9.1} {:>7.3} {:>6.2} {:>7.1}\n",
                c.cell.id,
                truncate(&c.cell.label, 44),
                c.cell.spec.topology.n_servers(),
                c.stats.peak_w / 1e3,
                c.stats.avg_w / 1e3,
                c.stats.p99_w / 1e3,
                if c.exact_quantiles { " " } else { "~" },
                c.stats.energy_kwh / 1e3,
                c.stats.max_ramp_w / 1e3,
                c.stats.cv,
                c.stats.peak_to_average,
                c.wall_s,
            ));
        }
        s
    }

    /// Write the full report under `dir`:
    ///
    /// ```text
    /// <dir>/grid.json                      the grid (reproduction recipe)
    /// <dir>/summary.csv                    one PlanningStats row per cell
    /// <dir>/<cell>/scenario.json           the expanded ScenarioSpec
    /// <dir>/<cell>/racks_<interval>s.csv   per-rack IT power
    /// <dir>/<cell>/rows_<interval>s.csv    per-row IT power
    /// <dir>/<cell>/facility_<interval>s.csv  PCC power per facility scale
    /// ```
    ///
    /// Cells executed in streaming mode carry no in-memory series
    /// (`scales: None`); their series CSVs were already appended
    /// incrementally by [`run_sweep_to`] into the same layout, so this
    /// writes only the metadata files for them.
    #[cfg(feature = "host")]
    pub fn write(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        self.write_sink(&DirSink::new(dir))
    }

    /// [`SweepReport::write`] against an arbitrary [`TraceSink`] (same
    /// logical layout at the sink root).
    pub fn write_sink(&self, sink: &dyn TraceSink) -> Result<()> {
        sink.put("grid.json", json::to_string_pretty(&self.grid.to_json()).as_bytes())?;
        sink.put("summary.csv", self.summary_csv().as_bytes())?;
        for c in &self.cells {
            write_cell_exports(sink, &c.cell.id, c)?;
        }
        Ok(())
    }
}

/// Write one cell's metadata + buffered series exports under the logical
/// `cdir` and return every logical path written (streamed series CSVs are
/// not re-written — they were already finalized by
/// [`CellWriters::finish`]). Every file lands atomically where the sink
/// supports it.
fn write_cell_exports(sink: &dyn TraceSink, cdir: &str, c: &CellResult) -> Result<Vec<String>> {
    let mut paths = Vec::new();
    let spec_path = format!("{cdir}/scenario.json");
    // Byte-identical to the pre-split `ScenarioSpec::save` (same pretty
    // printer, same trailing newline).
    sink.put(&spec_path, json::to_string_pretty(&c.cell.spec.to_json()).as_bytes())?;
    paths.push(spec_path);
    let Some(scales) = &c.scales else { return Ok(paths) };
    let sc = &scales.scales;
    let p = format!("{cdir}/racks_{}s.csv", fmt_secs(sc.rack_interval_s));
    write_series_csv(sink, &p, "rack", sc.rack_interval_s, &scales.racks_w)?;
    paths.push(p);
    let p = format!("{cdir}/rows_{}s.csv", fmt_secs(sc.row_interval_s));
    write_series_csv(sink, &p, "row", sc.row_interval_s, &scales.rows_w)?;
    paths.push(p);
    for (k, &interval) in sc.facility_intervals_s.iter().enumerate() {
        let p = format!("{cdir}/facility_{}s.csv", fmt_secs(interval));
        let fac = std::slice::from_ref(&scales.facility_w[k]);
        write_series_csv(sink, &p, "facility", interval, fac)?;
        paths.push(p);
    }
    Ok(paths)
}

// ---------------------------------------------------------------------------
// Incremental CSV writers (streaming mode)
// ---------------------------------------------------------------------------

/// One cell's set of incremental multi-scale CSV writers, streaming
/// through the run's [`TraceSink`] under the cell's logical directory.
struct CellWriters {
    racks: StreamingCsv,
    rows: StreamingCsv,
    facility: Vec<StreamingCsv>,
}

impl CellWriters {
    fn create(
        sink: &dyn TraceSink,
        cdir: &str,
        n_racks: usize,
        n_rows: usize,
        pue: f64,
        opts: &SweepOptions,
    ) -> Result<CellWriters> {
        let sc = &opts.scales;
        let racks = StreamingCsv::create(
            sink,
            &format!("{cdir}/racks_{}s.csv", fmt_secs(sc.rack_interval_s)),
            "rack",
            n_racks,
            opts.dt_s,
            sc.rack_interval_s,
            1.0,
        )?;
        let rows = StreamingCsv::create(
            sink,
            &format!("{cdir}/rows_{}s.csv", fmt_secs(sc.row_interval_s)),
            "row",
            n_rows,
            opts.dt_s,
            sc.row_interval_s,
            1.0,
        )?;
        let facility = sc
            .facility_intervals_s
            .iter()
            .map(|&interval| {
                // PUE rides on the resampler's scale factor, exactly as the
                // buffered `resample_mean_f64(&site, dt, interval, pue)`.
                StreamingCsv::create(
                    sink,
                    &format!("{cdir}/facility_{}s.csv", fmt_secs(interval)),
                    "facility",
                    1,
                    opts.dt_s,
                    interval,
                    pue,
                )
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(CellWriters { racks, rows, facility })
    }

    /// Append one generation window across every writer. `rows_w`/`site_w`
    /// are the per-row and site IT windows from
    /// [`StreamingFacilityAccumulator::fold_rows_site`].
    fn push_window(
        &mut self,
        acc: &mut StreamingFacilityAccumulator,
        rows_w: &[Vec<f64>],
        site_w: &[f64],
    ) -> Result<()> {
        for r in 0..acc.topology().n_racks() {
            self.racks.push_col(r, acc.rack_window(r));
        }
        self.racks.write_ready_rows()?;
        for (r, row) in rows_w.iter().enumerate() {
            self.rows.push_col(r, row);
        }
        self.rows.write_ready_rows()?;
        for f in self.facility.iter_mut() {
            f.push_col(0, site_w);
            f.write_ready_rows()?;
        }
        Ok(())
    }

    /// Finalize every writer (flush + publish through the sink) and return
    /// the finished logical paths.
    fn finish(self) -> Result<Vec<String>> {
        let mut paths = Vec::with_capacity(2 + self.facility.len());
        paths.push(self.racks.finish()?);
        paths.push(self.rows.finish()?);
        for f in self.facility {
            paths.push(f.finish()?);
        }
        Ok(paths)
    }
}

fn truncate(s: &str, max: usize) -> String {
    if s.chars().count() <= max {
        s.to_string()
    } else {
        let cut: String = s.chars().take(max.saturating_sub(1)).collect();
        format!("{cut}…")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn truncate_respects_char_boundaries() {
        assert_eq!(truncate("short", 10), "short");
        let t = truncate("λ̄-burstiness-very-long-label", 10);
        assert!(t.chars().count() <= 10);
    }

    #[test]
    fn wall_timer_is_monotone() {
        let t = WallTimer::start();
        assert!(t.elapsed_s() >= 0.0);
    }
}
