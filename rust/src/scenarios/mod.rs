//! The scenario sweep engine: declarative grids of serving scenarios,
//! expanded into concrete [`ScenarioSpec`](crate::config::ScenarioSpec)s,
//! executed in parallel over one shared [`Generator`](crate::coordinator::Generator),
//! and exported as multi-resolution power traces plus a planning summary.
//!
//! This is the paper's "generate traces for new traffic conditions and
//! serving configurations" loop turned into infrastructure: a planner
//! writes one JSON *grid* instead of N scenario files, and every cell of
//! the cross-product — workload × topology × fleet × seed — becomes a
//! deterministic, individually reproducible facility run.
//!
//! # Grid JSON schema
//!
//! ```text
//! {
//!   "name":       string                 — sweep name (output directory stem)
//!   "defaults": {                        — optional; applied to every cell
//!     "dataset":   string                  (default "sharegpt")
//!     "horizon_s": number                  (default 600)
//!     "p_base_w":  number                  (default 1000)
//!     "pue":       number                  (default 1.3)
//!   },
//!   "workloads":  [ WorkloadSpec, ... ]  — same objects as scenario files:
//!                                          {"kind":"poisson","rate":..},
//!                                          {"kind":"mmpp","mean_rate":..,"burstiness":..},
//!                                          {"kind":"diurnal", ...}, {"kind":"replay", ...}
//!   "topologies": [ {"rows":..,"racks_per_row":..,"servers_per_rack":..}, ... ]
//!   "fleets":     [ "config_id" | ["id_a","id_b"], ... ]
//!                                        — a string is a homogeneous hall, an
//!                                          array cycles configs over racks
//!   "seeds":      [ 0, 1, ... ]          — one full replication per seed
//! }
//! ```
//!
//! Every axis must be non-empty; the grid expands to
//! `workloads × topologies × fleets × seeds` cells in that (deterministic)
//! nesting order, each with a stable id `w<i>-t<j>-f<k>-s<seed>`.
//!
//! # Example
//!
//! Expansion is pure (no artifacts needed), so it can be driven directly:
//!
//! ```
//! use powertrace_sim::scenarios::SweepGrid;
//! use powertrace_sim::util::json;
//!
//! let grid = SweepGrid::from_json(&json::parse(r#"{
//!   "name": "rate_fleet_study",
//!   "defaults": {"horizon_s": 300},
//!   "workloads": [{"kind": "poisson", "rate": 0.5},
//!                 {"kind": "mmpp", "mean_rate": 0.5, "burstiness": 4.0}],
//!   "topologies": [{"rows": 1, "racks_per_row": 2, "servers_per_rack": 2}],
//!   "fleets": ["llama70b_a100_tp8",
//!              ["llama70b_a100_tp8", "gptoss120b_a100_tp4"]],
//!   "seeds": [0, 1]
//! }"#).unwrap()).unwrap();
//!
//! assert_eq!(grid.n_cells(), 8);
//! let cells = grid.expand();
//! assert_eq!(cells.len(), 8);
//! assert_eq!(cells[0].id, "w0-t0-f0-s0");
//! // Duplicate configs across fleets are loaded once.
//! assert_eq!(grid.config_ids().len(), 2);
//! ```
//!
//! Running a grid ([`run_sweep`]) prepares each referenced configuration
//! **once** on the generator (artifact load + classifier build — see
//! [`Generator::prepare`](crate::coordinator::Generator::prepare)), then
//! fans cells across a thread pool with
//! [`facility_shared`](crate::coordinator::Generator::facility_shared).
//! Each cell yields a [`PlanningStats`](crate::metrics::PlanningStats)
//! summary row and a [`MultiScale`](crate::aggregate::MultiScale) export —
//! rack series at 1 s, row series at 15 s, facility series at 5/15 min by
//! default. Cells are bit-reproducible per `(scenario, seed)`, so grid
//! summaries can be diffed across code revisions.

//! # Comparing revisions
//!
//! Because summaries are deterministic, two revisions of the same grid can
//! be compared cell-by-cell: [`diff::diff_summary_files`] (CLI:
//! `powertrace diff a.csv b.csv --tolerance 1e-9`) reports per-metric
//! deltas and exits non-zero beyond the tolerance — the metric-regression
//! gate CI runs after every sweep/site smoke. The site composition layer
//! ([`crate::site`]) reuses the same streaming CSV writers (now in
//! [`crate::export`]) for its `site_load.csv` export.

pub mod diff;
pub mod grid;
pub mod runner;

pub use diff::{diff_summaries, DiffReport};
#[cfg(feature = "host")]
pub use diff::diff_summary_files;
pub use grid::{GridDefaults, SweepCell, SweepGrid};
// The deprecated run_* entry points stay re-exported for source compat;
// new code routes through `crate::api`.
#[allow(deprecated)]
pub use runner::{run_sweep, run_sweep_sink};
pub use runner::{CellResult, SweepOptions, SweepReport};
#[allow(deprecated)]
#[cfg(feature = "host")]
pub use runner::{run_sweep_checkpointed, run_sweep_to};
#[cfg(feature = "host")]
pub use runner::{QuarantinedCell, SweepOutcome, SWEEP_MANIFEST};
