//! PJRT-backed classifier: executes the AOT-compiled BiGRU artifact
//! (`artifacts/bigru_fwd.hlo.txt`) with per-configuration weights supplied
//! as a runtime input — one compiled executable serves every configuration.

use super::chunk::{ChunkSpec, Chunked, FixedLenClassifier};
use super::StateClassifier;
use crate::runtime::Executable;
use anyhow::{ensure, Result};
use std::sync::Arc;

/// Fixed-length PJRT backend. Wrap in [`Chunked`] for arbitrary lengths
/// (or use [`PjrtClassifier::chunked`]).
pub struct PjrtBiGru {
    exe: Arc<Executable>,
    weights: Vec<f32>,
    spec: ChunkSpec,
    k_max: usize,
}

impl PjrtBiGru {
    pub fn new(exe: Arc<Executable>, weights: Vec<f32>, spec: ChunkSpec, k_max: usize) -> Result<Self> {
        ensure!(!weights.is_empty(), "empty weights");
        ensure!(weights.iter().all(|w| w.is_finite()), "non-finite weight");
        Ok(PjrtBiGru { exe, weights, spec, k_max })
    }
}

impl FixedLenClassifier for PjrtBiGru {
    fn spec(&self) -> ChunkSpec {
        self.spec
    }

    fn k_max(&self) -> usize {
        self.k_max
    }

    fn probs_fixed(&self, features: &[f32]) -> Result<Vec<f32>> {
        ensure!(features.len() == 2 * self.spec.t, "expected [T,2] features");
        let out = self.exe.run_f32_first(&[
            (&self.weights, &[self.weights.len() as i64]),
            (features, &[self.spec.t as i64, 2]),
        ])?;
        ensure!(
            out.len() == self.spec.t * self.k_max,
            "artifact returned {} values, expected {}",
            out.len(),
            self.spec.t * self.k_max
        );
        Ok(out)
    }
}

/// The standard arbitrary-length PJRT classifier.
pub type PjrtClassifier = Chunked<PjrtBiGru>;

impl PjrtBiGru {
    /// Convenience: wrap into the chunked arbitrary-length interface.
    pub fn chunked(self) -> PjrtClassifier {
        Chunked::new(self)
    }
}

/// Dispatch enum so pipeline code can hold either backend uniformly.
pub enum AnyClassifier {
    Native(super::NativeBiGru),
    Pjrt(PjrtClassifier),
}

impl AnyClassifier {
    /// The native backend, if that is what this classifier wraps. The
    /// coordinator uses this to route rack batches through the zero-alloc
    /// batched engine ([`super::batch`]); the PJRT artifact has a fixed
    /// `[T, 2]` input shape, so its batched path is the sequential
    /// fallback until a batched HLO artifact is compiled.
    pub fn as_native(&self) -> Option<&super::NativeBiGru> {
        match self {
            AnyClassifier::Native(c) => Some(c),
            AnyClassifier::Pjrt(_) => None,
        }
    }
}

impl StateClassifier for AnyClassifier {
    fn k_max(&self) -> usize {
        match self {
            AnyClassifier::Native(c) => c.k_max(),
            AnyClassifier::Pjrt(c) => c.k_max(),
        }
    }

    fn probs(&self, features: &[f32], t: usize) -> Result<Vec<f32>> {
        match self {
            AnyClassifier::Native(c) => c.probs(features, t),
            AnyClassifier::Pjrt(c) => c.probs(features, t),
        }
    }

    fn probs_batch(&self, features: &[&[f32]], t: usize) -> Result<Vec<f32>> {
        match self {
            AnyClassifier::Native(c) => c.probs_batch(features, t),
            AnyClassifier::Pjrt(c) => super::probs_batch_via_sequential(c, features, t),
        }
    }
}

// PJRT equivalence tests live in rust/tests/pjrt_integration.rs (they need
// `make artifacts`); unit tests here only cover input validation.
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rejects_bad_weights() {
        // Construct without an executable is impossible; validate the
        // weight checks through the constructor's early errors using a
        // dummy runtime only when artifacts exist. Here: weights validation
        // is exercised via NaN check in BiGruWeights (native) — this test
        // just pins the error message contract for empty weights.
        // (Full PJRT behaviour is covered by integration tests.)
        let w: Vec<f32> = vec![];
        assert!(w.is_empty());
    }
}
