//! Fixed-shape chunked inference.
//!
//! The AOT-compiled HLO has a static sequence length (T = 512), so long
//! traces are processed in overlapping windows: each window carries a halo
//! of context on both sides (the BiGRU is bidirectional, so both edges
//! matter) and only the interior `core = T − 2·halo` rows are kept. Short
//! sequences are zero-padded on the right (zero features = idle, the
//! natural boundary condition).

use super::StateClassifier;
use anyhow::{ensure, Result};

/// Chunking geometry. Defaults match `artifacts/manifest.json`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChunkSpec {
    /// Static sequence length of the compiled artifact.
    pub t: usize,
    /// Context rows discarded on each side of a window interior.
    pub halo: usize,
}

impl Default for ChunkSpec {
    fn default() -> Self {
        ChunkSpec { t: 512, halo: 64 }
    }
}

impl ChunkSpec {
    pub fn core(&self) -> usize {
        self.t - 2 * self.halo
    }
}

/// A fixed-shape backend: probabilities for exactly `spec.t` timesteps.
pub trait FixedLenClassifier {
    fn spec(&self) -> ChunkSpec;
    fn k_max(&self) -> usize;
    /// `features.len() == 2 * spec.t` → probs `[spec.t, k_max]`.
    fn probs_fixed(&self, features: &[f32]) -> Result<Vec<f32>>;
}

/// Adapts a [`FixedLenClassifier`] to arbitrary-length sequences.
pub struct Chunked<B: FixedLenClassifier> {
    pub backend: B,
}

impl<B: FixedLenClassifier> Chunked<B> {
    pub fn new(backend: B) -> Self {
        Chunked { backend }
    }
}

impl<B: FixedLenClassifier> StateClassifier for Chunked<B> {
    fn k_max(&self) -> usize {
        self.backend.k_max()
    }

    fn probs(&self, features: &[f32], t_len: usize) -> Result<Vec<f32>> {
        ensure!(features.len() == 2 * t_len, "features length mismatch");
        let spec = self.backend.spec();
        ensure!(spec.core() > 0, "halo too large for chunk length");
        let k = self.backend.k_max();
        let core = spec.core();
        let mut out = vec![0.0f32; t_len * k];
        let mut window = vec![0.0f32; 2 * spec.t];

        let mut out_start = 0usize;
        while out_start < t_len {
            // Window begins `halo` before the interior when possible. The
            // final window is shifted left to stay fully inside the
            // sequence (no right padding) so the backward scan starts from
            // the true sequence end; zero padding only remains for
            // sequences shorter than one window.
            let mut in_start = out_start.saturating_sub(spec.halo);
            if in_start + spec.t > t_len && t_len >= spec.t {
                in_start = t_len - spec.t;
            }
            let in_end = (in_start + spec.t).min(t_len);
            let n_in = in_end - in_start;
            window[..2 * n_in].copy_from_slice(&features[2 * in_start..2 * in_end]);
            window[2 * n_in..].fill(0.0); // right zero-pad (idle)
            let probs = self.backend.probs_fixed(&window)?;
            ensure!(probs.len() == spec.t * k, "backend returned wrong shape");

            let rel = out_start - in_start; // offset of interior in window
            let take = core.min(t_len - out_start).min(spec.t - rel);
            out[out_start * k..(out_start + take) * k]
                .copy_from_slice(&probs[rel * k..(rel + take) * k]);
            out_start += take;
        }
        Ok(out)
    }
}

/// Wrap a whole-sequence classifier as a fixed-length backend (used to test
/// chunking against the native model and as the PJRT cross-check).
pub struct FixedAdapter<C: StateClassifier> {
    pub inner: C,
    pub spec: ChunkSpec,
}

impl<C: StateClassifier> FixedLenClassifier for FixedAdapter<C> {
    fn spec(&self) -> ChunkSpec {
        self.spec
    }
    fn k_max(&self) -> usize {
        self.inner.k_max()
    }
    fn probs_fixed(&self, features: &[f32]) -> Result<Vec<f32>> {
        self.inner.probs(features, self.spec.t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::classifier::native::tests::{random_features, random_weights};
    use crate::classifier::{NativeBiGru, K_MAX};

    fn chunked(seed: u64, spec: ChunkSpec) -> Chunked<FixedAdapter<NativeBiGru>> {
        Chunked::new(FixedAdapter { inner: NativeBiGru::new(random_weights(seed)), spec })
    }

    #[test]
    fn chunked_matches_unchunked_away_from_halos() {
        let model = NativeBiGru::new(random_weights(11));
        let spec = ChunkSpec { t: 128, halo: 32 };
        let ch = chunked(11, spec);
        let t_len = 300;
        let xs = random_features(t_len, 12);
        let full = model.probs(&xs, t_len).unwrap();
        let chunked_probs = ch.probs(&xs, t_len).unwrap();
        assert_eq!(chunked_probs.len(), full.len());
        // Differences only from truncated context at window edges; with a
        // 32-step halo the GRU state has effectively converged (update-gate
        // leakage halves influence roughly every step), so rows agree
        // tightly everywhere.
        let mut max_diff = 0.0f32;
        for (a, b) in full.iter().zip(&chunked_probs) {
            max_diff = max_diff.max((a - b).abs());
        }
        assert!(max_diff < 5e-3, "max diff {max_diff}");
    }

    #[test]
    fn short_sequence_single_padded_window() {
        let spec = ChunkSpec { t: 64, halo: 16 };
        let ch = chunked(13, spec);
        let xs = random_features(10, 14);
        let p = ch.probs(&xs, 10).unwrap();
        assert_eq!(p.len(), 10 * K_MAX);
        for row in p.chunks(K_MAX) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn exact_multiple_lengths() {
        let spec = ChunkSpec { t: 32, halo: 8 };
        let ch = chunked(15, spec);
        for t_len in [16, 32, 48, 64, 100] {
            let xs = random_features(t_len, 16);
            let p = ch.probs(&xs, t_len).unwrap();
            assert_eq!(p.len(), t_len * K_MAX, "t_len {t_len}");
        }
    }

    #[test]
    fn rejects_bad_lengths() {
        let ch = chunked(17, ChunkSpec::default());
        assert!(ch.probs(&[0.0; 7], 3).is_err());
    }

    #[test]
    fn default_spec_geometry() {
        let s = ChunkSpec::default();
        assert_eq!(s.t, 512);
        assert_eq!(s.halo, 64);
        assert_eq!(s.core(), 384);
    }
}
