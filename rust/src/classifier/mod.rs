//! The temporal state classifier (paper §3.2, Eq. 3): a bidirectional GRU
//! mapping workload features `x_t = (A_t, ΔA_t)` to per-timestep state
//! posteriors `P(z_t = k | X)`.
//!
//! Two interchangeable backends:
//! * [`native::NativeBiGru`] — pure-Rust forward pass (portable; also the
//!   numerical cross-check for the artifact path);
//! * [`pjrt::PjrtClassifier`] — executes the AOT-compiled XLA artifact
//!   (`artifacts/bigru_fwd.hlo.txt`, lowered from the L2 JAX model whose
//!   hot loop is the L1 Pallas GRU kernel) through the PJRT CPU client.
//!
//! Both consume the same flat parameter vector (layout in DESIGN.md §6)
//! and the same chunking scheme ([`chunk`]) for long traces.

pub mod batch;
pub mod chunk;
pub mod native;
pub mod pjrt;
#[cfg(feature = "simd")]
pub(crate) mod simd;

pub use batch::{BatchScan, LaneFeatures, ScratchArena, SliceFeatures, BATCH_TILE};
pub use chunk::{ChunkSpec, Chunked};
pub use native::{BiGruWeights, NativeBiGru};
pub use pjrt::PjrtClassifier;

use anyhow::Result;

/// Feature transform baked into the model definition on both the Python
/// and Rust sides (keep in sync with `python/compile/model.py`):
/// `log1p` compresses the saturating tail of the occupancy→power curve
/// while keeping low-occupancy levels (idle vs A=1 vs A=2) separated.
#[inline]
pub fn scale_features(a: f32, da: f32) -> (f32, f32) {
    let fa = a.max(0.0).ln_1p() * 0.5;
    let fda = da.signum() * da.abs().ln_1p() * 0.5;
    (fa, if fda.is_nan() { 0.0 } else { fda })
}

/// Hidden size used throughout (paper §4.1: H = 64).
pub const HIDDEN: usize = 64;
/// Maximum number of states; configs with K < K_MAX mask unused logits.
pub const K_MAX: usize = 12;
/// Flat parameter count for (HIDDEN, K_MAX, input=2).
pub const N_PARAMS: usize = flat_param_count(HIDDEN, K_MAX);

/// Flat parameter count: two directions of (W_ih[3H,2] + b_ih[3H] +
/// W_hh[3H,H] + b_hh[3H]) plus the head (W[K,2H] + b[K]).
pub const fn flat_param_count(h: usize, k: usize) -> usize {
    2 * (3 * h * 2 + 3 * h + 3 * h * h + 3 * h) + k * 2 * h + k
}

/// A classifier backend: features `[T,2]` (raw, unscaled, interleaved) →
/// state posteriors `[T, k_max]` row-major.
pub trait StateClassifier {
    fn k_max(&self) -> usize;
    /// `features.len() == 2 * t`.
    fn probs(&self, features: &[f32], t: usize) -> Result<Vec<f32>>;

    /// Batched inference over `B = features.len()` equal-length sequences
    /// (each `features[lane].len() == 2 * t`), returning posteriors in
    /// lane-major rows `[T, B, k_max]`: the `(t, lane)` posterior occupies
    /// `out[(t*B + lane)*k_max ..][..k_max]`.
    ///
    /// The contract (which [`native::NativeBiGru`] exploits with a real
    /// rack-batched GEMM engine, see [`batch`]) is that the output is
    /// **bit-identical** to calling [`StateClassifier::probs`] once per
    /// lane; this default implementation does exactly that.
    fn probs_batch(&self, features: &[&[f32]], t: usize) -> Result<Vec<f32>> {
        probs_batch_via_sequential(self, features, t)
    }
}

/// The reference batched implementation: one sequential [`StateClassifier::probs`]
/// call per lane, interleaved into `[T, B, k_max]` lane-major rows. Used as
/// the trait default and as the fallback for backends without a native
/// batched engine (e.g. the fixed-shape PJRT artifact).
pub fn probs_batch_via_sequential<C: StateClassifier + ?Sized>(
    cls: &C,
    features: &[&[f32]],
    t: usize,
) -> Result<Vec<f32>> {
    let b = features.len();
    let k = cls.k_max();
    let mut out = vec![0.0f32; t * b * k];
    for (lane, f) in features.iter().enumerate() {
        let p = cls.probs(f, t)?;
        for tt in 0..t {
            out[(tt * b + lane) * k..(tt * b + lane + 1) * k]
                .copy_from_slice(&p[tt * k..(tt + 1) * k]);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_count_matches_design() {
        // DESIGN.md §6: 27,660 f32 for H=64, K=12, input 2.
        assert_eq!(N_PARAMS, 27_660);
        assert_eq!(flat_param_count(2, 3), 2 * (12 + 6 + 12 + 6) + 3 * 4 + 3);
    }
}
