//! Explicit f32x8 SIMD kernels for the batched scan (`--features simd`).
//!
//! The batched kernels in [`super::batch`] vectorize over the lane
//! (server) dimension implicitly, through auto-vectorizable scalar loops.
//! This module provides the same kernels as explicit AVX2 intrinsics:
//! eight lanes per `ymm` register, one broadcast weight amortized across
//! all eight, with a scalar tail for ragged batch widths.
//!
//! ## Bit-identity
//!
//! The fleet-fold contract requires batched posteriors to be bit-identical
//! to the sequential path, which pins the *reduction order over H* (8
//! partial-sum slots, left fold from 0.0, remainder in order — the
//! `native::dot` schedule). The lane dimension carries no reduction at
//! all: every lane is an independent scalar chain, and IEEE-754 vector
//! `mul`/`add` are elementwise identical to their scalar counterparts.
//! So these kernels replay the scalar kernels' exact per-lane arithmetic —
//! same multiplies, same adds, same order — and differ only in how many
//! lanes advance per instruction. Two deliberate consequences:
//!
//! * **no FMA**: `_mm256_fmadd_ps` would fuse `a·b + c` into one rounding
//!   where the scalar path rounds twice, so every multiply-accumulate is
//!   an explicit `_mm256_mul_ps` followed by `_mm256_add_ps`;
//! * **scalar transcendentals**: the gate nonlinearities (`sigmoid`,
//!   `tanh`) stay scalar in [`super::batch`]'s state update — a vector
//!   polynomial approximation would change bits.
//!
//! Dispatch happens per kernel call at runtime ([`avx2`], cached by
//! `std_detect`); builds without the feature, non-x86-64 targets, and
//! machines without AVX2 all take the scalar path unchanged. The parity
//! suite in `batch.rs` pins both paths to the sequential reference, and
//! `avx2_kernels_match_scalar_bitwise` compares the two kernel families
//! directly.

#[cfg(target_arch = "x86_64")]
pub(crate) use x86::{avx2, dot_lanes_avx2, gates_input_avx2, gemm_3h_lanes_avx2};

#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::{
        _mm256_add_ps, _mm256_loadu_ps, _mm256_mul_ps, _mm256_set1_ps, _mm256_storeu_ps,
    };

    /// Is the AVX2 fast path usable on this machine? (Cached by the
    /// standard library's feature-detection runtime.)
    #[inline]
    pub(crate) fn avx2() -> bool {
        std::arch::is_x86_64_feature_detected!("avx2")
    }

    /// `a[lane] += w · x[lane]` — one broadcast weight against eight lanes
    /// per step, scalar tail in lane order. Separate mul + add, never FMA.
    ///
    /// # Safety
    /// Caller must have verified AVX2 support ([`avx2`]).
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn axpy(w: f32, x: &[f32], a: &mut [f32]) {
        debug_assert_eq!(x.len(), a.len());
        let n = a.len();
        let wv = _mm256_set1_ps(w);
        let mut i = 0;
        while i + 8 <= n {
            let xv = _mm256_loadu_ps(x.as_ptr().add(i));
            let av = _mm256_loadu_ps(a.as_ptr().add(i));
            _mm256_storeu_ps(a.as_mut_ptr().add(i), _mm256_add_ps(av, _mm256_mul_ps(wv, xv)));
            i += 8;
        }
        while i < n {
            *a.get_unchecked_mut(i) += w * *x.get_unchecked(i);
            i += 1;
        }
    }

    /// `a[lane] += x[lane]` (no multiply — the slot fold adds raw partial
    /// sums, and `x · 1.0` would not be the same operation).
    ///
    /// # Safety
    /// Caller must have verified AVX2 support ([`avx2`]).
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn vacc(x: &[f32], a: &mut [f32]) {
        debug_assert_eq!(x.len(), a.len());
        let n = a.len();
        let mut i = 0;
        while i + 8 <= n {
            let xv = _mm256_loadu_ps(x.as_ptr().add(i));
            let av = _mm256_loadu_ps(a.as_ptr().add(i));
            _mm256_storeu_ps(a.as_mut_ptr().add(i), _mm256_add_ps(av, xv));
            i += 8;
        }
        while i < n {
            *a.get_unchecked_mut(i) += *x.get_unchecked(i);
            i += 1;
        }
    }

    /// `out[lane] = 0.0 + acc[0, lane] + … + acc[7, lane]` — the slot fold
    /// of `batch::fold_acc`, including the 0.0 start (signed zeros).
    ///
    /// # Safety
    /// Caller must have verified AVX2 support ([`avx2`]).
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn fold8(acc: &[f32], b: usize, out: &mut [f32]) {
        out.fill(0.0);
        for l in 0..8 {
            vacc(&acc[l * b..(l + 1) * b], out);
        }
    }

    /// AVX2 twin of `batch::gemm_3h_lanes`: identical chunk/slot/remainder
    /// schedule over `H`, bias added last, lanes advanced eight at a time.
    ///
    /// # Safety
    /// Caller must have verified AVX2 support ([`avx2`]).
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn gemm_3h_lanes_avx2(
        w: &[f32],
        bias: &[f32],
        hid: &[f32],
        h: usize,
        b: usize,
        acc: &mut [f32],
        out: &mut [f32],
    ) {
        debug_assert_eq!(acc.len(), 8 * b);
        let nchunks = h / 8;
        for j in 0..3 * h {
            let row = &w[j * h..(j + 1) * h];
            acc.fill(0.0);
            for c in 0..nchunks {
                for l in 0..8 {
                    let kk = 8 * c + l;
                    axpy(row[kk], &hid[kk * b..(kk + 1) * b], &mut acc[l * b..(l + 1) * b]);
                }
            }
            let out_row = &mut out[j * b..(j + 1) * b];
            fold8(acc, b, out_row);
            for kk in 8 * nchunks..h {
                axpy(row[kk], &hid[kk * b..(kk + 1) * b], out_row);
            }
            let bj = bias[j];
            let bjv = _mm256_set1_ps(bj);
            let mut lane = 0;
            while lane + 8 <= b {
                let ov = _mm256_loadu_ps(out_row.as_ptr().add(lane));
                _mm256_storeu_ps(out_row.as_mut_ptr().add(lane), _mm256_add_ps(ov, bjv));
                lane += 8;
            }
            while lane < b {
                *out_row.get_unchecked_mut(lane) += bj;
                lane += 1;
            }
        }
    }

    /// AVX2 twin of `batch::dot_lanes` (the head projection halves): same
    /// schedule as the GEMM rows, without the bias.
    ///
    /// # Safety
    /// Caller must have verified AVX2 support ([`avx2`]).
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn dot_lanes_avx2(
        row: &[f32],
        mat: &[f32],
        b: usize,
        acc: &mut [f32],
        out: &mut [f32],
    ) {
        let h = row.len();
        let nchunks = h / 8;
        acc.fill(0.0);
        for c in 0..nchunks {
            for l in 0..8 {
                let kk = 8 * c + l;
                axpy(row[kk], &mat[kk * b..(kk + 1) * b], &mut acc[l * b..(l + 1) * b]);
            }
        }
        fold8(acc, b, out);
        for kk in 8 * nchunks..h {
            axpy(row[kk], &mat[kk * b..(kk + 1) * b], out);
        }
    }

    /// AVX2 twin of the input-gate loop in `batch::step_lanes`:
    /// `out[j, lane] = (w_x0[j]·x0[lane] + w_x1[j]·x1[lane]) + b_ih[j]`,
    /// with the scalar expression's exact association.
    ///
    /// # Safety
    /// Caller must have verified AVX2 support ([`avx2`]).
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn gates_input_avx2(
        w_x0: &[f32],
        w_x1: &[f32],
        b_ih: &[f32],
        b: usize,
        x0: &[f32],
        x1: &[f32],
        out: &mut [f32],
    ) {
        for j in 0..w_x0.len() {
            let (w0, w1, bj) = (w_x0[j], w_x1[j], b_ih[j]);
            let orow = &mut out[j * b..(j + 1) * b];
            let w0v = _mm256_set1_ps(w0);
            let w1v = _mm256_set1_ps(w1);
            let bjv = _mm256_set1_ps(bj);
            let mut lane = 0;
            while lane + 8 <= b {
                let a0 = _mm256_loadu_ps(x0.as_ptr().add(lane));
                let a1 = _mm256_loadu_ps(x1.as_ptr().add(lane));
                let v = _mm256_add_ps(
                    _mm256_add_ps(_mm256_mul_ps(w0v, a0), _mm256_mul_ps(w1v, a1)),
                    bjv,
                );
                _mm256_storeu_ps(orow.as_mut_ptr().add(lane), v);
                lane += 8;
            }
            while lane < b {
                *orow.get_unchecked_mut(lane) =
                    w0 * *x0.get_unchecked(lane) + w1 * *x1.get_unchecked(lane) + bj;
                lane += 1;
            }
        }
    }
}
