//! Pure-Rust BiGRU forward pass, numerically identical (to f32 rounding)
//! to the JAX model in `python/compile/model.py`.
//!
//! Gate convention (torch order r, z, n):
//! ```text
//! r = σ(W_ir·x + b_ir + W_hr·h + b_hr)
//! z = σ(W_iz·x + b_iz + W_hz·h + b_hz)
//! n = tanh(W_in·x + b_in + r ⊙ (W_hn·h + b_hn))
//! h' = (1 − z) ⊙ n + z ⊙ h
//! ```
//! The head is a linear layer over the concatenated [fwd, bwd] hidden
//! state followed by softmax over `k_max` logits.
//!
//! Two execution paths share the packed parameter blocks built once in
//! [`NativeBiGru::new`]:
//!
//! * the sequential path here ([`NativeBiGru::probs_into`], one server at a
//!   time, all scratch supplied by a reusable [`ScratchArena`]);
//! * the rack-batched path in [`super::batch`]
//!   ([`NativeBiGru::probs_batch_into`]) that scans B servers in lockstep
//!   and is **bit-identical** per lane to this sequential path (see the
//!   accumulation-order contract on the private `dot` helper).

use super::batch::ScratchArena;
use super::{scale_features, StateClassifier};
use anyhow::{ensure, Result};

/// Flat BiGRU parameters (layout in DESIGN.md §6).
#[derive(Debug, Clone)]
pub struct BiGruWeights {
    pub h: usize,
    pub k_max: usize,
    pub flat: Vec<f32>,
}

/// One direction's parameter block, repacked for the scan loops: the tiny
/// `W_ih [3H, 2]` is transposed into its two columns (so the input-gate
/// update is two broadcast FMAs), and the recurrent block is a contiguous
/// row-major copy so neither path recomputes flat-vector offsets per step.
#[derive(Debug, Clone)]
pub(crate) struct PackedDir {
    /// Column 0 of `W_ih` (the `A_t` feature), `[3H]`.
    pub(crate) w_x0: Vec<f32>,
    /// Column 1 of `W_ih` (the `ΔA_t` feature), `[3H]`.
    pub(crate) w_x1: Vec<f32>,
    pub(crate) b_ih: Vec<f32>,
    /// `W_hh` row-major `[3H, H]`. Kept row-major deliberately: the
    /// bit-identity contract fixes the dot-product accumulation order along
    /// H (see [`dot`]), which a column-major transpose would re-associate.
    pub(crate) w_hh: Vec<f32>,
    pub(crate) b_hh: Vec<f32>,
}

/// All parameters repacked for execution, built once per configuration and
/// cached (via the classifier held on `coordinator::PreparedConfig`) for
/// every subsequent `probs` / `probs_batch` call.
#[derive(Debug, Clone)]
pub(crate) struct PackedWeights {
    pub(crate) h: usize,
    pub(crate) k_max: usize,
    /// `[forward, backward]` direction blocks.
    pub(crate) dirs: [PackedDir; 2],
    /// Head weights `[k_max, 2H]` row-major (fwd half then bwd half).
    pub(crate) w_head: Vec<f32>,
    pub(crate) b_head: Vec<f32>,
}

/// Borrowed views into one direction's parameter block of the flat vector.
struct DirView<'a> {
    w_ih: &'a [f32], // [3H, 2] row-major
    b_ih: &'a [f32], // [3H]
    w_hh: &'a [f32], // [3H, H] row-major
    b_hh: &'a [f32], // [3H]
}

impl BiGruWeights {
    pub fn new(h: usize, k_max: usize, flat: Vec<f32>) -> Result<BiGruWeights> {
        let expect = super::flat_param_count(h, k_max);
        ensure!(flat.len() == expect, "expected {expect} params, got {}", flat.len());
        ensure!(flat.iter().all(|x| x.is_finite()), "non-finite weight");
        Ok(BiGruWeights { h, k_max, flat })
    }

    fn dir(&self, d: usize) -> DirView<'_> {
        let h = self.h;
        let block = 3 * h * 2 + 3 * h + 3 * h * h + 3 * h;
        let base = d * block;
        let mut o = base;
        let mut take = |n: usize| {
            let s = &self.flat[o..o + n];
            o += n;
            s
        };
        DirView {
            w_ih: take(3 * h * 2),
            b_ih: take(3 * h),
            w_hh: take(3 * h * h),
            b_hh: take(3 * h),
        }
    }

    fn head(&self) -> (&[f32], &[f32]) {
        let h = self.h;
        let block = 3 * h * 2 + 3 * h + 3 * h * h + 3 * h;
        let base = 2 * block;
        let w = &self.flat[base..base + self.k_max * 2 * h];
        let b = &self.flat[base + self.k_max * 2 * h..];
        (w, b)
    }

    fn pack(&self) -> PackedWeights {
        let pack_dir = |v: &DirView<'_>| PackedDir {
            w_x0: (0..3 * self.h).map(|j| v.w_ih[2 * j]).collect(),
            w_x1: (0..3 * self.h).map(|j| v.w_ih[2 * j + 1]).collect(),
            b_ih: v.b_ih.to_vec(),
            w_hh: v.w_hh.to_vec(),
            b_hh: v.b_hh.to_vec(),
        };
        let (w_head, b_head) = self.head();
        PackedWeights {
            h: self.h,
            k_max: self.k_max,
            dirs: [pack_dir(&self.dir(0)), pack_dir(&self.dir(1))],
            w_head: w_head.to_vec(),
            b_head: b_head.to_vec(),
        }
    }
}

/// Native backend.
#[derive(Debug, Clone)]
pub struct NativeBiGru {
    pub weights: BiGruWeights,
    pub(crate) packed: PackedWeights,
}

impl NativeBiGru {
    pub fn new(weights: BiGruWeights) -> NativeBiGru {
        let packed = weights.pack();
        NativeBiGru { weights, packed }
    }

    /// Run one direction over scaled features, writing hidden states into
    /// `hs` (row t = h_t, length T*H). `reverse` scans right-to-left.
    /// All scratch (`hidden`, `gates_i`, `gates_h`) is caller-supplied so
    /// the scan performs zero allocations.
    fn scan_direction(
        &self,
        xs: &[f32],
        t_len: usize,
        dir: usize,
        reverse: bool,
        hidden: &mut [f32],
        gates_i: &mut [f32],
        gates_h: &mut [f32],
        hs: &mut [f32],
    ) {
        let h = self.packed.h;
        let d = &self.packed.dirs[dir];
        hidden.fill(0.0);
        for i in 0..t_len {
            let t = if reverse { t_len - 1 - i } else { i };
            let x0 = xs[2 * t];
            let x1 = xs[2 * t + 1];
            // gates_i = W_ih · x + b_ih  (input dim fixed at 2)
            for j in 0..3 * h {
                gates_i[j] = d.w_x0[j] * x0 + d.w_x1[j] * x1 + d.b_ih[j];
            }
            // gates_h = W_hh · h + b_hh
            gemv_3h(&d.w_hh, hidden, &d.b_hh, h, gates_h);
            for j in 0..h {
                let r = sigmoid(gates_i[j] + gates_h[j]);
                let z = sigmoid(gates_i[h + j] + gates_h[h + j]);
                let n = (gates_i[2 * h + j] + r * gates_h[2 * h + j]).tanh();
                hidden[j] = (1.0 - z) * n + z * hidden[j];
            }
            hs[t * h..(t + 1) * h].copy_from_slice(hidden);
        }
    }

    /// Sequential `probs` writing into a caller-owned output with all
    /// intermediate buffers drawn from `scratch` — the zero-allocation form
    /// the coordinator drives with one arena per worker thread.
    pub fn probs_into(
        &self,
        features: &[f32],
        t_len: usize,
        scratch: &mut ScratchArena,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        ensure!(features.len() == 2 * t_len, "features length mismatch");
        let h = self.packed.h;
        let k = self.packed.k_max;
        let ScratchArena { xs, h_fwd, h_bwd, hidden, gates_i, gates_h, logits, .. } = scratch;
        resize(xs, 2 * t_len);
        resize(h_fwd, t_len * h);
        resize(h_bwd, t_len * h);
        resize(hidden, h);
        resize(gates_i, 3 * h);
        resize(gates_h, 3 * h);
        resize(logits, k);
        // Feature transform (matches the JAX model exactly).
        for t in 0..t_len {
            let (fa, fda) = scale_features(features[2 * t], features[2 * t + 1]);
            xs[2 * t] = fa;
            xs[2 * t + 1] = fda;
        }
        self.scan_direction(xs, t_len, 0, false, hidden, gates_i, gates_h, h_fwd);
        self.scan_direction(xs, t_len, 1, true, hidden, gates_i, gates_h, h_bwd);

        let (w_head, b_head) = (&self.packed.w_head, &self.packed.b_head);
        out.clear();
        out.resize(t_len * k, 0.0);
        for t in 0..t_len {
            let hf = &h_fwd[t * h..(t + 1) * h];
            let hb = &h_bwd[t * h..(t + 1) * h];
            for (j, l) in logits.iter_mut().enumerate() {
                let row = &w_head[j * 2 * h..(j + 1) * 2 * h];
                *l = b_head[j] + dot(&row[..h], hf) + dot(&row[h..], hb);
            }
            softmax_into(logits, &mut out[t * k..(t + 1) * k]);
        }
        Ok(())
    }
}

/// Set a scratch vector's length (contents need not be preserved).
#[inline]
pub(crate) fn resize(v: &mut Vec<f32>, n: usize) {
    v.clear();
    v.resize(n, 0.0);
}

/// out = W[3H, H] · h + b, row-major W.
///
/// The inner dot product is written over `chunks_exact(8)` with independent
/// partial sums so LLVM vectorizes it to AVX FMA lanes (H = 64 → 8 chunks);
/// this is the hot loop of the whole generation pipeline (§Perf).
#[inline]
fn gemv_3h(w: &[f32], hidden: &[f32], b: &[f32], h: usize, out: &mut [f32]) {
    for j in 0..3 * h {
        let row = &w[j * h..(j + 1) * h];
        out[j] = dot(row, hidden) + b[j];
    }
}

/// Reference dot product: 8 independent partial sums over `chunks_exact(8)`
/// folded left-to-right (starting from 0.0), then the remainder in order.
///
/// This accumulation order is a **contract**: the batched GEMM in
/// [`super::batch`] reproduces it per lane so batched and sequential
/// posteriors are bit-identical. Change one only with the other.
#[inline]
pub(crate) fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 8];
    let (ca, ra) = a.split_at(a.len() - a.len() % 8);
    let (cb, rb) = b.split_at(ca.len());
    for (xs, ys) in ca.chunks_exact(8).zip(cb.chunks_exact(8)) {
        for l in 0..8 {
            acc[l] += xs[l] * ys[l];
        }
    }
    let mut total: f32 = acc.iter().sum();
    for (x, y) in ra.iter().zip(rb) {
        total += x * y;
    }
    total
}

#[inline]
pub(crate) fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

impl StateClassifier for NativeBiGru {
    fn k_max(&self) -> usize {
        self.weights.k_max
    }

    fn probs(&self, features: &[f32], t_len: usize) -> Result<Vec<f32>> {
        let mut scratch = ScratchArena::new();
        let mut out = Vec::new();
        self.probs_into(features, t_len, &mut scratch, &mut out)?;
        Ok(out)
    }

    fn probs_batch(&self, features: &[&[f32]], t_len: usize) -> Result<Vec<f32>> {
        let mut scratch = ScratchArena::new();
        let mut out = Vec::new();
        self.probs_batch_into(features, t_len, &mut scratch, &mut out)?;
        Ok(out)
    }
}

pub(crate) fn softmax_into(logits: &[f32], out: &mut [f32]) {
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut total = 0.0f32;
    for (o, &l) in out.iter_mut().zip(logits) {
        let e = (l - m).exp();
        *o = e;
        total += e;
    }
    for o in out.iter_mut() {
        *o /= total;
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::classifier::{flat_param_count, HIDDEN, K_MAX};
    use crate::util::rng::Rng;

    /// Random weights with sensible scale for tests.
    pub fn random_weights(seed: u64) -> BiGruWeights {
        random_weights_hk(HIDDEN, K_MAX, seed)
    }

    /// Random weights for an arbitrary (hidden, k_max) geometry.
    pub fn random_weights_hk(h: usize, k_max: usize, seed: u64) -> BiGruWeights {
        let mut rng = Rng::new(seed);
        let n = flat_param_count(h, k_max);
        let flat: Vec<f32> = (0..n).map(|_| (rng.normal() * 0.12) as f32).collect();
        BiGruWeights::new(h, k_max, flat).unwrap()
    }

    /// Random feature sequence resembling real (A, ΔA) traces.
    pub fn random_features(t_len: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut a = 0.0f32;
        let mut out = Vec::with_capacity(2 * t_len);
        for _ in 0..t_len {
            let da = (rng.below(5) as i32 - 2).max(-(a as i32)) as f32;
            a += da;
            out.push(a);
            out.push(da);
        }
        out
    }

    #[test]
    fn output_shape_and_normalization() {
        let model = NativeBiGru::new(random_weights(1));
        let xs = random_features(37, 2);
        let p = model.probs(&xs, 37).unwrap();
        assert_eq!(p.len(), 37 * K_MAX);
        for row in p.chunks(K_MAX) {
            let total: f32 = row.iter().sum();
            assert!((total - 1.0).abs() < 1e-5, "row sums to {total}");
            assert!(row.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn deterministic() {
        let model = NativeBiGru::new(random_weights(3));
        let xs = random_features(50, 4);
        assert_eq!(model.probs(&xs, 50).unwrap(), model.probs(&xs, 50).unwrap());
    }

    #[test]
    fn probs_into_reuses_scratch_and_matches_probs() {
        let model = NativeBiGru::new(random_weights(21));
        let mut scratch = ScratchArena::new();
        let mut out = Vec::new();
        for (t_len, seed) in [(40usize, 22u64), (7, 23), (40, 24)] {
            let xs = random_features(t_len, seed);
            model.probs_into(&xs, t_len, &mut scratch, &mut out).unwrap();
            assert_eq!(out, model.probs(&xs, t_len).unwrap(), "t_len {t_len}");
        }
    }

    #[test]
    fn bidirectional_context_affects_early_timesteps() {
        // Changing only the last feature must change the first timestep's
        // posterior (the backward pass carries it) — a pure causal model
        // would not.
        let model = NativeBiGru::new(random_weights(5));
        let t_len = 8;
        let mut xs = random_features(t_len, 6);
        let p1 = model.probs(&xs, t_len).unwrap();
        xs[2 * (t_len - 1)] += 40.0; // bump A at the last step
        let p2 = model.probs(&xs, t_len).unwrap();
        let d0: f32 = (0..K_MAX).map(|j| (p1[j] - p2[j]).abs()).sum();
        assert!(d0 > 1e-6, "first-step posterior unchanged: {d0}");
    }

    #[test]
    fn rejects_bad_inputs() {
        let model = NativeBiGru::new(random_weights(7));
        assert!(model.probs(&[1.0, 2.0, 3.0], 2).is_err());
        assert!(BiGruWeights::new(HIDDEN, K_MAX, vec![0.0; 10]).is_err());
        let mut flat = vec![0.0f32; flat_param_count(HIDDEN, K_MAX)];
        flat[0] = f32::NAN;
        assert!(BiGruWeights::new(HIDDEN, K_MAX, flat).is_err());
    }

    #[test]
    fn packing_preserves_parameter_blocks() {
        let w = random_weights_hk(3, 2, 31);
        let packed = w.pack();
        for d in 0..2 {
            let v = w.dir(d);
            let p = &packed.dirs[d];
            for j in 0..3 * w.h {
                assert_eq!(p.w_x0[j], v.w_ih[2 * j]);
                assert_eq!(p.w_x1[j], v.w_ih[2 * j + 1]);
            }
            assert_eq!(p.b_ih, v.b_ih);
            assert_eq!(p.w_hh, v.w_hh);
            assert_eq!(p.b_hh, v.b_hh);
        }
        let (wh, bh) = w.head();
        assert_eq!(packed.w_head, wh);
        assert_eq!(packed.b_head, bh);
    }

    #[test]
    fn hand_computed_tiny_gru() {
        // H=1, K=1 analytic check. Layout per direction:
        // w_ih [3,2], b_ih [3], w_hh [3,1], b_hh [3]; head w [1,2], b [1].
        let h = 1;
        let k = 1;
        let mut flat = Vec::new();
        // forward dir: w_ih rows r,z,n
        flat.extend([0.0, 0.0, 0.0, 0.0, 1.0, 0.0]); // w_ih: n gate reads x0
        flat.extend([0.0, 0.0, 0.0]); // b_ih
        flat.extend([0.0, 0.0, 0.0]); // w_hh
        flat.extend([0.0, 0.0, 0.0]); // b_hh
        // backward dir: all zeros
        flat.extend(vec![0.0; 6 + 3 + 3 + 3]);
        // head: w [1,2] = [1, 0], b = [0]
        flat.extend([1.0, 0.0, 0.0]);
        assert_eq!(flat.len(), flat_param_count(h, k));
        let w = BiGruWeights::new(h, k, flat).unwrap();
        let model = NativeBiGru::new(w);
        // Single timestep, x = (A=64, dA=0) → scaled x0 = log1p(64)/2.
        let p = model.probs(&[64.0, 0.0], 1).unwrap();
        // K=1 → softmax is 1.0 regardless; instead check via hidden by
        // swapping the head to read h directly... K=1 softmax collapses, so
        // just assert normalization here.
        assert_eq!(p, vec![1.0]);
    }

    #[test]
    fn gru_cell_matches_manual_two_state() {
        // H=1, K=2: head reads h_fwd into logit 0 and 0 into logit 1 so we
        // can recover tanh-level values through the softmax.
        let h = 1;
        let k = 2;
        let mut flat = Vec::new();
        flat.extend([0.0, 0.0, 0.0, 0.0, 1.0, 0.0]); // fwd w_ih (n reads x0)
        flat.extend([0.0, 0.0, 0.0]);
        flat.extend([0.0, 0.0, 0.0]);
        flat.extend([0.0, 0.0, 0.0]);
        flat.extend(vec![0.0; 15]); // bwd all zero
        flat.extend([1.0, 0.0, 0.0, 0.0]); // head w [2,2]: logit0 = h_fwd
        flat.extend([0.0, 0.0]); // head b
        assert_eq!(flat.len(), flat_param_count(h, k));
        let model = NativeBiGru::new(BiGruWeights::new(h, k, flat).unwrap());
        let p = model.probs(&[64.0, 0.0], 1).unwrap();
        // x0 = log1p(64)/2; h_fwd = 0.5·tanh(x0); logits = [h_fwd, 0]
        let x0 = (65.0f32).ln() * 0.5;
        let expected0 = 1.0 / (1.0 + (-0.5f32 * x0.tanh()).exp());
        assert!((p[0] - expected0).abs() < 1e-5, "{} vs {expected0}", p[0]);
    }
}
