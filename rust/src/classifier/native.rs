//! Pure-Rust BiGRU forward pass, numerically identical (to f32 rounding)
//! to the JAX model in `python/compile/model.py`.
//!
//! Gate convention (torch order r, z, n):
//! ```text
//! r = σ(W_ir·x + b_ir + W_hr·h + b_hr)
//! z = σ(W_iz·x + b_iz + W_hz·h + b_hz)
//! n = tanh(W_in·x + b_in + r ⊙ (W_hn·h + b_hn))
//! h' = (1 − z) ⊙ n + z ⊙ h
//! ```
//! The head is a linear layer over the concatenated [fwd, bwd] hidden
//! state followed by softmax over `k_max` logits.

use super::{scale_features, StateClassifier};
use anyhow::{ensure, Result};

/// Flat BiGRU parameters (layout in DESIGN.md §6).
#[derive(Debug, Clone)]
pub struct BiGruWeights {
    pub h: usize,
    pub k_max: usize,
    pub flat: Vec<f32>,
}

/// Borrowed views into one direction's parameter block.
struct DirView<'a> {
    w_ih: &'a [f32], // [3H, 2] row-major
    b_ih: &'a [f32], // [3H]
    w_hh: &'a [f32], // [3H, H] row-major
    b_hh: &'a [f32], // [3H]
}

impl BiGruWeights {
    pub fn new(h: usize, k_max: usize, flat: Vec<f32>) -> Result<BiGruWeights> {
        let expect = super::flat_param_count(h, k_max);
        ensure!(flat.len() == expect, "expected {expect} params, got {}", flat.len());
        ensure!(flat.iter().all(|x| x.is_finite()), "non-finite weight");
        Ok(BiGruWeights { h, k_max, flat })
    }

    fn dir(&self, d: usize) -> DirView<'_> {
        let h = self.h;
        let block = 3 * h * 2 + 3 * h + 3 * h * h + 3 * h;
        let base = d * block;
        let mut o = base;
        let mut take = |n: usize| {
            let s = &self.flat[o..o + n];
            o += n;
            s
        };
        DirView {
            w_ih: take(3 * h * 2),
            b_ih: take(3 * h),
            w_hh: take(3 * h * h),
            b_hh: take(3 * h),
        }
    }

    fn head(&self) -> (&[f32], &[f32]) {
        let h = self.h;
        let block = 3 * h * 2 + 3 * h + 3 * h * h + 3 * h;
        let base = 2 * block;
        let w = &self.flat[base..base + self.k_max * 2 * h];
        let b = &self.flat[base + self.k_max * 2 * h..];
        (w, b)
    }
}

/// Native backend.
#[derive(Debug, Clone)]
pub struct NativeBiGru {
    pub weights: BiGruWeights,
}

impl NativeBiGru {
    pub fn new(weights: BiGruWeights) -> NativeBiGru {
        NativeBiGru { weights }
    }

    /// Run one direction over scaled features, writing hidden states into
    /// `hs` (row t = h_t, length T*H). `reverse` scans right-to-left.
    fn scan_direction(&self, xs: &[f32], t_len: usize, dir: usize, reverse: bool, hs: &mut [f32]) {
        let h = self.weights.h;
        let v = self.weights.dir(dir);
        let mut hidden = vec![0.0f32; h];
        let mut gates_i = vec![0.0f32; 3 * h];
        let mut gates_h = vec![0.0f32; 3 * h];
        let steps: Box<dyn Iterator<Item = usize>> = if reverse {
            Box::new((0..t_len).rev())
        } else {
            Box::new(0..t_len)
        };
        for t in steps {
            let x0 = xs[2 * t];
            let x1 = xs[2 * t + 1];
            // gates_i = W_ih · x + b_ih  (input dim fixed at 2)
            for j in 0..3 * h {
                gates_i[j] = v.w_ih[2 * j] * x0 + v.w_ih[2 * j + 1] * x1 + v.b_ih[j];
            }
            // gates_h = W_hh · h + b_hh
            gemv_3h(v.w_hh, &hidden, v.b_hh, h, &mut gates_h);
            for j in 0..h {
                let r = sigmoid(gates_i[j] + gates_h[j]);
                let z = sigmoid(gates_i[h + j] + gates_h[h + j]);
                let n = (gates_i[2 * h + j] + r * gates_h[2 * h + j]).tanh();
                hidden[j] = (1.0 - z) * n + z * hidden[j];
            }
            hs[t * h..(t + 1) * h].copy_from_slice(&hidden);
        }
    }
}

/// out = W[3H, H] · h + b, row-major W.
///
/// The inner dot product is written over `chunks_exact(8)` with independent
/// partial sums so LLVM vectorizes it to AVX FMA lanes (H = 64 → 8 chunks);
/// this is the hot loop of the whole generation pipeline (§Perf).
#[inline]
fn gemv_3h(w: &[f32], hidden: &[f32], b: &[f32], h: usize, out: &mut [f32]) {
    for j in 0..3 * h {
        let row = &w[j * h..(j + 1) * h];
        out[j] = dot(row, hidden) + b[j];
    }
}

#[inline]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 8];
    let (ca, ra) = a.split_at(a.len() - a.len() % 8);
    let (cb, rb) = b.split_at(ca.len());
    for (xs, ys) in ca.chunks_exact(8).zip(cb.chunks_exact(8)) {
        for l in 0..8 {
            acc[l] += xs[l] * ys[l];
        }
    }
    let mut total: f32 = acc.iter().sum();
    for (x, y) in ra.iter().zip(rb) {
        total += x * y;
    }
    total
}

#[inline]
fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

impl StateClassifier for NativeBiGru {
    fn k_max(&self) -> usize {
        self.weights.k_max
    }

    fn probs(&self, features: &[f32], t_len: usize) -> Result<Vec<f32>> {
        ensure!(features.len() == 2 * t_len, "features length mismatch");
        let h = self.weights.h;
        let k = self.weights.k_max;
        // Feature transform (matches the JAX model exactly).
        let mut xs = vec![0.0f32; 2 * t_len];
        for t in 0..t_len {
            let (fa, fda) = scale_features(features[2 * t], features[2 * t + 1]);
            xs[2 * t] = fa;
            xs[2 * t + 1] = fda;
        }
        let mut h_fwd = vec![0.0f32; t_len * h];
        let mut h_bwd = vec![0.0f32; t_len * h];
        self.scan_direction(&xs, t_len, 0, false, &mut h_fwd);
        self.scan_direction(&xs, t_len, 1, true, &mut h_bwd);

        let (w_head, b_head) = self.weights.head();
        let mut out = vec![0.0f32; t_len * k];
        let mut logits = vec![0.0f32; k];
        for t in 0..t_len {
            let hf = &h_fwd[t * h..(t + 1) * h];
            let hb = &h_bwd[t * h..(t + 1) * h];
            for (j, l) in logits.iter_mut().enumerate() {
                let row = &w_head[j * 2 * h..(j + 1) * 2 * h];
                *l = b_head[j] + dot(&row[..h], hf) + dot(&row[h..], hb);
            }
            softmax_into(&logits, &mut out[t * k..(t + 1) * k]);
        }
        Ok(out)
    }
}

fn softmax_into(logits: &[f32], out: &mut [f32]) {
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut total = 0.0f32;
    for (o, &l) in out.iter_mut().zip(logits) {
        let e = (l - m).exp();
        *o = e;
        total += e;
    }
    for o in out.iter_mut() {
        *o /= total;
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::classifier::{flat_param_count, HIDDEN, K_MAX};
    use crate::util::rng::Rng;

    /// Random weights with sensible scale for tests.
    pub fn random_weights(seed: u64) -> BiGruWeights {
        let mut rng = Rng::new(seed);
        let n = flat_param_count(HIDDEN, K_MAX);
        let flat: Vec<f32> = (0..n).map(|_| (rng.normal() * 0.12) as f32).collect();
        BiGruWeights::new(HIDDEN, K_MAX, flat).unwrap()
    }

    /// Random feature sequence resembling real (A, ΔA) traces.
    pub fn random_features(t_len: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut a = 0.0f32;
        let mut out = Vec::with_capacity(2 * t_len);
        for _ in 0..t_len {
            let da = (rng.below(5) as i32 - 2).max(-(a as i32)) as f32;
            a += da;
            out.push(a);
            out.push(da);
        }
        out
    }

    #[test]
    fn output_shape_and_normalization() {
        let model = NativeBiGru::new(random_weights(1));
        let xs = random_features(37, 2);
        let p = model.probs(&xs, 37).unwrap();
        assert_eq!(p.len(), 37 * K_MAX);
        for row in p.chunks(K_MAX) {
            let total: f32 = row.iter().sum();
            assert!((total - 1.0).abs() < 1e-5, "row sums to {total}");
            assert!(row.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn deterministic() {
        let model = NativeBiGru::new(random_weights(3));
        let xs = random_features(50, 4);
        assert_eq!(model.probs(&xs, 50).unwrap(), model.probs(&xs, 50).unwrap());
    }

    #[test]
    fn bidirectional_context_affects_early_timesteps() {
        // Changing only the last feature must change the first timestep's
        // posterior (the backward pass carries it) — a pure causal model
        // would not.
        let model = NativeBiGru::new(random_weights(5));
        let t_len = 8;
        let mut xs = random_features(t_len, 6);
        let p1 = model.probs(&xs, t_len).unwrap();
        xs[2 * (t_len - 1)] += 40.0; // bump A at the last step
        let p2 = model.probs(&xs, t_len).unwrap();
        let d0: f32 = (0..K_MAX).map(|j| (p1[j] - p2[j]).abs()).sum();
        assert!(d0 > 1e-6, "first-step posterior unchanged: {d0}");
    }

    #[test]
    fn rejects_bad_inputs() {
        let model = NativeBiGru::new(random_weights(7));
        assert!(model.probs(&[1.0, 2.0, 3.0], 2).is_err());
        assert!(BiGruWeights::new(HIDDEN, K_MAX, vec![0.0; 10]).is_err());
        let mut flat = vec![0.0f32; flat_param_count(HIDDEN, K_MAX)];
        flat[0] = f32::NAN;
        assert!(BiGruWeights::new(HIDDEN, K_MAX, flat).is_err());
    }

    #[test]
    fn hand_computed_tiny_gru() {
        // H=1, K=1 analytic check. Layout per direction:
        // w_ih [3,2], b_ih [3], w_hh [3,1], b_hh [3]; head w [1,2], b [1].
        let h = 1;
        let k = 1;
        let mut flat = Vec::new();
        // forward dir: w_ih rows r,z,n
        flat.extend([0.0, 0.0, 0.0, 0.0, 1.0, 0.0]); // w_ih: n gate reads x0
        flat.extend([0.0, 0.0, 0.0]); // b_ih
        flat.extend([0.0, 0.0, 0.0]); // w_hh
        flat.extend([0.0, 0.0, 0.0]); // b_hh
        // backward dir: all zeros
        flat.extend(vec![0.0; 6 + 3 + 3 + 3]);
        // head: w [1,2] = [1, 0], b = [0]
        flat.extend([1.0, 0.0, 0.0]);
        assert_eq!(flat.len(), flat_param_count(h, k));
        let w = BiGruWeights::new(h, k, flat).unwrap();
        let model = NativeBiGru { weights: w };
        // Single timestep, x = (A=64, dA=0) → scaled x0 = log1p(64)/2.
        let p = model.probs(&[64.0, 0.0], 1).unwrap();
        // K=1 → softmax is 1.0 regardless; instead check via hidden by
        // swapping the head to read h directly... K=1 softmax collapses, so
        // just assert normalization here.
        assert_eq!(p, vec![1.0]);
    }

    #[test]
    fn gru_cell_matches_manual_two_state() {
        // H=1, K=2: head reads h_fwd into logit 0 and 0 into logit 1 so we
        // can recover tanh-level values through the softmax.
        let h = 1;
        let k = 2;
        let mut flat = Vec::new();
        flat.extend([0.0, 0.0, 0.0, 0.0, 1.0, 0.0]); // fwd w_ih (n reads x0)
        flat.extend([0.0, 0.0, 0.0]);
        flat.extend([0.0, 0.0, 0.0]);
        flat.extend([0.0, 0.0, 0.0]);
        flat.extend(vec![0.0; 15]); // bwd all zero
        flat.extend([1.0, 0.0, 0.0, 0.0]); // head w [2,2]: logit0 = h_fwd
        flat.extend([0.0, 0.0]); // head b
        assert_eq!(flat.len(), flat_param_count(h, k));
        let model = NativeBiGru { weights: BiGruWeights::new(h, k, flat).unwrap() };
        let p = model.probs(&[64.0, 0.0], 1).unwrap();
        // x0 = log1p(64)/2; h_fwd = 0.5·tanh(x0); logits = [h_fwd, 0]
        let x0 = (65.0f32).ln() * 0.5;
        let expected0 = 1.0 / (1.0 + (-0.5f32 * x0.tanh()).exp());
        assert!((p[0] - expected0).abs() < 1e-5, "{} vs {expected0}", p[0]);
    }
}
