//! Rack-batched BiGRU inference (§Perf — batched execution model).
//!
//! [`NativeBiGru::probs_batch_tiled`] scans B independent, equal-length
//! server sequences in lockstep: each timestep's recurrent update becomes a
//! `[3H, H] × [H, B]` GEMM whose inner loops are vectorized over the lane
//! dimension, so every weight load is amortized across B servers instead of
//! one. The head projection + softmax are fused into the forward sweep and
//! emitted tile-by-tile through a sink callback, so facility generation can
//! sample states as posteriors stream out without materializing the full
//! `[T, B, K]` tensor.
//!
//! ## Resumable scans (streaming horizons)
//!
//! Since the streaming-horizon work the tiled scan is **resumable**: a
//! [`BatchScan`] captures everything that must survive between time
//! windows — the forward carry `[H, B]` and the backward carry recorded at
//! each window boundary during one right-to-left prologue sweep
//! ([`NativeBiGru::begin_batch_scan`]) — and
//! [`NativeBiGru::scan_window`] then emits any window's posteriors on
//! demand, in increasing-time order. Features are pulled through the
//! [`LaneFeatures`] source trait, so callers with bounded memory (the
//! windowed facility pipeline) can rebuild each window's features from a
//! compressed event list instead of holding `[T, 2]` per lane.
//! `probs_batch_tiled` is a thin driver over the same two functions
//! (window = tile), so the one-shot and windowed paths share one
//! arithmetic path — their bit-identity is structural, not coincidental.
//!
//! ## Bit-identity contract
//!
//! Batching is only admissible in the facility pipeline because it is
//! **bit-identical** to the sequential path (the rack-granular
//! deterministic fold relies on byte-stable per-server traces). Every lane
//! therefore reproduces the sequential accumulation order exactly:
//!
//! * recurrent/head dot products keep 8 independent partial sums over
//!   `H`-chunks of 8, folded left-to-right from 0.0, then the remainder in
//!   order — the same schedule as the sequential `native::dot`;
//! * gate and state updates evaluate the same scalar expressions per lane;
//! * the head logit is `(b + dot_fwd) + dot_bwd`, as in the sequential
//!   head loop.
//!
//! With `--features simd` (and AVX2 detected at runtime) the lane loops of
//! the GEMM, head-projection, and input-gate kernels execute as explicit
//! f32x8 intrinsics — same schedule, eight lanes per instruction; see
//! `classifier/simd.rs` for why this cannot change bits.
//!
//! ## Memory: checkpointed backward scan
//!
//! A naive batched BiGRU stores `[T, H, B]` backward hidden states — 1.4 GB
//! per worker for a 24 h × 250 ms horizon at B = 16. Instead the backward
//! direction runs as a prologue sweep that only records the carry entering
//! each window (`[n_windows, H, B]`, owned by the [`BatchScan`]), then each
//! window recomputes its backward states from that checkpoint — in
//! sub-tiles of at most [`BATCH_TILE`] steps, so scratch stays
//! O(BATCH_TILE · H · B) even for multi-hour windows (windows wider than
//! one sub-tile record transient sub-tile checkpoints first, costing one
//! extra backward pass over that window). Recomputation costs ≤ 0.5× extra
//! scan FLOPs for single-sub-tile windows (the `probs_batch_tiled` case)
//! and ≤ 1× for wider ones. All tilings are bit-identical because carried
//! states are exact.

use super::native::{resize, sigmoid, softmax_into, NativeBiGru, PackedDir};
use super::scale_features;
use anyhow::{ensure, Result};

/// Default time-tile length for the batched scan: horizons up to ~17 min at
/// 250 ms run un-tiled; longer horizons stay cache-resident per tile. Also
/// the sub-tile bound inside [`NativeBiGru::scan_window`].
pub const BATCH_TILE: usize = 4096;

/// Reusable scratch for classifier execution — one per worker thread.
///
/// Every buffer the sequential ([`NativeBiGru::probs_into`]) and batched
/// ([`NativeBiGru::probs_batch_tiled`]) paths need lives here, so steady-
/// state inference performs no heap allocation: buffers are `resize`d (a
/// no-op once warm) and overwritten. (The only exception is the
/// [`BatchScan`] carry state, which must outlive the call that created it
/// and is owned by the scan — ~`(n_windows + 1) · H · B` floats per rack
/// batch.)
#[derive(Debug, Default)]
pub struct ScratchArena {
    /// Scaled features: `[T, 2]` (sequential) or `[tile, 2, B]` (batched).
    pub(crate) xs: Vec<f32>,
    /// Sequential per-direction hidden-state history `[T, H]`.
    pub(crate) h_fwd: Vec<f32>,
    pub(crate) h_bwd: Vec<f32>,
    /// Carry state: `[H]` (sequential; the batched forward carry lives on
    /// the [`BatchScan`]).
    pub(crate) hidden: Vec<f32>,
    /// Batched backward carry `[H, B]`.
    pub(crate) hidden_b: Vec<f32>,
    /// Gate pre-activations: `[3H]` or `[3H, B]`.
    pub(crate) gates_i: Vec<f32>,
    pub(crate) gates_h: Vec<f32>,
    /// Partial-sum slots for the batched GEMM, `[8, B]`.
    pub(crate) acc: Vec<f32>,
    /// Head logits: `[k_max]` (sequential) or `[k_max, B]` (batched).
    pub(crate) logits: Vec<f32>,
    /// Per-lane head dot products, `[B]` each.
    pub(crate) head_f: Vec<f32>,
    pub(crate) head_b: Vec<f32>,
    /// One lane's gathered logits, `[k_max]`.
    pub(crate) logits_row: Vec<f32>,
    /// One lane's raw feature rows for the current sub-tile, `[sub, 2]`.
    pub(crate) feat_rows: Vec<f32>,
    /// Recomputed backward states for the current sub-tile, `[sub, H, B]`.
    pub(crate) bwd_tile: Vec<f32>,
    /// Window-local backward carry at each sub-tile boundary,
    /// `[n_sub, H, B]`.
    pub(crate) checkpoints: Vec<f32>,
    /// Posterior tile handed to the sink, `[sub, B, k_max]`.
    pub(crate) probs_tile: Vec<f32>,
}

impl ScratchArena {
    pub fn new() -> ScratchArena {
        ScratchArena::default()
    }
}

/// Per-lane `(A_t, ΔA_t)` feature source for the batched scan. The scan
/// only ever asks for sub-tile ranges (≤ [`BATCH_TILE`] steps), in
/// right-to-left order during the prologue and left-to-right during
/// window emission — a source may be a plain slice
/// ([`SliceFeatures`]) or a bounded-memory reconstruction (the windowed
/// facility pipeline rebuilds ranges from compressed occupancy events).
///
/// Implementations must be pure: the same `(lane, t0, n)` must always
/// yield the same bytes, or the recomputed backward states diverge from
/// the checkpoints and bit-identity is lost.
pub trait LaneFeatures {
    /// Number of lanes (batch width B).
    fn lanes(&self) -> usize;
    /// Write lane `lane`'s interleaved `[n, 2]` rows `(A_t, ΔA_t)` for
    /// timesteps `t0 .. t0 + n` into `out[..2*n]`.
    fn fill(&self, lane: usize, t0: usize, n: usize, out: &mut [f32]);
}

/// [`LaneFeatures`] over in-memory `[T, 2]` feature slices (one per lane).
pub struct SliceFeatures<'a>(pub &'a [&'a [f32]]);

impl LaneFeatures for SliceFeatures<'_> {
    fn lanes(&self) -> usize {
        self.0.len()
    }

    fn fill(&self, lane: usize, t0: usize, n: usize, out: &mut [f32]) {
        out[..2 * n].copy_from_slice(&self.0[lane][2 * t0..2 * (t0 + n)]);
    }
}

/// Resumable state of one batched scan: everything that must persist
/// between [`NativeBiGru::scan_window`] calls. Windows are emitted in
/// increasing-time order; the struct is cheap enough to hold per rack for
/// an entire streaming facility run (`(n_windows + 1) · H · B` floats).
#[derive(Debug)]
pub struct BatchScan {
    b: usize,
    t_len: usize,
    window: usize,
    n_windows: usize,
    next: usize,
    /// Forward carry `[H, B]`, advanced by each emitted window.
    hidden_fwd: Vec<f32>,
    /// Backward carry entering each window, `[n_windows, H, B]`, recorded
    /// by the prologue sweep.
    checkpoints: Vec<f32>,
}

impl BatchScan {
    /// Timestep where the next emitted window starts.
    pub fn next_t0(&self) -> usize {
        self.next * self.window
    }

    pub fn n_windows(&self) -> usize {
        self.n_windows
    }

    pub fn is_done(&self) -> bool {
        self.next >= self.n_windows
    }
}

impl NativeBiGru {
    /// Batched posteriors for `B = features.len()` equal-length sequences,
    /// written as `[T, B, k_max]` (time-major, lane, then state — each
    /// `(t, lane)` posterior row is contiguous). Bit-identical per lane to
    /// [`StateClassifier::probs`](super::StateClassifier::probs) on that
    /// lane's features.
    pub fn probs_batch_into(
        &self,
        features: &[&[f32]],
        t_len: usize,
        scratch: &mut ScratchArena,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let b = features.len();
        let k = self.weights.k_max;
        out.clear();
        out.resize(t_len * b * k, 0.0);
        self.probs_batch_tiled(features, t_len, BATCH_TILE, scratch, |t0, n, tile| {
            out[t0 * b * k..(t0 + n) * b * k].copy_from_slice(tile);
            Ok(())
        })
    }

    /// Streaming batched inference: posteriors are produced in time tiles of
    /// up to `tile` steps and handed to `sink(t0, n_rows, tile_probs)` where
    /// `tile_probs` is `[n_rows, B, k_max]` covering timesteps
    /// `t0 .. t0 + n_rows`. Tiles arrive in increasing-time order.
    ///
    /// The tile length only bounds scratch memory — any `tile ≥ 1` yields
    /// bit-identical posteriors (checkpointed carries are exact). This is a
    /// one-shot driver over [`NativeBiGru::begin_batch_scan`] +
    /// [`NativeBiGru::scan_window`] with `window = tile`.
    pub fn probs_batch_tiled<F>(
        &self,
        features: &[&[f32]],
        t_len: usize,
        tile: usize,
        scratch: &mut ScratchArena,
        mut sink: F,
    ) -> Result<()>
    where
        F: FnMut(usize, usize, &[f32]) -> Result<()>,
    {
        if features.is_empty() || t_len == 0 {
            return Ok(());
        }
        for (lane, f) in features.iter().enumerate() {
            ensure!(
                f.len() == 2 * t_len,
                "lane {lane}: features length {} != 2·{t_len}",
                f.len()
            );
        }
        let src = SliceFeatures(features);
        let mut scan = self.begin_batch_scan(&src, t_len, tile, scratch)?;
        while self.scan_window(&mut scan, &src, scratch, &mut sink)? > 0 {}
        Ok(())
    }

    /// Start a resumable batched scan over `t_len` steps split into windows
    /// of `window` steps: runs the right-to-left backward prologue (in
    /// sub-tiles of ≤ [`BATCH_TILE`], so scratch stays bounded for any
    /// window size), recording the backward carry entering each window.
    /// A single-window scan skips the sweep entirely — its only checkpoint
    /// is the zero initial state.
    pub fn begin_batch_scan<S: LaneFeatures>(
        &self,
        src: &S,
        t_len: usize,
        window: usize,
        scratch: &mut ScratchArena,
    ) -> Result<BatchScan> {
        let b = src.lanes();
        let pw = &self.packed;
        let h = pw.h;
        if b == 0 || t_len == 0 {
            return Ok(BatchScan {
                b,
                t_len,
                window: window.max(1),
                n_windows: 0,
                next: 0,
                hidden_fwd: Vec::new(),
                checkpoints: Vec::new(),
            });
        }
        let window = window.max(1).min(t_len);
        let n_windows = (t_len + window - 1) / window;
        let sub = window.min(BATCH_TILE);
        let mut scan = BatchScan {
            b,
            t_len,
            window,
            n_windows,
            next: 0,
            hidden_fwd: vec![0.0; h * b],
            checkpoints: vec![0.0; n_windows * h * b],
        };
        if n_windows > 1 {
            let ScratchArena { xs, hidden_b, gates_i, gates_h, acc, feat_rows, .. } = scratch;
            resize(xs, sub * 2 * b);
            resize(hidden_b, h * b);
            resize(gates_i, 3 * h * b);
            resize(gates_h, 3 * h * b);
            resize(acc, 8 * b);
            resize(feat_rows, 2 * sub);
            hidden_b.fill(0.0);
            for wi in (0..n_windows).rev() {
                let w0 = wi * window;
                let wn = (t_len - w0).min(window);
                scan.checkpoints[wi * h * b..(wi + 1) * h * b].copy_from_slice(hidden_b);
                let n_sub = (wn + sub - 1) / sub;
                for ti in (0..n_sub).rev() {
                    let t0 = w0 + ti * sub;
                    let n = (wn - ti * sub).min(sub);
                    scale_tile_src(src, t0, n, b, feat_rows, xs);
                    for rel in (0..n).rev() {
                        let x0 = &xs[(rel * 2) * b..(rel * 2 + 1) * b];
                        let x1 = &xs[(rel * 2 + 1) * b..(rel * 2 + 2) * b];
                        step_lanes(&pw.dirs[1], h, b, x0, x1, gates_i, gates_h, acc, hidden_b);
                    }
                }
            }
        }
        Ok(scan)
    }

    /// Emit the next window of posteriors through `sink(t0, n_rows, tile)`
    /// (`tile` is `[n_rows, B, k_max]`; a window wider than [`BATCH_TILE`]
    /// arrives as several consecutive sub-tiles). Returns the number of
    /// timesteps emitted — `0` when the scan is exhausted.
    ///
    /// Backward states for the window are recomputed from the window's
    /// prologue checkpoint; windows wider than one sub-tile first rerun a
    /// window-local right-to-left sweep to place transient sub-tile
    /// checkpoints (scratch `[n_sub, H, B]`), keeping resident backward
    /// state at O([`BATCH_TILE`] · H · B) for any window size.
    pub fn scan_window<S: LaneFeatures, F>(
        &self,
        scan: &mut BatchScan,
        src: &S,
        scratch: &mut ScratchArena,
        mut sink: F,
    ) -> Result<usize>
    where
        F: FnMut(usize, usize, &[f32]) -> Result<()>,
    {
        if scan.next >= scan.n_windows {
            return Ok(0);
        }
        ensure!(
            src.lanes() == scan.b,
            "scan_window: source has {} lanes, scan expects {}",
            src.lanes(),
            scan.b
        );
        let pw = &self.packed;
        let (h, k) = (pw.h, pw.k_max);
        let b = scan.b;
        let wi = scan.next;
        let w0 = wi * scan.window;
        let wn = (scan.t_len - w0).min(scan.window);
        let sub = scan.window.min(BATCH_TILE);
        let n_sub = (wn + sub - 1) / sub;

        let ScratchArena {
            xs,
            hidden_b,
            gates_i,
            gates_h,
            acc,
            logits,
            head_f,
            head_b,
            logits_row,
            feat_rows,
            bwd_tile,
            checkpoints,
            probs_tile,
            ..
        } = scratch;
        resize(xs, sub * 2 * b);
        resize(hidden_b, h * b);
        resize(gates_i, 3 * h * b);
        resize(gates_h, 3 * h * b);
        resize(acc, 8 * b);
        resize(logits, k * b);
        resize(head_f, b);
        resize(head_b, b);
        resize(logits_row, k);
        resize(feat_rows, 2 * sub);
        resize(bwd_tile, sub * h * b);
        resize(checkpoints, n_sub * h * b);
        resize(probs_tile, sub * b * k);

        let win_cp = &scan.checkpoints[wi * h * b..(wi + 1) * h * b];
        if n_sub == 1 {
            checkpoints[..h * b].copy_from_slice(win_cp);
        } else {
            // Window-local backward sweep: place sub-tile checkpoints.
            hidden_b.copy_from_slice(win_cp);
            for ti in (0..n_sub).rev() {
                let t0 = w0 + ti * sub;
                let n = (wn - ti * sub).min(sub);
                checkpoints[ti * h * b..(ti + 1) * h * b].copy_from_slice(hidden_b);
                scale_tile_src(src, t0, n, b, feat_rows, xs);
                for rel in (0..n).rev() {
                    let x0 = &xs[(rel * 2) * b..(rel * 2 + 1) * b];
                    let x1 = &xs[(rel * 2 + 1) * b..(rel * 2 + 2) * b];
                    step_lanes(&pw.dirs[1], h, b, x0, x1, gates_i, gates_h, acc, hidden_b);
                }
            }
        }
        let checkpoints = &*checkpoints;

        // Per sub-tile, left-to-right: recompute the backward states from
        // the sub-tile checkpoint, then run the fused forward + head +
        // softmax sweep and hand the posterior tile to the sink.
        let hidden_fwd = &mut scan.hidden_fwd;
        for ti in 0..n_sub {
            let t0 = w0 + ti * sub;
            let n = (wn - ti * sub).min(sub);
            scale_tile_src(src, t0, n, b, feat_rows, xs);
            hidden_b.copy_from_slice(&checkpoints[ti * h * b..(ti + 1) * h * b]);
            for rel in (0..n).rev() {
                let x0 = &xs[(rel * 2) * b..(rel * 2 + 1) * b];
                let x1 = &xs[(rel * 2 + 1) * b..(rel * 2 + 2) * b];
                step_lanes(&pw.dirs[1], h, b, x0, x1, gates_i, gates_h, acc, hidden_b);
                bwd_tile[rel * h * b..(rel + 1) * h * b].copy_from_slice(hidden_b);
            }
            for rel in 0..n {
                let x0 = &xs[(rel * 2) * b..(rel * 2 + 1) * b];
                let x1 = &xs[(rel * 2 + 1) * b..(rel * 2 + 2) * b];
                step_lanes(&pw.dirs[0], h, b, x0, x1, gates_i, gates_h, acc, hidden_fwd);
                let hb = &bwd_tile[rel * h * b..(rel + 1) * h * b];
                // Fused head: logits[j, lane] = (b_j + dot_fwd) + dot_bwd.
                for j in 0..k {
                    let row = &pw.w_head[j * 2 * h..(j + 1) * 2 * h];
                    dot_lanes(&row[..h], hidden_fwd, b, acc, head_f);
                    dot_lanes(&row[h..], hb, b, acc, head_b);
                    let bj = pw.b_head[j];
                    let lrow = &mut logits[j * b..(j + 1) * b];
                    for lane in 0..b {
                        lrow[lane] = bj + head_f[lane] + head_b[lane];
                    }
                }
                for lane in 0..b {
                    for (j, l) in logits_row.iter_mut().enumerate() {
                        *l = logits[j * b + lane];
                    }
                    let o = &mut probs_tile[(rel * b + lane) * k..(rel * b + lane + 1) * k];
                    softmax_into(logits_row, o);
                }
            }
            sink(t0, n, &probs_tile[..n * b * k])?;
        }
        scan.next += 1;
        Ok(wn)
    }
}

/// Pull `(A, ΔA)` features for timesteps `t0 .. t0+n` from `src` and scale
/// them into lane-major `[n, 2, B]` (row `2·rel` = x0 over lanes, row
/// `2·rel+1` = x1). `rows` is a per-lane `[n, 2]` staging buffer.
fn scale_tile_src<S: LaneFeatures>(
    src: &S,
    t0: usize,
    n: usize,
    b: usize,
    rows: &mut [f32],
    xs: &mut [f32],
) {
    for lane in 0..b {
        src.fill(lane, t0, n, rows);
        for rel in 0..n {
            let (fa, fda) = scale_features(rows[2 * rel], rows[2 * rel + 1]);
            xs[(rel * 2) * b + lane] = fa;
            xs[(rel * 2 + 1) * b + lane] = fda;
        }
    }
}

/// One batched GRU step for one direction: input gates, recurrent GEMM,
/// then the elementwise state update — all lane-major over `B`.
#[inline]
fn step_lanes(
    d: &PackedDir,
    h: usize,
    b: usize,
    x0: &[f32],
    x1: &[f32],
    gates_i: &mut [f32],
    gates_h: &mut [f32],
    acc: &mut [f32],
    hid: &mut [f32],
) {
    gates_input(d, h, b, x0, x1, gates_i);
    gemm_3h_lanes(&d.w_hh, &d.b_hh, hid, h, b, acc, gates_h);
    for j in 0..h {
        let gi_r = &gates_i[j * b..(j + 1) * b];
        let gi_z = &gates_i[(h + j) * b..(h + j + 1) * b];
        let gi_n = &gates_i[(2 * h + j) * b..(2 * h + j + 1) * b];
        let gh_r = &gates_h[j * b..(j + 1) * b];
        let gh_z = &gates_h[(h + j) * b..(h + j + 1) * b];
        let gh_n = &gates_h[(2 * h + j) * b..(2 * h + j + 1) * b];
        let hrow = &mut hid[j * b..(j + 1) * b];
        for lane in 0..b {
            let r = sigmoid(gi_r[lane] + gh_r[lane]);
            let z = sigmoid(gi_z[lane] + gh_z[lane]);
            let n = (gi_n[lane] + r * gh_n[lane]).tanh();
            hrow[lane] = (1.0 - z) * n + z * hrow[lane];
        }
    }
}

/// Batched input-gate pre-activations:
/// `gates_i[j, lane] = (w_x0[j]·x0[lane] + w_x1[j]·x1[lane]) + b_ih[j]`.
#[inline]
fn gates_input(d: &PackedDir, h: usize, b: usize, x0: &[f32], x1: &[f32], gates_i: &mut [f32]) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if super::simd::avx2() {
        // SAFETY: AVX2 presence checked; the kernel replays this scalar
        // loop's exact per-lane arithmetic (see classifier/simd.rs).
        unsafe { super::simd::gates_input_avx2(&d.w_x0, &d.w_x1, &d.b_ih, b, x0, x1, gates_i) };
        return;
    }
    for j in 0..3 * h {
        let (w0, w1, bj) = (d.w_x0[j], d.w_x1[j], d.b_ih[j]);
        let orow = &mut gates_i[j * b..(j + 1) * b];
        for (o, (&a0, &a1)) in orow.iter_mut().zip(x0.iter().zip(x1)) {
            *o = w0 * a0 + w1 * a1 + bj;
        }
    }
}

/// Batched `out[j, lane] = dot(W_hh[j, :], hid[:, lane]) + b[j]` — the
/// `[3H, H] × [H, B]` GEMM. Each lane's reduction replays the exact
/// partial-sum schedule of the sequential `native::dot` (8 slots over
/// chunks of 8, left fold from 0.0, remainder in order), so the result is
/// bit-identical to the sequential GEMV while every weight element is
/// loaded once per B lanes. With `--features simd` and AVX2 present the
/// same schedule runs eight lanes per instruction (classifier/simd.rs).
#[inline]
fn gemm_3h_lanes(
    w: &[f32],
    bias: &[f32],
    hid: &[f32],
    h: usize,
    b: usize,
    acc: &mut [f32],
    out: &mut [f32],
) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if super::simd::avx2() {
        // SAFETY: AVX2 presence checked; bit-identical by construction.
        unsafe { super::simd::gemm_3h_lanes_avx2(w, bias, hid, h, b, acc, out) };
        return;
    }
    gemm_3h_lanes_scalar(w, bias, hid, h, b, acc, out)
}

/// The portable scalar GEMM body (also the reference the SIMD parity test
/// compares against).
fn gemm_3h_lanes_scalar(
    w: &[f32],
    bias: &[f32],
    hid: &[f32],
    h: usize,
    b: usize,
    acc: &mut [f32],
    out: &mut [f32],
) {
    debug_assert_eq!(acc.len(), 8 * b);
    let nchunks = h / 8;
    for j in 0..3 * h {
        let row = &w[j * h..(j + 1) * h];
        acc.fill(0.0);
        for c in 0..nchunks {
            for l in 0..8 {
                let kk = 8 * c + l;
                let wv = row[kk];
                let hrow = &hid[kk * b..(kk + 1) * b];
                let arow = &mut acc[l * b..(l + 1) * b];
                for (a, &x) in arow.iter_mut().zip(hrow) {
                    *a += wv * x;
                }
            }
        }
        let out_row = &mut out[j * b..(j + 1) * b];
        fold_acc(acc, b, out_row);
        for kk in 8 * nchunks..h {
            let wv = row[kk];
            let hrow = &hid[kk * b..(kk + 1) * b];
            for (o, &x) in out_row.iter_mut().zip(hrow) {
                *o += wv * x;
            }
        }
        let bj = bias[j];
        for o in out_row.iter_mut() {
            *o += bj;
        }
    }
}

/// Batched `out[lane] = dot(row, mat[:, lane])` with the same partial-sum
/// schedule as `native::dot` (used for the two halves of the head
/// projection).
#[inline]
fn dot_lanes(row: &[f32], mat: &[f32], b: usize, acc: &mut [f32], out: &mut [f32]) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if super::simd::avx2() {
        // SAFETY: AVX2 presence checked; bit-identical by construction.
        unsafe { super::simd::dot_lanes_avx2(row, mat, b, acc, out) };
        return;
    }
    dot_lanes_scalar(row, mat, b, acc, out)
}

/// The portable scalar head-projection body (also the SIMD parity
/// reference).
fn dot_lanes_scalar(row: &[f32], mat: &[f32], b: usize, acc: &mut [f32], out: &mut [f32]) {
    let h = row.len();
    let nchunks = h / 8;
    acc.fill(0.0);
    for c in 0..nchunks {
        for l in 0..8 {
            let kk = 8 * c + l;
            let wv = row[kk];
            let hrow = &mat[kk * b..(kk + 1) * b];
            let arow = &mut acc[l * b..(l + 1) * b];
            for (a, &x) in arow.iter_mut().zip(hrow) {
                *a += wv * x;
            }
        }
    }
    fold_acc(acc, b, out);
    for kk in 8 * nchunks..h {
        let wv = row[kk];
        let hrow = &mat[kk * b..(kk + 1) * b];
        for (o, &x) in out.iter_mut().zip(hrow) {
            *o += wv * x;
        }
    }
}

/// `out[lane] = 0.0 + acc[0, lane] + … + acc[7, lane]` — the lane-wise
/// equivalent of `acc.iter().sum::<f32>()` in `native::dot` (including the
/// 0.0 start, which matters for signed-zero bit-identity).
#[inline]
fn fold_acc(acc: &[f32], b: usize, out: &mut [f32]) {
    out.fill(0.0);
    for l in 0..8 {
        let arow = &acc[l * b..(l + 1) * b];
        for (o, &a) in out.iter_mut().zip(arow) {
            *o += a;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::classifier::native::tests::{random_features, random_weights, random_weights_hk};
    use crate::classifier::{StateClassifier, K_MAX};

    fn model_hk(h: usize, k: usize, seed: u64) -> NativeBiGru {
        NativeBiGru::new(random_weights_hk(h, k, seed))
    }

    /// Assert `probs_batch_tiled` output equals per-lane sequential `probs`
    /// bit-for-bit.
    fn assert_lane_parity(model: &NativeBiGru, b: usize, t_len: usize, tile: usize, seed: u64) {
        let k = model.k_max();
        let feats: Vec<Vec<f32>> =
            (0..b).map(|lane| random_features(t_len, seed + 31 * lane as u64)).collect();
        let refs: Vec<&[f32]> = feats.iter().map(|f| f.as_slice()).collect();
        let mut scratch = ScratchArena::new();
        let mut batched = vec![0.0f32; t_len * b * k];
        model
            .probs_batch_tiled(&refs, t_len, tile, &mut scratch, |t0, n, tp| {
                batched[t0 * b * k..(t0 + n) * b * k].copy_from_slice(tp);
                Ok(())
            })
            .unwrap();
        for (lane, f) in feats.iter().enumerate() {
            let seq = model.probs(f, t_len).unwrap();
            for t in 0..t_len {
                for j in 0..k {
                    let x = batched[(t * b + lane) * k + j];
                    let y = seq[t * k + j];
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "lane {lane} t {t} state {j}: batched {x} != sequential {y} \
                         (B={b}, T={t_len}, tile={tile})"
                    );
                }
            }
        }
    }

    #[test]
    fn parity_across_batch_and_sequence_sizes() {
        // Ragged batch widths (1, 3, 5, 8 — including non-multiples of any
        // SIMD lane width) × short/medium sequences, un-tiled.
        let model = model_hk(16, 5, 41);
        for &b in &[1usize, 3, 5, 8] {
            for &t in &[1usize, 7, 300] {
                assert_lane_parity(&model, b, t, BATCH_TILE, 1000 + (b * 7 + t) as u64);
            }
        }
    }

    #[test]
    fn parity_under_time_tiling() {
        // tile=64 over T=300 exercises checkpoints, recompute, and a ragged
        // final tile (300 = 4×64 + 44); tile=1 is the degenerate extreme.
        let model = model_hk(16, 5, 42);
        assert_lane_parity(&model, 4, 300, 64, 2000);
        assert_lane_parity(&model, 3, 7, 1, 2001);
    }

    #[test]
    fn parity_at_production_geometry() {
        // Full H=64, K=12 geometry, H not a multiple-of-8 edge covered next.
        let model = NativeBiGru::new(random_weights(43));
        assert_lane_parity(&model, 3, 50, BATCH_TILE, 3000);
    }

    #[test]
    fn parity_with_remainder_hidden_size() {
        // H=13 forces the non-multiple-of-8 remainder loop in the GEMM.
        let model = model_hk(13, 4, 44);
        assert_lane_parity(&model, 5, 40, 16, 4000);
    }

    #[test]
    fn trait_probs_batch_matches_tiled_path() {
        let model = model_hk(16, 5, 45);
        let (b, t) = (4usize, 90usize);
        let feats: Vec<Vec<f32>> = (0..b).map(|l| random_features(t, 5000 + l as u64)).collect();
        let refs: Vec<&[f32]> = feats.iter().map(|f| f.as_slice()).collect();
        let via_trait = StateClassifier::probs_batch(&model, &refs, t).unwrap();
        let mut scratch = ScratchArena::new();
        let mut via_into = Vec::new();
        model.probs_batch_into(&refs, t, &mut scratch, &mut via_into).unwrap();
        assert_eq!(via_trait, via_into);
        assert_eq!(via_trait.len(), t * b * model.k_max());
    }

    #[test]
    fn tiles_arrive_in_order_and_cover_sequence() {
        let model = model_hk(8, 3, 46);
        let t_len = 100;
        let feats = [random_features(t_len, 6000)];
        let refs: Vec<&[f32]> = feats.iter().map(|f| f.as_slice()).collect();
        let mut scratch = ScratchArena::new();
        let mut next_t0 = 0usize;
        model
            .probs_batch_tiled(&refs, t_len, 32, &mut scratch, |t0, n, tp| {
                assert_eq!(t0, next_t0);
                assert_eq!(tp.len(), n * model.k_max());
                next_t0 = t0 + n;
                Ok(())
            })
            .unwrap();
        assert_eq!(next_t0, t_len);
    }

    #[test]
    fn resumable_scan_matches_one_shot_bitwise() {
        // Drive begin_batch_scan / scan_window by hand — windows that don't
        // divide T (170 = 3×48 + 26) and an interleaved "pause" between
        // windows — and compare against the one-shot batched output.
        let model = model_hk(16, 5, 50);
        let (b, t_len, window) = (3usize, 170usize, 48usize);
        let k = model.k_max();
        let feats: Vec<Vec<f32>> = (0..b).map(|l| random_features(t_len, 9000 + l as u64)).collect();
        let refs: Vec<&[f32]> = feats.iter().map(|f| f.as_slice()).collect();
        let mut scratch = ScratchArena::new();
        let mut reference = Vec::new();
        model.probs_batch_into(&refs, t_len, &mut scratch, &mut reference).unwrap();

        let src = SliceFeatures(&refs);
        let mut scan = model.begin_batch_scan(&src, t_len, window, &mut scratch).unwrap();
        assert_eq!(scan.n_windows(), 4);
        let mut got = vec![0.0f32; t_len * b * k];
        let mut emitted = 0usize;
        while !scan.is_done() {
            assert_eq!(scan.next_t0(), emitted);
            let n = model
                .scan_window(&mut scan, &src, &mut scratch, |t0, rows, tp| {
                    got[t0 * b * k..(t0 + rows) * b * k].copy_from_slice(tp);
                    Ok(())
                })
                .unwrap();
            assert!(n > 0);
            emitted += n;
            // Unrelated work on the same arena between windows must not
            // perturb the scan (the windowed pipeline interleaves racks).
            let other = [random_features(9, 77)];
            let other_refs: Vec<&[f32]> = other.iter().map(|f| f.as_slice()).collect();
            let mut tmp = Vec::new();
            model.probs_batch_into(&other_refs, 9, &mut scratch, &mut tmp).unwrap();
        }
        assert_eq!(emitted, t_len);
        assert_eq!(
            model.scan_window(&mut scan, &src, &mut scratch, |_, _, _| Ok(())).unwrap(),
            0
        );
        for (i, (x, y)) in got.iter().zip(&reference).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "posterior {i}");
        }
    }

    #[test]
    fn wide_windows_subtile_internally_and_stay_bit_identical() {
        // A window wider than BATCH_TILE exercises the window-local
        // checkpoint sweep (n_sub > 1). T=9000, window=5000 → sub-tiles of
        // 4096 + 904 inside window 0, then a ragged window of 4000.
        let model = model_hk(8, 3, 51);
        let (b, t_len, window) = (2usize, 9000usize, 5000usize);
        let k = model.k_max();
        let feats: Vec<Vec<f32>> = (0..b).map(|l| random_features(t_len, 9100 + l as u64)).collect();
        let refs: Vec<&[f32]> = feats.iter().map(|f| f.as_slice()).collect();
        let mut scratch = ScratchArena::new();
        let mut reference = Vec::new();
        model.probs_batch_into(&refs, t_len, &mut scratch, &mut reference).unwrap();
        let src = SliceFeatures(&refs);
        let mut scan = model.begin_batch_scan(&src, t_len, window, &mut scratch).unwrap();
        let mut got = vec![0.0f32; t_len * b * k];
        while model
            .scan_window(&mut scan, &src, &mut scratch, |t0, rows, tp| {
                got[t0 * b * k..(t0 + rows) * b * k].copy_from_slice(tp);
                Ok(())
            })
            .unwrap()
            > 0
        {}
        for (i, (x, y)) in got.iter().zip(&reference).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "posterior {i}");
        }
    }

    #[test]
    fn arena_reuse_across_tilings_stays_bit_identical() {
        // A multi-tile call followed by a single-tile call on the SAME
        // arena: the single-tile path must not read stale checkpoint
        // carries left by the previous run.
        let model = model_hk(8, 3, 49);
        let k = model.k_max();
        let mut scratch = ScratchArena::new();
        let long: Vec<Vec<f32>> = (0..3).map(|l| random_features(200, 8000 + l as u64)).collect();
        let refs_long: Vec<&[f32]> = long.iter().map(|f| f.as_slice()).collect();
        model.probs_batch_tiled(&refs_long, 200, 32, &mut scratch, |_, _, _| Ok(())).unwrap();
        let short: Vec<Vec<f32>> = (0..3).map(|l| random_features(20, 8100 + l as u64)).collect();
        let refs_short: Vec<&[f32]> = short.iter().map(|f| f.as_slice()).collect();
        let mut out = Vec::new();
        model.probs_batch_into(&refs_short, 20, &mut scratch, &mut out).unwrap();
        for (lane, f) in short.iter().enumerate() {
            let seq = model.probs(f, 20).unwrap();
            for t in 0..20 {
                for j in 0..k {
                    assert_eq!(
                        out[(t * 3 + lane) * k + j].to_bits(),
                        seq[t * k + j].to_bits(),
                        "lane {lane} t {t} state {j} after arena reuse"
                    );
                }
            }
        }
    }

    #[test]
    fn empty_batch_and_bad_lengths() {
        let model = model_hk(8, 3, 47);
        let mut scratch = ScratchArena::new();
        let mut out = vec![1.0f32; 3];
        model.probs_batch_into(&[], 10, &mut scratch, &mut out).unwrap();
        assert!(out.is_empty());
        let short = vec![0.0f32; 4];
        let refs: Vec<&[f32]> = vec![&short];
        assert!(model.probs_batch_into(&refs, 10, &mut scratch, &mut out).is_err());
    }

    /// Kernel-level f32x8-vs-scalar bit identity over the parity matrix
    /// (whole-model parity is already pinned by the tests above, which run
    /// the dispatched path against the scalar sequential reference).
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    #[test]
    fn avx2_kernels_match_scalar_bitwise() {
        use crate::classifier::simd;
        if !simd::avx2() {
            eprintln!("avx2 unavailable on this machine; skipping kernel parity");
            return;
        }
        fn fill_rand(v: &mut [f32], mut s: u64) {
            for x in v.iter_mut() {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                *x = ((s >> 40) as f32 / (1u64 << 24) as f32) * 2.0 - 1.0;
            }
        }
        fn assert_bits(a: &[f32], b: &[f32], what: &str, h: usize, bw: usize) {
            for (i, (x, y)) in a.iter().zip(b).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "{what}[{i}]: avx2 {x} != scalar {y} (H={h}, B={bw})"
                );
            }
        }
        for &h in &[8usize, 13, 16, 64] {
            for &b in &[1usize, 3, 5, 8, 16] {
                let seed = (h * 131 + b) as u64 | 1;
                let mut w = vec![0.0f32; 3 * h * h];
                let mut bias = vec![0.0f32; 3 * h];
                let mut hid = vec![0.0f32; h * b];
                let mut x0 = vec![0.0f32; b];
                let mut x1 = vec![0.0f32; b];
                fill_rand(&mut w, seed);
                fill_rand(&mut bias, seed + 1);
                fill_rand(&mut hid, seed + 2);
                fill_rand(&mut x0, seed + 3);
                fill_rand(&mut x1, seed + 4);
                let mut acc = vec![0.0f32; 8 * b];
                let mut got = vec![0.0f32; 3 * h * b];
                let mut want = vec![0.0f32; 3 * h * b];
                gemm_3h_lanes_scalar(&w, &bias, &hid, h, b, &mut acc, &mut want);
                unsafe { simd::gemm_3h_lanes_avx2(&w, &bias, &hid, h, b, &mut acc, &mut got) };
                assert_bits(&got, &want, "gemm", h, b);
                let row = &w[..h];
                let mut got_d = vec![0.0f32; b];
                let mut want_d = vec![0.0f32; b];
                dot_lanes_scalar(row, &hid, b, &mut acc, &mut want_d);
                unsafe { simd::dot_lanes_avx2(row, &hid, b, &mut acc, &mut got_d) };
                assert_bits(&got_d, &want_d, "dot", h, b);
                let (w0, w1, bi) = (&bias[..3 * h], &w[..3 * h], &w[3 * h..6 * h]);
                let mut got_g = vec![0.0f32; 3 * h * b];
                let mut want_g = vec![0.0f32; 3 * h * b];
                for j in 0..3 * h {
                    let orow = &mut want_g[j * b..(j + 1) * b];
                    for (o, (&a0, &a1)) in orow.iter_mut().zip(x0.iter().zip(&x1)) {
                        *o = w0[j] * a0 + w1[j] * a1 + bi[j];
                    }
                }
                unsafe { simd::gates_input_avx2(w0, w1, bi, b, &x0, &x1, &mut got_g) };
                assert_bits(&got_g, &want_g, "gates", h, b);
            }
        }
    }

    #[test]
    fn batched_rows_are_normalized() {
        let model = NativeBiGru::new(random_weights(48));
        let feats: Vec<Vec<f32>> = (0..5).map(|l| random_features(20, 7000 + l)).collect();
        let refs: Vec<&[f32]> = feats.iter().map(|f| f.as_slice()).collect();
        let p = StateClassifier::probs_batch(&model, &refs, 20).unwrap();
        for row in p.chunks(K_MAX) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-5, "row sums to {s}");
        }
    }
}
