//! In-repo property-testing runner (proptest is unavailable offline —
//! DESIGN.md §3), plus synthetic artifact stores so integration tests and
//! benchmarks can drive the full facility pipeline without `make
//! artifacts`.
//!
//! `check` runs a property over many deterministically generated random
//! cases; on failure it reports the seed and case index so the exact case
//! can be replayed. Generation helpers cover the domains the invariant
//! tests need (trace lengths, rates, weights, schedules).

#[cfg(feature = "host")]
use crate::artifacts::ArtifactStore;
#[cfg(feature = "host")]
use crate::catalog::Catalog;
#[cfg(feature = "host")]
use crate::classifier::flat_param_count;
#[cfg(feature = "host")]
use crate::coordinator::Generator;
#[cfg(feature = "host")]
use crate::util::json::{self, Json};
use crate::util::rng::Rng;
#[cfg(feature = "host")]
use anyhow::Result;
#[cfg(feature = "host")]
use std::path::PathBuf;

/// Number of cases per property (overridable with `POWERTRACE_PROP_CASES`).
pub fn default_cases() -> usize {
    std::env::var("POWERTRACE_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

/// Run `prop` over `cases` random cases. The property receives a fresh RNG
/// per case; assert inside it. Panics with seed/case info on failure.
pub fn check<F: Fn(&mut Rng)>(name: &str, prop: F) {
    check_seeded(name, 0xC0FFEE, default_cases(), prop)
}

pub fn check_seeded<F: Fn(&mut Rng)>(name: &str, seed: u64, cases: usize, prop: F) {
    for case in 0..cases {
        let mut rng = Rng::new(seed).fork(case as u64);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property '{name}' failed (seed={seed:#x}, case={case}): {msg}");
        }
    }
}

// ---------------------------------------------------------------------------
// Assertion helpers
// ---------------------------------------------------------------------------

/// Assert |a-b| <= atol + rtol*|b| elementwise.
pub fn assert_allclose(a: &[f32], b: &[f32], atol: f32, rtol: f32, ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: length mismatch {} vs {}", a.len(), b.len());
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        let tol = atol + rtol * y.abs();
        assert!(
            (x - y).abs() <= tol,
            "{ctx}: element {i}: {x} vs {y} (|diff|={} > tol={tol})",
            (x - y).abs()
        );
    }
}

pub fn assert_close(a: f64, b: f64, tol: f64, ctx: &str) {
    assert!((a - b).abs() <= tol, "{ctx}: {a} vs {b} (tol {tol})");
}

// ---------------------------------------------------------------------------
// Synthetic artifact stores
// ---------------------------------------------------------------------------

/// Write a synthetic artifact store (random BiGRU weights, plausible state
/// dictionaries and surrogate parameters) for the given configuration ids
/// under a tag-unique temp directory, and return its root. The store
/// satisfies every invariant `ArtifactStore::load_config` re-validates, so
/// the full generation pipeline runs against it — the traces are
/// statistically meaningless but deterministically reproducible from
/// `seed`, which is all parity/throughput tests and benches need.
#[cfg(feature = "host")]
pub fn synth_artifact_store(
    tag: &str,
    hidden: usize,
    k_max: usize,
    config_ids: &[String],
    seed: u64,
) -> PathBuf {
    let root = std::env::temp_dir().join(format!("powertrace_synth_store_{tag}"));
    let _ = std::fs::remove_dir_all(&root);
    std::fs::create_dir_all(root.join("configs")).unwrap();

    let manifest = json::obj([
        (
            "chunk",
            json::obj([("t", 512usize.into()), ("halo", 64usize.into())]),
        ),
        ("k_max", k_max.into()),
        ("hidden", hidden.into()),
        ("hlo", "bigru_fwd.hlo.txt".into()),
        (
            "configs",
            Json::Arr(config_ids.iter().map(|s| Json::Str(s.clone())).collect()),
        ),
    ]);
    json::write_file(&root.join("manifest.json"), &manifest).unwrap();

    let mut rng = Rng::new(seed);
    let k = k_max.min(3);
    for id in config_ids {
        let n_params = flat_param_count(hidden, k_max);
        let weights: Vec<f32> = (0..n_params).map(|_| (rng.normal() * 0.12) as f32).collect();
        let mu: Vec<f64> = (0..k).map(|i| 300.0 + 140.0 * i as f64).collect();
        let pi: Vec<f64> = (0..k).map(|_| 1.0 / k as f64).collect();
        let art = json::obj([
            ("config_id", id.as_str().into()),
            ("k", k.into()),
            ("train_power_mean_w", 600.0.into()),
            (
                "states",
                json::obj([
                    ("pi", Json::from_f64s(&pi)),
                    ("mu", Json::from_f64s(&mu)),
                    ("sigma", Json::from_f64s(&vec![20.0; k])),
                    ("phi", Json::from_f64s(&vec![0.0; k])),
                    ("y_min", 250.0.into()),
                    ("y_max", (300.0 + 140.0 * k as f64 + 200.0).into()),
                ]),
            ),
            ("mode", "iid".into()),
            (
                "surrogate",
                json::obj([
                    ("alpha0", (-2.0).into()),
                    ("alpha1", 0.8.into()),
                    ("sigma_ttft", 0.2.into()),
                    ("mu_log_tbt", (-4.0).into()),
                    ("sigma_log_tbt", 0.2.into()),
                ]),
            ),
            ("weights", Json::from_f32s(&weights)),
        ]);
        json::write_file(&root.join("configs").join(format!("{id}.json")), &art).unwrap();
    }
    root
}

/// A native-backend [`Generator`] over a synthetic artifact store: the real
/// repo catalog (`data/catalog.json`) paired with random per-configuration
/// weights for its first `n_configs` configuration ids. Returns the
/// generator and the ids it can prepare.
#[cfg(feature = "host")]
pub fn synth_generator(
    tag: &str,
    hidden: usize,
    k_max: usize,
    n_configs: usize,
    seed: u64,
) -> Result<(Generator, Vec<String>)> {
    let cat = Catalog::load_default()?;
    let ids: Vec<String> = cat.config_ids().into_iter().take(n_configs.max(1)).collect();
    anyhow::ensure!(!ids.is_empty(), "catalog lists no configurations");
    let root = synth_artifact_store(tag, hidden, k_max, &ids, seed);
    let store = ArtifactStore::open(&root)?;
    Ok((Generator::native_with(cat, store), ids))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("uniform in range", |rng| {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn check_reports_failures() {
        check_seeded("always fails", 1, 4, |_| panic!("nope"));
    }

    #[cfg(feature = "host")]
    #[test]
    fn synth_store_loads_and_prepares() {
        let (mut gen, ids) = synth_generator("testutil_unit", 8, 4, 2, 5).unwrap();
        assert!(!ids.is_empty());
        let p = gen.prepare(&ids[0]).unwrap();
        assert!(p.art.k >= 1 && p.art.k <= 4);
        assert!(p.cls.as_native().is_some(), "native backend expected");
    }

    #[test]
    fn allclose_accepts_and_rejects() {
        assert_allclose(&[1.0, 2.0], &[1.0005, 2.0], 1e-3, 0.0, "ok");
        let r = std::panic::catch_unwind(|| {
            assert_allclose(&[1.0], &[1.1], 1e-3, 0.0, "bad");
        });
        assert!(r.is_err());
    }
}
