//! In-repo property-testing runner (proptest is unavailable offline —
//! DESIGN.md §3).
//!
//! `check` runs a property over many deterministically generated random
//! cases; on failure it reports the seed and case index so the exact case
//! can be replayed. Generation helpers cover the domains the invariant
//! tests need (trace lengths, rates, weights, schedules).

use crate::util::rng::Rng;

/// Number of cases per property (overridable with `POWERTRACE_PROP_CASES`).
pub fn default_cases() -> usize {
    std::env::var("POWERTRACE_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

/// Run `prop` over `cases` random cases. The property receives a fresh RNG
/// per case; assert inside it. Panics with seed/case info on failure.
pub fn check<F: Fn(&mut Rng)>(name: &str, prop: F) {
    check_seeded(name, 0xC0FFEE, default_cases(), prop)
}

pub fn check_seeded<F: Fn(&mut Rng)>(name: &str, seed: u64, cases: usize, prop: F) {
    for case in 0..cases {
        let mut rng = Rng::new(seed).fork(case as u64);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property '{name}' failed (seed={seed:#x}, case={case}): {msg}");
        }
    }
}

// ---------------------------------------------------------------------------
// Assertion helpers
// ---------------------------------------------------------------------------

/// Assert |a-b| <= atol + rtol*|b| elementwise.
pub fn assert_allclose(a: &[f32], b: &[f32], atol: f32, rtol: f32, ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: length mismatch {} vs {}", a.len(), b.len());
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        let tol = atol + rtol * y.abs();
        assert!(
            (x - y).abs() <= tol,
            "{ctx}: element {i}: {x} vs {y} (|diff|={} > tol={tol})",
            (x - y).abs()
        );
    }
}

pub fn assert_close(a: f64, b: f64, tol: f64, ctx: &str) {
    assert!((a - b).abs() <= tol, "{ctx}: {a} vs {b} (tol {tol})");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("uniform in range", |rng| {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn check_reports_failures() {
        check_seeded("always fails", 1, 4, |_| panic!("nope"));
    }

    #[test]
    fn allclose_accepts_and_rejects() {
        assert_allclose(&[1.0, 2.0], &[1.0005, 2.0], 1e-3, 0.0, "ok");
        let r = std::panic::catch_unwind(|| {
            assert_allclose(&[1.0], &[1.1], 1e-3, 0.0, "bad");
        });
        assert!(r.is_err());
    }
}
