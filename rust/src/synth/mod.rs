//! Trace synthesis (paper §3.3): sample a state trajectory from classifier
//! posteriors (Eq. 7), then sample power conditioned on the trajectory —
//! i.i.d. Gaussian per state for dense models (Eq. 8) or per-state AR(1)
//! for MoE models (Eq. 9) — and clip to the observed range.

use crate::states::StateDictionary;
use crate::util::rng::Rng;

/// Power-sampling mode per model kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SynthMode {
    /// Dense transformers: within-state variation is weakly correlated in
    /// time → independent draws (paper Eq. 8).
    Iid,
    /// MoE: expert routing induces temporal persistence → AR(1) (Eq. 9).
    Ar1,
}

/// Sample a state trajectory from per-timestep posteriors.
///
/// `probs` is `[T, k]` row-major (the classifier output). States are drawn
/// categorically rather than argmaxed (paper: "rather than taking an argmax
/// at each timestep"), which preserves ambiguity near transitions.
pub fn sample_states(probs: &[f32], k: usize, rng: &mut Rng) -> Vec<usize> {
    assert!(k > 0 && probs.len() % k == 0, "probs not divisible by k");
    probs.chunks_exact(k).map(|row| rng.categorical(row)).collect()
}

/// Argmax state trajectory (used by ablations).
pub fn argmax_states(probs: &[f32], k: usize) -> Vec<usize> {
    assert!(k > 0 && probs.len() % k == 0);
    probs
        .chunks_exact(k)
        .map(|row| {
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap()
        })
        .collect()
}

/// Sample a power trace conditioned on a state trajectory.
pub fn sample_power(
    states: &[usize],
    dict: &StateDictionary,
    mode: SynthMode,
    rng: &mut Rng,
) -> Vec<f32> {
    let mut out = Vec::with_capacity(states.len());
    match mode {
        SynthMode::Iid => {
            for &z in states {
                debug_assert!(z < dict.k());
                let y = rng.normal_ms(dict.mu[z], dict.sigma[z]);
                out.push(dict.clip(y) as f32);
            }
        }
        SynthMode::Ar1 => {
            let mut prev: Option<f64> = None;
            for &z in states {
                debug_assert!(z < dict.k());
                let (mu, sigma, phi) = (dict.mu[z], dict.sigma[z], dict.phi[z]);
                let y = match prev {
                    None => rng.normal_ms(mu, sigma),
                    Some(p) => {
                        // σ_noise = σ·√(1−φ²) keeps the marginal variance σ².
                        let noise = sigma * (1.0 - phi * phi).max(0.0).sqrt();
                        mu + phi * (p - mu) + noise * rng.normal()
                    }
                };
                let clipped = dict.clip(y);
                prev = Some(clipped);
                out.push(clipped as f32);
            }
        }
    }
    out
}

/// Convenience: full synthesis from posteriors.
pub fn synthesize(
    probs: &[f32],
    dict: &StateDictionary,
    mode: SynthMode,
    rng: &mut Rng,
) -> Vec<f32> {
    let states = sample_states(probs, dict.k(), rng);
    sample_power(&states, dict, mode, rng)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::acf;
    use crate::testutil::check;

    fn dict(phi: f64) -> StateDictionary {
        StateDictionary {
            pi: vec![0.5, 0.5],
            mu: vec![100.0, 300.0],
            sigma: vec![5.0, 8.0],
            phi: vec![phi, phi],
            y_min: 60.0,
            y_max: 340.0,
        }
    }

    #[test]
    fn sample_states_respects_degenerate_posteriors() {
        let mut rng = Rng::new(80);
        // T=3, K=2 with certain rows.
        let probs = [1.0f32, 0.0, 0.0, 1.0, 1.0, 0.0];
        assert_eq!(sample_states(&probs, 2, &mut rng), vec![0, 1, 0]);
    }

    #[test]
    fn sample_states_frequency_matches_posterior() {
        let mut rng = Rng::new(81);
        let probs: Vec<f32> = std::iter::repeat([0.3f32, 0.7]).take(20_000).flatten().collect();
        let states = sample_states(&probs, 2, &mut rng);
        let f1 = states.iter().filter(|&&z| z == 1).count() as f64 / states.len() as f64;
        assert!((f1 - 0.7).abs() < 0.02, "f1 {f1}");
    }

    #[test]
    fn argmax_picks_max() {
        let probs = [0.3f32, 0.7, 0.9, 0.1];
        assert_eq!(argmax_states(&probs, 2), vec![1, 0]);
    }

    #[test]
    fn iid_power_matches_state_moments() {
        let d = dict(0.0);
        let mut rng = Rng::new(82);
        let states = vec![0usize; 20_000];
        let ys = sample_power(&states, &d, SynthMode::Iid, &mut rng);
        let mean = ys.iter().map(|&y| y as f64).sum::<f64>() / ys.len() as f64;
        assert!((mean - 100.0).abs() < 0.2, "mean {mean}");
    }

    #[test]
    fn ar1_preserves_marginal_but_adds_correlation() {
        let d = dict(0.9);
        let mut rng = Rng::new(83);
        let states = vec![1usize; 40_000];
        let ys = sample_power(&states, &d, SynthMode::Ar1, &mut rng);
        let mean = ys.iter().map(|&y| y as f64).sum::<f64>() / ys.len() as f64;
        let var = ys.iter().map(|&y| (y as f64 - mean).powi(2)).sum::<f64>() / ys.len() as f64;
        assert!((mean - 300.0).abs() < 0.5, "mean {mean}");
        assert!((var.sqrt() - 8.0).abs() < 0.5, "std {}", var.sqrt());
        let rho1 = acf(&ys, 1)[1];
        assert!((rho1 - 0.9).abs() < 0.05, "rho1 {rho1}");

        // i.i.d. comparison: no lag-1 correlation.
        let ys_iid = sample_power(&states, &dict(0.0), SynthMode::Iid, &mut rng);
        assert!(acf(&ys_iid, 1)[1].abs() < 0.05);
    }

    #[test]
    fn prop_samples_always_within_clip_range() {
        check("synthesis clipped", |rng| {
            let d = dict(rng.range(0.0, 0.99));
            let t = 1 + rng.below(500);
            let probs: Vec<f32> = (0..t * 2).map(|_| rng.f64() as f32).collect();
            let mut local = rng.clone();
            let mode = if rng.f64() < 0.5 { SynthMode::Iid } else { SynthMode::Ar1 };
            let ys = synthesize(&probs, &d, mode, &mut local);
            assert_eq!(ys.len(), t);
            for &y in &ys {
                assert!((y as f64) >= d.y_min - 1e-6 && (y as f64) <= d.y_max + 1e-6);
            }
        });
    }

    #[test]
    fn state_switches_move_power_level() {
        let d = dict(0.0);
        let mut rng = Rng::new(84);
        let mut states = vec![0usize; 100];
        states.extend(vec![1usize; 100]);
        let ys = sample_power(&states, &d, SynthMode::Iid, &mut rng);
        let first: f64 = ys[..100].iter().map(|&y| y as f64).sum::<f64>() / 100.0;
        let second: f64 = ys[100..].iter().map(|&y| y as f64).sum::<f64>() / 100.0;
        assert!(second - first > 150.0);
    }
}
