//! Trace synthesis (paper §3.3): sample a state trajectory from classifier
//! posteriors (Eq. 7), then sample power conditioned on the trajectory —
//! i.i.d. Gaussian per state for dense models (Eq. 8) or per-state AR(1)
//! for MoE models (Eq. 9) — and clip to the observed range.

use crate::states::StateDictionary;
use crate::util::rng::Rng;

/// Power-sampling mode per model kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SynthMode {
    /// Dense transformers: within-state variation is weakly correlated in
    /// time → independent draws (paper Eq. 8).
    Iid,
    /// MoE: expert routing induces temporal persistence → AR(1) (Eq. 9).
    Ar1,
}

/// Sample a state trajectory from per-timestep posteriors.
///
/// `probs` is `[T, k]` row-major (the classifier output). States are drawn
/// categorically rather than argmaxed (paper: "rather than taking an argmax
/// at each timestep"), which preserves ambiguity near transitions.
pub fn sample_states(probs: &[f32], k: usize, rng: &mut Rng) -> Vec<usize> {
    assert!(k > 0 && probs.len() % k == 0, "probs not divisible by k");
    probs.chunks_exact(k).map(|row| rng.categorical(row)).collect()
}

/// Sample a state trajectory from the first `k` (live) states of `[T,
/// k_max]` posteriors, without materializing the masked copy. Draws are
/// identical to copying each row's live prefix and calling
/// [`sample_states`] (the categorical draw renormalizes internally).
pub fn sample_states_masked_into(
    probs: &[f32],
    k_max: usize,
    k: usize,
    rng: &mut Rng,
    out: &mut Vec<usize>,
) {
    assert!(k > 0 && k <= k_max && probs.len() % k_max == 0, "bad posterior shape");
    out.clear();
    out.reserve(probs.len() / k_max);
    for row in probs.chunks_exact(k_max) {
        out.push(rng.categorical(&row[..k]));
    }
}

/// Append one lane's states from a lane-major posterior tile — the batched
/// classifier's streaming output (`[n_rows, B, k_max]`, see
/// `StateClassifier::probs_batch`). Reads lane `lane`'s rows in time order
/// and draws from the first `k` live states, so per lane the draws are
/// bit-identical to the sequential [`sample_states`] path.
#[allow(clippy::too_many_arguments)]
pub fn sample_states_lane_into(
    tile_probs: &[f32],
    n_rows: usize,
    lane: usize,
    b: usize,
    k_max: usize,
    k: usize,
    rng: &mut Rng,
    out: &mut Vec<usize>,
) {
    assert!(lane < b && k > 0 && k <= k_max, "bad lane/state geometry");
    assert!(tile_probs.len() >= n_rows * b * k_max, "tile too short");
    out.reserve(n_rows);
    for r in 0..n_rows {
        let row = &tile_probs[(r * b + lane) * k_max..(r * b + lane) * k_max + k];
        out.push(rng.categorical(row));
    }
}

/// Argmax state trajectory (used by ablations).
pub fn argmax_states(probs: &[f32], k: usize) -> Vec<usize> {
    assert!(k > 0 && probs.len() % k == 0);
    probs
        .chunks_exact(k)
        .map(|row| {
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap()
        })
        .collect()
}

/// Sample a power trace conditioned on a state trajectory.
pub fn sample_power(
    states: &[usize],
    dict: &StateDictionary,
    mode: SynthMode,
    rng: &mut Rng,
) -> Vec<f32> {
    let mut out = Vec::new();
    sample_power_into(states, dict, mode, rng, &mut out);
    out
}

/// [`sample_power`] into a reusable buffer (the batched facility pipeline
/// recycles one power buffer per worker instead of allocating per server).
pub fn sample_power_into(
    states: &[usize],
    dict: &StateDictionary,
    mode: SynthMode,
    rng: &mut Rng,
    out: &mut Vec<f32>,
) {
    let mut carry = None;
    sample_power_resume(states, dict, mode, rng, &mut carry, out);
}

/// Chunk-resumable [`sample_power_into`]: `carry` threads the AR(1)
/// previous sample across calls, so sampling a trajectory one time-window
/// at a time (the streaming facility path) draws the **exact** sequence a
/// single full-horizon call would — provided the same `rng` is passed in
/// series order and `carry` starts as `None`. For [`SynthMode::Iid`] the
/// carry is unused; it is still updated so callers can switch modes per
/// configuration without special cases.
pub fn sample_power_resume(
    states: &[usize],
    dict: &StateDictionary,
    mode: SynthMode,
    rng: &mut Rng,
    carry: &mut Option<f64>,
    out: &mut Vec<f32>,
) {
    out.clear();
    out.reserve(states.len());
    match mode {
        SynthMode::Iid => {
            for &z in states {
                debug_assert!(z < dict.k());
                let y = rng.normal_ms(dict.mu[z], dict.sigma[z]);
                let clipped = dict.clip(y);
                *carry = Some(clipped);
                out.push(clipped as f32);
            }
        }
        SynthMode::Ar1 => {
            for &z in states {
                debug_assert!(z < dict.k());
                let (mu, sigma, phi) = (dict.mu[z], dict.sigma[z], dict.phi[z]);
                let y = match *carry {
                    None => rng.normal_ms(mu, sigma),
                    Some(p) => {
                        // σ_noise = σ·√(1−φ²) keeps the marginal variance σ².
                        let noise = sigma * (1.0 - phi * phi).max(0.0).sqrt();
                        mu + phi * (p - mu) + noise * rng.normal()
                    }
                };
                let clipped = dict.clip(y);
                *carry = Some(clipped);
                out.push(clipped as f32);
            }
        }
    }
}

/// Convenience: full synthesis from posteriors.
pub fn synthesize(
    probs: &[f32],
    dict: &StateDictionary,
    mode: SynthMode,
    rng: &mut Rng,
) -> Vec<f32> {
    let states = sample_states(probs, dict.k(), rng);
    sample_power(&states, dict, mode, rng)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::acf;
    use crate::testutil::check;

    fn dict(phi: f64) -> StateDictionary {
        StateDictionary {
            pi: vec![0.5, 0.5],
            mu: vec![100.0, 300.0],
            sigma: vec![5.0, 8.0],
            phi: vec![phi, phi],
            y_min: 60.0,
            y_max: 340.0,
        }
    }

    #[test]
    fn sample_states_respects_degenerate_posteriors() {
        let mut rng = Rng::new(80);
        // T=3, K=2 with certain rows.
        let probs = [1.0f32, 0.0, 0.0, 1.0, 1.0, 0.0];
        assert_eq!(sample_states(&probs, 2, &mut rng), vec![0, 1, 0]);
    }

    #[test]
    fn sample_states_frequency_matches_posterior() {
        let mut rng = Rng::new(81);
        let probs: Vec<f32> = std::iter::repeat([0.3f32, 0.7]).take(20_000).flatten().collect();
        let states = sample_states(&probs, 2, &mut rng);
        let f1 = states.iter().filter(|&&z| z == 1).count() as f64 / states.len() as f64;
        assert!((f1 - 0.7).abs() < 0.02, "f1 {f1}");
    }

    #[test]
    fn argmax_picks_max() {
        let probs = [0.3f32, 0.7, 0.9, 0.1];
        assert_eq!(argmax_states(&probs, 2), vec![1, 0]);
    }

    #[test]
    fn masked_sampling_matches_live_copy_path() {
        // The pre-batching pipeline copied each row's live prefix into a
        // dense [T, k] buffer before sampling; drawing straight from the
        // [T, k_max] rows must reproduce the same draws from the same seed.
        let (k_max, k, t) = (5usize, 3usize, 400usize);
        let mut gen = Rng::new(90);
        let probs: Vec<f32> = (0..t * k_max).map(|_| gen.f64() as f32).collect();
        let mut live = vec![0.0f32; t * k];
        for i in 0..t {
            live[i * k..(i + 1) * k].copy_from_slice(&probs[i * k_max..i * k_max + k]);
        }
        let mut r1 = Rng::new(91);
        let reference = sample_states(&live, k, &mut r1);
        let mut r2 = Rng::new(91);
        let mut masked = Vec::new();
        sample_states_masked_into(&probs, k_max, k, &mut r2, &mut masked);
        assert_eq!(masked, reference);
    }

    #[test]
    fn lane_sampling_matches_sequential_per_lane() {
        // Lane-major tile [n, B, k_max]: per lane, tile-wise sampling must
        // replay the sequential masked draw stream exactly.
        let (b, k_max, k, n) = (3usize, 4usize, 2usize, 50usize);
        let mut gen = Rng::new(92);
        let tile: Vec<f32> = (0..n * b * k_max).map(|_| gen.f64() as f32).collect();
        for lane in 0..b {
            // Sequential reference: extract this lane's rows.
            let mut rows = Vec::new();
            for r in 0..n {
                rows.extend_from_slice(&tile[(r * b + lane) * k_max..(r * b + lane + 1) * k_max]);
            }
            let mut r1 = Rng::new(93 + lane as u64);
            let mut reference = Vec::new();
            sample_states_masked_into(&rows, k_max, k, &mut r1, &mut reference);
            let mut r2 = Rng::new(93 + lane as u64);
            let mut lane_states = Vec::new();
            // Two half-tiles to exercise streaming append.
            let half = n / 2;
            sample_states_lane_into(&tile[..half * b * k_max], half, lane, b, k_max, k, &mut r2, &mut lane_states);
            sample_states_lane_into(&tile[half * b * k_max..], n - half, lane, b, k_max, k, &mut r2, &mut lane_states);
            assert_eq!(lane_states, reference, "lane {lane}");
        }
    }

    #[test]
    fn sample_power_into_reuses_buffer_and_matches() {
        let d = dict(0.6);
        let states = vec![0usize, 1, 1, 0];
        let mut r1 = Rng::new(94);
        let owned = sample_power(&states, &d, SynthMode::Ar1, &mut r1);
        let mut r2 = Rng::new(94);
        let mut buf = vec![123.0f32; 9]; // stale contents discarded
        sample_power_into(&states, &d, SynthMode::Ar1, &mut r2, &mut buf);
        assert_eq!(buf, owned);
    }

    #[test]
    fn resumed_chunks_match_one_shot_bitwise() {
        // Windowed synthesis with a carried AR(1) state must replay the
        // exact one-shot draw sequence — for both modes and for chunk
        // sizes that don't divide the trajectory.
        for (mode, phi) in [(SynthMode::Iid, 0.0), (SynthMode::Ar1, 0.8)] {
            let d = dict(phi);
            let mut gen = Rng::new(95);
            let states: Vec<usize> = (0..257).map(|_| gen.below(2)).collect();
            let mut r1 = Rng::new(96);
            let reference = sample_power(&states, &d, mode, &mut r1);
            let mut r2 = Rng::new(96);
            let mut carry = None;
            let mut got = Vec::new();
            let mut buf = Vec::new();
            for chunk in states.chunks(31) {
                sample_power_resume(chunk, &d, mode, &mut r2, &mut carry, &mut buf);
                got.extend_from_slice(&buf);
            }
            assert_eq!(got.len(), reference.len());
            for (i, (a, b)) in got.iter().zip(&reference).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{mode:?} sample {i}");
            }
        }
    }

    #[test]
    fn iid_power_matches_state_moments() {
        let d = dict(0.0);
        let mut rng = Rng::new(82);
        let states = vec![0usize; 20_000];
        let ys = sample_power(&states, &d, SynthMode::Iid, &mut rng);
        let mean = ys.iter().map(|&y| y as f64).sum::<f64>() / ys.len() as f64;
        assert!((mean - 100.0).abs() < 0.2, "mean {mean}");
    }

    #[test]
    fn ar1_preserves_marginal_but_adds_correlation() {
        let d = dict(0.9);
        let mut rng = Rng::new(83);
        let states = vec![1usize; 40_000];
        let ys = sample_power(&states, &d, SynthMode::Ar1, &mut rng);
        let mean = ys.iter().map(|&y| y as f64).sum::<f64>() / ys.len() as f64;
        let var = ys.iter().map(|&y| (y as f64 - mean).powi(2)).sum::<f64>() / ys.len() as f64;
        assert!((mean - 300.0).abs() < 0.5, "mean {mean}");
        assert!((var.sqrt() - 8.0).abs() < 0.5, "std {}", var.sqrt());
        let rho1 = acf(&ys, 1)[1];
        assert!((rho1 - 0.9).abs() < 0.05, "rho1 {rho1}");

        // i.i.d. comparison: no lag-1 correlation.
        let ys_iid = sample_power(&states, &dict(0.0), SynthMode::Iid, &mut rng);
        assert!(acf(&ys_iid, 1)[1].abs() < 0.05);
    }

    #[test]
    fn prop_samples_always_within_clip_range() {
        check("synthesis clipped", |rng| {
            let d = dict(rng.range(0.0, 0.99));
            let t = 1 + rng.below(500);
            let probs: Vec<f32> = (0..t * 2).map(|_| rng.f64() as f32).collect();
            let mut local = rng.clone();
            let mode = if rng.f64() < 0.5 { SynthMode::Iid } else { SynthMode::Ar1 };
            let ys = synthesize(&probs, &d, mode, &mut local);
            assert_eq!(ys.len(), t);
            for &y in &ys {
                assert!((y as f64) >= d.y_min - 1e-6 && (y as f64) <= d.y_max + 1e-6);
            }
        });
    }

    #[test]
    fn state_switches_move_power_level() {
        let d = dict(0.0);
        let mut rng = Rng::new(84);
        let mut states = vec![0usize; 100];
        states.extend(vec![1usize; 100]);
        let ys = sample_power(&states, &d, SynthMode::Iid, &mut rng);
        let first: f64 = ys[..100].iter().map(|&y| y as f64).sum::<f64>() / 100.0;
        let second: f64 = ys[100..].iter().map(|&y| y as f64).sum::<f64>() / 100.0;
        assert!(second - first > 150.0);
    }
}
