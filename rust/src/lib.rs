//! # powertrace-sim
//!
//! A from-scratch reproduction of *"From Servers to Sites: Compositional
//! Power Trace Generation of LLM Inference for Infrastructure Planning"*
//! as a three-layer Rust + JAX + Pallas system (Python only at build time;
//! this crate owns the entire generation path).
//!
//! The public API mirrors the paper's pipeline (Fig. 2):
//!
//! 1. [`workload`] — request arrival processes and length distributions;
//! 2. [`surrogate`] — the throughput surrogate (FIFO queue, TTFT/TBT laws)
//!    that turns an arrival schedule into workload features `(A_t, ΔA_t)`;
//! 3. [`classifier`] — the BiGRU feature→state classifier, executed either
//!    natively or through the AOT-compiled XLA artifact via PJRT;
//! 4. [`states`] / [`synth`] — GMM power-state dictionaries and the
//!    state-conditioned power samplers (i.i.d. for dense, AR(1) for MoE);
//! 5. [`aggregate`] — server → rack → row → facility aggregation with
//!    non-GPU IT power, PUE, and the multi-resolution export
//!    ([`aggregate::MultiScale`]);
//! 6. [`metrics`] / [`baselines`] — fidelity + planning metrics and the
//!    TDP / mean / Splitwise-style-LUT comparison baselines;
//! 7. [`testbed`] — the synthetic measurement substrate standing in for the
//!    paper's Azure DGX campaign (DESIGN.md §3);
//! 8. [`coordinator`] — the multi-server generation pipeline;
//! 9. [`scenarios`] — the sweep engine: declarative grids of scenarios
//!    (traffic × topology × fleet × seed) executed in parallel with shared
//!    per-configuration artifacts;
//! 10. [`site`] — the site composition engine: several facilities with
//!     phase-offset workloads driven in lockstep and summed at the utility
//!     point of interconnection, with load-duration / coincidence /
//!     ramp-distribution / headroom characterization.
//!
//! Every run shape above is fronted by one entry point: [`api`] defines
//! the `RunRequest { spec, options }` envelope (kind-tagged over
//! facility / sweep / site / site_sweep) and `execute` routes it through
//! the shared engines. The historical per-kind `run_*` functions remain
//! as deprecated wrappers. Behind the `serve` cargo feature, the same
//! envelope is the wire schema of the live planning service
//! (`powertrace serve`, module `serve`).
//!
//! See `examples/quickstart.rs` for the five-line path from a scenario to a
//! facility load shape, and `examples/sweep_grid.rs` for a whole scenario
//! family in one call.
//!
//! # Core/host split
//!
//! The crate is a pure generation core wrapped in a host shell. Everything
//! the engine reads arrives through [`source::ArtifactSource`] (bytes in),
//! everything it writes leaves through [`export::TraceSink`] (windows
//! out), and thread fan-out rides the [`util::threadpool::Executor`] seam
//! — so the core has no `std::fs`, `std::thread`, or clock dependence.
//! The filesystem/threadpool/CLI shell sits behind the default `host`
//! cargo feature; `--no-default-features` builds the same byte-identical
//! engine for any target, including `wasm32-unknown-unknown`. See
//! `docs/ARCHITECTURE.md` §"Core/host split" for the seam map.

// Clippy runs as a CI gate (`cargo clippy -- -D warnings`). Correctness
// lints stay on; the style lints below conflict with deliberate choices —
// index-heavy kernel loops whose explicit accumulation order *is* the
// bit-identity contract (`classifier/`), and many-argument pipeline
// plumbing that threads per-worker scratch instead of allocating.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::manual_div_ceil,
    clippy::type_complexity,
    clippy::len_without_is_empty,
    clippy::new_without_default,
    clippy::manual_memcpy,
    clippy::excessive_precision,
    clippy::collapsible_if,
    clippy::collapsible_else_if,
    clippy::format_push_string,
    clippy::uninlined_format_args,
    clippy::useless_format,
    clippy::redundant_closure
)]

pub mod util {
    #[cfg(feature = "host")]
    pub mod cli;
    pub mod json;
    pub mod rng;
    pub mod threadpool;
}

pub mod aggregate;
pub mod api;
pub mod artifacts;
pub mod baselines;
#[cfg(feature = "host")]
pub mod benchutil;
pub mod catalog;
pub mod classifier;
pub mod config;
pub mod coordinator;
#[cfg(feature = "host")]
pub mod experiments;
pub mod export;
pub mod metrics;
pub mod robust;
pub mod runtime;
pub mod scenarios;
#[cfg(feature = "serve")]
pub mod serve;
pub mod shard;
pub mod site;
pub mod source;
pub mod states;
pub mod surrogate;
pub mod synth;
pub mod testbed;
pub mod testutil;
pub mod workload;
