//! Micro-benchmark harness for the `benches/` targets (criterion is
//! unavailable offline — DESIGN.md §3).
//!
//! Provides warmup, adaptive iteration counts, and mean/p50/p95 reporting in
//! a stable text format that EXPERIMENTS.md quotes. Benches are built with
//! `harness = false` and call [`Bench::run`] per case.

use std::time::{Duration, Instant};

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "bench {:<44} iters={:<5} mean={:>12?} p50={:>12?} p95={:>12?} min={:>12?}",
            self.name, self.iters, self.mean, self.p50, self.p95, self.min
        );
    }
}

pub struct Bench {
    /// Target total measurement time per case.
    pub budget: Duration,
    /// Hard cap on iterations.
    pub max_iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        // `cargo bench -- --fast` or POWERTRACE_BENCH_FAST=1 shrink budgets
        // (used in CI / the final log capture).
        let fast = std::env::var("POWERTRACE_BENCH_FAST").is_ok()
            || std::env::args().any(|a| a == "--fast");
        Bench {
            budget: if fast { Duration::from_millis(300) } else { Duration::from_secs(2) },
            max_iters: if fast { 20 } else { 200 },
        }
    }
}

impl Bench {
    /// Measure `f`, which performs one logical iteration and returns a value
    /// that is black-boxed to prevent dead-code elimination.
    pub fn run<T, F: FnMut() -> T>(&self, name: &str, mut f: F) -> BenchResult {
        // Warmup: one untimed call (also forces lazy init like PJRT compile).
        black_box(f());

        let mut samples: Vec<Duration> = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.budget && samples.len() < self.max_iters {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed());
        }
        if samples.is_empty() {
            samples.push(Duration::ZERO);
        }
        let mut sorted = samples.clone();
        sorted.sort();
        let total: Duration = samples.iter().sum();
        let result = BenchResult {
            name: name.to_string(),
            iters: samples.len(),
            mean: total / samples.len() as u32,
            p50: sorted[sorted.len() / 2],
            p95: sorted[((sorted.len() as f64 * 0.95) as usize).min(sorted.len() - 1)],
            min: sorted[0],
        };
        result.report();
        result
    }
}

/// Opaque value sink (std::hint::black_box is stable since 1.66).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Print a bench section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_reports() {
        let b = Bench { budget: Duration::from_millis(20), max_iters: 10 };
        let r = b.run("noop", || 42u64);
        assert!(r.iters >= 1 && r.iters <= 10);
        assert!(r.p50 <= r.p95);
        assert!(r.min <= r.mean * 2);
    }
}
