//! Micro-benchmark harness for the `benches/` targets (criterion is
//! unavailable offline — DESIGN.md §3).
//!
//! Provides warmup, adaptive iteration counts, and mean/p50/p95 reporting in
//! a stable text format that EXPERIMENTS.md quotes. Benches are built with
//! `harness = false` and call [`Bench::run`] per case.
//!
//! Throughput-tracking benches additionally emit machine-readable entries
//! into `BENCH_facility.json` via [`write_bench_json`], so the perf
//! trajectory (servers/sec, sequential vs batched) is comparable across
//! PRs and CI runs.

use crate::util::json::{self, Json};
use std::collections::BTreeMap;
use std::path::Path;
use std::time::{Duration, Instant};

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "bench {:<44} iters={:<5} mean={:>12?} p50={:>12?} p95={:>12?} min={:>12?}",
            self.name, self.iters, self.mean, self.p50, self.p95, self.min
        );
    }
}

pub struct Bench {
    /// Target total measurement time per case.
    pub budget: Duration,
    /// Hard cap on iterations.
    pub max_iters: usize,
}

/// `cargo bench -- --fast` or POWERTRACE_BENCH_FAST=1 shrink budgets
/// (used by the CI bench-smoke job and the final log capture).
pub fn fast_mode() -> bool {
    std::env::var("POWERTRACE_BENCH_FAST").is_ok() || std::env::args().any(|a| a == "--fast")
}

impl Default for Bench {
    fn default() -> Self {
        let fast = fast_mode();
        Bench {
            budget: if fast { Duration::from_millis(300) } else { Duration::from_secs(2) },
            max_iters: if fast { 20 } else { 200 },
        }
    }
}

impl Bench {
    /// A bench with an explicit full-speed budget that still collapses to a
    /// single-iteration smoke run under [`fast_mode`] — heavyweight benches
    /// should construct through this so `cargo bench` can't bit-rot in CI
    /// without costing CI minutes.
    pub fn budgeted(full_budget: Duration, max_iters: usize) -> Bench {
        if fast_mode() {
            Bench { budget: Duration::from_millis(200), max_iters: max_iters.min(2) }
        } else {
            Bench { budget: full_budget, max_iters }
        }
    }

    /// Measure `f`, which performs one logical iteration and returns a value
    /// that is black-boxed to prevent dead-code elimination.
    pub fn run<T, F: FnMut() -> T>(&self, name: &str, mut f: F) -> BenchResult {
        // Warmup: one untimed call (also forces lazy init like PJRT compile).
        black_box(f());

        let mut samples: Vec<Duration> = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.budget && samples.len() < self.max_iters {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed());
        }
        if samples.is_empty() {
            samples.push(Duration::ZERO);
        }
        let mut sorted = samples.clone();
        sorted.sort();
        let total: Duration = samples.iter().sum();
        let result = BenchResult {
            name: name.to_string(),
            iters: samples.len(),
            mean: total / samples.len() as u32,
            p50: sorted[sorted.len() / 2],
            p95: sorted[((sorted.len() as f64 * 0.95) as usize).min(sorted.len() - 1)],
            min: sorted[0],
        };
        result.report();
        result
    }
}

/// Opaque value sink (std::hint::black_box is stable since 1.66).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// One machine-readable throughput record for [`write_bench_json`].
pub struct BenchEntry {
    /// Stable bench-case key, e.g. `"facility_batched"`.
    pub name: String,
    /// Mean wall-clock seconds per iteration.
    pub mean_s: f64,
    /// Servers generated per wall-second, where meaningful.
    pub servers_per_sec: Option<f64>,
}

impl BenchEntry {
    /// Entry from a [`BenchResult`] plus the number of servers one
    /// iteration generates.
    pub fn from_result(name: &str, r: &BenchResult, servers_per_iter: Option<f64>) -> BenchEntry {
        let mean_s = r.mean.as_secs_f64();
        BenchEntry {
            name: name.to_string(),
            mean_s,
            servers_per_sec: servers_per_iter.map(|n| if mean_s > 0.0 { n / mean_s } else { 0.0 }),
        }
    }
}

/// Merge throughput entries into a JSON report (`bench name → {mean_s,
/// servers_per_sec}`). Existing entries from other bench binaries are
/// preserved, so every bench target can contribute to one
/// `BENCH_facility.json`.
pub fn write_bench_json(path: &Path, entries: &[BenchEntry]) -> anyhow::Result<()> {
    let mut root = match json::parse_file(path) {
        Ok(Json::Obj(o)) => o,
        _ => BTreeMap::new(),
    };
    for e in entries {
        let mut o = BTreeMap::new();
        o.insert("mean_s".to_string(), Json::Num(e.mean_s));
        if let Some(sps) = e.servers_per_sec {
            o.insert("servers_per_sec".to_string(), Json::Num(sps));
        }
        root.insert(e.name.clone(), Json::Obj(o));
    }
    json::write_file(path, &Json::Obj(root))?;
    Ok(())
}

/// Print a bench section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_reports() {
        let b = Bench { budget: Duration::from_millis(20), max_iters: 10 };
        let r = b.run("noop", || 42u64);
        assert!(r.iters >= 1 && r.iters <= 10);
        assert!(r.p50 <= r.p95);
        assert!(r.min <= r.mean * 2);
    }

    #[test]
    fn bench_json_merges_entries_across_writes() {
        let dir = std::env::temp_dir().join("powertrace_test_benchjson");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_facility.json");
        let _ = std::fs::remove_file(&path);
        write_bench_json(
            &path,
            &[BenchEntry { name: "a".into(), mean_s: 1.5, servers_per_sec: Some(8.0) }],
        )
        .unwrap();
        write_bench_json(
            &path,
            &[BenchEntry { name: "b".into(), mean_s: 0.5, servers_per_sec: None }],
        )
        .unwrap();
        let v = json::parse_file(&path).unwrap();
        assert_eq!(v.get("a").unwrap().f64_field("mean_s").unwrap(), 1.5);
        assert_eq!(v.get("a").unwrap().f64_field("servers_per_sec").unwrap(), 8.0);
        assert_eq!(v.get("b").unwrap().f64_field("mean_s").unwrap(), 0.5);
        assert!(v.get("b").unwrap().get_opt("servers_per_sec").is_none());
    }

    #[test]
    fn entry_from_result_computes_rate() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            mean: Duration::from_millis(500),
            p50: Duration::from_millis(500),
            p95: Duration::from_millis(500),
            min: Duration::from_millis(500),
        };
        let e = BenchEntry::from_result("x", &r, Some(16.0));
        assert!((e.mean_s - 0.5).abs() < 1e-12);
        assert!((e.servers_per_sec.unwrap() - 32.0).abs() < 1e-9);
    }
}
