//! Generation pipeline implementation.
//!
//! Facility generation runs rack-by-rack. Within a rack every server shares
//! one serving configuration ([`crate::config::ServerAssignment`] is
//! rack-granular), so on the native backend the rack's servers are scanned
//! through the classifier **in lockstep** as one batched call
//! (`NativeBiGru::probs_batch_tiled`, §Perf in docs/ARCHITECTURE.md):
//! per-timestep matrix-vector products become `[3H, H] × [H, B]` GEMMs and
//! every weight load is amortized over the rack. Because the batched engine
//! is bit-identical per lane to the sequential path, the rack-granular
//! deterministic fold (see [`Generator::facility_shared`]) is preserved:
//! batched and sequential generation produce byte-identical facility
//! traces for a given `(spec, seed)`.
//!
//! All per-server scratch (classifier arena, feature buffers, sampled
//! states, power buffer) lives in one [`WorkerScratch`] per worker thread —
//! steady-state generation performs no per-server heap allocation.

use super::FacilityResult;
use crate::aggregate::{FacilityAccumulator, StreamingFacilityAccumulator};
use crate::artifacts::{ArtifactStore, ConfigArtifact};
use crate::catalog::Catalog;
use crate::classifier::native::BiGruWeights;
use crate::classifier::{
    pjrt::{AnyClassifier, PjrtBiGru},
    BatchScan, LaneFeatures, NativeBiGru, ScratchArena, StateClassifier, BATCH_TILE,
};
use crate::config::{ScenarioSpec, WorkloadSpec};
use crate::runtime::Executable;
#[cfg(feature = "host")]
use crate::runtime::Runtime;
use crate::source::ArtifactSource;
use crate::surrogate::{
    features_interleaved_into, simulate_queue_policy, OccupancyEvents, QueuePolicy,
};
use crate::synth::{
    sample_power, sample_power_into, sample_power_resume, sample_states_lane_into,
    sample_states_masked_into,
};
use crate::util::rng::Rng;
use crate::util::threadpool::{default_workers, parallel_fold};
use crate::workload::{
    poisson_arrivals, replay, token_arrivals, DiurnalProfile, LengthSampler, Mmpp, Schedule,
    TokenLengthSampler, TokenLengths, TrafficMode,
};
use anyhow::{ensure, Context, Result};
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, OnceLock};

/// Per-server RNG stream labels. After the queue simulation, each server's
/// RNG forks into **independent** state-sampling and power-sampling
/// streams (rather than one stream consumed states-then-power). Both the
/// one-shot and the windowed paths draw each stream strictly in time
/// order, which is what lets the windowed path interleave state and power
/// sampling per window while staying bit-identical to the one-shot path.
const RNG_STATES: u64 = 0x57A7E5;
const RNG_POWER: u64 = 0x90A3E6;

/// Default cap on servers per batched classifier call. Racks wider than
/// this are split into consecutive sub-batches (still in server order);
/// bounded B keeps the lane-major working set L2-resident.
pub const DEFAULT_MAX_BATCH: usize = 32;

/// Which classifier backend the generator uses.
pub enum Backend {
    /// Pure-Rust BiGRU (portable, no artifacts HLO needed beyond weights).
    Native,
    /// AOT-compiled XLA artifact through PJRT (the production path).
    Pjrt(Arc<Executable>),
}

/// One generated server trace plus its intermediate products (useful for
/// figures and diagnostics).
pub struct ServerTrace {
    pub power_w: Vec<f32>,
    pub a: Vec<f32>,
    pub states: Vec<usize>,
}

/// A configuration ready for generation: its artifact plus a constructed
/// classifier. Cached on the [`Generator`] so multi-scenario drivers (the
/// sweep engine, repeated `facility` calls) never rebuild per-config state.
/// For the native backend this includes the packed/transposed parameter
/// blocks the scan kernels execute from — built once per configuration.
pub struct PreparedConfig {
    pub art: Arc<ConfigArtifact>,
    pub cls: AnyClassifier,
}

/// Reusable per-worker scratch for trace generation: the classifier's
/// [`ScratchArena`] plus the pipeline-side buffers (feature rows, sampled
/// states, power) that the pre-batching code allocated fresh per server.
#[derive(Default)]
pub struct WorkerScratch {
    /// Classifier scratch (shared by sequential and batched paths).
    pub arena: ScratchArena,
    /// Occupancy difference-array for feature building.
    diff: Vec<i32>,
    /// Interleaved `[T, 2]` features for the sequential path.
    feats: Vec<f32>,
    /// Sequential-path posterior buffer.
    probs: Vec<f32>,
    /// State buffer: one full trajectory (sequential path) or one streamed
    /// tile (windowed path).
    states: Vec<usize>,
    /// Per-lane interleaved features (batched path).
    lane_feats: Vec<Vec<f32>>,
    /// Per-lane sampled state trajectories.
    lane_states: Vec<Vec<usize>>,
    /// Per-lane state-sampling RNG streams (fork [`RNG_STATES`]).
    lane_rngs: Vec<Rng>,
    /// Per-lane power-sampling RNG streams (fork [`RNG_POWER`]).
    lane_prngs: Vec<Rng>,
    /// Server index of each active lane.
    lane_servers: Vec<usize>,
    /// Power-synthesis buffer (one server at a time).
    power: Vec<f32>,
}

impl WorkerScratch {
    pub fn new() -> WorkerScratch {
        WorkerScratch::default()
    }
}

/// The trace generator: catalog + artifacts + classifier backend.
pub struct Generator {
    pub cat: Catalog,
    pub store: ArtifactStore,
    backend: Backend,
    configs: BTreeMap<String, Arc<ConfigArtifact>>,
    /// Per-config (artifact, classifier) pairs shared across runs; see
    /// [`Generator::prepare`].
    prepared: BTreeMap<String, Arc<PreparedConfig>>,
    /// Parsed replay schedules keyed by path. A replay scenario's base
    /// schedule is immutable, so a 1 000-server facility performs exactly
    /// one file read + parse per path instead of one per server. Each path
    /// gets its own [`ReplaySlot`] so a cold load of one path never blocks
    /// servers replaying an already-cached other path.
    replay_cache: Mutex<BTreeMap<String, Arc<ReplaySlot>>>,
    /// Byte provider for replay traces and token-empirical length
    /// distributions. Hosts default to a filesystem passthrough (paths in
    /// specs keep their historical meaning); core-only builds default to
    /// an empty in-memory source — inject one via
    /// [`Generator::set_replay_source`].
    replay_source: Arc<dyn ArtifactSource>,
}

/// Per-path replay-cache slot: `init` serializes the (at most one
/// successful) parse of this path, `cell` publishes the result. The global
/// map lock is only ever held for the slot lookup — never across file I/O.
#[derive(Default)]
struct ReplaySlot {
    init: Mutex<()>,
    cell: OnceLock<Arc<Schedule>>,
}

impl Generator {
    /// The build's default replay-trace byte provider: filesystem
    /// passthrough on hosts, an empty in-memory source otherwise.
    fn default_replay_source() -> Arc<dyn ArtifactSource> {
        #[cfg(feature = "host")]
        {
            Arc::new(crate::source::FsSource::passthrough())
        }
        #[cfg(not(feature = "host"))]
        {
            Arc::new(crate::source::MemSource::new())
        }
    }

    /// Open with the native classifier backend.
    #[cfg(feature = "host")]
    pub fn native() -> Result<Generator> {
        let cat = Catalog::load_default()?;
        let store = ArtifactStore::open_default()?;
        Ok(Self::native_with(cat, store))
    }

    /// Native-backend generator over an explicit catalog + artifact store
    /// (tests, benchmarks, and embedders inject synthetic or in-memory
    /// stores through this — it performs no I/O itself).
    pub fn native_with(cat: Catalog, store: ArtifactStore) -> Generator {
        Generator {
            cat,
            store,
            backend: Backend::Native,
            configs: BTreeMap::new(),
            prepared: BTreeMap::new(),
            replay_cache: Mutex::new(BTreeMap::new()),
            replay_source: Self::default_replay_source(),
        }
    }

    /// Replace the replay-trace byte provider (and invalidate the parse
    /// cache — cached schedules came from the previous source).
    pub fn set_replay_source(&mut self, src: Arc<dyn ArtifactSource>) {
        self.replay_cache.lock().unwrap().clear();
        self.replay_source = src;
    }

    /// Open with the PJRT backend (compiles the HLO artifact once).
    #[cfg(feature = "host")]
    pub fn pjrt() -> Result<Generator> {
        let cat = Catalog::load_default()?;
        let store = ArtifactStore::open_default()?;
        let rt = Runtime::cpu()?;
        let exe = Arc::new(rt.load_hlo_text(&store.hlo_path())?);
        Ok(Generator {
            cat,
            store,
            backend: Backend::Pjrt(exe),
            configs: BTreeMap::new(),
            prepared: BTreeMap::new(),
            replay_cache: Mutex::new(BTreeMap::new()),
            replay_source: Self::default_replay_source(),
        })
    }

    /// Backend selection by name ("native" | "pjrt").
    #[cfg(feature = "host")]
    pub fn with_backend(name: &str) -> Result<Generator> {
        match name {
            "native" => Self::native(),
            "pjrt" => Self::pjrt(),
            other => anyhow::bail!("unknown backend '{other}' (native|pjrt)"),
        }
    }

    /// Load (and cache) a configuration artifact.
    pub fn config(&mut self, config_id: &str) -> Result<Arc<ConfigArtifact>> {
        if let Some(a) = self.configs.get(config_id) {
            return Ok(a.clone());
        }
        let a = Arc::new(self.store.load_config(config_id)?);
        self.configs.insert(config_id.to_string(), a.clone());
        Ok(a)
    }

    /// Build a classifier for one configuration's weights.
    pub fn classifier(&self, art: &ConfigArtifact) -> Result<AnyClassifier> {
        let weights = BiGruWeights::new(
            self.store.manifest.hidden,
            self.store.manifest.k_max,
            art.weights.clone(),
        )?;
        Ok(match &self.backend {
            Backend::Native => AnyClassifier::Native(NativeBiGru::new(weights)),
            Backend::Pjrt(exe) => AnyClassifier::Pjrt(
                PjrtBiGru::new(
                    exe.clone(),
                    art.weights.clone(),
                    self.store.manifest.chunk,
                    self.store.manifest.k_max,
                )?
                .chunked(),
            ),
        })
    }

    /// Generate one server's power trace from an arrival schedule
    /// (the paper's per-server pipeline, §3.3).
    pub fn server_trace(
        &self,
        art: &ConfigArtifact,
        classifier: &AnyClassifier,
        schedule: &Schedule,
        horizon_s: f64,
        dt_s: f64,
        rng: &mut Rng,
    ) -> Result<ServerTrace> {
        let mut scratch = WorkerScratch::new();
        self.server_trace_with(art, classifier, schedule, horizon_s, dt_s, rng, &mut scratch)
    }

    /// [`Generator::server_trace`] drawing every intermediate buffer from a
    /// reusable [`WorkerScratch`] — the zero-allocation form the facility
    /// fold drives (only the returned trace itself is freshly allocated).
    #[allow(clippy::too_many_arguments)]
    pub fn server_trace_with(
        &self,
        art: &ConfigArtifact,
        classifier: &AnyClassifier,
        schedule: &Schedule,
        horizon_s: f64,
        dt_s: f64,
        rng: &mut Rng,
        scratch: &mut WorkerScratch,
    ) -> Result<ServerTrace> {
        let policy = QueuePolicy::slots(self.cat.campaign.max_batch);
        self.server_trace_policy(art, classifier, schedule, horizon_s, dt_s, policy, rng, scratch)
    }

    /// [`Generator::server_trace_with`] under an explicit queue batching
    /// policy (token-level workloads override slot count / token budget;
    /// see [`Generator::queue_policy_for`]). With the default policy this
    /// is bit-identical to `server_trace_with`.
    #[allow(clippy::too_many_arguments)]
    pub fn server_trace_policy(
        &self,
        art: &ConfigArtifact,
        classifier: &AnyClassifier,
        schedule: &Schedule,
        horizon_s: f64,
        dt_s: f64,
        policy: QueuePolicy,
        rng: &mut Rng,
        scratch: &mut WorkerScratch,
    ) -> Result<ServerTrace> {
        let n_steps = (horizon_s / dt_s).round() as usize;
        let intervals = simulate_queue_policy(schedule, &art.surrogate, policy, rng);
        // Fork the post-queue RNG into independent state/power streams —
        // see [`RNG_STATES`]: the windowed path interleaves the two kinds
        // of draws per window, so they must not share a stream.
        let mut zrng = rng.fork(RNG_STATES);
        let mut prng = rng.fork(RNG_POWER);
        let WorkerScratch { arena, diff, feats, probs, states, .. } = scratch;
        features_interleaved_into(&intervals, n_steps, dt_s, diff, feats);
        match classifier.as_native() {
            Some(native) => native.probs_into(feats, n_steps, arena, probs)?,
            None => *probs = classifier.probs(feats, n_steps)?,
        }
        // Draw only from the live K states of this configuration (unused
        // logits were masked at training time; renormalization happens
        // inside the categorical draw).
        let k_max = classifier.k_max();
        sample_states_masked_into(probs, k_max, art.k, &mut zrng, states);
        let power_w = sample_power(states, &art.dict, art.mode, &mut prng);
        let a = (0..n_steps).map(|t| feats[2 * t]).collect();
        Ok(ServerTrace { power_w, a, states: states.clone() })
    }

    /// Build the per-server arrival schedule for a scenario.
    pub fn schedule_for(
        &self,
        spec: &ScenarioSpec,
        server_idx: usize,
        base_rng: &Rng,
    ) -> Result<Schedule> {
        let profile = self
            .cat
            .datasets
            .get(&spec.dataset)
            .with_context(|| format!("unknown dataset '{}'", spec.dataset))?;
        // Reasoning multiplier depends on the model this server runs.
        let cfg_id = spec.server_config.config_for(&spec.topology, server_idx).to_string();
        let cfg = self.cat.config(&cfg_id)?;
        let out_mult = if self.cat.model_of(cfg).reasoning {
            self.cat.campaign.reasoning_out_mult
        } else {
            1.0
        };
        let lengths = LengthSampler::from_profile(profile, out_mult);
        let mut rng = base_rng.fork(0xA21 ^ server_idx as u64);
        Ok(match &spec.workload {
            WorkloadSpec::Poisson { rate } => {
                poisson_arrivals(*rate, spec.horizon_s, &lengths, &mut rng)
            }
            WorkloadSpec::Mmpp { mean_rate, burstiness } => {
                Mmpp::bursty(*mean_rate, *burstiness).arrivals(spec.horizon_s, &lengths, &mut rng)
            }
            WorkloadSpec::Diurnal { base_rate, swing, peak_hour, burst_sigma, mode } => {
                let p = DiurnalProfile {
                    base_rate: *base_rate,
                    swing: *swing,
                    peak_hour: *peak_hour,
                    burst_sigma: *burst_sigma,
                    burst_tau_s: 300.0,
                    mode: *mode,
                };
                p.schedule(server_idx, spec.horizon_s, &lengths, base_rng)
            }
            WorkloadSpec::Replay { path, offset_s } => {
                let base = self.replay_base(path)?;
                // Per-server random offset (paper §4.4) wrapped on horizon.
                let off = if *offset_s > 0.0 { rng.range(0.0, *offset_s) } else { 0.0 };
                let mut shifted: Schedule = base
                    .iter()
                    .map(|r| {
                        let mut r2 = *r;
                        r2.arrival_s = (r.arrival_s + off) % spec.horizon_s;
                        r2
                    })
                    .collect();
                shifted.sort_by(|a, b| a.arrival_s.partial_cmp(&b.arrival_s).unwrap());
                shifted
            }
            WorkloadSpec::Token { rate, lengths, .. } => {
                // Empirical length distributions resample (n_in, n_out)
                // pairs from a recorded trace; the parsed trace is shared
                // through the same per-path cache the replay workload uses.
                let sampler = if let TokenLengths::Empirical { path } = lengths {
                    TokenLengthSampler::empirical(self.replay_base(path)?)
                        .map_err(anyhow::Error::msg)?
                } else {
                    lengths
                        .sampler_local()
                        .expect("non-empirical token lengths build locally")
                };
                token_arrivals(*rate, spec.horizon_s, &sampler, &mut rng)
            }
        })
    }

    /// Resolve the queue batching policy for a scenario. Token workloads
    /// carry their own `max_batch` (0 ⇒ the campaign default) and optional
    /// token budget; every other workload uses the campaign's fixed batch
    /// capacity, exactly as before the token axis existed.
    pub fn queue_policy_for(&self, spec: &ScenarioSpec) -> QueuePolicy {
        match &spec.workload {
            WorkloadSpec::Token { max_batch, token_budget, .. } => QueuePolicy {
                max_batch: if *max_batch == 0 { self.cat.campaign.max_batch } else { *max_batch },
                token_budget: if *token_budget == 0 { None } else { Some(*token_budget) },
            },
            _ => QueuePolicy::slots(self.cat.campaign.max_batch),
        }
    }

    /// Number of distinct trace paths currently parsed into the shared
    /// replay cache (replay workloads and token-empirical length
    /// distributions both load through it). Test observability hook for
    /// the parse-once-per-path contract.
    pub fn cached_replay_paths(&self) -> usize {
        self.replay_cache.lock().unwrap().len()
    }

    /// Load-and-cache the immutable base schedule of a replay trace.
    ///
    /// Double-checked per-path locking: the global map lock is held only
    /// for the slot lookup (never across file I/O), so a cold load of path
    /// A never blocks workers replaying an already-cached path B. The
    /// per-path `init` mutex still guarantees each path is parsed
    /// **exactly once** on the success path (a failed parse releases the
    /// slot for the next caller to retry — the run is aborting anyway).
    fn replay_base(&self, path: &str) -> Result<Arc<Schedule>> {
        let slot = {
            let mut cache = self.replay_cache.lock().unwrap();
            cache.entry(path.to_string()).or_default().clone()
        };
        if let Some(s) = slot.cell.get() {
            return Ok(s.clone());
        }
        let _init = slot.init.lock().unwrap();
        if let Some(s) = slot.cell.get() {
            return Ok(s.clone());
        }
        let bytes = self.replay_source.read(path)?;
        let s = Arc::new(replay::from_named_bytes(path, &bytes)?);
        let _ = slot.cell.set(s.clone());
        Ok(s)
    }

    /// Load-or-build the cached (artifact, classifier) pair for a config.
    ///
    /// This is the per-configuration state that used to be rebuilt inside
    /// every `facility()` call; hoisting it onto the generator lets
    /// multi-scenario drivers (the [`crate::scenarios`] sweep engine) share
    /// it across an arbitrary number of runs.
    pub fn prepare(&mut self, config_id: &str) -> Result<Arc<PreparedConfig>> {
        if let Some(p) = self.prepared.get(config_id) {
            return Ok(p.clone());
        }
        let art = self.config(config_id)?;
        let cls = self.classifier(&art)?;
        let p = Arc::new(PreparedConfig { art, cls });
        self.prepared.insert(config_id.to_string(), p.clone());
        Ok(p)
    }

    /// Prepare every configuration a scenario actually uses (a `PerRack`
    /// list longer than the rack count never reaches its tail).
    pub fn prepare_for(&mut self, spec: &ScenarioSpec) -> Result<()> {
        for id in spec.server_config.config_ids_used(&spec.topology) {
            self.prepare(&id)?;
        }
        Ok(())
    }

    /// [`Generator::prepare_for`] over several scenarios at once — the
    /// multi-facility hoist the site composition engine ([`crate::site`])
    /// shares with the sweep engine: every configuration any facility
    /// references is prepared exactly once, and the N concurrent
    /// facility streams then run over one shared read-only cache.
    pub fn prepare_for_many<'a, I>(&mut self, specs: I) -> Result<()>
    where
        I: IntoIterator<Item = &'a ScenarioSpec>,
    {
        for spec in specs {
            self.prepare_for(spec)?;
        }
        Ok(())
    }

    /// Lookup an already-prepared configuration (shared, read-only).
    pub fn get_prepared(&self, config_id: &str) -> Option<Arc<PreparedConfig>> {
        self.prepared.get(config_id).cloned()
    }

    /// Ids of every configuration currently prepared on this generator
    /// (sorted — `prepared` is a BTree). The serve layer reports this in
    /// `/healthz` and uses it to re-warm after a store refresh.
    pub fn prepared_ids(&self) -> Vec<String> {
        self.prepared.keys().cloned().collect()
    }

    /// Swap in a (re-opened) artifact store: drop every cached artifact,
    /// prepared pair, and parsed replay schedule — they all came from the
    /// old store's bytes — then re-prepare the configurations that were
    /// prepared before, so a long-lived service stays warm across artifact
    /// refreshes. Returns the re-prepared ids; a config that vanished from
    /// the new store fails the refresh (and leaves the generator with
    /// whatever subset was re-prepared — callers treat that as fatal).
    pub fn refresh_store(&mut self, store: ArtifactStore) -> Result<Vec<String>> {
        let warm = self.prepared_ids();
        self.store = store;
        self.configs.clear();
        self.prepared.clear();
        self.replay_cache.lock().unwrap().clear();
        for id in &warm {
            self.prepare(id)?;
        }
        Ok(warm)
    }

    /// Generate a full facility run: every server in the topology, in
    /// parallel, reduced into a streaming accumulator.
    pub fn facility(&mut self, spec: &ScenarioSpec, dt_s: f64, workers: usize) -> Result<FacilityResult> {
        self.prepare_for(spec)?;
        self.facility_shared(spec, dt_s, workers)
    }

    /// [`Generator::facility`] against the shared prepared-config cache,
    /// with the default rack-batching width.
    pub fn facility_shared(&self, spec: &ScenarioSpec, dt_s: f64, workers: usize) -> Result<FacilityResult> {
        self.facility_shared_batched(spec, dt_s, workers, DEFAULT_MAX_BATCH)
    }

    /// Facility generation over the shared prepared-config cache with an
    /// explicit batching width.
    ///
    /// Takes `&self` so many scenarios can run concurrently over one
    /// generator; every configuration the scenario references must have
    /// been [`Generator::prepare`]d first (the `&mut` wrapper
    /// [`Generator::facility`] does this automatically).
    ///
    /// `max_batch` caps how many of a rack's servers are scanned through
    /// the classifier in one batched call (`0` = default). `1` forces the
    /// sequential per-server path. **Every width produces byte-identical
    /// output**: the batched classifier is bit-identical per lane to the
    /// sequential one, per-server RNG streams are independent forks
    /// consumed in the same order, and the accumulator fold below never
    /// re-associates.
    ///
    /// The result is bit-identical for a given `(spec, spec.seed)`
    /// regardless of `workers` or thread scheduling: work is partitioned at
    /// **rack** granularity, each rack's servers fold into that rack's
    /// buffer in server-index order, and the final merge only combines
    /// disjoint racks — no floating-point sum ever re-associates.
    pub fn facility_shared_batched(
        &self,
        spec: &ScenarioSpec,
        dt_s: f64,
        workers: usize,
        max_batch: usize,
    ) -> Result<FacilityResult> {
        anyhow::ensure!(
            dt_s.is_finite() && dt_s > 0.0,
            "dt must be a positive number of seconds (got {dt_s})"
        );
        let n_racks = spec.topology.n_racks();
        let per_rack = spec.topology.servers_per_rack;
        let n_steps = (spec.horizon_s / dt_s).round() as usize;
        anyhow::ensure!(
            n_steps > 0,
            "horizon {}s too short for dt {dt_s}s (zero samples)",
            spec.horizon_s
        );
        let max_batch = if max_batch == 0 { DEFAULT_MAX_BATCH } else { max_batch };
        let mut table: BTreeMap<String, Arc<PreparedConfig>> = BTreeMap::new();
        for id in spec.server_config.config_ids_used(&spec.topology) {
            let p = self.get_prepared(&id).with_context(|| {
                format!("config '{id}' not prepared (call Generator::prepare first)")
            })?;
            table.insert(id, p);
        }
        let base_rng = Rng::new(spec.seed);
        let policy = self.queue_policy_for(spec);
        let workers = if workers == 0 { default_workers() } else { workers };
        let errors = Mutex::new(Vec::<String>::new());
        let (acc, _scratch) = parallel_fold(
            n_racks,
            workers,
            || {
                (
                    FacilityAccumulator::new(spec.topology, n_steps, spec.p_base_w),
                    WorkerScratch::new(),
                )
            },
            |(acc, scratch), rack| {
                let s_begin = rack * per_rack;
                let id = spec.server_config.config_for(&spec.topology, s_begin);
                let p = &table[id];
                match (p.cls.as_native(), max_batch > 1) {
                    (Some(native), true) => {
                        let mut s0 = s_begin;
                        while s0 < s_begin + per_rack {
                            let s1 = (s0 + max_batch).min(s_begin + per_rack);
                            self.generate_batch(
                                spec, s0, s1, n_steps, dt_s, p, native, &base_rng, scratch,
                                acc, &errors,
                            );
                            s0 = s1;
                        }
                    }
                    // Sequential fallback: PJRT backend (fixed-shape
                    // artifact) or an explicit max_batch of 1.
                    _ => {
                        for s in s_begin..s_begin + per_rack {
                            let result = (|| -> Result<()> {
                                let sched = self.schedule_for(spec, s, &base_rng)?;
                                let mut rng = base_rng.fork(0x5E21 ^ s as u64);
                                let tr = self.server_trace_policy(
                                    &p.art,
                                    &p.cls,
                                    &sched,
                                    spec.horizon_s,
                                    dt_s,
                                    policy,
                                    &mut rng,
                                    scratch,
                                )?;
                                acc.add_server(s, &tr.power_w)?;
                                Ok(())
                            })();
                            if let Err(e) = result {
                                errors.lock().unwrap().push(format!("server {s}: {e:#}"));
                            }
                        }
                    }
                }
            },
            |(mut a, sa), (b, _sb)| {
                a.merge(&b);
                (a, sa)
            },
        );
        let errs = errors.into_inner().unwrap();
        if !errs.is_empty() {
            anyhow::bail!("facility generation failed: {}", errs.join("; "));
        }
        Ok(FacilityResult { scenario: spec.clone(), dt_s, acc })
    }

    /// Generate servers `s0..s1` (one rack's same-config slice) through one
    /// batched classifier call, sampling states as posterior tiles stream
    /// out and folding power traces in server-index order.
    #[allow(clippy::too_many_arguments)]
    fn generate_batch(
        &self,
        spec: &ScenarioSpec,
        s0: usize,
        s1: usize,
        n_steps: usize,
        dt_s: f64,
        p: &PreparedConfig,
        native: &NativeBiGru,
        base_rng: &Rng,
        scratch: &mut WorkerScratch,
        acc: &mut FacilityAccumulator,
        errors: &Mutex<Vec<String>>,
    ) {
        let WorkerScratch {
            arena, diff, lane_feats, lane_states, lane_rngs, lane_prngs, lane_servers, power, ..
        } = scratch;
        lane_rngs.clear();
        lane_prngs.clear();
        lane_servers.clear();
        while lane_feats.len() < s1 - s0 {
            lane_feats.push(Vec::new());
            lane_states.push(Vec::new());
        }
        // Stage 1 — per server, in index order: workload schedule →
        // surrogate queue → interleaved features. Each server's RNG stream
        // is forked exactly as in the sequential path and carried to the
        // sampling stages below.
        let policy = self.queue_policy_for(spec);
        for s in s0..s1 {
            let result = (|| -> Result<()> {
                let sched = self.schedule_for(spec, s, base_rng)?;
                let mut rng = base_rng.fork(0x5E21 ^ s as u64);
                let intervals = simulate_queue_policy(&sched, &p.art.surrogate, policy, &mut rng);
                let lane = lane_servers.len();
                features_interleaved_into(&intervals, n_steps, dt_s, diff, &mut lane_feats[lane]);
                lane_rngs.push(rng.fork(RNG_STATES));
                lane_prngs.push(rng.fork(RNG_POWER));
                lane_servers.push(s);
                Ok(())
            })();
            if let Err(e) = result {
                errors.lock().unwrap().push(format!("server {s}: {e:#}"));
            }
        }
        let b = lane_servers.len();
        if b == 0 {
            return;
        }
        for st in lane_states[..b].iter_mut() {
            st.clear();
        }
        // Stage 2 — one batched classifier scan for all lanes; states are
        // drawn from each posterior tile as it streams out (per lane in
        // time order, exactly the sequential draw sequence).
        let k = p.art.k;
        let k_max = p.cls.k_max();
        let refs: Vec<&[f32]> = lane_feats[..b].iter().map(|f| f.as_slice()).collect();
        let classified =
            native.probs_batch_tiled(&refs, n_steps, BATCH_TILE, arena, |_t0, n_rows, tile| {
                for (lane, states) in lane_states[..b].iter_mut().enumerate() {
                    sample_states_lane_into(tile, n_rows, lane, b, k_max, k, &mut lane_rngs[lane], states);
                }
                Ok(())
            });
        if let Err(e) = classified {
            errors
                .lock()
                .unwrap()
                .push(format!("servers {s0}..{s1}: batched classifier failed: {e:#}"));
            return;
        }
        // Stage 3 — per server, in index order: state-conditioned power
        // synthesis (from the dedicated power stream) and the
        // deterministic rack fold.
        for (lane, &s) in lane_servers.iter().enumerate() {
            sample_power_into(&lane_states[lane], &p.art.dict, p.art.mode, &mut lane_prngs[lane], power);
            if let Err(e) = acc.add_server(s, power) {
                errors.lock().unwrap().push(format!("server {s}: {e:#}"));
            }
        }
    }
    /// Windowed streaming facility generation (the >24 h path): prepares
    /// configurations, then drives [`Generator::facility_shared_windowed`].
    pub fn facility_windowed<F>(
        &mut self,
        spec: &ScenarioSpec,
        dt_s: f64,
        window_s: f64,
        workers: usize,
        max_batch: usize,
        sink: F,
    ) -> Result<()>
    where
        F: FnMut(&mut StreamingFacilityAccumulator) -> Result<()>,
    {
        self.prepare_for(spec)?;
        self.facility_shared_windowed(spec, dt_s, window_s, workers, max_batch, sink)
    }

    /// Facility generation with horizon-independent memory: every rack
    /// advances through the horizon **one `window_s` window at a time**, in
    /// lockstep, folding into a bounded [`StreamingFacilityAccumulator`]
    /// (O(racks × window) sample storage) that `sink` consumes after each
    /// window barrier — incremental CSV writers, streamed planning stats.
    ///
    /// **Bit-identity with the buffered path.** The windowed run produces,
    /// per rack element, the exact f64 sums of
    /// [`Generator::facility_shared_batched`] on the same `(spec, seed)`:
    /// the classifier windows reuse the same resumable checkpointed scan
    /// the one-shot path drives ([`NativeBiGru::begin_batch_scan`]), the
    /// per-window features are exact reconstructions from compressed
    /// occupancy events, and the per-server state/power RNG streams (see
    /// [`RNG_STATES`]) are each consumed strictly in time order in both
    /// modes. Peak/mean/energy statistics and exported CSV bytes therefore
    /// match the buffered export wherever both can run.
    ///
    /// Persistent per-rack state is O(workload events + windows·H·B) — the
    /// compressed arrival/occupancy timeline (independent of `dt_s`) plus
    /// the scan's window checkpoints; no per-timestep buffer survives a
    /// window. Requires the native backend (the PJRT artifact has a fixed
    /// one-shot shape).
    ///
    /// `sink` runs on the caller thread between window barriers; it reads
    /// the accumulator's window (`window_t0()`, `window_len()`,
    /// `rack_window(r)`, `fold_rows_site`).
    ///
    /// Takes `&self`: several windowed streams can run concurrently over
    /// one generator (each with its own accumulator and rack state) —
    /// the site composition engine ([`crate::site`]) drives one stream
    /// per facility in lockstep this way.
    pub fn facility_shared_windowed<F>(
        &self,
        spec: &ScenarioSpec,
        dt_s: f64,
        window_s: f64,
        workers: usize,
        max_batch: usize,
        mut sink: F,
    ) -> Result<()>
    where
        F: FnMut(&mut StreamingFacilityAccumulator) -> Result<()>,
    {
        let (n_steps, window, n_windows) = window_geometry(spec.horizon_s, dt_s, window_s)?;
        let n_racks = spec.topology.n_racks();
        let max_batch = if max_batch == 0 { DEFAULT_MAX_BATCH } else { max_batch };
        let mut table: BTreeMap<String, Arc<PreparedConfig>> = BTreeMap::new();
        for id in spec.server_config.config_ids_used(&spec.topology) {
            let p = self.get_prepared(&id).with_context(|| {
                format!("config '{id}' not prepared (call Generator::prepare first)")
            })?;
            ensure!(
                p.cls.as_native().is_some(),
                "windowed streaming generation requires the native backend \
                 (config '{id}' is prepared for PJRT)"
            );
            table.insert(id, p);
        }
        let base_rng = Rng::new(spec.seed);
        let workers = if workers == 0 { default_workers() } else { workers };
        let mut acc = StreamingFacilityAccumulator::new(spec.topology, window, spec.p_base_w);
        let slots: Vec<Mutex<Option<RackStream>>> =
            (0..n_racks).map(|_| Mutex::new(None)).collect();
        // One warm scratch arena per worker, shared across *all* windows —
        // per-window parallel passes borrow a free slot instead of
        // regrowing the (multi-MB) arenas thousands of times on a
        // week-long horizon.
        let scratch_pool: Vec<Mutex<WorkerScratch>> =
            (0..workers).map(|_| Mutex::new(WorkerScratch::new())).collect();
        let errors = Mutex::new(Vec::<String>::new());
        for wi in 0..n_windows {
            let t0 = wi * window;
            let n = (n_steps - t0).min(window);
            acc.begin_window(t0, n);
            let acc_ref = &acc;
            let errors_ref = &errors;
            let table_ref = &table;
            let slots_ref = &slots;
            let base_ref = &base_rng;
            let pool_ref = &scratch_pool;
            parallel_fold(
                n_racks,
                workers,
                || (),
                |_, rack| {
                    let mut scratch = lock_any_scratch(pool_ref);
                    let scratch = &mut *scratch;
                    let mut slot = slots_ref[rack].lock().unwrap();
                    if wi == 0 {
                        debug_assert!(slot.is_none());
                        match self.build_rack_stream(
                            spec, rack, n_steps, dt_s, window, max_batch, table_ref, base_ref,
                            scratch,
                        ) {
                            Ok(rs) => *slot = Some(rs),
                            Err(e) => {
                                errors_ref.lock().unwrap().push(format!("rack {rack}: {e:#}"));
                                return;
                            }
                        }
                    }
                    let Some(rs) = slot.as_mut() else { return };
                    if let Err(e) = self.scan_rack_window(rs, scratch, acc_ref, t0, n) {
                        errors_ref.lock().unwrap().push(format!("rack {rack}: {e:#}"));
                        *slot = None;
                    }
                },
                |a, _b| a,
            );
            {
                let errs = errors.lock().unwrap();
                if !errs.is_empty() {
                    anyhow::bail!("windowed facility generation failed: {}", errs.join("; "));
                }
            }
            sink(&mut acc)?;
        }
        Ok(())
    }

    /// Build one rack's resumable generation state: per server, the
    /// workload schedule → queue simulation → **compressed** occupancy
    /// events (the O(T) buffers are transient scratch), plus the forked
    /// state/power RNG streams and the classifier's backward-checkpoint
    /// prologue over the full horizon.
    #[allow(clippy::too_many_arguments)]
    fn build_rack_stream(
        &self,
        spec: &ScenarioSpec,
        rack: usize,
        n_steps: usize,
        dt_s: f64,
        window: usize,
        max_batch: usize,
        table: &BTreeMap<String, Arc<PreparedConfig>>,
        base_rng: &Rng,
        scratch: &mut WorkerScratch,
    ) -> Result<RackStream> {
        let per_rack = spec.topology.servers_per_rack;
        let s_begin = rack * per_rack;
        let id = spec.server_config.config_for(&spec.topology, s_begin);
        let prepared = table[id].clone();
        let native = prepared.cls.as_native().expect("checked in facility_shared_windowed");
        let mut batches = Vec::new();
        let mut s0 = s_begin;
        while s0 < s_begin + per_rack {
            let s1 = (s0 + max_batch).min(s_begin + per_rack);
            let mut events = Vec::with_capacity(s1 - s0);
            let mut zrngs = Vec::with_capacity(s1 - s0);
            let mut prngs = Vec::with_capacity(s1 - s0);
            let policy = self.queue_policy_for(spec);
            for s in s0..s1 {
                let sched = self
                    .schedule_for(spec, s, base_rng)
                    .with_context(|| format!("server {s}"))?;
                let mut rng = base_rng.fork(0x5E21 ^ s as u64);
                let intervals =
                    simulate_queue_policy(&sched, &prepared.art.surrogate, policy, &mut rng);
                events.push(OccupancyEvents::from_intervals_with(
                    &intervals,
                    n_steps,
                    dt_s,
                    &mut scratch.diff,
                ));
                zrngs.push(rng.fork(RNG_STATES));
                prngs.push(rng.fork(RNG_POWER));
            }
            let carries = vec![None; s1 - s0];
            let scan =
                native.begin_batch_scan(&EventLanes(&events), n_steps, window, &mut scratch.arena)?;
            batches.push(LaneBatch { s0, events, zrngs, prngs, carries, scan });
            s0 = s1;
        }
        Ok(RackStream { prepared, batches })
    }

    /// Advance one rack by one window: emit the window's posteriors from
    /// the resumable scan, sample each lane's states and power per
    /// streamed sub-tile (state and power streams each consumed in time
    /// order — the one-shot draw sequences), and fold into the window
    /// accumulator in server order.
    fn scan_rack_window(
        &self,
        rs: &mut RackStream,
        scratch: &mut WorkerScratch,
        acc: &StreamingFacilityAccumulator,
        t0: usize,
        n: usize,
    ) -> Result<()> {
        let RackStream { prepared, batches } = rs;
        let native = prepared.cls.as_native().expect("native-only path");
        let k = prepared.art.k;
        let k_max = prepared.cls.k_max();
        let WorkerScratch { arena, states, power, .. } = scratch;
        for lb in batches.iter_mut() {
            let LaneBatch { s0, events, zrngs, prngs, carries, scan } = lb;
            let b = events.len();
            ensure!(scan.next_t0() == t0, "rack scan out of lockstep at t0 {t0}");
            let src = EventLanes(events);
            let emitted = native.scan_window(scan, &src, arena, |abs_t0, rows, tile| {
                for lane in 0..b {
                    states.clear();
                    sample_states_lane_into(
                        tile, rows, lane, b, k_max, k, &mut zrngs[lane], states,
                    );
                    sample_power_resume(
                        states,
                        &prepared.art.dict,
                        prepared.art.mode,
                        &mut prngs[lane],
                        &mut carries[lane],
                        power,
                    );
                    acc.add_server_tile(*s0 + lane, abs_t0 - t0, power)?;
                }
                Ok(())
            })?;
            ensure!(emitted == n, "rack window emitted {emitted} steps, expected {n}");
        }
        Ok(())
    }
}

/// The streaming paths' shared window geometry: `(n_steps, window_steps,
/// n_windows)` for a horizon sampled at `dt_s` and split into `window_s`
/// windows (final window ragged). [`Generator::facility_shared_windowed`]
/// and the site composition coordinator ([`crate::site`]) both derive
/// their lockstep schedule from this one function, so they can never
/// disagree on window boundaries. Errors on non-positive `dt_s` /
/// `window_s` or a zero-sample horizon.
pub fn window_geometry(horizon_s: f64, dt_s: f64, window_s: f64) -> Result<(usize, usize, usize)> {
    ensure!(
        dt_s.is_finite() && dt_s > 0.0,
        "dt must be a positive number of seconds (got {dt_s})"
    );
    ensure!(
        window_s.is_finite() && window_s > 0.0,
        "window must be a positive number of seconds (got {window_s})"
    );
    let n_steps = (horizon_s / dt_s).round() as usize;
    ensure!(n_steps > 0, "horizon {horizon_s}s too short for dt {dt_s}s (zero samples)");
    let window = ((window_s / dt_s).round() as usize).clamp(1, n_steps);
    Ok((n_steps, window, (n_steps + window - 1) / window))
}

/// Borrow any free scratch slot. The pool is sized to the worker count,
/// so at most `workers` concurrent tasks compete for `workers` slots — a
/// free one always exists modulo transient hand-off races, which the
/// yield-and-rescan loop absorbs.
fn lock_any_scratch(pool: &[Mutex<WorkerScratch>]) -> std::sync::MutexGuard<'_, WorkerScratch> {
    loop {
        for m in pool {
            if let Ok(g) = m.try_lock() {
                return g;
            }
        }
        std::thread::yield_now();
    }
}

/// [`LaneFeatures`] over per-lane compressed occupancy timelines — the
/// windowed pipeline's bounded-memory feature source.
struct EventLanes<'a>(&'a [OccupancyEvents]);

impl LaneFeatures for EventLanes<'_> {
    fn lanes(&self) -> usize {
        self.0.len()
    }

    fn fill(&self, lane: usize, t0: usize, n: usize, out: &mut [f32]) {
        self.0[lane].fill_interleaved(t0, n, out);
    }
}

/// One rack's persistent streaming state: its prepared configuration plus
/// one [`LaneBatch`] per `max_batch` sub-batch (same split as the buffered
/// path, so the per-element fold order matches).
struct RackStream {
    prepared: Arc<PreparedConfig>,
    batches: Vec<LaneBatch>,
}

/// One sub-batch of a rack mid-scan: compressed per-lane workloads, the
/// resumable classifier scan, and each lane's sampling streams/carries.
struct LaneBatch {
    /// First server index of this sub-batch (lane `l` is server `s0 + l`).
    s0: usize,
    events: Vec<OccupancyEvents>,
    zrngs: Vec<Rng>,
    prngs: Vec<Rng>,
    /// AR(1) carry per lane (None before the first sample).
    carries: Vec<Option<f64>>,
    scan: BatchScan,
}

// Integration tests for the full pipeline live in rust/tests/ (they need
// `make artifacts` or the synthetic stores from `testutil`).
