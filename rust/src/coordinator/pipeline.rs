//! Generation pipeline implementation.

use super::FacilityResult;
use crate::aggregate::FacilityAccumulator;
use crate::artifacts::{ArtifactStore, ConfigArtifact};
use crate::catalog::Catalog;
use crate::classifier::{
    pjrt::{AnyClassifier, PjrtBiGru},
    NativeBiGru, StateClassifier,
};
use crate::classifier::native::BiGruWeights;
use crate::config::{ScenarioSpec, WorkloadSpec};
use crate::runtime::{Executable, Runtime};
use crate::surrogate::{features_from_intervals, simulate_queue};
use crate::synth::{sample_power, sample_states};
use crate::util::rng::Rng;
use crate::util::threadpool::{default_workers, parallel_fold};
use crate::workload::{
    poisson_arrivals, replay, DiurnalProfile, LengthSampler, Mmpp, Schedule, TrafficMode,
};
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::sync::Arc;

/// Which classifier backend the generator uses.
pub enum Backend {
    /// Pure-Rust BiGRU (portable, no artifacts HLO needed beyond weights).
    Native,
    /// AOT-compiled XLA artifact through PJRT (the production path).
    Pjrt(Arc<Executable>),
}

/// One generated server trace plus its intermediate products (useful for
/// figures and diagnostics).
pub struct ServerTrace {
    pub power_w: Vec<f32>,
    pub a: Vec<f32>,
    pub states: Vec<usize>,
}

/// A configuration ready for generation: its artifact plus a constructed
/// classifier. Cached on the [`Generator`] so multi-scenario drivers (the
/// sweep engine, repeated `facility` calls) never rebuild per-config state.
pub struct PreparedConfig {
    pub art: Arc<ConfigArtifact>,
    pub cls: AnyClassifier,
}

/// The trace generator: catalog + artifacts + classifier backend.
pub struct Generator {
    pub cat: Catalog,
    pub store: ArtifactStore,
    backend: Backend,
    configs: BTreeMap<String, Arc<ConfigArtifact>>,
    /// Per-config (artifact, classifier) pairs shared across runs; see
    /// [`Generator::prepare`].
    prepared: BTreeMap<String, Arc<PreparedConfig>>,
}

impl Generator {
    /// Open with the native classifier backend.
    pub fn native() -> Result<Generator> {
        let cat = Catalog::load_default()?;
        let store = ArtifactStore::open_default()?;
        Ok(Generator {
            cat,
            store,
            backend: Backend::Native,
            configs: BTreeMap::new(),
            prepared: BTreeMap::new(),
        })
    }

    /// Open with the PJRT backend (compiles the HLO artifact once).
    pub fn pjrt() -> Result<Generator> {
        let cat = Catalog::load_default()?;
        let store = ArtifactStore::open_default()?;
        let rt = Runtime::cpu()?;
        let exe = Arc::new(rt.load_hlo_text(&store.hlo_path())?);
        Ok(Generator {
            cat,
            store,
            backend: Backend::Pjrt(exe),
            configs: BTreeMap::new(),
            prepared: BTreeMap::new(),
        })
    }

    /// Backend selection by name ("native" | "pjrt").
    pub fn with_backend(name: &str) -> Result<Generator> {
        match name {
            "native" => Self::native(),
            "pjrt" => Self::pjrt(),
            other => anyhow::bail!("unknown backend '{other}' (native|pjrt)"),
        }
    }

    /// Load (and cache) a configuration artifact.
    pub fn config(&mut self, config_id: &str) -> Result<Arc<ConfigArtifact>> {
        if let Some(a) = self.configs.get(config_id) {
            return Ok(a.clone());
        }
        let a = Arc::new(self.store.load_config(config_id)?);
        self.configs.insert(config_id.to_string(), a.clone());
        Ok(a)
    }

    /// Build a classifier for one configuration's weights.
    pub fn classifier(&self, art: &ConfigArtifact) -> Result<AnyClassifier> {
        let weights = BiGruWeights::new(
            self.store.manifest.hidden,
            self.store.manifest.k_max,
            art.weights.clone(),
        )?;
        Ok(match &self.backend {
            Backend::Native => AnyClassifier::Native(NativeBiGru::new(weights)),
            Backend::Pjrt(exe) => AnyClassifier::Pjrt(
                PjrtBiGru::new(
                    exe.clone(),
                    art.weights.clone(),
                    self.store.manifest.chunk,
                    self.store.manifest.k_max,
                )?
                .chunked(),
            ),
        })
    }

    /// Generate one server's power trace from an arrival schedule
    /// (the paper's per-server pipeline, §3.3).
    pub fn server_trace(
        &self,
        art: &ConfigArtifact,
        classifier: &AnyClassifier,
        schedule: &Schedule,
        horizon_s: f64,
        dt_s: f64,
        rng: &mut Rng,
    ) -> Result<ServerTrace> {
        let n_steps = (horizon_s / dt_s).round() as usize;
        let intervals = simulate_queue(schedule, &art.surrogate, self.cat.campaign.max_batch, rng);
        let feats = features_from_intervals(&intervals, n_steps, dt_s);
        let probs = classifier.probs(&feats.interleaved(), n_steps)?;
        // Keep only the live K states of this configuration (unused logits
        // were masked at training time; renormalization happens inside the
        // categorical draw).
        let k_max = classifier.k_max();
        let k = art.k;
        let mut live = vec![0.0f32; n_steps * k];
        for t in 0..n_steps {
            live[t * k..(t + 1) * k].copy_from_slice(&probs[t * k_max..t * k_max + k]);
        }
        let states = sample_states(&live, k, rng);
        let power_w = sample_power(&states, &art.dict, art.mode, rng);
        Ok(ServerTrace { power_w, a: feats.a, states })
    }

    /// Build the per-server arrival schedule for a scenario.
    pub fn schedule_for(
        &self,
        spec: &ScenarioSpec,
        server_idx: usize,
        base_rng: &Rng,
    ) -> Result<Schedule> {
        let profile = self
            .cat
            .datasets
            .get(&spec.dataset)
            .with_context(|| format!("unknown dataset '{}'", spec.dataset))?;
        // Reasoning multiplier depends on the model this server runs.
        let cfg_id = spec.server_config.config_for(&spec.topology, server_idx).to_string();
        let cfg = self.cat.config(&cfg_id)?;
        let out_mult = if self.cat.model_of(cfg).reasoning {
            self.cat.campaign.reasoning_out_mult
        } else {
            1.0
        };
        let lengths = LengthSampler::from_profile(profile, out_mult);
        let mut rng = base_rng.fork(0xA21 ^ server_idx as u64);
        Ok(match &spec.workload {
            WorkloadSpec::Poisson { rate } => {
                poisson_arrivals(*rate, spec.horizon_s, &lengths, &mut rng)
            }
            WorkloadSpec::Mmpp { mean_rate, burstiness } => {
                Mmpp::bursty(*mean_rate, *burstiness).arrivals(spec.horizon_s, &lengths, &mut rng)
            }
            WorkloadSpec::Diurnal { base_rate, swing, peak_hour, burst_sigma, mode } => {
                let p = DiurnalProfile {
                    base_rate: *base_rate,
                    swing: *swing,
                    peak_hour: *peak_hour,
                    burst_sigma: *burst_sigma,
                    burst_tau_s: 300.0,
                    mode: *mode,
                };
                p.schedule(server_idx, spec.horizon_s, &lengths, base_rng)
            }
            WorkloadSpec::Replay { path, offset_s } => {
                let base = replay::load(std::path::Path::new(path))?;
                // Per-server random offset (paper §4.4) wrapped on horizon.
                let off = if *offset_s > 0.0 { rng.range(0.0, *offset_s) } else { 0.0 };
                let mut shifted: Schedule = base
                    .iter()
                    .map(|r| {
                        let mut r2 = *r;
                        r2.arrival_s = (r.arrival_s + off) % spec.horizon_s;
                        r2
                    })
                    .collect();
                shifted.sort_by(|a, b| a.arrival_s.partial_cmp(&b.arrival_s).unwrap());
                shifted
            }
        })
    }

    /// Load-or-build the cached (artifact, classifier) pair for a config.
    ///
    /// This is the per-configuration state that used to be rebuilt inside
    /// every `facility()` call; hoisting it onto the generator lets
    /// multi-scenario drivers (the [`crate::scenarios`] sweep engine) share
    /// it across an arbitrary number of runs.
    pub fn prepare(&mut self, config_id: &str) -> Result<Arc<PreparedConfig>> {
        if let Some(p) = self.prepared.get(config_id) {
            return Ok(p.clone());
        }
        let art = self.config(config_id)?;
        let cls = self.classifier(&art)?;
        let p = Arc::new(PreparedConfig { art, cls });
        self.prepared.insert(config_id.to_string(), p.clone());
        Ok(p)
    }

    /// Prepare every configuration a scenario actually uses (a `PerRack`
    /// list longer than the rack count never reaches its tail).
    pub fn prepare_for(&mut self, spec: &ScenarioSpec) -> Result<()> {
        for id in spec.server_config.config_ids_used(&spec.topology) {
            self.prepare(&id)?;
        }
        Ok(())
    }

    /// Lookup an already-prepared configuration (shared, read-only).
    pub fn get_prepared(&self, config_id: &str) -> Option<Arc<PreparedConfig>> {
        self.prepared.get(config_id).cloned()
    }

    /// Generate a full facility run: every server in the topology, in
    /// parallel, reduced into a streaming accumulator.
    pub fn facility(&mut self, spec: &ScenarioSpec, dt_s: f64, workers: usize) -> Result<FacilityResult> {
        self.prepare_for(spec)?;
        self.facility_shared(spec, dt_s, workers)
    }

    /// [`Generator::facility`] against the shared prepared-config cache.
    ///
    /// Takes `&self` so many scenarios can run concurrently over one
    /// generator; every configuration the scenario references must have
    /// been [`Generator::prepare`]d first (the `&mut` wrapper
    /// [`Generator::facility`] does this automatically).
    ///
    /// The result is bit-identical for a given `(spec, spec.seed)`
    /// regardless of `workers` or thread scheduling: work is partitioned at
    /// **rack** granularity, each rack's servers fold into that rack's
    /// buffer in server-index order, and the final merge only combines
    /// disjoint racks — no floating-point sum ever re-associates.
    pub fn facility_shared(&self, spec: &ScenarioSpec, dt_s: f64, workers: usize) -> Result<FacilityResult> {
        anyhow::ensure!(
            dt_s.is_finite() && dt_s > 0.0,
            "dt must be a positive number of seconds (got {dt_s})"
        );
        let n_racks = spec.topology.n_racks();
        let per_rack = spec.topology.servers_per_rack;
        let n_steps = (spec.horizon_s / dt_s).round() as usize;
        anyhow::ensure!(
            n_steps > 0,
            "horizon {}s too short for dt {dt_s}s (zero samples)",
            spec.horizon_s
        );
        let mut table: BTreeMap<String, Arc<PreparedConfig>> = BTreeMap::new();
        for id in spec.server_config.config_ids_used(&spec.topology) {
            let p = self.get_prepared(&id).with_context(|| {
                format!("config '{id}' not prepared (call Generator::prepare first)")
            })?;
            table.insert(id, p);
        }
        let base_rng = Rng::new(spec.seed);
        let workers = if workers == 0 { default_workers() } else { workers };
        let errors = std::sync::Mutex::new(Vec::<String>::new());
        let acc = parallel_fold(
            n_racks,
            workers,
            || FacilityAccumulator::new(spec.topology, n_steps, spec.p_base_w),
            |acc, rack| {
                for s in rack * per_rack..(rack + 1) * per_rack {
                    let result = (|| -> Result<()> {
                        let id = spec.server_config.config_for(&spec.topology, s);
                        let p = &table[id];
                        let sched = self.schedule_for(spec, s, &base_rng)?;
                        let mut rng = base_rng.fork(0x5E21 ^ s as u64);
                        let tr = self
                            .server_trace(&p.art, &p.cls, &sched, spec.horizon_s, dt_s, &mut rng)?;
                        acc.add_server(s, &tr.power_w)?;
                        Ok(())
                    })();
                    if let Err(e) = result {
                        errors.lock().unwrap().push(format!("server {s}: {e:#}"));
                    }
                }
            },
            |mut a, b| {
                a.merge(&b);
                a
            },
        );
        let errs = errors.into_inner().unwrap();
        if !errs.is_empty() {
            anyhow::bail!("facility generation failed: {}", errs.join("; "));
        }
        Ok(FacilityResult { scenario: spec.clone(), dt_s, acc })
    }
}

// Integration tests for the full pipeline live in rust/tests/ (they need
// `make artifacts`).
