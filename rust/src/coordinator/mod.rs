//! The generation coordinator — the L3 system that turns a planner-facing
//! scenario into server/rack/row/facility power traces (paper Fig. 2,
//! right half).
//!
//! Per server: schedule → surrogate queue → features `(A_t, ΔA_t)` →
//! classifier posteriors → state sampling → state-conditioned power
//! sampling → clip. Servers fan out across a thread pool and reduce into a
//! streaming [`FacilityAccumulator`].

pub mod pipeline;

pub use pipeline::{
    window_geometry, Generator, PreparedConfig, ServerTrace, WorkerScratch, DEFAULT_MAX_BATCH,
};

use crate::aggregate::FacilityAccumulator;
use crate::config::ScenarioSpec;

/// Result of a facility-scale generation run.
pub struct FacilityResult {
    pub scenario: ScenarioSpec,
    pub dt_s: f64,
    pub acc: FacilityAccumulator,
}

impl FacilityResult {
    /// Facility power at the PCC (PUE applied).
    pub fn facility_series(&self) -> Vec<f32> {
        self.acc.facility_series(self.scenario.pue)
    }

    /// Facility IT power.
    pub fn it_series(&self) -> Vec<f32> {
        self.acc.site_it_series()
    }
}
