//! Site specification: N facilities, each a full facility scenario plus a
//! phase offset, composed into one utility-facing load profile.
//!
//! A [`SiteSpec`] is the planner-facing JSON a utility interconnection
//! study consumes — the spatial rung above
//! [`ScenarioSpec`](crate::config::ScenarioSpec): each facility keeps its
//! own topology, serving-config mix, workload model, PUE, and seed, and
//! adds a **phase offset** modelling its timezone: a facility three hours
//! west sees the same diurnal demand shape three hours later in the shared
//! simulation clock. Offsets shift the diurnal envelope
//! ([`FacilitySpec::effective_scenario`]); stationary workloads (Poisson,
//! MMPP) are statistically invariant under time shift and pass through
//! unchanged, as does replay (its per-server `offset_s` field already
//! covers deliberate shifting).

use super::overlay::OverlaySpec;
use crate::config::{ScenarioSpec, WorkloadSpec};
use crate::util::json::{self, Json};
use anyhow::{bail, Context, Result};
#[cfg(feature = "host")]
use std::path::Path;

/// Default utility ramp-measurement intervals (5 / 15 / 60 min — dispatch,
/// settlement, and scheduling cadences).
pub const DEFAULT_UTILITY_INTERVALS_S: [f64; 3] = [300.0, 900.0, 3600.0];

/// A training facility archetype: deterministic step-function power.
///
/// Large training jobs draw near-constant power during compute phases and
/// drop to a base level during checkpoint/stall windows, producing a
/// square-wave facility profile — the mixed-class smoothing setup of the
/// related site-composition work (arxiv 2604.10769). `power_at` is a pure
/// function of the simulation clock, so training facilities need no
/// artifact store, seed, or server topology.
#[derive(Debug, Clone, PartialEq)]
pub struct TrainingSpec {
    /// Trace horizon (s); must match every other facility of the site.
    pub horizon_s: f64,
    /// Facility power during checkpoint/stall windows (W).
    pub base_w: f64,
    /// Extra power during compute windows (W); the step height.
    pub amplitude_w: f64,
    /// Step period (s): one compute + checkpoint cycle.
    pub period_s: f64,
    /// Fraction of each period spent at `base_w + amplitude_w`, in [0, 1].
    pub duty: f64,
}

impl TrainingSpec {
    /// Facility power at simulation time `t_s` (phase-shift by evaluating
    /// at `t - phase_offset_s`: positive offsets move steps later, exactly
    /// the diurnal peak convention).
    pub fn power_at(&self, t_s: f64) -> f64 {
        let phase = t_s.rem_euclid(self.period_s);
        if phase < self.duty * self.period_s {
            self.base_w + self.amplitude_w
        } else {
            self.base_w
        }
    }

    pub fn validate(&self) -> Result<()> {
        if !(self.horizon_s.is_finite() && self.horizon_s > 0.0) {
            bail!("training horizon_s must be positive seconds (got {})", self.horizon_s);
        }
        if !(self.base_w.is_finite() && self.base_w >= 0.0) {
            bail!("training base_w must be non-negative (got {})", self.base_w);
        }
        if !(self.amplitude_w.is_finite() && self.amplitude_w >= 0.0) {
            bail!("training amplitude_w must be non-negative (got {})", self.amplitude_w);
        }
        if !(self.period_s.is_finite() && self.period_s > 0.0) {
            bail!("training period_s must be positive seconds (got {})", self.period_s);
        }
        if !(self.duty.is_finite() && (0.0..=1.0).contains(&self.duty)) {
            bail!("training duty must be in [0, 1] (got {})", self.duty);
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        json::obj([
            ("horizon_s", self.horizon_s.into()),
            ("base_w", self.base_w.into()),
            ("amplitude_w", self.amplitude_w.into()),
            ("period_s", self.period_s.into()),
            ("duty", self.duty.into()),
        ])
    }

    pub fn from_json(v: &Json) -> Result<TrainingSpec> {
        Ok(TrainingSpec {
            horizon_s: v.f64_field("horizon_s")?,
            base_w: v.f64_field("base_w")?,
            amplitude_w: v.f64_field("amplitude_w")?,
            period_s: v.f64_field("period_s")?,
            duty: v.f64_field("duty")?,
        })
    }
}

/// What a facility runs: a full inference scenario (the generated path) or
/// a deterministic training archetype.
#[derive(Debug, Clone, PartialEq)]
pub enum FacilityKind {
    Inference(ScenarioSpec),
    Training(TrainingSpec),
}

/// One facility of a site: what it runs plus its phase offset in the
/// site's shared clock.
#[derive(Debug, Clone, PartialEq)]
pub struct FacilitySpec {
    /// Facility name (unique within the site; becomes a CSV column).
    pub name: String,
    /// Phase offset in seconds: positive values shift this facility's
    /// diurnal peak (or training step pattern) later (a facility further
    /// west).
    pub phase_offset_s: f64,
    pub kind: FacilityKind,
    /// Net-load overlay stages applied to this facility's PCC window
    /// stream, in order, **before** it is summed into the site (a
    /// facility nameplate cap, an on-site battery or PV plant). Empty =
    /// identity — the facility stream is untouched.
    pub overlays: Vec<OverlaySpec>,
}

impl FacilitySpec {
    /// An inference facility (the pre-training-archetype constructor).
    pub fn inference(name: &str, phase_offset_s: f64, scenario: ScenarioSpec) -> FacilitySpec {
        FacilitySpec {
            name: name.to_string(),
            phase_offset_s,
            kind: FacilityKind::Inference(scenario),
            overlays: Vec::new(),
        }
    }

    /// A training facility.
    pub fn training(name: &str, phase_offset_s: f64, training: TrainingSpec) -> FacilitySpec {
        FacilitySpec {
            name: name.to_string(),
            phase_offset_s,
            kind: FacilityKind::Training(training),
            overlays: Vec::new(),
        }
    }

    /// The inference scenario, if this facility runs one.
    pub fn scenario(&self) -> Option<&ScenarioSpec> {
        match &self.kind {
            FacilityKind::Inference(s) => Some(s),
            FacilityKind::Training(_) => None,
        }
    }

    /// Mutable access to the inference scenario (seed ladders, tests).
    pub fn scenario_mut(&mut self) -> Option<&mut ScenarioSpec> {
        match &mut self.kind {
            FacilityKind::Inference(s) => Some(s),
            FacilityKind::Training(_) => None,
        }
    }

    /// The training archetype, if this facility runs one.
    pub fn training_spec(&self) -> Option<&TrainingSpec> {
        match &self.kind {
            FacilityKind::Training(t) => Some(t),
            FacilityKind::Inference(_) => None,
        }
    }

    /// This facility's trace horizon (s), whatever it runs.
    pub fn horizon_s(&self) -> f64 {
        match &self.kind {
            FacilityKind::Inference(s) => s.horizon_s,
            FacilityKind::Training(t) => t.horizon_s,
        }
    }

    /// Server count (0 for training facilities — their power model is
    /// facility-level).
    pub fn n_servers(&self) -> usize {
        match &self.kind {
            FacilityKind::Inference(s) => s.topology.n_servers(),
            FacilityKind::Training(_) => 0,
        }
    }

    /// Summary-row role label ("facility" for inference — the pre-existing
    /// label, kept for export compatibility — and "training").
    pub fn role(&self) -> &'static str {
        match &self.kind {
            FacilityKind::Inference(_) => "facility",
            FacilityKind::Training(_) => "training",
        }
    }

    /// The scenario an inference facility actually runs (`None` for
    /// training): the declared scenario with the phase offset folded into
    /// its workload. Diurnal workloads shift their `peak_hour` by
    /// `offset / 3600` (wrapped on 24 h); stationary and replay workloads
    /// are unchanged (see module docs).
    pub fn effective_scenario(&self) -> Option<ScenarioSpec> {
        let mut s = self.scenario()?.clone();
        if let WorkloadSpec::Diurnal { ref mut peak_hour, .. } = s.workload {
            *peak_hour = (*peak_hour + self.phase_offset_s / 3600.0).rem_euclid(24.0);
        }
        Some(s)
    }

    /// The overlay stages this facility actually runs: the declared list
    /// with the phase offset folded into every clock-bearing stage (PV
    /// peaks shift with the facility's timezone — the same machinery as
    /// [`FacilitySpec::effective_scenario`]).
    pub fn effective_overlays(&self) -> Vec<OverlaySpec> {
        self.overlays.iter().map(|o| o.shifted(self.phase_offset_s)).collect()
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("name", Json::Str(self.name.clone())),
            ("phase_offset_s", self.phase_offset_s.into()),
        ];
        // Inference facilities keep the pre-archetype "scenario" key, so
        // every existing site spec round-trips byte-identically; training
        // facilities carry a "training" object instead.
        match &self.kind {
            FacilityKind::Inference(s) => fields.push(("scenario", s.to_json())),
            FacilityKind::Training(t) => fields.push(("training", t.to_json())),
        }
        // Omitted when empty: an overlay-free spec round-trips to the
        // exact pre-overlay JSON (the site_spec.json byte-identity
        // surface).
        if !self.overlays.is_empty() {
            fields.push(("overlays", OverlaySpec::list_to_json(&self.overlays)));
        }
        json::obj(fields)
    }

    pub fn from_json(v: &Json) -> Result<FacilitySpec> {
        let kind = match (v.get_opt("scenario"), v.get_opt("training")) {
            (Some(s), None) => FacilityKind::Inference(ScenarioSpec::from_json(s)?),
            (None, Some(t)) => FacilityKind::Training(TrainingSpec::from_json(t)?),
            (Some(_), Some(_)) => {
                bail!("facility declares both 'scenario' and 'training' (pick one)")
            }
            (None, None) => bail!("facility needs a 'scenario' or 'training' object"),
        };
        Ok(FacilitySpec {
            name: v.str_field("name")?,
            phase_offset_s: match v.get_opt("phase_offset_s") {
                Some(x) => x.as_f64()?,
                None => 0.0,
            },
            kind,
            overlays: match v.get_opt("overlays") {
                Some(x) => OverlaySpec::list_from_json(x)?,
                None => Vec::new(),
            },
        })
    }
}

/// A site: several facilities driven in lockstep and summed at the utility
/// point of interconnection, plus the site-level planning baseline.
#[derive(Debug, Clone, PartialEq)]
pub struct SiteSpec {
    pub name: String,
    /// Interconnection nameplate in W — the oversubscription baseline the
    /// headroom metrics are reported against. `None` defaults to the sum
    /// of facility peaks (headroom then measures pure diversity savings).
    pub nameplate_w: Option<f64>,
    /// Ramp-measurement intervals (s) for the utility-facing summary.
    pub utility_intervals_s: Vec<f64>,
    pub facilities: Vec<FacilitySpec>,
    /// Net-load overlay stages applied to the **composed** site window
    /// stream, in order, after the facility fold (an interconnection cap,
    /// a site battery, utility-scale PV). Empty = identity.
    pub overlays: Vec<OverlaySpec>,
}

impl SiteSpec {
    /// Shared horizon of every facility (validated equal).
    pub fn horizon_s(&self) -> f64 {
        self.facilities[0].horizon_s()
    }

    /// Total servers across facilities (training facilities count 0).
    pub fn n_servers(&self) -> usize {
        self.facilities.iter().map(|f| f.n_servers()).sum()
    }

    /// Unique configuration ids referenced by any inference facility, in
    /// first-use order (the artifact set a synthetic store must cover).
    /// Training facilities reference none.
    pub fn config_ids(&self) -> Vec<String> {
        let mut out: Vec<String> = Vec::new();
        for f in &self.facilities {
            let Some(scenario) = f.scenario() else { continue };
            for id in scenario.server_config.config_ids() {
                if !out.contains(&id) {
                    out.push(id);
                }
            }
        }
        out
    }

    /// Reject sites the composition engine cannot drive in lockstep.
    pub fn validate(&self) -> Result<()> {
        if self.facilities.is_empty() {
            bail!("site '{}' has no facilities", self.name);
        }
        let horizon = self.facilities[0].horizon_s();
        for (i, f) in self.facilities.iter().enumerate() {
            if f.name.is_empty() {
                bail!("site '{}': facility {i} has an empty name", self.name);
            }
            if let FacilityKind::Training(t) = &f.kind {
                t.validate()
                    .with_context(|| format!("site '{}': facility '{}'", self.name, f.name))?;
            }
            // "site" is the composed series' column/row name, and the
            // site's own name keys the summary's site row — a facility
            // sharing either would alias them in both exports.
            if f.name == "site" || f.name == self.name {
                bail!(
                    "site '{}': facility name '{}' collides with the composed-series naming",
                    self.name,
                    f.name
                );
            }
            if !f.phase_offset_s.is_finite() {
                bail!("site '{}': facility '{}' has a non-finite phase offset", self.name, f.name);
            }
            if f.horizon_s() != horizon {
                bail!(
                    "site '{}': facility '{}' horizon {}s != '{}' horizon {}s \
                     (lockstep composition needs one shared horizon)",
                    self.name,
                    f.name,
                    f.horizon_s(),
                    self.facilities[0].name,
                    horizon
                );
            }
            for other in &self.facilities[..i] {
                if other.name == f.name {
                    bail!("site '{}': duplicate facility name '{}'", self.name, f.name);
                }
            }
            for (k, o) in f.overlays.iter().enumerate() {
                o.validate().with_context(|| {
                    format!("site '{}': facility '{}' overlays[{k}]", self.name, f.name)
                })?;
            }
        }
        for (k, o) in self.overlays.iter().enumerate() {
            o.validate().with_context(|| format!("site '{}': overlays[{k}]", self.name))?;
        }
        if let Some(np) = self.nameplate_w {
            if !(np.is_finite() && np > 0.0) {
                bail!("site '{}': nameplate_w must be positive (got {np})", self.name);
            }
        }
        if self.utility_intervals_s.is_empty() {
            bail!("site '{}': utility_intervals_s must name at least one interval", self.name);
        }
        for &iv in &self.utility_intervals_s {
            if !(iv.is_finite() && iv > 0.0) {
                bail!("site '{}': utility interval must be positive seconds (got {iv})", self.name);
            }
            // The exact ramp distribution keeps O(horizon / interval)
            // points per series (`StreamingRamps`); cap it at the planning
            // stats' exact-sample budget so a pathological interval cannot
            // make site memory scale with the horizon.
            let n_points = horizon / iv;
            if n_points > crate::metrics::planning::EXACT_QUANTILE_CAP as f64 {
                bail!(
                    "site '{}': utility interval {iv}s yields {:.0} ramp points over the \
                     {horizon}s horizon (cap {}); use a coarser interval",
                    self.name,
                    n_points,
                    crate::metrics::planning::EXACT_QUANTILE_CAP
                );
            }
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("name", Json::Str(self.name.clone())),
            (
                "utility_intervals_s",
                Json::Arr(self.utility_intervals_s.iter().map(|&x| Json::Num(x)).collect()),
            ),
            (
                "facilities",
                Json::Arr(self.facilities.iter().map(|f| f.to_json()).collect()),
            ),
        ];
        if let Some(np) = self.nameplate_w {
            fields.insert(1, ("nameplate_w", Json::Num(np)));
        }
        // Omitted when empty (see FacilitySpec::to_json).
        if !self.overlays.is_empty() {
            fields.push(("overlays", OverlaySpec::list_to_json(&self.overlays)));
        }
        json::obj(fields)
    }

    pub fn from_json(v: &Json) -> Result<SiteSpec> {
        let facilities = v
            .get("facilities")?
            .as_arr()
            .map_err(anyhow::Error::from)?
            .iter()
            .enumerate()
            .map(|(i, f)| FacilitySpec::from_json(f).with_context(|| format!("facilities[{i}]")))
            .collect::<Result<Vec<_>>>()?;
        let spec = SiteSpec {
            name: match v.get_opt("name") {
                Some(x) => x.as_str()?.to_string(),
                None => "site".to_string(),
            },
            nameplate_w: match v.get_opt("nameplate_w") {
                Some(x) => Some(x.as_f64()?),
                None => None,
            },
            utility_intervals_s: match v.get_opt("utility_intervals_s") {
                Some(x) => x.f64_array().map_err(anyhow::Error::from)?,
                None => DEFAULT_UTILITY_INTERVALS_S.to_vec(),
            },
            facilities,
            overlays: match v.get_opt("overlays") {
                Some(x) => OverlaySpec::list_from_json(x)?,
                None => Vec::new(),
            },
        };
        spec.validate()?;
        Ok(spec)
    }

    #[cfg(feature = "host")]
    pub fn load(path: &Path) -> Result<SiteSpec> {
        let v = json::parse_file(path).map_err(anyhow::Error::from)?;
        Self::from_json(&v).with_context(|| format!("parsing site spec {}", path.display()))
    }

    #[cfg(feature = "host")]
    pub fn save(&self, path: &Path) -> Result<()> {
        json::write_file(path, &self.to_json()).map_err(anyhow::Error::from)
    }

    /// A demonstration site: `n_facilities` copies of `base`, facility `i`
    /// seeded `base.seed + i` and phase-shifted `i × stagger_h` hours — a
    /// timezone ladder (the composition-smooths-demand setup of the
    /// related work). Used by the site example and unit tests.
    pub fn staggered(
        name: &str,
        base: &ScenarioSpec,
        n_facilities: usize,
        stagger_h: f64,
    ) -> SiteSpec {
        let facilities = (0..n_facilities)
            .map(|i| {
                let mut scenario = base.clone();
                scenario.seed = base.seed + i as u64;
                FacilitySpec::inference(
                    &format!("fac{i}"),
                    i as f64 * stagger_h * 3600.0,
                    scenario,
                )
            })
            .collect();
        SiteSpec {
            name: name.to_string(),
            nameplate_w: None,
            utility_intervals_s: DEFAULT_UTILITY_INTERVALS_S.to_vec(),
            facilities,
            overlays: Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::TrafficMode;

    fn base() -> ScenarioSpec {
        ScenarioSpec::default_poisson("cfg_a", 0.5)
    }

    fn diurnal_base() -> ScenarioSpec {
        let mut s = base();
        s.workload = WorkloadSpec::Diurnal {
            base_rate: 0.5,
            swing: 0.6,
            peak_hour: 15.0,
            burst_sigma: 0.3,
            mode: TrafficMode::SharedIntensity,
        };
        s
    }

    fn training_base() -> TrainingSpec {
        TrainingSpec {
            horizon_s: 600.0,
            base_w: 1.0e4,
            amplitude_w: 5.0e4,
            period_s: 100.0,
            duty: 0.5,
        }
    }

    #[test]
    fn phase_offset_shifts_diurnal_peak_only() {
        let fac = FacilitySpec::inference("west", 3.0 * 3600.0, diurnal_base());
        match fac.effective_scenario().unwrap().workload {
            WorkloadSpec::Diurnal { peak_hour, .. } => assert_eq!(peak_hour, 18.0),
            other => panic!("unexpected workload {other:?}"),
        }
        // Wraps on 24 h.
        let fac = FacilitySpec::inference("far", 12.0 * 3600.0, diurnal_base());
        match fac.effective_scenario().unwrap().workload {
            WorkloadSpec::Diurnal { peak_hour, .. } => assert_eq!(peak_hour, 3.0),
            other => panic!("unexpected workload {other:?}"),
        }
        // Stationary workloads pass through untouched.
        let fac = FacilitySpec::inference("p", 7200.0, base());
        assert_eq!(fac.effective_scenario().unwrap(), base());
        // Training facilities have no scenario at all.
        let fac = FacilitySpec::training("t", 0.0, training_base());
        assert!(fac.effective_scenario().is_none());
        assert_eq!(fac.n_servers(), 0);
        assert_eq!(fac.role(), "training");
        assert_eq!(fac.horizon_s(), 600.0);
    }

    #[test]
    fn training_power_is_a_phase_shiftable_step_function() {
        let t = training_base();
        // duty 0.5 over a 100 s period: high for t ∈ [0, 50), low after.
        assert_eq!(t.power_at(0.0), 6.0e4);
        assert_eq!(t.power_at(49.9), 6.0e4);
        assert_eq!(t.power_at(50.0), 1.0e4);
        assert_eq!(t.power_at(99.9), 1.0e4);
        assert_eq!(t.power_at(100.0), 6.0e4); // wraps
        // Phase shifting like the diurnal convention: evaluating at
        // `t - offset` moves the step pattern later by `offset`.
        let offset = 25.0;
        assert_eq!(t.power_at(30.0 - offset), t.power_at(5.0));
        assert_eq!(t.power_at(0.0 - offset), 1.0e4); // rem_euclid: 75 s into the period
        // Degenerate duties are flat lines.
        let flat = TrainingSpec { duty: 0.0, ..training_base() };
        assert_eq!(flat.power_at(10.0), 1.0e4);
        let full = TrainingSpec { duty: 1.0, ..training_base() };
        assert_eq!(full.power_at(10.0), 6.0e4);
    }

    #[test]
    fn training_validation_rejects_bad_parameters() {
        assert!(training_base().validate().is_ok());
        assert!(TrainingSpec { horizon_s: 0.0, ..training_base() }.validate().is_err());
        assert!(TrainingSpec { base_w: -1.0, ..training_base() }.validate().is_err());
        assert!(TrainingSpec { amplitude_w: f64::NAN, ..training_base() }.validate().is_err());
        assert!(TrainingSpec { period_s: 0.0, ..training_base() }.validate().is_err());
        assert!(TrainingSpec { duty: 1.5, ..training_base() }.validate().is_err());
        assert!(TrainingSpec { duty: -0.1, ..training_base() }.validate().is_err());
        // Site validation surfaces training errors with facility context.
        let mut site = SiteSpec::staggered("s", &base(), 2, 0.0);
        site.facilities.push(FacilitySpec::training(
            "train0",
            0.0,
            TrainingSpec { horizon_s: base().horizon_s, duty: 2.0, ..training_base() },
        ));
        assert!(site.validate().is_err());
    }

    #[test]
    fn mixed_site_roundtrips_and_validates() {
        let mut site = SiteSpec::staggered("mixed", &diurnal_base(), 2, 4.0);
        site.facilities.push(FacilitySpec::training(
            "train0",
            1800.0,
            TrainingSpec { horizon_s: diurnal_base().horizon_s, ..training_base() },
        ));
        site.validate().unwrap();
        // Training facilities reference no configs and no servers.
        assert_eq!(site.config_ids(), vec!["cfg_a".to_string()]);
        assert_eq!(site.n_servers(), 2 * base().topology.n_servers());
        let back = SiteSpec::from_json(&site.to_json()).unwrap();
        assert_eq!(back, site);
        // A facility must declare exactly one of scenario/training.
        let neither = json::parse(r#"{"name": "x", "phase_offset_s": 0}"#).unwrap();
        assert!(FacilitySpec::from_json(&neither).is_err());
        let mut both = site.facilities[0].to_json();
        if let Json::Obj(ref mut o) = both {
            o.insert("training".into(), training_base().to_json());
        }
        assert!(FacilitySpec::from_json(&both).is_err());
        // Horizon mismatch between a training facility and the inference
        // facilities is caught like any other mismatch.
        let mut site = site;
        if let FacilityKind::Training(ref mut t) = site.facilities[2].kind {
            t.horizon_s *= 2.0;
        }
        assert!(site.validate().is_err());
    }

    #[test]
    fn staggered_builder_and_json_roundtrip() {
        let site = SiteSpec::staggered("tri", &diurnal_base(), 3, 4.0);
        site.validate().unwrap();
        assert_eq!(site.facilities.len(), 3);
        assert_eq!(site.facilities[2].phase_offset_s, 8.0 * 3600.0);
        assert_eq!(site.facilities[1].scenario().unwrap().seed, 1);
        assert_eq!(site.config_ids(), vec!["cfg_a".to_string()]);
        let back = SiteSpec::from_json(&site.to_json()).unwrap();
        assert_eq!(back, site);
        // With a nameplate, too.
        let mut site = site;
        site.nameplate_w = Some(5e6);
        let back = SiteSpec::from_json(&site.to_json()).unwrap();
        assert_eq!(back, site);
    }

    #[test]
    fn validation_rejects_bad_sites() {
        let mut site = SiteSpec::staggered("s", &base(), 2, 0.0);
        site.facilities.clear();
        assert!(site.validate().is_err());

        let mut site = SiteSpec::staggered("s", &base(), 2, 0.0);
        site.facilities[1].scenario_mut().unwrap().horizon_s *= 2.0;
        assert!(site.validate().is_err());

        let mut site = SiteSpec::staggered("s", &base(), 2, 0.0);
        site.facilities[1].name = site.facilities[0].name.clone();
        assert!(site.validate().is_err());

        let mut site = SiteSpec::staggered("s", &base(), 2, 0.0);
        site.nameplate_w = Some(-1.0);
        assert!(site.validate().is_err());

        let mut site = SiteSpec::staggered("s", &base(), 2, 0.0);
        site.utility_intervals_s = vec![300.0, 0.0];
        assert!(site.validate().is_err());

        // Pathologically fine interval vs horizon: bounded-memory cap.
        let mut site = SiteSpec::staggered("s", &base(), 2, 0.0);
        site.facilities
            .iter_mut()
            .for_each(|f| f.scenario_mut().unwrap().horizon_s = 1e10);
        site.utility_intervals_s = vec![1.0];
        assert!(site.validate().is_err());

        let mut site = SiteSpec::staggered("s", &base(), 2, 0.0);
        site.facilities[0].phase_offset_s = f64::NAN;
        assert!(site.validate().is_err());

        // Reserved names: the composed column/row and the site's own name.
        let mut site = SiteSpec::staggered("s", &base(), 2, 0.0);
        site.facilities[1].name = "site".into();
        assert!(site.validate().is_err());
        let mut site = SiteSpec::staggered("s", &base(), 2, 0.0);
        site.facilities[1].name = "s".into();
        assert!(site.validate().is_err());
    }

    #[test]
    fn overlays_roundtrip_and_stay_out_of_overlay_free_json() {
        use crate::site::overlay::OverlaySpec;
        // An overlay-free spec's JSON carries no `overlays` field at all —
        // the exact pre-overlay serialization (site_spec.json
        // byte-identity surface).
        let plain = SiteSpec::staggered("plain", &base(), 2, 0.0);
        let j = plain.to_json();
        assert!(j.get_opt("overlays").is_none());
        assert!(j.get("facilities").unwrap().as_arr().unwrap()[0].get_opt("overlays").is_none());

        // Facility- and site-level overlays round-trip in order.
        let mut site = SiteSpec::staggered("ov", &diurnal_base(), 2, 4.0);
        site.facilities[0].overlays = vec![OverlaySpec::Cap { cap_w: 9e4 }];
        site.overlays = vec![
            OverlaySpec::Battery {
                capacity_kwh: 50.0,
                power_w: 2e4,
                efficiency: 0.9,
                threshold_w: 1.2e5,
                initial_soc_frac: 0.5,
            },
            OverlaySpec::Pv { peak_w: 3e4, peak_hour: 12.0, daylight_h: 12.0 },
        ];
        site.validate().unwrap();
        let back = SiteSpec::from_json(&site.to_json()).unwrap();
        assert_eq!(back, site);

        // Invalid overlays are rejected by site validation, with context.
        let mut site = SiteSpec::staggered("bad", &base(), 2, 0.0);
        site.overlays = vec![OverlaySpec::Cap { cap_w: -5.0 }];
        assert!(site.validate().is_err());
        let mut site = SiteSpec::staggered("bad", &base(), 2, 0.0);
        site.facilities[1].overlays = vec![OverlaySpec::Cap { cap_w: f64::NAN }];
        assert!(site.validate().is_err());
    }

    #[test]
    fn effective_overlays_shift_pv_with_the_facility_phase() {
        use crate::site::overlay::OverlaySpec;
        let mut fac = FacilitySpec::inference("west", 6.0 * 3600.0, base());
        fac.overlays = vec![
            OverlaySpec::Cap { cap_w: 1e5 },
            OverlaySpec::Pv { peak_w: 1e4, peak_hour: 12.0, daylight_h: 12.0 },
        ];
        let eff = fac.effective_overlays();
        assert_eq!(eff[0], fac.overlays[0]); // caps are clock-free
        match eff[1] {
            OverlaySpec::Pv { peak_hour, .. } => assert_eq!(peak_hour, 18.0),
            ref other => panic!("unexpected {other:?}"),
        }
    }

    #[cfg(feature = "host")]
    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("powertrace_test_site_spec");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("site.json");
        let site = SiteSpec::staggered("roundtrip", &diurnal_base(), 2, 6.0);
        site.save(&p).unwrap();
        assert_eq!(SiteSpec::load(&p).unwrap(), site);
    }
}
