//! Lockstep multi-facility composition: drive N
//! [`facility_shared_windowed`](crate::coordinator::Generator::facility_shared_windowed)
//! streams window-by-window, fold them into a bounded
//! [`SiteAccumulator`], and characterize the composed utility-facing
//! profile as it streams past.
//!
//! # Execution model
//!
//! Under the threaded executor (the host default), one thread per facility
//! runs the windowed facility engine (each with its own inner
//! rack-parallel worker share); a bounded rendezvous channel per facility
//! (capacity 1) delivers each completed PCC window to the coordinator,
//! which waits for window *w* from **every** facility before folding — so
//! the whole site advances through the horizon in lockstep and no stream
//! can run more than two windows ahead. Peak memory is
//! O(facilities × window) site-side plus each facility's own
//! O(racks × window) streaming state; nothing scales with the horizon.
//!
//! Under [`Executor::Sequential`] (the only option in a core-only build)
//! there are no threads at all: each facility stream runs to completion on
//! the caller thread in spec order, buffering its windows, and the same
//! coordinator fold then replays them in the same lockstep order. The
//! window production and fold code is shared with the threaded path
//! ([`drive_facility`] / [`WindowFolder`]), so the exports are
//! byte-identical by construction — the trade is O(facilities × horizon)
//! peak memory for zero thread dependence.
//!
//! # Determinism
//!
//! Every facility window is bit-identical regardless of worker count,
//! batch width, and window size (the PR 3 invariant), and the site fold
//! sums facilities in spec order ([`SiteAccumulator::fold_site`]) — so
//! `site_load.csv` / `site_summary.csv` are byte-identical across worker
//! counts, window sizes, and executors, and a single-facility site
//! reproduces the plain facility path's PCC series exactly.
//!
//! # Overlays
//!
//! Net-load overlay chains ([`super::overlay`]) hook the stream at two
//! points: each facility's chain transforms its PCC window inside the
//! facility stream (before characterization, export, and the site fold —
//! the site composes *net* facility load), and the site-level chain
//! transforms the composed window right after the barrier fold. Both are
//! O(1)-state sample folds, so the determinism guarantees above extend to
//! overlaid runs; empty chains are skipped outright, keeping the
//! overlay-free path byte-identical to PR 4.

use super::metrics::{
    characterization_header, characterization_row, SeriesSummary, SiteSeriesStats,
};
use super::overlay::OverlayChain;
use super::spec::{FacilityKind, SiteSpec, TrainingSpec};
use crate::aggregate::{pcc_window_into, SiteAccumulator};
use crate::config::ScenarioSpec;
use crate::coordinator::{window_geometry, Generator};
#[cfg(feature = "host")]
use crate::export::DirSink;
use crate::export::{csv_field, fmt_secs, StreamingCsv, TraceSink};
use crate::robust::{failpoint, Deadline};
use crate::util::json;
use crate::util::threadpool::{default_workers, Executor};
#[cfg(feature = "host")]
use anyhow::bail;
use anyhow::{anyhow, ensure, Result};
use std::collections::VecDeque;
#[cfg(feature = "host")]
use std::path::Path;
#[cfg(feature = "host")]
use std::sync::mpsc;

/// Marker a facility thread reports when the coordinator stopped taking
/// windows (the real failure is elsewhere; this one is filtered out).
#[cfg(feature = "host")]
const ABORT_MSG: &str = "site window delivery aborted";

/// What one facility's window stream runs: the generated inference
/// pipeline (phase already folded into the scenario) or the deterministic
/// training synthesizer (phase applied at evaluation time).
enum FacStream {
    Inference(ScenarioSpec),
    Training(TrainingSpec, f64),
}

/// Execution knobs for one site run.
#[derive(Debug, Clone)]
pub struct SiteOptions {
    /// Generation sample interval (s). Sites default to 1 s: utility
    /// characterization happens at ≥ 5 min intervals, and planning
    /// horizons are days.
    pub dt_s: f64,
    /// Generation window (s); memory is O(facilities × window) site-side.
    pub window_s: f64,
    /// Total worker budget split across facilities (0 = auto).
    pub workers: usize,
    /// Servers per batched classifier call (0 = default, 1 = sequential).
    pub max_batch: usize,
    /// Interval for the headline `PlanningStats::max_ramp_w` (clamped to
    /// half the horizon, like the sweep engine).
    pub ramp_interval_s: f64,
    /// Export interval of `site_load.csv`.
    pub load_interval_s: f64,
    /// Retain the full composed site series on the report (tests; O(T)).
    pub collect_series: bool,
    /// How facility streams run: threaded lockstep (host default) or
    /// fully sequential on the caller thread (the core-build default; a
    /// debugging choice on hosts). Byte-invariant — see the module docs.
    pub executor: Executor,
    /// Site-sweep shard: run only the variants this shard owns (`None` =
    /// all). Ignored by single-site runs. Same contract as
    /// [`crate::scenarios::SweepOptions::shard`]: recorded in the
    /// manifest, excluded from the identity hash.
    pub shard: Option<crate::shard::Shard>,
}

impl Default for SiteOptions {
    fn default() -> Self {
        SiteOptions {
            dt_s: 1.0,
            window_s: 3600.0,
            workers: 0,
            max_batch: 0,
            ramp_interval_s: 900.0,
            load_interval_s: 60.0,
            collect_series: false,
            executor: Executor::default(),
            shard: None,
        }
    }
}

impl SiteOptions {
    /// The options that determine output *bytes* — a site-sweep manifest's
    /// hash binds to exactly these. Workers, batch width, window size, and
    /// the executor are byte-invariant by contract (see the module docs)
    /// and excluded.
    pub(crate) fn identity_json(&self) -> crate::util::json::Json {
        use crate::util::json::{obj, Json};
        obj([
            ("dt_s", Json::Num(self.dt_s)),
            ("ramp_interval_s", Json::Num(self.ramp_interval_s)),
            ("load_interval_s", Json::Num(self.load_interval_s)),
        ])
    }

    /// What the manifest records as launch options: the identity fields
    /// plus the window size and shard — `--resume` reads its defaults from
    /// here (an explicit `--shard` flag overrides the recorded one).
    pub(crate) fn record_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let Json::Obj(mut o) = self.identity_json() else { unreachable!("identity is an object") };
        o.insert("window_s".to_string(), Json::Num(self.window_s));
        if let Some(sh) = self.shard {
            o.insert("shard".to_string(), Json::Str(sh.to_string()));
        }
        Json::Obj(o)
    }

    /// Does this run own site-sweep variant `id`? `None` owns everything.
    pub(crate) fn owns_cell(&self, id: &str) -> bool {
        self.shard.map_or(true, |s| s.owns(id))
    }
}

/// One facility's slice of a completed site run.
pub struct FacilityReport {
    pub name: String,
    pub phase_offset_s: f64,
    /// Server count (0 for training facilities).
    pub servers: usize,
    /// Scenario seed; `None` for training facilities (their step-function
    /// power model is deterministic and seedless).
    pub seed: Option<u64>,
    /// Summary-row role: "facility" (inference) or "training".
    pub role: &'static str,
    pub summary: SeriesSummary,
}

/// A completed site run: per-facility and composed characterizations plus
/// the site-level coincidence / headroom metrics.
pub struct SiteReport {
    pub spec: SiteSpec,
    pub dt_s: f64,
    pub facilities: Vec<FacilityReport>,
    /// Characterization of the composed site series.
    pub site: SeriesSummary,
    /// Σ facility peaks (the non-coincident worst case), in facility order.
    pub sum_facility_peaks_w: f64,
    /// Site peak ÷ Σ facility peaks, in (0, 1]. Clamped at 1: the site
    /// series is exported in f32, whose half-ulp rounding can nudge the
    /// coincident-peak case above the f64 sum by ~1e-7 relative.
    pub coincidence_factor: f64,
    /// 1 / coincidence factor (≥ 1).
    pub diversity_factor: f64,
    /// The oversubscription baseline (spec nameplate, else Σ facility peaks).
    pub nameplate_w: f64,
    /// `nameplate_w − site peak`.
    pub headroom_w: f64,
    /// `headroom_w / nameplate_w`.
    pub headroom_frac: f64,
    /// The composed site PCC series ([`SiteOptions::collect_series`]).
    pub site_series: Option<Vec<f32>>,
}

/// Prepare every configuration the site's inference facilities reference
/// (artifact load + classifier + packed-weight build, once per config) on
/// the generator. [`run_site_sink`] calls this itself; call it directly
/// before fanning variants over [`run_site_prepared_sink`] with a shared
/// `&Generator`.
pub fn prepare_site(gen: &mut Generator, spec: &SiteSpec) -> Result<()> {
    let scenarios: Vec<ScenarioSpec> =
        spec.facilities.iter().filter_map(|f| f.effective_scenario()).collect();
    gen.prepare_for_many(scenarios.iter().collect())
}

/// Run a site: compose every facility's windowed stream into the
/// utility-facing profile. With `out_dir`, streams `site_load.csv`
/// window-by-window and writes `site_summary.csv` + `site_spec.json` on
/// completion. Requires the native backend (windowed generation).
#[cfg(feature = "host")]
#[deprecated(since = "0.2.0", note = "route through crate::api::execute with RunSpec::Site")]
pub fn run_site(
    gen: &mut Generator,
    spec: &SiteSpec,
    opts: &SiteOptions,
    out_dir: Option<&Path>,
) -> Result<SiteReport> {
    spec.validate()?;
    prepare_site(gen, spec)?;
    let sink = out_dir.map(DirSink::new);
    run_site_inner(gen, spec, opts, sink.as_ref().map(|s| s as &dyn TraceSink), None)
}

/// [`run_site`] against an already-prepared shared generator (see
/// [`prepare_site`]): takes `&Generator`, so site-sweep variants can fan
/// out without exclusive access. Fails inside generation if a facility
/// references a configuration that was never prepared.
#[cfg(feature = "host")]
#[deprecated(
    since = "0.2.0",
    note = "route through crate::api::execute_prepared with RunSpec::Site"
)]
pub fn run_site_prepared(
    gen: &Generator,
    spec: &SiteSpec,
    opts: &SiteOptions,
    out_dir: Option<&Path>,
) -> Result<SiteReport> {
    let sink = out_dir.map(DirSink::new);
    run_site_inner(gen, spec, opts, sink.as_ref().map(|s| s as &dyn TraceSink), None)
}

/// [`run_site`] with exports routed through an arbitrary [`TraceSink`]
/// (`site_load.csv`, `site_summary.csv`, `site_spec.json` at the sink
/// root) — the embedding entry point, available without the `host`
/// feature.
#[deprecated(since = "0.2.0", note = "route through crate::api::execute with RunSpec::Site")]
pub fn run_site_sink(
    gen: &mut Generator,
    spec: &SiteSpec,
    opts: &SiteOptions,
    sink: Option<&dyn TraceSink>,
) -> Result<SiteReport> {
    spec.validate()?;
    prepare_site(gen, spec)?;
    run_site_inner(gen, spec, opts, sink, None)
}

/// [`run_site`] over an already-prepared generator with exports routed
/// through an arbitrary [`TraceSink`]; see [`run_site_sink`].
#[deprecated(
    since = "0.2.0",
    note = "route through crate::api::execute_prepared with RunSpec::Site"
)]
pub fn run_site_prepared_sink(
    gen: &Generator,
    spec: &SiteSpec,
    opts: &SiteOptions,
    sink: Option<&dyn TraceSink>,
) -> Result<SiteReport> {
    run_site_inner(gen, spec, opts, sink, None)
}

/// Shared per-facility stream geometry — every facility stream and both
/// executors see the same numbers.
#[derive(Clone, Copy)]
struct FacCtx<'a> {
    dt: f64,
    ramp_s: f64,
    utility_intervals: &'a [f64],
    n_steps: usize,
    window: usize,
    n_windows: usize,
    inner_workers: usize,
    max_batch: usize,
    window_s: f64,
}

/// Run one facility's window stream to completion, handing each finished
/// PCC window (overlays already applied) to `deliver` in order. Both
/// executors drive facilities through this one function — the threaded
/// path delivers into a rendezvous channel, the sequential path into a
/// buffer — so a facility's window bytes cannot depend on the executor.
fn drive_facility(
    gen_ro: &Generator,
    stream: &FacStream,
    chain: &mut OverlayChain,
    ctx: FacCtx<'_>,
    deliver: &mut dyn FnMut(Vec<f32>) -> Result<()>,
) -> Result<SeriesSummary> {
    let mut fac_stats = SiteSeriesStats::new(ctx.dt, ctx.ramp_s, ctx.utility_intervals)?;
    let mut pcc: Vec<f32> = Vec::new();
    match stream {
        FacStream::Inference(spec_f) => {
            let pue = spec_f.pue;
            let mut rows_buf: Vec<Vec<f64>> = Vec::new();
            let mut site_buf: Vec<f64> = Vec::new();
            gen_ro.facility_shared_windowed(
                spec_f,
                ctx.dt,
                ctx.window_s,
                ctx.inner_workers,
                ctx.max_batch,
                |facc| {
                    facc.fold_rows_site(&mut rows_buf, &mut site_buf);
                    // The facility PCC f32 series exactly as the sweep
                    // engine's streamed cells build it (shared helper).
                    pcc_window_into(&site_buf, pue, &mut pcc);
                    // Facility overlays transform the window before
                    // characterization, export, AND the site fold — the
                    // site composes **net** facility load. An empty chain
                    // is skipped entirely (the PR-4 byte-identity surface).
                    if !chain.is_empty() {
                        chain.apply_window(facc.window_t0(), &mut pcc);
                    }
                    fac_stats.push_window(&pcc);
                    deliver(pcc.clone())
                },
            )?;
        }
        FacStream::Training(tspec, phase) => {
            // The training synthesizer: evaluate the step function over
            // each lockstep window (phase-shifted like diurnal peaks:
            // positive offsets move steps later), run the same
            // per-facility overlay chain, characterize, and deliver —
            // indistinguishable from a generated stream to the
            // coordinator.
            let phase = *phase;
            for wi in 0..ctx.n_windows {
                let t0 = wi * ctx.window;
                let len = (ctx.n_steps - t0).min(ctx.window);
                pcc.clear();
                pcc.extend(
                    (0..len).map(|i| tspec.power_at((t0 + i) as f64 * ctx.dt - phase) as f32),
                );
                if !chain.is_empty() {
                    chain.apply_window(t0, &mut pcc);
                }
                fac_stats.push_window(&pcc);
                deliver(pcc.clone())?;
            }
        }
    }
    let mut summary = fac_stats.finalize()?;
    if !chain.is_empty() {
        summary.overlay = Some(chain.summary());
    }
    Ok(summary)
}

/// The coordinator side of one site run: the accumulator, the site
/// overlay chain, characterization state, and the streamed export. Both
/// executors fold every window through [`WindowFolder::fold_window`], so
/// the composed bytes cannot depend on the executor either.
struct WindowFolder {
    acc: SiteAccumulator,
    site_pcc: Vec<f32>,
    site_chain: OverlayChain,
    site_stats: SiteSeriesStats,
    site_series: Option<Vec<f32>>,
    writer: Option<StreamingCsv>,
    n_fac: usize,
    n_steps: usize,
    window: usize,
}

impl WindowFolder {
    /// One lockstep barrier: pull window `wi` from every facility (via
    /// `recv`, in facility order), fold, overlay, characterize, export.
    fn fold_window(
        &mut self,
        wi: usize,
        recv: &mut dyn FnMut(usize) -> Result<Vec<f32>>,
    ) -> Result<()> {
        let t0 = wi * self.window;
        let len = (self.n_steps - t0).min(self.window);
        self.acc.begin_window(t0, len);
        for f in 0..self.n_fac {
            let win = recv(f)?;
            self.acc.set_facility(f, &win)?;
        }
        let site_w = self.acc.fold_site()?;
        self.site_pcc.clear();
        self.site_pcc.extend(site_w.iter().map(|&x| x as f32));
        // Site-level overlays modulate the composed window before
        // characterization and export (empty chain = skipped).
        if !self.site_chain.is_empty() {
            self.site_chain.apply_window(self.acc.window_t0(), &mut self.site_pcc);
        }
        self.site_stats.push_window(&self.site_pcc);
        if let Some(series) = self.site_series.as_mut() {
            series.extend_from_slice(&self.site_pcc);
        }
        if let Some(w) = self.writer.as_mut() {
            w.push_col_f32(0, &self.site_pcc);
            for f in 0..self.n_fac {
                w.push_col_f32(1 + f, self.acc.facility_window(f));
            }
            w.write_ready_rows()?;
        }
        Ok(())
    }
}

/// The composition engine behind [`run_site_sink`] /
/// [`run_site_prepared_sink`]. With a [`Deadline`], the soft wall-clock
/// budget is checked at every lockstep window barrier (the site path's
/// cooperative yield points).
pub(crate) fn run_site_inner(
    gen: &Generator,
    spec: &SiteSpec,
    opts: &SiteOptions,
    sink: Option<&dyn TraceSink>,
    deadline: Option<&Deadline>,
) -> Result<SiteReport> {
    spec.validate()?;
    ensure!(
        opts.dt_s.is_finite() && opts.dt_s > 0.0,
        "site: dt must be positive seconds (got {})",
        opts.dt_s
    );
    ensure!(
        opts.window_s.is_finite() && opts.window_s > 0.0,
        "site: window must be positive seconds (got {})",
        opts.window_s
    );
    // Each facility contributes one window stream: inference facilities
    // run the full windowed generation engine; training facilities
    // synthesize their deterministic step-function profile directly.
    let streams: Vec<FacStream> = spec
        .facilities
        .iter()
        .map(|f| match &f.kind {
            FacilityKind::Inference(_) => {
                FacStream::Inference(f.effective_scenario().expect("inference facility"))
            }
            FacilityKind::Training(t) => FacStream::Training(t.clone(), f.phase_offset_s),
        })
        .collect();
    let n_inference = streams.iter().filter(|s| matches!(s, FacStream::Inference(_))).count();
    let gen_ro: &Generator = gen;

    let n_fac = streams.len();
    let dt = opts.dt_s;
    let horizon = spec.horizon_s();
    // The exact window geometry every facility stream computes internally
    // (one shared function — the lockstep schedule cannot drift).
    let (n_steps, window, n_windows) = window_geometry(horizon, dt, opts.window_s)?;
    let ramp_s = crate::metrics::planning::clamp_ramp_interval(opts.ramp_interval_s, horizon, dt);
    let total_workers = if opts.workers == 0 { default_workers() } else { opts.workers };
    // Only generating (inference) streams consume the worker budget; the
    // training synthesizer streams are O(window) loops. A sequential
    // executor forces every inner fan-out to the caller thread.
    let inner_workers = opts.executor.workers((total_workers / n_inference.max(1)).max(1));
    let ctx = FacCtx {
        dt,
        ramp_s,
        utility_intervals: &spec.utility_intervals_s,
        n_steps,
        window,
        n_windows,
        inner_workers,
        max_batch: opts.max_batch,
        window_s: opts.window_s,
    };

    let site_stats = SiteSeriesStats::new(dt, ramp_s, &spec.utility_intervals_s)?;
    let writer: Option<StreamingCsv> = match sink {
        Some(s) => {
            let mut names = vec!["site_w".to_string()];
            names.extend(spec.facilities.iter().map(|f| format!("{}_w", f.name)));
            Some(StreamingCsv::create_named(
                s,
                "site_load.csv",
                &names,
                dt,
                opts.load_interval_s,
                1.0,
            )?)
        }
        None => None,
    };
    let site_series: Option<Vec<f32>> =
        if opts.collect_series { Some(Vec::new()) } else { None };

    // Per-facility overlay chains (facility PCC modulation — a facility
    // nameplate cap, on-site battery/PV), built up front so spec errors
    // surface before any stream starts. PV stages follow the facility's
    // timezone (`effective_overlays`).
    let mut fac_chains: Vec<OverlayChain> = spec
        .facilities
        .iter()
        .map(|f| OverlayChain::new(&f.effective_overlays(), dt))
        .collect::<Result<Vec<_>>>()?;
    // Site-level overlay chain (interconnection cap, site battery,
    // utility-scale PV), applied to the composed window after the fold.
    let site_chain = OverlayChain::new(&spec.overlays, dt)?;

    let mut folder = WindowFolder {
        acc: SiteAccumulator::new(n_fac, window),
        site_pcc: Vec::new(),
        site_chain,
        site_stats,
        site_series,
        writer,
        n_fac,
        n_steps,
        window,
    };

    let fac_summaries: Vec<SeriesSummary> = if opts.executor.is_sequential() {
        // Sequential composition: run every facility stream to completion
        // in spec order (buffering its windows), then replay the exact
        // lockstep fold. The deadline is checked at every delivered window
        // and every fold barrier, so long runs stay interruptible.
        let mut buffered: Vec<VecDeque<Vec<f32>>> = Vec::with_capacity(n_fac);
        let mut summaries = Vec::with_capacity(n_fac);
        for ((f, stream), mut chain) in streams.iter().enumerate().zip(fac_chains.drain(..)) {
            let mut q = VecDeque::new();
            let summary = drive_facility(gen_ro, stream, &mut chain, ctx, &mut |w| {
                if let Some(d) = deadline {
                    d.check()?;
                }
                q.push_back(w);
                Ok(())
            })
            .map_err(|e| {
                anyhow!("site composition failed: facility '{}': {e:#}", spec.facilities[f].name)
            })?;
            buffered.push(q);
            summaries.push(summary);
        }
        for wi in 0..n_windows {
            if let Some(d) = deadline {
                d.check()?;
            }
            failpoint::hit("site.window", &spec.name)?;
            folder.fold_window(wi, &mut |f| {
                buffered[f].pop_front().ok_or_else(|| {
                    anyhow!("facility '{}': window stream ended early", spec.facilities[f].name)
                })
            })?;
        }
        summaries
    } else {
        #[cfg(feature = "host")]
        {
            compose_threaded(gen_ro, spec, &streams, fac_chains, ctx, &mut folder, deadline)?
        }
        #[cfg(not(feature = "host"))]
        {
            unreachable!("threaded executor requires the host feature")
        }
    };

    let WindowFolder { writer, site_chain, site_stats, site_series, .. } = folder;
    if let Some(w) = writer {
        w.finish()?;
    }
    let mut site = site_stats.finalize()?;
    if !site_chain.is_empty() {
        site.overlay = Some(site_chain.summary());
    }
    let sum_facility_peaks_w: f64 = fac_summaries.iter().map(|s| s.stats.peak_w).sum();
    let coincidence_factor = if sum_facility_peaks_w > 0.0 {
        (site.stats.peak_w / sum_facility_peaks_w).min(1.0)
    } else {
        1.0
    };
    let nameplate_w = spec.nameplate_w.unwrap_or(sum_facility_peaks_w);
    let headroom_w = nameplate_w - site.stats.peak_w;
    let report = SiteReport {
        spec: spec.clone(),
        dt_s: dt,
        facilities: spec
            .facilities
            .iter()
            .zip(fac_summaries)
            .map(|(f, summary)| FacilityReport {
                name: f.name.clone(),
                phase_offset_s: f.phase_offset_s,
                servers: f.n_servers(),
                seed: f.scenario().map(|s| s.seed),
                role: f.role(),
                summary,
            })
            .collect(),
        site,
        sum_facility_peaks_w,
        coincidence_factor,
        diversity_factor: 1.0 / coincidence_factor,
        nameplate_w,
        headroom_w,
        headroom_frac: if nameplate_w > 0.0 { headroom_w / nameplate_w } else { 0.0 },
        site_series,
    };
    if let Some(s) = sink {
        s.put("site_summary.csv", report.summary_csv().as_bytes())?;
        // Byte-identical to the pre-split `SiteSpec::save` (same pretty
        // printer, same trailing newline), minus the host-only staging.
        s.put("site_spec.json", json::to_string_pretty(&report.spec.to_json()).as_bytes())?;
    }
    Ok(report)
}

/// The threaded composition path: one thread per facility stream, a
/// capacity-1 rendezvous channel each, and the coordinator folding at the
/// lockstep barrier. Failures are recorded (never early-returned) so the
/// channels always drop and the facility threads always join.
#[cfg(feature = "host")]
fn compose_threaded(
    gen_ro: &Generator,
    spec: &SiteSpec,
    streams: &[FacStream],
    fac_chains: Vec<OverlayChain>,
    ctx: FacCtx<'_>,
    folder: &mut WindowFolder,
    deadline: Option<&Deadline>,
) -> Result<Vec<SeriesSummary>> {
    let n_fac = streams.len();
    std::thread::scope(|sc| -> Result<Vec<SeriesSummary>> {
        let mut handles = Vec::with_capacity(n_fac);
        let mut rxs = Vec::with_capacity(n_fac);
        for (stream, chain) in streams.iter().zip(fac_chains) {
            let (tx, rx) = mpsc::sync_channel::<Vec<f32>>(1);
            rxs.push(rx);
            handles.push(sc.spawn(move || -> Result<SeriesSummary> {
                let mut chain = chain;
                drive_facility(gen_ro, stream, &mut chain, ctx, &mut |w| {
                    tx.send(w).map_err(|_| anyhow!(ABORT_MSG))
                })
            }));
        }

        // Coordinator: one lockstep barrier per window. Failures are
        // recorded (never early-returned) so the channels always drop and
        // the facility threads always join.
        let mut coord_err: Option<anyhow::Error> = None;
        'windows: for wi in 0..ctx.n_windows {
            if let Some(d) = deadline {
                if let Err(e) = d.check() {
                    coord_err = Some(e);
                    break 'windows;
                }
            }
            if let Err(e) = failpoint::hit("site.window", &spec.name) {
                coord_err = Some(e);
                break 'windows;
            }
            let folded = folder.fold_window(wi, &mut |f| {
                rxs[f].recv().map_err(|_| {
                    anyhow!("facility '{}': window stream ended early", spec.facilities[f].name)
                })
            });
            if let Err(e) = folded {
                coord_err = Some(e);
                break 'windows;
            }
        }
        drop(rxs);
        let mut summaries = Vec::with_capacity(n_fac);
        let mut errors: Vec<String> = Vec::new();
        for (f, h) in handles.into_iter().enumerate() {
            let name = &spec.facilities[f].name;
            match h.join() {
                Ok(Ok(s)) => summaries.push(s),
                Ok(Err(e)) => {
                    let msg = format!("{e:#}");
                    // Delivery aborts are downstream of the real failure.
                    if !msg.contains(ABORT_MSG) {
                        errors.push(format!("facility '{name}': {msg}"));
                    }
                }
                Err(_) => errors.push(format!("facility '{name}': generation thread panicked")),
            }
        }
        if !errors.is_empty() {
            bail!("site composition failed: {}", errors.join("; "));
        }
        if let Some(e) = coord_err {
            return Err(e);
        }
        ensure!(
            summaries.len() == n_fac,
            "site composition failed: {} of {n_fac} facility streams aborted",
            n_fac - summaries.len()
        );
        Ok(summaries)
    })
}

impl SiteReport {
    /// `true` when any series of this report (a facility's or the
    /// composed site's) was transformed by an overlay chain — the exports
    /// then carry the overlay delta columns on every row.
    pub fn has_overlays(&self) -> bool {
        self.site.overlay.is_some() || self.facilities.iter().any(|f| f.summary.overlay.is_some())
    }

    /// The utility-facing summary as CSV: one row per facility plus the
    /// composed `site` row. Site-only columns (coincidence, headroom) are
    /// empty on facility rows, as are overlay columns on overlay-free rows
    /// (and absent entirely from overlay-free reports — the PR-4 header).
    /// Deterministic per `(spec, seeds)`: shortest round-trip float
    /// formatting, no timing columns.
    pub fn summary_csv(&self) -> String {
        let with_overlay = self.has_overlays();
        let mut s = String::from(
            "name,role,servers,seed,phase_offset_s,peak_w,avg_w,p99_w,energy_kwh,cv,load_factor,max_ramp_w",
        );
        characterization_header(&self.site, with_overlay, &mut s);
        s.push_str(
            ",coincidence_factor,diversity_factor,sum_facility_peaks_w,nameplate_w,headroom_w,headroom_frac\n",
        );
        for f in &self.facilities {
            let seed = match f.seed {
                Some(s) => format!("{s}"),
                None => String::new(),
            };
            push_series_row(
                &mut s,
                &f.name,
                f.role,
                f.servers,
                &seed,
                &format!("{}", f.phase_offset_s),
                &f.summary,
                with_overlay,
            );
            // Six site-only trailing columns stay empty on facility rows.
            s.push_str(",,,,,,\n");
        }
        push_series_row(
            &mut s,
            &self.spec.name,
            "site",
            self.spec.n_servers(),
            "",
            "",
            &self.site,
            with_overlay,
        );
        s.push_str(&format!(
            ",{},{},{},{},{},{}\n",
            self.coincidence_factor,
            self.diversity_factor,
            self.sum_facility_peaks_w,
            self.nameplate_w,
            self.headroom_w,
            self.headroom_frac,
        ));
        s
    }

    /// Human-readable summary (MW units).
    pub fn summary_table(&self) -> String {
        let mut s = format!(
            "{:<16} {:<9} {:>6} {:>9} {:>9} {:>9} {:>9} {:>9} {:>7}\n",
            "name", "role", "srv", "peak MW", "avg MW", "p99 MW", "MWh", "ramp MW", "CV"
        );
        let mut row = |name: &str, role: &str, servers: usize, sum: &SeriesSummary| {
            s.push_str(&format!(
                "{:<16} {:<9} {:>6} {:>9.3} {:>9.3} {:>8.3}{} {:>9.2} {:>9.3} {:>7.3}\n",
                name,
                role,
                servers,
                sum.stats.peak_w / 1e6,
                sum.stats.avg_w / 1e6,
                sum.stats.p99_w / 1e6,
                if sum.exact_quantiles { " " } else { "~" },
                sum.stats.energy_kwh / 1e3,
                sum.stats.max_ramp_w / 1e6,
                sum.stats.cv,
            ));
        };
        for f in &self.facilities {
            row(&f.name, f.role, f.servers, &f.summary);
        }
        row(&self.spec.name, "site", self.spec.n_servers(), &self.site);
        s.push_str(&format!(
            "coincidence {:.4} (diversity {:.4}) | Σ facility peaks {:.3} MW | \
             nameplate {:.3} MW → headroom {:.3} MW ({:.1}%)\n",
            self.coincidence_factor,
            self.diversity_factor,
            self.sum_facility_peaks_w / 1e6,
            self.nameplate_w / 1e6,
            self.headroom_w / 1e6,
            self.headroom_frac * 100.0,
        ));
        for r in &self.site.ramps {
            s.push_str(&format!(
                "site ramp @{}s: max {:.3} MW, p99 {:.3} MW over {} intervals\n",
                fmt_secs(r.interval_s),
                r.max_w / 1e6,
                r.p99_w / 1e6,
                r.n_ramps,
            ));
        }
        let mut overlay_line = |name: &str, sum: &SeriesSummary| {
            if let Some(o) = &sum.overlay {
                s.push_str(&format!(
                    "{name} overlay: net peak {:.3} MW (raw {:.3}, shaved {:.3}) | \
                     Δ {:.1} kWh | cap clip {:.1} kWh over {:.0} s | \
                     battery {:.2} cycles, SoC [{:.2}, {:.2}] | PV offset {:.1} kWh\n",
                    o.net_peak_w / 1e6,
                    o.raw_peak_w / 1e6,
                    o.shaved_peak_w / 1e6,
                    o.shaved_kwh,
                    o.cap_clipped_kwh,
                    o.cap_violation_s,
                    o.battery_cycles,
                    o.soc_min_frac,
                    o.soc_max_frac,
                    o.pv_offset_kwh,
                ));
            }
        };
        for f in &self.facilities {
            overlay_line(&f.name, &f.summary);
        }
        overlay_line("site", &self.site);
        s
    }
}

/// Append the shared (non-site-only) prefix of one summary row — without a
/// trailing newline, so the caller controls the site-only tail.
fn push_series_row(
    s: &mut String,
    name: &str,
    role: &str,
    servers: usize,
    seed: &str,
    phase: &str,
    sum: &SeriesSummary,
    with_overlay: bool,
) {
    s.push_str(&format!(
        "{},{role},{servers},{seed},{phase},{},{},{},{},{},{},{}",
        csv_field(name),
        sum.stats.peak_w,
        sum.stats.avg_w,
        sum.stats.p99_w,
        sum.stats.energy_kwh,
        sum.stats.cv,
        sum.stats.load_factor,
        sum.stats.max_ramp_w,
    ));
    characterization_row(sum, with_overlay, s);
}
