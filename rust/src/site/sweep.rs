//! Site sweep axis: expand one base [`SiteSpec`] across phase-spread and
//! seed axes, run every variant through the composition engine, and
//! summarize how workload phase diversity shapes the utility-facing
//! profile (the related-work observation that composition smooths
//! aggregate demand, turned into a scannable axis).
//!
//! # Grid JSON schema
//!
//! ```text
//! {
//!   "name":            string        — sweep name
//!   "site":            SiteSpec      — the base site (facility list, nameplate)
//!   "phase_spreads_h": [ 0, 3, ... ] — facility i adds i × spread hours to its
//!                                      declared phase offset (a timezone ladder)
//!   "seeds":           [ 0, 1, ... ] — facility i runs seed `seed + i`
//! }
//! ```

use super::compose::{run_site, SiteOptions, SiteReport};
use super::spec::SiteSpec;
use crate::coordinator::Generator;
use crate::scenarios::runner::csv_field;
use crate::util::json::{self, Json};
use anyhow::{bail, Context, Result};
use std::path::Path;

/// A declarative site sweep: one base site × phase spreads × seeds.
#[derive(Debug, Clone, PartialEq)]
pub struct SiteGrid {
    pub name: String,
    pub base: SiteSpec,
    /// Inter-facility phase stagger in hours; facility `i` adds
    /// `i × spread × 3600` s to its declared offset.
    pub phase_spreads_h: Vec<f64>,
    /// Base seeds; facility `i` runs `seed + i`.
    pub seeds: Vec<u64>,
}

/// One expanded site-sweep variant.
#[derive(Debug, Clone)]
pub struct SiteVariant {
    /// Stable id `p<i>-s<seed>` (axis index, seed value).
    pub id: String,
    pub label: String,
    pub spec: SiteSpec,
}

impl SiteGrid {
    pub fn n_variants(&self) -> usize {
        self.phase_spreads_h.len() * self.seeds.len()
    }

    pub fn validate(&self) -> Result<()> {
        self.base.validate().with_context(|| format!("site sweep '{}': base site", self.name))?;
        if self.phase_spreads_h.is_empty() {
            bail!("site sweep '{}' has no phase spreads", self.name);
        }
        if self.seeds.is_empty() {
            bail!("site sweep '{}' has no seeds", self.name);
        }
        if self.phase_spreads_h.iter().any(|s| !s.is_finite()) {
            bail!("site sweep '{}': phase spreads must be finite hours", self.name);
        }
        if self.seeds.iter().any(|&s| s > (1u64 << 53)) {
            bail!("site sweep '{}': seeds must be < 2^53 to round-trip through JSON", self.name);
        }
        Ok(())
    }

    /// Expand the cross-product, phase-major / seed-minor, with stable ids.
    pub fn expand(&self) -> Vec<SiteVariant> {
        let mut out = Vec::with_capacity(self.n_variants());
        for (pi, &spread_h) in self.phase_spreads_h.iter().enumerate() {
            for &seed in &self.seeds {
                let mut spec = self.base.clone();
                spec.name = format!("{}-p{pi}-s{seed}", self.base.name);
                for (i, fac) in spec.facilities.iter_mut().enumerate() {
                    fac.phase_offset_s += i as f64 * spread_h * 3600.0;
                    fac.scenario.seed = seed + i as u64;
                }
                out.push(SiteVariant {
                    id: format!("p{pi}-s{seed}"),
                    label: format!("spread {spread_h}h | seed {seed}"),
                    spec,
                });
            }
        }
        out
    }

    pub fn to_json(&self) -> Json {
        json::obj([
            ("name", self.name.as_str().into()),
            ("site", self.base.to_json()),
            (
                "phase_spreads_h",
                Json::Arr(self.phase_spreads_h.iter().map(|&x| Json::Num(x)).collect()),
            ),
            ("seeds", Json::Arr(self.seeds.iter().map(|&s| Json::Num(s as f64)).collect())),
        ])
    }

    pub fn from_json(v: &Json) -> Result<SiteGrid> {
        let grid = SiteGrid {
            name: match v.get_opt("name") {
                Some(x) => x.as_str()?.to_string(),
                None => "site_sweep".to_string(),
            },
            base: SiteSpec::from_json(v.get("site")?)?,
            phase_spreads_h: v.get("phase_spreads_h")?.f64_array().map_err(anyhow::Error::from)?,
            seeds: v
                .get("seeds")?
                .f64_array()
                .map_err(anyhow::Error::from)?
                .into_iter()
                .map(|s| {
                    if s < 0.0 || s.fract() != 0.0 || s > (1u64 << 53) as f64 {
                        bail!("seeds must be integers in [0, 2^53] (got {s})");
                    }
                    Ok(s as u64)
                })
                .collect::<Result<Vec<_>>>()?,
        };
        grid.validate()?;
        Ok(grid)
    }

    pub fn load(path: &Path) -> Result<SiteGrid> {
        let v = json::parse_file(path).map_err(anyhow::Error::from)?;
        Self::from_json(&v).with_context(|| format!("parsing site sweep {}", path.display()))
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        json::write_file(path, &self.to_json()).map_err(anyhow::Error::from)
    }
}

/// Run every variant of a site sweep (sequentially — each variant already
/// parallelizes across facilities and racks). With `out_dir`, each variant
/// exports under `<out_dir>/<variant_id>/` and a
/// `site_sweep_summary.csv` collects one site row per variant.
pub fn run_site_sweep(
    gen: &mut Generator,
    grid: &SiteGrid,
    opts: &SiteOptions,
    out_dir: Option<&Path>,
) -> Result<Vec<(SiteVariant, SiteReport)>> {
    grid.validate()?;
    if let Some(dir) = out_dir {
        std::fs::create_dir_all(dir)?;
    }
    let mut out = Vec::with_capacity(grid.n_variants());
    for variant in grid.expand() {
        let vdir = out_dir.map(|d| d.join(&variant.id));
        let report = run_site(gen, &variant.spec, opts, vdir.as_deref())
            .with_context(|| format!("site variant {}", variant.id))?;
        out.push((variant, report));
    }
    if let Some(dir) = out_dir {
        std::fs::write(dir.join("site_sweep_summary.csv"), sweep_summary_csv(&out))?;
        grid.save(&dir.join("site_sweep.json"))?;
    }
    Ok(out)
}

/// One site row per variant (same metric columns as `site_summary.csv`'s
/// site row, keyed by variant id — `powertrace diff`-comparable).
pub fn sweep_summary_csv(results: &[(SiteVariant, SiteReport)]) -> String {
    let mut s = String::from(
        "variant,site,facilities,servers,peak_w,avg_w,p99_w,energy_kwh,cv,load_factor,max_ramp_w",
    );
    if let Some((_, first)) = results.first() {
        super::metrics::characterization_header(&first.site, &mut s);
    }
    s.push_str(",coincidence_factor,headroom_frac\n");
    for (variant, report) in results {
        s.push_str(&format!(
            "{},{},{},{},{},{},{},{},{},{},{}",
            variant.id,
            csv_field(&report.spec.name),
            report.facilities.len(),
            report.spec.n_servers(),
            report.site.stats.peak_w,
            report.site.stats.avg_w,
            report.site.stats.p99_w,
            report.site.stats.energy_kwh,
            report.site.stats.cv,
            report.site.stats.load_factor,
            report.site.stats.max_ramp_w,
        ));
        super::metrics::characterization_row(&report.site, &mut s);
        s.push_str(&format!(",{},{}\n", report.coincidence_factor, report.headroom_frac));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ScenarioSpec;

    fn grid() -> SiteGrid {
        let base = SiteSpec::staggered("tri", &ScenarioSpec::default_poisson("cfg", 0.5), 3, 0.0);
        SiteGrid {
            name: "spread_study".into(),
            base,
            phase_spreads_h: vec![0.0, 3.0],
            seeds: vec![0, 7],
        }
    }

    #[test]
    fn expansion_is_deterministic_cross_product() {
        let g = grid();
        assert_eq!(g.n_variants(), 4);
        let a = g.expand();
        let b = g.expand();
        assert_eq!(a.len(), 4);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.spec, y.spec);
        }
        // Ids unique; phase ladder and seeds applied per facility.
        assert_eq!(a[0].id, "p0-s0");
        let last = &a[3]; // p1-s7, spread 3 h
        assert_eq!(last.spec.facilities[2].phase_offset_s, 2.0 * 3.0 * 3600.0);
        assert_eq!(last.spec.facilities[2].scenario.seed, 9);
        last.spec.validate().unwrap();
    }

    #[test]
    fn json_roundtrip_and_validation() {
        let g = grid();
        let back = SiteGrid::from_json(&g.to_json()).unwrap();
        assert_eq!(back, g);

        let mut g = grid();
        g.seeds.clear();
        assert!(g.validate().is_err());
        let mut g = grid();
        g.phase_spreads_h = vec![f64::INFINITY];
        assert!(g.validate().is_err());
    }
}
