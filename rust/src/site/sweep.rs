//! Site sweep axis: expand one base [`SiteSpec`] across phase-spread and
//! seed axes, run every variant through the composition engine, and
//! summarize how workload phase diversity shapes the utility-facing
//! profile (the related-work observation that composition smooths
//! aggregate demand, turned into a scannable axis).
//!
//! # Grid JSON schema
//!
//! ```text
//! {
//!   "name":            string        — sweep name
//!   "site":            SiteSpec      — the base site (facility list, nameplate)
//!   "phase_spreads_h": [ 0, 3, ... ] — facility i adds i × spread hours to its
//!                                      declared phase offset (a timezone ladder)
//!   "seeds":           [ 0, 1, ... ] — facility i runs seed `seed + i`
//!   "battery_kwh":     [ 0, 50, … ]  — optional overlay axis: site-level battery
//!                                      capacity per variant (0 = no battery);
//!                                      needs the `battery` template below
//!   "cap_w":           [ 0, 1.5e5 ]  — optional overlay axis: site interconnection
//!                                      cap per variant (0 = uncapped)
//!   "battery":         OverlaySpec   — battery template (kind "battery") whose
//!                                      capacity_kwh each axis point replaces
//! }
//! ```
//!
//! The overlay axes answer the sizing question the overlays exist for:
//! *how much battery (and how tight a cap) does this site's net load
//! tolerate?* Each variant appends its battery (then its cap — shave
//! first, clip the residual) to the base site's **site-level** overlay
//! list; axis value 0 appends nothing, so the baseline rides in the same
//! sweep. Variants without the axes keep their PR-4 ids (`p<i>-s<seed>`);
//! with them, ids extend to `p<i>-s<seed>-b<j>-c<k>`.

#[cfg(feature = "host")]
use super::compose::prepare_site;
use super::compose::run_site_inner;
use super::compose::{SiteOptions, SiteReport};
use super::metrics::SeriesSummary;
use super::overlay::OverlaySpec;
use super::spec::SiteSpec;
use crate::coordinator::Generator;
use crate::export::csv_field;
#[cfg(feature = "host")]
use crate::export::DirSink;
use crate::export::{ScopedSink, TraceSink};
#[cfg(feature = "host")]
use crate::robust::manifest::content_hash;
#[cfg(feature = "host")]
use crate::robust::shutdown;
#[cfg(feature = "host")]
use crate::robust::{
    failpoint, fsx, run_isolated, CellStatus, ExportRecord, Isolated, ManifestKeeper, RetryPolicy,
    RunManifest,
};
#[cfg(feature = "host")]
use crate::scenarios::QuarantinedCell;
use crate::util::json::{self, Json};
use crate::util::threadpool::parallel_map_results;
use anyhow::{bail, Context, Result};
#[cfg(feature = "host")]
use std::path::{Path, PathBuf};

/// A declarative site sweep: one base site × phase spreads × seeds.
#[derive(Debug, Clone, PartialEq)]
pub struct SiteGrid {
    pub name: String,
    pub base: SiteSpec,
    /// Inter-facility phase stagger in hours; facility `i` adds
    /// `i × spread × 3600` s to its declared offset.
    pub phase_spreads_h: Vec<f64>,
    /// Base seeds; facility `i` runs `seed + i`.
    pub seeds: Vec<u64>,
    /// Optional overlay axis: site-level battery capacities (kWh; 0 = no
    /// battery). Empty = axis absent (ids keep the `p<i>-s<seed>` form).
    pub battery_kwh: Vec<f64>,
    /// Optional overlay axis: site interconnection caps (W; 0 = uncapped).
    pub cap_w: Vec<f64>,
    /// Battery template the `battery_kwh` axis instantiates (must be a
    /// `battery` stage; its `capacity_kwh` is replaced per axis point).
    pub battery: Option<OverlaySpec>,
}

/// One expanded site-sweep variant.
#[derive(Debug, Clone)]
pub struct SiteVariant {
    /// Stable id `p<i>-s<seed>` (axis index, seed value).
    pub id: String,
    pub label: String,
    pub spec: SiteSpec,
}

impl SiteGrid {
    pub fn n_variants(&self) -> usize {
        self.phase_spreads_h.len()
            * self.seeds.len()
            * self.battery_kwh.len().max(1)
            * self.cap_w.len().max(1)
    }

    pub fn validate(&self) -> Result<()> {
        self.base.validate().with_context(|| format!("site sweep '{}': base site", self.name))?;
        if self.phase_spreads_h.is_empty() {
            bail!("site sweep '{}' has no phase spreads", self.name);
        }
        if self.seeds.is_empty() {
            bail!("site sweep '{}' has no seeds", self.name);
        }
        if self.phase_spreads_h.iter().any(|s| !s.is_finite()) {
            bail!("site sweep '{}': phase spreads must be finite hours", self.name);
        }
        if self.seeds.iter().any(|&s| s > (1u64 << 53)) {
            bail!("site sweep '{}': seeds must be < 2^53 to round-trip through JSON", self.name);
        }
        if self.battery_kwh.iter().any(|b| !b.is_finite() || *b < 0.0) {
            bail!("site sweep '{}': battery_kwh axis must be finite and non-negative", self.name);
        }
        if self.cap_w.iter().any(|c| !c.is_finite() || *c < 0.0) {
            bail!("site sweep '{}': cap_w axis must be finite and non-negative", self.name);
        }
        match &self.battery {
            Some(t @ OverlaySpec::Battery { .. }) => t
                .validate()
                .with_context(|| format!("site sweep '{}': battery template", self.name))?,
            Some(other) => bail!(
                "site sweep '{}': battery template must have kind 'battery' (got '{}')",
                self.name,
                other.kind()
            ),
            None if self.battery_kwh.iter().any(|&b| b > 0.0) => bail!(
                "site sweep '{}': battery_kwh axis needs a 'battery' template spec",
                self.name
            ),
            None => {}
        }
        Ok(())
    }

    /// Expand the cross-product — phase-major, then seed, then battery,
    /// then cap — with stable ids. Overlay axes append to the base site's
    /// site-level overlay list (battery before cap: shave first, clip the
    /// residual); an empty axis contributes neither a stage nor an id
    /// suffix, so overlay-free grids expand exactly as before.
    pub fn expand(&self) -> Vec<SiteVariant> {
        // An absent axis behaves as one pass-through point.
        let b_axis: Vec<Option<(usize, f64)>> = if self.battery_kwh.is_empty() {
            vec![None]
        } else {
            self.battery_kwh.iter().enumerate().map(|(i, &b)| Some((i, b))).collect()
        };
        let c_axis: Vec<Option<(usize, f64)>> = if self.cap_w.is_empty() {
            vec![None]
        } else {
            self.cap_w.iter().enumerate().map(|(i, &c)| Some((i, c))).collect()
        };
        let mut out = Vec::with_capacity(self.n_variants());
        for (pi, &spread_h) in self.phase_spreads_h.iter().enumerate() {
            for &seed in &self.seeds {
                for b in &b_axis {
                    for c in &c_axis {
                        let mut spec = self.base.clone();
                        for (i, fac) in spec.facilities.iter_mut().enumerate() {
                            fac.phase_offset_s += i as f64 * spread_h * 3600.0;
                            // Training facilities are seedless; the seed
                            // axis only re-seeds the generated streams.
                            if let Some(s) = fac.scenario_mut() {
                                s.seed = seed + i as u64;
                            }
                        }
                        let mut id = format!("p{pi}-s{seed}");
                        let mut label = format!("spread {spread_h}h | seed {seed}");
                        if let Some((bi, kwh)) = *b {
                            id.push_str(&format!("-b{bi}"));
                            label.push_str(&format!(" | battery {kwh} kWh"));
                            if kwh > 0.0 {
                                let mut stage =
                                    self.battery.clone().expect("validated battery template");
                                if let OverlaySpec::Battery { ref mut capacity_kwh, .. } = stage {
                                    *capacity_kwh = kwh;
                                }
                                spec.overlays.push(stage);
                            }
                        }
                        if let Some((ci, cap)) = *c {
                            id.push_str(&format!("-c{ci}"));
                            label.push_str(&format!(" | cap {cap} W"));
                            if cap > 0.0 {
                                spec.overlays.push(OverlaySpec::Cap { cap_w: cap });
                            }
                        }
                        spec.name = format!("{}-{id}", self.base.name);
                        out.push(SiteVariant { id, label, spec });
                    }
                }
            }
        }
        out
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("name", Json::Str(self.name.clone())),
            ("site", self.base.to_json()),
            (
                "phase_spreads_h",
                Json::Arr(self.phase_spreads_h.iter().map(|&x| Json::Num(x)).collect()),
            ),
            ("seeds", Json::Arr(self.seeds.iter().map(|&s| Json::Num(s as f64)).collect())),
        ];
        // Overlay axes omitted when absent (pre-overlay JSON unchanged).
        if !self.battery_kwh.is_empty() {
            fields.push((
                "battery_kwh",
                Json::Arr(self.battery_kwh.iter().map(|&x| Json::Num(x)).collect()),
            ));
        }
        if !self.cap_w.is_empty() {
            fields.push(("cap_w", Json::Arr(self.cap_w.iter().map(|&x| Json::Num(x)).collect())));
        }
        if let Some(t) = &self.battery {
            fields.push(("battery", t.to_json()));
        }
        json::obj(fields)
    }

    pub fn from_json(v: &Json) -> Result<SiteGrid> {
        let grid = SiteGrid {
            name: match v.get_opt("name") {
                Some(x) => x.as_str()?.to_string(),
                None => "site_sweep".to_string(),
            },
            base: SiteSpec::from_json(v.get("site")?)?,
            phase_spreads_h: v.get("phase_spreads_h")?.f64_array().map_err(anyhow::Error::from)?,
            seeds: v
                .get("seeds")?
                .f64_array()
                .map_err(anyhow::Error::from)?
                .into_iter()
                .map(|s| {
                    if s < 0.0 || s.fract() != 0.0 || s > (1u64 << 53) as f64 {
                        bail!("seeds must be integers in [0, 2^53] (got {s})");
                    }
                    Ok(s as u64)
                })
                .collect::<Result<Vec<_>>>()?,
            battery_kwh: match v.get_opt("battery_kwh") {
                Some(x) => x.f64_array().map_err(anyhow::Error::from)?,
                None => Vec::new(),
            },
            cap_w: match v.get_opt("cap_w") {
                Some(x) => x.f64_array().map_err(anyhow::Error::from)?,
                None => Vec::new(),
            },
            battery: match v.get_opt("battery") {
                Some(x) => Some(OverlaySpec::from_json(x).context("battery template")?),
                None => None,
            },
        };
        grid.validate()?;
        Ok(grid)
    }

    #[cfg(feature = "host")]
    pub fn load(path: &Path) -> Result<SiteGrid> {
        let v = json::parse_file(path).map_err(anyhow::Error::from)?;
        Self::from_json(&v).with_context(|| format!("parsing site sweep {}", path.display()))
    }

    #[cfg(feature = "host")]
    pub fn save(&self, path: &Path) -> Result<()> {
        json::write_file(path, &self.to_json()).map_err(anyhow::Error::from)
    }
}

/// Run every variant of a site sweep (one at a time — each variant already
/// parallelizes across facilities and racks). With `out_dir`, each variant
/// exports under `<out_dir>/<variant_id>/` and a
/// `site_sweep_summary.csv` collects one site row per variant.
///
/// Variants run through the fault-isolating
/// [`parallel_map_results`] path (a panicking variant surfaces as that
/// variant's error instead of unwinding through the sweep), but this
/// entry point still fails fast on the first bad variant. For quarantine
/// semantics and crash-safe resume, use [`run_site_sweep_checkpointed`].
#[cfg(feature = "host")]
#[deprecated(since = "0.2.0", note = "route through crate::api::execute with RunSpec::SiteSweep")]
pub fn run_site_sweep(
    gen: &mut Generator,
    grid: &SiteGrid,
    opts: &SiteOptions,
    out_dir: Option<&Path>,
) -> Result<Vec<(SiteVariant, SiteReport)>> {
    grid.validate()?;
    // Variants differ only in phases, seeds, and site-level overlays —
    // never in server configurations — so preparing the base site covers
    // every variant, and the fan-out can share a read-only generator.
    prepare_site(gen, &grid.base)?;
    match out_dir {
        Some(dir) => {
            std::fs::create_dir_all(dir)?;
            let sink = DirSink::new(dir);
            site_sweep_prepared_sink(gen, grid, opts, Some(&sink))
        }
        None => site_sweep_prepared_sink(gen, grid, opts, None),
    }
}

/// [`run_site_sweep`] over an already-prepared shared generator (see
/// [`prepare_site`]), exports routed through an arbitrary [`TraceSink`] —
/// the `pub(crate)` engine behind [`crate::api::execute_prepared`] and
/// the serve layer. Each variant exports under `<variant_id>/` via a
/// [`ScopedSink`]; `site_sweep_summary.csv` + `site_sweep.json` land at
/// the sink root, byte-identical to the directory-backed layout.
pub(crate) fn site_sweep_prepared_sink(
    gen: &Generator,
    grid: &SiteGrid,
    opts: &SiteOptions,
    sink: Option<&dyn TraceSink>,
) -> Result<Vec<(SiteVariant, SiteReport)>> {
    grid.validate()?;
    let mut variants = grid.expand();
    if opts.shard.is_some() {
        variants.retain(|v| opts.owns_cell(&v.id));
    }
    let results = parallel_map_results(variants.len(), 1, |i| {
        let variant = &variants[i];
        let scoped = sink.map(|s| ScopedSink::new(s, &variant.id));
        run_site_inner(
            gen,
            &variant.spec,
            opts,
            scoped.as_ref().map(|s| s as &dyn TraceSink),
            None,
        )
    });
    let mut out = Vec::with_capacity(variants.len());
    for (variant, r) in variants.into_iter().zip(results) {
        let report = r.with_context(|| format!("site variant {}", variant.id))?;
        out.push((variant, report));
    }
    if let Some(s) = sink {
        s.put("site_sweep_summary.csv", sweep_summary_csv(&out).as_bytes())?;
        s.put("site_sweep.json", json::to_string_pretty(&grid.to_json()).as_bytes())?;
    }
    Ok(out)
}

/// Header line for the site-sweep summary. `site` supplies the
/// data-independent characterization columns (ramp intervals come from the
/// spec, so any variant's summary yields the same header); `None` — no
/// variant has completed — drops them, matching an empty result set.
pub(crate) fn site_sweep_header(site: Option<&SeriesSummary>, with_overlay: bool) -> String {
    let mut s = String::from(
        "variant,site,facilities,servers,peak_w,avg_w,p99_w,energy_kwh,cv,load_factor,max_ramp_w",
    );
    if let Some(site) = site {
        super::metrics::characterization_header(site, with_overlay, &mut s);
    }
    s.push_str(",coincidence_factor,headroom_frac\n");
    s
}

/// One [`site_sweep_header`]-shaped row (trailing newline included).
pub(crate) fn site_sweep_row(variant_id: &str, report: &SiteReport, with_overlay: bool) -> String {
    let mut s = format!(
        "{},{},{},{},{},{},{},{},{},{},{}",
        variant_id,
        csv_field(&report.spec.name),
        report.facilities.len(),
        report.spec.n_servers(),
        report.site.stats.peak_w,
        report.site.stats.avg_w,
        report.site.stats.p99_w,
        report.site.stats.energy_kwh,
        report.site.stats.cv,
        report.site.stats.load_factor,
        report.site.stats.max_ramp_w,
    );
    super::metrics::characterization_row(&report.site, with_overlay, &mut s);
    s.push_str(&format!(",{},{}\n", report.coincidence_factor, report.headroom_frac));
    s
}

/// One site row per variant (same metric columns as `site_summary.csv`'s
/// site row, keyed by variant id — `powertrace diff`-comparable).
pub fn sweep_summary_csv(results: &[(SiteVariant, SiteReport)]) -> String {
    // One decision for the whole table: overlay columns appear when any
    // variant modulated its load (rows without a chain pad with empties).
    let with_overlay = results.iter().any(|(_, r)| r.has_overlays());
    let mut s = site_sweep_header(results.first().map(|(_, r)| &r.site), with_overlay);
    for (variant, report) in results {
        s.push_str(&site_sweep_row(&variant.id, report, with_overlay));
    }
    s
}

/// Manifest file name inside a checkpointed site-sweep output directory.
#[cfg(feature = "host")]
pub const SITE_SWEEP_MANIFEST: &str = "manifest.json";

/// What [`run_site_sweep_checkpointed`] hands back.
#[cfg(feature = "host")]
pub struct SiteSweepOutcome {
    /// Variants executed *this* run, paired with their reports, in grid
    /// order (restored variants are in the summary but not re-run).
    pub executed: Vec<(SiteVariant, SiteReport)>,
    /// Variants restored from the manifest without re-running.
    pub restored: usize,
    /// Variants that exhausted their retry budget this run.
    pub failed: Vec<QuarantinedCell>,
    /// Variants still `pending` when the run stopped — nonzero only when
    /// a cooperative shutdown ([`crate::robust::shutdown`]) interrupted
    /// the run; `--resume` re-runs exactly these.
    pub interrupted: usize,
    /// The final `site_sweep_summary.csv` bytes (restored + fresh rows in
    /// grid order — byte-identical to an uninterrupted run).
    pub summary_csv: String,
    pub manifest_path: PathBuf,
}

/// Crash-safe [`run_site_sweep`]: a `manifest.json` in `dir` records every
/// variant's status and summary row, updated atomically as variants
/// finish. On a fresh directory this runs the whole grid; pointed at a
/// directory holding a matching manifest it skips `done` variants (after
/// verifying their exports are intact) and re-runs the rest. A variant
/// that panics or errors is retried per [`RetryPolicy`], then quarantined
/// — the remaining variants still run, and the final summary carries every
/// completed row.
#[cfg(feature = "host")]
#[deprecated(
    since = "0.2.0",
    note = "route through crate::api::execute_checkpointed with RunSpec::SiteSweep"
)]
pub fn run_site_sweep_checkpointed(
    gen: &mut Generator,
    grid: &SiteGrid,
    opts: &SiteOptions,
    dir: &Path,
    policy: &RetryPolicy,
) -> Result<SiteSweepOutcome> {
    grid.validate()?;
    prepare_site(gen, &grid.base)?;
    site_sweep_checkpointed_prepared(gen, grid, opts, dir, policy)
}

/// [`run_site_sweep_checkpointed`] over an already-prepared shared
/// generator (see [`prepare_site`]) — the `pub(crate)` engine behind
/// [`crate::api::execute_checkpointed`].
#[cfg(feature = "host")]
pub(crate) fn site_sweep_checkpointed_prepared(
    gen: &Generator,
    grid: &SiteGrid,
    opts: &SiteOptions,
    dir: &Path,
    policy: &RetryPolicy,
) -> Result<SiteSweepOutcome> {
    grid.validate()?;
    let variants = grid.expand();
    let ids: Vec<String> = variants.iter().map(|v| v.id.clone()).collect();
    let hash = content_hash("site_sweep", &grid.to_json(), &opts.identity_json());
    std::fs::create_dir_all(dir)?;
    let mpath = dir.join(SITE_SWEEP_MANIFEST);
    let mut manifest = if mpath.exists() {
        let m = RunManifest::load(&mpath)?;
        m.ensure_matches("site_sweep", &hash, &ids)?;
        m
    } else {
        RunManifest::new("site_sweep", &grid.name, hash, grid.to_json(), opts.record_json(), &ids)
    };
    manifest.reconcile_exports(dir);
    let restored = manifest.done_count();
    // Overlay columns are a static property of the expanded grid (a chain
    // is non-empty iff its spec lists a stage), so restored rows and fresh
    // rows agree on the table shape without re-running anything.
    let with_overlay = variants.iter().any(|v| {
        !v.spec.overlays.is_empty() || v.spec.facilities.iter().any(|f| !f.overlays.is_empty())
    });
    // The manifest always covers the FULL variant set (every shard of a
    // grid shares one manifest shape; `merge` unions done cells); sharding
    // only narrows which pending variants *this* process runs. Variants
    // another shard owns stay `pending` — normal, not an interruption.
    let todo: Vec<usize> = (0..variants.len())
        .filter(|&i| !manifest.is_done(&variants[i].id) && opts.owns_cell(&variants[i].id))
        .collect();
    let keeper = ManifestKeeper::new(manifest, mpath.clone())?;
    let gen_ro: &Generator = gen;
    let results = parallel_map_results(todo.len(), 1, |k| -> Result<Option<SiteReport>> {
        let variant = &variants[todo[k]];
        // Not yet started when shutdown arrived: stays `pending` in the
        // durable manifest, no attempt charged — `--resume` picks it up.
        if shutdown::requested() {
            return Ok(None);
        }
        let prior = keeper.with(|m| m.attempts(&variant.id));
        let vsink = DirSink::new(dir.join(&variant.id));
        let isolated = run_isolated(policy, prior, |deadline| {
            failpoint::hit("site.variant", &variant.id)?;
            run_site_inner(gen_ro, &variant.spec, opts, Some(&vsink as &dyn TraceSink), Some(deadline))
        });
        match isolated {
            Isolated::Done { value: report, attempts } => {
                let row = site_sweep_row(&variant.id, &report, with_overlay);
                let exports = variant_exports(dir, &variant.id)?;
                keeper.update(|m| {
                    if m.header.is_none() {
                        m.header = Some(site_sweep_header(Some(&report.site), with_overlay));
                    }
                    m.mark_done(&variant.id, attempts, row, exports);
                })?;
                Ok(Some(report))
            }
            // Interrupted mid-variant (the deadline check at a lockstep
            // barrier surfaced the shutdown request): not a failure — the
            // variant stays pending, uncharged, for --resume.
            Isolated::Failed { reason, .. } if shutdown::is_interrupt(&reason) => Ok(None),
            Isolated::Failed { attempts, reason } => {
                keeper.update(|m| m.mark_failed(&variant.id, attempts, reason))?;
                Ok(None)
            }
        }
    });
    // Only manifest-IO errors surface here; variant failures are already
    // quarantined in the manifest.
    let mut executed = Vec::new();
    for (k, r) in results.into_iter().enumerate() {
        let id = &variants[todo[k]].id;
        if let Some(report) = r.with_context(|| format!("site variant {id}"))? {
            executed.push((variants[todo[k]].clone(), report));
        }
    }
    let manifest = keeper.into_inner();
    let mut summary =
        manifest.header.clone().unwrap_or_else(|| site_sweep_header(None, with_overlay));
    for v in &variants {
        if let Some(row) = manifest.row(&v.id) {
            summary.push_str(row);
        }
    }
    grid.save(&dir.join("site_sweep.json"))?;
    fsx::atomic_write(&dir.join("site_sweep_summary.csv"), summary.as_bytes())?;
    let failed: Vec<QuarantinedCell> = variants
        .iter()
        .filter_map(|v| {
            let st = manifest.cells.get(&v.id)?;
            (st.status == CellStatus::Failed).then(|| QuarantinedCell {
                id: v.id.clone(),
                attempts: st.attempts,
                reason: st.reason.clone().unwrap_or_default(),
            })
        })
        .collect();
    let interrupted = variants
        .iter()
        .filter(|v| {
            opts.owns_cell(&v.id)
                && manifest.cells.get(&v.id).is_some_and(|st| st.status == CellStatus::Pending)
        })
        .count();
    Ok(SiteSweepOutcome {
        executed,
        restored,
        failed,
        interrupted,
        summary_csv: summary,
        manifest_path: mpath,
    })
}

/// Stat the three files every completed variant directory holds, as
/// manifest export records (relative paths, recorded sizes).
#[cfg(feature = "host")]
fn variant_exports(root: &Path, id: &str) -> Result<Vec<ExportRecord>> {
    let mut out = Vec::with_capacity(3);
    for name in ["site_load.csv", "site_summary.csv", "site_spec.json"] {
        let p = root.join(id).join(name);
        let meta = std::fs::metadata(&p).with_context(|| format!("stat export {}", p.display()))?;
        out.push(ExportRecord { path: format!("{id}/{name}"), bytes: meta.len() });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ScenarioSpec;

    fn grid() -> SiteGrid {
        let base = SiteSpec::staggered("tri", &ScenarioSpec::default_poisson("cfg", 0.5), 3, 0.0);
        SiteGrid {
            name: "spread_study".into(),
            base,
            phase_spreads_h: vec![0.0, 3.0],
            seeds: vec![0, 7],
            battery_kwh: Vec::new(),
            cap_w: Vec::new(),
            battery: None,
        }
    }

    fn battery_template() -> OverlaySpec {
        OverlaySpec::Battery {
            capacity_kwh: 1.0,
            power_w: 2e4,
            efficiency: 0.9,
            threshold_w: 9e4,
            initial_soc_frac: 0.0,
        }
    }

    #[test]
    fn expansion_is_deterministic_cross_product() {
        let g = grid();
        assert_eq!(g.n_variants(), 4);
        let a = g.expand();
        let b = g.expand();
        assert_eq!(a.len(), 4);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.spec, y.spec);
        }
        // Ids unique; phase ladder and seeds applied per facility.
        assert_eq!(a[0].id, "p0-s0");
        let last = &a[3]; // p1-s7, spread 3 h
        assert_eq!(last.spec.facilities[2].phase_offset_s, 2.0 * 3.0 * 3600.0);
        assert_eq!(last.spec.facilities[2].scenario().unwrap().seed, 9);
        last.spec.validate().unwrap();
    }

    #[test]
    fn json_roundtrip_and_validation() {
        let g = grid();
        let back = SiteGrid::from_json(&g.to_json()).unwrap();
        assert_eq!(back, g);
        // Overlay-free grids serialize without the overlay-axis fields.
        assert!(g.to_json().get_opt("battery_kwh").is_none());
        assert!(g.to_json().get_opt("battery").is_none());

        let mut g = grid();
        g.seeds.clear();
        assert!(g.validate().is_err());
        let mut g = grid();
        g.phase_spreads_h = vec![f64::INFINITY];
        assert!(g.validate().is_err());
    }

    #[test]
    fn battery_cap_axes_expand_with_stable_ids_and_overlays() {
        let mut g = grid();
        g.phase_spreads_h = vec![0.0];
        g.seeds = vec![5];
        g.battery_kwh = vec![0.0, 50.0];
        g.cap_w = vec![0.0, 1.2e5];
        g.battery = Some(battery_template());
        g.validate().unwrap();
        assert_eq!(g.n_variants(), 4);
        let v = g.expand();
        assert_eq!(v.len(), 4);
        let ids: Vec<&str> = v.iter().map(|x| x.id.as_str()).collect();
        assert_eq!(ids, vec!["p0-s5-b0-c0", "p0-s5-b0-c1", "p0-s5-b1-c0", "p0-s5-b1-c1"]);
        // Axis value 0 = stage omitted; the baseline rides along.
        assert!(v[0].spec.overlays.is_empty());
        assert_eq!(v[1].spec.overlays, vec![OverlaySpec::Cap { cap_w: 1.2e5 }]);
        // Battery precedes cap (shave first, clip the residual), with the
        // template's capacity replaced by the axis point.
        assert_eq!(v[3].spec.overlays.len(), 2);
        match &v[3].spec.overlays[0] {
            OverlaySpec::Battery { capacity_kwh, power_w, .. } => {
                assert_eq!(*capacity_kwh, 50.0);
                assert_eq!(*power_w, 2e4);
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(v[3].spec.overlays[1], OverlaySpec::Cap { cap_w: 1.2e5 });
        for x in &v {
            x.spec.validate().unwrap();
            assert_eq!(x.spec.name, format!("tri-{}", x.id));
        }
        // Expansion is deterministic, and the grid round-trips.
        let w = g.expand();
        for (a, b) in v.iter().zip(&w) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.spec, b.spec);
        }
        assert_eq!(SiteGrid::from_json(&g.to_json()).unwrap(), g);
    }

    #[test]
    fn overlay_axis_validation_rejects_bad_grids() {
        // A non-zero battery axis without a template is rejected.
        let mut g = grid();
        g.battery_kwh = vec![10.0];
        assert!(g.validate().is_err());
        // A template of the wrong kind is rejected.
        let mut g = grid();
        g.battery_kwh = vec![10.0];
        g.battery = Some(OverlaySpec::Cap { cap_w: 1.0 });
        assert!(g.validate().is_err());
        // Negative axis values are rejected.
        let mut g = grid();
        g.cap_w = vec![-1.0];
        assert!(g.validate().is_err());
        let mut g = grid();
        g.battery_kwh = vec![f64::NAN];
        g.battery = Some(battery_template());
        assert!(g.validate().is_err());
        // An all-zero battery axis needs no template.
        let mut g = grid();
        g.battery_kwh = vec![0.0];
        g.validate().unwrap();
    }
}
