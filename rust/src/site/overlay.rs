//! Net-load overlay pipeline: transform the composed (or per-facility) PCC
//! series — power caps, battery peak-shaving, PV offset — **as it streams**
//! past the site barrier, before export and characterization.
//!
//! The paper's site-level deliverable feeds infrastructure planning:
//! oversubscription, power modulation, and utility-facing load
//! characterization. The composition engine (PR 4) characterizes the raw
//! composed load; this module *modulates* it, turning the site path into a
//! planning tool — the cap-and-shave / PV-offset net-load shapes a utility
//! actually evaluates at an interconnection (see the related work on
//! workload composition and whole-facility power profiles).
//!
//! # Stages
//!
//! An overlay is an **ordered list** of stages ([`OverlaySpec`]), applied
//! left to right to every sample; order is part of the spec (a cap before
//! a battery clips what the battery would have shaved — the stages do not
//! commute, deliberately):
//!
//! * **`cap`** — hard power limit (a facility nameplate or the site
//!   interconnection cap): samples clip to `cap_w`; clipped energy and the
//!   violation duration (time the *input* exceeded the cap) are accounted.
//! * **`battery`** — a threshold peak-shaver with O(1) carried state:
//!   above `threshold_w` it discharges (bounded by `power_w` and the
//!   stored energy), below it recharges (bounded by `power_w` and the
//!   remaining capacity); a round-trip `efficiency` is split √η/√η across
//!   charge and discharge. State of charge carries across windows exactly
//!   like [`StreamingResampler`](crate::metrics::planning::StreamingResampler)
//!   carries partial sums — the fold is sample-granular, so any window
//!   partition of the series produces bit-identical output.
//! * **`pv`** — a diurnal irradiance profile (cos² bell of `daylight_h`
//!   hours peaking at `peak_hour`) subtracted to form net load. Offset is
//!   bounded by the instantaneous load (no-export convention: surplus PV
//!   is curtailed rather than driving the net load negative — the
//!   quantile/histogram machinery downstream assumes non-negative PCC
//!   power). Facility-level PV reuses the site spec's phase-shift
//!   machinery: [`OverlaySpec::shifted`] moves `peak_hour` by the
//!   facility's `phase_offset_s`, exactly as
//!   [`FacilitySpec::effective_scenario`](super::spec::FacilitySpec::effective_scenario)
//!   shifts the diurnal workload envelope.
//!
//! # Determinism and the identity surface
//!
//! Every stage is a deterministic f64 state machine advanced in series
//! order with O(1) carried state, so — like the facility and site folds
//! beneath it — overlay output is invariant to worker count and window
//! size ([`OverlayChain::apply_window`] asserts window contiguity). An
//! **empty overlay list is the identity**: the composition engine skips
//! the chain entirely (no f32→f64→f32 round trip, no extra summary
//! columns), so an overlay-free site run is byte-identical to the PR-4
//! path — the bit-identity surface the site integration tests pin.
//!
//! # Accounting
//!
//! Each chain folds a delta summary alongside the transformed series
//! ([`OverlaySummary`]): net/raw/shaved peak, the raw−net energy integral,
//! cap clip energy + violation duration, battery equivalent full cycles
//! and the SoC excursion, and the PV energy offset. The site engine
//! threads it through the shared characterization emitters into
//! `site_summary.csv` / `site_sweep_summary.csv` (columns `net_peak_w`,
//! `shaved_kwh`, `cap_violation_s`, …) — present only when some series
//! carries an overlay, so overlay-free exports keep their exact PR-4
//! header.

use crate::metrics::planning::joules_to_kwh;
use crate::util::json::{self, Json};
use anyhow::{bail, ensure, Context, Result};

/// One overlay stage of a net-load pipeline (see the module docs for the
/// semantics of each kind).
#[derive(Debug, Clone, PartialEq)]
pub enum OverlaySpec {
    /// Hard power cap (facility nameplate / interconnection limit), W.
    Cap { cap_w: f64 },
    /// Threshold peak-shaving battery with SoC carried across windows.
    Battery {
        capacity_kwh: f64,
        /// Max charge/discharge power at the terminals, W.
        power_w: f64,
        /// Round-trip efficiency in (0, 1]; split √η per direction.
        efficiency: f64,
        /// Discharge above, recharge below, W.
        threshold_w: f64,
        /// Initial state of charge as a fraction of capacity, [0, 1].
        initial_soc_frac: f64,
    },
    /// Diurnal PV offset: a cos² irradiance bell subtracted from load.
    Pv {
        /// Plant peak output, W.
        peak_w: f64,
        /// Hour of day the bell peaks at, [0, 24).
        peak_hour: f64,
        /// Width of the generation window, hours in (0, 24].
        daylight_h: f64,
    },
}

/// Default battery round-trip efficiency when the spec omits it.
pub const DEFAULT_BATTERY_EFFICIENCY: f64 = 0.9;
/// Default PV peak hour (solar noon) when the spec omits it.
pub const DEFAULT_PV_PEAK_HOUR: f64 = 12.0;
/// Default PV generation-window width when the spec omits it.
pub const DEFAULT_PV_DAYLIGHT_H: f64 = 12.0;

impl OverlaySpec {
    /// Stable kind tag (the JSON `kind` field and error-message label).
    pub fn kind(&self) -> &'static str {
        match self {
            OverlaySpec::Cap { .. } => "cap",
            OverlaySpec::Battery { .. } => "battery",
            OverlaySpec::Pv { .. } => "pv",
        }
    }

    /// Reject stages the overlay engine cannot run deterministically.
    pub fn validate(&self) -> Result<()> {
        match *self {
            OverlaySpec::Cap { cap_w } => {
                ensure!(
                    cap_w.is_finite() && cap_w > 0.0,
                    "cap overlay: cap_w must be positive W (got {cap_w})"
                );
            }
            OverlaySpec::Battery {
                capacity_kwh,
                power_w,
                efficiency,
                threshold_w,
                initial_soc_frac,
            } => {
                ensure!(
                    capacity_kwh.is_finite() && capacity_kwh > 0.0,
                    "battery overlay: capacity_kwh must be positive (got {capacity_kwh})"
                );
                ensure!(
                    power_w.is_finite() && power_w > 0.0,
                    "battery overlay: power_w must be positive W (got {power_w})"
                );
                ensure!(
                    efficiency.is_finite() && efficiency > 0.0 && efficiency <= 1.0,
                    "battery overlay: efficiency must be in (0, 1] (got {efficiency})"
                );
                ensure!(
                    threshold_w.is_finite() && threshold_w >= 0.0,
                    "battery overlay: threshold_w must be non-negative W (got {threshold_w})"
                );
                ensure!(
                    (0.0..=1.0).contains(&initial_soc_frac),
                    "battery overlay: initial_soc_frac must be in [0, 1] (got {initial_soc_frac})"
                );
            }
            OverlaySpec::Pv { peak_w, peak_hour, daylight_h } => {
                ensure!(
                    peak_w.is_finite() && peak_w > 0.0,
                    "pv overlay: peak_w must be positive W (got {peak_w})"
                );
                ensure!(
                    (0.0..24.0).contains(&peak_hour),
                    "pv overlay: peak_hour must be in [0, 24) (got {peak_hour})"
                );
                ensure!(
                    daylight_h.is_finite() && daylight_h > 0.0 && daylight_h <= 24.0,
                    "pv overlay: daylight_h must be in (0, 24] (got {daylight_h})"
                );
            }
        }
        Ok(())
    }

    /// This stage as seen from a facility with the given phase offset: PV
    /// peaks shift with the facility's timezone (the same wrap-on-24 h
    /// rule as the diurnal workload envelope); caps and batteries are
    /// clock-free and pass through unchanged.
    pub fn shifted(&self, phase_offset_s: f64) -> OverlaySpec {
        match *self {
            OverlaySpec::Pv { peak_w, peak_hour, daylight_h } => OverlaySpec::Pv {
                peak_w,
                peak_hour: (peak_hour + phase_offset_s / 3600.0).rem_euclid(24.0),
                daylight_h,
            },
            ref other => other.clone(),
        }
    }

    pub fn to_json(&self) -> Json {
        match *self {
            OverlaySpec::Cap { cap_w } => {
                json::obj([("kind", "cap".into()), ("cap_w", cap_w.into())])
            }
            OverlaySpec::Battery {
                capacity_kwh,
                power_w,
                efficiency,
                threshold_w,
                initial_soc_frac,
            } => {
                json::obj([
                    ("kind", "battery".into()),
                    ("capacity_kwh", capacity_kwh.into()),
                    ("power_w", power_w.into()),
                    ("efficiency", efficiency.into()),
                    ("threshold_w", threshold_w.into()),
                    ("initial_soc_frac", initial_soc_frac.into()),
                ])
            }
            OverlaySpec::Pv { peak_w, peak_hour, daylight_h } => json::obj([
                ("kind", "pv".into()),
                ("peak_w", peak_w.into()),
                ("peak_hour", peak_hour.into()),
                ("daylight_h", daylight_h.into()),
            ]),
        }
    }

    pub fn from_json(v: &Json) -> Result<OverlaySpec> {
        let kind = v.str_field("kind").map_err(anyhow::Error::from)?;
        let f = |key: &str, default: Option<f64>| -> Result<f64> {
            match (v.get_opt(key), default) {
                (Some(x), _) => x.as_f64().map_err(anyhow::Error::from),
                (None, Some(d)) => Ok(d),
                (None, None) => bail!("{kind} overlay: missing field '{key}'"),
            }
        };
        let spec = match kind.as_str() {
            "cap" => OverlaySpec::Cap { cap_w: f("cap_w", None)? },
            "battery" => OverlaySpec::Battery {
                capacity_kwh: f("capacity_kwh", None)?,
                power_w: f("power_w", None)?,
                efficiency: f("efficiency", Some(DEFAULT_BATTERY_EFFICIENCY))?,
                threshold_w: f("threshold_w", None)?,
                initial_soc_frac: f("initial_soc_frac", Some(0.0))?,
            },
            "pv" => OverlaySpec::Pv {
                peak_w: f("peak_w", None)?,
                peak_hour: f("peak_hour", Some(DEFAULT_PV_PEAK_HOUR))?,
                daylight_h: f("daylight_h", Some(DEFAULT_PV_DAYLIGHT_H))?,
            },
            other => bail!("unknown overlay kind '{other}' (expected cap | battery | pv)"),
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Parse a JSON **array** of overlay stages (the `overlays` spec field
    /// and the CLI `--overlay` file), preserving order.
    pub fn list_from_json(v: &Json) -> Result<Vec<OverlaySpec>> {
        v.as_arr()
            .map_err(anyhow::Error::from)?
            .iter()
            .enumerate()
            .map(|(i, o)| OverlaySpec::from_json(o).with_context(|| format!("overlays[{i}]")))
            .collect()
    }

    /// Serialize a stage list (order-preserving inverse of
    /// [`OverlaySpec::list_from_json`]).
    pub fn list_to_json(list: &[OverlaySpec]) -> Json {
        Json::Arr(list.iter().map(|o| o.to_json()).collect())
    }
}

/// Diurnal irradiance at absolute simulation time `t_s`: a cos² bell of
/// width `daylight_h` hours peaking at `peak_hour`, zero outside the
/// generation window, wrapped on the 24 h day. Pure function of time —
/// windows cannot desynchronize it.
pub fn pv_irradiance_w(peak_w: f64, peak_hour: f64, daylight_h: f64, t_s: f64) -> f64 {
    let h = (t_s / 3600.0).rem_euclid(24.0);
    let mut dh = h - peak_hour;
    if dh > 12.0 {
        dh -= 24.0;
    } else if dh < -12.0 {
        dh += 24.0;
    }
    if dh.abs() >= daylight_h / 2.0 {
        return 0.0;
    }
    let c = (std::f64::consts::PI * dh / daylight_h).cos();
    peak_w * c * c
}

/// Runtime state of one overlay stage: the spec plus the O(1) carry and
/// the per-stage accounting folds.
#[derive(Debug, Clone)]
enum Stage {
    Cap { cap_w: f64, clipped_j: f64, violation_s: f64 },
    Battery {
        cap_j: f64,
        power_w: f64,
        /// One-way efficiency √η (round-trip η split across directions).
        eff: f64,
        threshold_w: f64,
        soc_j: f64,
        soc_min_j: f64,
        soc_max_j: f64,
        discharged_j: f64,
        charged_j: f64,
    },
    Pv { peak_w: f64, peak_hour: f64, daylight_h: f64, offset_j: f64 },
}

impl Stage {
    fn new(spec: &OverlaySpec) -> Stage {
        match *spec {
            OverlaySpec::Cap { cap_w } => Stage::Cap { cap_w, clipped_j: 0.0, violation_s: 0.0 },
            OverlaySpec::Battery {
                capacity_kwh,
                power_w,
                efficiency,
                threshold_w,
                initial_soc_frac,
            } => {
                let cap_j = capacity_kwh * 3.6e6;
                let soc_j = initial_soc_frac * cap_j;
                Stage::Battery {
                    cap_j,
                    power_w,
                    eff: efficiency.sqrt(),
                    threshold_w,
                    soc_j,
                    soc_min_j: soc_j,
                    soc_max_j: soc_j,
                    discharged_j: 0.0,
                    charged_j: 0.0,
                }
            }
            OverlaySpec::Pv { peak_w, peak_hour, daylight_h } => {
                Stage::Pv { peak_w, peak_hour, daylight_h, offset_j: 0.0 }
            }
        }
    }

    /// Advance one sample: input power `x` (W) at absolute time `t_s`,
    /// held for `dt` seconds; returns the stage's output power.
    #[inline]
    fn transform(&mut self, x: f64, t_s: f64, dt: f64) -> f64 {
        match self {
            Stage::Cap { cap_w, clipped_j, violation_s } => {
                if x > *cap_w {
                    *clipped_j += (x - *cap_w) * dt;
                    *violation_s += dt;
                    *cap_w
                } else {
                    x
                }
            }
            Stage::Battery {
                cap_j,
                power_w,
                eff,
                threshold_w,
                soc_j,
                soc_min_j,
                soc_max_j,
                discharged_j,
                charged_j,
            } => {
                // Float comparisons route a NaN sample through unchanged
                // (both arms false), matching the downstream NaN policy.
                let out = if x > *threshold_w {
                    // Discharge toward the threshold: bounded by the power
                    // rating and by the energy deliverable at the
                    // terminals (stored × one-way efficiency).
                    let want = (x - *threshold_w).min(*power_w);
                    let avail_w = *soc_j * *eff / dt;
                    let p = want.min(avail_w).max(0.0);
                    *soc_j = (*soc_j - p * dt / *eff).max(0.0);
                    *discharged_j += p * dt;
                    x - p
                } else if x < *threshold_w {
                    // Recharge toward the threshold: bounded by the power
                    // rating and the headroom left in the store (terminal
                    // power × one-way efficiency is what gets stored).
                    // `want ≤ threshold − x` means a charging battery can
                    // never raise the net load above the threshold.
                    let want = (*threshold_w - x).min(*power_w);
                    let headroom_w = (*cap_j - *soc_j) / (dt * *eff);
                    let p = want.min(headroom_w).max(0.0);
                    *soc_j = (*soc_j + p * dt * *eff).min(*cap_j);
                    *charged_j += p * dt;
                    x + p
                } else {
                    x
                };
                *soc_min_j = soc_min_j.min(*soc_j);
                *soc_max_j = soc_max_j.max(*soc_j);
                out
            }
            Stage::Pv { peak_w, peak_hour, daylight_h, offset_j } => {
                let pv = pv_irradiance_w(*peak_w, *peak_hour, *daylight_h, t_s);
                // No-export convention: offset at most the instantaneous
                // load, so net load never goes negative (module docs).
                let used = pv.min(x).max(0.0);
                *offset_j += used * dt;
                x - used
            }
        }
    }
}

/// Delta summary of one finished overlay chain: what the modulation did to
/// the series, in planner units. Fields not applicable to the chain's
/// stage mix (e.g. battery columns of a cap-only chain) are zero.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct OverlaySummary {
    /// Peak of the raw (pre-overlay) series, W.
    pub raw_peak_w: f64,
    /// Peak of the net (post-overlay) series, W — tracked in f64 before
    /// the f32 write-back, so a cap stage bounds it *exactly*.
    pub net_peak_w: f64,
    /// `raw_peak_w − net_peak_w`. Negative when a stage raised the net
    /// peak — a battery whose `threshold_w` sits above the raw peak
    /// charges toward it (net load is bounded by `max(raw, threshold)`);
    /// size thresholds off the measured raw peak for pure shaving.
    pub shaved_peak_w: f64,
    /// `∫ (raw − net) dt` over the whole series, kWh. Slightly negative
    /// values are possible for a battery-only chain (charging losses add
    /// net energy).
    pub shaved_kwh: f64,
    /// Σ energy clipped by cap stages, kWh.
    pub cap_clipped_kwh: f64,
    /// Σ time any cap stage's *input* exceeded its cap, s.
    pub cap_violation_s: f64,
    /// Battery equivalent full cycles: terminal discharged energy ÷
    /// capacity, summed over battery stages.
    pub battery_cycles: f64,
    /// Lowest state of charge reached, as a fraction of capacity (first
    /// battery stage; 0 when the chain has none).
    pub soc_min_frac: f64,
    /// Highest state of charge reached, fraction of capacity.
    pub soc_max_frac: f64,
    /// Σ load energy offset by PV stages, kWh.
    pub pv_offset_kwh: f64,
}

/// A streaming overlay pipeline over one PCC series: the ordered stages
/// plus the chain-level accounting. Feed windows **in series order**
/// ([`OverlayChain::apply_window`] asserts contiguity); state carries
/// across windows, so any window partition yields bit-identical output.
#[derive(Debug, Clone)]
pub struct OverlayChain {
    dt_s: f64,
    stages: Vec<Stage>,
    raw_peak_w: f64,
    net_peak_w: f64,
    shaved_j: f64,
    samples: u64,
    next_step: u64,
}

impl OverlayChain {
    /// Build a chain from validated stage specs. `dt_s` is the sample
    /// interval of the series the chain will transform.
    pub fn new(specs: &[OverlaySpec], dt_s: f64) -> Result<OverlayChain> {
        ensure!(
            dt_s.is_finite() && dt_s > 0.0,
            "overlay chain: dt must be positive seconds (got {dt_s})"
        );
        for (i, s) in specs.iter().enumerate() {
            s.validate().with_context(|| format!("overlays[{i}]"))?;
        }
        Ok(OverlayChain {
            dt_s,
            stages: specs.iter().map(Stage::new).collect(),
            raw_peak_w: f64::NEG_INFINITY,
            net_peak_w: f64::NEG_INFINITY,
            shaved_j: 0.0,
            samples: 0,
            next_step: 0,
        })
    }

    /// `true` for a stage-free (identity) chain — callers skip the
    /// transform entirely, preserving the PR-4 byte-identity surface.
    pub fn is_empty(&self) -> bool {
        self.stages.is_empty()
    }

    /// Samples transformed so far.
    pub fn samples_seen(&self) -> u64 {
        self.samples
    }

    /// Transform one window in place. `t0_step` is the absolute series
    /// step of `window[0]` (sample *k* models time `k·dt`); windows must
    /// arrive contiguously in series order — carried state (battery SoC)
    /// is what makes the fold partition-invariant, and a gap would
    /// silently desynchronize the PV clock, so it is a programming error
    /// (assert), not an I/O error.
    pub fn apply_window(&mut self, t0_step: usize, window: &mut [f32]) {
        assert_eq!(
            t0_step as u64, self.next_step,
            "overlay chain: window starts at step {t0_step}, expected {}",
            self.next_step
        );
        for (i, w) in window.iter_mut().enumerate() {
            let t_s = (t0_step + i) as f64 * self.dt_s;
            let raw = *w as f64;
            let mut x = raw;
            for st in self.stages.iter_mut() {
                x = st.transform(x, t_s, self.dt_s);
            }
            self.raw_peak_w = self.raw_peak_w.max(raw);
            self.net_peak_w = self.net_peak_w.max(x);
            self.shaved_j += (raw - x) * self.dt_s;
            *w = x as f32;
        }
        self.samples += window.len() as u64;
        self.next_step += window.len() as u64;
    }

    /// The delta summary of everything folded so far (non-consuming — the
    /// site engine reads it after the last window).
    pub fn summary(&self) -> OverlaySummary {
        // Peaks stay zero until a sample was folded (NEG_INFINITY would
        // otherwise leak into the CSV of a zero-length series).
        let folded = self.samples > 0;
        let mut out = OverlaySummary {
            raw_peak_w: if folded { self.raw_peak_w } else { 0.0 },
            net_peak_w: if folded { self.net_peak_w } else { 0.0 },
            shaved_peak_w: if folded { self.raw_peak_w - self.net_peak_w } else { 0.0 },
            shaved_kwh: if folded { joules_to_kwh(self.shaved_j) } else { 0.0 },
            ..OverlaySummary::default()
        };
        let mut first_battery = true;
        for st in &self.stages {
            match st {
                Stage::Cap { clipped_j, violation_s, .. } => {
                    out.cap_clipped_kwh += joules_to_kwh(*clipped_j);
                    out.cap_violation_s += *violation_s;
                }
                Stage::Battery { cap_j, soc_min_j, soc_max_j, discharged_j, .. } => {
                    out.battery_cycles += discharged_j / cap_j;
                    // SoC excursion reported for the first battery stage
                    // (chains rarely carry more than one).
                    if first_battery {
                        out.soc_min_frac = soc_min_j / cap_j;
                        out.soc_max_frac = soc_max_j / cap_j;
                        first_battery = false;
                    }
                }
                Stage::Pv { offset_j, .. } => out.pv_offset_kwh += joules_to_kwh(*offset_j),
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::check;

    fn cap(cap_w: f64) -> OverlaySpec {
        OverlaySpec::Cap { cap_w }
    }

    fn battery(capacity_kwh: f64, power_w: f64, threshold_w: f64) -> OverlaySpec {
        OverlaySpec::Battery {
            capacity_kwh,
            power_w,
            efficiency: 0.9,
            threshold_w,
            initial_soc_frac: 0.0,
        }
    }

    /// Apply `specs` to `series` in one chain partitioned at `chunk`
    /// boundaries; returns the net series and the summary.
    fn run_chunked(
        specs: &[OverlaySpec],
        series: &[f32],
        dt: f64,
        chunk: usize,
    ) -> (Vec<f32>, OverlaySummary) {
        let mut chain = OverlayChain::new(specs, dt).unwrap();
        let mut out = series.to_vec();
        let mut t0 = 0;
        for c in out.chunks_mut(chunk) {
            chain.apply_window(t0, c);
            t0 += c.len();
        }
        (out, chain.summary())
    }

    fn wavy(n: usize) -> Vec<f32> {
        (0..n).map(|i| 2000.0 + 900.0 * ((i as f32) * 0.07).sin() + (i % 17) as f32).collect()
    }

    #[test]
    fn cap_clips_and_accounts_known_values() {
        let series = [100.0f32, 300.0, 500.0, 200.0];
        let (net, sum) = run_chunked(&[cap(250.0)], &series, 2.0, 4);
        assert_eq!(net, vec![100.0f32, 250.0, 250.0, 200.0]);
        assert_eq!(sum.net_peak_w, 250.0);
        assert_eq!(sum.raw_peak_w, 500.0);
        assert_eq!(sum.shaved_peak_w, 250.0);
        assert_eq!(sum.cap_violation_s, 4.0); // two samples × 2 s
        // (50 + 250) W × 2 s = 600 J.
        assert_eq!(sum.cap_clipped_kwh, 600.0 / 3.6e6);
        assert_eq!(sum.shaved_kwh.to_bits(), sum.cap_clipped_kwh.to_bits());
    }

    #[test]
    fn prop_cap_net_peak_bounded_and_shaved_equals_clip_integral() {
        // The satellite property: for ANY cap overlay, net_peak_w ≤ cap
        // and shaved_kwh equals the clip integral — bit-identical folds,
        // at any window partition.
        check("cap overlay bounds", |rng| {
            let n = 16 + rng.below(200);
            let dt = [0.25, 1.0, 7.5][rng.below(3)];
            let series: Vec<f32> = (0..n).map(|_| rng.range(0.0, 5e5) as f32).collect();
            let cap_w = rng.range(1e3, 6e5);
            let chunk = 1 + rng.below(n);
            let (net, sum) = run_chunked(&[cap(cap_w)], &series, dt, chunk);
            assert!(sum.net_peak_w <= cap_w, "net peak {} vs cap {cap_w}", sum.net_peak_w);
            // Identical accumulation order ⇒ identical bits.
            assert_eq!(sum.shaved_kwh.to_bits(), sum.cap_clipped_kwh.to_bits());
            // Against an independently folded reference integral: the
            // same sum of products, so within 1 scaled ulp.
            let clip_j: f64 =
                series.iter().map(|&x| ((x as f64) - cap_w).max(0.0) * dt).sum::<f64>();
            let tol = (clip_j / 3.6e6).abs() * 1e-12 + 1e-15;
            assert!(
                (sum.shaved_kwh - clip_j / 3.6e6).abs() <= tol,
                "shaved {} vs clip integral {}",
                sum.shaved_kwh,
                clip_j / 3.6e6
            );
            // Output samples never exceed the cap beyond f32 rounding.
            for &x in &net {
                assert!(x as f64 <= cap_w * (1.0 + 1e-6), "sample {x} above cap {cap_w}");
            }
            // Violation duration counts input samples above the cap.
            let above = series.iter().filter(|&&x| x as f64 > cap_w).count();
            assert_eq!(sum.cap_violation_s, above as f64 * dt);
        });
    }

    #[test]
    fn battery_soc_carry_is_window_partition_invariant() {
        // The streaming contract: any window partition — including ragged
        // 1-sample windows — produces bit-identical net series and
        // summaries, because SoC is carried exactly.
        let series = wavy(401);
        let dt = 0.5;
        let pv = OverlaySpec::Pv { peak_w: 400.0, peak_hour: 0.01, daylight_h: 12.0 };
        let specs = [battery(0.02, 600.0, 2300.0), cap(2700.0), pv];
        let (reference, ref_sum) = run_chunked(&specs, &series, dt, series.len());
        for chunk in [1usize, 7, 64, 400] {
            let (net, sum) = run_chunked(&specs, &series, dt, chunk);
            for (i, (a, b)) in net.iter().zip(&reference).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "chunk {chunk} sample {i}");
            }
            assert_eq!(sum, ref_sum, "chunk {chunk}");
        }
    }

    #[test]
    fn battery_shaves_peaks_and_respects_bounds() {
        // A square wave: long trough to charge, then a peak to shave.
        let mut series = vec![1000.0f32; 600];
        for x in series[300..].iter_mut() {
            *x = 3000.0;
        }
        let spec = battery(0.25, 800.0, 2000.0); // 0.25 kWh = 900 kJ
        let (net, sum) = run_chunked(&[spec], &series, 1.0, 37);
        // While charged energy lasts, the peak is held at the threshold.
        assert_eq!(net[300], 2200.0); // 3000 − 800 (power-limited)
        // The trough charges toward the threshold (power-limited).
        assert_eq!(net[0], 1800.0); // 1000 + 800
        assert!(sum.battery_cycles > 0.0);
        assert!(sum.soc_min_frac >= 0.0 && sum.soc_max_frac <= 1.0);
        assert!(sum.soc_min_frac <= sum.soc_max_frac);
        assert!(sum.net_peak_w < sum.raw_peak_w);
        // Net energy added is non-negative: round-trip losses mean the
        // battery never *creates* energy.
        assert!(sum.shaved_kwh <= 1e-12, "battery-only chain shaved {}", sum.shaved_kwh);
    }

    #[test]
    fn battery_with_full_initial_soc_discharges_immediately() {
        let spec = OverlaySpec::Battery {
            capacity_kwh: 1.0,
            power_w: 500.0,
            efficiency: 1.0,
            threshold_w: 900.0,
            initial_soc_frac: 1.0,
        };
        let series = [1200.0f32; 4];
        let (net, sum) = run_chunked(&[spec], &series, 1.0, 4);
        assert_eq!(net[0], 900.0);
        assert!(sum.battery_cycles > 0.0);
        assert!(sum.soc_max_frac == 1.0);
    }

    #[test]
    fn pv_offsets_by_daylight_and_never_drives_net_negative() {
        let pv = OverlaySpec::Pv { peak_w: 2000.0, peak_hour: 12.0, daylight_h: 12.0 };
        // One day at 1 h samples, constant 800 W load.
        let series = [800.0f32; 24];
        let (net, sum) = run_chunked(&[pv], &series, 3600.0, 24);
        // Midnight: no irradiance.
        assert_eq!(net[0], 800.0);
        // Noon: PV (2000 W) exceeds load — net floors at 0, surplus
        // curtailed.
        assert_eq!(net[12], 0.0);
        for &x in &net {
            assert!(x >= 0.0);
        }
        assert!(sum.pv_offset_kwh > 0.0);
        // Offset is bounded by the plant's irradiance integral.
        let pv_j: f64 =
            (0..24).map(|i| pv_irradiance_w(2000.0, 12.0, 12.0, i as f64 * 3600.0) * 3600.0).sum();
        assert!(sum.pv_offset_kwh <= pv_j / 3.6e6 + 1e-12);
        // The chain's raw−net integral is the PV offset (only stage); the
        // two folds differ by at most the subtraction's rounding.
        assert!((sum.shaved_kwh - sum.pv_offset_kwh).abs() < 1e-12);
    }

    #[test]
    fn pv_irradiance_shape() {
        assert_eq!(pv_irradiance_w(1000.0, 12.0, 12.0, 12.0 * 3600.0), 1000.0);
        assert_eq!(pv_irradiance_w(1000.0, 12.0, 12.0, 0.0), 0.0);
        assert_eq!(pv_irradiance_w(1000.0, 12.0, 12.0, 5.9 * 3600.0), 0.0);
        // Half-way out the bell: cos²(π/4) = 1/2.
        let x = pv_irradiance_w(1000.0, 12.0, 12.0, 9.0 * 3600.0);
        assert!((x - 500.0).abs() < 1e-9, "{x}");
        // Wraps on the day boundary (peak at midnight).
        let y = pv_irradiance_w(1000.0, 0.0, 12.0, 23.0 * 3600.0);
        assert!(y > 0.0);
        // Second day repeats the first.
        assert_eq!(
            pv_irradiance_w(1000.0, 12.0, 12.0, 9.0 * 3600.0),
            pv_irradiance_w(1000.0, 12.0, 12.0, (24.0 + 9.0) * 3600.0)
        );
    }

    #[test]
    fn stage_order_matters_and_is_preserved() {
        // Cap-then-battery ≠ battery-then-cap: the ordered list is the
        // spec, not a set.
        let series = [3000.0f32; 8];
        let b = OverlaySpec::Battery {
            capacity_kwh: 1.0,
            power_w: 500.0,
            efficiency: 1.0,
            threshold_w: 2000.0,
            initial_soc_frac: 1.0,
        };
        let (net_cb, sum_cb) = run_chunked(&[cap(2400.0), b.clone()], &series, 1.0, 8);
        let (net_bc, sum_bc) = run_chunked(&[b, cap(2400.0)], &series, 1.0, 8);
        // Cap first clips to 2400, battery shaves on to 2000.
        assert_eq!(net_cb[0], 2000.0);
        // Battery first shaves to 2500 (power-limited), cap clips to 2400.
        assert_eq!(net_bc[0], 2400.0);
        assert!(sum_cb.cap_clipped_kwh > sum_bc.cap_clipped_kwh);
    }

    #[test]
    fn empty_chain_is_identity() {
        let mut chain = OverlayChain::new(&[], 1.0).unwrap();
        assert!(chain.is_empty());
        let mut w = wavy(64);
        let original = w.clone();
        chain.apply_window(0, &mut w);
        for (a, b) in w.iter().zip(&original) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let sum = chain.summary();
        assert_eq!(sum.shaved_kwh, 0.0);
        assert_eq!(sum.net_peak_w.to_bits(), sum.raw_peak_w.to_bits());
    }

    #[test]
    #[should_panic(expected = "expected")]
    fn non_contiguous_windows_are_rejected() {
        let mut chain = OverlayChain::new(&[cap(100.0)], 1.0).unwrap();
        let mut w = [50.0f32; 4];
        chain.apply_window(0, &mut w);
        chain.apply_window(8, &mut w); // gap: steps 4..8 skipped
    }

    #[test]
    fn json_roundtrip_preserves_order_and_defaults_fill() {
        let specs = vec![
            cap(1.5e5),
            battery(50.0, 2e4, 1.2e5),
            OverlaySpec::Pv { peak_w: 3e4, peak_hour: 13.5, daylight_h: 10.0 },
        ];
        let back = OverlaySpec::list_from_json(&OverlaySpec::list_to_json(&specs)).unwrap();
        assert_eq!(back, specs);
        // Optional fields default.
        let v = json::parse(
            r#"[{"kind":"battery","capacity_kwh":10,"power_w":1000,"threshold_w":500}]"#,
        )
        .unwrap();
        match &OverlaySpec::list_from_json(&v).unwrap()[0] {
            OverlaySpec::Battery { efficiency, initial_soc_frac, .. } => {
                assert_eq!(*efficiency, DEFAULT_BATTERY_EFFICIENCY);
                assert_eq!(*initial_soc_frac, 0.0);
            }
            other => panic!("unexpected {other:?}"),
        }
        let v = json::parse(r#"[{"kind":"pv","peak_w":1000}]"#).unwrap();
        match &OverlaySpec::list_from_json(&v).unwrap()[0] {
            OverlaySpec::Pv { peak_hour, daylight_h, .. } => {
                assert_eq!(*peak_hour, DEFAULT_PV_PEAK_HOUR);
                assert_eq!(*daylight_h, DEFAULT_PV_DAYLIGHT_H);
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn validation_rejects_bad_stages() {
        assert!(cap(0.0).validate().is_err());
        assert!(cap(f64::NAN).validate().is_err());
        assert!(battery(-1.0, 100.0, 50.0).validate().is_err());
        assert!(battery(1.0, 0.0, 50.0).validate().is_err());
        let mut b = battery(1.0, 100.0, 50.0);
        if let OverlaySpec::Battery { ref mut efficiency, .. } = b {
            *efficiency = 1.5;
        }
        assert!(b.validate().is_err());
        let mut b = battery(1.0, 100.0, 50.0);
        if let OverlaySpec::Battery { ref mut initial_soc_frac, .. } = b {
            *initial_soc_frac = 2.0;
        }
        assert!(b.validate().is_err());
        assert!(OverlaySpec::Pv { peak_w: 1.0, peak_hour: 24.0, daylight_h: 12.0 }
            .validate()
            .is_err());
        assert!(OverlaySpec::Pv { peak_w: 1.0, peak_hour: 0.0, daylight_h: 0.0 }
            .validate()
            .is_err());
        assert!(OverlaySpec::from_json(&json::parse(r#"{"kind":"flywheel"}"#).unwrap()).is_err());
        assert!(OverlayChain::new(&[cap(100.0)], 0.0).is_err());
        assert!(OverlayChain::new(&[cap(-1.0)], 1.0).is_err());
    }

    #[test]
    fn pv_shifts_with_facility_phase_like_the_diurnal_envelope() {
        let pv = OverlaySpec::Pv { peak_w: 1e3, peak_hour: 12.0, daylight_h: 10.0 };
        match pv.shifted(3.0 * 3600.0) {
            OverlaySpec::Pv { peak_hour, .. } => assert_eq!(peak_hour, 15.0),
            other => panic!("unexpected {other:?}"),
        }
        // Wraps on 24 h, like FacilitySpec::effective_scenario.
        match pv.shifted(14.0 * 3600.0) {
            OverlaySpec::Pv { peak_hour, .. } => assert_eq!(peak_hour, 2.0),
            other => panic!("unexpected {other:?}"),
        }
        // Clock-free stages pass through.
        let c = cap(5e5);
        assert_eq!(c.shifted(7200.0), c);
    }
}
