//! The site composition engine — the paper's "from servers to **sites**"
//! layer: several facilities, each with its own topology, serving-config
//! mix, workload model, and timezone phase offset, driven in lockstep
//! through the windowed facility engine and summed at the utility point of
//! interconnection.
//!
//! What a capacity / interconnection study consumes is the *composed*
//! demand shape, not per-server traces: the load-duration curve, the
//! coincidence (diversity) factor between facility peaks, ramp-rate
//! distributions at utility dispatch/settlement intervals, and the
//! oversubscription headroom against an interconnection nameplate. This
//! module computes exactly that set, streamed with bounded memory so a
//! 10-facility × 7-day site run is routine:
//!
//! * [`SiteSpec`] / [`FacilitySpec`] — the planner-facing JSON
//!   (`spec`): facilities + phase offsets + nameplate + utility intervals.
//!   A facility is either a full inference scenario or a [`TrainingSpec`]
//!   archetype (deterministic step-function power — compute vs checkpoint
//!   phases), so one site composes mixed inference + training classes;
//! * [`run_site`] — the lockstep composition engine (`compose`): one
//!   windowed facility stream per facility, a rendezvous barrier per
//!   window, a bounded [`SiteAccumulator`](crate::aggregate::SiteAccumulator)
//!   fold, incremental `site_load.csv` export, and the deterministic
//!   byte-identity guarantees the facility layers already carry;
//! * [`SiteSeriesStats`] / [`SeriesSummary`] — the utility-facing
//!   characterization (`metrics`), shared by facility and site series;
//! * [`OverlaySpec`] / [`OverlayChain`] — the net-load overlay pipeline
//!   (`overlay`): power caps, battery peak-shaving, and PV offset applied
//!   per window as the composed (or per-facility) stream passes the
//!   barrier, with delta accounting in the summary exports;
//! * [`SiteGrid`] / [`run_site_sweep`] — the sweep axis (`sweep`):
//!   phase spreads × seeds (× battery size × cap) over one base site,
//!   with a crash-safe manifest-checkpointed variant
//!   ([`run_site_sweep_checkpointed`]) that supports `--resume`.
//!
//! CLI: `powertrace site --site <spec.json> --out <dir>` (plus
//! `--grid <sweep.json>` for the sweep axis and `--overlay <list.json>`
//! for ad-hoc site-level overlays); see `examples/site_interconnect.rs`
//! and `examples/peak_shaving.rs` for the library path.

pub mod compose;
pub mod metrics;
pub mod overlay;
pub mod spec;
pub mod sweep;

// The deprecated run_* entry points stay re-exported for source compat;
// new code routes through `crate::api`.
#[allow(deprecated)]
pub use compose::{run_site_prepared_sink, run_site_sink};
pub use compose::{prepare_site, FacilityReport, SiteOptions, SiteReport};
#[allow(deprecated)]
#[cfg(feature = "host")]
pub use compose::{run_site, run_site_prepared};
pub use metrics::{
    LoadDurationPoint, SeriesSummary, SiteSeriesStats, LOAD_DURATION_QUANTILES,
};
pub use overlay::{pv_irradiance_w, OverlayChain, OverlaySpec, OverlaySummary};
pub use spec::{
    FacilityKind, FacilitySpec, SiteSpec, TrainingSpec, DEFAULT_UTILITY_INTERVALS_S,
};
pub use sweep::{sweep_summary_csv, SiteGrid, SiteVariant};
#[allow(deprecated)]
#[cfg(feature = "host")]
pub use sweep::{run_site_sweep, run_site_sweep_checkpointed};
#[cfg(feature = "host")]
pub use sweep::{SiteSweepOutcome, SITE_SWEEP_MANIFEST};
