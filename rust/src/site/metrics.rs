//! Utility-facing series characterization: the per-stream fold the site
//! composition engine runs over every facility PCC series and over the
//! composed site series — planning stats, load-duration quantiles, and
//! ramp-rate distributions at the utility intervals, all streamed with
//! bounded memory (see [`crate::metrics::planning`] for the underlying
//! folds and their exactness guarantees).

use super::overlay::OverlaySummary;
use crate::metrics::planning::{PlanningStats, RampStats, StreamingPlanningStats, StreamingRamps};
use anyhow::Result;

/// Load-duration quantiles reported per series: the fraction of time the
/// load stays **below** each level (`0.99` → the level exceeded 1 % of the
/// time — the paper's oversubscription operating point).
pub const LOAD_DURATION_QUANTILES: [f64; 4] = [0.50, 0.90, 0.95, 0.99];

/// One point of the (quantile-sampled) load-duration curve.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LoadDurationPoint {
    /// Quantile in [0, 1] (fraction of time at or below `power_w`).
    pub q: f64,
    pub power_w: f64,
}

/// Finalized characterization of one PCC series.
#[derive(Debug, Clone)]
pub struct SeriesSummary {
    pub stats: PlanningStats,
    /// `true` when p99 / CV / load-duration came from retained samples
    /// (bit-identical to the buffered computation); `false` once the
    /// horizon spilled to the collapsing histogram.
    pub exact_quantiles: bool,
    /// Absolute error bound on `stats.p99_w` and the load-duration points
    /// (0 when exact).
    pub p99_bound_w: f64,
    /// Load-duration curve sampled at [`LOAD_DURATION_QUANTILES`].
    pub load_duration: Vec<LoadDurationPoint>,
    /// Ramp-rate distribution per utility interval, in spec order.
    pub ramps: Vec<RampStats>,
    /// Net-load overlay delta summary, when this series was transformed
    /// by an overlay chain (`stats` etc. then describe the **net** load).
    /// `None` for an overlay-free series — and the overlay columns stay
    /// out of the CSV exports entirely unless some row carries one.
    pub overlay: Option<OverlaySummary>,
}

/// Streaming characterization fold: planning stats + one
/// [`StreamingRamps`] per utility interval. Push the series window by
/// window (any partition — every fold is sample-granular), then
/// [`SiteSeriesStats::finalize`].
pub struct SiteSeriesStats {
    stats: StreamingPlanningStats,
    ramps: Vec<StreamingRamps>,
}

impl SiteSeriesStats {
    /// `ramp_interval_s` feeds `stats.max_ramp_w` (the headline
    /// [`PlanningStats`] ramp, clamped by the caller exactly as the sweep
    /// engine clamps it); `utility_intervals_s` get full distributions.
    pub fn new(
        dt_s: f64,
        ramp_interval_s: f64,
        utility_intervals_s: &[f64],
    ) -> Result<SiteSeriesStats> {
        Ok(SiteSeriesStats {
            stats: StreamingPlanningStats::new(dt_s, ramp_interval_s)?,
            ramps: utility_intervals_s
                .iter()
                .map(|&iv| StreamingRamps::new(dt_s, iv))
                .collect::<Result<Vec<_>>>()?,
        })
    }

    /// Fold one window of the PCC series, in series order.
    pub fn push_window(&mut self, pcc_w: &[f32]) {
        self.stats.push_slice(pcc_w);
        for r in self.ramps.iter_mut() {
            r.push_slice(pcc_w);
        }
    }

    pub fn finalize(self) -> Result<SeriesSummary> {
        let SiteSeriesStats { stats, ramps } = self;
        // Load-duration quantiles read before the stats fold is consumed —
        // batched, so the retained buffer is sorted once, and following
        // the p99 policy (see `StreamingPlanningStats::quantiles`).
        let load_duration = LOAD_DURATION_QUANTILES
            .iter()
            .zip(stats.quantiles(&LOAD_DURATION_QUANTILES)?)
            .map(|(&q, power_w)| LoadDurationPoint { q, power_w })
            .collect();
        let ramps = ramps.into_iter().map(|r| r.finalize()).collect::<Result<Vec<_>>>()?;
        let out = stats.finalize()?;
        Ok(SeriesSummary {
            stats: out.stats,
            exact_quantiles: out.exact_quantiles,
            p99_bound_w: out.p99_error_bound_w,
            load_duration,
            ramps,
            overlay: None,
        })
    }
}

/// The overlay delta columns appended when `with_overlay` is set — one
/// spelling, shared by [`characterization_header`]'s header and the docs.
pub(crate) const OVERLAY_COLUMNS: &str = ",net_peak_w,shaved_peak_w,shaved_kwh,cap_clipped_kwh,\
     cap_violation_s,battery_cycles,soc_min_frac,soc_max_frac,pv_offset_kwh";

/// Append one summary's load-duration + ramp **column names**
/// (`,ld_p50_w,…,ramp_max_300s_w,ramp_p99_300s_w,…`), plus the overlay
/// delta columns when `with_overlay` (set iff *some* row of the export
/// carries an overlay summary — the emitters must agree across all rows,
/// and an overlay-free export keeps its exact pre-overlay header). Shared
/// by `site_summary.csv` and `site_sweep_summary.csv`: `powertrace diff`
/// matches columns by header name, so the two exports must spell these
/// identically — one emitter makes drift impossible.
pub(crate) fn characterization_header(sum: &SeriesSummary, with_overlay: bool, s: &mut String) {
    for p in &sum.load_duration {
        s.push_str(&format!(",ld_p{}_w", (p.q * 100.0).round() as u32));
    }
    for r in &sum.ramps {
        let iv = crate::export::fmt_secs(r.interval_s);
        s.push_str(&format!(",ramp_max_{iv}s_w,ramp_p99_{iv}s_w"));
    }
    if with_overlay {
        s.push_str(OVERLAY_COLUMNS);
    }
}

/// Append one summary's load-duration + ramp **values**, in
/// [`characterization_header`] column order. With `with_overlay`, rows
/// without an overlay chain emit empty cells (empty == empty under
/// `powertrace diff`).
pub(crate) fn characterization_row(sum: &SeriesSummary, with_overlay: bool, s: &mut String) {
    for p in &sum.load_duration {
        s.push_str(&format!(",{}", p.power_w));
    }
    for r in &sum.ramps {
        s.push_str(&format!(",{},{}", r.max_w, r.p99_w));
    }
    if with_overlay {
        match &sum.overlay {
            Some(o) => s.push_str(&format!(
                ",{},{},{},{},{},{},{},{},{}",
                o.net_peak_w,
                o.shaved_peak_w,
                o.shaved_kwh,
                o.cap_clipped_kwh,
                o.cap_violation_s,
                o.battery_cycles,
                o.soc_min_frac,
                o.soc_max_frac,
                o.pv_offset_kwh
            )),
            None => s.push_str(",,,,,,,,,"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::planning::{max_ramp, percentile};

    fn wavy(n: usize) -> Vec<f32> {
        (0..n).map(|i| 2000.0 + 700.0 * ((i as f32) * 0.11).sin() + (i % 13) as f32).collect()
    }

    #[test]
    fn summary_matches_buffered_references() {
        let s = wavy(4000);
        let (dt, ramp_iv) = (0.25, 9.0);
        let intervals = [3.0, 9.0];
        let mut st = SiteSeriesStats::new(dt, ramp_iv, &intervals).unwrap();
        for chunk in s.chunks(61) {
            st.push_window(chunk);
        }
        let out = st.finalize().unwrap();
        assert!(out.exact_quantiles);
        assert_eq!(out.p99_bound_w, 0.0);
        let reference = PlanningStats::compute(&s, dt, ramp_iv).unwrap();
        assert_eq!(out.stats, reference);
        // Load-duration points are the interpolated percentiles, and the
        // p99 point agrees with stats.p99_w.
        for p in &out.load_duration {
            let want = percentile(&s, p.q * 100.0).unwrap();
            assert_eq!(p.power_w.to_bits(), want.to_bits(), "q {}", p.q);
        }
        assert_eq!(out.load_duration.last().unwrap().power_w.to_bits(), out.stats.p99_w.to_bits());
        // Monotone non-decreasing in q.
        for w in out.load_duration.windows(2) {
            assert!(w[0].power_w <= w[1].power_w);
        }
        // Per-interval ramp maxima match the buffered max_ramp.
        for (k, &iv) in intervals.iter().enumerate() {
            let want = max_ramp(&s, dt, iv).unwrap();
            assert_eq!(out.ramps[k].max_w.to_bits(), want.to_bits(), "interval {iv}");
            assert_eq!(out.ramps[k].interval_s, iv);
        }
    }

    #[test]
    fn empty_series_errors() {
        let st = SiteSeriesStats::new(1.0, 60.0, &[300.0]).unwrap();
        assert!(st.finalize().is_err());
    }

    #[test]
    fn overlay_columns_align_between_header_and_rows() {
        let mut st = SiteSeriesStats::new(1.0, 4.0, &[2.0]).unwrap();
        st.push_window(&wavy(64));
        let mut sum = st.finalize().unwrap();
        let count = |s: &str| s.matches(',').count();

        // Without overlays the emitters are unchanged (no extra columns).
        let (mut h0, mut r0) = (String::new(), String::new());
        characterization_header(&sum, false, &mut h0);
        characterization_row(&sum, false, &mut r0);
        assert_eq!(count(&h0), count(&r0));
        assert!(!h0.contains("net_peak_w"));

        // With overlays: header gains the delta columns; a row without a
        // chain pads with empty cells, a row with one fills them — both
        // aligned with the header.
        let (mut h1, mut r_none) = (String::new(), String::new());
        characterization_header(&sum, true, &mut h1);
        characterization_row(&sum, true, &mut r_none);
        assert_eq!(count(&h1), count(&r_none));
        assert!(h1.ends_with(OVERLAY_COLUMNS));
        sum.overlay = Some(crate::site::overlay::OverlaySummary {
            raw_peak_w: 10.0,
            net_peak_w: 8.0,
            ..Default::default()
        });
        let mut r_some = String::new();
        characterization_row(&sum, true, &mut r_some);
        assert_eq!(count(&h1), count(&r_some));
        assert!(r_some.contains(",8,"));
    }
}
