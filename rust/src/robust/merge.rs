//! `powertrace merge`: assemble sharded partial sweeps into the bytes an
//! unsharded run would have written.
//!
//! Each shard of a grid (`powertrace sweep --shard i/N`) runs only the
//! cells it owns but keeps a manifest over the **full** cell set — unowned
//! cells simply stay `pending`. Because every shard binds to the same
//! [`content_hash`](super::manifest::content_hash) (the shard is excluded
//! from manifest identity, like worker counts), merging is a plain
//! per-cell union: `done` beats `failed` beats `pending`, `done` rows are
//! replayed **verbatim** in grid order under the recorded header — the
//! same replay machinery `--resume` uses — so the assembled `summary.csv`
//! is byte-identical to an unsharded run's by construction, in any merge
//! order.
//!
//! The merged directory holds a full manifest (shard key stripped) and is
//! itself resumable: point `--resume` at it to run any cells no shard
//! covered. Per-cell export files are not copied — they stay under their
//! shard directories; the merged manifest drops the export records so
//! resume replays rows instead of demoting every cell over "missing"
//! files.

use super::fsx;
use super::manifest::{CellStatus, RunManifest};
use crate::scenarios::runner::summary_header;
use crate::scenarios::SweepGrid;
use crate::site::sweep::site_sweep_header;
use crate::site::SiteGrid;
use crate::util::json::{self, Json};
use anyhow::{bail, ensure, Context, Result};
use std::path::{Path, PathBuf};

/// What a completed merge wrote, plus the cells still outstanding.
pub struct MergeReport {
    /// `"sweep"` or `"site_sweep"`.
    pub kind: String,
    /// Manifests merged.
    pub inputs: usize,
    /// Total cells in the grid.
    pub cells: usize,
    /// Cells with a summary row in the merged output.
    pub done: usize,
    /// Quarantined cells (present only with `allow_partial`).
    pub failed: Vec<String>,
    /// Cells no input had run (present only with `allow_partial`).
    pub pending: Vec<String>,
    /// The merged `manifest.json` (resumable; shard key stripped).
    pub manifest_path: PathBuf,
    /// The assembled summary CSV.
    pub summary_path: PathBuf,
}

/// A CLI input is either a run directory or a manifest path; both sweep
/// kinds name their manifest `manifest.json`.
fn resolve_manifest(p: &Path) -> PathBuf {
    if p.is_dir() {
        p.join("manifest.json")
    } else {
        p.to_path_buf()
    }
}

/// Merge shard manifests into `out_dir`: the union manifest, the grid
/// snapshot, and the grid-order summary CSV. Unless `allow_partial`, every
/// cell must be `done` across the union — the whole point is byte-equality
/// with the unsharded run, and a partial summary can't deliver that.
pub fn merge_manifests(
    inputs: &[PathBuf],
    out_dir: &Path,
    allow_partial: bool,
) -> Result<MergeReport> {
    ensure!(!inputs.is_empty(), "merge: need at least one run directory or manifest");
    let mut manifests = Vec::with_capacity(inputs.len());
    for p in inputs {
        let mp = resolve_manifest(p);
        manifests.push(
            RunManifest::load(&mp).with_context(|| format!("loading {}", mp.display()))?,
        );
    }
    let mut merged = manifests[0].clone();
    for (i, m) in manifests.iter().enumerate().skip(1) {
        let at = inputs[i].display();
        ensure!(
            m.kind == merged.kind,
            "merge: {at} is a '{}' run but the first input is a '{}' run",
            m.kind,
            merged.kind
        );
        ensure!(
            m.grid_hash == merged.grid_hash,
            "merge: {at} has content hash {} but the first input has {} — \
             the shards ran different grids or different dt/ramp/scale options",
            m.grid_hash,
            merged.grid_hash
        );
        ensure!(
            m.cells.len() == merged.cells.len()
                && m.cells.keys().all(|id| merged.cells.contains_key(id)),
            "merge: {at} covers a different cell set than the first input"
        );
        for (id, st) in &m.cells {
            let base = merged.cells.get_mut(id).expect("cell set verified above");
            match (base.status, st.status) {
                (CellStatus::Done, CellStatus::Done) => {
                    // Same hash ⇒ same bytes; a mismatch means a shard's
                    // output was edited or corrupted. Refuse to guess.
                    ensure!(
                        base.row == st.row,
                        "merge: cell '{id}' has conflicting summary rows across inputs"
                    );
                }
                // Done always wins; a failure beats never-attempted.
                (_, CellStatus::Done) | (CellStatus::Pending, CellStatus::Failed) => {
                    *base = st.clone();
                }
                _ => {}
            }
        }
        if merged.header.is_none() {
            merged.header = m.header.clone();
        }
    }
    // The merged run is no one shard's run: drop the recorded shard so
    // `--resume` on the merged directory runs every remaining cell.
    if let Json::Obj(o) = &mut merged.options {
        o.remove("shard");
    }
    // Rows replay from the manifest; export files stay in the shard
    // directories (see module docs).
    for st in merged.cells.values_mut() {
        st.exports.clear();
    }
    // Grid-order assembly + per-kind artifact names, exactly as the
    // checkpointed runners write them.
    let (ids, header, summary_name, grid_name) = match merged.kind.as_str() {
        "sweep" => {
            let grid = SweepGrid::from_json(&merged.grid).context("merge: grid in manifest")?;
            let ids: Vec<String> = grid.expand().iter().map(|c| c.id.clone()).collect();
            let header = merged.header.clone().unwrap_or_else(|| summary_header().to_string());
            (ids, header, "summary.csv", "grid.json")
        }
        "site_sweep" => {
            let grid = SiteGrid::from_json(&merged.grid).context("merge: grid in manifest")?;
            let variants = grid.expand();
            // Same static table-shape rule as the checkpointed runner.
            let with_overlay = variants.iter().any(|v| {
                !v.spec.overlays.is_empty()
                    || v.spec.facilities.iter().any(|f| !f.overlays.is_empty())
            });
            let ids: Vec<String> = variants.iter().map(|v| v.id.clone()).collect();
            let header =
                merged.header.clone().unwrap_or_else(|| site_sweep_header(None, with_overlay));
            (ids, header, "site_sweep_summary.csv", "site_sweep.json")
        }
        other => bail!("merge: unsupported run kind '{other}' (sweep|site_sweep)"),
    };
    merged
        .ensure_matches(&merged.kind.clone(), &merged.grid_hash.clone(), &ids)
        .context("merge: manifest cells do not match the grid expansion")?;
    let mut failed = Vec::new();
    let mut pending = Vec::new();
    for id in &ids {
        match merged.cells[id].status {
            CellStatus::Done => {}
            CellStatus::Failed => failed.push(id.clone()),
            CellStatus::Pending => pending.push(id.clone()),
        }
    }
    if !allow_partial && !(failed.is_empty() && pending.is_empty()) {
        bail!(
            "merge: {} of {} cells incomplete (failed: [{}]; pending: [{}]) — \
             run the missing shards, resume the failed ones, or pass --allow-partial",
            failed.len() + pending.len(),
            ids.len(),
            failed.join(", "),
            pending.join(", "),
        );
    }
    let mut summary = header;
    for id in &ids {
        if let Some(row) = merged.row(id) {
            summary.push_str(row);
        }
    }
    std::fs::create_dir_all(out_dir)?;
    let manifest_path = out_dir.join("manifest.json");
    merged.save(&manifest_path)?;
    json::write_file(&out_dir.join(grid_name), &merged.grid).map_err(anyhow::Error::from)?;
    let summary_path = out_dir.join(summary_name);
    fsx::atomic_write(&summary_path, summary.as_bytes())?;
    Ok(MergeReport {
        kind: merged.kind.clone(),
        inputs: inputs.len(),
        cells: ids.len(),
        done: merged.done_count(),
        failed,
        pending,
        manifest_path,
        summary_path,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aggregate::Topology;
    use crate::config::{ServerAssignment, WorkloadSpec};
    use crate::robust::manifest::content_hash;
    use crate::scenarios::grid::GridDefaults;
    use crate::shard::Shard;

    fn grid() -> SweepGrid {
        SweepGrid {
            name: "m".into(),
            defaults: GridDefaults::default(),
            workloads: vec![
                WorkloadSpec::Poisson { rate: 0.25 },
                WorkloadSpec::Mmpp { mean_rate: 0.5, burstiness: 4.0 },
            ],
            topologies: vec![Topology { rows: 1, racks_per_row: 1, servers_per_rack: 2 }],
            fleets: vec![ServerAssignment::Uniform("a".into())],
            seeds: vec![0, 7],
        }
    }

    /// A shard's manifest: full cell set, owned cells `done` with a
    /// synthetic row, everything else `pending`.
    fn shard_manifest(g: &SweepGrid, shard: Shard) -> RunManifest {
        let identity = json::obj([("dt_s", Json::Num(0.25))]);
        let hash = content_hash("sweep", &g.to_json(), &identity);
        let ids: Vec<String> = g.expand().iter().map(|c| c.id.clone()).collect();
        let mut opts = json::obj([("dt_s", Json::Num(0.25))]);
        if let Json::Obj(o) = &mut opts {
            o.insert("shard".to_string(), Json::Str(shard.to_string()));
        }
        let mut m = RunManifest::new("sweep", &g.name, hash, g.to_json(), opts, &ids);
        m.header = Some(summary_header().to_string());
        for id in ids.iter().filter(|id| shard.owns(id)) {
            m.mark_done(id, 1, format!("{id},row\n"), Vec::new());
        }
        m
    }

    fn write_dir(name: &str, m: &RunManifest) -> PathBuf {
        let dir = std::env::temp_dir().join("powertrace_test_merge").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        m.save(&dir.join("manifest.json")).unwrap();
        dir
    }

    #[test]
    fn union_replays_rows_in_grid_order_and_strips_shard() {
        let g = grid();
        let dirs: Vec<PathBuf> = (0..3)
            .map(|i| write_dir(&format!("u{i}"), &shard_manifest(&g, Shard::new(i, 3).unwrap())))
            .collect();
        let out = std::env::temp_dir().join("powertrace_test_merge/u_out");
        let _ = std::fs::remove_dir_all(&out);
        let rep = merge_manifests(&dirs, &out, false).unwrap();
        assert_eq!((rep.cells, rep.done), (4, 4));
        assert!(rep.failed.is_empty() && rep.pending.is_empty());
        // Rows land in grid order regardless of which shard ran them.
        let expect: String = summary_header().to_string()
            + &g.expand().iter().map(|c| format!("{},row\n", c.id)).collect::<String>();
        assert_eq!(std::fs::read_to_string(&rep.summary_path).unwrap(), expect);
        // The merged manifest is whole-grid: same hash, no shard key.
        let m = RunManifest::load(&rep.manifest_path).unwrap();
        assert_eq!(m.grid_hash, shard_manifest(&g, Shard::new(0, 1).unwrap()).grid_hash);
        assert!(m.options.get_opt("shard").is_none());
        assert_eq!(m.done_count(), 4);
        // Merge order doesn't matter: reversed inputs, same summary bytes.
        let out2 = std::env::temp_dir().join("powertrace_test_merge/u_out2");
        let _ = std::fs::remove_dir_all(&out2);
        let rev: Vec<PathBuf> = dirs.iter().rev().cloned().collect();
        let rep2 = merge_manifests(&rev, &out2, false).unwrap();
        assert_eq!(
            std::fs::read_to_string(&rep2.summary_path).unwrap(),
            std::fs::read_to_string(&rep.summary_path).unwrap()
        );
    }

    #[test]
    fn incomplete_union_is_rejected_unless_allow_partial() {
        let g = grid();
        // Only shard 0/3 ran: the other cells are pending.
        let d = write_dir("p0", &shard_manifest(&g, Shard::new(0, 3).unwrap()));
        let out = std::env::temp_dir().join("powertrace_test_merge/p_out");
        let _ = std::fs::remove_dir_all(&out);
        let err = format!("{:#}", merge_manifests(&[d.clone()], &out, false).unwrap_err());
        assert!(err.contains("incomplete"), "{err}");
        let rep = merge_manifests(&[d], &out, true).unwrap();
        assert!(rep.done < rep.cells);
        assert!(!rep.pending.is_empty());
        // The partial summary still replays its done rows in grid order.
        let s = std::fs::read_to_string(&rep.summary_path).unwrap();
        assert!(s.starts_with(summary_header()));
    }

    #[test]
    fn mismatched_inputs_are_rejected() {
        let g = grid();
        let a = write_dir("m0", &shard_manifest(&g, Shard::new(0, 2).unwrap()));
        // Different identity options → different hash.
        let mut other = shard_manifest(&g, Shard::new(1, 2).unwrap());
        other.grid_hash = "fnv1a:0000000000000000".into();
        let b = write_dir("m1", &other);
        let out = std::env::temp_dir().join("powertrace_test_merge/m_out");
        let err = format!("{:#}", merge_manifests(&[a.clone(), b], &out, true).unwrap_err());
        assert!(err.contains("content hash"), "{err}");
        // Conflicting rows for the same done cell are refused.
        let mut c = shard_manifest(&g, Shard::new(0, 2).unwrap());
        for st in c.cells.values_mut() {
            if st.status == CellStatus::Done {
                st.row = Some("tampered\n".into());
            }
        }
        let cdir = write_dir("m2", &c);
        let err = format!("{:#}", merge_manifests(&[a, cdir], &out, true).unwrap_err());
        assert!(err.contains("conflicting summary rows"), "{err}");
    }
}
