//! Deterministic failure injection behind the `failpoints` feature.
//!
//! Call sites are unconditional — [`hit`] compiles to an inlined `Ok(())`
//! when the feature is off, so the production binary carries no registry,
//! no locking, and no branch. With `--features failpoints`, each named
//! site consults a process-wide registry of armed specs and can inject a
//! panic, an error, a stall, or a hard process abort — the same failure
//! menu the crash-safety layer must survive.
//!
//! # Sites
//!
//! | site                | tag                | threaded through                  |
//! |---------------------|--------------------|-----------------------------------|
//! | `sweep.cell`        | cell id            | start of every cell attempt       |
//! | `sweep.cell.window` | cell id            | each streamed generation window   |
//! | `export.write`      | export file name   | streaming + buffered CSV writers  |
//! | `site.variant`      | variant id         | start of every site-variant attempt |
//! | `site.window`       | site name          | each lockstep composition window  |
//!
//! # Arming
//!
//! Programmatic (tests): [`arm`] / [`clear_all`]. Process-level (CI kill
//! smokes): the `POWERTRACE_FAILPOINTS` environment variable, parsed on
//! first hit — `;`-separated `site[@tag]=action[*count]` clauses where
//! `action` is `panic` | `error` | `abort` | `sleep-<ms>` | `interrupt`
//! (request a cooperative shutdown, as SIGINT would), `tag` is a
//! substring match on the call-site tag (empty = any), and `*count`
//! bounds the number of firings (absent = unlimited). Example:
//!
//! ```text
//! POWERTRACE_FAILPOINTS='sweep.cell@w1=abort;export.write=error*1'
//! ```
//!
//! Matching and counting are deterministic: specs fire in armed order,
//! and all injection sites sit on deterministic execution paths — the
//! n-th window of cell `w1-t0-f0-s1` is the same work on every run.

#[cfg(not(feature = "failpoints"))]
#[inline(always)]
pub fn hit(_site: &str, _tag: &str) -> anyhow::Result<()> {
    Ok(())
}

#[cfg(feature = "failpoints")]
pub use imp::{arm, clear_all, hit, parse_specs, FailAction, FailSpec};

#[cfg(feature = "failpoints")]
mod imp {
    use anyhow::{bail, Context, Result};
    use std::sync::{Mutex, MutexGuard, OnceLock};

    /// What an armed failpoint does when it fires.
    #[derive(Debug, Clone, PartialEq)]
    pub enum FailAction {
        /// `panic!` at the call site (exercises `catch_unwind` isolation).
        Panic,
        /// Return an `anyhow` error from the call site.
        Error,
        /// `std::process::abort()` — the CI kill-and-resume smoke.
        Abort,
        /// Sleep this many milliseconds (exercises the soft deadline).
        SleepMs(u64),
        /// Request a cooperative shutdown
        /// ([`crate::robust::shutdown::request`]) and continue — the
        /// deterministic stand-in for SIGINT in interrupt-then-resume
        /// tests.
        Interrupt,
    }

    /// One armed injection spec.
    #[derive(Debug, Clone)]
    pub struct FailSpec {
        /// Site name, matched exactly.
        pub site: String,
        /// Substring the call-site tag must contain (empty = any tag).
        pub tag: String,
        pub action: FailAction,
        /// Remaining firings; `None` = unlimited.
        pub remaining: Option<u32>,
    }

    fn registry() -> MutexGuard<'static, Vec<FailSpec>> {
        static REG: OnceLock<Mutex<Vec<FailSpec>>> = OnceLock::new();
        let m = REG.get_or_init(|| {
            let specs = match std::env::var("POWERTRACE_FAILPOINTS") {
                Ok(s) => parse_specs(&s).expect("POWERTRACE_FAILPOINTS"),
                Err(_) => Vec::new(),
            };
            Mutex::new(specs)
        });
        // A panic injected while the lock is held is impossible (the lock
        // is released before any action runs), but a panicking *test*
        // poisoning the mutex must not cascade into later tests.
        m.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Arm one spec (appended after any env-armed specs).
    pub fn arm(spec: FailSpec) {
        registry().push(spec);
    }

    /// Disarm everything (tests call this on entry and exit).
    pub fn clear_all() {
        registry().clear();
    }

    /// Parse a `POWERTRACE_FAILPOINTS` value: `;`-separated
    /// `site[@tag]=action[*count]` clauses.
    pub fn parse_specs(s: &str) -> Result<Vec<FailSpec>> {
        let mut out = Vec::new();
        for part in s.split(';').map(str::trim).filter(|p| !p.is_empty()) {
            let (lhs, rhs) = part
                .split_once('=')
                .with_context(|| format!("failpoint '{part}': expected site[@tag]=action"))?;
            let (site, tag) = match lhs.split_once('@') {
                Some((s, t)) => (s, t),
                None => (lhs, ""),
            };
            let (act, remaining) = match rhs.split_once('*') {
                Some((a, n)) => (
                    a,
                    Some(n.parse::<u32>().with_context(|| format!("failpoint '{part}': count"))?),
                ),
                None => (rhs, None),
            };
            let action = match act.strip_prefix("sleep-") {
                Some(ms) => FailAction::SleepMs(
                    ms.parse().with_context(|| format!("failpoint '{part}': sleep ms"))?,
                ),
                None => match act {
                    "panic" => FailAction::Panic,
                    "error" => FailAction::Error,
                    "abort" => FailAction::Abort,
                    "interrupt" => FailAction::Interrupt,
                    other => bail!("failpoint '{part}': unknown action '{other}'"),
                },
            };
            out.push(FailSpec { site: site.to_string(), tag: tag.to_string(), action, remaining });
        }
        Ok(out)
    }

    /// The instrumented call site: fire the first matching armed spec.
    pub fn hit(site: &str, tag: &str) -> Result<()> {
        let action = {
            let mut reg = registry();
            let mut found = None;
            for spec in reg.iter_mut() {
                if spec.site != site || !tag.contains(spec.tag.as_str()) {
                    continue;
                }
                if spec.remaining == Some(0) {
                    continue;
                }
                if let Some(n) = spec.remaining.as_mut() {
                    *n -= 1;
                }
                found = Some(spec.action.clone());
                break;
            }
            found
        };
        match action {
            None => Ok(()),
            Some(FailAction::SleepMs(ms)) => {
                std::thread::sleep(std::time::Duration::from_millis(ms));
                Ok(())
            }
            Some(FailAction::Interrupt) => {
                crate::robust::shutdown::request();
                Ok(())
            }
            Some(FailAction::Error) => bail!("failpoint '{site}' ({tag}): injected error"),
            Some(FailAction::Panic) => panic!("failpoint '{site}' ({tag}): injected panic"),
            Some(FailAction::Abort) => {
                eprintln!("failpoint '{site}' ({tag}): aborting process");
                std::process::abort();
            }
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn parses_the_clause_grammar() {
            let specs =
                parse_specs("sweep.cell@w1=abort; export.write=error*1;x=sleep-250").unwrap();
            assert_eq!(specs.len(), 3);
            assert_eq!(specs[0].site, "sweep.cell");
            assert_eq!(specs[0].tag, "w1");
            assert_eq!(specs[0].action, FailAction::Abort);
            assert_eq!(specs[0].remaining, None);
            assert_eq!(specs[1].tag, "");
            assert_eq!(specs[1].action, FailAction::Error);
            assert_eq!(specs[1].remaining, Some(1));
            assert_eq!(specs[2].action, FailAction::SleepMs(250));
            let specs = parse_specs("sweep.cell.window=interrupt*1").unwrap();
            assert_eq!(specs[0].action, FailAction::Interrupt);
            assert_eq!(specs[0].remaining, Some(1));
            assert!(parse_specs("nope").is_err());
            assert!(parse_specs("a=explode").is_err());
            assert!(parse_specs("a=error*x").is_err());
            assert!(parse_specs("").unwrap().is_empty());
        }
    }
}
